//! Property tests for the structured-tracing subsystem (`bda::obs`):
//!
//! 1. **Zero perturbation**: decode output is bitwise identical with
//!    tracing on vs off — for MHA and BDA, at worker counts {1, 8}, under
//!    an overload pool that forces preempt→resume. Tracing observes the
//!    engine; it must never steer it.
//! 2. **Lifecycle coverage**: a traced overload run records every request
//!    lifecycle phase (enqueue → admit → prefill → token… → preempt →
//!    park → resume → complete) plus the thread-track phases, and the
//!    Chrome-trace export round-trips through the JSON parser.
//! 3. **Drain ordering**: flushing rings filled by concurrent producer
//!    threads yields a stream whose per-thread sequence numbers are
//!    strictly increasing (producer FIFO survives the merge).
//!
//! The enable gate and the recorder registry are process-global, so every
//! test serializes on one mutex and resets the gate + collection buffer
//! around its body (the lib unit tests never flip the gate for the same
//! reason — this binary owns it).

use bda::bd::Strategy;
use bda::coordinator::server::replay_trace;
use bda::coordinator::{BatcherConfig, KvCacheConfig, Request, SchedulerConfig, ServerConfig};
use bda::engine::PagedNativeBackend;
use bda::model::{ModelConfig, Transformer};
use bda::obs::{self, Phase};
use bda::tensor::DType;
use bda::util::json::Json;
use bda::util::threadpool::ThreadPool;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

static GATE: Mutex<()> = Mutex::new(());

/// Serialize on the process-global tracing state; a panicked holder must
/// not wedge the remaining tests.
fn serialized() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Drop anything a previous test (or an untraced run) left behind and put
/// the gate into a known state.
fn reset(enabled: bool) {
    obs::set_enabled(false);
    let _ = obs::take_collected();
    obs::set_enabled(enabled);
}

/// Overload geometry (mirrors `prop_preemption.rs`): 3-way concurrency
/// against a 10-block pool, 6 requests of 8 prompt + 10 new tokens — peak
/// demand 3 × 5 blocks, so decode must preempt.
fn overload_config() -> ServerConfig {
    ServerConfig {
        batcher: BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(0) },
        scheduler: SchedulerConfig {
            max_active: 3,
            eos_token: None,
            kv: KvCacheConfig { block_size: 4, num_blocks: 10, ..Default::default() },
            ..Default::default()
        },
    }
}

fn overload_trace(vocab: u32) -> Vec<Request> {
    (0..6u64)
        .map(|i| {
            let prompt: Vec<u32> =
                (0..8u64).map(|j| ((i * 37 + j * 13 + 5) % vocab as u64) as u32).collect();
            Request::new(i, prompt, 10)
        })
        .collect()
}

type Generations = Vec<(u64, Vec<u32>)>;

fn run_overload(model: &Transformer, workers: usize) -> (Generations, u64) {
    let cfg = overload_config();
    let pool = Arc::new(ThreadPool::new(workers));
    let backend = PagedNativeBackend::with_thread_pool(model.clone(), cfg.scheduler.kv, pool);
    let trace = overload_trace(model.config.vocab_size as u32);
    let (mut responses, metrics) = replay_trace(backend, cfg, trace).expect("overload serve");
    responses.sort_by_key(|r| r.id);
    let generations = responses.into_iter().map(|r| (r.id, r.tokens)).collect();
    (generations, metrics.snapshot().preemptions)
}

#[test]
fn prop_decode_bitwise_identical_with_tracing_on_vs_off() {
    let _g = serialized();
    let mha = Transformer::new_mha(ModelConfig::tiny(), 881);
    let bda = mha.to_bda(Strategy::ResidualMin, DType::F32).expect("bda prep");
    for (label, model) in [("mha", &mha), ("bda", &bda)] {
        for workers in [1usize, 8] {
            let tag = format!("{label}/workers={workers}");
            reset(false);
            let (off_gen, off_preempt) = run_overload(model, workers);
            assert!(off_preempt > 0, "{tag}: the overload pool must preempt");
            assert!(
                obs::take_collected().is_empty(),
                "{tag}: a disabled trace must record nothing"
            );

            reset(true);
            let (on_gen, on_preempt) = run_overload(model, workers);
            let events = obs::take_collected();
            obs::set_enabled(false);
            assert!(!events.is_empty(), "{tag}: an enabled trace must record");
            assert_eq!(on_preempt, off_preempt, "{tag}: tracing changed scheduling");
            assert_eq!(
                on_gen, off_gen,
                "{tag}: tracing on vs off changed decode output (must be bitwise identical)"
            );
        }
    }
}

#[test]
fn traced_overload_run_covers_full_request_lifecycle() {
    let _g = serialized();
    reset(true);
    let model = Transformer::new_mha(ModelConfig::tiny(), 882);
    let (generations, preemptions) = run_overload(&model, 2);
    let events = obs::take_collected();
    obs::set_enabled(false);
    assert_eq!(generations.len(), 6);
    assert!(preemptions > 0, "lifecycle coverage needs a preempting run");

    // Every lifecycle phase must appear, plus the decode-path thread
    // tracks (the paged engine instruments attn/gemm; the scheduler
    // emits decode_step/sample).
    let count = |p: Phase| events.iter().filter(|e| e.phase == p).count();
    for phase in [
        Phase::Enqueue,
        Phase::Admit,
        Phase::Prefill,
        Phase::PrefillChunk,
        Phase::Token,
        Phase::Preempt,
        Phase::Park,
        Phase::Resume,
        Phase::Complete,
        Phase::DecodeStep,
        Phase::Attn,
        Phase::Gemm,
        Phase::Sample,
    ] {
        assert!(count(phase) >= 1, "phase {} missing from the trace", phase.name());
    }
    // One complete per request; every preemption parks and resumes.
    assert_eq!(count(Phase::Complete), 6);
    assert_eq!(count(Phase::Preempt), preemptions as usize);
    assert_eq!(count(Phase::Park), count(Phase::Resume));

    // Per-sequence timelines: 6 sequences, each with ≥ 10 tokens, and at
    // least one preempted timeline whose TBT series still covers the gap.
    let timelines = bda::obs::timeline::timelines(&events);
    assert_eq!(timelines.len(), 6);
    assert!(timelines.iter().all(|t| t.token_times_ns().len() >= 10));
    assert!(timelines.iter().any(|t| t.preempted()));
    assert!(timelines.iter().all(|t| !t.tbt_secs().is_empty()));

    // The Chrome-trace export is valid JSON and carries every event as an
    // "X" record (plus "M" track-name metadata).
    let doc = bda::obs::export::chrome_trace(&events, &obs::thread_labels());
    let reparsed = Json::parse(&doc.to_string()).expect("exported trace must parse");
    let arr = reparsed.get("traceEvents").as_arr().expect("traceEvents");
    let xs = arr.iter().filter(|e| e.get("ph").as_str() == Some("X")).count();
    assert_eq!(xs, events.len());
}

#[test]
fn flush_preserves_per_thread_seqno_order_under_concurrent_producers() {
    let _g = serialized();
    reset(true);
    const THREADS: usize = 4;
    const PER_THREAD: u64 = 256; // well under the 4096-event ring capacity
    std::thread::scope(|s| {
        for t in 0..THREADS {
            s.spawn(move || {
                for i in 0..PER_THREAD {
                    // id encodes (producer, local index) so the merged
                    // stream can be checked for per-producer FIFO.
                    obs::instant(Phase::Work, ((t as u64) << 32) | i);
                }
            });
        }
    });
    let events = obs::take_collected();
    obs::set_enabled(false);
    let work: Vec<_> = events.iter().filter(|e| e.phase == Phase::Work).collect();
    assert_eq!(work.len(), THREADS * PER_THREAD as usize, "no event may be lost");

    // Per recording thread: seqnos strictly increase (producer order
    // survives the drain) and local indices arrive in FIFO order.
    let mut tids: Vec<u32> = work.iter().map(|e| e.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    assert_eq!(tids.len(), THREADS, "each producer thread gets its own ring");
    for tid in tids {
        let mine: Vec<_> = work.iter().filter(|e| e.tid == tid).collect();
        assert!(
            mine.windows(2).all(|w| w[0].seqno < w[1].seqno),
            "tid {tid}: drained seqnos must be strictly increasing"
        );
        assert!(
            mine.windows(2).all(|w| (w[0].id & 0xffff_ffff) < (w[1].id & 0xffff_ffff)),
            "tid {tid}: producer FIFO order must survive the drain"
        );
    }
    // The merged stream carries globally unique seqnos.
    let mut seqnos: Vec<u64> = work.iter().map(|e| e.seqno).collect();
    seqnos.sort_unstable();
    seqnos.dedup();
    assert_eq!(seqnos.len(), work.len());
}
