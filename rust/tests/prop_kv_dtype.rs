//! Property tests for engine invariant 7: a pool storing K/V blocks as
//! real 16-bit words (`KvDtype::F16` / `BF16`) produces generations
//! **bitwise identical** to an f32 pool whose writes pass through
//! `DType::quantize_slice` — quantize-at-write is the reference
//! semantics, so every existing bitwise invariant (parallel == serial,
//! cache hit == cold prefill, preempt→resume == uninterrupted, chunked ==
//! monolithic) extends to 16-bit storage by composition.
//!
//! The matrix: MHA and BDA × {fp16, bf16} × worker counts {1, 8} ×
//! prefix cache {off, on} × prefill chunk budgets {4, 0}, on an ample
//! pool; then a deliberately tiny pool that forces preempt→resume with
//! the radix tree live, so donated-then-readopted blocks are proven
//! bit-stable in 16-bit storage too.
//!
//! The "small" pool size honors `BDA_TEST_POOL_BLOCKS` (the same knob the
//! CI overload matrix pins for `prop_preemption`), clamped so one
//! sequence always fits alone.

use bda::bd::Strategy;
use bda::coordinator::kv_cache::test_pool_blocks;
use bda::coordinator::server::replay_trace;
use bda::coordinator::{BatcherConfig, KvCacheConfig, Request, SchedulerConfig, ServerConfig};
use bda::engine::PagedNativeBackend;
use bda::model::{ModelConfig, Transformer};
use bda::tensor::DType;
use bda::util::threadpool::ThreadPool;
use std::sync::Arc;
use std::time::Duration;

/// Overload pool size (see `prop_preemption`): env knob clamped so a
/// single sequence fits alone, 10 blocks otherwise — anything below 15
/// exhausts mid-decode at concurrency 3.
fn overload_pool_blocks() -> usize {
    test_pool_blocks().map(|n| n.clamp(6, 64)).unwrap_or(10)
}

fn server_config(num_blocks: usize, dtype: DType) -> ServerConfig {
    ServerConfig {
        batcher: BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(0) },
        scheduler: SchedulerConfig {
            max_active: 3,
            eos_token: None,
            kv: KvCacheConfig { block_size: 4, num_blocks, dtype },
            ..Default::default()
        },
    }
}

/// 6 requests with distinct 8-token prompts sharing no prefix, 10 new
/// tokens each: peak demand 3 × 5 blocks at concurrency 3.
fn trace(vocab: u32) -> Vec<Request> {
    (0..6u64)
        .map(|i| {
            let prompt: Vec<u32> =
                (0..8u64).map(|j| ((i * 37 + j * 13 + 5) % vocab as u64) as u32).collect();
            Request::new(i, prompt, 10)
        })
        .collect()
}

type Generations = Vec<(u64, Vec<u32>)>;

/// One serving run. With `quantize_ref` set, the pool stores f32 but
/// every K/V write is passed through `quantize_slice(dtype)` — the
/// reference semantics a real 16-bit pool must reproduce bitwise.
fn run(
    model: &Transformer,
    dtype: DType,
    quantize_ref: bool,
    workers: usize,
    cache: bool,
    chunk: usize,
    num_blocks: usize,
) -> (Generations, bda::coordinator::metrics::Snapshot) {
    let storage = if quantize_ref { DType::F32 } else { dtype };
    let mut cfg = server_config(num_blocks, storage);
    cfg.scheduler.prefill_chunk = chunk;
    let pool = Arc::new(ThreadPool::new(workers));
    let mut backend = PagedNativeBackend::with_thread_pool(model.clone(), cfg.scheduler.kv, pool);
    if quantize_ref {
        backend.set_kv_write_quantize(dtype);
    }
    backend.set_prefix_cache(cache);
    let t = trace(model.config.vocab_size as u32);
    let (mut responses, metrics) = replay_trace(backend, cfg, t).expect("kv dtype serve");
    responses.sort_by_key(|r| r.id);
    let generations = responses.into_iter().map(|r| (r.id, r.tokens)).collect();
    (generations, metrics.snapshot())
}

/// Invariant 7 across the full serving matrix on an ample pool: real
/// 16-bit storage == quantize-at-write f32 storage, bitwise, for every
/// (model, dtype, workers, prefix cache, chunk budget) combination.
#[test]
fn prop_16bit_pool_bitwise_equals_quantize_at_write_f32_pool() {
    let mha = Transformer::new_mha(ModelConfig::tiny(), 881);
    let bda = mha.to_bda(Strategy::ResidualMin, DType::F32).expect("bda prep");
    for (label, model) in [("mha", &mha), ("bda", &bda)] {
        for dtype in [DType::F16, DType::BF16] {
            for workers in [1usize, 8] {
                for cache in [false, true] {
                    for chunk in [4usize, 0] {
                        let tag = format!(
                            "{label}/{}/workers={workers}/cache={cache}/chunk={chunk}",
                            dtype.name()
                        );
                        let (narrow_gen, narrow_snap) =
                            run(model, dtype, false, workers, cache, chunk, 512);
                        let (ref_gen, ref_snap) =
                            run(model, dtype, true, workers, cache, chunk, 512);
                        assert_eq!(narrow_gen.len(), 6, "{tag}: lost responses");
                        assert_eq!(
                            narrow_gen, ref_gen,
                            "{tag}: 16-bit pool diverged from quantize-at-write f32 \
                             reference (invariant 7 violated)"
                        );
                        // The metrics surface must be honest about storage:
                        // the 16-bit pool reports half the reference's bytes.
                        assert_eq!(narrow_snap.kv_dtype, Some(dtype.name()), "{tag}");
                        assert_eq!(ref_snap.kv_dtype, Some(DType::F32.name()), "{tag}");
                        assert_eq!(
                            narrow_snap.kv_pool_bytes * 2,
                            ref_snap.kv_pool_bytes,
                            "{tag}: 16-bit pool bytes must be half of f32"
                        );
                    }
                }
            }
        }
    }
}

/// Invariant 7 under pool exhaustion with the radix tree live: preempted
/// sequences donate blocks to the prefix cache, later admissions readopt
/// them, and resumes recompute through chunked prefill — all on 16-bit
/// words moved verbatim (block copies never re-round), so the tiny-pool
/// run must still match the quantize-at-write reference bitwise, and
/// both runs must make identical scheduling decisions (same preemption
/// and resume counts — storage width changes bytes, never behavior at a
/// fixed block count).
#[test]
fn prop_16bit_pool_bitwise_through_preempt_and_readoption() {
    let mha = Transformer::new_mha(ModelConfig::tiny(), 883);
    let bda = mha.to_bda(Strategy::ResidualMin, DType::F32).expect("bda prep");
    let small = overload_pool_blocks();
    for (label, model) in [("mha", &mha), ("bda", &bda)] {
        for dtype in [DType::F16, DType::BF16] {
            let tag = format!("{label}/{}/blocks={small}", dtype.name());
            let (narrow_gen, narrow_snap) = run(model, dtype, false, 2, true, 4, small);
            let (ref_gen, ref_snap) = run(model, dtype, true, 2, true, 4, small);
            if small < 15 {
                assert!(
                    narrow_snap.preemptions > 0,
                    "{tag}: a {small}-block pool must force preemption"
                );
            }
            assert_eq!(
                (narrow_snap.preemptions, narrow_snap.resumes, narrow_snap.recomputed_tokens),
                (ref_snap.preemptions, ref_snap.resumes, ref_snap.recomputed_tokens),
                "{tag}: storage width changed scheduling behavior"
            );
            assert_eq!(
                narrow_gen, ref_gen,
                "{tag}: preempt→donate→readopt→resume on 16-bit storage diverged \
                 from the quantize-at-write reference (invariant 7 violated)"
            );
        }
    }
}

/// The env-default construction path (`BDA_KV_DTYPE` → `KvCacheConfig::
/// default()` → engine): what each CI determinism-matrix cell actually
/// pins. Whatever dtype the env selects, the engine must honor it and
/// reproduce the quantize-at-write reference for that dtype bitwise
/// (trivially so for f32, where the reference is the identity).
#[test]
fn env_default_engine_matches_quantize_at_write_reference() {
    let model = Transformer::new_mha(ModelConfig::tiny(), 887);
    let env_dtype = KvCacheConfig::default().dtype;
    let cfg = server_config(512, env_dtype);
    let backend = PagedNativeBackend::new(model.clone(), cfg.scheduler.kv);
    let t = trace(model.config.vocab_size as u32);
    let (mut responses, _) = replay_trace(backend, cfg, t).expect("env serve");
    responses.sort_by_key(|r| r.id);
    let env_gen: Generations = responses.into_iter().map(|r| (r.id, r.tokens)).collect();
    // The reference run pins its own workers/cache/chunk knobs: invariants
    // 2, 4, and 6 make all of those bitwise-neutral, so any difference
    // here is attributable to storage width alone.
    let (ref_gen, _) = if env_dtype == DType::F32 {
        (env_gen.clone(), None)
    } else {
        let (g, s) = run(&model, env_dtype, true, 2, true, 0, 512);
        (g, Some(s))
    };
    assert_eq!(
        env_gen,
        ref_gen,
        "env-default engine ({}) violated invariant 7",
        env_dtype.name()
    );
}
