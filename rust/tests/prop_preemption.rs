//! Property tests for engine invariant 5: a run forced through
//! preempt→resume by a tiny block pool is **bitwise-identical** to an
//! uninterrupted run on an ample pool — same responses, same tokens —
//! for MHA and BDA, at worker counts {1, 2, 8}, with the prefix cache on
//! and off. Preemption (victim eviction + recompute-on-resume) must trade
//! recompute for memory, never output.
//!
//! The "small" pool size honors the `BDA_TEST_POOL_BLOCKS` overload knob
//! (see `coordinator::kv_cache::test_pool_blocks`), so CI's determinism
//! matrix can force the preempt/resume path in every
//! threads × prefix-cache configuration rather than relying on one
//! hand-built fixture.

use bda::bd::Strategy;
use bda::coordinator::kv_cache::test_pool_blocks;
use bda::coordinator::server::replay_trace;
use bda::coordinator::{BatcherConfig, KvCacheConfig, Request, SchedulerConfig, ServerConfig};
use bda::engine::PagedNativeBackend;
use bda::model::{ModelConfig, Transformer};
use bda::tensor::DType;
use bda::util::threadpool::ThreadPool;
use std::sync::Arc;
use std::time::Duration;

/// The overload pool size: the env knob when set (clamped so one sequence
/// always fits alone — 8-token prompts + 10 generated = 5 blocks of 4),
/// a hand-tuned 10 otherwise. At concurrency 3, anything below 15 blocks
/// is guaranteed to exhaust mid-decode (3 × 5-block peak demand).
fn overload_pool_blocks() -> usize {
    test_pool_blocks().map(|n| n.clamp(6, 64)).unwrap_or(10)
}

fn server_config(num_blocks: usize) -> ServerConfig {
    ServerConfig {
        batcher: BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(0) },
        scheduler: SchedulerConfig {
            max_active: 3,
            eos_token: None,
            kv: KvCacheConfig { block_size: 4, num_blocks, ..Default::default() },
            ..Default::default()
        },
    }
}

/// 6 requests with distinct 8-token prompts, 10 new tokens each: peak
/// demand 3 × 5 blocks at concurrency 3.
fn overload_trace(vocab: u32) -> Vec<Request> {
    (0..6u64)
        .map(|i| {
            let prompt: Vec<u32> =
                (0..8u64).map(|j| ((i * 37 + j * 13 + 5) % vocab as u64) as u32).collect();
            Request::new(i, prompt, 10)
        })
        .collect()
}

type Generations = Vec<(u64, Vec<u32>)>;

fn run_with_pool(
    model: &Transformer,
    workers: usize,
    cache: bool,
    num_blocks: usize,
) -> (Generations, bda::coordinator::metrics::Snapshot) {
    let cfg = server_config(num_blocks);
    let pool = Arc::new(ThreadPool::new(workers));
    let mut backend = PagedNativeBackend::with_thread_pool(model.clone(), cfg.scheduler.kv, pool);
    backend.set_prefix_cache(cache);
    let trace = overload_trace(model.config.vocab_size as u32);
    let (mut responses, metrics) = replay_trace(backend, cfg, trace).expect("overload serve");
    responses.sort_by_key(|r| r.id);
    let generations = responses.into_iter().map(|r| (r.id, r.tokens)).collect();
    (generations, metrics.snapshot())
}

#[test]
fn prop_preempt_resume_bitwise_identical_to_uninterrupted() {
    let mha = Transformer::new_mha(ModelConfig::tiny(), 771);
    let bda = mha.to_bda(Strategy::ResidualMin, DType::F32).expect("bda prep");
    let small = overload_pool_blocks();
    for (label, model) in [("mha", &mha), ("bda", &bda)] {
        for workers in [1usize, 2, 8] {
            for cache in [false, true] {
                let tag = format!("{label}/workers={workers}/cache={cache}");
                let (ample_gen, ample_snap) = run_with_pool(model, workers, cache, 512);
                assert_eq!(ample_snap.preemptions, 0, "{tag}: ample pool must not preempt");
                assert_eq!(ample_gen.len(), 6, "{tag}: lost responses on the ample pool");

                let (tight_gen, tight_snap) = run_with_pool(model, workers, cache, small);
                if small < 15 {
                    assert!(
                        tight_snap.preemptions > 0,
                        "{tag}: a {small}-block pool must force preemption"
                    );
                    assert_eq!(
                        tight_snap.resumes, tight_snap.preemptions,
                        "{tag}: every preempted sequence must resume exactly once per park"
                    );
                    assert!(tight_snap.recomputed_tokens > 0, "{tag}: resumes must recompute");
                }
                assert_eq!(
                    tight_gen, ample_gen,
                    "{tag}: preempt→resume changed generations (invariant 5 violated)"
                );
            }
        }
    }
}

/// Engine invariant 6 under overload: chunked prefill at any budget —
/// fused with active decodes and interrupted by preempt→resume on a tiny
/// pool — generates bitwise identically to an uninterrupted monolithic
/// run on an ample pool, for MHA and BDA, cache on and off. Budget 4 is
/// one 4-token block per step, 512 covers the 8-token prompts whole, 0 is
/// unbounded.
#[test]
fn prop_chunked_prefill_bitwise_under_preempting_pool() {
    let mha = Transformer::new_mha(ModelConfig::tiny(), 773);
    let bda = mha.to_bda(Strategy::ResidualMin, DType::F32).expect("bda prep");
    let small = overload_pool_blocks();
    let run = |model: &Transformer, cache: bool, num_blocks: usize, chunk: usize| {
        let mut cfg = server_config(num_blocks);
        cfg.scheduler.prefill_chunk = chunk;
        let pool = Arc::new(ThreadPool::new(2));
        let mut backend =
            PagedNativeBackend::with_thread_pool(model.clone(), cfg.scheduler.kv, pool);
        backend.set_prefix_cache(cache);
        let trace = overload_trace(model.config.vocab_size as u32);
        let (mut responses, metrics) = replay_trace(backend, cfg, trace).expect("chunked serve");
        responses.sort_by_key(|r| r.id);
        let gens: Generations = responses.into_iter().map(|r| (r.id, r.tokens)).collect();
        (gens, metrics.snapshot())
    };
    for (label, model) in [("mha", &mha), ("bda", &bda)] {
        for cache in [false, true] {
            let (ample_gen, ample_snap) = run(model, cache, 512, 0);
            assert_eq!(ample_snap.preemptions, 0, "{label}: ample pool must not preempt");
            assert_eq!(ample_gen.len(), 6, "{label}: lost responses on the ample pool");
            for chunk in [4usize, 512, 0] {
                let tag = format!("{label}/cache={cache}/chunk={chunk}");
                let (tight_gen, tight_snap) = run(model, cache, small, chunk);
                if small < 15 {
                    assert!(
                        tight_snap.preemptions > 0,
                        "{tag}: a {small}-block pool must force preemption"
                    );
                }
                if chunk == 4 {
                    // 6 admissions × ≥ 2 chunks each (8-token prompts at a
                    // 4-token budget), plus chunked resume replays.
                    assert!(
                        tight_snap.prefill_chunks >= 12,
                        "{tag}: expected >= 12 prefill chunks, saw {}",
                        tight_snap.prefill_chunks
                    );
                    assert!(tight_snap.chunked_tokens >= 48, "{tag}: chunked tokens undercount");
                }
                assert_eq!(
                    tight_gen, ample_gen,
                    "{tag}: chunked prefill under preemption changed generations \
                     (invariant 6 violated)"
                );
            }
        }
    }
}

/// The same invariant through an engine built entirely from environment
/// defaults (`BDA_NUM_THREADS` worker count on the global pool,
/// `BDA_PREFIX_CACHE` cache setting, `BDA_PREFILL_CHUNK` budget) — the
/// configuration each CI determinism-matrix cell actually pins, so the
/// preempt/resume path is exercised under every
/// (threads, prefix-cache, chunk) combination.
#[test]
fn preempt_resume_bitwise_under_env_default_engine() {
    let model = Transformer::new_mha(ModelConfig::tiny(), 772);
    let small = overload_pool_blocks();
    let run = |num_blocks: usize| {
        let cfg = server_config(num_blocks);
        let backend = PagedNativeBackend::new(model.clone(), cfg.scheduler.kv);
        let trace = overload_trace(model.config.vocab_size as u32);
        let (mut responses, metrics) = replay_trace(backend, cfg, trace).expect("env serve");
        responses.sort_by_key(|r| r.id);
        let gens: Generations = responses.into_iter().map(|r| (r.id, r.tokens)).collect();
        (gens, metrics.snapshot())
    };
    let (ample_gen, _) = run(512);
    let (tight_gen, tight_snap) = run(small);
    if small < 15 {
        assert!(tight_snap.preemptions > 0, "the {small}-block pool must force preemption");
    }
    assert_eq!(tight_gen, ample_gen, "env-default engine violated invariant 5");
}
