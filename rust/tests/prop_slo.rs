//! Property tests for SLO scoring, the resource sampler, and class-aware
//! preemption:
//!
//! 1. **Zero perturbation**: decode output is bitwise identical with the
//!    sampler + tracing on vs off — for MHA and BDA, at worker counts
//!    {1, 8}, prefix cache off and on, under an overload pool that forces
//!    preempt→resume — while SLO scoring (which always runs) tallies every
//!    response. Observability observes; it must never steer.
//! 2. **Counter tracks**: a traced overload run buffers step-boundary
//!    resource samples with real pool occupancy, and the Chrome-trace
//!    export surfaces them as `"ph":"C"` counter events.
//! 3. **Class-aware preemption**: with the victim-policy gate on, an
//!    overloaded run preempts the lowest-priority class first and still
//!    resumes bitwise (engine invariant 5) — generations match the
//!    ample-pool baseline under both the gated policy and the default
//!    youngest-victim policy.
//!
//! The tracing gate and the sampler buffer are process-global, so every
//! test serializes on one mutex and resets both around its body (mirrors
//! `prop_trace.rs`).

use bda::bd::Strategy;
use bda::coordinator::server::replay_trace;
use bda::coordinator::{
    BatcherConfig, KvCacheConfig, Request, RequestClass, SchedulerConfig, ServerConfig,
};
use bda::engine::PagedNativeBackend;
use bda::model::{ModelConfig, Transformer};
use bda::obs;
use bda::tensor::DType;
use bda::util::threadpool::ThreadPool;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

static GATE: Mutex<()> = Mutex::new(());

fn serialized() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Known state: gate set, span rings and sampler buffer drained.
fn reset(enabled: bool) {
    obs::set_enabled(false);
    let _ = obs::take_collected();
    let _ = obs::sampler::take_samples();
    obs::set_enabled(enabled);
}

/// Overload geometry (mirrors `prop_trace.rs`): 3-way concurrency against
/// a 10-block pool, 6 requests of 8 prompt + 10 new tokens — peak demand
/// 3 × 5 blocks, so decode must preempt.
fn overload_config(num_blocks: usize) -> ServerConfig {
    ServerConfig {
        batcher: BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(0) },
        scheduler: SchedulerConfig {
            max_active: 3,
            eos_token: None,
            kv: KvCacheConfig { block_size: 4, num_blocks, ..Default::default() },
            ..Default::default()
        },
    }
}

/// The overload trace with a non-default class mix: priorities cycle
/// 0/1/2 and each class carries its own deadlines, so SLO scoring and the
/// class-aware victim policy both see real variety.
fn classed_trace(vocab: u32) -> Vec<Request> {
    (0..6u64)
        .map(|i| {
            let prompt: Vec<u32> =
                (0..8u64).map(|j| ((i * 37 + j * 13 + 5) % vocab as u64) as u32).collect();
            let class = RequestClass {
                priority: (i % 3) as u8,
                ttft_deadline: 0.5 + 0.25 * (i % 3) as f64,
                tbt_budget: 0.1 + 0.05 * (i % 3) as f64,
            };
            Request::new(i, prompt, 10).with_class(class)
        })
        .collect()
}

type Generations = Vec<(u64, Vec<u32>)>;

struct RunOut {
    generations: Generations,
    preemptions: u64,
    scored: u64,
    class_ok: bool,
}

fn run_overload(model: &Transformer, workers: usize, cache: bool, num_blocks: usize) -> RunOut {
    let cfg = overload_config(num_blocks);
    let pool = Arc::new(ThreadPool::new(workers));
    let mut backend = PagedNativeBackend::with_thread_pool(model.clone(), cfg.scheduler.kv, pool);
    backend.set_prefix_cache(cache);
    let trace = classed_trace(model.config.vocab_size as u32);
    let (mut responses, metrics) = replay_trace(backend, cfg, trace).expect("overload serve");
    responses.sort_by_key(|r| r.id);
    // Responses must carry their class and a sane worst token gap.
    let class_ok = responses
        .iter()
        .all(|r| r.class.priority == (r.id % 3) as u8 && r.max_tbt >= 0.0 && r.max_tbt <= r.latency);
    let snap = metrics.snapshot();
    let scored = snap.slo_by_class.iter().map(|c| c.completed).sum();
    RunOut {
        generations: responses.into_iter().map(|r| (r.id, r.tokens)).collect(),
        preemptions: snap.preemptions,
        scored,
        class_ok,
    }
}

#[test]
fn prop_decode_bitwise_identical_with_sampler_and_slo_scoring_on_vs_off() {
    let _g = serialized();
    let mha = Transformer::new_mha(ModelConfig::tiny(), 881);
    let bda = mha.to_bda(Strategy::ResidualMin, DType::F32).expect("bda prep");
    for (label, model) in [("mha", &mha), ("bda", &bda)] {
        for workers in [1usize, 8] {
            for cache in [false, true] {
                let tag = format!("{label}/workers={workers}/cache={cache}");
                reset(false);
                let off = run_overload(model, workers, cache, 10);
                assert!(off.preemptions > 0, "{tag}: the overload pool must preempt");
                assert_eq!(off.scored, 6, "{tag}: every completion is SLO-scored");
                assert!(off.class_ok, "{tag}: responses must carry class + max_tbt");
                assert!(
                    obs::sampler::take_samples().is_empty(),
                    "{tag}: a disabled trace must not sample resources"
                );

                reset(true);
                let on = run_overload(model, workers, cache, 10);
                let samples = obs::sampler::take_samples();
                let events = obs::take_collected();
                obs::set_enabled(false);
                assert!(!samples.is_empty(), "{tag}: an enabled trace must sample");
                assert!(!events.is_empty(), "{tag}: an enabled trace must record spans");
                assert_eq!(on.scored, 6, "{tag}: scoring is gate-independent");
                assert_eq!(
                    on.preemptions, off.preemptions,
                    "{tag}: the sampler changed scheduling"
                );
                assert_eq!(
                    on.generations, off.generations,
                    "{tag}: sampler + SLO scoring on vs off changed decode output \
                     (must be bitwise identical)"
                );
            }
        }
    }
}

#[test]
fn sampler_samples_surface_as_counter_tracks() {
    let _g = serialized();
    reset(true);
    let model = Transformer::new_mha(ModelConfig::tiny(), 882);
    let out = run_overload(&model, 2, true, 10);
    let samples = obs::sampler::take_samples();
    let events = obs::take_collected();
    obs::set_enabled(false);
    assert_eq!(out.generations.len(), 6);
    assert!(!samples.is_empty(), "one sample per scheduler step");
    // The paged backend reports real pool occupancy; under overload some
    // step must have seen a fully-claimed pool.
    assert!(samples.iter().all(|s| s.pool.is_some()), "pool-owning backend samples counters");
    assert!(samples.iter().any(|s| s.pool.unwrap().used_blocks > 0));
    assert!(samples.iter().any(|s| s.active > 0));
    let doc = bda::obs::export::chrome_trace_full(&events, &obs::thread_labels(), &samples);
    let arr = doc.get("traceEvents").as_arr().expect("traceEvents");
    let counters: Vec<_> =
        arr.iter().filter(|e| e.get("ph").as_str() == Some("C")).collect();
    assert!(counters.len() >= samples.len(), "every sample emits at least one counter event");
    assert!(counters.iter().any(|e| e.get("name").as_str() == Some("kv_pool_blocks")));
    assert!(counters.iter().any(|e| e.get("name").as_str() == Some("queue_depth")));
}

#[test]
fn class_aware_preemption_resumes_bitwise_and_matches_default_policy_output() {
    let _g = serialized();
    reset(false);
    let model = Transformer::new_mha(ModelConfig::tiny(), 884);
    let run = |num_blocks: usize, class_preempt: bool| {
        let cfg = overload_config(num_blocks);
        let pool = Arc::new(ThreadPool::new(2));
        let mut backend =
            PagedNativeBackend::with_thread_pool(model.clone(), cfg.scheduler.kv, pool);
        backend.set_class_preempt(class_preempt);
        assert_eq!(backend.class_preempt_enabled(), class_preempt);
        let trace = classed_trace(model.config.vocab_size as u32);
        let (mut responses, metrics) = replay_trace(backend, cfg, trace).expect("serve");
        responses.sort_by_key(|r| r.id);
        let generations: Generations =
            responses.into_iter().map(|r| (r.id, r.tokens)).collect();
        (generations, metrics.snapshot().preemptions)
    };
    let (ample, ample_preempt) = run(1024, true);
    assert_eq!(ample_preempt, 0, "the ample pool must not preempt");
    let (gated, gated_preempt) = run(10, true);
    assert!(gated_preempt > 0, "the tight pool must preempt under the gated policy");
    assert_eq!(
        gated, ample,
        "class-aware victims must resume bitwise (invariant 5): tight == ample"
    );
    let (default_policy, default_preempt) = run(10, false);
    assert!(default_preempt > 0, "the tight pool must preempt under the default policy");
    assert_eq!(
        default_policy, ample,
        "youngest-victim policy must also resume bitwise: tight == ample"
    );
}
