//! Cross-module integration: BD → attention → model → eval, end to end in
//! pure Rust (no artifacts needed).

use bda::attention::{mha::mha_forward, mha::MhaWeights, AttnShape, BdaAttention, PifaAttention};
use bda::bd::Strategy;
use bda::coordinator::{NativeBackend, Request, SchedulerConfig, Scheduler};
use bda::eval::corpus::Corpus;
use bda::eval::perplexity;
use bda::model::{ModelConfig, Transformer};
use bda::tensor::{DType, Tensor};

/// The paper's central claim, end-to-end: replacing every MHA layer with
/// BDA changes logits only at float-rounding level, shrinks the model, and
/// leaves PPL essentially unchanged (Fig. 2a at small scale).
#[test]
fn full_model_bda_exactness_and_ppl() {
    let model = Transformer::new_mha(ModelConfig::tiny(), 1234);
    let bda = model.to_bda(Strategy::ResidualMin, DType::F32).unwrap();

    // Logits.
    let tokens: Vec<u32> = (0..48).map(|i| (i * 31 + 5) % 256).collect();
    let a = model.forward_full(&tokens);
    let b = bda.forward_full(&tokens);
    let rel = (b.max_abs_diff(&a) as f64) / a.fro_norm().max(1e-12);
    assert!(rel < 1e-4, "logits rel diff {rel}");

    // Params.
    assert!(bda.param_count() < model.param_count());

    // PPL.
    let corpus = Corpus::tiny_wiki(256, 600, 9);
    let p_mha = perplexity(&model, &corpus.tokens, 32);
    let p_bda = perplexity(&bda, &corpus.tokens, 32);
    let inc = (p_bda - p_mha).abs() / p_mha * 100.0;
    assert!(inc < 0.05, "ppl increase {inc}%");
}

/// All three attention implementations agree on outputs (MHA reference,
/// BDA with aligned contiguous basis, PIFA-style with pivoted basis) —
/// they differ only in speed/memory-traffic, exactly the paper's setup.
#[test]
fn three_implementations_agree() {
    let s = AttnShape::new(32, 4, 8);
    let mha = MhaWeights::random(s, 77);
    let x = Tensor::randn(&[10, 32], 1.0, 78);
    let y_ref = mha_forward(&mha, &x, true);

    let bda = BdaAttention::from_mha(&mha, Strategy::ResidualMin, DType::F32).unwrap();
    let y_bda = bda.forward(&x, true);
    let pifa = PifaAttention::from_mha(&mha);
    let y_pifa = pifa.forward(&x, true);

    let rel = |y: &Tensor| (y.max_abs_diff(&y_ref) as f64) / y_ref.fro_norm().max(1e-12);
    assert!(rel(&y_bda) < 1e-3, "bda {}", rel(&y_bda));
    assert!(rel(&y_pifa) < 1e-3, "pifa {}", rel(&y_pifa));
}

/// Table 3 pipeline at small scale: dense → low-rank (lossy, smaller) →
/// BD (lossless vs low-rank, smaller still).
#[test]
fn lowrank_bd_pipeline_params_and_ppl() {
    let dense = Transformer::new_mha(ModelConfig::tiny(), 31);
    let lowrank = dense.to_lowrank(0.8);
    let bd = lowrank.to_bd_from_lowrank(Strategy::ResidualMin);

    assert!(lowrank.param_count() < dense.param_count());
    assert!(bd.param_count() < lowrank.param_count());

    let corpus = Corpus::tiny_wiki(256, 400, 10);
    let p_dense = perplexity(&dense, &corpus.tokens, 32);
    let p_lr = perplexity(&lowrank, &corpus.tokens, 32);
    let p_bd = perplexity(&bd, &corpus.tokens, 32);
    // Low-rank is lossy vs dense; BD preserves the low-rank model's PPL.
    assert!((p_lr - p_dense).abs() / p_dense > 1e-6);
    assert!(
        (p_bd - p_lr).abs() / p_lr < 1e-3,
        "BD must preserve low-rank PPL: {p_lr} vs {p_bd}"
    );
}

/// Structured pruning (Fig. 2a dashed baseline) is measurably lossy while
/// BDA is not, at the same K/V compression ratio.
#[test]
fn pruning_lossy_bda_lossless_same_ratio() {
    let model = Transformer::new_mha(ModelConfig::tiny(), 55);
    let corpus = Corpus::tiny_wiki(256, 400, 11);
    let base = perplexity(&model, &corpus.tokens, 32);

    let bda = model.to_bda(Strategy::ResidualMin, DType::F32).unwrap();
    let pruned = model.to_pruned(0.25);
    let p_bda = perplexity(&bda, &corpus.tokens, 32);
    let p_pruned = perplexity(&pruned, &corpus.tokens, 32);

    let inc_bda = (p_bda - base).abs() / base;
    let inc_pruned = (p_pruned - base).abs() / base;
    assert!(inc_bda < 1e-4, "bda inc {inc_bda}");
    assert!(
        inc_pruned > inc_bda * 10.0,
        "pruning should dominate BDA's error: {inc_pruned} vs {inc_bda}"
    );
}

/// Serving stack over the real model: coordinator + scheduler + KV cache +
/// native backend produce identical generations for MHA and BDA.
#[test]
fn serving_stack_mha_bda_identical_generations() {
    let mha_model = Transformer::new_mha(ModelConfig::tiny(), 91);
    let bda_model = mha_model.to_bda(Strategy::ResidualMin, DType::F32).unwrap();

    let run = |model: Transformer| -> Vec<(u64, Vec<u32>)> {
        let mut sched = Scheduler::new(NativeBackend::new(model), SchedulerConfig::default());
        for i in 0..6u64 {
            let prompt: Vec<u32> = (0..4 + i).map(|j| ((j * 13 + i * 7) % 256) as u32).collect();
            sched.admit(Request::new(i, prompt, 6)).unwrap();
        }
        let mut done = sched.drain().unwrap();
        done.sort_by_key(|r| r.id);
        done.into_iter().map(|r| (r.id, r.tokens)).collect()
    };

    assert_eq!(run(mha_model), run(bda_model));
}

/// BLEU + beam-search over a trained-ish model pipeline sanity: decoding
/// the same model twice gives identical BLEU (determinism).
#[test]
fn decode_determinism() {
    use bda::eval::beam::beam_search;
    use bda::eval::bleu;
    let model = Transformer::new_mha(ModelConfig::tiny(), 101);
    let prompts: Vec<Vec<u32>> = (0..4).map(|i| vec![2 + i, 7, 11 + i]).collect();
    let decode = |m: &Transformer| -> Vec<Vec<u32>> {
        prompts.iter().map(|p| beam_search(m, p, 2, 6, 1)).collect()
    };
    let a = decode(&model);
    let b = decode(&model);
    assert_eq!(a, b);
    assert!((bleu(&a, &b) - 100.0).abs() < 1e-9);
}
