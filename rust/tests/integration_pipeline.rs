//! Whole-pipeline integration: PJRT-backed serving through the coordinator
//! (queue → batcher → scheduler → AOT executable), plus failure injection.

#![cfg(feature = "pjrt")]

use bda::coordinator::kv_cache::SeqId;
use bda::coordinator::{Backend, DecodeOutcome, PjrtBackend, Request, Scheduler, SchedulerConfig};
use anyhow::Result;

fn open_backend(attention: &str) -> Option<PjrtBackend> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    PjrtBackend::open(dir, attention).ok()
}

#[test]
fn pjrt_serving_end_to_end() {
    let Some(backend) = open_backend("bda") else { return };
    let mut sched = Scheduler::new(backend, SchedulerConfig::default());
    for i in 0..3u64 {
        let prompt: Vec<u32> = (1..5 + i).map(|j| (j * 17 + i * 3) as u32 % 512).collect();
        sched.admit(Request::new(i, prompt, 4)).unwrap();
    }
    let done = sched.drain().unwrap();
    assert_eq!(done.len(), 3);
    for r in &done {
        assert_eq!(r.tokens.len(), 4);
        assert!(r.tokens.iter().all(|&t| t < 512));
    }
}

#[test]
fn pjrt_mha_and_bda_generate_identically() {
    // The serving-visible losslessness claim across the AOT boundary:
    // greedy generations from the two artifacts must coincide.
    let Some(mha) = open_backend("mha") else { return };
    let Some(bda) = open_backend("bda") else { return };
    let run = |backend: PjrtBackend| {
        let mut sched = Scheduler::new(backend, SchedulerConfig::default());
        for i in 0..3u64 {
            let prompt: Vec<u32> = (0..6).map(|j| ((j * 29 + i * 11) % 512) as u32).collect();
            sched.admit(Request::new(i, prompt, 5)).unwrap();
        }
        let mut done = sched.drain().unwrap();
        done.sort_by_key(|r| r.id);
        done.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
    };
    assert_eq!(run(mha), run(bda), "greedy decode must match across MHA/BDA artifacts");
}

#[test]
fn incremental_backend_matches_recompute_backend() {
    // The KV-cached step artifact must generate exactly what the
    // full-recompute forward artifact generates (same weights baked in).
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let Ok(inc) = bda::coordinator::PjrtIncrementalBackend::open(&dir, "bda") else {
        eprintln!("skipping: step artifact not built");
        return;
    };
    let full = PjrtBackend::open(&dir, "bda").unwrap();

    fn run<B: Backend>(backend: B) -> Vec<Vec<u32>> {
        let mut sched = Scheduler::new(backend, SchedulerConfig::default());
        for i in 0..2u64 {
            let prompt: Vec<u32> = (0..5).map(|j| ((j * 41 + i * 13) % 512) as u32).collect();
            sched.admit(Request::new(i, prompt, 4)).unwrap();
        }
        let mut done = sched.drain().unwrap();
        done.sort_by_key(|r| r.id);
        done.into_iter().map(|r| r.tokens).collect()
    }
    assert_eq!(
        run(inc),
        run(full),
        "incremental KV decode must match full-recompute decode"
    );
}

/// Failure injection: a backend that errors on decode mid-flight. The
/// scheduler must propagate the error without panicking or corrupting KV
/// accounting.
struct FlakyBackend {
    inner: bda::coordinator::scheduler::test_support::MockBackend,
    fail_after: usize,
    calls: usize,
}

impl Backend for FlakyBackend {
    fn vocab_size(&self) -> usize {
        self.inner.vocab
    }
    fn max_seq_len(&self) -> usize {
        self.inner.max_seq
    }
    fn prefill(&mut self, seq: SeqId, prompt: &[u32]) -> Result<Vec<f32>> {
        self.inner.prefill(seq, prompt)
    }
    fn decode(&mut self, seqs: &[(SeqId, u32)]) -> Result<DecodeOutcome> {
        self.calls += 1;
        if self.calls > self.fail_after {
            anyhow::bail!("injected backend failure");
        }
        self.inner.decode(seqs)
    }
    fn release(&mut self, seq: SeqId) {
        self.inner.release(seq)
    }
}

#[test]
fn backend_failure_surfaces_cleanly() {
    let backend = FlakyBackend {
        inner: bda::coordinator::scheduler::test_support::MockBackend::new(16, 64),
        fail_after: 2,
        calls: 0,
    };
    let mut sched = Scheduler::new(backend, SchedulerConfig::default());
    sched.admit(Request::new(1, vec![1, 2], 10)).unwrap();
    let mut saw_error = false;
    for _ in 0..10 {
        match sched.step() {
            Ok(_) => {}
            Err(e) => {
                saw_error = true;
                assert!(e.to_string().contains("injected"));
                break;
            }
        }
    }
    assert!(saw_error, "injected failure must surface");
    // KV accounting still self-consistent after the failure.
    sched.kv.as_ref().unwrap().check_invariants().unwrap();
}
