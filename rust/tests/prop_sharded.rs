//! Property tests for engine invariant 8: for a fixed request set, every
//! request's token stream is **bitwise identical at any worker count and
//! any placement** — the prefix-aware router never splits a sequence
//! across pool shards, and invariants 1–6 pin each shard scheduler's
//! per-request output. Exercised for MHA and BDA, at worker counts
//! {1, 2, 4} (plus the `BDA_WORKERS` CI axis), with the prefix cache on
//! and off, on ample and preempting per-shard pools.
//!
//! The "small" per-shard pool honors the `BDA_TEST_POOL_BLOCKS` overload
//! knob (see `coordinator::kv_cache::test_pool_blocks`) so the CI
//! determinism matrix can force preempt/resume churn inside shards while
//! the router steers admissions around it.

use bda::bd::Strategy;
use bda::coordinator::kv_cache::test_pool_blocks;
use bda::coordinator::server::{replay_trace_sharded, ServerConfig};
use bda::coordinator::{
    workers_from_env, BatcherConfig, KvCacheConfig, Request, SchedulerConfig, Snapshot,
};
use bda::engine::PagedNativeBackend;
use bda::model::{ModelConfig, Transformer};
use bda::tensor::DType;
use bda::util::threadpool::ThreadPool;
use std::sync::Arc;
use std::time::Duration;

/// The per-shard overload pool size: the env knob when set (clamped so
/// one sequence always fits alone — 12-token prompts + 8 generated = 5
/// blocks of 4), a hand-tuned 12 otherwise. At concurrency 3 a single
/// shard needs 15 blocks peak, so anything below that preempts when one
/// worker carries the whole trace.
fn overload_pool_blocks() -> usize {
    test_pool_blocks().map(|n| n.clamp(8, 64)).unwrap_or(12)
}

fn server_config(num_blocks: usize) -> ServerConfig {
    ServerConfig {
        batcher: BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(0) },
        scheduler: SchedulerConfig {
            max_active: 3,
            eos_token: None,
            kv: KvCacheConfig { block_size: 4, num_blocks, ..Default::default() },
            ..Default::default()
        },
    }
}

/// 8 requests in two prefix families: each prompt shares its first 8
/// tokens (2 blocks) with the other requests of its family and diverges
/// in the last 4. Overlapping prefixes give the router's cache-affinity
/// term real signal when the prefix cache is on; distinct tails keep
/// every token stream request-specific.
fn sharded_trace(vocab: u32) -> Vec<Request> {
    (0..8u64)
        .map(|i| {
            let family = i % 2;
            let v = vocab as u64;
            let mut prompt: Vec<u32> =
                (0..8u64).map(|j| ((family * 97 + j * 13 + 5) % v) as u32).collect();
            prompt.extend((0..4u64).map(|j| ((i * 41 + j * 7 + 11) % v) as u32));
            Request::new(i, prompt, 8)
        })
        .collect()
}

type Generations = Vec<(u64, Vec<u32>)>;

/// Run the trace through `workers` pool-shard engines (each with its own
/// 2-thread pool and `num_blocks`-block KV pool) behind the router.
fn run_sharded(
    model: &Transformer,
    workers: usize,
    cache: bool,
    num_blocks: usize,
) -> (Generations, Snapshot) {
    let cfg = server_config(num_blocks);
    let backends: Vec<PagedNativeBackend> = (0..workers)
        .map(|_| {
            let pool = Arc::new(ThreadPool::new(2));
            let mut backend =
                PagedNativeBackend::with_thread_pool(model.clone(), cfg.scheduler.kv, pool);
            backend.set_prefix_cache(cache);
            backend
        })
        .collect();
    let trace = sharded_trace(model.config.vocab_size as u32);
    let (mut responses, snap) = replay_trace_sharded(backends, cfg, trace).expect("sharded serve");
    responses.sort_by_key(|r| r.id);
    let generations = responses.into_iter().map(|r| (r.id, r.tokens)).collect();
    (generations, snap)
}

#[test]
fn prop_sharded_placement_invariant_token_streams() {
    let mha = Transformer::new_mha(ModelConfig::tiny(), 881);
    let bda = mha.to_bda(Strategy::ResidualMin, DType::F32).expect("bda prep");
    let small = overload_pool_blocks();
    for (label, model) in [("mha", &mha), ("bda", &bda)] {
        for cache in [false, true] {
            // Single-worker ample pool is the reference stream.
            let (baseline, base_snap) = run_sharded(model, 1, cache, 256);
            assert_eq!(baseline.len(), 8, "{label}/cache={cache}: lost responses at baseline");
            assert_eq!(base_snap.preemptions, 0, "{label}: ample pool must not preempt");
            for workers in [1usize, 2, 4] {
                let tag = format!("{label}/workers={workers}/cache={cache}");
                let (ample_gen, ample_snap) = run_sharded(model, workers, cache, 256);
                assert_eq!(
                    ample_gen, baseline,
                    "{tag}: placement changed token streams (invariant 8 violated)"
                );
                assert_eq!(ample_snap.requests_completed, 8, "{tag}: aggregate completions");
                assert_eq!(ample_snap.tokens_out, 64, "{tag}: aggregate tokens");

                // Tight per-shard pools: shards preempt internally, the
                // router steers around the churn, and the streams still
                // must not move.
                let (tight_gen, tight_snap) = run_sharded(model, workers, cache, small);
                assert_eq!(
                    tight_gen, baseline,
                    "{tag}: preempting shards changed token streams (invariant 8 violated)"
                );
                assert_eq!(
                    tight_snap.resumes, tight_snap.preemptions,
                    "{tag}: every preempted sequence must resume exactly once per park"
                );
                if workers == 1 && small < 15 {
                    assert!(
                        tight_snap.preemptions > 0,
                        "{tag}: a {small}-block shard must force preemption"
                    );
                }
            }
        }
    }
}

/// The CI determinism-matrix axis: `BDA_WORKERS` picks the shard count
/// (default 1), and the resulting streams must match the single-worker
/// baseline bitwise, on both ample and preempting per-shard pools.
#[test]
fn sharded_env_worker_count_matches_single_worker_baseline() {
    let model = Transformer::new_mha(ModelConfig::tiny(), 883);
    let workers = workers_from_env();
    let small = overload_pool_blocks();
    let (baseline, _) = run_sharded(&model, 1, true, 256);
    for num_blocks in [256usize, small] {
        let (gens, snap) = run_sharded(&model, workers, true, num_blocks);
        assert_eq!(
            gens, baseline,
            "BDA_WORKERS={workers} over {num_blocks}-block shards changed token streams \
             (invariant 8 violated)"
        );
        assert_eq!(snap.requests_completed, 8);
        assert_eq!(snap.tokens_out, 64);
        assert!(snap.tokens_per_sec > 0.0, "aggregate throughput must be derived from sums");
    }
}
