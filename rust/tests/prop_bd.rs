//! Property tests for the BD core (mini-framework over seeds; the offline
//! crate set has no proptest — see DESIGN.md §2).

use bda::bd::{bd_col, bd_row, reconstruct_col, reconstruct_row, BdCost, Strategy};
use bda::tensor::matmul::matmul;
use bda::tensor::Tensor;
use bda::util::rng::Rng;

const CASES: u64 = 60;

fn rank_r(m: usize, n: usize, r: usize, seed: u64) -> Tensor {
    let u = Tensor::randn(&[m, r], 1.0, seed);
    let vt = Tensor::randn(&[r, n], 1.0, seed.wrapping_add(7919));
    matmul(&u, &vt)
}

/// For every random (m, n, r): BD reconstructs the rank-r product to float
/// tolerance, for both axes and both strategies.
#[test]
fn prop_bd_roundtrip_exact() {
    for case in 0..CASES {
        let mut rng = Rng::new(case * 31 + 1);
        let m = rng.range(4, 24);
        let n = rng.range(4, 24);
        let r = rng.range(1, m.min(n) - 1);
        let w = rank_r(m, n, r, case);
        let tol = (1e-2 * w.fro_norm()).max(1e-3);

        for strategy in [Strategy::FirstR, Strategy::ResidualMin] {
            let col = bd_col(&w, r, strategy)
                .unwrap_or_else(|e| panic!("case {case} ({m}x{n} r{r}) col: {e}"));
            let rc = reconstruct_col(col.tag, &col.b, &col.c);
            assert!(
                rc.sub(&w).fro_norm() < tol,
                "case {case}: col residual {} tol {tol}",
                rc.sub(&w).fro_norm()
            );
            let row = bd_row(&w, r, strategy)
                .unwrap_or_else(|e| panic!("case {case} ({m}x{n} r{r}) row: {e}"));
            let rr = reconstruct_row(row.tag, &row.b, &row.c);
            assert!(rr.sub(&w).fro_norm() < tol, "case {case}: row residual");
        }
    }
}

/// Residual-min never selects a worse candidate than First-r.
#[test]
fn prop_residual_min_dominates() {
    for case in 0..CASES {
        let mut rng = Rng::new(case * 97 + 3);
        let m = rng.range(5, 20);
        let n = rng.range(5, 20);
        let r = rng.range(1, m.min(n) - 1);
        let w = rank_r(m, n, r, case + 5000);
        let f = bd_row(&w, r, Strategy::FirstR).unwrap();
        let mres = bd_row(&w, r, Strategy::ResidualMin).unwrap();
        assert!(
            mres.residual <= f.residual + 1e-9,
            "case {case}: {} > {}",
            mres.residual,
            f.residual
        );
    }
}

/// Cost-model invariants hold on every shape: bd < lowrank < dense params
/// (given r below the low-rank break-even), and bd apply-FLOPs < lowrank's.
#[test]
fn prop_cost_model_orderings() {
    for case in 0..CASES {
        let mut rng = Rng::new(case * 13 + 7);
        let m = rng.range(2, 256);
        let n = rng.range(2, 256);
        let r = rng.range(1, m.min(n) - 1).max(1);
        let c = BdCost::new(m, n, r);
        assert!(c.bd_params() < c.lowrank_params(), "case {case}");
        assert!(c.bd_params() < c.dense_params(), "case {case}");
        assert!(c.bd_recon_flops() <= c.lowrank_recon_flops(), "case {case}");
        assert!(c.bd_apply_flops(17) < c.lowrank_apply_flops(17), "case {case}");
    }
}

/// Inner-product preservation: for random MHA weights of random shapes,
/// Q'K'^T == QK^T per head after preparation (the §3.4 invariant).
#[test]
fn prop_qk_inner_products_preserved() {
    use bda::attention::mha::MhaWeights;
    use bda::attention::{kproj, AttnShape};
    use bda::tensor::DType;
    for case in 0..20 {
        let mut rng = Rng::new(case * 211 + 17);
        let d_h = [4usize, 8, 16][rng.range(0, 2)];
        let mult = rng.range(2, 4);
        let n_heads = rng.range(1, 4);
        let s = AttnShape::new(d_h * mult, n_heads, d_h);
        let w = MhaWeights::random(s, case + 100);
        let bda =
            bda::attention::bda::BdaWeights::prepare(&w, Strategy::ResidualMin, DType::F32)
                .unwrap();
        let l = rng.range(2, 12);
        let x = Tensor::randn(&[l, s.d], 1.0, case + 200);
        let q = matmul(&x, &w.wq);
        let k = matmul(&x, &w.wk);
        let qp = matmul(&x, &bda.b_qk);
        let kp = kproj::kproj_bda(&x, &bda.c_qk, bda.tag_qk, s);
        for i in 0..s.n_heads {
            let sl = |t: &Tensor| t.slice_cols(i * s.d_h, (i + 1) * s.d_h);
            let sc = matmul(&sl(&q), &sl(&k).transpose());
            let sp = matmul(&sl(&qp), &sl(&kp).transpose());
            let rel = (sp.max_abs_diff(&sc) as f64) / sc.fro_norm().max(1e-9);
            assert!(rel < 1e-3, "case {case} head {i}: rel {rel}");
        }
    }
}

/// BD memory formula r(m+n-r) equals actual stored elements.
#[test]
fn prop_memory_formula_matches_storage() {
    for case in 0..CASES {
        let mut rng = Rng::new(case * 389 + 23);
        let m = rng.range(4, 32);
        let n = rng.range(4, 32);
        let r = rng.range(1, m.min(n) - 1);
        let w = rank_r(m, n, r, case + 9000);
        let col = bd_col(&w, r, Strategy::FirstR).unwrap();
        assert_eq!(col.b.numel() + col.c.numel(), r * (m + n - r), "case {case}");
        let row = bd_row(&w, r, Strategy::FirstR).unwrap();
        assert_eq!(row.b.numel() + row.c.numel(), r * (m + n - r), "case {case}");
    }
}

/// Quantized preparation error ordering: fp32 ≤ fp16 ≤ bf16 (NMSE),
/// matching Table 4's columns, across random models.
#[test]
fn prop_dtype_error_ordering() {
    use bda::model::{ModelConfig, Transformer};
    use bda::prepare::prepare_model;
    use bda::tensor::DType;
    for case in 0..6 {
        let mut cfg = ModelConfig::tiny();
        cfg.n_layers = 1;
        let m = Transformer::new_mha(cfg, case * 7 + 2);
        let e32 = prepare_model(&m, Strategy::ResidualMin, DType::F32).unwrap().qk_nmse();
        let e16 = prepare_model(&m, Strategy::ResidualMin, DType::F16).unwrap().qk_nmse();
        let ebf = prepare_model(&m, Strategy::ResidualMin, DType::BF16).unwrap().qk_nmse();
        assert!(e32 < e16, "case {case}: fp32 {e32} !< fp16 {e16}");
        assert!(e16 < ebf, "case {case}: fp16 {e16} !< bf16 {ebf}");
    }
}
