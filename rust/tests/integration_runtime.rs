//! PJRT runtime integration: load the AOT artifacts, execute them, verify
//! against the build-time test vector and the paper's invariants.
//!
//! Skipped (cleanly) when `artifacts/` has not been built — run
//! `make artifacts` first. These tests ARE the cross-layer proof: JAX +
//! Pallas (build time) → HLO text → Rust PJRT (request path).

#![cfg(feature = "pjrt")]

use bda::runtime::{lit_i32, lit_scalar_f32, literal_scalar_f32, Runtime};

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::open(dir).expect("open runtime"))
}

#[test]
fn lm_artifacts_match_test_vector() {
    let Some(mut rt) = runtime() else { return };
    let tv = rt.manifest.test_vector.clone().expect("test vector");
    let tokens: Vec<i32> = tv.tokens.iter().flatten().copied().collect();
    let lit = lit_i32(&tokens, &[tv.batch as i64, tv.seq_len as i64]).unwrap();

    // MHA must match the jax-side logits bit-closely; BDA within its
    // (lossless-up-to-rounding) tolerance.
    for (name, tol) in [("lm_mha_fwd_probe", 1e-4f32), ("lm_bda_fwd_probe", 2e-2f32)] {
        let exe = rt.load(name).expect(name);
        let out = exe.run(std::slice::from_ref(&lit)).expect("run");
        let logits: Vec<f32> = out[0].to_vec().expect("logits");
        let lm = rt.manifest.lm_config.as_ref().unwrap();
        assert_eq!(logits.len(), tv.batch * tv.seq_len * lm.vocab_size);
        for (i, (&got, &want)) in logits.iter().zip(tv.logits_head.iter()).enumerate() {
            assert!(
                (got - want).abs() < tol,
                "{name} logit {i}: {got} vs {want}"
            );
        }
    }
}

#[test]
fn mha_and_bda_artifacts_agree() {
    // The losslessness claim, measured across the PJRT boundary.
    let Some(mut rt) = runtime() else { return };
    let tv = rt.manifest.test_vector.clone().unwrap();
    let tokens: Vec<i32> = tv.tokens.iter().flatten().copied().collect();
    let lit = lit_i32(&tokens, &[tv.batch as i64, tv.seq_len as i64]).unwrap();
    let mha = rt.load("lm_mha_fwd_probe").unwrap();
    let bda = rt.load("lm_bda_fwd_probe").unwrap();
    let a: Vec<f32> = mha.run(std::slice::from_ref(&lit)).unwrap()[0].to_vec().unwrap();
    let b: Vec<f32> = bda.run(std::slice::from_ref(&lit)).unwrap()[0].to_vec().unwrap();
    let max_a = a.iter().fold(0f32, |m, &x| m.max(x.abs()));
    let max_diff =
        a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
    let rel = max_diff / max_a.max(1e-9);
    assert!(rel < 5e-3, "BDA/MHA artifact divergence: rel {rel}");
}

#[test]
fn kproj_artifacts_execute_and_match_shapes() {
    let Some(mut rt) = runtime() else { return };
    // kproj artifacts: x (L, 512); mha weight (512, 8*128); bda C (384, 8*128).
    let l = 64usize;
    let (d, dh, n) = (512usize, 128usize, 8usize);
    let x: Vec<f32> = (0..l * d).map(|i| ((i % 97) as f32) * 0.01 - 0.5).collect();
    let xl = bda::runtime::lit_f32(&x, &[l as i64, d as i64]).unwrap();

    let w: Vec<f32> = (0..d * n * dh).map(|i| ((i % 89) as f32) * 1e-3).collect();
    let wl = bda::runtime::lit_f32(&w, &[d as i64, (n * dh) as i64]).unwrap();
    let mha = rt.load("kproj_mha_l64").unwrap();
    let out = mha.run(&[xl, wl]).unwrap();
    let k: Vec<f32> = out[0].to_vec().unwrap();
    assert_eq!(k.len(), l * n * dh);

    let c: Vec<f32> = (0..(d - dh) * n * dh).map(|i| ((i % 83) as f32) * 1e-3).collect();
    let cl = bda::runtime::lit_f32(&c, &[(d - dh) as i64, (n * dh) as i64]).unwrap();
    let x2 = bda::runtime::lit_f32(&x, &[l as i64, d as i64]).unwrap();
    let bda_exe = rt.load("kproj_bda_l64").unwrap();
    let out = bda_exe.run(&[x2, cl]).unwrap();
    let kp: Vec<f32> = out[0].to_vec().unwrap();
    assert_eq!(kp.len(), l * n * dh);

    // Cross-check the BDA artifact against the Rust operator on the same
    // inputs (three implementations of line 2 of Algorithm 2 agree:
    // Pallas kernel via PJRT, Rust fused operator, algebra).
    let xt = bda::tensor::Tensor::from_vec(x.clone(), &[l, d]);
    let ct = bda::tensor::Tensor::from_vec(c, &[d - dh, n * dh]);
    let s = bda::attention::AttnShape::new(d, n, dh);
    let rust_kp = bda::attention::kproj::kproj_bda(&xt, &ct, bda::bd::Tag::First, s);
    let max_diff = rust_kp
        .data
        .iter()
        .zip(kp.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-3, "pallas vs rust kproj diff {max_diff}");
}

#[test]
fn train_step_decreases_loss_from_rust() {
    // The e2e training driver: run several AOT train steps and check the
    // loss trends down on a learnable synthetic batch.
    let Some(mut rt) = runtime() else { return };
    let tc = rt.manifest.train_config.clone().expect("train config");
    let init = rt.load("train_init_mha").unwrap();
    let step = rt.load("train_step_mha").unwrap();
    let mut state = init.run(&[]).unwrap();

    // One fixed batch, repeated: loss must drop (overfit check).
    let pairs = bda::eval::corpus::translation_pairs(tc.batch, tc.vocab_size, 6, 14, 3);
    let mut tokens: Vec<i32> = Vec::new();
    for p in &pairs {
        tokens.extend(p.pack(tc.max_seq_len + 1).iter().map(|&t| t as i32));
    }
    let tok_lit = || lit_i32(&tokens, &[tc.batch as i64, (tc.max_seq_len + 1) as i64]).unwrap();

    let mut losses = Vec::new();
    for _ in 0..8 {
        let mut inputs = state;
        inputs.push(tok_lit());
        inputs.push(lit_scalar_f32(4.0));
        let mut out = step.run(&inputs).unwrap();
        losses.push(literal_scalar_f32(&out.pop().unwrap()).unwrap());
        state = out;
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss should decrease: {losses:?}"
    );
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn bda_artifact_smaller_than_mha() {
    let Some(rt) = runtime() else { return };
    let mha = rt.manifest.get("lm_mha_fwd_b8").unwrap().bytes;
    let bda = rt.manifest.get("lm_bda_fwd_b8").unwrap().bytes;
    assert!(bda < mha, "BDA artifact must be smaller ({bda} vs {mha})");
}
