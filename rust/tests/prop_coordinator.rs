//! Property tests over coordinator invariants: no request lost or
//! duplicated, KV blocks never leak, batch bounds respected — and the
//! paged batched engine's decode is bit-identical to per-sequence decode.

use bda::coordinator::kv_cache::{BlockAllocator, KvCacheConfig, SeqId};
use bda::coordinator::scheduler::Backend;
use bda::coordinator::{
    Batcher, BatcherConfig, Request, RequestQueue, Scheduler, SchedulerConfig,
};
use bda::engine::PagedNativeBackend;
use bda::model::transformer::KvCache;
use bda::model::{ModelConfig, Transformer};
use bda::tensor::DType;
use bda::util::rng::Rng;
use std::time::Duration;

/// Random scheduler workloads: every admitted request completes exactly
/// once with exactly `max_new_tokens` tokens; KV pool returns to initial
/// state; allocator invariants hold throughout.
#[test]
fn prop_scheduler_conservation() {
    for case in 0..30u64 {
        let mut rng = Rng::new(case * 61 + 5);
        let mut sched = make_sched(rng.range(1, 8), rng.range(8, 64));
        let free0 = sched.kv.as_ref().unwrap().free_blocks();
        let n_req = rng.range(1, 24);
        let mut expected: Vec<(u64, usize)> = Vec::new();
        let mut pending: Vec<Request> = (0..n_req as u64)
            .map(|i| {
                let plen = rng.range(1, 12);
                let new = rng.range(1, 10);
                expected.push((i, new));
                Request::new(i, (0..plen).map(|j| j as u32).collect(), new)
            })
            .collect();
        pending.reverse();

        let mut done = Vec::new();
        let mut stall = 0;
        while done.len() < n_req {
            // Try to admit.
            if let Some(req) = pending.pop() {
                if let Err(r) = sched.admit(req) {
                    pending.push(r);
                }
            }
            let completed = sched.step().expect("step");
            if completed.is_empty() && pending.is_empty() && sched.active_count() == 0 {
                stall += 1;
                assert!(stall < 100, "case {case}: deadlock with {} done", done.len());
            }
            done.extend(completed);
            let kv = sched.kv.as_ref().unwrap();
            kv.check_invariants().unwrap_or_else(|e| panic!("case {case}: {e}"));
        }
        // Conservation: exactly once each, correct token counts.
        let mut ids: Vec<u64> = done.iter().map(|r| r.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n_req, "case {case}: duplicate or lost responses");
        for r in &done {
            let want = expected.iter().find(|(id, _)| *id == r.id).unwrap().1;
            assert_eq!(r.tokens.len(), want.max(1).min(64), "case {case} req {}", r.id);
        }
        let free_end = sched.kv.as_ref().unwrap().free_blocks();
        assert_eq!(free_end, free0, "case {case}: leaked blocks");
    }
}

fn make_sched(
    max_active: usize,
    num_blocks: usize,
) -> Scheduler<bda::coordinator::scheduler::test_support::MockBackend> {
    Scheduler::new(
        bda::coordinator::scheduler::test_support::MockBackend::new(16, 128),
        SchedulerConfig {
            max_active,
            eos_token: None,
            kv: KvCacheConfig { block_size: 4, num_blocks, ..Default::default() },
            ..Default::default()
        },
    )
}

/// Allocator fuzz: random register/append/fork/release sequences keep all
/// invariants; operations on unknown ids fail cleanly without corruption.
#[test]
fn prop_allocator_fuzz() {
    for case in 0..40u64 {
        let mut rng = Rng::new(case * 127 + 11);
        let mut alloc = BlockAllocator::new(KvCacheConfig {
            block_size: rng.range(1, 8),
            num_blocks: rng.range(4, 64),
            ..Default::default()
        });
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _op in 0..200 {
            match rng.below(10) {
                0..=3 => {
                    let id = next_id;
                    next_id += 1;
                    if alloc.register(id, rng.range(1, 24)).is_ok() {
                        live.push(id);
                    }
                }
                4..=6 => {
                    if !live.is_empty() {
                        let id = live[rng.range(0, live.len() - 1)];
                        let _ = alloc.append_token(id);
                    }
                }
                7 => {
                    if !live.is_empty() {
                        let parent = live[rng.range(0, live.len() - 1)];
                        let child = next_id;
                        next_id += 1;
                        if alloc.fork(parent, child).is_ok() {
                            live.push(child);
                        }
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let idx = rng.range(0, live.len() - 1);
                        let id = live.swap_remove(idx);
                        alloc.release(id).unwrap();
                    }
                }
            }
            alloc.check_invariants().unwrap_or_else(|e| panic!("case {case}: {e}"));
        }
        // Release everything: pool must be full again.
        for id in live {
            alloc.release(id).unwrap();
        }
        assert_eq!(alloc.free_blocks(), alloc.config.num_blocks, "case {case}");
    }
}

/// The lossless claim extended to the serving engine: for random prompts,
/// batch sizes, block sizes, and attention variants (MHA and BDA), the
/// paged batched engine's decode logits are *bit-identical* to running
/// each sequence alone through `Transformer::decode_step` — same floats,
/// not just close ones. Paging, batching, and storage indirection must be
/// pure data movement.
#[test]
fn prop_paged_engine_decode_bit_identical_to_per_seq() {
    for case in 0..8u64 {
        let mut rng = Rng::new(case * 97 + 13);
        let model = Transformer::new_mha(ModelConfig::tiny(), 300 + case);
        let model = if case % 2 == 1 {
            // Odd cases exercise the BDA variant (fp32 preparation).
            model
                .to_bda(bda::bd::Strategy::ResidualMin, bda::tensor::DType::F32)
                .expect("bda prep")
        } else {
            model
        };
        // f32 pinned: this test compares paged output against the f32
        // per-sequence KvCache reference (16-bit pools are covered by the
        // quantize-at-write suite in prop_kv_dtype.rs).
        let kv =
            KvCacheConfig { block_size: rng.range(1, 8), num_blocks: 512, dtype: DType::F32 };
        let mut engine = PagedNativeBackend::new(model.clone(), kv);

        let batch = rng.range(1, 8);
        let vocab = model.config.vocab_size as u32;
        let mut caches: Vec<KvCache> = Vec::new();
        for i in 0..batch {
            let plen = rng.range(1, 12);
            let prompt: Vec<u32> = (0..plen).map(|_| rng.below(vocab as u64) as u32).collect();
            let got = engine.prefill(i as SeqId, &prompt).expect("prefill");
            let mut c = KvCache::new(model.config.n_layers);
            let want = model.prefill(&mut c, &prompt);
            assert_eq!(got, want.data, "case {case}: prefill logits diverge (seq {i})");
            caches.push(c);
        }

        let rounds = rng.range(2, 5);
        for round in 0..rounds {
            let step: Vec<(SeqId, u32)> = (0..batch)
                .map(|i| (i as SeqId, rng.below(vocab as u64) as u32))
                .collect();
            let got = engine.decode(&step).expect("decode").expect_complete();
            for (i, cache) in caches.iter_mut().enumerate() {
                let want = model.decode_step(cache, step[i].1);
                assert_eq!(
                    got[i], want.data,
                    "case {case} round {round} seq {i}: batched paged decode \
                     is not bit-identical to per-sequence decode"
                );
            }
            engine.alloc.check_invariants().unwrap_or_else(|e| panic!("case {case}: {e}"));
        }

        for i in 0..batch {
            engine.release(i as SeqId);
        }
        // With the prefix cache enabled (the default), released prompts
        // stay resident in the radix tree; everything else must be freed.
        assert_eq!(
            engine.used_blocks(),
            engine.cached_blocks(),
            "case {case}: leaked blocks beyond radix-tree residency"
        );
        engine.alloc.check_invariants().unwrap_or_else(|e| panic!("case {case}: {e}"));
    }
}

/// Batcher: never exceeds max_batch, never loses or reorders requests.
#[test]
fn prop_batcher_bounds_and_order() {
    for case in 0..20u64 {
        let mut rng = Rng::new(case * 53 + 29);
        let max_batch = rng.range(1, 9);
        let q = RequestQueue::new(128);
        let n = rng.range(1, 64);
        for i in 0..n as u64 {
            q.push(Request::new(i, vec![1], 1));
        }
        let b = Batcher::new(BatcherConfig { max_batch, max_wait: Duration::from_millis(0) });
        let mut seen = Vec::new();
        loop {
            let batch = b.next_batch(&q, Duration::from_millis(1));
            if batch.is_empty() {
                break;
            }
            assert!(batch.len() <= max_batch, "case {case}");
            seen.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(seen, (0..n as u64).collect::<Vec<_>>(), "case {case}: order/loss");
    }
}
