//! Property tests for the blocked parallel paged-attention kernel, the
//! fused Q/K/V weight packing, and the radix-tree prefix cache — the
//! bit-exactness contracts of the serving engine:
//!
//! 1. `paged_attention_decode` (blocked, parallel over (row, head) work
//!    items) is **bit-identical** to the retained serial reference at any
//!    worker count, across random batch sizes, block sizes, head counts,
//!    history lengths, and per-sequence query row counts (decode rows and
//!    causally masked prefill chunks alike). CI additionally runs the
//!    whole suite under `BDA_NUM_THREADS=1` and `=8` so the env-driven
//!    default path is covered end to end.
//! 2. The packed Q/K/V projection (`FusedQkv`) equals the three separate
//!    projections bitwise for every packable attention variant, and the
//!    paged engine built on both stays bit-identical to per-sequence
//!    decode for MHA and BDA alike.
//! 3. A **prefix-cache hit is bitwise-identical to a cold prefill**
//!    (engine invariant 4): adopting cached prompt blocks and prefilling
//!    only the tail changes no logits, for MHA and BDA, at worker counts
//!    {1, 2, 8}.
//!
//! Worker counts are pinned per call (`_with_workers` / `_on`) rather
//! than via `BDA_NUM_THREADS` because the env var is latched once per
//! process; the kernel routes through the persistent parked pool either
//! way, so the sweep also exercises pool dispatch at widths below the
//! pool size and repeated dispatch on long-lived dedicated pools.

use bda::attention::bda::BdaWeights;
use bda::attention::mha::MhaWeights;
use bda::attention::paged::{
    paged_attention_decode_on, paged_attention_decode_serial, paged_attention_decode_with_workers,
    PagedLayerView, PagedSeq,
};
use bda::attention::AttnShape;
use bda::bd::Strategy;
use bda::bench_support::scatter_paged_kv;
use bda::coordinator::kv_cache::{KvCacheConfig, SeqId};
use bda::coordinator::scheduler::Backend;
use bda::coordinator::{Request, Scheduler, SchedulerConfig};
use bda::engine::PagedNativeBackend;
use bda::model::transformer::KvCache;
use bda::model::weights::FusedQkv;
use bda::model::{AttentionImpl, ModelConfig, Transformer};
use bda::tensor::{DType, Tensor};
use bda::util::rng::Rng;
use bda::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Fisher–Yates shuffle of 0..n (deterministic per rng state).
fn permutation(n: usize, rng: &mut Rng) -> Vec<usize> {
    let mut p: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        let j = rng.below((i + 1) as u64) as usize;
        p.swap(i, j);
    }
    p
}

#[test]
fn prop_parallel_paged_attention_is_bit_identical_to_serial() {
    for case in 0..25u64 {
        let mut rng = Rng::new(case * 9973 + 17);
        let d_h = [2usize, 4, 8][rng.below(3) as usize];
        let n_heads = rng.range(1, 4);
        let d = d_h * rng.range(2, 5); // any d > d_h works for the operator
        let s = AttnShape::new(d, n_heads, d_h);
        let width = s.proj_width();
        let block_size = rng.range(1, 8);
        let b = rng.range(1, 6);
        let lens: Vec<usize> = (0..b).map(|_| rng.range(1, 40)).collect();

        // Disjoint per-sequence block tables carved from a shuffled pool.
        let blocks_needed: usize = lens.iter().map(|l| l.div_ceil(block_size)).sum();
        let num_blocks = blocks_needed + rng.range(0, 8);
        let perm = permutation(num_blocks, &mut rng);
        let mut tables: Vec<Vec<usize>> = Vec::new();
        let mut next = 0usize;
        for &len in &lens {
            let n = len.div_ceil(block_size);
            tables.push(perm[next..next + n].to_vec());
            next += n;
        }

        // Scatter random K/V histories under the tables.
        let mut pk = vec![0.0f32; num_blocks * block_size * width];
        let mut pv = vec![0.0f32; num_blocks * block_size * width];
        for (si, (&len, table)) in lens.iter().zip(&tables).enumerate() {
            let k = Tensor::randn(&[len, width], 1.0, case * 1000 + si as u64 * 2 + 1);
            let v = Tensor::randn(&[len, width], 1.0, case * 1000 + si as u64 * 2 + 2);
            scatter_paged_kv(&mut pk, &mut pv, &k.data, &v.data, len, width, block_size, table);
        }

        let q = Tensor::randn(&[b, width], 1.0, case * 1000 + 999);
        let layer = PagedLayerView::f32(&pk, &pv, block_size, width);
        let seqs: Vec<PagedSeq> = tables
            .iter()
            .zip(&lens)
            .map(|(t, &len)| PagedSeq { blocks: t, len, q_rows: 1 })
            .collect();

        let serial = paged_attention_decode_serial(&q, &layer, &seqs, s);
        for workers in [1usize, 2, 8] {
            let par = paged_attention_decode_with_workers(&q, &layer, &seqs, s, workers);
            assert_eq!(
                par, serial,
                "case {case} (b={b}, bs={block_size}, heads={n_heads}, d_h={d_h}): \
                 workers {workers} diverged from the serial reference"
            );
        }
    }
}

/// The multi-row generalization of the same contract: each sequence
/// contributes `q_rows` query rows (a causally masked prefill chunk; 1 is
/// a plain decode row), and the blocked parallel kernel must stay
/// bit-identical to the serial reference across random mixes of chunk
/// and decode rows at any worker count.
#[test]
fn prop_multi_row_paged_attention_is_bit_identical_to_serial() {
    for case in 0..15u64 {
        let mut rng = Rng::new(case * 7919 + 23);
        let d_h = [2usize, 4, 8][rng.below(3) as usize];
        let n_heads = rng.range(1, 4);
        let s = AttnShape::new(d_h * rng.range(2, 5), n_heads, d_h);
        let width = s.proj_width();
        let block_size = rng.range(1, 8);
        let b = rng.range(1, 6);
        let lens: Vec<usize> = (0..b).map(|_| rng.range(1, 40)).collect();
        // Per-sequence query row counts: 1 (decode) up to a whole chunk.
        let q_rows: Vec<usize> = lens.iter().map(|&l| rng.range(1, l.min(6))).collect();

        let blocks_needed: usize = lens.iter().map(|l| l.div_ceil(block_size)).sum();
        let num_blocks = blocks_needed + rng.range(0, 8);
        let perm = permutation(num_blocks, &mut rng);
        let mut tables: Vec<Vec<usize>> = Vec::new();
        let mut next = 0usize;
        for &len in &lens {
            let n = len.div_ceil(block_size);
            tables.push(perm[next..next + n].to_vec());
            next += n;
        }
        let mut pk = vec![0.0f32; num_blocks * block_size * width];
        let mut pv = vec![0.0f32; num_blocks * block_size * width];
        for (si, (&len, table)) in lens.iter().zip(&tables).enumerate() {
            let k = Tensor::randn(&[len, width], 1.0, case * 2000 + si as u64 * 2 + 1);
            let v = Tensor::randn(&[len, width], 1.0, case * 2000 + si as u64 * 2 + 2);
            scatter_paged_kv(&mut pk, &mut pv, &k.data, &v.data, len, width, block_size, table);
        }

        let total_rows: usize = q_rows.iter().sum();
        let q = Tensor::randn(&[total_rows, width], 1.0, case * 2000 + 999);
        let layer = PagedLayerView::f32(&pk, &pv, block_size, width);
        let seqs: Vec<PagedSeq> = tables
            .iter()
            .zip(lens.iter().zip(&q_rows))
            .map(|(t, (&len, &q_rows))| PagedSeq { blocks: t, len, q_rows })
            .collect();

        let serial = paged_attention_decode_serial(&q, &layer, &seqs, s);
        for workers in [1usize, 2, 8] {
            let par = paged_attention_decode_with_workers(&q, &layer, &seqs, s, workers);
            assert_eq!(
                par, serial,
                "case {case} (b={b}, bs={block_size}, rows={q_rows:?}): \
                 workers {workers} diverged from the serial reference"
            );
        }
    }
}

/// Dedicated persistent pools: a long-lived [`ThreadPool`] per worker
/// count, dispatched repeatedly, must stay bit-identical to the serial
/// reference on every dispatch — per-worker scratch arenas surviving
/// across dispatches must not leak state between calls.
#[test]
fn prop_paged_parallel_bitwise_on_dedicated_pools() {
    let s = AttnShape::new(24, 3, 8);
    let width = s.proj_width();
    let (block_size, num_blocks) = (4usize, 16usize);
    let lens = [1usize, 7, 12, 4];
    let tables: [&[usize]; 4] = [&[9], &[3, 11], &[0, 5, 14], &[7]];
    let q = Tensor::randn(&[4, width], 1.0, 61);
    let mut pk = vec![0.0f32; num_blocks * block_size * width];
    let mut pv = vec![0.0f32; num_blocks * block_size * width];
    for (i, (&len, table)) in lens.iter().zip(tables.iter()).enumerate() {
        let k = Tensor::randn(&[len, width], 1.0, 70 + i as u64);
        let v = Tensor::randn(&[len, width], 1.0, 80 + i as u64);
        scatter_paged_kv(&mut pk, &mut pv, &k.data, &v.data, len, width, block_size, table);
    }
    let layer = PagedLayerView::f32(&pk, &pv, block_size, width);
    let seqs: Vec<PagedSeq> = lens
        .iter()
        .zip(tables.iter())
        .map(|(&len, &blocks)| PagedSeq { blocks, len, q_rows: 1 })
        .collect();
    let serial = paged_attention_decode_serial(&q, &layer, &seqs, s);
    for workers in [1usize, 2, 8] {
        let pool = ThreadPool::new(workers);
        for round in 0..3 {
            let par = paged_attention_decode_on(&pool, &q, &layer, &seqs, s, workers);
            assert_eq!(
                par, serial,
                "dedicated pool of {workers} diverged from serial on dispatch {round}"
            );
        }
    }
}

#[test]
fn prop_fused_qkv_packing_is_bitwise_exact() {
    for case in 0..10u64 {
        let mut rng = Rng::new(case * 53 + 3);
        let d_h = [2usize, 4, 8][rng.below(3) as usize];
        let s = AttnShape::new(d_h * rng.range(2, 5), rng.range(1, 4), d_h);
        let w = MhaWeights::random(s, case + 500);
        let x = Tensor::randn(&[rng.range(1, 9), s.d], 1.0, case + 900);

        // MHA: one packed [d × 3·n·d_h] GEMM == three GEMMs.
        let attn = AttentionImpl::Mha(w.clone());
        let fused = FusedQkv::pack(&attn);
        assert!(matches!(fused, FusedQkv::Dense { .. }));
        let (q0, k0, v0) = attn.project_qkv(&x);
        let (q1, k1, v1) = fused.project(&x, &attn);
        assert_eq!(q0, q1, "mha q case {case}");
        assert_eq!(k0, k1, "mha k case {case}");
        assert_eq!(v0, v1, "mha v case {case}");

        // BDA prepared with FirstR aligns the QK and VO tags, so packing
        // must take the compact-basis fused path and still match bitwise.
        let bw = BdaWeights::prepare(&w, Strategy::FirstR, DType::F32).unwrap();
        let battn = AttentionImpl::Bda(bw);
        let bfused = FusedQkv::pack(&battn);
        assert!(matches!(bfused, FusedQkv::CompactBasis { .. }));
        let (q0, k0, v0) = battn.project_qkv(&x);
        let (q1, k1, v1) = bfused.project(&x, &battn);
        assert_eq!(q0.data, q1.data, "bda q case {case}");
        assert_eq!(k0.data, k1.data, "bda k case {case}");
        assert_eq!(v0.data, v1.data, "bda v case {case}");
    }
}

/// Invariant 4 (the prefix-cache contract): decode after a prefix-cache
/// hit is **bitwise identical** to cold-prefill decode, for MHA and BDA,
/// at worker counts {1, 2, 8}. Each engine owns a dedicated pool of the
/// swept width (its GEMMs and attention both ride it via the ambient-pool
/// override), serves and releases a warm-up request to seed the radix
/// tree, then serves a second request sharing the prompt prefix — the
/// hit's prefill logits and every subsequent decode step must equal a
/// cold per-sequence run float for float.
#[test]
fn prop_prefix_cache_hit_decode_bitwise_identical_to_cold() {
    let mha = Transformer::new_mha(ModelConfig::tiny(), 400);
    let models = vec![
        ("mha", mha.clone()),
        ("bda", mha.to_bda(Strategy::ResidualMin, DType::F32).unwrap()),
    ];
    for (label, model) in models {
        for workers in [1usize, 2, 8] {
            // Pinned f32 storage: this test compares paged output against
            // the f32 per-sequence KvCache reference, which only matches
            // bitwise at full width (16-bit pools have their own
            // quantize-at-write equivalence suite in prop_kv_dtype.rs).
            let kv = KvCacheConfig { block_size: 4, num_blocks: 128, dtype: DType::F32 };
            let pool = Arc::new(ThreadPool::new(workers));
            let mut engine = PagedNativeBackend::with_thread_pool(model.clone(), kv, pool);
            engine.set_prefix_cache(true); // force on regardless of env
            let shared: Vec<u32> = (0..13).map(|j| (j * 31 + 5) % 250).collect();
            engine.prefill(1, &shared).unwrap();
            for tok in [9u32, 11] {
                engine.decode(&[(1, tok)]).unwrap().expect_complete();
            }
            engine.release(1);
            assert!(engine.cached_blocks() > 0, "{label}/{workers}: tree not seeded");

            let mut prompt = shared.clone();
            prompt.extend([77u32, 3]);
            let before = engine.prefix_stats();
            let got_prefill = engine.prefill(2, &prompt).unwrap();
            let after = engine.prefix_stats();
            assert_eq!(after.hits, before.hits + 1, "{label}/{workers}: lookup must hit");
            assert_eq!(
                after.blocks_saved - before.blocks_saved,
                3,
                "{label}/{workers}: 12 of 15 prompt tokens ride cached blocks"
            );

            let mut cache = KvCache::new(model.config.n_layers);
            let want_prefill = model.prefill(&mut cache, &prompt);
            assert_eq!(
                got_prefill, want_prefill.data,
                "{label}/{workers}: hit prefill logits diverged from cold prefill"
            );
            for tok in [4u32, 19, 249, 8] {
                let got = engine.decode(&[(2, tok)]).unwrap().expect_complete();
                let want = model.decode_step(&mut cache, tok);
                assert_eq!(
                    got[0], want.data,
                    "{label}/{workers}: decode after a cache hit diverged at token {tok}"
                );
            }
            engine.release(2);
            engine.alloc.check_invariants().unwrap();
            assert_eq!(engine.used_blocks(), engine.cached_blocks());
        }
    }
}

/// End-to-end engine property: batched decode through the paged engine
/// (blocked parallel attention + fused QKV) reproduces per-sequence decode
/// bit for bit, for MHA and both BDA preparations, across random batch
/// compositions and block sizes.
#[test]
fn prop_engine_decode_bit_identical_to_per_seq() {
    for case in 0..3u64 {
        let mha = Transformer::new_mha(ModelConfig::tiny(), 100 + case);
        let models = vec![
            ("mha", mha.clone()),
            ("bda-residmin", mha.to_bda(Strategy::ResidualMin, DType::F32).unwrap()),
            ("bda-firstr", mha.to_bda(Strategy::FirstR, DType::F32).unwrap()),
        ];
        let mut rng = Rng::new(case * 31 + 7);
        for (label, model) in models {
            // f32 pinned: compared against the f32 per-sequence reference.
            let kv =
                KvCacheConfig { block_size: rng.range(2, 8), num_blocks: 256, dtype: DType::F32 };
            let mut engine = PagedNativeBackend::new(model.clone(), kv);
            let b = rng.range(1, 5);
            let mut caches = Vec::new();
            for i in 0..b {
                let plen = rng.range(1, 9);
                let prompt: Vec<u32> = (0..plen)
                    .map(|j| ((case * 7 + i as u64 * 13 + j as u64) % 251) as u32)
                    .collect();
                engine.prefill(i as SeqId, &prompt).unwrap();
                let mut c = KvCache::new(model.config.n_layers);
                let _ = model.prefill(&mut c, &prompt);
                caches.push(c);
            }
            for round in 0..3u32 {
                let batch: Vec<(SeqId, u32)> =
                    (0..b).map(|i| (i as SeqId, (round * 5 + i as u32) % 250)).collect();
                let got = engine.decode(&batch).unwrap().expect_complete();
                for (i, c) in caches.iter_mut().enumerate() {
                    let want = model.decode_step(c, batch[i].1);
                    assert_eq!(
                        got[i], want.data,
                        "{label} case {case} round {round} seq {i}: \
                         paged batched decode diverged from per-sequence decode"
                    );
                }
            }
        }
    }
}

/// Engine invariant 6 end to end: chunked prefill at any budget produces
/// generations **bitwise identical** to an unbounded (single-chunk) run,
/// for MHA and BDA, at worker counts {1, 8}, prefix cache on and off.
/// The workload fuses chunks with live decodes (a long prompt lands while
/// a short one is mid-generation) and, with the cache on, replays a
/// released prompt so the tail chunk rides adopted blocks.
#[test]
fn prop_chunked_prefill_generations_bitwise_identical_to_monolithic() {
    let mha = Transformer::new_mha(ModelConfig::tiny(), 500);
    let models = vec![
        ("mha", mha.clone()),
        ("bda", mha.to_bda(Strategy::ResidualMin, DType::F32).unwrap()),
    ];
    for (label, model) in &models {
        for workers in [1usize, 8] {
            for cache in [false, true] {
                let run = |chunk: usize| {
                    // Paged-vs-paged comparison: storage dtype inherits the
                    // env (BDA_KV_DTYPE) so the CI axis exercises chunked
                    // prefill on 16-bit pools too.
                    let kv = KvCacheConfig { block_size: 4, num_blocks: 256, ..Default::default() };
                    let pool = Arc::new(ThreadPool::new(workers));
                    let mut backend =
                        PagedNativeBackend::with_thread_pool(model.clone(), kv, pool);
                    backend.set_prefix_cache(cache);
                    let mut s = Scheduler::new(
                        backend,
                        SchedulerConfig {
                            max_active: 4,
                            eos_token: None,
                            kv,
                            prefill_chunk: chunk,
                        },
                    );
                    let short: Vec<u32> = (0u32..6).map(|j| (j * 17 + 3) % 250).collect();
                    s.admit(Request::new(1, short, 8)).unwrap();
                    s.step().unwrap();
                    let long: Vec<u32> = (0u32..29).map(|j| (j * 13 + 1) % 250).collect();
                    s.admit(Request::new(2, long.clone(), 6)).unwrap();
                    let mut done = s.drain().unwrap();
                    // Re-serve the long prompt: with the cache on, its
                    // released blocks make this admission a prefix hit
                    // whose uncovered tail still prefills in chunks.
                    s.admit(Request::new(3, long, 5)).unwrap();
                    done.extend(s.drain().unwrap());
                    done.sort_by_key(|r| r.id);
                    done.into_iter().map(|r| (r.id, r.tokens)).collect::<Vec<_>>()
                };
                let monolithic = run(0);
                assert_eq!(monolithic.len(), 3, "{label}: lost responses");
                for chunk in [4usize, 512] {
                    let tag = format!("{label}/workers={workers}/cache={cache}/chunk={chunk}");
                    assert_eq!(
                        run(chunk),
                        monolithic,
                        "{tag}: chunked generations diverged from monolithic (invariant 6)"
                    );
                }
            }
        }
    }
}
