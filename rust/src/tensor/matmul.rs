//! Blocked, multithreaded matrix multiplication — the L3 hot path.
//!
//! The Fig. 2b / Tables 6–7 operator benchmarks bottom out here, so this is
//! written for throughput: row-panel parallelism across the persistent
//! parked worker pool ([`crate::util::threadpool`]; dispatch wakes parked
//! workers instead of spawning threads, so per-layer-per-step GEMMs carry
//! no spawn cost — and rides the caller's ambient pool under
//! [`crate::util::threadpool::with_pool`], so engine GEMMs use the
//! engine's dedicated workers), a k-blocked micro-kernel over contiguous rows of B
//! (unit-stride loads for both operands), and f32 accumulation. Logical
//! f16/bf16 matmuls quantize the *output* through the dtype (inputs are
//! assumed already quantized), matching a 16-bit-storage /
//! 32-bit-accumulate GPU tensor-core pipeline.

use super::{DType, Tensor};
use crate::util::threadpool::{parallel_chunks, SendPtr};

/// Tuning knobs for the blocked kernel. Values chosen by the perf pass
/// (EXPERIMENTS.md §Perf) on this CPU.
const KC: usize = 256; // k-dimension block
const MR: usize = 4; // row micro-tile

/// `C = A @ B` for 2-D tensors. Accumulates in f32, quantizes the result
/// through `out_dtype`.
pub fn matmul_dt(a: &Tensor, b: &Tensor, out_dtype: DType) -> Tensor {
    assert_eq!(a.ndim(), 2, "matmul: A must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul: B must be 2-D");
    let (m, k) = (a.shape[0], a.shape[1]);
    let (kb, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, kb, "matmul inner dim mismatch: {k} vs {kb}");

    let mut out = Tensor::zeros(&[m, n]);
    out.dtype = out_dtype;
    matmul_into(&a.data, &b.data, &mut out.data, m, k, n);
    out_dtype.quantize_slice(&mut out.data);
    out
}

/// `C = A @ B` in the dtype of `a`.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_dt(a, b, a.dtype)
}

/// Raw blocked GEMM on slices: `c[m×n] = a[m×k] @ b[k×n]` (c pre-zeroed).
/// Parallel over row panels.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);

    // Choose a row-panel size that gives each worker a few panels. Sized
    // by the *current* dispatch pool — the engine's own pool when the call
    // runs under `threadpool::with_pool` (per-engine GEMM pools), the
    // process-wide width otherwise. Panel boundaries never change
    // per-element accumulation order (each output row accumulates over k
    // in the same fixed order regardless of row partitioning), so this is
    // a pure scheduling choice.
    let threads = crate::util::threadpool::current_workers();
    let panel = (m.div_ceil(threads * 4)).clamp(MR, 64.max(MR));

    // SAFETY of the parallel write: panels are disjoint row ranges of C.
    let c_addr = SendPtr(c.as_mut_ptr());
    parallel_chunks(m, panel, |lo, hi| {
        let c_panel =
            unsafe { std::slice::from_raw_parts_mut(c_addr.get().add(lo * n), (hi - lo) * n) };
        gemm_panel(&a[lo * k..hi * k], b, c_panel, hi - lo, k, n, k);
    });
}

/// Single-threaded panel GEMM: k-blocked, MR-row micro-tiles, B rows
/// traversed contiguously (i-k-j order) so the inner loop is a saxpy over
/// unit-stride slices — autovectorizes well. Public so the fused attention
/// operators (kproj) reuse the same micro-kernel as plain matmul —
/// otherwise operator comparisons measure GEMM quality, not algorithm
/// (EXPERIMENTS.md §Perf, iteration 1).
pub fn gemm_serial(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm_panel(a, b, c, m, k, n, k)
}

/// Strided-A GEMM accumulate: row i of A starts at `a[i*lda]`, uses columns
/// `[0, k)`. Lets fused operators run directly on a column-slice of X
/// without packing a contiguous copy (perf iteration 2: the pack cost an
/// extra read+write of X_rest per call, which dominated beyond LLC sizes).
pub fn gemm_serial_strided(
    a: &[f32],
    lda: usize,
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert!(lda >= k);
    gemm_panel(a, b, c, m, k, n, lda)
}

fn gemm_panel(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    lda: usize,
) {
    for kc0 in (0..k).step_by(KC) {
        let kc1 = (kc0 + KC).min(k);
        let mut i = 0;
        while i + MR <= m {
            gemm_micro::<MR>(a, b, c, i, kc0, kc1, lda, n);
            i += MR;
        }
        while i < m {
            gemm_micro::<1>(a, b, c, i, kc0, kc1, lda, n);
            i += 1;
        }
    }
}

/// Micro-kernel: R rows of A against the k-block, updating R rows of C.
/// `k` here is the A row stride (lda).
#[inline]
fn gemm_micro<const R: usize>(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    i: usize,
    kc0: usize,
    kc1: usize,
    k: usize,
    n: usize,
) {
    for p in kc0..kc1 {
        let brow = &b[p * n..p * n + n];
        // Load the R A-scalars once per k-step.
        let mut ar = [0.0f32; R];
        for r in 0..R {
            ar[r] = a[(i + r) * k + p];
        }
        for r in 0..R {
            let crow = &mut c[(i + r) * n..(i + r) * n + n];
            let av = ar[r];
            if av != 0.0 {
                for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += av * bv;
                }
            }
        }
    }
}

/// Naive reference matmul (for tests).
pub fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[1];
    assert_eq!(k, b.shape[0]);
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for p in 0..k {
            let av = a.data[i * k + p];
            for j in 0..n {
                out.data[i * n + j] += av * b.data[p * n + j];
            }
        }
    }
    out
}

/// FLOPs of an m×k @ k×n multiply (2mkn, the paper's convention).
pub fn gemm_flops(m: usize, k: usize, n: usize) -> u64 {
    2 * m as u64 * k as u64 * n as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_naive_small() {
        let a = Tensor::randn(&[7, 5], 1.0, 1);
        let b = Tensor::randn(&[5, 9], 1.0, 2);
        let fast = matmul(&a, &b);
        let slow = matmul_naive(&a, &b);
        assert!(fast.max_abs_diff(&slow) < 1e-4);
    }

    #[test]
    fn matches_naive_blocked_sizes() {
        // Exercise k-blocking (k > KC) and row tail (m % MR != 0).
        let a = Tensor::randn(&[13, 300], 0.5, 3);
        let b = Tensor::randn(&[300, 17], 0.5, 4);
        let fast = matmul(&a, &b);
        let slow = matmul_naive(&a, &b);
        assert!(fast.max_abs_diff(&slow) < 1e-3);
    }

    #[test]
    fn identity_is_noop() {
        let a = Tensor::randn(&[6, 6], 1.0, 5);
        let i = Tensor::eye(6);
        assert!(matmul(&a, &i).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&i, &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn shapes() {
        let a = Tensor::zeros(&[3, 4]);
        let b = Tensor::zeros(&[4, 2]);
        assert_eq!(matmul(&a, &b).shape, vec![3, 2]);
    }

    #[test]
    #[should_panic]
    fn inner_dim_mismatch_panics() {
        let _ = matmul(&Tensor::zeros(&[2, 3]), &Tensor::zeros(&[4, 2]));
    }

    #[test]
    fn f16_output_quantized() {
        let a = Tensor::from_vec(vec![1.0, 2f32.powi(-12)], &[1, 2]).cast(DType::F16);
        let b = Tensor::from_vec(vec![1.0, 1.0], &[2, 1]).cast(DType::F16);
        let c = matmul(&a, &b);
        // 1 + 2^-12 rounds to 1.0 in f16
        assert_eq!(c.data[0], 1.0);
        assert_eq!(c.dtype, DType::F16);
    }

    #[test]
    fn parallel_equals_serial() {
        // Large enough to span several panels.
        let a = Tensor::randn(&[200, 64], 0.3, 6);
        let b = Tensor::randn(&[64, 96], 0.3, 7);
        let fast = matmul(&a, &b);
        let slow = matmul_naive(&a, &b);
        assert!(fast.max_abs_diff(&slow) < 1e-3);
    }

    #[test]
    fn gemm_flops_formula() {
        assert_eq!(gemm_flops(2, 3, 4), 48);
    }
}
