//! Logical dtypes with bit-exact rounding simulation.
//!
//! All Rust-side compute is carried in `f32`; `F16`/`BF16` are *logical*
//! dtypes realized by round-tripping values through the 16-bit format after
//! each op that the paper's kernels would perform in 16-bit. This reproduces
//! the paper's FP16/BF16 numerics (Tables 4–7) without a `half` dependency.

/// Logical element type of a tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    BF16,
}

impl DType {
    /// Bytes per element in the *stored* format the paper benchmarks
    /// (used for memory-footprint accounting, not for our f32 carrier).
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F16 | DType::BF16 => 2,
        }
    }

    /// Round an f32 value through this dtype's representation.
    #[inline]
    pub fn quantize(self, x: f32) -> f32 {
        match self {
            DType::F32 => x,
            DType::F16 => f16_to_f32(f32_to_f16(x)),
            DType::BF16 => bf16_to_f32(f32_to_bf16(x)),
        }
    }

    /// Quantize a whole slice in place.
    pub fn quantize_slice(self, xs: &mut [f32]) {
        if self == DType::F32 {
            return;
        }
        for x in xs.iter_mut() {
            *x = self.quantize(*x);
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "fp32",
            DType::F16 => "fp16",
            DType::BF16 => "bf16",
        }
    }

    pub fn parse(s: &str) -> Option<DType> {
        match s.to_ascii_lowercase().as_str() {
            "fp32" | "f32" | "float32" => Some(DType::F32),
            "fp16" | "f16" | "float16" => Some(DType::F16),
            "bf16" | "bfloat16" => Some(DType::BF16),
            _ => None,
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

// ---- IEEE 754 binary16 -----------------------------------------------------

/// f32 -> IEEE binary16 bits, round-to-nearest-even, with proper
/// subnormal/overflow/NaN handling.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // Unbiased exponent
    let e = exp - 127;
    if e > 15 {
        // Overflow -> inf
        return sign | 0x7C00;
    }
    if e >= -14 {
        // Normal range. 10-bit mantissa, round to nearest even on bit 13.
        let m = mant >> 13;
        let rest = mant & 0x1FFF;
        let mut h = sign | (((e + 15) as u16) << 10) | m as u16;
        if rest > 0x1000 || (rest == 0x1000 && (m & 1) == 1) {
            h = h.wrapping_add(1); // may carry into exponent — correct behaviour
        }
        return h;
    }
    if e >= -24 {
        // Subnormal half
        let shift = (-14 - e) as u32; // 1..=10
        let full = 0x0080_0000 | mant; // implicit leading 1
        let m = full >> (13 + shift);
        let rest = full & ((1u32 << (13 + shift)) - 1);
        let half_ulp = 1u32 << (12 + shift);
        let mut h = sign | m as u16;
        if rest > half_ulp || (rest == half_ulp && (m & 1) == 1) {
            h = h.wrapping_add(1);
        }
        return h;
    }
    // Underflow to signed zero
    sign
}

/// IEEE binary16 bits -> f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: normalize
            let mut e = 0i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03FF;
            sign | (((127 - 15 + e + 1) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

// ---- bfloat16 ---------------------------------------------------------------

/// f32 -> bfloat16 bits, round-to-nearest-even.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // quiet the NaN
    }
    let round_bit = 0x0000_8000u32;
    let lower = bits & 0xFFFF;
    let mut upper = (bits >> 16) as u16;
    if lower > round_bit || (lower == round_bit && (upper & 1) == 1) {
        upper = upper.wrapping_add(1);
    }
    upper
}

/// bfloat16 bits -> f32.
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_roundtrip_exact_values() {
        // Values exactly representable in binary16 survive the round trip.
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.25, 1.5] {
            assert_eq!(f16_to_f32(f32_to_f16(v)), v, "{v}");
        }
    }

    #[test]
    fn f16_rounds_to_nearest() {
        // 1 + 2^-11 is exactly between 1.0 and 1+2^-10 -> ties to even (1.0).
        let x = 1.0 + 2f32.powi(-11);
        assert_eq!(f16_to_f32(f32_to_f16(x)), 1.0);
        // Slightly above the midpoint rounds up.
        let y = 1.0 + 2f32.powi(-11) + 2f32.powi(-16);
        assert_eq!(f16_to_f32(f32_to_f16(y)), 1.0 + 2f32.powi(-10));
    }

    #[test]
    fn f16_overflow_to_inf() {
        assert!(f16_to_f32(f32_to_f16(1e6)).is_infinite());
        assert!(f16_to_f32(f32_to_f16(-1e6)).is_infinite());
        assert_eq!(f16_to_f32(f32_to_f16(65519.0)), 65504.0); // below the midpoint -> max finite
        assert!(f16_to_f32(f32_to_f16(65520.0)).is_infinite()); // at midpoint -> ties up
    }

    #[test]
    fn f16_subnormals() {
        let min_sub = 2f32.powi(-24);
        assert_eq!(f16_to_f32(f32_to_f16(min_sub)), min_sub);
        assert_eq!(f16_to_f32(f32_to_f16(min_sub * 0.4)), 0.0);
        let min_norm = 2f32.powi(-14);
        assert_eq!(f16_to_f32(f32_to_f16(min_norm)), min_norm);
    }

    #[test]
    fn f16_nan_preserved() {
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
    }

    #[test]
    fn bf16_roundtrip_exact() {
        for v in [0.0f32, -0.0, 1.0, -2.0, 0.5, 3.0e38, 1.0e-38] {
            let rt = bf16_to_f32(f32_to_bf16(v));
            let rel = if v == 0.0 { (rt - v).abs() } else { ((rt - v) / v).abs() };
            assert!(rel <= 1.0 / 128.0, "{v} -> {rt}");
        }
        assert_eq!(bf16_to_f32(f32_to_bf16(1.0)), 1.0);
    }

    #[test]
    fn bf16_round_nearest_even() {
        // 1 + 2^-8 is halfway between 1.0 and 1+2^-7 -> ties to even = 1.0.
        let x = 1.0 + 2f32.powi(-8);
        assert_eq!(bf16_to_f32(f32_to_bf16(x)), 1.0);
        let y = 1.0 + 2f32.powi(-8) + 2f32.powi(-12);
        assert_eq!(bf16_to_f32(f32_to_bf16(y)), 1.0 + 2f32.powi(-7));
    }

    #[test]
    fn bf16_precision_coarser_than_f16_near_one() {
        let x = 1.0 + 2f32.powi(-9);
        let e_bf = (bf16_to_f32(f32_to_bf16(x)) - x).abs();
        let e_f16 = (f16_to_f32(f32_to_f16(x)) - x).abs();
        assert!(e_bf > e_f16);
    }

    #[test]
    fn quantize_slice_f32_noop() {
        let mut xs = [1.1f32, 2.2, 3.3];
        let orig = xs;
        DType::F32.quantize_slice(&mut xs);
        assert_eq!(xs, orig);
    }

    #[test]
    fn dtype_parse_and_name() {
        assert_eq!(DType::parse("FP16"), Some(DType::F16));
        assert_eq!(DType::parse("bfloat16"), Some(DType::BF16));
        assert_eq!(DType::parse("f32"), Some(DType::F32));
        assert_eq!(DType::parse("int8"), None);
        assert_eq!(DType::BF16.name(), "bf16");
    }

    #[test]
    fn exhaustive_f16_bits_roundtrip() {
        // Every finite f16 bit pattern must round-trip bits->f32->bits.
        for bits in 0u16..=0xFFFF {
            let exp = (bits >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // inf/nan: representation not unique
            }
            let f = f16_to_f32(bits);
            assert_eq!(f32_to_f16(f), bits, "bits {bits:#06x} -> {f}");
        }
    }
}
