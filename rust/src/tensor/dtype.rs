//! Logical dtypes with bit-exact rounding simulation.
//!
//! All Rust-side compute is carried in `f32`; `F16`/`BF16` are *logical*
//! dtypes realized by round-tripping values through the 16-bit format after
//! each op that the paper's kernels would perform in 16-bit. This reproduces
//! the paper's FP16/BF16 numerics (Tables 4–7) without a `half` dependency.

/// Logical element type of a tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    BF16,
}

impl DType {
    /// Bytes per element in the *stored* format the paper benchmarks
    /// (used for memory-footprint accounting, not for our f32 carrier).
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F16 | DType::BF16 => 2,
        }
    }

    /// Round an f32 value through this dtype's representation.
    #[inline]
    pub fn quantize(self, x: f32) -> f32 {
        match self {
            DType::F32 => x,
            DType::F16 => f16_to_f32(f32_to_f16(x)),
            DType::BF16 => bf16_to_f32(f32_to_bf16(x)),
        }
    }

    /// Quantize a whole slice in place.
    pub fn quantize_slice(self, xs: &mut [f32]) {
        if self == DType::F32 {
            return;
        }
        for x in xs.iter_mut() {
            *x = self.quantize(*x);
        }
    }

    /// Narrowing store for this dtype's 16-bit word — the write half of
    /// quantize-at-write K/V storage ([`crate::engine::PagedKvPool`]).
    /// `widen_u16(narrow_f32(x)) == quantize(x)` bit for bit, which is
    /// what makes 16-bit pool storage equivalent to an f32 pool whose
    /// writes pass through [`DType::quantize_slice`] (engine invariant 7).
    pub fn narrow_f32(self) -> fn(f32) -> u16 {
        match self {
            DType::F32 => |_| panic!("F32 has no 16-bit storage word"),
            DType::F16 => f32_to_f16,
            DType::BF16 => f32_to_bf16,
        }
    }

    /// Widening load for this dtype's 16-bit word — the read half of
    /// 16-bit K/V storage. Widening is exact for both F16 and BF16 (every
    /// 16-bit value is representable in f32), so reading back a stored
    /// row reproduces the quantized f32 values bit for bit.
    pub fn widen_u16(self) -> fn(u16) -> f32 {
        match self {
            DType::F32 => |_| panic!("F32 has no 16-bit storage word"),
            DType::F16 => f16_to_f32,
            DType::BF16 => bf16_to_f32,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "fp32",
            DType::F16 => "fp16",
            DType::BF16 => "bf16",
        }
    }

    pub fn parse(s: &str) -> Option<DType> {
        match s.to_ascii_lowercase().as_str() {
            "fp32" | "f32" | "float32" => Some(DType::F32),
            "fp16" | "f16" | "float16" => Some(DType::F16),
            "bf16" | "bfloat16" => Some(DType::BF16),
            _ => None,
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

// ---- IEEE 754 binary16 -----------------------------------------------------

/// f32 -> IEEE binary16 bits, round-to-nearest-even, with proper
/// subnormal/overflow/NaN handling.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // Unbiased exponent
    let e = exp - 127;
    if e > 15 {
        // Overflow -> inf
        return sign | 0x7C00;
    }
    if e >= -14 {
        // Normal range. 10-bit mantissa, round to nearest even on bit 13.
        let m = mant >> 13;
        let rest = mant & 0x1FFF;
        let mut h = sign | (((e + 15) as u16) << 10) | m as u16;
        if rest > 0x1000 || (rest == 0x1000 && (m & 1) == 1) {
            h = h.wrapping_add(1); // may carry into exponent — correct behaviour
        }
        return h;
    }
    if e >= -24 {
        // Subnormal half
        let shift = (-14 - e) as u32; // 1..=10
        let full = 0x0080_0000 | mant; // implicit leading 1
        let m = full >> (13 + shift);
        let rest = full & ((1u32 << (13 + shift)) - 1);
        let half_ulp = 1u32 << (12 + shift);
        let mut h = sign | m as u16;
        if rest > half_ulp || (rest == half_ulp && (m & 1) == 1) {
            h = h.wrapping_add(1);
        }
        return h;
    }
    // Underflow to signed zero
    sign
}

/// IEEE binary16 bits -> f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: normalize
            let mut e = 0i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03FF;
            sign | (((127 - 15 + e + 1) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

// ---- bfloat16 ---------------------------------------------------------------

/// f32 -> bfloat16 bits, round-to-nearest-even.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // quiet the NaN
    }
    let round_bit = 0x0000_8000u32;
    let lower = bits & 0xFFFF;
    let mut upper = (bits >> 16) as u16;
    if lower > round_bit || (lower == round_bit && (upper & 1) == 1) {
        upper = upper.wrapping_add(1);
    }
    upper
}

/// bfloat16 bits -> f32.
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_roundtrip_exact_values() {
        // Values exactly representable in binary16 survive the round trip.
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.25, 1.5] {
            assert_eq!(f16_to_f32(f32_to_f16(v)), v, "{v}");
        }
    }

    #[test]
    fn f16_rounds_to_nearest() {
        // 1 + 2^-11 is exactly between 1.0 and 1+2^-10 -> ties to even (1.0).
        let x = 1.0 + 2f32.powi(-11);
        assert_eq!(f16_to_f32(f32_to_f16(x)), 1.0);
        // Slightly above the midpoint rounds up.
        let y = 1.0 + 2f32.powi(-11) + 2f32.powi(-16);
        assert_eq!(f16_to_f32(f32_to_f16(y)), 1.0 + 2f32.powi(-10));
    }

    #[test]
    fn f16_overflow_to_inf() {
        assert!(f16_to_f32(f32_to_f16(1e6)).is_infinite());
        assert!(f16_to_f32(f32_to_f16(-1e6)).is_infinite());
        assert_eq!(f16_to_f32(f32_to_f16(65519.0)), 65504.0); // below the midpoint -> max finite
        assert!(f16_to_f32(f32_to_f16(65520.0)).is_infinite()); // at midpoint -> ties up
    }

    #[test]
    fn f16_subnormals() {
        let min_sub = 2f32.powi(-24);
        assert_eq!(f16_to_f32(f32_to_f16(min_sub)), min_sub);
        assert_eq!(f16_to_f32(f32_to_f16(min_sub * 0.4)), 0.0);
        let min_norm = 2f32.powi(-14);
        assert_eq!(f16_to_f32(f32_to_f16(min_norm)), min_norm);
    }

    #[test]
    fn f16_nan_preserved() {
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
    }

    #[test]
    fn bf16_roundtrip_exact() {
        for v in [0.0f32, -0.0, 1.0, -2.0, 0.5, 3.0e38, 1.0e-38] {
            let rt = bf16_to_f32(f32_to_bf16(v));
            let rel = if v == 0.0 { (rt - v).abs() } else { ((rt - v) / v).abs() };
            assert!(rel <= 1.0 / 128.0, "{v} -> {rt}");
        }
        assert_eq!(bf16_to_f32(f32_to_bf16(1.0)), 1.0);
    }

    #[test]
    fn bf16_round_nearest_even() {
        // 1 + 2^-8 is halfway between 1.0 and 1+2^-7 -> ties to even = 1.0.
        let x = 1.0 + 2f32.powi(-8);
        assert_eq!(bf16_to_f32(f32_to_bf16(x)), 1.0);
        let y = 1.0 + 2f32.powi(-8) + 2f32.powi(-12);
        assert_eq!(bf16_to_f32(f32_to_bf16(y)), 1.0 + 2f32.powi(-7));
    }

    #[test]
    fn bf16_precision_coarser_than_f16_near_one() {
        let x = 1.0 + 2f32.powi(-9);
        let e_bf = (bf16_to_f32(f32_to_bf16(x)) - x).abs();
        let e_f16 = (f16_to_f32(f32_to_f16(x)) - x).abs();
        assert!(e_bf > e_f16);
    }

    #[test]
    fn quantize_slice_f32_noop() {
        let mut xs = [1.1f32, 2.2, 3.3];
        let orig = xs;
        DType::F32.quantize_slice(&mut xs);
        assert_eq!(xs, orig);
    }

    #[test]
    fn dtype_parse_and_name() {
        assert_eq!(DType::parse("FP16"), Some(DType::F16));
        assert_eq!(DType::parse("bfloat16"), Some(DType::BF16));
        assert_eq!(DType::parse("f32"), Some(DType::F32));
        assert_eq!(DType::parse("int8"), None);
        assert_eq!(DType::BF16.name(), "bf16");
    }

    #[test]
    fn exhaustive_f16_bits_roundtrip() {
        // Every finite f16 bit pattern must round-trip bits->f32->bits.
        for bits in 0u16..=0xFFFF {
            let exp = (bits >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // inf/nan: representation not unique
            }
            let f = f16_to_f32(bits);
            assert_eq!(f32_to_f16(f), bits, "bits {bits:#06x} -> {f}");
        }
    }

    #[test]
    fn exhaustive_f16_bits_all_classes() {
        // All 65,536 patterns, including inf/NaN: the round trip preserves
        // the value class (finite values exactly — subnormals included —
        // infinities exactly, NaNs stay NaN with the sign preserved), and
        // widening never changes a finite value (f16 ⊂ f32 exactly).
        for bits in 0u16..=0xFFFF {
            let f = f16_to_f32(bits);
            let exp = (bits >> 10) & 0x1F;
            let mant = bits & 0x03FF;
            let sign_neg = bits & 0x8000 != 0;
            if exp == 0x1F && mant != 0 {
                assert!(f.is_nan(), "NaN bits {bits:#06x} widened to {f}");
                let rt = f32_to_f16(f);
                assert_eq!(rt >> 10 & 0x1F, 0x1F, "{bits:#06x}");
                assert_ne!(rt & 0x03FF, 0, "NaN class lost for {bits:#06x}");
                assert_eq!(rt & 0x8000 != 0, sign_neg, "NaN sign lost for {bits:#06x}");
            } else if exp == 0x1F {
                assert!(f.is_infinite());
                assert_eq!(f32_to_f16(f), bits);
            } else {
                assert!(f.is_finite());
                assert_eq!(f.is_sign_negative(), sign_neg, "{bits:#06x}");
                // Quantizing an exactly-representable value is the identity.
                assert_eq!(DType::F16.quantize(f).to_bits(), f.to_bits());
                assert_eq!(f32_to_f16(f), bits);
            }
        }
    }

    #[test]
    fn exhaustive_bf16_bits_roundtrip() {
        // All 65,536 bf16 patterns. Finite values (subnormals included)
        // widen exactly — the low 16 f32 mantissa bits are zero — so the
        // narrowing round trip is the identity. NaNs keep their payload
        // and sign, with only the quiet bit (0x0040) forced on.
        for bits in 0u16..=0xFFFF {
            let f = bf16_to_f32(bits);
            let exp = (bits >> 7) & 0xFF;
            let mant = bits & 0x007F;
            if exp == 0xFF && mant != 0 {
                assert!(f.is_nan(), "NaN bits {bits:#06x} widened to {f}");
                assert_eq!(f32_to_bf16(f), bits | 0x0040, "payload lost for {bits:#06x}");
            } else {
                assert_eq!(f.to_bits(), (bits as u32) << 16, "widening must be exact");
                assert_eq!(f32_to_bf16(f), bits, "bits {bits:#06x} -> {f}");
                if f.is_finite() {
                    assert_eq!(DType::BF16.quantize(f).to_bits(), f.to_bits());
                }
            }
        }
    }

    #[test]
    fn widen_narrow_compose_to_quantize() {
        // The storage pair (narrow_f32, widen_u16) must reproduce quantize()
        // bit for bit — this is what lets a u16 pool stand in for an f32
        // pool with quantize-at-write (engine invariant 7).
        for dt in [DType::F16, DType::BF16] {
            let (narrow, widen) = (dt.narrow_f32(), dt.widen_u16());
            for i in 0..50_000u32 {
                // Deterministic pseudo-random f32 sweep (finite values only).
                let bits = i.wrapping_mul(2_654_435_761).rotate_left(7) ^ 0x5A5A_1234;
                let x = f32::from_bits(bits);
                if !x.is_finite() {
                    continue;
                }
                assert_eq!(
                    widen(narrow(x)).to_bits(),
                    dt.quantize(x).to_bits(),
                    "{dt} x={x:e}"
                );
            }
        }
    }

    /// Correctly rounded f32 -> f16 reference: widen all candidate f16
    /// values to f64 and pick the nearest, breaking ties toward the even
    /// (low-mantissa-bit-zero) candidate. Exhaustive over the f16 lattice,
    /// so it is a ground-truth oracle rather than a reimplementation.
    fn f16_reference_rne(x: f32) -> u16 {
        if x.is_nan() {
            return 0x7E00 | ((x.to_bits() >> 16) as u16 & 0x8000);
        }
        let xd = x as f64;
        let sign = if x.is_sign_negative() { 0x8000u16 } else { 0 };
        let mag = xd.abs();
        // Overflow: 65520 is the midpoint between max-finite (65504) and
        // the next lattice step; at or above it RNE rounds to infinity
        // (the tie goes to the even candidate, which is inf).
        if mag >= 65520.0 {
            return sign | 0x7C00;
        }
        // Magnitudes 0x0000..=0x7C00 (zero..inf) are monotone in bit order.
        let mut best: u16 = 0;
        let mut best_err = f64::INFINITY;
        let mut lo = 0u16;
        let mut hi = 0x7C00u16;
        // Binary search the monotone lattice to a small window, then scan.
        while hi - lo > 8 {
            let mid = lo + (hi - lo) / 2;
            if (f16_to_f32(mid) as f64) <= mag {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        for cand in lo.saturating_sub(1)..=hi {
            let err = ((f16_to_f32(cand) as f64) - mag).abs();
            if err < best_err || (err == best_err && cand & 1 == 0) {
                best_err = err;
                best = cand;
            }
        }
        sign | best
    }

    #[test]
    fn f16_narrowing_matches_big_float_reference() {
        // Deterministic pseudo-random f32s plus every f16 lattice midpoint:
        // f32_to_f16 must agree with the exhaustive f64 oracle everywhere.
        let mut check = |x: f32| {
            let got = f32_to_f16(x);
            let want = f16_reference_rne(x);
            assert_eq!(got, want, "x={x:e} bits={:#010x}", x.to_bits());
        };
        for h in 0u16..0x7C00 {
            // Exact lattice point and the midpoint to its successor — the
            // hardest rounding cases, covering normals and subnormals.
            let a = f16_to_f32(h) as f64;
            let b = f16_to_f32(h + 1) as f64;
            check(a as f32);
            check(((a + b) / 2.0) as f32);
            check(-(((a + b) / 2.0) as f32));
        }
        for i in 0..200_000u32 {
            let bits = i.wrapping_mul(0x9E37_79B9).rotate_left(11) ^ 0x0BAD_F00D;
            let x = f32::from_bits(bits);
            if x.is_finite() {
                check(x);
            }
        }
    }

    #[test]
    fn f16_narrowing_is_monotone() {
        // For finite a <= b, quantize(a) <= quantize(b). Sweep ordered
        // pairs across the whole finite f16 range, including the
        // subnormal band and the overflow edge.
        let mut xs: Vec<f32> = Vec::new();
        for h in 0u16..=0x7BFF {
            let v = f16_to_f32(h) as f64;
            let n = f16_to_f32(h + 1) as f64;
            xs.push(v as f32);
            xs.push((v + (n - v) * 0.25) as f32);
            xs.push(((v + n) / 2.0) as f32);
        }
        xs.push(65520.0); // rounds to inf
        xs.push(1e9);
        xs.sort_by(f32::total_cmp);
        let mut prev = f32::NEG_INFINITY;
        for &x in &xs {
            let q = f16_to_f32(f32_to_f16(x));
            assert!(q >= prev, "monotonicity broken at x={x:e}: {q} < {prev}");
            prev = q;
        }
        // Mirror for negatives: narrowing commutes with negation.
        for &x in &xs {
            assert_eq!(f32_to_f16(-x), f32_to_f16(x) ^ 0x8000, "x={x:e}");
        }
    }
}
