//! Elementwise/structural tensor operations used by the attention operators
//! and the pure-Rust transformer.

use super::{DType, Tensor};

impl Tensor {
    /// Elementwise addition. Shapes must match.
    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape, "add shape mismatch");
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
        out
    }

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape, "sub shape mismatch");
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(other.data.iter()) {
            *a -= b;
        }
        out
    }

    pub fn scale(&self, s: f32) -> Tensor {
        let mut out = self.clone();
        for a in out.data.iter_mut() {
            *a *= s;
        }
        out
    }

    /// 2-D transpose.
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[c, r]);
        out.dtype = self.dtype;
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    /// Column slice `self[:, lo..hi]` of a 2-D tensor.
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Tensor {
        assert_eq!(self.ndim(), 2);
        assert!(lo <= hi && hi <= self.shape[1], "slice_cols out of range");
        let (r, c) = (self.shape[0], self.shape[1]);
        let w = hi - lo;
        let mut out = Tensor::zeros(&[r, w]);
        out.dtype = self.dtype;
        for i in 0..r {
            out.data[i * w..(i + 1) * w].copy_from_slice(&self.data[i * c + lo..i * c + hi]);
        }
        out
    }

    /// Row slice `self[lo..hi, :]` of a 2-D tensor.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        assert_eq!(self.ndim(), 2);
        assert!(lo <= hi && hi <= self.shape[0], "slice_rows out of range");
        let c = self.shape[1];
        let mut out = Tensor::zeros(&[hi - lo, c]);
        out.dtype = self.dtype;
        out.data.copy_from_slice(&self.data[lo * c..hi * c]);
        out
    }

    /// Horizontal concat of 2-D tensors (same row count).
    pub fn concat_cols(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let r = parts[0].shape[0];
        let total: usize = parts.iter().map(|p| {
            assert_eq!(p.ndim(), 2);
            assert_eq!(p.shape[0], r, "concat_cols row mismatch");
            p.shape[1]
        }).sum();
        let mut out = Tensor::zeros(&[r, total]);
        out.dtype = parts[0].dtype;
        for i in 0..r {
            let mut off = 0;
            for p in parts {
                let c = p.shape[1];
                out.data[i * total + off..i * total + off + c]
                    .copy_from_slice(&p.data[i * c..(i + 1) * c]);
                off += c;
            }
        }
        out
    }

    /// Vertical concat of 2-D tensors (same column count).
    pub fn concat_rows(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let c = parts[0].shape[1];
        let total: usize = parts.iter().map(|p| {
            assert_eq!(p.ndim(), 2);
            assert_eq!(p.shape[1], c, "concat_rows col mismatch");
            p.shape[0]
        }).sum();
        let mut out = Tensor::zeros(&[total, c]);
        out.dtype = parts[0].dtype;
        let mut off = 0;
        for p in parts {
            out.data[off..off + p.data.len()].copy_from_slice(&p.data);
            off += p.data.len();
        }
        out
    }

    /// Repeat a 2-D tensor `n` times along the second dimension:
    /// `[X]^{×n}` in the paper's notation (Eq. 12).
    pub fn repeat_cols(&self, n: usize) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[r, c * n]);
        out.dtype = self.dtype;
        for i in 0..r {
            let src = &self.data[i * c..(i + 1) * c];
            for k in 0..n {
                out.data[i * c * n + k * c..i * c * n + (k + 1) * c].copy_from_slice(src);
            }
        }
        out
    }

    /// Row-wise softmax of a 2-D tensor (numerically stable).
    pub fn softmax_rows(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = self.clone();
        for i in 0..r {
            let row = &mut out.data[i * c..(i + 1) * c];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
        out
    }

    /// Row-wise softmax with a causal mask: entry (i, j) is masked (-inf)
    /// when j > i + offset. Used by the decoder attention.
    pub fn softmax_rows_causal(&self, offset: usize) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = self.clone();
        for i in 0..r {
            let row = &mut out.data[i * c..(i + 1) * c];
            let visible = (i + offset + 1).min(c);
            let max = row[..visible].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for v in row[..visible].iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            for v in row[..visible].iter_mut() {
                *v *= inv;
            }
            for v in row[visible..].iter_mut() {
                *v = 0.0;
            }
        }
        out
    }

    /// RMSNorm over the last dim with learned gain.
    pub fn rmsnorm(&self, gain: &[f32], eps: f32) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        assert_eq!(gain.len(), c);
        let mut out = self.clone();
        for i in 0..r {
            let row = &mut out.data[i * c..(i + 1) * c];
            let ms = row.iter().map(|x| x * x).sum::<f32>() / c as f32;
            let inv = 1.0 / (ms + eps).sqrt();
            for (v, g) in row.iter_mut().zip(gain.iter()) {
                *v = *v * inv * g;
            }
        }
        out
    }

    /// SiLU activation x * sigmoid(x), elementwise.
    pub fn silu(&self) -> Tensor {
        let mut out = self.clone();
        for v in out.data.iter_mut() {
            *v = *v / (1.0 + (-*v).exp());
        }
        out
    }

    /// Elementwise product.
    pub fn mul_elem(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(other.data.iter()) {
            *a *= b;
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Mean squared difference vs another tensor (f64 accumulate).
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        let n = self.numel() as f64;
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| {
                let d = a as f64 - b as f64;
                d * d
            })
            .sum::<f64>()
            / n
    }

    /// Max absolute difference.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// NMSE = MSE(a,b) / mean(b^2) — the normalized error of Table 4.
pub fn nmse(approx: &Tensor, exact: &Tensor) -> f64 {
    let mse = approx.mse(exact);
    let denom = exact.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
        / exact.numel() as f64;
    if denom == 0.0 { 0.0 } else { mse / denom }
}

/// Quantize a tensor's values through a dtype without changing the tag
/// (simulates a 16-bit intermediate store).
pub fn quantized_copy(t: &Tensor, dt: DType) -> Tensor {
    let mut out = t.clone();
    dt.quantize_slice(&mut out.data);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(data: Vec<f32>, r: usize, c: usize) -> Tensor {
        Tensor::from_vec(data, &[r, c])
    }

    #[test]
    fn add_sub_scale() {
        let a = t2(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        let b = t2(vec![4.0, 3.0, 2.0, 1.0], 2, 2);
        assert_eq!(a.add(&b).data, vec![5.0; 4]);
        assert_eq!(a.sub(&b).data, vec![-3.0, -1.0, 1.0, 3.0]);
        assert_eq!(a.scale(2.0).data, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn transpose_2d() {
        let a = t2(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        let at = a.transpose();
        assert_eq!(at.shape, vec![3, 2]);
        assert_eq!(at.data, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        assert_eq!(at.transpose(), a);
    }

    #[test]
    fn slicing() {
        let a = t2((1..=12).map(|x| x as f32).collect(), 3, 4);
        let c = a.slice_cols(1, 3);
        assert_eq!(c.shape, vec![3, 2]);
        assert_eq!(c.data, vec![2.0, 3.0, 6.0, 7.0, 10.0, 11.0]);
        let r = a.slice_rows(1, 2);
        assert_eq!(r.data, vec![5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn concat_inverse_of_slice() {
        let a = t2((1..=12).map(|x| x as f32).collect(), 3, 4);
        let left = a.slice_cols(0, 2);
        let right = a.slice_cols(2, 4);
        assert_eq!(Tensor::concat_cols(&[&left, &right]), a);
        let top = a.slice_rows(0, 1);
        let bot = a.slice_rows(1, 3);
        assert_eq!(Tensor::concat_rows(&[&top, &bot]), a);
    }

    #[test]
    fn repeat_cols_matches_paper_notation() {
        let x = t2(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        let r = x.repeat_cols(3);
        assert_eq!(r.shape, vec![2, 6]);
        assert_eq!(r.row(0), &[1.0, 2.0, 1.0, 2.0, 1.0, 2.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let a = t2(vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0], 2, 3);
        let s = a.softmax_rows();
        for i in 0..2 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
        // Monotone: larger logit -> larger prob
        assert!(s.at(0, 2) > s.at(0, 1));
    }

    #[test]
    fn softmax_stable_large_values() {
        let a = t2(vec![1000.0, 1001.0], 1, 2);
        let s = a.softmax_rows();
        assert!(s.data.iter().all(|v| v.is_finite()));
        assert!((s.data.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn causal_softmax_masks_future() {
        let a = t2(vec![1.0; 9], 3, 3);
        let s = a.softmax_rows_causal(0);
        assert_eq!(s.at(0, 1), 0.0);
        assert_eq!(s.at(0, 2), 0.0);
        assert_eq!(s.at(1, 2), 0.0);
        assert!((s.at(0, 0) - 1.0).abs() < 1e-6);
        assert!((s.at(1, 0) - 0.5).abs() < 1e-6);
        // offset shifts visibility (decode position)
        let s2 = a.softmax_rows_causal(2);
        assert!(s2.at(0, 2) > 0.0);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let a = t2(vec![3.0, 4.0], 1, 2);
        let n = a.rmsnorm(&[1.0, 1.0], 0.0);
        let ms: f32 = n.data.iter().map(|x| x * x).sum::<f32>() / 2.0;
        assert!((ms - 1.0).abs() < 1e-5);
    }

    #[test]
    fn nmse_zero_for_identical() {
        let a = Tensor::randn(&[4, 4], 1.0, 3);
        assert_eq!(nmse(&a, &a), 0.0);
    }

    #[test]
    fn silu_values() {
        let a = t2(vec![0.0, 10.0], 1, 2);
        let s = a.silu();
        assert!((s.data[0] - 0.0).abs() < 1e-6);
        assert!((s.data[1] - 10.0).abs() < 1e-3);
    }

    #[test]
    fn fro_norm_matches_manual() {
        let a = t2(vec![3.0, 4.0], 1, 2);
        assert!((a.fro_norm() - 5.0).abs() < 1e-12);
    }
}
