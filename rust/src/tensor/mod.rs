//! Dense row-major tensors with f32 carrier storage and logical dtypes.
//!
//! This is the L3 compute substrate: the pure-Rust reference path for
//! MHA/BDA/PIFA operators, the model forward used for PPL evaluation, and
//! the bench targets of Tables 6–7 / Fig. 2b all run on these tensors.

pub mod dtype;
pub mod matmul;
pub mod ops;

pub use dtype::DType;

/// A dense row-major tensor of up to 4 dims. Values are carried in `f32`;
/// `dtype` records the logical precision (see [`dtype`]).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl Tensor {
    // ---- constructors ------------------------------------------------------

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec(), dtype: DType::F32 }
    }

    pub fn filled(shape: &[usize], v: f32) -> Tensor {
        Tensor { data: vec![v; shape.iter().product()], shape: shape.to_vec(), dtype: DType::F32 }
    }

    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor { data, shape: shape.to_vec(), dtype: DType::F32 }
    }

    /// Gaussian init N(0, std^2), deterministic for a given seed.
    pub fn randn(shape: &[usize], std: f32, seed: u64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        let mut rng = crate::util::rng::Rng::new(seed);
        rng.fill_gaussian(&mut t.data, std);
        t
    }

    /// Identity matrix (2-D).
    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    // ---- shape bookkeeping --------------------------------------------------

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rows(&self) -> usize {
        assert_eq!(self.ndim(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.ndim(), 2);
        self.shape[1]
    }

    /// Logical memory footprint in bytes at the stated dtype
    /// (what the paper's Table 3 "Memory (GB)" counts).
    pub fn logical_bytes(&self) -> usize {
        self.numel() * self.dtype.size_bytes()
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(self.numel(), shape.iter().product::<usize>(), "reshape numel mismatch");
        self.shape = shape.to_vec();
        self
    }

    /// Set logical dtype and quantize the carrier values through it.
    pub fn cast(mut self, dtype: DType) -> Tensor {
        dtype.quantize_slice(&mut self.data);
        self.dtype = dtype;
        self
    }

    /// Re-quantize in place through the current logical dtype (models a
    /// 16-bit store after a higher-precision accumulate).
    pub fn requantize(&mut self) {
        self.dtype.quantize_slice(&mut self.data);
    }

    // ---- element access (2-D convenience) ------------------------------------

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        &mut self.data[i * c + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let z = Tensor::zeros(&[2, 3]);
        assert_eq!(z.numel(), 6);
        assert_eq!(z.shape, vec![2, 3]);
        let f = Tensor::filled(&[4], 2.5);
        assert!(f.data.iter().all(|&x| x == 2.5));
        let e = Tensor::eye(3);
        assert_eq!(e.at(0, 0), 1.0);
        assert_eq!(e.at(0, 1), 0.0);
        assert_eq!(e.at(2, 2), 1.0);
    }

    #[test]
    fn randn_deterministic() {
        let a = Tensor::randn(&[8, 8], 0.02, 7);
        let b = Tensor::randn(&[8, 8], 0.02, 7);
        assert_eq!(a, b);
        let c = Tensor::randn(&[8, 8], 0.02, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]).reshape(&[4]);
        assert_eq!(t.shape, vec![4]);
        assert_eq!(t.data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic]
    fn reshape_bad_numel_panics() {
        let _ = Tensor::zeros(&[2, 2]).reshape(&[5]);
    }

    #[test]
    fn cast_quantizes() {
        let t = Tensor::from_vec(vec![1.0 + 2f32.powi(-12)], &[1]).cast(DType::F16);
        assert_eq!(t.data[0], 1.0); // rounded through binary16
        assert_eq!(t.dtype, DType::F16);
        assert_eq!(t.logical_bytes(), 2);
    }

    #[test]
    fn row_access() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(t.at(1, 2), 6.0);
    }
}
