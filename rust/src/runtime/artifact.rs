//! Artifact manifest (`artifacts/manifest.json`) parsing.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Metadata of one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub path: String,
    pub bytes: usize,
    pub batch: Option<usize>,
    pub seq_len: Option<usize>,
    pub attention: Option<String>,
    pub n_state: Option<usize>,
    pub state_shapes: Vec<Vec<usize>>,
}

/// Test vector embedded at artifact-build time (cross-layer numeric check).
#[derive(Clone, Debug)]
pub struct TestVector {
    pub tokens: Vec<Vec<i32>>,
    pub logits_head: Vec<f32>,
    pub batch: usize,
    pub seq_len: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactInfo>,
    pub test_vector: Option<TestVector>,
    pub lm_config: Option<LmConfig>,
    pub train_config: Option<TrainConfig>,
    pub selfcheck_rel_err: f64,
}

#[derive(Clone, Debug)]
pub struct LmConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_h: usize,
    pub max_seq_len: usize,
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub vocab_size: usize,
    pub max_seq_len: usize,
    pub batch: usize,
}

fn parse_artifact(name: &str, j: &Json) -> ArtifactInfo {
    ArtifactInfo {
        name: name.to_string(),
        path: j.get("path").as_str().unwrap_or_default().to_string(),
        bytes: j.get("bytes").as_usize().unwrap_or(0),
        batch: j.get("batch").as_usize(),
        seq_len: j.get("seq_len").as_usize(),
        attention: j.get("attention").as_str().map(|s| s.to_string()),
        n_state: j.get("n_state").as_usize(),
        state_shapes: j
            .get("state_shapes")
            .as_arr()
            .map(|a| {
                a.iter()
                    .map(|s| {
                        s.as_arr()
                            .map(|d| d.iter().filter_map(|x| x.as_usize()).collect())
                            .unwrap_or_default()
                    })
                    .collect()
            })
            .unwrap_or_default(),
    }
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let mut artifacts = Vec::new();
        for section in ["lm", "kproj", "train"] {
            if let Some(obj) = j.get(section).as_obj() {
                for (name, info) in obj {
                    artifacts.push(parse_artifact(name, info));
                }
            }
        }
        let test_vector = j.get("lm_test_vector").as_obj().map(|_| {
            let tv = j.get("lm_test_vector");
            TestVector {
                tokens: tv
                    .get("tokens")
                    .as_arr()
                    .map(|rows| {
                        rows.iter()
                            .map(|r| {
                                r.as_arr()
                                    .map(|xs| {
                                        xs.iter()
                                            .filter_map(|x| x.as_f64())
                                            .map(|x| x as i32)
                                            .collect()
                                    })
                                    .unwrap_or_default()
                            })
                            .collect()
                    })
                    .unwrap_or_default(),
                logits_head: tv
                    .get("logits_b0_t0_head")
                    .as_arr()
                    .map(|xs| xs.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect())
                    .unwrap_or_default(),
                batch: tv.get("batch").as_usize().unwrap_or(0),
                seq_len: tv.get("seq_len").as_usize().unwrap_or(0),
            }
        });
        let lm_config = j.get("lm_config").as_obj().map(|_| {
            let c = j.get("lm_config");
            LmConfig {
                vocab_size: c.get("vocab_size").as_usize().unwrap_or(0),
                d_model: c.get("d_model").as_usize().unwrap_or(0),
                n_layers: c.get("n_layers").as_usize().unwrap_or(0),
                n_heads: c.get("n_heads").as_usize().unwrap_or(0),
                d_h: c.get("d_h").as_usize().unwrap_or(0),
                max_seq_len: c.get("max_seq_len").as_usize().unwrap_or(0),
            }
        });
        let train_config = j.get("train_config").as_obj().map(|_| {
            let c = j.get("train_config");
            TrainConfig {
                vocab_size: c.get("vocab_size").as_usize().unwrap_or(0),
                max_seq_len: c.get("max_seq_len").as_usize().unwrap_or(0),
                batch: c.get("batch").as_usize().unwrap_or(0),
            }
        });
        Ok(Manifest {
            artifacts,
            test_vector,
            lm_config,
            train_config,
            selfcheck_rel_err: j.get("lm_selfcheck_rel_err").as_f64().unwrap_or(f64::NAN),
        })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn path_of(&self, name: &str) -> Option<&str> {
        self.get(name).map(|a| a.path.as_str())
    }

    /// Names of lm forward artifacts for an attention variant, sorted by
    /// batch size (the batcher picks the smallest fitting one).
    pub fn lm_variants(&self, attention: &str) -> Vec<&ArtifactInfo> {
        let mut v: Vec<&ArtifactInfo> = self
            .artifacts
            .iter()
            .filter(|a| {
                a.name.starts_with("lm_")
                    && a.name.contains("_fwd_b")
                    && a.attention.as_deref() == Some(attention)
            })
            .collect();
        v.sort_by_key(|a| a.batch.unwrap_or(0));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "lm_selfcheck_rel_err": 1e-5,
      "lm": {
        "lm_mha_fwd_b1": {"path": "lm_mha_fwd_b1.hlo.txt", "bytes": 10,
                          "batch": 1, "seq_len": 64, "attention": "mha"},
        "lm_mha_fwd_b8": {"path": "lm_mha_fwd_b8.hlo.txt", "bytes": 10,
                          "batch": 8, "seq_len": 64, "attention": "mha"}
      },
      "kproj": {
        "kproj_mha_l64": {"path": "kproj_mha_l64.hlo.txt", "bytes": 5,
                          "seq_len": 64}
      },
      "train": {
        "train_step_mha": {"path": "t.hlo.txt", "bytes": 2, "n_state": 2,
                           "state_shapes": [[4, 4], [4]]}
      },
      "lm_test_vector": {"tokens": [[1, 2]], "logits_b0_t0_head": [0.5, -1.0],
                         "batch": 1, "seq_len": 2},
      "lm_config": {"vocab_size": 512, "d_model": 256, "n_layers": 2,
                    "n_heads": 4, "d_h": 64, "max_seq_len": 64}
    }"#;

    #[test]
    fn parses_sections() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 4);
        assert_eq!(m.path_of("kproj_mha_l64"), Some("kproj_mha_l64.hlo.txt"));
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn lm_variants_sorted() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let v = m.lm_variants("mha");
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].batch, Some(1));
        assert_eq!(v[1].batch, Some(8));
        assert!(m.lm_variants("bda").is_empty());
    }

    #[test]
    fn test_vector_parsed() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let tv = m.test_vector.unwrap();
        assert_eq!(tv.tokens, vec![vec![1, 2]]);
        assert_eq!(tv.logits_head, vec![0.5, -1.0]);
    }

    #[test]
    fn train_state_shapes() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let t = m.get("train_step_mha").unwrap();
        assert_eq!(t.n_state, Some(2));
        assert_eq!(t.state_shapes, vec![vec![4, 4], vec![4]]);
    }

    #[test]
    fn config_parsed() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let c = m.lm_config.unwrap();
        assert_eq!(c.vocab_size, 512);
        assert_eq!(c.d_h, 64);
        assert!((m.selfcheck_rel_err - 1e-5).abs() < 1e-12);
    }
}
