//! PJRT runtime — loads and executes the AOT artifacts built by
//! `python/compile/aot.py` (HLO text; Python is never on this path).
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Executables are compiled once and cached;
//! the coordinator owns a `Runtime` on a dedicated executor thread.

pub mod artifact;

pub use artifact::Manifest;

use crate::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact ready to execute.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; flattens the tuple output into a
    /// vector of literals (artifacts are lowered with return_tuple=True).
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {}: {e:?}", self.name))?;
        Ok(lit.to_tuple()?)
    }
}

/// The PJRT runtime: client + artifact registry + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<String, std::sync::Arc<Executable>>,
}

impl Runtime {
    /// Open the artifacts directory (expects `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn load(&mut self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let rel = self
            .manifest
            .path_of(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?
            .to_string();
        let path = self.dir.join(rel);
        let exe = self.compile_file(&path).with_context(|| format!("loading {name}"))?;
        let handle = std::sync::Arc::new(Executable { name: name.to_string(), exe });
        self.cache.insert(name.to_string(), handle.clone());
        Ok(handle)
    }

    /// Compile an HLO text file directly (tests / ad-hoc tools).
    pub fn load_path(&self, path: &Path) -> Result<Executable> {
        let exe = self.compile_file(path)?;
        Ok(Executable { name: path.display().to_string(), exe })
    }

    fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
    }
}

// ---- literal <-> tensor conversions ----------------------------------------

/// i32 literal of the given dims.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// f32 literal of the given dims.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// f32 scalar literal (rank 0).
pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Tensor -> f32 literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    lit_f32(&t.data, &dims)
}

/// f32 literal -> Tensor.
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data: Vec<f32> = lit.to_vec()?;
    Ok(Tensor::from_vec(data, &dims))
}

/// Scalar f32 from a literal.
pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    // Runtime tests that need artifacts live in rust/tests/
    // integration_runtime.rs; conversion helpers are testable standalone.
    use super::*;

    #[test]
    fn literal_tensor_roundtrip() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn i32_literal_shape() {
        let lit = lit_i32(&[1, 2, 3, 4], &[2, 2]).unwrap();
        assert_eq!(lit.element_count(), 4);
    }

    #[test]
    fn scalar_literal() {
        let lit = lit_scalar_f32(2.5);
        assert_eq!(literal_scalar_f32(&lit).unwrap(), 2.5);
    }
}
