//! Evaluation substrates: synthetic corpora, tokenization, perplexity,
//! BLEU + beam search, and serving workload traces — everything the
//! paper's evaluation section needs that we cannot download (WikiText2,
//! IWSLT'14) is replaced by deterministic synthetic equivalents
//! (substitution table in DESIGN.md §2).

pub mod beam;
pub mod bleu;
pub mod corpus;
pub mod ppl;
pub mod tokenizer;
pub mod trace;

pub use bleu::bleu;
pub use corpus::{Corpus, TranslationPair};
pub use ppl::perplexity;
