//! Deterministic synthetic corpora.
//!
//! * `Corpus::tiny_wiki` — a Markov-chain word stream with Zipfian
//!   unigrams and topic locality: enough statistical structure for PPL to
//!   be meaningful (a trained model beats a uniform baseline) while being
//!   fully reproducible. Stands in for WikiText2.
//! * `translation_pairs` — a synthetic "language pair": the target is the
//!   source under a fixed vocabulary permutation with deterministic local
//!   reordering and an inserted article token — structure a seq2seq LM can
//!   learn. Stands in for IWSLT'14 En→De (Table 2).

use crate::util::rng::Rng;

/// A tokenized corpus with a vocabulary.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub tokens: Vec<u32>,
    pub vocab_size: usize,
}

impl Corpus {
    /// Zipf-ish Markov corpus. `vocab_size` ≥ 16.
    pub fn tiny_wiki(vocab_size: usize, len: usize, seed: u64) -> Corpus {
        assert!(vocab_size >= 16);
        let mut rng = Rng::new(seed);
        // Topic centers give local structure; transitions prefer tokens
        // near the current topic with Zipf-weighted ranks.
        let n_topics = 8;
        let topic_span = vocab_size / n_topics;
        let mut tokens = Vec::with_capacity(len);
        let mut topic = 0usize;
        let mut prev = 0u32;
        for i in 0..len {
            if i % 64 == 0 {
                topic = rng.below(n_topics as u64) as usize;
            }
            // Zipf rank within the topic, occasionally global.
            let r = rng.next_f64();
            let tok = if r < 0.15 {
                // Function-word band: the most common global tokens.
                zipf(&mut rng, 16.min(vocab_size)) as u32
            } else if r < 0.9 {
                let base = topic * topic_span;
                (base + zipf(&mut rng, topic_span.max(2))) as u32 % vocab_size as u32
            } else {
                // Bigram echo: repeat-after pattern gives learnable 2-grams.
                prev
            };
            tokens.push(tok);
            prev = tok;
        }
        Corpus { tokens, vocab_size }
    }

    /// Split into (train, eval) at a fraction.
    pub fn split(&self, train_frac: f64) -> (Corpus, Corpus) {
        let n = (self.tokens.len() as f64 * train_frac) as usize;
        (
            Corpus { tokens: self.tokens[..n].to_vec(), vocab_size: self.vocab_size },
            Corpus { tokens: self.tokens[n..].to_vec(), vocab_size: self.vocab_size },
        )
    }

    /// Sequential (input, target-shifted) batches of the given seq length:
    /// each item is seq_len+1 tokens.
    pub fn batches(&self, seq_len: usize, batch: usize) -> Vec<Vec<Vec<u32>>> {
        let item = seq_len + 1;
        let n_items = self.tokens.len() / item;
        let mut items: Vec<Vec<u32>> = (0..n_items)
            .map(|i| self.tokens[i * item..(i + 1) * item].to_vec())
            .collect();
        let mut out = Vec::new();
        while items.len() >= batch {
            out.push(items.drain(..batch).collect());
        }
        out
    }
}

fn zipf(rng: &mut Rng, n: usize) -> usize {
    // Inverse-CDF Zipf(s=1.1) over [0, n).
    let s = 1.1;
    let u = rng.next_f64();
    let mut acc = 0.0;
    let norm: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
    for k in 1..=n {
        acc += 1.0 / (k as f64).powf(s) / norm;
        if u <= acc {
            return k - 1;
        }
    }
    n - 1
}

/// A source/target pair of the synthetic translation task.
#[derive(Clone, Debug, PartialEq)]
pub struct TranslationPair {
    pub src: Vec<u32>,
    pub tgt: Vec<u32>,
}

/// Deterministic synthetic translation data. Vocabulary is split:
/// [2, vocab/2) source words, [vocab/2, vocab) target words; 0 = BOS,
/// 1 = EOS. Target = permuted source tokens with adjacent-swap reordering
/// keyed on token parity (a fixed, learnable "grammar").
pub fn translation_pairs(
    n_pairs: usize,
    vocab_size: usize,
    min_len: usize,
    max_len: usize,
    seed: u64,
) -> Vec<TranslationPair> {
    assert!(vocab_size >= 16 && vocab_size % 2 == 0);
    let half = vocab_size / 2;
    let src_words = half - 2;
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n_pairs);
    for _ in 0..n_pairs {
        let len = rng.range(min_len, max_len);
        let src: Vec<u32> = (0..len)
            .map(|_| 2 + zipf(&mut rng, src_words) as u32)
            .collect();
        // Deterministic "translation": map word w -> half + (w - 2),
        // then swap adjacent pairs when the first is even (fixed grammar).
        let mut tgt: Vec<u32> = src.iter().map(|&w| half as u32 + (w - 2)).collect();
        let mut i = 0;
        while i + 1 < tgt.len() {
            if tgt[i] % 2 == 0 {
                tgt.swap(i, i + 1);
                i += 2;
            } else {
                i += 1;
            }
        }
        out.push(TranslationPair { src, tgt });
    }
    out
}

impl TranslationPair {
    /// Pack as a single LM sequence: BOS src EOS tgt EOS, padded/truncated
    /// to `total_len` (teacher-forced seq2seq as decoder-only LM).
    pub fn pack(&self, total_len: usize) -> Vec<u32> {
        let mut seq = Vec::with_capacity(total_len);
        seq.push(0);
        seq.extend_from_slice(&self.src);
        seq.push(1);
        seq.extend_from_slice(&self.tgt);
        seq.push(1);
        seq.truncate(total_len);
        while seq.len() < total_len {
            seq.push(1); // EOS pad
        }
        seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_deterministic() {
        let a = Corpus::tiny_wiki(256, 1000, 5);
        let b = Corpus::tiny_wiki(256, 1000, 5);
        assert_eq!(a.tokens, b.tokens);
        assert!(a.tokens.iter().all(|&t| t < 256));
    }

    #[test]
    fn corpus_has_structure() {
        // Bigram entropy must be meaningfully below unigram log(V):
        // the corpus is learnable, not uniform noise.
        let c = Corpus::tiny_wiki(256, 50_000, 7);
        let mut unigram = vec![0f64; 256];
        for &t in &c.tokens {
            unigram[t as usize] += 1.0;
        }
        let n = c.tokens.len() as f64;
        let h_uni: f64 = unigram
            .iter()
            .filter(|&&c| c > 0.0)
            .map(|&c| {
                let p = c / n;
                -p * p.ln()
            })
            .sum();
        assert!(h_uni < (256f64).ln() * 0.95, "unigram entropy {h_uni}");
    }

    #[test]
    fn split_preserves_tokens() {
        let c = Corpus::tiny_wiki(64, 1000, 1);
        let (tr, ev) = c.split(0.8);
        assert_eq!(tr.tokens.len() + ev.tokens.len(), 1000);
        assert_eq!(tr.tokens.len(), 800);
    }

    #[test]
    fn batches_shape() {
        let c = Corpus::tiny_wiki(64, 10_000, 2);
        let bs = c.batches(16, 4);
        assert!(!bs.is_empty());
        for b in &bs {
            assert_eq!(b.len(), 4);
            for item in b {
                assert_eq!(item.len(), 17);
            }
        }
    }

    #[test]
    fn translation_deterministic_mapping() {
        let pairs = translation_pairs(50, 64, 4, 10, 3);
        assert_eq!(pairs, translation_pairs(50, 64, 4, 10, 3));
        for p in &pairs {
            assert_eq!(p.src.len(), p.tgt.len());
            assert!(p.src.iter().all(|&w| (2..32).contains(&w)));
            assert!(p.tgt.iter().all(|&w| (32..64).contains(&w)));
            // Same multiset after unmapping.
            let mut src_sorted = p.src.clone();
            src_sorted.sort();
            let mut unmapped: Vec<u32> = p.tgt.iter().map(|&w| w - 32 + 2).collect();
            unmapped.sort();
            assert_eq!(src_sorted, unmapped);
        }
    }

    #[test]
    fn pack_layout() {
        let p = TranslationPair { src: vec![5, 6], tgt: vec![37, 36] };
        let seq = p.pack(10);
        assert_eq!(seq[0], 0);
        assert_eq!(&seq[1..3], &[5, 6]);
        assert_eq!(seq[3], 1);
        assert_eq!(&seq[4..6], &[37, 36]);
        assert_eq!(seq.len(), 10);
        assert!(seq[6..].iter().all(|&t| t == 1));
    }
}
