//! Corpus BLEU (up to 4-grams, with brevity penalty) — Table 2's metric.

use std::collections::HashMap;

/// Corpus-level BLEU-4 with brevity penalty, on token id sequences.
/// Uses standard "add-epsilon-free" corpus counting (sums of clipped
/// matches over sums of candidate n-grams), with smoothing +1 on orders
/// with zero matches (NIST-style floor for short corpora).
pub fn bleu(candidates: &[Vec<u32>], references: &[Vec<u32>]) -> f64 {
    assert_eq!(candidates.len(), references.len());
    if candidates.is_empty() {
        return 0.0;
    }
    let max_n = 4;
    let mut match_counts = vec![0u64; max_n];
    let mut total_counts = vec![0u64; max_n];
    let mut cand_len = 0u64;
    let mut ref_len = 0u64;

    for (c, r) in candidates.iter().zip(references.iter()) {
        cand_len += c.len() as u64;
        ref_len += r.len() as u64;
        for n in 1..=max_n {
            if c.len() < n {
                continue;
            }
            let mut ref_ngrams: HashMap<&[u32], u64> = HashMap::new();
            if r.len() >= n {
                for w in r.windows(n) {
                    *ref_ngrams.entry(w).or_insert(0) += 1;
                }
            }
            let mut cand_ngrams: HashMap<&[u32], u64> = HashMap::new();
            for w in c.windows(n) {
                *cand_ngrams.entry(w).or_insert(0) += 1;
            }
            for (gram, &count) in &cand_ngrams {
                total_counts[n - 1] += count;
                let clip = ref_ngrams.get(gram).copied().unwrap_or(0);
                match_counts[n - 1] += count.min(clip);
            }
        }
    }

    // No unigram overlap at all: BLEU is 0 (smoothing only applies to
    // higher orders of otherwise-overlapping corpora).
    if match_counts[0] == 0 {
        return 0.0;
    }

    // Geometric mean of modified precisions (smoothed).
    let mut log_p_sum = 0.0f64;
    for n in 0..max_n {
        let (m, t) = (match_counts[n], total_counts[n]);
        let p = if t == 0 {
            return 0.0; // candidate too short for n-grams at all
        } else if m == 0 {
            1.0 / (2.0 * t as f64) // smoothing for zero matches
        } else {
            m as f64 / t as f64
        };
        log_p_sum += p.ln();
    }
    let geo = (log_p_sum / max_n as f64).exp();

    // Brevity penalty.
    let bp = if cand_len >= ref_len {
        1.0
    } else if cand_len == 0 {
        0.0
    } else {
        (1.0 - ref_len as f64 / cand_len as f64).exp()
    };
    100.0 * bp * geo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_match_is_100() {
        let refs = vec![vec![1, 2, 3, 4, 5], vec![9, 8, 7, 6, 5]];
        let b = bleu(&refs, &refs);
        assert!((b - 100.0).abs() < 1e-9, "{b}");
    }

    #[test]
    fn disjoint_is_near_zero() {
        let cand = vec![vec![1, 2, 3, 4, 5]];
        let refs = vec![vec![10, 11, 12, 13, 14]];
        assert!(bleu(&cand, &refs) < 1.0);
    }

    #[test]
    fn partial_overlap_between() {
        let cand = vec![vec![1, 2, 3, 99, 98]];
        let refs = vec![vec![1, 2, 3, 4, 5]];
        let b = bleu(&cand, &refs);
        assert!(b > 1.0 && b < 90.0, "{b}");
    }

    #[test]
    fn brevity_penalty_applies() {
        // Identical prefix but shorter candidate must score lower than a
        // full-length identical candidate.
        let refs = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let full = bleu(&refs, &refs);
        let short = bleu(&[vec![1, 2, 3, 4, 5]], &refs);
        assert!(short < full);
    }

    #[test]
    fn order_sensitivity() {
        // Same unigrams, scrambled order -> lower BLEU (n>1 precisions drop).
        let refs = vec![vec![1, 2, 3, 4, 5, 6]];
        let scrambled = bleu(&[vec![6, 4, 2, 5, 3, 1]], &refs);
        let correct = bleu(&refs, &refs);
        assert!(scrambled < correct * 0.7);
    }

    #[test]
    fn empty_corpus() {
        assert_eq!(bleu(&[], &[]), 0.0);
    }
}
