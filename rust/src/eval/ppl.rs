//! Perplexity evaluation (Fig. 2a / Tables 3 & 5).

use crate::model::Transformer;

/// Token-level perplexity of a model over a token stream, evaluated in
/// non-overlapping windows of `seq_len`. Returns exp(mean NLL).
pub fn perplexity(model: &Transformer, tokens: &[u32], seq_len: usize) -> f64 {
    let mut total_nll = 0.0f64;
    let mut count = 0usize;
    let window = seq_len.min(model.config.max_seq_len);
    for chunk in tokens.chunks(window) {
        if chunk.len() < 2 {
            continue;
        }
        let logits = model.forward_full(chunk);
        let v = model.config.vocab_size;
        // NLL of token[t+1] under logits at position t.
        for t in 0..chunk.len() - 1 {
            let row = &logits.data[t * v..(t + 1) * v];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let logsum: f64 =
                row.iter().map(|&x| ((x - max) as f64).exp()).sum::<f64>().ln() + max as f64;
            let target = chunk[t + 1] as usize % v;
            total_nll += logsum - row[target] as f64;
            count += 1;
        }
    }
    if count == 0 {
        return f64::NAN;
    }
    (total_nll / count as f64).exp()
}

/// Relative PPL increase in percent: 100·(ppl_new − ppl_base)/ppl_base
/// (the quantity Fig. 2a / Table 5 report).
pub fn ppl_increase_percent(base: f64, new: f64) -> f64 {
    100.0 * (new - base) / base
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::corpus::Corpus;
    use crate::model::{ModelConfig, Transformer};

    #[test]
    fn random_model_ppl_near_vocab() {
        // An untrained model on random-ish text has PPL near vocab size
        // (uniform predictions).
        let m = Transformer::new_mha(ModelConfig::tiny(), 3);
        let c = Corpus::tiny_wiki(256, 600, 4);
        let ppl = perplexity(&m, &c.tokens, 32);
        assert!(ppl.is_finite());
        assert!(ppl > 64.0 && ppl < 1024.0, "ppl {ppl}");
    }

    #[test]
    fn bda_ppl_matches_mha_exactly_fp32() {
        // The Fig. 2a headline at tiny scale: FP32 BDA PPL ≈ MHA PPL.
        use crate::bd::Strategy;
        use crate::tensor::DType;
        let m = Transformer::new_mha(ModelConfig::tiny(), 5);
        let bda = m.to_bda(Strategy::ResidualMin, DType::F32).unwrap();
        let c = Corpus::tiny_wiki(256, 400, 6);
        let p0 = perplexity(&m, &c.tokens, 32);
        let p1 = perplexity(&bda, &c.tokens, 32);
        let inc = ppl_increase_percent(p0, p1).abs();
        assert!(inc < 0.1, "ppl increase {inc}%");
    }

    #[test]
    fn increase_percent_formula() {
        assert!((ppl_increase_percent(10.0, 10.1) - 1.0).abs() < 1e-9);
        assert!(ppl_increase_percent(10.0, 10.0) == 0.0);
    }

    #[test]
    fn short_stream_nan() {
        let m = Transformer::new_mha(ModelConfig::tiny(), 7);
        assert!(perplexity(&m, &[1], 32).is_nan());
    }
}
