//! Beam-search decoding (the paper evaluates IWSLT BLEU with beam size 2,
//! Appendix C).

use crate::model::transformer::KvCache;
use crate::model::Transformer;

/// One beam hypothesis.
#[derive(Clone, Debug)]
struct Hyp {
    tokens: Vec<u32>,
    logp: f64,
    done: bool,
}

/// Beam-search decode from a prompt. Returns the best completion
/// (generated tokens only, EOS excluded). `eos` terminates a hypothesis.
///
/// Uses full-sequence re-scoring per step (clarity over speed: the serving
/// path uses KV caches; evaluation decodes are offline).
pub fn beam_search(
    model: &Transformer,
    prompt: &[u32],
    beam_size: usize,
    max_new: usize,
    eos: u32,
) -> Vec<u32> {
    assert!(beam_size >= 1);
    let vocab = model.config.vocab_size;
    let mut beams = vec![Hyp { tokens: Vec::new(), logp: 0.0, done: false }];

    for _ in 0..max_new {
        if beams.iter().all(|b| b.done) {
            break;
        }
        let mut candidates: Vec<Hyp> = Vec::new();
        for hyp in &beams {
            if hyp.done {
                candidates.push(hyp.clone());
                continue;
            }
            // Score the next-token distribution.
            let mut seq: Vec<u32> = prompt.to_vec();
            seq.extend_from_slice(&hyp.tokens);
            if seq.len() >= model.config.max_seq_len {
                let mut done_hyp = hyp.clone();
                done_hyp.done = true;
                candidates.push(done_hyp);
                continue;
            }
            let mut cache = KvCache::new(model.config.n_layers);
            let logits = model.prefill(&mut cache, &seq);
            let row = &logits.data[..vocab];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let logsum: f64 =
                row.iter().map(|&x| ((x - max) as f64).exp()).sum::<f64>().ln() + max as f64;
            // Top beam_size next tokens.
            let mut idx: Vec<usize> = (0..vocab).collect();
            idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
            for &t in idx.iter().take(beam_size) {
                let lp = row[t] as f64 - logsum;
                let mut tokens = hyp.tokens.clone();
                tokens.push(t as u32);
                candidates.push(Hyp {
                    done: t as u32 == eos,
                    logp: hyp.logp + lp,
                    tokens,
                });
            }
        }
        // Keep the best `beam_size` by length-normalized logp.
        candidates.sort_by(|a, b| {
            let na = a.logp / a.tokens.len().max(1) as f64;
            let nb = b.logp / b.tokens.len().max(1) as f64;
            nb.partial_cmp(&na).unwrap()
        });
        candidates.truncate(beam_size);
        beams = candidates;
    }

    let best = beams
        .into_iter()
        .max_by(|a, b| {
            let na = a.logp / a.tokens.len().max(1) as f64;
            let nb = b.logp / b.tokens.len().max(1) as f64;
            na.partial_cmp(&nb).unwrap()
        })
        .unwrap();
    let mut out = best.tokens;
    if out.last() == Some(&eos) {
        out.pop();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Transformer};

    #[test]
    fn beam1_equals_greedy() {
        let m = Transformer::new_mha(ModelConfig::tiny(), 21);
        let prompt = [3u32, 7, 11];
        let beam = beam_search(&m, &prompt, 1, 5, u32::MAX);
        // Greedy reference.
        let mut greedy = Vec::new();
        let mut seq = prompt.to_vec();
        for _ in 0..5 {
            let logits = m.forward_full(&seq);
            let v = m.config.vocab_size;
            let last = &logits.data[(seq.len() - 1) * v..seq.len() * v];
            let tok = last
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as u32;
            greedy.push(tok);
            seq.push(tok);
        }
        assert_eq!(beam, greedy);
    }

    #[test]
    fn wider_beam_no_worse_logp() {
        let m = Transformer::new_mha(ModelConfig::tiny(), 22);
        let prompt = [5u32, 9];
        let b1 = beam_search(&m, &prompt, 1, 4, u32::MAX);
        let b3 = beam_search(&m, &prompt, 3, 4, u32::MAX);
        // Score both under the model; beam-3 must not be worse.
        let score = |tokens: &[u32]| -> f64 {
            let mut seq = prompt.to_vec();
            let mut lp = 0.0f64;
            for &t in tokens {
                let logits = m.forward_full(&seq);
                let v = m.config.vocab_size;
                let row = &logits.data[(seq.len() - 1) * v..seq.len() * v];
                let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let logsum: f64 = row.iter().map(|&x| ((x - max) as f64).exp()).sum::<f64>().ln()
                    + max as f64;
                lp += row[t as usize] as f64 - logsum;
                seq.push(t);
            }
            lp / tokens.len().max(1) as f64
        };
        assert!(score(&b3) >= score(&b1) - 1e-6);
    }

    #[test]
    fn stops_at_eos() {
        let m = Transformer::new_mha(ModelConfig::tiny(), 23);
        // Use the greedy first token as "eos": generation should stop
        // immediately and return an empty completion.
        let prompt = [2u32, 4];
        let first = beam_search(&m, &prompt, 1, 1, u32::MAX);
        let out = beam_search(&m, &prompt, 1, 8, first[0]);
        assert!(out.is_empty());
    }
}
