//! Serving workload traces: Poisson arrivals with Zipf-ish prompt lengths,
//! used by the serving example, ablation benches, and the open-loop SLO
//! load generator (`benches/slo_loadgen.rs`).

use crate::coordinator::request::{Request, RequestClass};
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::time::Instant;

/// Trace generation parameters.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    pub n_requests: usize,
    pub vocab_size: usize,
    pub min_prompt: usize,
    pub max_prompt: usize,
    pub min_new: usize,
    pub max_new: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_requests: 64,
            vocab_size: 512,
            min_prompt: 4,
            max_prompt: 24,
            min_new: 4,
            max_new: 16,
            seed: 1,
        }
    }
}

/// Generate a deterministic request trace (arrival = now; the replay
/// driver controls pacing).
pub fn generate(config: TraceConfig) -> Vec<Request> {
    let mut rng = Rng::new(config.seed);
    let now = Instant::now();
    (0..config.n_requests)
        .map(|i| {
            let plen = rng.range(config.min_prompt, config.max_prompt);
            let prompt: Vec<u32> =
                (0..plen).map(|_| rng.below(config.vocab_size as u64) as u32).collect();
            let new = rng.range(config.min_new, config.max_new);
            Request {
                id: i as u64,
                prompt,
                max_new_tokens: new,
                temperature: None,
                arrival: now,
                class: RequestClass::default(),
            }
        })
        .collect()
}

/// Exponential inter-arrival gaps for an open-loop replay at `rate` req/s.
pub fn poisson_gaps(n: usize, rate: f64, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| -(1.0 - rng.next_f64()).ln() / rate).collect()
}

/// One entry of a replayable open-loop trace: the Poisson gap since the
/// previous arrival plus everything needed to rebuild the request.
#[derive(Clone, Debug, PartialEq)]
pub struct OpenLoopEntry {
    /// Seconds to wait after the previous arrival before submitting.
    pub gap_s: f64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub class: RequestClass,
}

/// A replayable open-loop workload: seeded Poisson arrivals at a fixed
/// offered rate over mixed prompt/output-length distributions, assigned
/// round-robin over a set of deadline/priority classes. Serializes to
/// JSON (via `util::json`) so a swept load point can be saved and
/// replayed bit-for-bit by `benches/slo_loadgen.rs` or an external
/// driver.
#[derive(Clone, Debug, PartialEq)]
pub struct OpenLoopTrace {
    pub seed: u64,
    /// Offered load, requests per second (the Poisson rate).
    pub rate: f64,
    pub entries: Vec<OpenLoopEntry>,
}

impl OpenLoopTrace {
    /// Generate a trace: request shapes from `config` (same RNG stream as
    /// [`generate`]), arrival gaps from an independent Poisson stream at
    /// `rate` req/s (seeded `config.seed ^ 0x9e3779b9`), classes assigned
    /// round-robin from `classes` (empty = ambient default class).
    pub fn generate(config: TraceConfig, rate: f64, classes: &[RequestClass]) -> OpenLoopTrace {
        let gaps = poisson_gaps(config.n_requests, rate, config.seed ^ 0x9e37_79b9);
        let mut rng = Rng::new(config.seed);
        let entries = (0..config.n_requests)
            .map(|i| {
                let plen = rng.range(config.min_prompt, config.max_prompt);
                let prompt: Vec<u32> =
                    (0..plen).map(|_| rng.below(config.vocab_size as u64) as u32).collect();
                let max_new_tokens = rng.range(config.min_new, config.max_new);
                let class = if classes.is_empty() {
                    RequestClass::default()
                } else {
                    classes[i % classes.len()]
                };
                OpenLoopEntry { gap_s: gaps[i], prompt, max_new_tokens, class }
            })
            .collect();
        OpenLoopTrace { seed: config.seed, rate, entries }
    }

    /// Materialize entry `i` as a `Request` arriving now (the replay
    /// driver constructs each request at its submit instant so `arrival`
    /// reflects true open-loop arrival time).
    pub fn request(&self, i: usize) -> Request {
        let e = &self.entries[i];
        Request::new(i as u64, e.prompt.clone(), e.max_new_tokens).with_class(e.class)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("rate", Json::num(self.rate)),
            (
                "entries",
                Json::Arr(
                    self.entries
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("gap_s", Json::num(e.gap_s)),
                                (
                                    "prompt",
                                    Json::Arr(
                                        e.prompt.iter().map(|&t| Json::num(t as f64)).collect(),
                                    ),
                                ),
                                ("max_new_tokens", Json::num(e.max_new_tokens as f64)),
                                ("priority", Json::num(e.class.priority as f64)),
                                ("ttft_deadline", Json::num(e.class.ttft_deadline)),
                                ("tbt_budget", Json::num(e.class.tbt_budget)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(doc: &Json) -> Option<OpenLoopTrace> {
        let entries = doc
            .get("entries")
            .as_arr()?
            .iter()
            .map(|e| {
                Some(OpenLoopEntry {
                    gap_s: e.get("gap_s").as_f64()?,
                    prompt: e
                        .get("prompt")
                        .as_arr()?
                        .iter()
                        .map(|t| t.as_f64().map(|v| v as u32))
                        .collect::<Option<Vec<u32>>>()?,
                    max_new_tokens: e.get("max_new_tokens").as_usize()?,
                    class: RequestClass {
                        priority: e.get("priority").as_f64()? as u8,
                        ttft_deadline: e.get("ttft_deadline").as_f64()?,
                        tbt_budget: e.get("tbt_budget").as_f64()?,
                    },
                })
            })
            .collect::<Option<Vec<_>>>()?;
        Some(OpenLoopTrace {
            seed: doc.get("seed").as_f64()? as u64,
            rate: doc.get("rate").as_f64()?,
            entries,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(TraceConfig::default());
        let b = generate(TraceConfig::default());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
    }

    #[test]
    fn respects_bounds() {
        let c = TraceConfig { min_prompt: 3, max_prompt: 5, min_new: 2, max_new: 4, ..Default::default() };
        for r in generate(c) {
            assert!((3..=5).contains(&r.prompt.len()));
            assert!((2..=4).contains(&r.max_new_tokens));
            assert!(r.prompt.iter().all(|&t| t < c.vocab_size as u32));
        }
    }

    #[test]
    fn poisson_gaps_mean() {
        let gaps = poisson_gaps(10_000, 100.0, 3);
        let mean: f64 = gaps.iter().sum::<f64>() / gaps.len() as f64;
        assert!((mean - 0.01).abs() < 0.001, "mean {mean}");
        assert!(gaps.iter().all(|&g| g >= 0.0));
    }

    #[test]
    fn open_loop_trace_round_trips_through_json() {
        let classes = [
            RequestClass { priority: 2, ttft_deadline: 0.5, tbt_budget: 0.05 },
            RequestClass { priority: 0, ttft_deadline: 2.0, tbt_budget: 0.5 },
        ];
        let cfg = TraceConfig { n_requests: 9, seed: 11, ..Default::default() };
        let t = OpenLoopTrace::generate(cfg, 40.0, &classes);
        assert_eq!(t.entries.len(), 9);
        assert!(t.entries.iter().all(|e| e.gap_s >= 0.0));
        // Round-robin class assignment.
        assert_eq!(t.entries[0].class, classes[0]);
        assert_eq!(t.entries[1].class, classes[1]);
        assert_eq!(t.entries[2].class, classes[0]);
        let doc = Json::parse(&t.to_json().to_string()).expect("trace json parses");
        let back = OpenLoopTrace::from_json(&doc).expect("trace json round-trips");
        // f64 gaps survive the compact printer at full precision only
        // approximately; shapes and classes must be exact.
        assert_eq!(back.entries.len(), t.entries.len());
        for (a, b) in back.entries.iter().zip(&t.entries) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.class, b.class);
            assert!((a.gap_s - b.gap_s).abs() < 1e-9);
        }
        assert_eq!(back.seed, 11);
    }

    #[test]
    fn open_loop_trace_same_seed_same_trace() {
        let cfg = TraceConfig { n_requests: 6, seed: 5, ..Default::default() };
        let a = OpenLoopTrace::generate(cfg, 25.0, &[]);
        let b = OpenLoopTrace::generate(cfg, 25.0, &[]);
        assert_eq!(a, b);
        let r = a.request(3);
        assert_eq!(r.id, 3);
        assert_eq!(r.prompt, a.entries[3].prompt);
    }
}
