//! Serving workload traces: Poisson arrivals with Zipf-ish prompt lengths,
//! used by the serving example and ablation benches.

use crate::coordinator::request::Request;
use crate::util::rng::Rng;
use std::time::Instant;

/// Trace generation parameters.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    pub n_requests: usize,
    pub vocab_size: usize,
    pub min_prompt: usize,
    pub max_prompt: usize,
    pub min_new: usize,
    pub max_new: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_requests: 64,
            vocab_size: 512,
            min_prompt: 4,
            max_prompt: 24,
            min_new: 4,
            max_new: 16,
            seed: 1,
        }
    }
}

/// Generate a deterministic request trace (arrival = now; the replay
/// driver controls pacing).
pub fn generate(config: TraceConfig) -> Vec<Request> {
    let mut rng = Rng::new(config.seed);
    let now = Instant::now();
    (0..config.n_requests)
        .map(|i| {
            let plen = rng.range(config.min_prompt, config.max_prompt);
            let prompt: Vec<u32> =
                (0..plen).map(|_| rng.below(config.vocab_size as u64) as u32).collect();
            let new = rng.range(config.min_new, config.max_new);
            Request {
                id: i as u64,
                prompt,
                max_new_tokens: new,
                temperature: None,
                arrival: now,
            }
        })
        .collect()
}

/// Exponential inter-arrival gaps for an open-loop replay at `rate` req/s.
pub fn poisson_gaps(n: usize, rate: f64, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| -(1.0 - rng.next_f64()).ln() / rate).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(TraceConfig::default());
        let b = generate(TraceConfig::default());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
    }

    #[test]
    fn respects_bounds() {
        let c = TraceConfig { min_prompt: 3, max_prompt: 5, min_new: 2, max_new: 4, ..Default::default() };
        for r in generate(c) {
            assert!((3..=5).contains(&r.prompt.len()));
            assert!((2..=4).contains(&r.max_new_tokens));
            assert!(r.prompt.iter().all(|&t| t < c.vocab_size as u32));
        }
    }

    #[test]
    fn poisson_gaps_mean() {
        let gaps = poisson_gaps(10_000, 100.0, 3);
        let mean: f64 = gaps.iter().sum::<f64>() / gaps.len() as f64;
        assert!((mean - 0.01).abs() < 0.001, "mean {mean}");
        assert!(gaps.iter().all(|&g| g >= 0.0));
    }
}
