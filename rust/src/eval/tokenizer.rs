//! Word-level tokenizer with frequency-capped vocabulary.
//!
//! Completes the evaluation substrate: real text corpora (when available)
//! can be tokenized to the id streams the PPL/BLEU machinery consumes.
//! Deterministic: ties in frequency break lexicographically.

use std::collections::HashMap;

pub const PAD: u32 = 0;
pub const UNK: u32 = 1;
pub const BOS: u32 = 2;
pub const EOS: u32 = 3;
const SPECIALS: usize = 4;

/// Word-level vocabulary.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    word_to_id: HashMap<String, u32>,
    id_to_word: Vec<String>,
}

impl Tokenizer {
    /// Build from text: lowercased whitespace/punctuation-split words,
    /// most frequent first, capped at `max_vocab` (including 4 specials).
    pub fn fit(text: &str, max_vocab: usize) -> Tokenizer {
        assert!(max_vocab > SPECIALS);
        let mut counts: HashMap<String, u64> = HashMap::new();
        for word in split_words(text) {
            *counts.entry(word).or_insert(0) += 1;
        }
        let mut words: Vec<(String, u64)> = counts.into_iter().collect();
        words.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        words.truncate(max_vocab - SPECIALS);

        let mut id_to_word: Vec<String> =
            ["<pad>", "<unk>", "<bos>", "<eos>"].iter().map(|s| s.to_string()).collect();
        id_to_word.extend(words.into_iter().map(|(w, _)| w));
        let word_to_id =
            id_to_word.iter().enumerate().map(|(i, w)| (w.clone(), i as u32)).collect();
        Tokenizer { word_to_id, id_to_word }
    }

    pub fn vocab_size(&self) -> usize {
        self.id_to_word.len()
    }

    /// Encode text (unknown words → UNK).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        split_words(text)
            .map(|w| self.word_to_id.get(&w).copied().unwrap_or(UNK))
            .collect()
    }

    /// Encode wrapped in BOS/EOS.
    pub fn encode_sentence(&self, text: &str) -> Vec<u32> {
        let mut out = vec![BOS];
        out.extend(self.encode(text));
        out.push(EOS);
        out
    }

    /// Decode ids back to a space-joined string (specials skipped).
    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .filter(|&&id| id as usize >= SPECIALS)
            .map(|&id| {
                self.id_to_word.get(id as usize).map(|s| s.as_str()).unwrap_or("<bad>")
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

fn split_words(text: &str) -> impl Iterator<Item = String> + '_ {
    text.split(|c: char| !c.is_alphanumeric())
        .filter(|w| !w.is_empty())
        .map(|w| w.to_lowercase())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "The cat sat on the mat. The cat, the CAT!";

    #[test]
    fn frequency_order() {
        let t = Tokenizer::fit(SAMPLE, 100);
        // "the"/"cat" are most frequent -> lowest non-special ids.
        let the = t.encode("the")[0];
        let cat = t.encode("cat")[0];
        let mat = t.encode("mat")[0];
        assert!(the < mat && cat < mat);
        assert_eq!(t.encode("THE")[0], the, "case-insensitive");
    }

    #[test]
    fn unknown_maps_to_unk() {
        let t = Tokenizer::fit(SAMPLE, 100);
        assert_eq!(t.encode("zebra"), vec![UNK]);
    }

    #[test]
    fn vocab_cap_respected() {
        let t = Tokenizer::fit(SAMPLE, 6); // 4 specials + 2 words
        assert_eq!(t.vocab_size(), 6);
        // Less-frequent words fall to UNK.
        assert_eq!(t.encode("mat"), vec![UNK]);
        assert_ne!(t.encode("the"), vec![UNK]);
    }

    #[test]
    fn roundtrip_known_words() {
        let t = Tokenizer::fit(SAMPLE, 100);
        let ids = t.encode("the cat sat");
        assert_eq!(t.decode(&ids), "the cat sat");
    }

    #[test]
    fn sentence_wrapping() {
        let t = Tokenizer::fit(SAMPLE, 100);
        let ids = t.encode_sentence("the cat");
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), EOS);
        assert_eq!(t.decode(&ids), "the cat");
    }

    #[test]
    fn deterministic() {
        let a = Tokenizer::fit(SAMPLE, 50);
        let b = Tokenizer::fit(SAMPLE, 50);
        assert_eq!(a.encode("the cat sat on the mat"), b.encode("the cat sat on the mat"));
    }
}
