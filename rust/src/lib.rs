//! # BD Attention (BDA)
//!
//! Production-oriented reproduction of *Accelerating Attention with Basis
//! Decomposition* (Jialin Zhao, 2025): a lossless algorithmic reformulation
//! of multi-head attention built as a three-layer Rust + JAX + Pallas stack.
//!
//! - **L3 (this crate):** the serving coordinator (router, dynamic
//!   batcher, ref-counted block KV-cache, continuous-batching scheduler)
//!   over the **paged batched decode engine** ([`engine`]): a shared
//!   block-granular K/V storage pool plus a single batched decode step
//!   that advances every active sequence at once through paged attention,
//!   with fork/copy-on-write prefix sharing. The decode hot path is a
//!   **blocked paged-attention kernel parallelized over (sequence, head)
//!   work items** (`BDA_NUM_THREADS` sets the worker count; output is
//!   bit-identical to the serial reference at any setting) with the
//!   per-layer Q/K/V projections fused into one packed GEMM, and every
//!   parallel region dispatches on a **persistent parked worker pool**
//!   ([`util::threadpool`]) — no thread spawn/join on the hot path.
//!   Alongside it: the BD math library, pure-Rust attention operators
//!   (MHA / BDA / PIFA-style / paged), model definitions, and evaluation
//!   harnesses for every table and figure in the paper.
//! - **L2/L1 (`python/compile/`):** JAX transformer + Pallas kernels,
//!   AOT-lowered once to `artifacts/*.hlo.txt` and executed from Rust via
//!   PJRT (the `runtime` module, behind the `pjrt` feature). Python is
//!   never on the request path.
//!
//! Entry points: [`bd`] for the decomposition, [`attention`] for the
//! operators, [`prepare`] for Algorithm 3 model conversion, [`engine`] for
//! the paged decode engine, [`coordinator`] for serving, [`obs`] for
//! structured tracing and per-sequence timelines (Perfetto/Prometheus
//! export, gated by `BDA_TRACE`).

pub mod bd;
pub mod model;
pub mod prepare;
pub mod attention;
pub mod coordinator;
pub mod engine;
pub mod obs;
pub mod bench_support;
pub mod eval;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod linalg;
pub mod tensor;
pub mod util;
