//! # BD Attention (BDA)
//!
//! Production-oriented reproduction of *Accelerating Attention with Basis
//! Decomposition* (Jialin Zhao, 2025): a lossless algorithmic reformulation
//! of multi-head attention built as a three-layer Rust + JAX + Pallas stack.
//!
//! - **L3 (this crate):** serving coordinator (router, dynamic batcher,
//!   KV-cache, scheduler), the BD math library, pure-Rust attention
//!   operators (MHA / BDA / PIFA-style), model definitions, and evaluation
//!   harnesses for every table and figure in the paper.
//! - **L2/L1 (`python/compile/`):** JAX transformer + Pallas kernels,
//!   AOT-lowered once to `artifacts/*.hlo.txt` and executed from Rust via
//!   PJRT ([`runtime`]). Python is never on the request path.
//!
//! Entry points: [`bd`] for the decomposition, [`attention`] for the
//! operators, [`prepare`] for Algorithm 3 model conversion, [`coordinator`]
//! for serving.

pub mod bd;
pub mod model;
pub mod prepare;
pub mod attention;
pub mod coordinator;
pub mod bench_support;
pub mod eval;
pub mod runtime;
pub mod linalg;
pub mod tensor;
pub mod util;
