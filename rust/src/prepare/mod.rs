//! Model-level BDA preparation — Algorithm 3 applied across all layers,
//! with the timing and residual statistics the paper reports ("4s offline
//! preparation", Table 4 errors, Table 5 preparation time).

use crate::attention::bda::PrepStats;
use crate::bd::Strategy;
use crate::model::Transformer;
use crate::tensor::DType;
use crate::util::timer::Timer;

/// Outcome of preparing a whole model.
pub struct PrepReport {
    pub model: Transformer,
    /// Wallclock seconds for the whole preparation (Table 5 row).
    pub seconds: f64,
    /// Per-layer QK stats.
    pub qk: Vec<PrepStats>,
    /// Per-layer VO stats.
    pub vo: Vec<PrepStats>,
    pub strategy: Strategy,
    pub dtype: DType,
}

impl PrepReport {
    fn agg(stats: &[PrepStats], f: impl Fn(&PrepStats) -> f64) -> f64 {
        if stats.is_empty() {
            return 0.0;
        }
        stats.iter().map(f).sum::<f64>() / stats.len() as f64
    }

    /// Mean MSE across layers/heads (Table 4 "QK MSE" cell).
    pub fn qk_mse(&self) -> f64 {
        Self::agg(&self.qk, |s| s.mean_mse())
    }
    pub fn qk_nmse(&self) -> f64 {
        Self::agg(&self.qk, |s| s.mean_nmse())
    }
    pub fn vo_mse(&self) -> f64 {
        Self::agg(&self.vo, |s| s.mean_mse())
    }
    pub fn vo_nmse(&self) -> f64 {
        Self::agg(&self.vo, |s| s.mean_nmse())
    }
}

/// Prepare a dense-MHA model as BDA, collecting stats + timing.
pub fn prepare_model(
    model: &Transformer,
    strategy: Strategy,
    dtype: DType,
) -> Result<PrepReport, crate::bd::BdError> {
    let t = Timer::start();
    let converted = model.to_bda(strategy, dtype)?;
    let seconds = t.elapsed_secs();
    let mut qk = Vec::new();
    let mut vo = Vec::new();
    for b in &converted.blocks {
        if let crate::model::AttentionImpl::Bda(w) = &b.attn {
            qk.push(w.qk_stats.clone());
            vo.push(w.vo_stats.clone());
        }
    }
    Ok(PrepReport { model: converted, seconds, qk, vo, strategy, dtype })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    #[test]
    fn prepare_reports_stats_and_time() {
        let m = Transformer::new_mha(ModelConfig::tiny(), 1);
        let rep = prepare_model(&m, Strategy::ResidualMin, DType::F32).unwrap();
        assert_eq!(rep.qk.len(), m.config.n_layers);
        assert_eq!(rep.vo.len(), m.config.n_layers);
        assert!(rep.seconds > 0.0);
        // FP32 errors are tiny (Table 4: ~1e-12 MSE scale).
        assert!(rep.qk_mse() < 1e-8, "qk mse {}", rep.qk_mse());
        assert!(rep.vo_mse() < 1e-8);
    }

    #[test]
    fn fp16_errors_larger_than_fp32() {
        let m = Transformer::new_mha(ModelConfig::tiny(), 2);
        let r32 = prepare_model(&m, Strategy::ResidualMin, DType::F32).unwrap();
        let r16 = prepare_model(&m, Strategy::ResidualMin, DType::F16).unwrap();
        assert!(r16.qk_nmse() > r32.qk_nmse());
        assert!(r16.vo_nmse() > r32.vo_nmse());
    }

    #[test]
    fn residual_min_not_worse_than_first() {
        let m = Transformer::new_mha(ModelConfig::tiny(), 3);
        for dt in [DType::F32, DType::F16, DType::BF16] {
            let rf = prepare_model(&m, Strategy::FirstR, dt).unwrap();
            let rm = prepare_model(&m, Strategy::ResidualMin, dt).unwrap();
            // Mean selected residual of Residual-min <= First-r's (Alg. 3
            // compares means, so this holds per layer in expectation; we
            // assert the aggregate).
            let f: f64 = rf.qk.iter().map(|s| s.mean_residual_first()).sum();
            let m_sel: f64 = rm
                .qk
                .iter()
                .map(|s| s.mean_residual_first().min(s.mean_residual_last()))
                .sum();
            assert!(m_sel <= f + 1e-12, "{dt}: {m_sel} vs {f}");
        }
    }
}
