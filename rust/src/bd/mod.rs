//! Basis Decomposition (BD) — the paper's core contribution (§3.1–3.3).
//!
//! Given a low-rank product `W = U V^T` (rank r), BD re-expresses `W`
//! around `r` *contiguous* rows or columns of `W` itself:
//!
//! ```text
//! (1) row & first:    W = [I; C] B          B = first r rows
//! (2) row & last:     W = [C; I] B          B = last  r rows
//! (3) column & first: W = B [I, C]          B = first r cols
//! (4) column & last:  W = B [C, I]          B = last  r cols
//! ```
//!
//! Memory: `r(m+n-r)` vs. low-rank's `r(m+n)` vs. dense `mn`.
//! Reconstruction FLOPs: `2r(m-r)n` vs. low-rank's `2rmn`.
//! Contiguity of the basis is what makes the identity hardware-friendly
//! (coalesced loads; no per-head gather — unlike PIFA's pivoted basis).

pub mod cost;
pub mod decompose;
pub mod linear;
pub mod reconstruct;

pub use cost::BdCost;
pub use decompose::{bd_col, bd_row, BdError, ColBd, RowBd};
pub use linear::BdLinear;
pub use reconstruct::{reconstruct_col, reconstruct_row};

/// Which contiguous block of rows/columns forms the basis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tag {
    First,
    Last,
}

impl Tag {
    pub fn name(self) -> &'static str {
        match self {
            Tag::First => "first",
            Tag::Last => "last",
        }
    }
}

/// Basis-selection strategy (Fig. 2a / Tables 4–5 compare these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Always take the first-r rows/columns.
    FirstR,
    /// Take whichever of first-r / last-r has the smaller reconstruction
    /// residual (the paper's default).
    ResidualMin,
}

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Strategy::FirstR => "First-r",
            Strategy::ResidualMin => "Residual-min",
        }
    }
}
