//! BD layer for low-rank linear layers (§3.3, Eq. 5).
//!
//! A low-rank layer `y = (xU)V^T` (U: d_in×r, V: d_out×r) is replaced by
//! `h = xB; y = [h, hC]` (column BD, First) — fewer params
//! (`r(d_in+d_out−r)` vs `r(d_in+d_out)`) and fewer FLOPs, with exactly the
//! same outputs. This is the plug-in step applied on top of low-rank-pruned
//! models in Table 3.

use super::{bd_col, BdCost, BdError, Strategy, Tag};
use crate::tensor::matmul::matmul;
use crate::tensor::Tensor;

/// A linear layer in BD form.
#[derive(Clone, Debug)]
pub struct BdLinear {
    pub tag: Tag,
    /// d_in × r — the basis columns of W = U V^T.
    pub b: Tensor,
    /// r × (d_out − r) — coefficients.
    pub c: Tensor,
    pub d_in: usize,
    pub d_out: usize,
    pub r: usize,
    /// Decomposition residual (‖W − recon‖_F).
    pub residual: f64,
}

impl BdLinear {
    /// Build from low-rank factors U (d_in×r), V (d_out×r):
    /// decomposes W = U V^T with column BD.
    pub fn from_lowrank(u: &Tensor, v: &Tensor, strategy: Strategy) -> Result<BdLinear, BdError> {
        assert_eq!(u.ndim(), 2);
        assert_eq!(v.ndim(), 2);
        assert_eq!(u.cols(), v.cols(), "rank mismatch between U and V");
        let (d_in, r) = (u.rows(), u.cols());
        let d_out = v.rows();
        let w = matmul(u, &v.transpose());
        let col = bd_col(&w, r, strategy)?;
        Ok(BdLinear {
            tag: col.tag,
            b: col.b,
            c: col.c,
            d_in,
            d_out,
            r,
            residual: col.residual,
        })
    }

    /// Forward pass `y = x W` computed in BD form (Eq. 5):
    /// `h = x B; y = [h, hC]` (First) or `y = [hC, h]` (Last).
    pub fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.cols(), self.d_in);
        let h = matmul(x, &self.b);
        let hc = matmul(&h, &self.c);
        match self.tag {
            Tag::First => Tensor::concat_cols(&[&h, &hc]),
            Tag::Last => Tensor::concat_cols(&[&hc, &h]),
        }
    }

    /// Reference forward through the reconstructed dense W (for tests).
    pub fn forward_dense_ref(&self, x: &Tensor) -> Tensor {
        let w = super::reconstruct_col(self.tag, &self.b, &self.c);
        matmul(x, &w)
    }

    pub fn cost(&self) -> BdCost {
        BdCost::new(self.d_in, self.d_out, self.r)
    }

    /// Parameters actually stored by this layer.
    pub fn param_count(&self) -> usize {
        self.b.numel() + self.c.numel()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_lowrank_forward_exactly() {
        let (d_in, d_out, r) = (24, 16, 5);
        let u = Tensor::randn(&[d_in, r], 0.2, 1);
        let v = Tensor::randn(&[d_out, r], 0.2, 2);
        let layer = BdLinear::from_lowrank(&u, &v, Strategy::ResidualMin).unwrap();
        let x = Tensor::randn(&[7, d_in], 1.0, 3);
        // Reference: y = (xU)V^T
        let y_ref = matmul(&matmul(&x, &u), &v.transpose());
        let y_bd = layer.forward(&x);
        assert!(
            y_bd.max_abs_diff(&y_ref) < 1e-3,
            "diff {}",
            y_bd.max_abs_diff(&y_ref)
        );
    }

    #[test]
    fn bd_forward_matches_dense_reconstruction() {
        let u = Tensor::randn(&[10, 3], 0.5, 4);
        let v = Tensor::randn(&[8, 3], 0.5, 5);
        let layer = BdLinear::from_lowrank(&u, &v, Strategy::FirstR).unwrap();
        let x = Tensor::randn(&[4, 10], 1.0, 6);
        let a = layer.forward(&x);
        let b = layer.forward_dense_ref(&x);
        assert!(a.max_abs_diff(&b) < 1e-4);
    }

    #[test]
    fn param_count_formula() {
        let (d_in, d_out, r) = (32, 20, 6);
        let u = Tensor::randn(&[d_in, r], 0.3, 7);
        let v = Tensor::randn(&[d_out, r], 0.3, 8);
        let layer = BdLinear::from_lowrank(&u, &v, Strategy::ResidualMin).unwrap();
        assert_eq!(layer.param_count(), r * (d_in + d_out - r));
        assert_eq!(layer.param_count(), layer.cost().bd_params());
        assert!(layer.param_count() < r * (d_in + d_out));
    }

    #[test]
    fn last_tag_output_order() {
        // Force Last by making the first-r columns tiny (ill-conditioned).
        let mut u = Tensor::randn(&[12, 2], 1.0, 9);
        let v = Tensor::randn(&[10, 2], 1.0, 10);
        // Shrink contributions so that first columns of W are nearly
        // parallel -> larger residual for First in f32.
        for i in 0..12 {
            *u.at_mut(i, 1) *= 1e-3;
        }
        let layer = BdLinear::from_lowrank(&u, &v, Strategy::ResidualMin).unwrap();
        let x = Tensor::randn(&[3, 12], 1.0, 11);
        let y_ref = matmul(&matmul(&x, &u), &v.transpose());
        assert!(layer.forward(&x).max_abs_diff(&y_ref) < 1e-3);
    }
}
