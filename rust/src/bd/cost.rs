//! Parameter / FLOP cost model of BD vs. low-rank vs. dense (§3.1).
//!
//! These formulas back the paper's headline claims: 25% weight reduction
//! and the 1.33× theoretical k_proj speedup at `d_h/d = 25%`.

/// Costs of representing / applying an m×n rank-r matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BdCost {
    pub m: usize,
    pub n: usize,
    pub r: usize,
}

impl BdCost {
    pub fn new(m: usize, n: usize, r: usize) -> Self {
        assert!(r <= m.min(n), "rank {r} exceeds min({m},{n})");
        BdCost { m, n, r }
    }

    /// Dense parameter count `mn`.
    pub fn dense_params(&self) -> usize {
        self.m * self.n
    }

    /// Low-rank (U V^T) parameter count `r(m+n)`.
    pub fn lowrank_params(&self) -> usize {
        self.r * (self.m + self.n)
    }

    /// BD parameter count `r(m+n-r)` — strictly below both for r < min(m,n).
    pub fn bd_params(&self) -> usize {
        self.r * (self.m + self.n - self.r)
    }

    /// FLOPs to reconstruct W from low-rank factors: `2rmn`.
    pub fn lowrank_recon_flops(&self) -> u64 {
        2 * self.r as u64 * self.m as u64 * self.n as u64
    }

    /// FLOPs to reconstruct W from BD: `2r(m-r)n` (CB product only; basis
    /// rows are copied).
    pub fn bd_recon_flops(&self) -> u64 {
        2 * self.r as u64 * (self.m - self.r) as u64 * self.n as u64
    }

    /// FLOPs to apply a length-L batch through the *low-rank* layer
    /// `y = (xU)V^T`: `2Lr(m+n)` for x: L×m.
    pub fn lowrank_apply_flops(&self, l: usize) -> u64 {
        2 * l as u64 * self.r as u64 * (self.m + self.n) as u64
    }

    /// FLOPs to apply through the *BD* layer `h = xB; y = [h, hC]`:
    /// `2Lr m + 2Lr(n-r) = 2Lr(m+n-r)`.
    pub fn bd_apply_flops(&self, l: usize) -> u64 {
        2 * l as u64 * self.r as u64 * (self.m + self.n - self.r) as u64
    }

    /// Parameter saving of BD vs low-rank: `r/(m+n)` of the low-rank size
    /// (the paper's `d_h/d`-flavoured reduction for attention shapes).
    pub fn saving_vs_lowrank(&self) -> f64 {
        self.r as f64 / (self.m + self.n) as f64
    }
}

/// The paper's k_proj-operator speedup bound for MHA vs BDA.
///
/// MHA computes `K = X W_k` (`X`: L×d, `W_k`: d×n·d_h): `2·L·d·n·d_h` FLOPs.
/// BDA computes `K' = [X_{:,1:d_h}]^{×n} + X_{:,d_h:} C_qk`
/// (`C_qk`: (d−d_h)×n·d_h): `2·L·(d−d_h)·n·d_h` (+ L·n·d_h adds, dropped by
/// the paper as the repeat-add is fused/bandwidth-level).
/// Ratio = d/(d−d_h) = 1/(1−d_h/d); at d_h/d = 25% → 4/3 ≈ 1.33×.
pub fn kproj_theoretical_speedup(d: usize, d_h: usize) -> f64 {
    assert!(d_h < d);
    d as f64 / (d - d_h) as f64
}

/// Weight reduction of BDA's K (or V) projection replacement: the d×(n·d_h)
/// `W_k` becomes the (d−d_h)×(n·d_h) `C_qk` → saving d_h/d (25% at 128/512).
pub fn kv_weight_reduction(d: usize, d_h: usize) -> f64 {
    d_h as f64 / d as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bd_strictly_smaller() {
        for (m, n, r) in [(512, 128, 64), (100, 100, 99), (64, 512, 32)] {
            let c = BdCost::new(m, n, r);
            assert!(c.bd_params() < c.lowrank_params());
            assert!(c.bd_params() < c.dense_params());
            assert!(c.bd_recon_flops() < c.lowrank_recon_flops());
            assert!(c.bd_apply_flops(16) < c.lowrank_apply_flops(16));
        }
    }

    #[test]
    fn lowrank_only_compact_below_threshold() {
        // r < mn/(m+n) is the paper's threshold for low-rank beating dense.
        let c = BdCost::new(100, 100, 51); // threshold is 50
        assert!(c.lowrank_params() > c.dense_params());
        assert!(c.bd_params() < c.dense_params()); // BD still wins
    }

    #[test]
    fn deepseek_numbers() {
        // d=512, d_h=128 -> 1.33x speedup, 25% weight cut (paper §4.1).
        let s = kproj_theoretical_speedup(512, 128);
        assert!((s - 4.0 / 3.0).abs() < 1e-12);
        assert!((kv_weight_reduction(512, 128) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn formulas_match_paper_text() {
        let c = BdCost::new(7, 5, 3);
        assert_eq!(c.bd_params(), 3 * (7 + 5 - 3));
        assert_eq!(c.lowrank_params(), 3 * (7 + 5));
        assert_eq!(c.bd_recon_flops(), 2 * 3 * (7 - 3) * 5);
        assert_eq!(c.lowrank_recon_flops(), 2 * 3 * 7 * 5);
    }

    #[test]
    #[should_panic]
    fn rank_bound_enforced() {
        BdCost::new(4, 5, 5);
    }
}
