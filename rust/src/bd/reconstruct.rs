//! BD reconstruction — Algorithm 5 (row) and its column analogue: the four
//! identities of Eq. 2.

use super::Tag;
use crate::tensor::matmul::matmul;
use crate::tensor::Tensor;

/// Row reconstruction: `W = [B; CB]` (First) or `W = [CB; B]` (Last).
/// B: r×n, C: (m−r)×r → W: m×n.
pub fn reconstruct_row(tag: Tag, b: &Tensor, c: &Tensor) -> Tensor {
    assert_eq!(b.ndim(), 2);
    assert_eq!(c.ndim(), 2);
    assert_eq!(c.cols(), b.rows(), "C cols must equal basis rank");
    let cb = matmul(c, b);
    match tag {
        Tag::First => Tensor::concat_rows(&[b, &cb]),
        Tag::Last => Tensor::concat_rows(&[&cb, b]),
    }
}

/// Column reconstruction: `W = [B, BC]` (First) or `W = [BC, B]` (Last).
/// B: m×r, C: r×(n−r) → W: m×n.
pub fn reconstruct_col(tag: Tag, b: &Tensor, c: &Tensor) -> Tensor {
    assert_eq!(b.ndim(), 2);
    assert_eq!(c.ndim(), 2);
    assert_eq!(b.cols(), c.rows(), "C rows must equal basis rank");
    let bc = matmul(b, c);
    match tag {
        Tag::First => Tensor::concat_cols(&[b, &bc]),
        Tag::Last => Tensor::concat_cols(&[&bc, b]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_first_layout() {
        // B = [[1,2]], C = [[3],[4]] -> W = [[1,2],[3,6],[4,8]]
        let b = Tensor::from_vec(vec![1.0, 2.0], &[1, 2]);
        let c = Tensor::from_vec(vec![3.0, 4.0], &[2, 1]);
        let w = reconstruct_row(Tag::First, &b, &c);
        assert_eq!(w.shape, vec![3, 2]);
        assert_eq!(w.data, vec![1.0, 2.0, 3.0, 6.0, 4.0, 8.0]);
    }

    #[test]
    fn row_last_layout() {
        let b = Tensor::from_vec(vec![1.0, 2.0], &[1, 2]);
        let c = Tensor::from_vec(vec![3.0], &[1, 1]);
        let w = reconstruct_row(Tag::Last, &b, &c);
        assert_eq!(w.data, vec![3.0, 6.0, 1.0, 2.0]);
    }

    #[test]
    fn col_first_layout() {
        // B = [[1],[2]], C = [[5, 6]] -> W = [[1,5,6],[2,10,12]]
        let b = Tensor::from_vec(vec![1.0, 2.0], &[2, 1]);
        let c = Tensor::from_vec(vec![5.0, 6.0], &[1, 2]);
        let w = reconstruct_col(Tag::First, &b, &c);
        assert_eq!(w.shape, vec![2, 3]);
        assert_eq!(w.data, vec![1.0, 5.0, 6.0, 2.0, 10.0, 12.0]);
    }

    #[test]
    fn col_last_layout() {
        let b = Tensor::from_vec(vec![1.0, 2.0], &[2, 1]);
        let c = Tensor::from_vec(vec![5.0], &[1, 1]);
        let w = reconstruct_col(Tag::Last, &b, &c);
        assert_eq!(w.data, vec![5.0, 1.0, 10.0, 2.0]);
    }

    #[test]
    fn identity_coefficients() {
        // C rows that are unit vectors reproduce basis rows.
        let b = Tensor::from_vec(vec![1.0, 0.0, 0.0, 1.0], &[2, 2]);
        let c = Tensor::from_vec(vec![1.0, 0.0], &[1, 2]);
        let w = reconstruct_row(Tag::First, &b, &c);
        assert_eq!(w.row(2), w.row(0));
    }
}
