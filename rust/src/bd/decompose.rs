//! BD decomposition — Algorithm 4 (row) and its column analogue.
//!
//! Solves for the coefficient matrix `C` expressing the non-basis
//! rows/columns of `W` in the chosen contiguous basis, evaluating both the
//! first-r and last-r candidates and (optionally) keeping the smaller
//! Frobenius residual (*Residual-min*, the paper's default).

use super::{reconstruct_col, reconstruct_row, Strategy, Tag};
use crate::linalg::lu::{lu_solve_matrix_f64, solve_xa_b_f64, LinalgError, MatF64};
use crate::tensor::Tensor;

#[derive(Debug, thiserror::Error)]
pub enum BdError {
    #[error("rank {r} out of range for {m}x{n} matrix")]
    BadRank { r: usize, m: usize, n: usize },
    #[error("basis is singular: {0}")]
    SingularBasis(#[from] LinalgError),
}

/// Row-based BD of W (m×n) with basis rank r: `W = [I; C] B` (first) or
/// `W = [C; I] B` (last).
#[derive(Clone, Debug)]
pub struct RowBd {
    pub tag: Tag,
    /// Basis rows, r×n.
    pub b: Tensor,
    /// Coefficients, (m−r)×r.
    pub c: Tensor,
    /// Frobenius-norm reconstruction residual of the selected candidate.
    pub residual: f64,
    /// Residuals of both candidates (first, last) — Table 4 reports these.
    pub residual_first: f64,
    pub residual_last: f64,
}

/// Column-based BD of W (m×n) with basis rank r: `W = B [I, C]` (first) or
/// `W = B [C, I]` (last).
#[derive(Clone, Debug)]
pub struct ColBd {
    pub tag: Tag,
    /// Basis columns, m×r.
    pub b: Tensor,
    /// Coefficients, r×(n−r).
    pub c: Tensor,
    pub residual: f64,
    pub residual_first: f64,
    pub residual_last: f64,
}

fn check_rank(r: usize, m: usize, n: usize) -> Result<(), BdError> {
    if r == 0 || r >= m || r > n {
        return Err(BdError::BadRank { r, m, n });
    }
    Ok(())
}

/// Solve one row-candidate: basis = rows [lo, hi) of W; C solves
/// `C B = W_rest` via the r×r Gram-free system `C (B B-square)`… —
/// concretely we solve `X A = B` with A the r×r submatrix *of the basis on
/// its own columns*? No: the paper solves the (generally overdetermined but
/// exactly consistent) system `W_rest = C B` directly. With rank(W)=r and B
/// spanning the row space, `C = W_rest B^T (B B^T)^{-1}` — we form the
/// normal equations, which are exact for consistent systems and cheap
/// (B B^T is r×r).
fn solve_row_candidate(w: &Tensor, lo: usize, hi: usize) -> Result<(Tensor, f64), BdError> {
    let b = w.slice_rows(lo, hi);
    // rest = rows of W outside [lo, hi)
    let top = w.slice_rows(0, lo);
    let bot = w.slice_rows(hi, w.rows());
    let rest = Tensor::concat_rows(&[&top, &bot]);
    // Normal equations in f64 (offline prep runs in double precision; the
    // paper's FP32 Table 4 errors are ~1e-12, only reachable this way):
    // C (B B^T) = rest B^T.
    let b64 = MatF64::from_tensor(&b);
    let rest64 = MatF64::from_tensor(&rest);
    let bbt = b64.matmul(&b64.transpose());
    let rbt = rest64.matmul(&b64.transpose());
    let c = solve_xa_b_f64(&bbt, &rbt)?.to_tensor();
    // Residual over the full reconstruction.
    let tag = if lo == 0 { Tag::First } else { Tag::Last };
    let recon = reconstruct_row(tag, &b, &c);
    let residual = recon.sub(w).fro_norm();
    Ok((c, residual))
}

fn solve_col_candidate(w: &Tensor, lo: usize, hi: usize) -> Result<(Tensor, f64), BdError> {
    let b = w.slice_cols(lo, hi);
    let left = w.slice_cols(0, lo);
    let right = w.slice_cols(hi, w.cols());
    let rest = Tensor::concat_cols(&[&left, &right]);
    // Solve B C = rest (tall, consistent) via f64 normal equations:
    // (B^T B) C = B^T rest.
    let b64 = MatF64::from_tensor(&b);
    let rest64 = MatF64::from_tensor(&rest);
    let btb = b64.transpose().matmul(&b64);
    let btr = b64.transpose().matmul(&rest64);
    let c = lu_solve_matrix_f64(&btb, &btr)?.to_tensor();
    let tag = if lo == 0 { Tag::First } else { Tag::Last };
    let recon = reconstruct_col(tag, &b, &c);
    let residual = recon.sub(w).fro_norm();
    Ok((c, residual))
}

/// Row-based BD (Algorithm 4): evaluates first-r and last-r bases, keeps
/// per `strategy`.
pub fn bd_row(w: &Tensor, r: usize, strategy: Strategy) -> Result<RowBd, BdError> {
    let (m, n) = (w.rows(), w.cols());
    check_rank(r, m, n)?;
    let (c_f, res_f) = solve_row_candidate(w, 0, r)?;
    match strategy {
        Strategy::FirstR => Ok(RowBd {
            tag: Tag::First,
            b: w.slice_rows(0, r),
            c: c_f,
            residual: res_f,
            residual_first: res_f,
            residual_last: f64::NAN,
        }),
        Strategy::ResidualMin => {
            let (c_l, res_l) = solve_row_candidate(w, m - r, m)?;
            if res_f <= res_l {
                Ok(RowBd {
                    tag: Tag::First,
                    b: w.slice_rows(0, r),
                    c: c_f,
                    residual: res_f,
                    residual_first: res_f,
                    residual_last: res_l,
                })
            } else {
                Ok(RowBd {
                    tag: Tag::Last,
                    b: w.slice_rows(m - r, m),
                    c: c_l,
                    residual: res_l,
                    residual_first: res_f,
                    residual_last: res_l,
                })
            }
        }
    }
}

/// Column-based BD: evaluates first-r and last-r column bases.
pub fn bd_col(w: &Tensor, r: usize, strategy: Strategy) -> Result<ColBd, BdError> {
    let (m, n) = (w.rows(), w.cols());
    // Column BD needs r < n and r <= m.
    if r == 0 || r >= n || r > m {
        return Err(BdError::BadRank { r, m, n });
    }
    let (c_f, res_f) = solve_col_candidate(w, 0, r)?;
    match strategy {
        Strategy::FirstR => Ok(ColBd {
            tag: Tag::First,
            b: w.slice_cols(0, r),
            c: c_f,
            residual: res_f,
            residual_first: res_f,
            residual_last: f64::NAN,
        }),
        Strategy::ResidualMin => {
            let (c_l, res_l) = solve_col_candidate(w, n - r, n)?;
            if res_f <= res_l {
                Ok(ColBd {
                    tag: Tag::First,
                    b: w.slice_cols(0, r),
                    c: c_f,
                    residual: res_f,
                    residual_first: res_f,
                    residual_last: res_l,
                })
            } else {
                Ok(ColBd {
                    tag: Tag::Last,
                    b: w.slice_cols(n - r, n),
                    c: c_l,
                    residual: res_l,
                    residual_first: res_f,
                    residual_last: res_l,
                })
            }
        }
    }
}

/// Convenience: build a rank-r product W = U V^T from factors.
pub fn lowrank_product(u: &Tensor, vt: &Tensor) -> Tensor {
    crate::tensor::matmul::matmul(u, vt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul::matmul;

    fn rank_r(m: usize, n: usize, r: usize, seed: u64) -> Tensor {
        let u = Tensor::randn(&[m, r], 1.0, seed);
        let vt = Tensor::randn(&[r, n], 1.0, seed + 1000);
        matmul(&u, &vt)
    }

    #[test]
    fn row_bd_exact_on_rank_r() {
        let w = rank_r(12, 8, 3, 1);
        let bd = bd_row(&w, 3, Strategy::ResidualMin).unwrap();
        let recon = reconstruct_row(bd.tag, &bd.b, &bd.c);
        assert!(recon.max_abs_diff(&w) < 1e-3, "diff {}", recon.max_abs_diff(&w));
        assert!(bd.residual < 1e-3 * w.fro_norm().max(1.0));
    }

    #[test]
    fn col_bd_exact_on_rank_r() {
        let w = rank_r(8, 12, 3, 2);
        let bd = bd_col(&w, 3, Strategy::ResidualMin).unwrap();
        let recon = reconstruct_col(bd.tag, &bd.b, &bd.c);
        assert!(recon.max_abs_diff(&w) < 1e-3);
    }

    #[test]
    fn attention_shapes_exact() {
        // The MHA case: d×d_h @ d_h×d product, col-BD with r=d_h (QK),
        // row-BD with r=d_h (VO).
        let (d, dh) = (64, 16);
        let wq = Tensor::randn(&[d, dh], 0.05, 3);
        let wk = Tensor::randn(&[d, dh], 0.05, 4);
        let w = matmul(&wq, &wk.transpose()); // d×d rank dh
        let col = bd_col(&w, dh, Strategy::ResidualMin).unwrap();
        let rc = reconstruct_col(col.tag, &col.b, &col.c);
        assert!(rc.max_abs_diff(&w) < 1e-4);
        let row = bd_row(&w, dh, Strategy::ResidualMin).unwrap();
        let rr = reconstruct_row(row.tag, &row.b, &row.c);
        assert!(rr.max_abs_diff(&w) < 1e-4);
    }

    #[test]
    fn residual_min_never_worse_than_first() {
        for seed in 0..8 {
            let w = rank_r(20, 10, 4, 100 + seed);
            let f = bd_row(&w, 4, Strategy::FirstR).unwrap();
            let m = bd_row(&w, 4, Strategy::ResidualMin).unwrap();
            assert!(m.residual <= f.residual + 1e-12);
        }
    }

    #[test]
    fn first_strategy_always_first_tag() {
        let w = rank_r(10, 6, 2, 9);
        let bd = bd_row(&w, 2, Strategy::FirstR).unwrap();
        assert_eq!(bd.tag, Tag::First);
        assert!(bd.residual_last.is_nan());
    }

    #[test]
    fn shapes_of_factors() {
        let w = rank_r(10, 7, 3, 11);
        let row = bd_row(&w, 3, Strategy::ResidualMin).unwrap();
        assert_eq!(row.b.shape, vec![3, 7]);
        assert_eq!(row.c.shape, vec![7, 3]); // (m-r) x r
        let w2 = rank_r(7, 10, 3, 12);
        let col = bd_col(&w2, 3, Strategy::ResidualMin).unwrap();
        assert_eq!(col.b.shape, vec![7, 3]);
        assert_eq!(col.c.shape, vec![3, 7]); // r x (n-r)
    }

    #[test]
    fn bad_rank_rejected() {
        let w = rank_r(6, 6, 2, 13);
        assert!(bd_row(&w, 0, Strategy::FirstR).is_err());
        assert!(bd_row(&w, 6, Strategy::FirstR).is_err());
        assert!(bd_col(&w, 6, Strategy::FirstR).is_err());
    }

    #[test]
    fn overrank_bd_still_small_residual() {
        // If we decompose at r > true rank, basis Gram is singular-ish but
        // normal equations may still solve; at r == true rank it's exact.
        // Here: r equals true rank exactly -> tiny residual (relative).
        let w = rank_r(16, 16, 5, 14);
        let bd = bd_row(&w, 5, Strategy::ResidualMin).unwrap();
        assert!(bd.residual / w.fro_norm() < 1e-4);
    }

    #[test]
    fn full_rank_square_minus_one() {
        // r = n-1 on an (n x n) rank-(n-1) matrix — boundary case.
        let w = rank_r(9, 9, 8, 15);
        let bd = bd_row(&w, 8, Strategy::ResidualMin).unwrap();
        let recon = reconstruct_row(bd.tag, &bd.b, &bd.c);
        assert!(recon.max_abs_diff(&w) < 5e-3);
    }
}
