//! Trace exporters: Chrome trace-event JSON (Perfetto-loadable) and
//! Prometheus text exposition.
//!
//! The Chrome exporter lays the recorded stream out on two process rows:
//!
//! * **pid 1 — `bda workers`**: one track per recording thread (engine
//!   thread, pool workers), carrying the thread-track phases
//!   (`decode_step`, `attn`, `gemm`, `sample`, `prefix_*`, `work`).
//! * **pid 2 — `bda sequences`**: one track per request id, carrying the
//!   lifecycle phases (`enqueue` → `admit`/`prefill` → `token`… →
//!   `preempt`/`park`/`resume` → `complete`), which reads as a swimlane
//!   per sequence in Perfetto.
//!
//! All events are emitted as `"X"` (complete) events with microsecond
//! `ts`/`dur`; instants get `dur: 0`. Track names arrive as `"M"`
//! metadata events, per the trace-event format.
//!
//! [`chrome_trace_full`] additionally lays the continuous resource
//! samples ([`super::sampler`]) out as **counter tracks** (`"ph":"C"`)
//! on a third process row:
//!
//! * **pid 3 — `bda counters`**: `kv_pool_blocks`
//!   (free/used/evictable), `queue_depth`
//!   (waiting/active/prefilling/parked), and `prefix_cache_blocks` —
//!   Perfetto renders each as a stacked area chart aligned with the span
//!   tracks (they share the trace epoch).
//!
//! Sharded serving shows up in both families: every lifecycle event's
//! `args` carries the `shard` that ran it (shard 0 in single-worker
//! runs), and when samples from more than one shard are present the
//! counter tracks split per shard (`kv_pool_blocks/shard0`, …) so each
//! pool shard plots as its own area chart.

use super::recorder::SpanEvent;
use super::sampler::ResourceSample;
use super::timeline;
use crate::coordinator::metrics::Snapshot;
use crate::util::json::Json;
use crate::util::stats::{HistSnapshot, Quantiles};
use std::collections::BTreeSet;

/// Process id for per-thread (worker/engine) tracks.
const PID_WORKERS: u64 = 1;
/// Process id for per-sequence (request lifecycle) tracks.
const PID_SEQS: u64 = 2;
/// Process id for resource counter tracks.
const PID_COUNTERS: u64 = 3;

fn meta_event(name: &str, pid: u64, tid: u64, value: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::str("M")),
        ("name", Json::str(name)),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("args", Json::obj(vec![("name", Json::str(value))])),
    ])
}

/// Build a Chrome trace-event JSON document from a recorded stream.
///
/// `labels` maps thread ids to display names (from
/// [`super::thread_labels`]); unlabeled threads fall back to
/// `thread-{tid}`.
pub fn chrome_trace(events: &[SpanEvent], labels: &[(u32, String)]) -> Json {
    chrome_trace_full(events, labels, &[])
}

/// One `"ph":"C"` counter event; `series` keys become the stacked values
/// Perfetto plots for the track named `name`.
fn counter_event(name: &str, t_ns: u64, series: Vec<(&str, f64)>) -> Json {
    Json::obj(vec![
        ("ph", Json::str("C")),
        ("name", Json::str(name)),
        ("pid", Json::num(PID_COUNTERS as f64)),
        ("tid", Json::num(0.0)),
        ("ts", Json::num(t_ns as f64 / 1e3)),
        ("args", Json::obj(series.into_iter().map(|(k, v)| (k, Json::num(v))).collect())),
    ])
}

/// [`chrome_trace`] plus resource counter tracks: every
/// [`ResourceSample`] becomes `"ph":"C"` events on pid 3 (`kv_pool_blocks`,
/// `queue_depth`, and — when a pool reports prefix residency —
/// `prefix_cache_blocks`). With no samples the output is byte-identical
/// to [`chrome_trace`]: counter process metadata is only emitted when at
/// least one sample exists.
pub fn chrome_trace_full(
    events: &[SpanEvent],
    labels: &[(u32, String)],
    samples: &[ResourceSample],
) -> Json {
    let mut sorted: Vec<SpanEvent> = events.to_vec();
    sorted.sort_by_key(|e| e.seqno);

    let mut out = vec![
        meta_event("process_name", PID_WORKERS, 0, "bda workers"),
        meta_event("process_name", PID_SEQS, 0, "bda sequences"),
    ];

    let mut labeled: BTreeSet<u32> = BTreeSet::new();
    for (tid, label) in labels {
        out.push(meta_event("thread_name", PID_WORKERS, *tid as u64, label));
        labeled.insert(*tid);
    }
    let mut seq_tracks: BTreeSet<u64> = BTreeSet::new();
    for e in &sorted {
        if !labeled.contains(&e.tid) {
            out.push(meta_event(
                "thread_name",
                PID_WORKERS,
                e.tid as u64,
                &format!("thread-{}", e.tid),
            ));
            labeled.insert(e.tid);
        }
        if e.phase.is_lifecycle() && seq_tracks.insert(e.id) {
            out.push(meta_event("thread_name", PID_SEQS, e.id, &format!("seq {}", e.id)));
        }
    }

    for e in &sorted {
        let (pid, tid) = if e.phase.is_lifecycle() {
            (PID_SEQS, e.id)
        } else {
            (PID_WORKERS, e.tid as u64)
        };
        let mut args =
            vec![("id", Json::num(e.id as f64)), ("seqno", Json::num(e.seqno as f64))];
        if e.phase.is_lifecycle() {
            // Placement tag: which engine shard ran this request phase
            // (always present — shard 0 in single-worker serving — so
            // trace consumers can rely on it unconditionally).
            args.push(("shard", Json::num(e.shard as f64)));
        }
        out.push(Json::obj(vec![
            ("ph", Json::str("X")),
            ("name", Json::str(e.phase.name())),
            ("cat", Json::str(if e.phase.is_lifecycle() { "lifecycle" } else { "thread" })),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
            ("ts", Json::num(e.start_ns as f64 / 1e3)),
            ("dur", Json::num(e.dur_ns as f64 / 1e3)),
            ("args", Json::obj(args)),
        ]));
    }

    if !samples.is_empty() {
        out.push(meta_event("process_name", PID_COUNTERS, 0, "bda counters"));
        // Single-shard runs keep the legacy track names; with samples from
        // more than one shard, each shard gets its own counter tracks so
        // per-pool occupancy stays readable instead of interleaving.
        let multi_shard = samples.iter().map(|s| s.shard).collect::<BTreeSet<u32>>().len() > 1;
        let track = |name: &str, shard: u32| {
            if multi_shard {
                format!("{name}/shard{shard}")
            } else {
                name.to_string()
            }
        };
        for s in samples {
            if let Some(p) = s.pool {
                out.push(counter_event(
                    &track("kv_pool_blocks", s.shard),
                    s.t_ns,
                    vec![
                        ("free", p.free_blocks as f64),
                        ("used", p.used_blocks as f64),
                        ("evictable", p.evictable_blocks as f64),
                    ],
                ));
                out.push(counter_event(
                    &track("prefix_cache_blocks", s.shard),
                    s.t_ns,
                    vec![("blocks", p.prefix_cached_blocks as f64)],
                ));
            }
            out.push(counter_event(
                &track("queue_depth", s.shard),
                s.t_ns,
                vec![
                    ("waiting", s.waiting as f64),
                    ("active", s.active as f64),
                    ("prefilling", s.prefilling as f64),
                    ("parked", s.parked as f64),
                ],
            ));
        }
    }

    Json::obj(vec![("traceEvents", Json::Arr(out)), ("displayTimeUnit", Json::str("ms"))])
}

fn prom_counter(out: &mut String, name: &str, help: &str, v: f64) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"));
}

fn prom_gauge(out: &mut String, name: &str, help: &str, v: f64) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"));
}

fn prom_summary(out: &mut String, name: &str, help: &str, q: &Quantiles) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} summary\n"));
    for (label, v) in [("0.5", q.p50), ("0.95", q.p95), ("0.99", q.p99)] {
        out.push_str(&format!("{name}{{quantile=\"{label}\"}} {v}\n"));
    }
    out.push_str(&format!("{name}_sum {}\n{name}_count {}\n", q.sum, q.count));
}

/// Native Prometheus histogram exposition: cumulative `_bucket{le=...}`
/// series per finite bound, the implicit `+Inf` bucket (= `_count`), and
/// `_sum`/`_count` — the type external scrapers can aggregate across
/// workers, unlike pre-computed quantile summaries.
fn prom_histogram(out: &mut String, name: &str, help: &str, h: &HistSnapshot) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
    for &(le, n) in &h.buckets {
        out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {n}\n"));
    }
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
    out.push_str(&format!("{name}_sum {}\n{name}_count {}\n", h.sum, h.count));
}

/// Render a metrics [`Snapshot`] in Prometheus text exposition format
/// (scrape-style consumption; write to a file or serve as-is).
pub fn prometheus_text(s: &Snapshot) -> String {
    let mut out = String::new();
    let counters: [(&str, &str, f64); 16] = [
        ("bda_requests_admitted_total", "Requests admitted", s.requests_admitted as f64),
        ("bda_requests_completed_total", "Requests completed", s.requests_completed as f64),
        ("bda_requests_rejected_total", "Requests rejected", s.requests_rejected as f64),
        ("bda_tokens_in_total", "Prompt tokens admitted", s.tokens_in as f64),
        ("bda_tokens_out_total", "Tokens generated", s.tokens_out as f64),
        ("bda_decode_steps_total", "Batched decode steps", s.decode_steps as f64),
        ("bda_preemptions_total", "Sequences preempted", s.preemptions as f64),
        ("bda_resumes_total", "Preempted sequences resumed", s.resumes as f64),
        ("bda_recomputed_tokens_total", "Tokens replayed on resume", s.recomputed_tokens as f64),
        ("bda_prefix_hits_total", "Prefix-cache lookup hits", s.prefix_hits as f64),
        ("bda_prefix_misses_total", "Prefix-cache lookup misses", s.prefix_misses as f64),
        ("bda_prefix_blocks_saved_total", "K/V blocks deduplicated", s.prefix_blocks_saved as f64),
        ("bda_goodput_tokens_total", "Tokens from SLO-met requests", s.goodput_tokens as f64),
        ("bda_slo_ttft_violations_total", "TTFT deadline violations", s.ttft_violations as f64),
        ("bda_slo_tbt_violations_total", "TBT budget violations", s.tbt_violations as f64),
        (
            "bda_trace_dropped_events_total",
            "Trace events lost to full span rings",
            s.trace_dropped_events as f64,
        ),
    ];
    for (name, help, v) in counters {
        prom_counter(&mut out, name, help, v);
    }
    prom_gauge(&mut out, "bda_tokens_per_sec", "Generation throughput", s.tokens_per_sec);
    prom_gauge(&mut out, "bda_decode_occupancy", "Mean decode-batch occupancy", s.decode_occupancy);
    prom_gauge(&mut out, "bda_mean_batch_size", "Mean formed batch size", s.mean_batch_size);
    prom_gauge(&mut out, "bda_goodput_tok_s", "Throughput from SLO-met requests", s.goodput_tok_s);
    prom_gauge(
        &mut out,
        "bda_slo_attainment",
        "Fraction of completed requests meeting their class SLO",
        s.slo_attainment(),
    );
    if !s.slo_by_class.is_empty() {
        out.push_str(
            "# HELP bda_slo_attainment_by_class Per-class SLO attainment\n\
             # TYPE bda_slo_attainment_by_class gauge\n",
        );
        for c in &s.slo_by_class {
            out.push_str(&format!(
                "bda_slo_attainment_by_class{{priority=\"{}\"}} {}\n",
                c.priority,
                c.attainment()
            ));
        }
    }
    if let Some(dtype) = s.kv_dtype {
        out.push_str(&format!(
            "# HELP bda_kv_pool_bytes Allocated K/V pool bytes\n\
             # TYPE bda_kv_pool_bytes gauge\n\
             bda_kv_pool_bytes{{dtype=\"{dtype}\"}} {}\n",
            s.kv_pool_bytes
        ));
    }
    let latency = Quantiles {
        p50: s.latency_p50,
        p95: s.latency_p95,
        p99: s.latency_p99,
        mean: s.latency_mean,
        count: s.requests_completed,
        sum: s.latency_mean * s.requests_completed as f64,
    };
    let ttft = Quantiles {
        p50: s.ttft_p50,
        p95: s.ttft_p95,
        p99: s.ttft_p99,
        mean: 0.0,
        count: s.requests_completed,
        sum: 0.0,
    };
    prom_summary(&mut out, "bda_request_latency_seconds", "End-to-end request latency", &latency);
    prom_summary(&mut out, "bda_ttft_seconds", "Time to first token", &ttft);
    prom_summary(&mut out, "bda_tbt_seconds", "Time between tokens", &s.tbt);
    prom_summary(&mut out, "bda_step_attn_seconds", "Per-step attention time", &s.step_attn);
    prom_summary(&mut out, "bda_step_gemm_seconds", "Per-step GEMM time", &s.step_gemm);
    prom_summary(&mut out, "bda_step_sample_seconds", "Per-step sampling time", &s.step_sample);
    // Native histogram exposition of the same distributions (cumulative
    // buckets aggregate across workers; the summaries above cannot).
    prom_histogram(&mut out, "bda_ttft_seconds_hist", "Time to first token", &s.ttft_hist);
    prom_histogram(&mut out, "bda_tbt_seconds_hist", "Time between tokens", &s.tbt_hist);
    prom_histogram(
        &mut out,
        "bda_step_attn_seconds_hist",
        "Per-step attention time",
        &s.step_attn_hist,
    );
    prom_histogram(&mut out, "bda_step_gemm_seconds_hist", "Per-step GEMM time", &s.step_gemm_hist);
    prom_histogram(
        &mut out,
        "bda_step_sample_seconds_hist",
        "Per-step sampling time",
        &s.step_sample_hist,
    );
    out
}

/// Per-lifecycle-phase event counts in a recorded stream — the CI trace
/// check asserts each expected phase appears at least once.
pub fn phase_counts(events: &[SpanEvent]) -> Vec<(&'static str, usize)> {
    super::Phase::ALL
        .iter()
        .map(|p| (p.name(), events.iter().filter(|e| e.phase == *p).count()))
        .collect()
}

/// Summarize per-sequence timelines for human output: sequence count and
/// total TBT samples derivable from the stream.
pub fn timeline_summary(events: &[SpanEvent]) -> (usize, usize) {
    let tls = timeline::timelines(events);
    let gaps = tls.iter().map(|t| t.tbt_secs().len()).sum();
    (tls.len(), gaps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Phase;

    fn ev(phase: Phase, id: u64, tid: u32, seqno: u64) -> SpanEvent {
        SpanEvent { seqno, phase, id, tid, start_ns: seqno * 1000, dur_ns: 500, shard: 0 }
    }

    #[test]
    fn chrome_trace_routes_tracks() {
        let events = vec![
            ev(Phase::Admit, 7, 1, 0),
            ev(Phase::Attn, 0, 2, 1),
            ev(Phase::Token, 7, 1, 2),
            ev(Phase::Complete, 7, 1, 3),
        ];
        let labels = vec![(1u32, "engine".to_string()), (2u32, "bda-pool-0".to_string())];
        let doc = chrome_trace(&events, &labels);
        let arr = doc.get("traceEvents").as_arr().expect("traceEvents array");
        // 2 process_name + 2 thread_name (workers) + 1 seq track + 4 events.
        assert_eq!(arr.len(), 9);
        let xs: Vec<&Json> = arr.iter().filter(|e| e.get("ph").as_str() == Some("X")).collect();
        assert_eq!(xs.len(), 4);
        // Lifecycle events land on pid 2 with tid = request id.
        let admit = xs.iter().find(|e| e.get("name").as_str() == Some("admit")).unwrap();
        assert_eq!(admit.get("pid").as_f64(), Some(2.0));
        assert_eq!(admit.get("tid").as_f64(), Some(7.0));
        // Thread-track events land on pid 1 with tid = thread id.
        let attn = xs.iter().find(|e| e.get("name").as_str() == Some("attn")).unwrap();
        assert_eq!(attn.get("pid").as_f64(), Some(1.0));
        assert_eq!(attn.get("tid").as_f64(), Some(2.0));
        // The serialized document round-trips through the JSON parser.
        let reparsed = Json::parse(&doc.to_string()).expect("valid JSON");
        assert_eq!(reparsed, doc);
    }

    #[test]
    fn chrome_trace_labels_unknown_threads() {
        let events = vec![ev(Phase::Work, 0, 9, 0)];
        let doc = chrome_trace(&events, &[]);
        let arr = doc.get("traceEvents").as_arr().unwrap();
        let named = arr.iter().any(|e| {
            e.get("ph").as_str() == Some("M")
                && e.get("args").get("name").as_str() == Some("thread-9")
        });
        assert!(named);
    }

    #[test]
    fn phase_counts_cover_all_phases() {
        let events = vec![ev(Phase::Token, 1, 1, 0), ev(Phase::Token, 1, 1, 1)];
        let counts = phase_counts(&events);
        assert_eq!(counts.len(), Phase::ALL.len());
        let token = counts.iter().find(|(n, _)| *n == "token").unwrap();
        assert_eq!(token.1, 2);
    }

    #[test]
    fn prometheus_text_is_well_formed() {
        let m = crate::coordinator::metrics::Metrics::new();
        m.admitted(4);
        m.tokens_generated(10);
        m.record_tbts(&[0.01, 0.02]);
        m.completed(0.5, 0.1);
        let text = prometheus_text(&m.snapshot());
        assert!(text.contains("bda_requests_admitted_total 4"));
        assert!(text.contains("bda_tokens_out_total 10"));
        assert!(text.contains("bda_tbt_seconds{quantile=\"0.99\"}"));
        assert!(text.contains("bda_tbt_seconds_count 2"));
        // Every line is a comment or `name{labels} value`.
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.split_whitespace().count() == 2 || line.is_empty(),
                "malformed line: {line}"
            );
        }
    }

    #[test]
    fn chrome_trace_full_emits_counter_tracks() {
        use crate::obs::sampler::PoolCounters;
        let events = vec![ev(Phase::Token, 1, 1, 0)];
        let samples = vec![
            ResourceSample {
                t_ns: 1000,
                pool: Some(PoolCounters {
                    free_blocks: 5,
                    used_blocks: 3,
                    evictable_blocks: 1,
                    prefix_cached_blocks: 1,
                }),
                waiting: 2,
                active: 3,
                prefilling: 1,
                parked: 0,
                shard: 0,
            },
            ResourceSample { t_ns: 2000, pool: None, waiting: 0, active: 4, ..Default::default() },
        ];
        let doc = chrome_trace_full(&events, &[], &samples);
        let arr = doc.get("traceEvents").as_arr().unwrap();
        let cs: Vec<&Json> = arr.iter().filter(|e| e.get("ph").as_str() == Some("C")).collect();
        // Pooled sample: kv_pool_blocks + prefix_cache_blocks + queue_depth;
        // pool-less sample: queue_depth only.
        assert_eq!(cs.len(), 4);
        let pool = cs.iter().find(|e| e.get("name").as_str() == Some("kv_pool_blocks")).unwrap();
        assert_eq!(pool.get("pid").as_f64(), Some(3.0));
        assert_eq!(pool.get("args").get("free").as_f64(), Some(5.0));
        assert_eq!(pool.get("args").get("evictable").as_f64(), Some(1.0));
        assert_eq!(pool.get("ts").as_f64(), Some(1.0), "1000 ns = 1 µs");
        let q: Vec<&&Json> =
            cs.iter().filter(|e| e.get("name").as_str() == Some("queue_depth")).collect();
        assert_eq!(q.len(), 2);
        assert_eq!(q[0].get("args").get("waiting").as_f64(), Some(2.0));
        assert_eq!(q[1].get("args").get("parked").as_f64(), Some(0.0));
        assert!(arr.iter().any(|e| e.get("ph").as_str() == Some("M")
            && e.get("args").get("name").as_str() == Some("bda counters")));
        let reparsed = Json::parse(&doc.to_string()).expect("valid JSON");
        assert_eq!(reparsed, doc);
        // With no samples the document is identical to chrome_trace.
        assert_eq!(chrome_trace_full(&events, &[], &[]), chrome_trace(&events, &[]));
    }

    #[test]
    fn prometheus_exports_native_histograms_and_slo_metrics() {
        use crate::coordinator::request::{RequestClass, Response};
        let m = crate::coordinator::metrics::Metrics::new();
        m.record_tbts(&[0.01, 0.02]);
        m.completed(0.5, 0.1);
        let class = RequestClass { priority: 1, ttft_deadline: 1.0, tbt_budget: 0.25 };
        let resp = |ttft: f64, tokens: Vec<u32>| Response {
            id: 1,
            tokens,
            ttft,
            latency: 0.5,
            prompt_len: 2,
            class,
            max_tbt: 0.01,
        };
        m.slo_scored(&resp(0.1, vec![1, 2, 3]));
        m.slo_scored(&resp(5.0, vec![4]));
        let text = prometheus_text(&m.snapshot());
        assert!(text.contains("# TYPE bda_tbt_seconds_hist histogram"));
        assert!(text.contains("bda_tbt_seconds_hist_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("bda_tbt_seconds_hist_count 2"));
        assert!(text.contains("bda_ttft_seconds_hist_count 1"));
        assert!(text.contains("bda_goodput_tokens_total 3"));
        assert!(text.contains("bda_slo_ttft_violations_total 1"));
        assert!(text.contains("bda_slo_attainment 0.5"));
        assert!(text.contains("bda_slo_attainment_by_class{priority=\"1\"} 0.5"));
        assert!(text.contains("bda_trace_dropped_events_total"));
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.split_whitespace().count() == 2 || line.is_empty(),
                "malformed line: {line}"
            );
        }
    }

    #[test]
    fn lifecycle_events_carry_shard_and_multi_shard_counters_split() {
        use crate::obs::sampler::PoolCounters;
        let mut admit = ev(Phase::Admit, 7, 1, 0);
        admit.shard = 2;
        let attn = ev(Phase::Attn, 0, 1, 1); // thread-track: no shard arg
        let doc = chrome_trace(&[admit, attn], &[]);
        let arr = doc.get("traceEvents").as_arr().unwrap();
        let admit_ev =
            arr.iter().find(|e| e.get("name").as_str() == Some("admit")).expect("admit event");
        assert_eq!(admit_ev.get("args").get("shard").as_f64(), Some(2.0));
        let attn_ev = arr.iter().find(|e| e.get("name").as_str() == Some("attn")).unwrap();
        assert!(attn_ev.get("args").get("shard").as_f64().is_none());

        // Samples from two shards split the counter tracks per shard.
        let sample = |shard: u32| ResourceSample {
            t_ns: 1000,
            pool: Some(PoolCounters { free_blocks: 1, ..Default::default() }),
            shard,
            ..Default::default()
        };
        let doc = chrome_trace_full(&[], &[], &[sample(0), sample(1)]);
        let arr = doc.get("traceEvents").as_arr().unwrap();
        let names: Vec<&str> = arr
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("C"))
            .filter_map(|e| e.get("name").as_str())
            .collect();
        for n in [
            "kv_pool_blocks/shard0",
            "kv_pool_blocks/shard1",
            "queue_depth/shard0",
            "queue_depth/shard1",
        ] {
            assert!(names.contains(&n), "missing counter track {n}: {names:?}");
        }
        // A single-shard run keeps the legacy unsuffixed names.
        let doc = chrome_trace_full(&[], &[], &[sample(1), sample(1)]);
        let arr = doc.get("traceEvents").as_arr().unwrap();
        assert!(arr
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("C"))
            .all(|e| !e.get("name").as_str().unwrap().contains("/shard")));
    }

    #[test]
    fn timeline_summary_counts() {
        let events = vec![
            ev(Phase::Token, 1, 1, 0),
            ev(Phase::Token, 1, 1, 1),
            ev(Phase::Token, 2, 1, 2),
        ];
        assert_eq!(timeline_summary(&events), (2, 1));
    }
}
