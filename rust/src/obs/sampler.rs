//! Continuous resource sampler: pool occupancy, prefix-cache residency,
//! and queue depths captured once per scheduler step into a bounded
//! buffer, exported as Perfetto **counter tracks** (`"ph":"C"`) alongside
//! the span tracks and as Prometheus gauges.
//!
//! Sampling is pull-at-step-boundary, not a thread: the scheduler calls
//! [`record`] just before its step-boundary `obs::flush()`, **only when
//! tracing is enabled**, so a disabled trace pays nothing and an enabled
//! one observes — never steers — the token stream (the bitwise pin of
//! `tests/prop_slo.rs`). The buffer drops new samples past its cap and
//! counts the drops rather than growing without bound.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// KV-pool occupancy of a pool-owning backend, in blocks. Reported by
/// [`crate::coordinator::scheduler::Backend::pool_counters`]; `free` and
/// `evictable` overlap deliberately — evictable prefix-cache blocks are
/// counted free for admission but still hold reusable K/V.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolCounters {
    /// Blocks admission can claim right now (unused + evictable).
    pub free_blocks: usize,
    /// Blocks pinned by live sequences.
    pub used_blocks: usize,
    /// Blocks held only by the prefix cache (reclaimable via eviction).
    pub evictable_blocks: usize,
    /// Blocks resident in the radix-tree prefix cache.
    pub prefix_cached_blocks: usize,
}

/// One step-boundary resource sample. Times share the span epoch so
/// counter tracks line up with span tracks in the same trace.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ResourceSample {
    /// Sample time, nanoseconds since the trace epoch.
    pub t_ns: u64,
    /// Pool occupancy, when the backend owns real block storage.
    pub pool: Option<PoolCounters>,
    /// Requests waiting in the server's admission queue (the most recent
    /// depth the server noted via [`note_queue_depth`]).
    pub waiting: usize,
    /// Sequences decoding this step.
    pub active: usize,
    /// Sequences mid-chunked-prefill.
    pub prefilling: usize,
    /// Preempted sequences parked for resume.
    pub parked: usize,
    /// Engine shard the sample describes (0 in single-worker serving;
    /// stamped from the recording thread's [`crate::obs::set_shard`] id).
    /// Exporters split counter tracks per shard when more than one
    /// appears.
    pub shard: u32,
}

/// Cap on buffered samples; one sample per scheduler step means this
/// absorbs tens of thousands of steps between exports.
const SAMPLE_CAP: usize = 1 << 16;

static SAMPLES: Mutex<Vec<ResourceSample>> = Mutex::new(Vec::new());
static DROPPED: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Last waiting-queue depth noted on this thread. Thread-local, not
    /// global: each sharded worker notes its *own* queue's depth just
    /// before stepping, so concurrent workers don't clobber each other's
    /// gauge between note and sample (note and record run on the same
    /// worker thread).
    static QUEUE_DEPTH: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

/// Note the calling worker's current admission-queue depth; this
/// thread's next [`record`] stamps it into the sample. Callers gate on
/// `obs::enabled()` to keep the disabled path at zero work.
pub fn note_queue_depth(n: usize) {
    QUEUE_DEPTH.with(|c| c.set(n));
}

/// Capture one resource sample at a step boundary. Callers gate on
/// [`crate::obs::enabled`] (the scheduler does); the sample clock shares
/// the span epoch so exported counter tracks align with span tracks.
pub fn record(pool: Option<PoolCounters>, active: usize, prefilling: usize, parked: usize) {
    let epoch = super::recorder::ensure_epoch();
    let sample = ResourceSample {
        t_ns: Instant::now().saturating_duration_since(epoch).as_nanos() as u64,
        pool,
        waiting: QUEUE_DEPTH.with(|c| c.get()),
        active,
        prefilling,
        parked,
        shard: super::recorder::current_shard(),
    };
    let mut buf = SAMPLES.lock().unwrap();
    if buf.len() < SAMPLE_CAP {
        buf.push(sample);
    } else {
        DROPPED.fetch_add(1, Ordering::Relaxed);
    }
}

/// Take ownership of every buffered sample (the buffer is left empty),
/// in record order.
pub fn take_samples() -> Vec<ResourceSample> {
    std::mem::take(&mut *SAMPLES.lock().unwrap())
}

/// Samples lost to the buffer cap since process start.
pub fn dropped_total() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test owns the whole global-buffer lifecycle — the buffer is
    // process-wide and lib tests run concurrently. (The gate itself is
    // never flipped here; `record` is below the gate by design.)
    #[test]
    fn record_take_roundtrip_with_queue_depth() {
        note_queue_depth(7);
        let pool = PoolCounters {
            free_blocks: 10,
            used_blocks: 6,
            evictable_blocks: 2,
            prefix_cached_blocks: 2,
        };
        record(Some(pool), 3, 1, 2);
        record(None, 4, 0, 0);
        let samples = take_samples();
        assert!(samples.len() >= 2, "both samples buffered");
        let ours: Vec<&ResourceSample> =
            samples.iter().filter(|s| s.waiting == 7 && s.active >= 3).collect();
        assert!(ours.len() >= 2);
        let with_pool = ours.iter().find(|s| s.pool.is_some()).expect("pooled sample");
        assert_eq!(with_pool.pool.unwrap(), pool);
        assert_eq!(with_pool.prefilling, 1);
        assert_eq!(with_pool.parked, 2);
        assert!(samples.windows(2).all(|w| w[0].t_ns <= w[1].t_ns), "monotone sample times");
        assert!(take_samples().is_empty(), "take drains the buffer");
        assert_eq!(dropped_total(), 0);
    }
}
