//! Per-sequence token timelines derived from the recorded event stream.
//!
//! Lifecycle events (phase `is_lifecycle()`) are keyed by request id;
//! grouping them and ordering by time yields one timeline per sequence:
//! admission, prefill, every generated token, preempt/park/resume, and
//! completion. The gaps between consecutive `Token` instants are the
//! time-between-tokens (TBT) samples — note that a gap spanning a
//! preemption includes the parked time, which is exactly what a waiting
//! client observes.

use super::recorder::SpanEvent;
use super::Phase;
use std::collections::BTreeMap;

/// All lifecycle events of one request, ordered by start time.
#[derive(Clone, Debug)]
pub struct SeqTimeline {
    /// Request id.
    pub id: u64,
    pub events: Vec<SpanEvent>,
}

impl SeqTimeline {
    /// Start times (ns since epoch) of the generated tokens, in order.
    pub fn token_times_ns(&self) -> Vec<u64> {
        self.events.iter().filter(|e| e.phase == Phase::Token).map(|e| e.start_ns).collect()
    }

    /// Time-between-tokens samples in seconds: gaps between consecutive
    /// `Token` instants. Empty for sequences with fewer than two tokens.
    pub fn tbt_secs(&self) -> Vec<f64> {
        let t = self.token_times_ns();
        t.windows(2).map(|w| (w[1] - w[0]) as f64 * 1e-9).collect()
    }

    /// Whether this sequence was preempted at least once.
    pub fn preempted(&self) -> bool {
        self.events.iter().any(|e| e.phase == Phase::Preempt)
    }
}

/// Group the lifecycle events of a recorded stream into per-sequence
/// timelines, ordered by request id; events within a timeline are ordered
/// by start time (ties broken by seqno, which preserves producer order).
pub fn timelines(events: &[SpanEvent]) -> Vec<SeqTimeline> {
    let mut by_id: BTreeMap<u64, Vec<SpanEvent>> = BTreeMap::new();
    for e in events {
        if e.phase.is_lifecycle() {
            by_id.entry(e.id).or_default().push(*e);
        }
    }
    by_id
        .into_iter()
        .map(|(id, mut events)| {
            events.sort_by_key(|e| (e.start_ns, e.seqno));
            SeqTimeline { id, events }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(phase: Phase, id: u64, start_ns: u64, seqno: u64) -> SpanEvent {
        SpanEvent { seqno, phase, id, tid: 1, start_ns, dur_ns: 0 }
    }

    #[test]
    fn groups_by_request_and_orders_by_time() {
        let events = vec![
            ev(Phase::Token, 2, 300, 4),
            ev(Phase::Admit, 1, 100, 0),
            ev(Phase::Token, 1, 200, 2),
            ev(Phase::Admit, 2, 150, 1),
            ev(Phase::Attn, 9, 0, 3), // thread-track: excluded
            ev(Phase::Token, 1, 250, 5),
        ];
        let tl = timelines(&events);
        assert_eq!(tl.len(), 2);
        assert_eq!(tl[0].id, 1);
        assert_eq!(tl[0].events.len(), 3);
        assert!(tl[0].events.windows(2).all(|w| w[0].start_ns <= w[1].start_ns));
        assert_eq!(tl[1].id, 2);
    }

    #[test]
    fn tbt_is_token_gaps() {
        let events = vec![
            ev(Phase::Token, 7, 1_000_000_000, 0),
            ev(Phase::Token, 7, 1_500_000_000, 1),
            ev(Phase::Token, 7, 1_750_000_000, 2),
            ev(Phase::Complete, 7, 1_750_000_100, 3),
        ];
        let tl = timelines(&events);
        let tbt = tl[0].tbt_secs();
        assert_eq!(tbt.len(), 2);
        assert!((tbt[0] - 0.5).abs() < 1e-9);
        assert!((tbt[1] - 0.25).abs() < 1e-9);
    }

    #[test]
    fn single_token_has_no_tbt() {
        let events = vec![ev(Phase::Token, 1, 10, 0)];
        assert!(timelines(&events)[0].tbt_secs().is_empty());
    }

    #[test]
    fn preemption_detection() {
        let with = vec![ev(Phase::Preempt, 1, 10, 0)];
        let without = vec![ev(Phase::Token, 1, 10, 0)];
        assert!(timelines(&with)[0].preempted());
        assert!(!timelines(&without)[0].preempted());
    }
}
