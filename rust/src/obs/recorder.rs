//! Lock-free per-thread span recorders and the step-boundary drain.
//!
//! Each recording thread owns one single-producer/single-consumer ring
//! buffer. The producer side is wait-free (a full ring drops the event and
//! bumps a counter instead of blocking); the consumer side is whoever
//! holds the global registry lock — [`flush`] is called by the scheduler
//! at step boundaries, so exactly one consumer drains at a time.
//!
//! Events carry a global sequence number taken with one relaxed
//! `fetch_add`, which makes the merged stream totally ordered even though
//! rings drain independently: exporters sort by `seqno` and per-thread
//! order is preserved because each producer's seqnos are monotone.

use super::Phase;
use std::cell::{OnceCell, UnsafeCell};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// One recorded span or instant event. Plain `Copy` data: times are
/// nanoseconds relative to the process-wide trace epoch, `dur_ns == 0`
/// marks an instant event, and `seqno` totally orders the merged stream.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SpanEvent {
    /// Global sequence number (allocation order across all threads).
    pub seqno: u64,
    pub phase: Phase,
    /// Request id for lifecycle phases; free-form argument otherwise.
    pub id: u64,
    /// Recording thread (registration order, starting at 1).
    pub tid: u32,
    /// Start time, nanoseconds since the trace epoch.
    pub start_ns: u64,
    /// Duration in nanoseconds; 0 for instant events.
    pub dur_ns: u64,
    /// Engine shard that recorded the event (0 in single-worker serving;
    /// worker threads stamp theirs via [`set_shard`]). Lets exporters tag
    /// lifecycle spans with their placement without a side table.
    pub shard: u32,
}

/// Ring capacity per thread. 4096 events absorbs well over one scheduler
/// step of per-layer spans before a step-boundary drain.
const RING_CAP: usize = 4096;

/// Cap on events held between drains and export; beyond this, new events
/// are counted as dropped rather than growing without bound.
const COLLECT_CAP: usize = 1 << 20;

/// SPSC ring buffer of [`SpanEvent`]s with monotone head/tail indices.
///
/// The owning thread is the only producer ([`Ring::push`]); the only
/// consumer is the holder of the registry lock ([`Ring::drain`]). Slots in
/// `[tail, head)` are readable by the consumer while the producer writes
/// only into `[head, tail + cap)` — disjoint ranges, synchronized by the
/// Release store of `head` (publish) and of `tail` (free).
pub(crate) struct Ring {
    buf: Box<[UnsafeCell<SpanEvent>]>,
    /// Next write index (monotone; slot = index & mask). Producer-owned.
    head: AtomicUsize,
    /// Next read index (monotone). Consumer-owned.
    tail: AtomicUsize,
    dropped: AtomicU64,
}

// SAFETY: the SPSC protocol above keeps producer and consumer on disjoint
// slots; `UnsafeCell` accesses never alias across the head/tail fences.
unsafe impl Sync for Ring {}
unsafe impl Send for Ring {}

impl Ring {
    pub(crate) fn new(cap: usize) -> Self {
        assert!(cap.is_power_of_two());
        let buf: Vec<UnsafeCell<SpanEvent>> =
            (0..cap).map(|_| UnsafeCell::new(SpanEvent::default())).collect();
        Ring {
            buf: buf.into_boxed_slice(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Producer side: record one event, or count a drop if the ring is
    /// full. Must only be called from the ring's owning thread.
    pub(crate) fn push(&self, ev: SpanEvent) {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        if head.wrapping_sub(tail) >= self.buf.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let slot = head & (self.buf.len() - 1);
        // SAFETY: `slot` is outside [tail, head), so no concurrent reader.
        unsafe { *self.buf[slot].get() = ev };
        self.head.store(head.wrapping_add(1), Ordering::Release);
    }

    /// Consumer side: pop every published event, oldest first. Callers
    /// must hold the registry lock (single-consumer requirement).
    pub(crate) fn drain(&self, mut f: impl FnMut(SpanEvent)) -> usize {
        let head = self.head.load(Ordering::Acquire);
        let mut tail = self.tail.load(Ordering::Relaxed);
        let n = head.wrapping_sub(tail);
        for _ in 0..n {
            let slot = tail & (self.buf.len() - 1);
            // SAFETY: `slot` is in [tail, head), published by the Release
            // store of `head`; the producer will not touch it until the
            // Release store of `tail` below frees it.
            f(unsafe { *self.buf[slot].get() });
            tail = tail.wrapping_add(1);
        }
        self.tail.store(tail, Ordering::Release);
        n
    }

    fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

struct Entry {
    tid: u32,
    label: String,
    ring: Arc<Ring>,
}

static REGISTRY: Mutex<Vec<Entry>> = Mutex::new(Vec::new());
static COLLECTED: Mutex<Vec<SpanEvent>> = Mutex::new(Vec::new());
static SEQNO: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU32 = AtomicU32::new(1);
/// Events lost because [`COLLECT_CAP`] was reached.
static OVERFLOW: AtomicU64 = AtomicU64::new(0);
/// Latched true the first time tracing is enabled; lets [`flush`] stay a
/// single relaxed load in never-traced processes (no registry lock).
static EVER_ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();

thread_local! {
    static LOCAL: OnceCell<(u32, Arc<Ring>)> = const { OnceCell::new() };
    /// Shard id stamped into this thread's events and samples. Worker
    /// threads set it once at spawn; everything else records shard 0.
    static CURRENT_SHARD: std::cell::Cell<u32> = const { std::cell::Cell::new(0) };
}

/// Declare that the calling thread records on behalf of engine shard
/// `shard`: every subsequent [`SpanEvent`] and resource sample from this
/// thread carries the id. Called once by each sharded worker at spawn
/// (and per routed step by the synchronous replay path).
pub fn set_shard(shard: u32) {
    CURRENT_SHARD.with(|c| c.set(shard));
}

/// The calling thread's shard id (0 unless [`set_shard`] was called).
pub(crate) fn current_shard() -> u32 {
    CURRENT_SHARD.with(|c| c.get())
}

/// Fix the trace epoch (idempotent). Called when tracing is first
/// enabled so `start_ns` values are small and consistent.
pub(crate) fn ensure_epoch() -> Instant {
    EVER_ENABLED.store(true, Ordering::Relaxed);
    *EPOCH.get_or_init(Instant::now)
}

fn with_local<R>(f: impl FnOnce(u32, &Ring) -> R) -> R {
    LOCAL.with(|cell| {
        let (tid, ring) = cell.get_or_init(|| {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let ring = Arc::new(Ring::new(RING_CAP));
            let label = std::thread::current()
                .name()
                .map(str::to_string)
                .unwrap_or_else(|| format!("thread-{tid}"));
            REGISTRY.lock().unwrap().push(Entry { tid, label, ring: Arc::clone(&ring) });
            (tid, ring)
        });
        f(*tid, ring)
    })
}

/// Record one event on the calling thread's ring. Only reached when the
/// enable gate is up (see `obs::span_at` / `obs::instant`).
pub(crate) fn record(phase: Phase, id: u64, start: Instant, dur: Duration) {
    let epoch = ensure_epoch();
    // `start` can predate the epoch (e.g. a request's arrival timestamp
    // taken before tracing was switched on): clamp to 0 rather than panic.
    let start_ns = start.saturating_duration_since(epoch).as_nanos() as u64;
    let ev = SpanEvent {
        seqno: SEQNO.fetch_add(1, Ordering::Relaxed),
        phase,
        id,
        tid: 0, // filled in below
        start_ns,
        dur_ns: dur.as_nanos() as u64,
        shard: current_shard(),
    };
    with_local(|tid, ring| ring.push(SpanEvent { tid, ..ev }));
}

/// Name the calling thread's track in exported traces (e.g.
/// `bda-pool-3`). Registers the thread's ring if it has none yet.
pub fn set_thread_label(label: &str) {
    let tid = with_local(|tid, _| tid);
    let mut reg = REGISTRY.lock().unwrap();
    if let Some(e) = reg.iter_mut().find(|e| e.tid == tid) {
        e.label = label.to_string();
    }
}

/// Drain every registered ring into the global collection buffer.
/// Called by the scheduler at step boundaries; returns events drained.
/// A handful of relaxed loads when tracing has never been enabled.
pub fn flush() -> usize {
    if !EVER_ENABLED.load(Ordering::Relaxed) {
        return 0;
    }
    let reg = REGISTRY.lock().unwrap();
    let mut out = COLLECTED.lock().unwrap();
    let mut n = 0;
    for e in reg.iter() {
        n += e.ring.drain(|ev| {
            if out.len() < COLLECT_CAP {
                out.push(ev);
            } else {
                OVERFLOW.fetch_add(1, Ordering::Relaxed);
            }
        });
    }
    n
}

/// Flush, then take ownership of everything collected so far (the
/// collection buffer is left empty). Exporters sort by `seqno`.
pub fn take_collected() -> Vec<SpanEvent> {
    flush();
    std::mem::take(&mut *COLLECTED.lock().unwrap())
}

/// Total events lost to full rings or the collection cap.
pub fn dropped_total() -> u64 {
    let rings: u64 = REGISTRY.lock().unwrap().iter().map(|e| e.ring.dropped()).sum();
    rings + OVERFLOW.load(Ordering::Relaxed)
}

/// `(tid, label)` for every thread that has recorded at least one event
/// (or explicitly labeled itself), in registration order.
pub fn thread_labels() -> Vec<(u32, String)> {
    REGISTRY.lock().unwrap().iter().map(|e| (e.tid, e.label.clone())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(seqno: u64) -> SpanEvent {
        SpanEvent { seqno, phase: Phase::Work, id: 0, tid: 1, start_ns: seqno, dur_ns: 1, shard: 0 }
    }

    #[test]
    fn ring_preserves_fifo_order() {
        let r = Ring::new(8);
        for i in 0..5 {
            r.push(ev(i));
        }
        let mut got = Vec::new();
        let n = r.drain(|e| got.push(e.seqno));
        assert_eq!(n, 5);
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn ring_full_drops_and_counts() {
        let r = Ring::new(4);
        for i in 0..7 {
            r.push(ev(i));
        }
        assert_eq!(r.dropped(), 3);
        let mut got = Vec::new();
        r.drain(|e| got.push(e.seqno));
        // The oldest four survive; overflowing events are dropped, not
        // overwritten (drop-new keeps drained batches contiguous).
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn ring_wraps_across_drains() {
        let r = Ring::new(4);
        let mut next = 0u64;
        let mut seen = Vec::new();
        for _ in 0..5 {
            for _ in 0..3 {
                r.push(ev(next));
                next += 1;
            }
            r.drain(|e| seen.push(e.seqno));
        }
        assert_eq!(seen, (0..15).collect::<Vec<_>>());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn ring_drain_empty_is_zero() {
        let r = Ring::new(4);
        assert_eq!(r.drain(|_| panic!("no events expected")), 0);
    }

    // Cross-thread drain ordering under concurrent producers is covered
    // by `tests/prop_trace.rs` (needs the global gate, which lib tests
    // must not flip — they share one process).
}
