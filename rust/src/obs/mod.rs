//! Structured tracing: low-overhead span/event recording for the serving
//! engine, with Chrome-trace (Perfetto) and Prometheus export.
//!
//! # Design
//!
//! Every instrumentation site funnels through three entry points —
//! [`span_at`], [`instant`], and [`event_at`] — each of which begins with a
//! single relaxed atomic load of the global enable gate. When tracing is
//! disabled (the default) that load-and-branch is the *entire* cost: no
//! clock reads, no allocation, no locks, and therefore no perturbation of
//! the decode path (engine invariants 1–5 are untouched; a property test
//! pins decode output bitwise identical with tracing on vs off).
//!
//! When enabled, events go into a per-thread lock-free SPSC ring buffer
//! ([`recorder`]). Each event carries a global sequence number (one relaxed
//! `fetch_add`), a thread id, and nanosecond start/duration relative to a
//! process-wide epoch. The scheduler drains all rings at step boundaries
//! via [`flush`]; a full ring drops new events and counts the drops rather
//! than blocking the producer.
//!
//! # Span taxonomy
//!
//! Phases split into two track families (see [`Phase::is_lifecycle`]):
//!
//! * **Lifecycle** phases (`Enqueue`, `Admit`, `Prefill`, `PrefillChunk`,
//!   `Token`, `Preempt`, `Park`, `Resume`, `Complete`) describe one
//!   request; their
//!   `id` is the request id and the exporter places them on a per-sequence
//!   track. The per-sequence `Token` instants form the token timeline from
//!   which time-between-tokens (TBT) is derived ([`timeline`]).
//! * **Thread-track** phases (`DecodeStep`, `Attn`, `Gemm`, `Sample`,
//!   `PrefixLookup`, `PrefixAdopt`, `PrefixEvict`, `Work`) describe work on
//!   a thread; the exporter places them on a per-thread track keyed by the
//!   recording thread's id, with `id` as a free-form argument (sequence id,
//!   block count, …).
//!
//! # Knobs
//!
//! * `BDA_TRACE` — `1`/`true`/`on` enables recording process-wide;
//!   [`set_enabled`] overrides programmatically (used by `--trace-out`).
//! * `BDA_QUIET` — suppresses the one-shot informational stderr lines
//!   (e.g. the thread-pool size announcement) routed through [`announce`].

pub mod export;
pub mod recorder;
pub mod sampler;
pub mod timeline;

pub use recorder::{
    dropped_total, flush, set_shard, set_thread_label, take_collected, thread_labels, SpanEvent,
};
pub use sampler::{PoolCounters, ResourceSample};

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{Duration, Instant};

/// What a span or instant event describes. Discriminants are stable and
/// `ALL` enumerates every variant (used by exporters and CI validation).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Phase {
    // -- lifecycle (per-request tracks; `id` = request id) ---------------
    /// Queue wait: request arrival until the scheduler begins admission.
    Enqueue,
    /// Admission: sequence registration + prefill + first-token sample.
    Admit,
    /// The backend prefill call within admission (or within resume replay).
    /// Under chunked prefill this is the aggregate span from reservation to
    /// the final chunk; the individual fused chunks are `PrefillChunk`.
    Prefill,
    /// One prompt chunk fused into a batched decode step (chunked
    /// prefill); Perfetto timelines show these interleaving with tokens.
    PrefillChunk,
    /// One generated token (instant); gaps between these are the TBT.
    Token,
    /// The scheduler evicted this sequence mid-decode (instant).
    Preempt,
    /// Time spent parked off-pool between preemption and resume.
    Park,
    /// Recompute-on-resume replay prefill for a preempted sequence.
    Resume,
    /// Terminal event: the finished response left the scheduler (instant).
    Complete,
    // -- thread-track (per-thread tracks; `id` = contextual argument) ----
    /// One batched decode step over all active sequences (`id` = batch).
    DecodeStep,
    /// Paged-attention portion of a decode layer (`id` = layer).
    Attn,
    /// GEMM portion of a decode layer or the logit projection (`id` = layer).
    Gemm,
    /// Token sampling for one sequence (`id` = request id).
    Sample,
    /// Radix-tree prefix-cache lookup (`id` = prompt length in tokens).
    PrefixLookup,
    /// Cached-prefix adoption during prefill (`id` = adopted block count).
    PrefixAdopt,
    /// LRU eviction of cached blocks (`id` = blocks evicted).
    PrefixEvict,
    /// A thread-pool worker executing one parallel job (`id` = dispatch
    /// epoch, shared by every worker participating in that region).
    #[default]
    Work,
}

impl Phase {
    /// Every phase, in declaration order.
    pub const ALL: [Phase; 17] = [
        Phase::Enqueue,
        Phase::Admit,
        Phase::Prefill,
        Phase::PrefillChunk,
        Phase::Token,
        Phase::Preempt,
        Phase::Park,
        Phase::Resume,
        Phase::Complete,
        Phase::DecodeStep,
        Phase::Attn,
        Phase::Gemm,
        Phase::Sample,
        Phase::PrefixLookup,
        Phase::PrefixAdopt,
        Phase::PrefixEvict,
        Phase::Work,
    ];

    /// Stable lowercase name, used as the Chrome-trace event name.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Enqueue => "enqueue",
            Phase::Admit => "admit",
            Phase::Prefill => "prefill",
            Phase::PrefillChunk => "prefill_chunk",
            Phase::Token => "token",
            Phase::Preempt => "preempt",
            Phase::Park => "park",
            Phase::Resume => "resume",
            Phase::Complete => "complete",
            Phase::DecodeStep => "decode_step",
            Phase::Attn => "attn",
            Phase::Gemm => "gemm",
            Phase::Sample => "sample",
            Phase::PrefixLookup => "prefix_lookup",
            Phase::PrefixAdopt => "prefix_adopt",
            Phase::PrefixEvict => "prefix_evict",
            Phase::Work => "work",
        }
    }

    /// Lifecycle phases land on per-sequence tracks (keyed by request id);
    /// the rest land on per-thread tracks (keyed by recording thread).
    pub fn is_lifecycle(self) -> bool {
        matches!(
            self,
            Phase::Enqueue
                | Phase::Admit
                | Phase::Prefill
                | Phase::PrefillChunk
                | Phase::Token
                | Phase::Preempt
                | Phase::Park
                | Phase::Resume
                | Phase::Complete
        )
    }
}

/// Tri-state enable gate: 0 = uninitialized (consult `BDA_TRACE` on first
/// query), 1 = disabled, 2 = enabled. A single relaxed load answers the
/// hot-path question after first use.
static STATE: AtomicU8 = AtomicU8::new(0);

/// Is tracing enabled? First call latches `BDA_TRACE` from the
/// environment; [`set_enabled`] overrides at any time.
#[inline]
pub fn enabled() -> bool {
    match STATE.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => init_from_env(),
    }
}

#[cold]
fn init_from_env() -> bool {
    let on = std::env::var("BDA_TRACE")
        .map(|v| {
            let v = v.to_ascii_lowercase();
            matches!(v.as_str(), "1" | "true" | "on" | "yes")
        })
        .unwrap_or(false);
    // Racing initializers agree (both read the same env), so a plain
    // store is fine; a later set_enabled still wins.
    STATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
    if on {
        recorder::ensure_epoch();
    }
    on
}

/// Force tracing on or off, overriding `BDA_TRACE`. Used by `--trace-out`
/// and by the bitwise-equivalence property tests.
pub fn set_enabled(on: bool) {
    if on {
        recorder::ensure_epoch();
    }
    STATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
}

/// Record a completed span that started at `start` and ran for `dur`.
///
/// Callers pass `Instant`s they already hold for metrics timing, so an
/// enabled trace adds no extra clock reads on the decode path; disabled,
/// this is one relaxed load and a branch.
#[inline]
pub fn span_at(phase: Phase, id: u64, start: Instant, dur: Duration) {
    if enabled() {
        recorder::record(phase, id, start, dur);
    }
}

/// Record an instant (zero-duration) event happening now.
#[inline]
pub fn instant(phase: Phase, id: u64) {
    if enabled() {
        recorder::record(phase, id, Instant::now(), Duration::ZERO);
    }
}

/// Record an instant (zero-duration) event at a caller-supplied time.
#[inline]
pub fn event_at(phase: Phase, id: u64, at: Instant) {
    if enabled() {
        recorder::record(phase, id, at, Duration::ZERO);
    }
}

/// One-shot informational message channel with a quiet knob.
///
/// Library components that previously wrote unconditionally to stderr
/// (e.g. the thread pool's resolved-worker-count line) route through here
/// instead: `BDA_QUIET=1` (or `true`/`on`/`yes`) suppresses the output.
pub fn announce(msg: &str) {
    if !quiet() {
        eprintln!("{msg}");
    }
}

/// Whether `BDA_QUIET` asks informational stderr lines to be suppressed.
pub fn quiet() -> bool {
    std::env::var("BDA_QUIET")
        .map(|v| {
            let v = v.to_ascii_lowercase();
            matches!(v.as_str(), "1" | "true" | "on" | "yes")
        })
        .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_all_covers_every_name_once() {
        let mut names: Vec<&str> = Phase::ALL.iter().map(|p| p.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Phase::ALL.len());
    }

    #[test]
    fn lifecycle_split_is_exhaustive() {
        let lifecycle = Phase::ALL.iter().filter(|p| p.is_lifecycle()).count();
        assert_eq!(lifecycle, 9);
        assert_eq!(Phase::ALL.len() - lifecycle, 8);
    }

    // NOTE: no test here flips the global enable gate — the lib test
    // binary runs tests concurrently and the gate is process-wide. The
    // enabled-path behavior is exercised by `tests/prop_trace.rs`, which
    // serializes access in its own process.
}
