//! Radix-tree prefix cache: automatic cross-request K/V prompt sharing.
//!
//! The engine's `fork`/copy-on-write machinery (PR 1–3) dedups K/V when a
//! caller *explicitly* forks a sequence. This module makes the sharing
//! automatic: a radix tree over prompt token sequences whose nodes own
//! ref-counted block-table fragments in the live
//! [`crate::engine::PagedKvPool`]. On admission the engine matches an
//! incoming prompt against the tree at **block granularity**, adopts the
//! longest cached prefix into the new sequence (zero-copy table adoption,
//! copy-on-write on divergence — exactly a fork from the tree), and
//! prefills only the uncovered tail. On release a sequence's full-block
//! prefix is inserted into the tree (ref-bumped via
//! [`BlockAllocator::hold_blocks`]) instead of freed, with LRU eviction of
//! zero-ref leaves when pool pressure demands — the eviction machinery the
//! scheduler-preemption roadmap item builds on.
//!
//! # Why a cache hit is bitwise-lossless
//!
//! Causal attention makes the K/V row of position `t` a function of tokens
//! `0..=t` only, and every operator on that path (GEMM rows, RMSNorm,
//! paged attention) is row-deterministic. Two requests sharing a token
//! prefix therefore produce **bit-identical** prefix K/V, so adopting the
//! cached rows and prefilling only the tail yields logits bit-identical to
//! a cold full prefill — for MHA and BDA alike (BDA's losslessness, §3.4,
//! keeps the cache attention-variant-agnostic). This is invariant 4 of
//! [`crate::engine`], property-tested in `tests/prop_paged_parallel.rs`.
//!
//! # Structure
//!
//! Each node owns an *edge*: one or more whole blocks of tokens
//! (`tokens.len() == blocks.len() * block_size`) plus the pool blocks
//! holding their K/V. Children of a node differ in their first block's
//! token content. Insertion splits a node at a block boundary when a new
//! sequence diverges mid-edge; matching walks block-by-block and never
//! returns a partial block (a hit must leave ≥ 1 tail token so the tail
//! prefill produces the last-position logits).
//!
//! Safety is ref-count-based, not policy-based: the tree holds its blocks
//! through [`BlockAllocator::hold_blocks`], active sequences hold theirs
//! through their tables, and eviction only ever drops the *tree's* hold —
//! a block shared with a live sequence survives eviction (the allocator
//! frees blocks only at ref zero). "Zero-ref leaf" below means a leaf
//! whose blocks are referenced by the tree alone (`ref_count == 1`).

use crate::coordinator::kv_cache::{BlockAllocator, BlockId};
use crate::obs::{self, Phase};
use std::time::Instant;

/// Index of the root sentinel node (empty edge, never evicted).
const ROOT: usize = 0;

/// Cumulative prefix-cache counters (monotonic; diff two snapshots for a
/// per-step delta).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// Prompt lookups performed (one per engine prefill while enabled).
    pub lookups: u64,
    /// Lookups that matched at least one cached block.
    pub hits: u64,
    /// Prompt blocks adopted from the tree instead of being re-prefilled.
    pub blocks_saved: u64,
    /// Blocks inserted into the tree by releasing sequences.
    pub inserted_blocks: u64,
    /// Subset of `inserted_blocks` donated by *preempted* sequences (their
    /// committed full-block prefix moves into the tree so resume gets a
    /// warm start instead of a full recompute).
    pub donated_blocks: u64,
    /// Blocks returned to the pool by LRU eviction.
    pub evicted_blocks: u64,
}

impl PrefixStats {
    /// Lookups that matched nothing.
    pub fn misses(&self) -> u64 {
        self.lookups - self.hits
    }

    /// Hit fraction over all lookups (0.0 when none ran).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }
}

#[derive(Debug)]
struct Node {
    /// Token content of this edge; always `blocks.len() * block_size` long
    /// (empty only for the root sentinel).
    tokens: Vec<u32>,
    /// Pool blocks holding the K/V rows for `tokens`, in order. The tree
    /// holds one allocator hold per block.
    blocks: Vec<BlockId>,
    children: Vec<usize>,
    parent: usize,
    /// LRU tick of the last lookup/insert that touched this node.
    last_used: u64,
}

/// Radix tree over prompt token sequences, nodes owning ref-counted block
/// fragments in the paged K/V pool. See the module docs for semantics.
#[derive(Debug)]
pub struct PrefixCache {
    block_size: usize,
    /// Slab of nodes; `None` marks a freed slot. Slot [`ROOT`] is the
    /// sentinel and always live.
    nodes: Vec<Option<Node>>,
    free_slots: Vec<usize>,
    tick: u64,
    stats: PrefixStats,
}

impl PrefixCache {
    pub fn new(block_size: usize) -> PrefixCache {
        assert!(block_size > 0, "prefix cache needs a positive block size");
        PrefixCache {
            block_size,
            nodes: vec![Some(Node {
                tokens: Vec::new(),
                blocks: Vec::new(),
                children: Vec::new(),
                parent: ROOT,
                last_used: 0,
            })],
            free_slots: Vec::new(),
            tick: 0,
            stats: PrefixStats::default(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Cumulative counters since construction.
    pub fn stats(&self) -> PrefixStats {
        self.stats
    }

    fn node(&self, id: usize) -> &Node {
        self.nodes[id].as_ref().expect("live node")
    }

    fn node_mut(&mut self, id: usize) -> &mut Node {
        self.nodes[id].as_mut().expect("live node")
    }

    fn new_node(&mut self, parent: usize, tokens: Vec<u32>, blocks: Vec<BlockId>) -> usize {
        debug_assert_eq!(tokens.len(), blocks.len() * self.block_size);
        debug_assert!(!blocks.is_empty());
        let node = Node { tokens, blocks, children: Vec::new(), parent, last_used: self.tick };
        match self.free_slots.pop() {
            Some(slot) => {
                self.nodes[slot] = Some(node);
                slot
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        }
    }

    /// Live nodes excluding the root (the tree's size, for tests/reports).
    pub fn node_count(&self) -> usize {
        self.nodes.iter().flatten().count() - 1
    }

    /// Blocks currently held by the tree.
    pub fn held_blocks(&self) -> usize {
        self.nodes.iter().flatten().map(|n| n.blocks.len()).sum()
    }

    /// Child of `node` whose edge starts with the block-sized token run at
    /// `want` (children are distinguished by their first block).
    fn child_matching(&self, node: usize, want: &[u32]) -> Option<usize> {
        self.node(node)
            .children
            .iter()
            .copied()
            .find(|&c| self.node(c).tokens[..self.block_size] == *want)
    }

    /// Longest cached whole-block prefix of `prompt`, capped so at least
    /// one prompt token is left uncovered. Returns the matched blocks in
    /// order (empty on a miss); the caller adopts them into a new
    /// sequence's table via [`BlockAllocator::register_with_prefix`].
    /// Touches every matched node's LRU stamp. Counters are **not**
    /// updated here — call [`PrefixCache::record_admission`] once the
    /// sequence is actually registered, so retried admissions don't
    /// inflate hit statistics.
    pub fn lookup(&mut self, prompt: &[u32]) -> Vec<BlockId> {
        let lookup_start = obs::enabled().then(Instant::now);
        let matched = self.lookup_inner(prompt);
        if let Some(t) = lookup_start {
            obs::span_at(Phase::PrefixLookup, prompt.len() as u64, t, t.elapsed());
        }
        matched
    }

    fn lookup_inner(&mut self, prompt: &[u32]) -> Vec<BlockId> {
        let bs = self.block_size;
        let max_blocks = prompt.len().saturating_sub(1) / bs;
        self.tick += 1;
        let tick = self.tick;
        let mut matched: Vec<BlockId> = Vec::new();
        let mut node = ROOT;
        'walk: while matched.len() < max_blocks {
            let pos = matched.len() * bs;
            let Some(child) = self.child_matching(node, &prompt[pos..pos + bs]) else {
                break;
            };
            self.node_mut(child).last_used = tick;
            let edge_blocks = self.node(child).blocks.len();
            for b in 0..edge_blocks {
                if matched.len() == max_blocks {
                    break 'walk;
                }
                let lo = matched.len() * bs;
                if self.node(child).tokens[b * bs..(b + 1) * bs] == prompt[lo..lo + bs] {
                    matched.push(self.node(child).blocks[b]);
                } else {
                    break 'walk;
                }
            }
            node = child;
        }
        matched
    }

    /// Read-only probe: how many whole blocks of `prompt` the tree holds,
    /// with exactly [`PrefixCache::lookup`]'s matching semantics (walks
    /// partial edges, capped so ≥ 1 tail token stays uncovered) but **no
    /// side effects** — no LRU touch, no tick bump, no counters, no holds.
    /// The router calls this against every shard per admission to place a
    /// request on the shard with its longest cached prefix; a probe that
    /// perturbed LRU order would let routing traffic evict-shield stale
    /// leaves the engine itself never re-used.
    pub fn peek_prefix_blocks(&self, prompt: &[u32]) -> usize {
        let bs = self.block_size;
        let max_blocks = prompt.len().saturating_sub(1) / bs;
        let mut matched = 0usize;
        let mut node = ROOT;
        'walk: while matched < max_blocks {
            let pos = matched * bs;
            let Some(child) = self.child_matching(node, &prompt[pos..pos + bs]) else {
                break;
            };
            let edge_blocks = self.node(child).blocks.len();
            for b in 0..edge_blocks {
                if matched == max_blocks {
                    break 'walk;
                }
                let lo = matched * bs;
                if self.node(child).tokens[b * bs..(b + 1) * bs] == prompt[lo..lo + bs] {
                    matched += 1;
                } else {
                    break 'walk;
                }
            }
            node = child;
        }
        matched
    }

    /// Record one served admission that adopted `adopted_blocks` cached
    /// blocks (0 = miss). Kept separate from [`PrefixCache::lookup`] so
    /// the engine counts each request once, after its registration
    /// succeeded — an admission requeued on pool pressure and retried
    /// later contributes a single lookup, not one per attempt.
    pub fn record_admission(&mut self, adopted_blocks: usize) {
        self.stats.lookups += 1;
        if adopted_blocks > 0 {
            self.stats.hits += 1;
            self.stats.blocks_saved += adopted_blocks as u64;
        }
    }

    /// Insert a released sequence's whole-block prefix: `tokens` must be a
    /// multiple of the block size and `blocks` its backing pool blocks
    /// (`blocks.len() * block_size == tokens.len()`). Ranges the tree
    /// already covers (by token content) are deduplicated — the existing
    /// nodes keep their blocks and the duplicates stay with the releasing
    /// sequence (freed by its table release). Only the uncovered tail
    /// becomes a new node, whose blocks get an allocator hold so they
    /// outlive the sequence.
    pub fn insert(&mut self, tokens: &[u32], blocks: &[BlockId], alloc: &mut BlockAllocator) {
        let bs = self.block_size;
        assert_eq!(tokens.len(), blocks.len() * bs, "insert needs whole blocks");
        let total = blocks.len();
        if total == 0 {
            return;
        }
        self.tick += 1;
        let tick = self.tick;
        let mut covered = 0usize;
        let mut node = ROOT;
        while covered < total {
            let pos = covered * bs;
            let Some(child) = self.child_matching(node, &tokens[pos..pos + bs]) else {
                // No child shares the next block: everything remaining
                // becomes one new leaf under `node`.
                self.attach(node, &tokens[pos..], &blocks[covered..], alloc);
                return;
            };
            self.node_mut(child).last_used = tick;
            let edge_blocks = self.node(child).blocks.len();
            let mut m = 0;
            while m < edge_blocks
                && covered + m < total
                && self.node(child).tokens[m * bs..(m + 1) * bs]
                    == tokens[(covered + m) * bs..(covered + m + 1) * bs]
            {
                m += 1;
            }
            covered += m;
            if m == edge_blocks {
                node = child;
                continue;
            }
            if covered == total {
                // The input is a prefix of this edge: fully covered, no
                // split needed (lookups match partial edges fine).
                return;
            }
            // Divergence mid-edge: split the edge at block boundary `m`
            // and attach the remainder as a new sibling leaf.
            self.split(child, m);
            self.attach(child, &tokens[covered * bs..], &blocks[covered..], alloc);
            return;
        }
    }

    /// [`PrefixCache::insert`] for a *preempted* sequence: identical tree
    /// semantics (dedup, splitting, holds), but the blocks that actually
    /// enter the tree are additionally counted as `donated_blocks`. The
    /// donation keeps the victim's committed K/V reachable — evictable
    /// under pressure like any zero-ref leaf, but a warm start for the
    /// resume's replay prefill when the pool recovers first.
    pub fn donate(&mut self, tokens: &[u32], blocks: &[BlockId], alloc: &mut BlockAllocator) {
        let before = self.stats.inserted_blocks;
        self.insert(tokens, blocks, alloc);
        self.stats.donated_blocks += self.stats.inserted_blocks - before;
    }

    /// Create a leaf under `parent` holding `blocks`, taking allocator
    /// holds so the blocks survive the owning sequence's release.
    fn attach(
        &mut self,
        parent: usize,
        tokens: &[u32],
        blocks: &[BlockId],
        alloc: &mut BlockAllocator,
    ) {
        alloc.hold_blocks(blocks);
        self.stats.inserted_blocks += blocks.len() as u64;
        let id = self.new_node(parent, tokens.to_vec(), blocks.to_vec());
        self.node_mut(parent).children.push(id);
    }

    /// Split `node`'s edge after `at_blocks` blocks: `node` keeps the
    /// front, a new child takes the back (and inherits `node`'s children).
    fn split(&mut self, node: usize, at_blocks: usize) {
        let bs = self.block_size;
        debug_assert!(at_blocks > 0 && at_blocks < self.node(node).blocks.len());
        let n = self.node_mut(node);
        let back_tokens = n.tokens.split_off(at_blocks * bs);
        let back_blocks = n.blocks.split_off(at_blocks);
        let back_children = std::mem::take(&mut n.children);
        let back = self.new_node(node, back_tokens, back_blocks);
        self.node_mut(back).children = back_children;
        for c in self.node(back).children.clone() {
            self.node_mut(c).parent = back;
        }
        self.node_mut(node).children.push(back);
    }

    /// Is this block's *only* reference the tree's own hold? Exactly then
    /// does dropping the hold return it to the pool: a second reference —
    /// a sequence table, or an extra hold pinning the block for an
    /// in-flight admission — means eviction would reclaim nothing (or,
    /// for a hold-less table-only ref, corrupt live state).
    fn sole_tree_ref(alloc: &BlockAllocator, b: BlockId) -> bool {
        alloc.ref_count(b) == 1 && alloc.hold_count(b) == 1
    }

    /// Evict the least-recently-used zero-ref leaf — a leaf whose blocks
    /// are referenced by the tree's hold alone (see `sole_tree_ref`), so
    /// dropping the hold returns exactly those blocks to the pool.
    /// Returns the number of blocks freed (0 when nothing is evictable).
    /// Repeated calls cascade: evicting a leaf can turn its parent into
    /// the next evictable leaf.
    pub fn evict_lru(&mut self, alloc: &mut BlockAllocator) -> usize {
        let mut victim: Option<(usize, u64)> = None;
        for (id, slot) in self.nodes.iter().enumerate().skip(1) {
            let Some(n) = slot.as_ref() else { continue };
            if !n.children.is_empty() {
                continue;
            }
            if !n.blocks.iter().all(|&b| Self::sole_tree_ref(alloc, b)) {
                continue; // shared with a live sequence or pinned: not zero-ref
            }
            let older = match victim {
                None => true,
                Some((_, last_used)) => n.last_used < last_used,
            };
            if older {
                victim = Some((id, n.last_used));
            }
        }
        let Some((id, _)) = victim else { return 0 };
        let node = self.nodes[id].take().expect("victim is live");
        self.free_slots.push(id);
        let parent = self.node_mut(node.parent);
        parent.children.retain(|&c| c != id);
        alloc.release_held(&node.blocks);
        self.stats.evicted_blocks += node.blocks.len() as u64;
        obs::instant(Phase::PrefixEvict, node.blocks.len() as u64);
        node.blocks.len()
    }

    /// Blocks eviction could reclaim right now: the total over maximal
    /// subtrees in which every node's blocks carry the tree's hold and
    /// nothing else (see `sole_tree_ref`). Admission counts
    /// these as free — cached-but-unpinned K/V is reclaimable capacity,
    /// not occupancy. Leaves pinned by an extra hold (an admission in
    /// flight adopting them) are **not** counted: they cannot actually be
    /// reclaimed until the hold drops, and counting them would overstate
    /// capacity to the scheduler.
    ///
    /// Cost: one tree walk with an O(1) ref-count probe per held block,
    /// so O(held blocks) ≤ O(pool size) per call — cheap next to the
    /// prefill each admission check gates, but called per queued request
    /// per scheduler tick. If that ever shows up in profiles, the fix is
    /// an incrementally maintained counter invalidated on
    /// insert/evict/adopt/release transitions.
    pub fn evictable_blocks(&self, alloc: &BlockAllocator) -> usize {
        self.evictable_walk(ROOT, alloc).0
    }

    /// Post-order walk returning `(evictable_count, subtree_fully_evictable)`.
    /// A node's own blocks count only if every descendant is fully
    /// evictable (leaf-first eviction can only reach it then).
    fn evictable_walk(&self, id: usize, alloc: &BlockAllocator) -> (usize, bool) {
        let n = self.node(id);
        let mut sum = 0;
        let mut all = true;
        for &c in &n.children {
            let (s, f) = self.evictable_walk(c, alloc);
            sum += s;
            all &= f;
        }
        if id != ROOT && all && n.blocks.iter().all(|&b| Self::sole_tree_ref(alloc, b)) {
            (sum + n.blocks.len(), true)
        } else {
            (sum, false)
        }
    }

    /// Drop every hold and empty the tree (used when the cache is turned
    /// off on a live engine).
    pub fn clear(&mut self, alloc: &mut BlockAllocator) {
        let mut evicted = 0u64;
        for slot in self.nodes.iter_mut().skip(1) {
            if let Some(n) = slot.take() {
                alloc.release_held(&n.blocks);
                evicted += n.blocks.len() as u64;
            }
        }
        self.stats.evicted_blocks += evicted;
        self.nodes.truncate(1);
        self.free_slots.clear();
        self.node_mut(ROOT).children.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv_cache::{KvCacheConfig, SeqId};

    const BS: usize = 4;

    fn alloc(blocks: usize) -> BlockAllocator {
        BlockAllocator::new(KvCacheConfig { block_size: BS, num_blocks: blocks, ..Default::default() })
    }

    /// Register `seq` for `tokens`, then release it into the tree the way
    /// the engine does: insert the full-block prefix, drop the table.
    fn serve_and_release(
        cache: &mut PrefixCache,
        a: &mut BlockAllocator,
        seq: SeqId,
        tokens: &[u32],
    ) -> Vec<BlockId> {
        a.register(seq, tokens.len()).unwrap();
        let blocks = a.seq_blocks(seq).unwrap().to_vec();
        let full = tokens.len() / BS * BS;
        cache.insert(&tokens[..full], &blocks[..full / BS], a);
        a.release(seq).unwrap();
        a.check_invariants().unwrap();
        blocks
    }

    fn toks(seed: u32, n: usize) -> Vec<u32> {
        (0..n as u32).map(|i| seed * 100 + i).collect()
    }

    #[test]
    fn lookup_hits_longest_cached_prefix() {
        let mut c = PrefixCache::new(BS);
        let mut a = alloc(32);
        let t = toks(1, 12); // 3 full blocks
        let blocks = serve_and_release(&mut c, &mut a, 1, &t);
        assert_eq!(c.held_blocks(), 3);
        assert_eq!(a.used_blocks(), 3, "tree keeps the prefix alive");

        // Identical prompt + tail: all 3 blocks hit.
        let mut p = t.clone();
        p.extend([777, 778]);
        let m = c.lookup(&p);
        assert_eq!(m, blocks[..3].to_vec());
        c.record_admission(m.len());

        // Prompt equal to the cached tokens: capped at (len-1)/bs blocks so
        // one tail token is always left to prefill.
        let m = c.lookup(&t);
        assert_eq!(m.len(), 2);
        c.record_admission(m.len());

        // Diverging in the second block: only the first block hits.
        let mut q = t.clone();
        q[5] = 999;
        let m = c.lookup(&q);
        assert_eq!(m, blocks[..1].to_vec());
        c.record_admission(m.len());

        // Diverging in the first block: miss. Lookups retried without a
        // recorded admission (requeued requests) don't count.
        assert!(c.lookup(&toks(9, 12)).is_empty());
        assert!(c.lookup(&toks(9, 12)).is_empty());
        c.record_admission(0);
        let s = c.stats();
        assert_eq!((s.lookups, s.hits), (4, 3));
        assert_eq!(s.blocks_saved, 3 + 2 + 1);
    }

    #[test]
    fn insert_dedups_and_splits_on_divergence() {
        let mut c = PrefixCache::new(BS);
        let mut a = alloc(32);
        let t1 = toks(1, 12);
        serve_and_release(&mut c, &mut a, 1, &t1);
        assert_eq!(c.node_count(), 1);

        // Same content from a different sequence: deduplicated, nothing new
        // held, the duplicate blocks free with the releasing table.
        let used = a.used_blocks();
        serve_and_release(&mut c, &mut a, 2, &t1);
        assert_eq!(c.node_count(), 1);
        assert_eq!(c.held_blocks(), 3);
        assert_eq!(a.used_blocks(), used);

        // Shared first block, divergent second: the 3-block edge splits at
        // block 1 and the new branch hangs off the front half.
        let mut t2 = toks(1, 12);
        t2[6] = 555;
        serve_and_release(&mut c, &mut a, 3, &t2);
        assert_eq!(c.node_count(), 3, "front + back + new branch");
        assert_eq!(c.held_blocks(), 5);

        // Both variants still hit fully.
        let mut p1 = t1.clone();
        p1.push(0);
        let mut p2 = t2.clone();
        p2.push(0);
        assert_eq!(c.lookup(&p1).len(), 3);
        assert_eq!(c.lookup(&p2).len(), 3);
        a.check_invariants().unwrap();
    }

    #[test]
    fn referenced_blocks_never_freed_and_eviction_frees_exactly_unshared() {
        // The satellite regression: (a) blocks held by the tree are never
        // returned to the pool while referenced, (b) evicting a zero-ref
        // leaf frees exactly its unshared blocks.
        let mut c = PrefixCache::new(BS);
        let mut a = alloc(16);
        let t = toks(3, 8); // 2 full blocks
        serve_and_release(&mut c, &mut a, 1, &t);
        assert_eq!(a.used_blocks(), 2);

        // A hit sequence adopts the cached blocks: the leaf is no longer
        // zero-ref, so eviction must refuse to touch it.
        let hit = c.lookup(&[&t[..], &[42]].concat());
        assert_eq!(hit.len(), 2);
        a.register_with_prefix(7, &hit, 9).unwrap();
        assert_eq!(c.evict_lru(&mut a), 0, "shared leaf must not be evicted");
        assert_eq!(c.evictable_blocks(&a), 0);
        a.check_invariants().unwrap();

        // Extend the tree under the shared node with the hit sequence's
        // private continuation, then release it.
        let mut hist = t.clone();
        hist.extend([42, 43, 44, 45]); // 9th..12th tokens -> 3rd full block
        let blocks = a.seq_blocks(7).unwrap().to_vec();
        c.insert(&hist, &blocks[..3], &mut a);
        a.release(7).unwrap();
        a.check_invariants().unwrap();
        assert_eq!(c.held_blocks(), 3);
        assert_eq!(a.used_blocks(), 3);

        // Everything is zero-ref now. Evicting the LRU leaf frees exactly
        // the leaf's single unshared block; the shared parent survives
        // until a second eviction cascades to it.
        assert_eq!(c.evictable_blocks(&a), 3);
        assert_eq!(c.evict_lru(&mut a), 1, "leaf owns exactly one block");
        assert_eq!(a.used_blocks(), 2);
        assert_eq!(c.evict_lru(&mut a), 2, "parent becomes the next leaf");
        assert_eq!(a.used_blocks(), 0);
        assert_eq!(c.evict_lru(&mut a), 0, "empty tree has nothing to evict");
        a.check_invariants().unwrap();
        assert_eq!(c.stats().evicted_blocks, 3);
    }

    #[test]
    fn hold_pinned_leaves_are_not_counted_evictable() {
        // The admission-in-flight regression: a temporary hold on a
        // matched leaf (the engine pins the hit blocks between lookup and
        // registration) must remove the leaf from both eviction and the
        // evictable-capacity count the scheduler admits against.
        let mut c = PrefixCache::new(BS);
        let mut a = alloc(16);
        let t = toks(4, 8); // 2 full blocks
        let blocks = serve_and_release(&mut c, &mut a, 1, &t);
        assert_eq!(c.evictable_blocks(&a), 2);

        a.hold_blocks(&blocks[..2]); // in-flight admission pins the leaf
        assert_eq!(c.evictable_blocks(&a), 0, "pinned leaf is not reclaimable");
        assert_eq!(c.evict_lru(&mut a), 0, "pinned leaf must not be evicted");
        a.release_held(&blocks[..2]);
        assert_eq!(c.evictable_blocks(&a), 2, "dropping the pin restores evictability");
        assert_eq!(c.evict_lru(&mut a), 2);
        a.check_invariants().unwrap();
    }

    #[test]
    fn donate_counts_only_fresh_blocks() {
        let mut c = PrefixCache::new(BS);
        let mut a = alloc(16);
        let t = toks(5, 12); // 3 full blocks
        serve_and_release(&mut c, &mut a, 1, &t);
        assert_eq!(c.stats().donated_blocks, 0, "plain release is not a donation");

        // A preempted sequence donates the same content extended by one
        // block: only the uncovered tail counts as donated.
        let mut hist = t.clone();
        hist.extend(900..904);
        a.register(2, hist.len()).unwrap();
        let blocks = a.seq_blocks(2).unwrap().to_vec();
        c.donate(&hist, &blocks, &mut a);
        a.release(2).unwrap();
        let s = c.stats();
        assert_eq!(s.donated_blocks, 1, "3 of 4 donated blocks dedup against the tree");
        assert_eq!(s.inserted_blocks, 4);
        a.check_invariants().unwrap();

        // The donated prefix is a warm start: a resume replay hits it.
        let mut p = hist.clone();
        p.push(42);
        assert_eq!(c.lookup(&p).len(), 4);
    }

    #[test]
    fn eviction_is_lru_ordered() {
        let mut c = PrefixCache::new(BS);
        let mut a = alloc(16);
        serve_and_release(&mut c, &mut a, 1, &toks(1, 4));
        serve_and_release(&mut c, &mut a, 2, &toks(2, 4));
        // Touch branch 1 so branch 2 becomes the LRU.
        let one_hit = c.lookup(&[&toks(1, 4)[..], &[9]].concat());
        assert_eq!(one_hit.len(), 1);
        c.evict_lru(&mut a);
        assert!(c.lookup(&[&toks(1, 4)[..], &[9]].concat()).len() == 1, "MRU branch survives");
        assert!(c.lookup(&[&toks(2, 4)[..], &[9]].concat()).is_empty(), "LRU branch evicted");
    }

    #[test]
    fn clear_releases_every_hold() {
        let mut c = PrefixCache::new(BS);
        let mut a = alloc(16);
        serve_and_release(&mut c, &mut a, 1, &toks(1, 8));
        serve_and_release(&mut c, &mut a, 2, &toks(2, 12));
        assert!(a.used_blocks() > 0);
        c.clear(&mut a);
        assert_eq!(a.used_blocks(), 0);
        assert_eq!(c.node_count(), 0);
        assert_eq!(c.held_blocks(), 0);
        a.check_invariants().unwrap();
    }

    #[test]
    fn peek_matches_lookup_without_side_effects() {
        let mut c = PrefixCache::new(BS);
        let mut a = alloc(32);
        let t = toks(1, 12); // 3 full blocks in one edge
        serve_and_release(&mut c, &mut a, 1, &t);
        let stats_before = c.stats();

        // Empty-tree cold path first, on a fresh cache.
        let cold = PrefixCache::new(BS);
        assert_eq!(cold.peek_prefix_blocks(&toks(1, 16)), 0);
        assert_eq!(cold.peek_prefix_blocks(&[]), 0);

        // Full hit with a tail, and the (len-1)/bs cap on an exact prompt.
        let mut p = t.clone();
        p.extend([777, 778]);
        assert_eq!(c.peek_prefix_blocks(&p), 3);
        assert_eq!(c.peek_prefix_blocks(&t), 2, "≥1 tail token stays uncovered");

        // Mid-edge partial match: diverging inside block 2 of the 3-block
        // edge matches exactly the first two blocks of that edge.
        let mut q = t.clone();
        q[9] = 999;
        assert_eq!(c.peek_prefix_blocks(&q), 2);
        // Diverging inside the first block: clean miss.
        assert_eq!(c.peek_prefix_blocks(&toks(9, 12)), 0);
        // Sub-block prompts can never match (no whole block fits under the cap).
        assert_eq!(c.peek_prefix_blocks(&t[..BS]), 0);

        // No side effects: stats untouched, and the probe agrees with a
        // subsequent real lookup.
        assert_eq!(c.stats(), stats_before);
        assert_eq!(c.lookup(&p).len(), 3);
    }

    #[test]
    fn peek_descends_across_split_edges() {
        let mut c = PrefixCache::new(BS);
        let mut a = alloc(32);
        let t1 = toks(1, 12);
        let mut t2 = toks(1, 12);
        t2[6] = 555; // shared first block, divergent second → edge split
        serve_and_release(&mut c, &mut a, 1, &t1);
        serve_and_release(&mut c, &mut a, 2, &t2);
        assert_eq!(c.node_count(), 3, "front + back + new branch");

        let mut p1 = t1.clone();
        p1.push(0);
        let mut p2 = t2.clone();
        p2.push(0);
        assert_eq!(c.peek_prefix_blocks(&p1), 3, "walks front edge then back child");
        assert_eq!(c.peek_prefix_blocks(&p2), 3, "walks front edge then branch child");

        // Shared block only: stops at the split point.
        let mut q = toks(1, 12);
        q[4] = 111;
        assert_eq!(c.peek_prefix_blocks(&q), 1);
    }

    #[test]
    fn peek_does_not_perturb_lru_order() {
        let mut c = PrefixCache::new(BS);
        let mut a = alloc(16);
        serve_and_release(&mut c, &mut a, 1, &toks(1, 4));
        serve_and_release(&mut c, &mut a, 2, &toks(2, 4));
        // A real lookup touching branch 1 would shield it from eviction;
        // the probe must not. Branch 1 stays LRU and is evicted first.
        assert_eq!(c.peek_prefix_blocks(&[&toks(1, 4)[..], &[9]].concat()), 1);
        c.evict_lru(&mut a);
        assert!(c.lookup(&[&toks(1, 4)[..], &[9]].concat()).is_empty(), "probed branch evicted");
        assert_eq!(c.lookup(&[&toks(2, 4)[..], &[9]].concat()).len(), 1, "other branch survives");
        a.check_invariants().unwrap();
    }

    #[test]
    fn short_prompts_are_uncacheable() {
        let mut c = PrefixCache::new(BS);
        let mut a = alloc(8);
        // 3 tokens < block size: nothing inserted, lookups miss.
        serve_and_release(&mut c, &mut a, 1, &toks(1, 3));
        assert_eq!(c.node_count(), 0);
        assert!(c.lookup(&toks(1, 3)).is_empty());
        assert_eq!(a.used_blocks(), 0);
    }
}
