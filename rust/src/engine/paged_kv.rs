//! Paged K/V storage pool: the *real* memory behind the block allocator's
//! bookkeeping.
//!
//! Per layer, one contiguous K tensor and one contiguous V tensor of
//! `num_blocks * block_size` rows, indexed by `BlockId` — the layout
//! vLLM-style paged attention gathers from. Layer widths may differ (the
//! pruning baseline keeps fewer channels), so each layer sizes its own
//! buffers. Blocks are plain storage here; ownership, ref counts, and
//! copy-on-write *decisions* live in
//! [`crate::coordinator::kv_cache::BlockAllocator`] — this pool only
//! executes the resulting writes and block copies.
//!
//! Storage dtype ([`KvCacheConfig::dtype`]): `F32` keeps rows as raw
//! `f32`; `F16`/`BF16` store real 16-bit words — half the resident bytes,
//! double the batch capacity at fixed memory — narrowed at write time and
//! widened back to f32 at the kernel boundary. Because widening a 16-bit
//! word is exact, quantize-at-write is the complete semantics: a 16-bit
//! pool behaves bit-for-bit like an f32 pool whose `write_row` inputs pass
//! through [`DType::quantize_slice`] (engine invariant 7). Block copies
//! move stored words verbatim in either representation, so COW forks and
//! prefix-cache donation/readoption never re-round.

use crate::attention::paged::{KvSlice, PagedLayerView};
use crate::coordinator::kv_cache::{BlockId, KvCacheConfig};
use crate::tensor::DType;

/// One layer's K or V storage in its resident representation.
#[derive(Debug)]
enum KvStore {
    F32(Vec<f32>),
    U16(Vec<u16>),
}

impl KvStore {
    fn alloc(dtype: DType, len: usize) -> KvStore {
        match dtype {
            DType::F32 => KvStore::F32(vec![0.0; len]),
            DType::F16 | DType::BF16 => KvStore::U16(vec![0; len]),
        }
    }

    fn len(&self) -> usize {
        match self {
            KvStore::F32(d) => d.len(),
            KvStore::U16(d) => d.len(),
        }
    }

    /// Actual allocated bytes of the backing buffer's elements.
    fn bytes(&self) -> usize {
        match self {
            KvStore::F32(d) => d.len() * std::mem::size_of::<f32>(),
            KvStore::U16(d) => d.len() * std::mem::size_of::<u16>(),
        }
    }

    fn write(&mut self, dtype: DType, quantize: Option<DType>, base: usize, row: &[f32]) {
        match self {
            KvStore::F32(d) => {
                let dst = &mut d[base..base + row.len()];
                dst.copy_from_slice(row);
                if let Some(q) = quantize {
                    q.quantize_slice(dst);
                }
            }
            KvStore::U16(d) => {
                let narrow = dtype.narrow_f32();
                for (dst, &x) in d[base..base + row.len()].iter_mut().zip(row) {
                    *dst = narrow(x);
                }
            }
        }
    }

    /// Copy `n` stored words from `src..src+n` to `dst..` verbatim — no
    /// widening/re-narrowing round trip, so copies are bit-stable at any
    /// dtype (COW invariant 3 extends to 16-bit storage by construction).
    fn copy_within(&mut self, src: usize, dst: usize, n: usize) {
        match self {
            KvStore::F32(d) => d.copy_within(src..src + n, dst),
            KvStore::U16(d) => d.copy_within(src..src + n, dst),
        }
    }

    fn slice(&self, dtype: DType) -> KvSlice<'_> {
        match self {
            KvStore::F32(d) => KvSlice::F32(d),
            KvStore::U16(d) => KvSlice::U16 { bits: d, dtype },
        }
    }
}

#[derive(Debug)]
struct LayerPool {
    k: KvStore,
    v: KvStore,
    width: usize,
}

/// Block-granular K/V storage for every layer of a model.
#[derive(Debug)]
pub struct PagedKvPool {
    pub config: KvCacheConfig,
    layers: Vec<LayerPool>,
    /// Test-facing reference mode for engine invariant 7: when set on an
    /// `F32`-storage pool, every `write_row` is passed through
    /// [`DType::quantize_slice`] at this dtype before landing. A 16-bit
    /// pool at dtype `d` must generate bitwise identically to an f32 pool
    /// with `write_quantize = Some(d)` — that equivalence is what
    /// `tests/prop_kv_dtype.rs` pins. Ignored on 16-bit storage (the
    /// narrowing write already *is* the quantization).
    write_quantize: Option<DType>,
}

impl PagedKvPool {
    /// Allocate a pool with one (K, V) buffer pair per layer, `widths[i]`
    /// values per token row in layer `i`, stored at `config.dtype`.
    pub fn new(config: KvCacheConfig, widths: &[usize]) -> PagedKvPool {
        let rows = config.num_blocks * config.block_size;
        let layers = widths
            .iter()
            .map(|&w| LayerPool {
                k: KvStore::alloc(config.dtype, rows * w),
                v: KvStore::alloc(config.dtype, rows * w),
                width: w,
            })
            .collect();
        PagedKvPool { config, layers, write_quantize: None }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn width(&self, layer: usize) -> usize {
        self.layers[layer].width
    }

    /// Storage dtype of block data.
    pub fn dtype(&self) -> DType {
        self.config.dtype
    }

    /// Total *actually allocated* pool bytes (capacity, not occupancy):
    /// element count × resident element size. A 16-bit pool reports half
    /// an f32 pool's bytes for the same shape.
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.bytes() + l.v.bytes()).sum()
    }

    /// Enable quantize-at-write reference mode (invariant-7 test harness;
    /// see the `write_quantize` field). Only meaningful on `F32` storage.
    pub fn set_write_quantize(&mut self, dtype: DType) {
        debug_assert_eq!(self.config.dtype, DType::F32, "reference mode needs f32 storage");
        self.write_quantize = Some(dtype);
    }

    /// Write one token's K/V row into `(block, slot)` of a layer,
    /// narrowing to the storage dtype (16-bit pools) or applying the
    /// optional quantize-at-write reference (f32 pools).
    pub fn write_row(
        &mut self,
        layer: usize,
        block: BlockId,
        slot: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        debug_assert!(slot < self.config.block_size);
        let dtype = self.config.dtype;
        let quantize = self.write_quantize;
        let l = &mut self.layers[layer];
        debug_assert_eq!(k_row.len(), l.width);
        debug_assert_eq!(v_row.len(), l.width);
        let base = (block * self.config.block_size + slot) * l.width;
        l.k.write(dtype, quantize, base, k_row);
        l.v.write(dtype, quantize, base, v_row);
    }

    /// Copy a whole block's K/V across every layer (the data half of
    /// copy-on-write; the allocator decides *when*). Stored words move
    /// verbatim, so the copy is exact at any storage dtype.
    pub fn copy_block(&mut self, src: BlockId, dst: BlockId) {
        let bs = self.config.block_size;
        for l in &mut self.layers {
            let n = bs * l.width;
            l.k.copy_within(src * n, dst * n, n);
            l.v.copy_within(src * n, dst * n, n);
        }
    }

    /// Borrow one layer's storage for the paged attention operator.
    pub fn layer_view(&self, layer: usize) -> PagedLayerView<'_> {
        let l = &self.layers[layer];
        PagedLayerView {
            k: l.k.slice(self.config.dtype),
            v: l.v.slice(self.config.dtype),
            block_size: self.config.block_size,
            width: l.width,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv_cache::KvDtype;

    fn pool_with(dtype: KvDtype) -> PagedKvPool {
        PagedKvPool::new(
            KvCacheConfig { block_size: 4, num_blocks: 8, dtype },
            &[6, 6],
        )
    }

    fn pool() -> PagedKvPool {
        pool_with(KvDtype::F32)
    }

    fn read_row(view: &PagedLayerView<'_>, base: usize, n: usize) -> (Vec<f32>, Vec<f32>) {
        let mut kb = Vec::new();
        let mut vb = Vec::new();
        let k = view.k.row(base, n, &mut kb).to_vec();
        let v = view.v.row(base, n, &mut vb).to_vec();
        (k, v)
    }

    #[test]
    fn write_then_read_roundtrip() {
        let mut p = pool();
        let k: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..6).map(|i| 10.0 + i as f32).collect();
        p.write_row(1, 3, 2, &k, &v);
        let view = p.layer_view(1);
        let base = view.row_offset(&[0, 3], 6); // token 6 -> block 3, slot 2
        let (rk, rv) = read_row(&view, base, 6);
        assert_eq!(rk, k);
        assert_eq!(rv, v);
        // Other layer untouched.
        let v0 = p.layer_view(0);
        let (zk, _) = read_row(&v0, 0, 6);
        assert!(zk.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn sixteen_bit_write_reads_back_quantized_exactly() {
        // Invariant 7 at pool granularity: a 16-bit pool's read-back equals
        // quantize() of the written values, bit for bit — for values that
        // do round (0.1) and values that don't (exact halves).
        for dt in [KvDtype::F16, KvDtype::BF16] {
            let mut p = pool_with(dt);
            let k: Vec<f32> = (0..6).map(|i| 0.1 + i as f32 * 0.3).collect();
            let v: Vec<f32> = (0..6).map(|i| -1.5 * i as f32).collect();
            p.write_row(0, 2, 1, &k, &v);
            let view = p.layer_view(0);
            let base = view.row_offset(&[0, 0, 2], 4 + 1);
            let (rk, rv) = read_row(&view, base, 6);
            for i in 0..6 {
                assert_eq!(rk[i].to_bits(), dt.quantize(k[i]).to_bits(), "{dt} k[{i}]");
                assert_eq!(rv[i].to_bits(), dt.quantize(v[i]).to_bits(), "{dt} v[{i}]");
            }
        }
    }

    #[test]
    fn quantize_at_write_reference_matches_16bit_storage() {
        // The f32 pool in reference mode and the real 16-bit pool must read
        // back identical f32 rows — the pool-level form of invariant 7.
        for dt in [KvDtype::F16, KvDtype::BF16] {
            let mut refp = pool_with(KvDtype::F32);
            refp.set_write_quantize(dt);
            let mut real = pool_with(dt);
            let k: Vec<f32> = (0..6).map(|i| (i as f32 - 2.7) * 0.013).collect();
            let v: Vec<f32> = (0..6).map(|i| 1.0 / (i as f32 + 3.0)).collect();
            refp.write_row(1, 5, 3, &k, &v);
            real.write_row(1, 5, 3, &k, &v);
            let (rv, xv) = (refp.layer_view(1), real.layer_view(1));
            let base = rv.row_offset(&[0, 0, 0, 0, 0, 5], 20 + 3);
            let (rk, rvv) = read_row(&rv, base, 6);
            let (xk, xvv) = read_row(&xv, base, 6);
            assert_eq!(
                rk.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                xk.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                "{dt} K"
            );
            assert_eq!(
                rvv.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                xvv.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                "{dt} V"
            );
        }
    }

    #[test]
    fn copy_block_copies_all_layers() {
        for dt in [KvDtype::F32, KvDtype::F16, KvDtype::BF16] {
            let mut p = pool_with(dt);
            for layer in 0..2 {
                for slot in 0..4 {
                    let row = vec![(layer * 10 + slot) as f32 + 0.1; 6];
                    p.write_row(layer, 2, slot, &row, &row);
                }
            }
            p.copy_block(2, 5);
            for layer in 0..2 {
                let view = p.layer_view(layer);
                for slot in 0..4 {
                    let src = view.row_offset(&[0, 0, 2], 8 + slot);
                    let dst = view.row_offset(&[0, 5], 4 + slot);
                    let (sk, sv) = read_row(&view, src, 6);
                    let (dk, dv) = read_row(&view, dst, 6);
                    assert_eq!(sk, dk, "{dt} layer {layer} slot {slot}");
                    assert_eq!(sv, dv, "{dt} layer {layer} slot {slot}");
                }
            }
        }
    }

    #[test]
    fn capacity_accounting_reports_actual_bytes() {
        // 2 layers * 2 tensors * 8 blocks * 4 slots * 6 wide elements.
        let elems = 2 * 2 * 8 * 4 * 6;
        let p32 = pool_with(KvDtype::F32);
        assert_eq!(p32.bytes(), elems * 4);
        assert_eq!(p32.n_layers(), 2);
        assert_eq!(p32.width(0), 6);
        // A 16-bit pool of the same shape allocates exactly half the bytes.
        for dt in [KvDtype::F16, KvDtype::BF16] {
            let p16 = pool_with(dt);
            assert_eq!(p16.bytes(), elems * 2, "{dt}");
            assert_eq!(p16.bytes() * 2, p32.bytes(), "{dt} must halve f32 bytes");
            assert_eq!(p16.dtype(), dt);
        }
    }
}
