//! Paged K/V storage pool: the *real* memory behind the block allocator's
//! bookkeeping.
//!
//! Per layer, one contiguous K tensor and one contiguous V tensor of
//! `num_blocks * block_size` rows, indexed by `BlockId` — the layout
//! vLLM-style paged attention gathers from. Layer widths may differ (the
//! pruning baseline keeps fewer channels), so each layer sizes its own
//! buffers. Blocks are plain storage here; ownership, ref counts, and
//! copy-on-write *decisions* live in
//! [`crate::coordinator::kv_cache::BlockAllocator`] — this pool only
//! executes the resulting writes and block copies.

use crate::attention::paged::PagedLayerView;
use crate::coordinator::kv_cache::{BlockId, KvCacheConfig};
use crate::tensor::DType;

#[derive(Debug)]
struct LayerPool {
    k: Vec<f32>,
    v: Vec<f32>,
    width: usize,
}

/// Block-granular K/V storage for every layer of a model.
#[derive(Debug)]
pub struct PagedKvPool {
    pub config: KvCacheConfig,
    layers: Vec<LayerPool>,
}

impl PagedKvPool {
    /// Allocate a pool with one (K, V) buffer pair per layer, `widths[i]`
    /// values per token row in layer `i`.
    pub fn new(config: KvCacheConfig, widths: &[usize]) -> PagedKvPool {
        let rows = config.num_blocks * config.block_size;
        let layers = widths
            .iter()
            .map(|&w| LayerPool { k: vec![0.0; rows * w], v: vec![0.0; rows * w], width: w })
            .collect();
        PagedKvPool { config, layers }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn width(&self, layer: usize) -> usize {
        self.layers[layer].width
    }

    /// Total pool bytes at a logical dtype (capacity, not occupancy).
    pub fn bytes(&self, dtype: DType) -> usize {
        self.layers.iter().map(|l| (l.k.len() + l.v.len()) * dtype.size_bytes()).sum()
    }

    /// Write one token's K/V row into `(block, slot)` of a layer.
    pub fn write_row(
        &mut self,
        layer: usize,
        block: BlockId,
        slot: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        debug_assert!(slot < self.config.block_size);
        let l = &mut self.layers[layer];
        debug_assert_eq!(k_row.len(), l.width);
        debug_assert_eq!(v_row.len(), l.width);
        let base = (block * self.config.block_size + slot) * l.width;
        l.k[base..base + l.width].copy_from_slice(k_row);
        l.v[base..base + l.width].copy_from_slice(v_row);
    }

    /// Copy a whole block's K/V across every layer (the data half of
    /// copy-on-write; the allocator decides *when*).
    pub fn copy_block(&mut self, src: BlockId, dst: BlockId) {
        let bs = self.config.block_size;
        for l in &mut self.layers {
            let n = bs * l.width;
            l.k.copy_within(src * n..src * n + n, dst * n);
            l.v.copy_within(src * n..src * n + n, dst * n);
        }
    }

    /// Borrow one layer's storage for the paged attention operator.
    pub fn layer_view(&self, layer: usize) -> PagedLayerView<'_> {
        let l = &self.layers[layer];
        PagedLayerView {
            k: &l.k,
            v: &l.v,
            block_size: self.config.block_size,
            width: l.width,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> PagedKvPool {
        PagedKvPool::new(KvCacheConfig { block_size: 4, num_blocks: 8 }, &[6, 6])
    }

    #[test]
    fn write_then_read_roundtrip() {
        let mut p = pool();
        let k: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..6).map(|i| 10.0 + i as f32).collect();
        p.write_row(1, 3, 2, &k, &v);
        let view = p.layer_view(1);
        let base = view.row_offset(&[0, 3], 6); // token 6 -> block 3, slot 2
        assert_eq!(&view.k[base..base + 6], &k[..]);
        assert_eq!(&view.v[base..base + 6], &v[..]);
        // Other layer untouched.
        assert!(p.layer_view(0).k.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn copy_block_copies_all_layers() {
        let mut p = pool();
        for layer in 0..2 {
            for slot in 0..4 {
                let row = vec![(layer * 10 + slot) as f32; 6];
                p.write_row(layer, 2, slot, &row, &row);
            }
        }
        p.copy_block(2, 5);
        for layer in 0..2 {
            let view = p.layer_view(layer);
            for slot in 0..4 {
                let src = view.row_offset(&[0, 0, 2], 8 + slot);
                let dst = view.row_offset(&[0, 5], 4 + slot);
                assert_eq!(view.k[src..src + 6], view.k[dst..dst + 6]);
                assert_eq!(view.v[src..src + 6], view.v[dst..dst + 6]);
            }
        }
    }

    #[test]
    fn capacity_accounting() {
        let p = pool();
        // 2 layers * 2 tensors * 8 blocks * 4 slots * 6 wide * 4 bytes.
        assert_eq!(p.bytes(DType::F32), 2 * 2 * 8 * 4 * 6 * 4);
        assert_eq!(p.bytes(DType::F16), 2 * 2 * 8 * 4 * 6 * 2);
        assert_eq!(p.n_layers(), 2);
        assert_eq!(p.width(0), 6);
    }
}
