//! `PagedNativeBackend` — the paged batched decode engine.
//!
//! Drop-in [`Backend`] for the continuous-batching scheduler that replaces
//! [`crate::coordinator::NativeBackend`]'s one-sequence-at-a-time decode
//! (private contiguous `KvCache` per sequence) with:
//!
//! * a single [`crate::engine::PagedKvPool`] holding every sequence's K/V
//!   in shared block-granular storage, leased through the ref-counted
//!   [`BlockAllocator`];
//! * **one batched step** for the whole active set — decode rows *and*
//!   prompt prefill chunks fused together ([`StepWork`]): one embedding
//!   gather, per layer one batched RMSNorm + one batched Q/K/V projection
//!   GEMM + one batched multi-row paged-attention call + one batched
//!   output/FFN pass, and a single logits GEMM against a cached
//!   transposed embedding — all rows through every weight matrix instead
//!   of separate passes per sequence or per phase;
//! * **chunked prefill, zero-copy end to end**: [`Backend::begin_prefill`]
//!   reserves a prompt's blocks (adopting any cached prefix in place),
//!   then the prompt rows ride batched steps as
//!   [`StepWork::PrefillChunk`] entries, each attending directly over the
//!   block table with causal masking. There is no contiguous staging
//!   `KvCache`, no O(prefix) gather on a prefix-cache hit, and no
//!   monolithic prompt pass stalling active decodes — and any chunk
//!   budget produces bit-identical generations (engine invariant 6);
//! * ref-counted prefix sharing: [`PagedNativeBackend::fork`] duplicates
//!   block *tables* only, so forked sequences dedup K/V memory, with
//!   copy-on-write the first time a fork writes into a shared tail block;
//! * **automatic cross-request prompt sharing**: a radix-tree
//!   [`PrefixCache`] over released sequences' prompts (enabled by default,
//!   `BDA_PREFIX_CACHE=0` disables). Admission matches each incoming
//!   prompt against the tree at block granularity, adopts the longest
//!   cached prefix zero-copy (COW on divergence), prefills only the
//!   uncovered tail, and evicts LRU zero-ref leaves under pool pressure;
//! * **victim preemption with recompute-on-resume**: when a decode step
//!   exhausts the pool *and* the tree has nothing left to evict, the
//!   youngest batch member is preempted (or, under `BDA_CLASS_PREEMPT`,
//!   the lowest `RequestClass` priority first, youngest within a class)
//!   — its committed full-block prefix
//!   donated to the prefix cache, its blocks released, the sequence
//!   reported in the step's
//!   [`crate::coordinator::scheduler::DecodeOutcome`] — instead of the
//!   whole batched step failing. The scheduler re-admits preempted
//!   sequences ahead of the waiting queue by replaying their token record
//!   through the prefill path; the replayed K/V is bit-identical (engine
//!   invariant 5), so overload degrades throughput, never correctness.
//!
//! Every row-level operation (embedding, RMSNorm, GEMM row, attention
//! accumulation order, FFN, logits) is arithmetically identical to the
//! per-sequence path, so batched paged decode returns *bit-identical*
//! logits to `Transformer::decode_step` for MHA and BDA alike — the
//! paper's losslessness claim carried through the serving engine (see
//! `tests/prop_coordinator.rs`). The same row determinism is what makes a
//! prefix-cache hit bitwise-equal to a cold prefill (invariant 4 in
//! [`crate::engine`]).
//!
//! Every parallel region of a decode or prefill — paged attention *and*
//! the GEMMs dispatched through the tensor wrappers — runs on this
//! engine's own worker pool: the step body executes under
//! [`threadpool::with_pool`], so an engine constructed via
//! [`PagedNativeBackend::with_thread_pool`] is fully isolated from the
//! process-wide pool (per-shard isolation for multi-worker sharding).

use super::prefix_cache::{PrefixCache, PrefixStats};
use crate::attention::paged::{paged_attention_decode_on, PagedSeq};
use crate::coordinator::kv_cache::{
    AppendSlot, BlockAllocator, BlockId, KvCacheConfig, KvError, SeqId,
};
use crate::coordinator::metrics::StepTiming;
use crate::coordinator::scheduler::{Backend, DecodeOutcome, PrefixProbeHandle, StepWork};
use crate::model::transformer::Transformer;
use crate::model::weights::FusedQkv;
use crate::obs::{self, Phase};
use crate::tensor::matmul::matmul;
use crate::tensor::Tensor;
use crate::util::threadpool::{self, ThreadPool};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Parse a prefix-cache on/off token (shared by `BDA_PREFIX_CACHE` and
/// the CLI `--prefix-cache` flag): everything is "on" except
/// `0` / `false` / `off` / `no` (trimmed, case-insensitive).
pub fn prefix_cache_flag(v: &str) -> bool {
    !matches!(v.trim().to_ascii_lowercase().as_str(), "0" | "false" | "off" | "no")
}

/// Resolve the `BDA_PREFIX_CACHE` environment knob: the radix-tree prefix
/// cache is **on** unless the variable opts out per
/// [`prefix_cache_flag`]. Read at engine construction (not latched
/// process-wide); [`PagedNativeBackend::set_prefix_cache`] overrides it
/// per engine.
pub fn prefix_cache_enabled_from_env() -> bool {
    match std::env::var("BDA_PREFIX_CACHE") {
        Err(_) => true,
        Ok(v) => prefix_cache_flag(&v),
    }
}

/// Resolve the `BDA_CLASS_PREEMPT` environment knob: the class-aware
/// preemption victim policy (evict the lowest [`RequestClass`] priority
/// first, youngest within a class) is **off** unless the variable is
/// `1` / `true` / `on` / `yes`. Read at engine construction;
/// [`PagedNativeBackend::set_class_preempt`] overrides it per engine.
/// Off (the default) keeps victim selection bit-identical to the
/// youngest-only policy.
///
/// [`RequestClass`]: crate::coordinator::request::RequestClass
pub fn class_preempt_from_env() -> bool {
    std::env::var("BDA_CLASS_PREEMPT")
        .map(|v| matches!(v.trim().to_ascii_lowercase().as_str(), "1" | "true" | "on" | "yes"))
        .unwrap_or(false)
}

/// Paged batched serving backend over the native Rust transformer.
pub struct PagedNativeBackend {
    pub model: Transformer,
    /// Block bookkeeping: tables, ref counts, copy-on-write decisions.
    pub alloc: BlockAllocator,
    /// Block storage: the K/V rows the tables point at.
    pool: super::paged_kv::PagedKvPool,
    /// Cached `embed.transpose()` for the tied LM head (the per-sequence
    /// path re-transposes it every decode step).
    embed_t: Tensor,
    /// Per-layer packed Q/K/V projection weights (one concatenated GEMM
    /// per layer instead of three), precomputed at construction.
    fused_qkv: Vec<FusedQkv>,
    /// Attention/GEMM wall-time split of the most recent decode step,
    /// consumed by the scheduler via [`Backend::take_step_timing`].
    last_timing: Option<StepTiming>,
    /// Persistent parked worker pool running the decode hot path.
    /// Defaults to a handle on the process-wide pool; a dedicated pool
    /// ([`PagedNativeBackend::with_thread_pool`]) gives this engine its
    /// own worker set. Both paged attention *and* the GEMMs dispatched
    /// through the tensor wrappers ride this pool — prefill and decode
    /// bodies run under [`threadpool::with_pool`] — so per-engine pools
    /// give full per-shard isolation.
    threads: Arc<ThreadPool>,
    /// Radix-tree prefix cache (`None` = disabled): automatic
    /// cross-request K/V prompt sharing. See [`PrefixCache`]. Behind an
    /// `Arc<Mutex<_>>` so the sharded router can probe the tree for its
    /// longest-cached-prefix placement decision from another thread
    /// ([`Backend::router_probe`]); the engine itself only ever touches it
    /// from its own worker thread, so the lock is uncontended on the hot
    /// path (one uncontended lock per admission/release, none per decode
    /// step).
    prefix: Option<Arc<Mutex<PrefixCache>>>,
    /// Per-sequence token history (prompt + decoded tokens), tracked only
    /// while the prefix cache is enabled; release inserts each history's
    /// full-block prefix into the tree.
    histories: HashMap<SeqId, Vec<u32>>,
    /// Per-sequence scheduling priority, noted by the scheduler at
    /// admission/resume ([`Backend::note_seq_priority`]). Consulted by
    /// victim selection only when `class_preempt` is on; absent entries
    /// rank as priority 0 (lowest — evicted first).
    priorities: HashMap<SeqId, u8>,
    /// Class-aware victim policy gate (`BDA_CLASS_PREEMPT`): off keeps
    /// the youngest-only policy bit-for-bit.
    class_preempt: bool,
    /// Prefix-cache counters already surfaced through [`StepTiming`]
    /// (deltas are reported, cumulative stats stay queryable).
    reported_prefix: PrefixStats,
}

impl PagedNativeBackend {
    pub fn new(model: Transformer, kv: KvCacheConfig) -> PagedNativeBackend {
        PagedNativeBackend::with_thread_pool(model, kv, Arc::clone(threadpool::global()))
    }

    /// Construct with an explicit worker pool: this engine's batched
    /// paged-attention steps dispatch on `threads` instead of the
    /// process-wide pool. Output is bit-identical on any pool at any
    /// width (the kernel's determinism contract).
    pub fn with_thread_pool(
        model: Transformer,
        kv: KvCacheConfig,
        threads: Arc<ThreadPool>,
    ) -> PagedNativeBackend {
        let widths: Vec<usize> =
            model.blocks.iter().map(|b| b.attn.effective_shape().proj_width()).collect();
        let embed_t = model.embed.transpose();
        let fused_qkv = model.blocks.iter().map(|b| b.attn.pack_qkv()).collect();
        let prefix = prefix_cache_enabled_from_env()
            .then(|| Arc::new(Mutex::new(PrefixCache::new(kv.block_size))));
        PagedNativeBackend {
            alloc: BlockAllocator::new(kv),
            pool: super::paged_kv::PagedKvPool::new(kv, &widths),
            embed_t,
            fused_qkv,
            last_timing: None,
            threads,
            prefix,
            histories: HashMap::new(),
            priorities: HashMap::new(),
            class_preempt: class_preempt_from_env(),
            reported_prefix: PrefixStats::default(),
            model,
        }
    }

    /// Enable or disable the class-aware preemption victim policy,
    /// overriding the `BDA_CLASS_PREEMPT` default. On: pool exhaustion
    /// evicts the lowest-priority decode entry first (youngest within a
    /// class). Off (default): youngest only — bit-identical victim
    /// choices to an engine without classes. Either way each victim
    /// resumes bitwise (engine invariant 5): the policy picks *who*
    /// recomputes, never *what* they generate.
    pub fn set_class_preempt(&mut self, on: bool) {
        self.class_preempt = on;
    }

    pub fn class_preempt_enabled(&self) -> bool {
        self.class_preempt
    }

    /// Enable or disable the radix-tree prefix cache, overriding the
    /// `BDA_PREFIX_CACHE` default. Disabling clears the tree and releases
    /// every cached block back to the pool. Toggling never affects
    /// generated tokens (invariant 4: a cache hit is bitwise-identical to
    /// a cold prefill) — only how much prefill work and K/V memory repeat
    /// prompts cost.
    pub fn set_prefix_cache(&mut self, enabled: bool) {
        match (enabled, self.prefix.is_some()) {
            (true, false) => {
                self.prefix =
                    Some(Arc::new(Mutex::new(PrefixCache::new(self.alloc.config.block_size))));
                // Fresh tree, fresh counters: the delta baseline must
                // match or the next step's u64 deltas would underflow.
                self.reported_prefix = PrefixStats::default();
            }
            (false, true) => {
                if let Some(cache) = self.prefix.take() {
                    cache.lock().unwrap().clear(&mut self.alloc);
                }
                self.histories.clear();
                self.reported_prefix = PrefixStats::default();
            }
            _ => {}
        }
    }

    pub fn prefix_cache_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Cumulative prefix-cache counters (zeroed stats when disabled).
    pub fn prefix_stats(&self) -> PrefixStats {
        self.prefix.as_ref().map(|c| c.lock().unwrap().stats()).unwrap_or_default()
    }

    /// Blocks currently resident in the radix tree (they count as used in
    /// [`PagedNativeBackend::used_blocks`]; the evictable subset is
    /// reported as reclaimable through [`Backend::free_blocks`]).
    pub fn cached_blocks(&self) -> usize {
        self.prefix.as_ref().map(|c| c.lock().unwrap().held_blocks()).unwrap_or(0)
    }

    /// A clone of the shared prefix-cache handle (`None` when the cache is
    /// disabled). The sharded router holds one per worker for read-only
    /// [`PrefixCache::peek_prefix_blocks`] probes; everything that mutates
    /// the tree stays inside this backend.
    pub fn prefix_cache_handle(&self) -> Option<Arc<Mutex<PrefixCache>>> {
        self.prefix.clone()
    }

    /// Pool sized by the default [`KvCacheConfig`].
    pub fn with_default_pool(model: Transformer) -> PagedNativeBackend {
        PagedNativeBackend::new(model, KvCacheConfig::default())
    }

    /// The worker pool this engine dispatches paged attention on.
    pub fn thread_pool(&self) -> &Arc<ThreadPool> {
        &self.threads
    }

    /// Fork `child` from `parent`: shares every current block (table copy +
    /// ref-count bump), so the fork costs zero K/V memory until the child
    /// diverges — at which point copy-on-write gives it a private tail
    /// block. The K/V dedup counterpart of the allocator-level `fork`.
    ///
    /// When this backend is driven by a [`crate::coordinator::Scheduler`],
    /// admission capacity is queried through [`Backend::free_blocks`] —
    /// this allocator, the engine truth — so blocks consumed by forks and
    /// their copy-on-write divergence are visible to admission even though
    /// the scheduler's own bookkeeping allocator never sees them. (Full
    /// ownership unification — one allocator, preemption — remains a
    /// ROADMAP item.)
    pub fn fork(&mut self, parent: SeqId, child: SeqId) -> Result<(), KvError> {
        self.alloc.fork(parent, child)?;
        // The child shares the parent's history, so its prefix is
        // insertable into the radix tree on release like any sequence.
        if let Some(h) = self.histories.get(&parent).cloned() {
            self.histories.insert(child, h);
        }
        Ok(())
    }

    /// Actual allocated bytes of the K/V pool (capacity at the pool's
    /// *storage* dtype, not occupancy): a 16-bit pool reports half an f32
    /// pool's bytes for the same shape. Historically this multiplied the
    /// logical element count by the model's logical dtype — fiction when
    /// storage was always f32; it is pool truth now.
    pub fn kv_pool_bytes(&self) -> usize {
        self.pool.bytes()
    }

    /// Storage dtype of the K/V pool.
    pub fn kv_dtype(&self) -> crate::tensor::DType {
        self.pool.dtype()
    }

    /// Quantize-at-write reference mode on an f32 pool — the invariant-7
    /// test harness (`tests/prop_kv_dtype.rs`): a 16-bit pool at dtype `d`
    /// must generate bitwise identically to an f32 pool with writes passed
    /// through `quantize_slice(d)`. See `PagedKvPool::set_write_quantize`.
    pub fn set_kv_write_quantize(&mut self, dtype: crate::tensor::DType) {
        self.pool.set_write_quantize(dtype);
    }

    /// Blocks currently leased (dedup makes this less than the sum of
    /// per-sequence lengths when forks share prefixes).
    pub fn used_blocks(&self) -> usize {
        self.alloc.used_blocks()
    }

    /// Evict one LRU zero-ref leaf from the prefix cache; false when there
    /// is no cache or nothing evictable.
    fn evict_one(&mut self) -> bool {
        match &self.prefix {
            Some(cache) => cache.lock().unwrap().evict_lru(&mut self.alloc) > 0,
            None => false,
        }
    }

    /// Register `seq` (adopting `prefix` blocks when non-empty), evicting
    /// cached blocks under pool pressure until registration fits or the
    /// tree runs dry. The caller must protect `prefix` with a temporary
    /// hold so eviction cannot free the very blocks being adopted.
    fn register_evicting(
        &mut self,
        seq: SeqId,
        prefix: &[BlockId],
        total_tokens: usize,
    ) -> Result<(), KvError> {
        loop {
            let res = if prefix.is_empty() {
                self.alloc.register(seq, total_tokens)
            } else {
                self.alloc.register_with_prefix(seq, prefix, total_tokens)
            };
            match res {
                Err(KvError::OutOfBlocks { .. }) if self.evict_one() => continue,
                res => return res,
            }
        }
    }

    /// [`BlockAllocator::append_token_cow`] with the same pressure valve:
    /// a boundary or COW allocation that runs dry evicts cached leaves
    /// before giving up. Active sequences' blocks are table-referenced and
    /// therefore never eviction victims — when the tree runs dry too, the
    /// decode step escalates to **preempting** an active sequence (see
    /// [`PagedNativeBackend::preempt`]) instead of failing the batch.
    fn append_evicting(&mut self, seq: SeqId) -> Result<AppendSlot, KvError> {
        loop {
            match self.alloc.append_token_cow(seq) {
                Err(KvError::OutOfBlocks { .. }) if self.evict_one() => continue,
                res => return res,
            }
        }
    }

    /// Preempt `seq` mid-decode: donate its committed full-block prefix to
    /// the prefix cache (a warm start for the resume's replay — and still
    /// reclaimable, since tree leaves are evictable under pressure), then
    /// release its table and drop its history. The caller replays the
    /// sequence's token record through the prefill path on resume; row
    /// determinism makes the recomputed K/V bit-identical (engine
    /// invariant 5).
    ///
    /// `pending_append` marks a victim that already leased this step's
    /// append slot: its history carries one token whose K/V row has *not*
    /// been written yet (rows land in the per-layer loop, after every
    /// append), so that token is excluded from the donation — the tree
    /// must only ever hold fully written rows.
    fn preempt(&mut self, seq: SeqId, pending_append: bool) {
        self.priorities.remove(&seq);
        let mut history = self.histories.remove(&seq);
        if pending_append {
            if let Some(h) = history.as_mut() {
                h.pop();
            }
        }
        self.cache_history_then_release(seq, history, true);
    }

    /// The shared back half of [`Backend::release`] and
    /// [`PagedNativeBackend::preempt`]: insert the sequence's committed
    /// full-block history into the prefix cache (the tree takes its own
    /// holds; `donated` routes the blocks through the donation counter),
    /// then release the table — a bulk release respecting refs/holds, so
    /// blocks shared with forks or the tree survive and everything
    /// private returns to the pool.
    fn cache_history_then_release(&mut self, seq: SeqId, history: Option<Vec<u32>>, donated: bool) {
        if let (Some(cache), Some(h)) = (&self.prefix, history) {
            let bs = self.alloc.config.block_size;
            let full = h.len() / bs * bs;
            if full > 0 {
                if let Some(blocks) = self.alloc.seq_blocks(seq) {
                    let blocks = blocks[..full / bs].to_vec();
                    let mut cache = cache.lock().unwrap();
                    if donated {
                        cache.donate(&h[..full], &blocks, &mut self.alloc);
                    } else {
                        cache.insert(&h[..full], &blocks, &mut self.alloc);
                    }
                }
            }
        }
        let _ = self.alloc.release_counting(seq);
    }
}

impl Backend for PagedNativeBackend {
    fn vocab_size(&self) -> usize {
        self.model.config.vocab_size
    }

    fn max_seq_len(&self) -> usize {
        self.model.config.max_seq_len
    }

    /// Monolithic prefill: reserve blocks, then run the whole uncovered
    /// tail as a single unbounded chunk through the fused step path — the
    /// same multi-row kernel chunked prefill uses, so "monolithic" is
    /// literally the one-chunk special case (which is why any chunk budget
    /// is bitwise-identical: engine invariant 6). No step timing is
    /// recorded — a direct prefill is an admission, not a scheduler step.
    fn prefill(&mut self, seq: SeqId, prompt: &[u32]) -> Result<Vec<f32>> {
        // GEMMs inside the prefill ride this engine's pool, not the
        // process-wide one (per-engine GEMM pools).
        let threads = Arc::clone(&self.threads);
        threadpool::with_pool(&threads, || {
            let covered = self.begin_prefill_inner(seq, prompt)?;
            let work = [StepWork::PrefillChunk {
                seq,
                tokens: prompt[covered..].to_vec(),
                start: covered,
            }];
            let out = self.step_inner(&work, false)?;
            out.logits
                .into_iter()
                .next()
                .flatten()
                .ok_or_else(|| anyhow!("prefill seq {seq}: chunk produced no logits"))
        })
    }

    /// The batched decode step: all sequences advance one token in one
    /// pass over the model. Attention *and* GEMMs dispatch on this
    /// engine's worker pool. Pool exhaustion never fails the step while a
    /// preemptible sequence holds blocks: the youngest batch member is
    /// preempted (recompute-on-resume) and reported in the outcome.
    fn decode(&mut self, seqs: &[(SeqId, u32)]) -> Result<DecodeOutcome> {
        let work: Vec<StepWork> =
            seqs.iter().map(|&(seq, token)| StepWork::Decode { seq, token }).collect();
        let threads = Arc::clone(&self.threads);
        threadpool::with_pool(&threads, || self.step_inner(&work, true))
    }

    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    /// Block reservation half of admission: prefix-cache lookup + adoption
    /// + registration, no forward pass. Returns the number of leading
    /// prompt tokens already resident (always < the prompt length — the
    /// cache never covers the final token, so at least one chunk row
    /// remains to produce the last-position logits).
    fn begin_prefill(&mut self, seq: SeqId, prompt: &[u32]) -> Result<usize> {
        self.begin_prefill_inner(seq, prompt)
    }

    /// One fused batched step over mixed decode + prefill-chunk work —
    /// the continuous-batching hot path. Decode entries behave exactly as
    /// in [`Backend::decode`] (including preemption under pool
    /// exhaustion); chunk entries never allocate (their blocks were
    /// reserved by [`Backend::begin_prefill`]) and are never preempted.
    fn step(&mut self, work: &[StepWork]) -> Result<DecodeOutcome> {
        let threads = Arc::clone(&self.threads);
        threadpool::with_pool(&threads, || self.step_inner(work, true))
    }

    fn release(&mut self, seq: SeqId) {
        // Instead of freeing the sequence's prefix, insert its full-block
        // history (prompt + generated tokens — all deterministic K/V) into
        // the radix tree so future requests sharing the prefix skip its
        // prefill. Blocks return to the pool when their ref count hits
        // zero; forks and the prefix cache still holding shared blocks
        // keep them alive.
        let history = self.histories.remove(&seq);
        self.priorities.remove(&seq);
        self.cache_history_then_release(seq, history, false);
    }

    /// Note the sequence's class priority for victim selection. Always
    /// recorded (one map insert) so flipping the policy on mid-run still
    /// sees every live sequence's class.
    fn note_seq_priority(&mut self, seq: SeqId, priority: u8) {
        self.priorities.insert(seq, priority);
    }

    /// Pool occupancy for the continuous resource sampler: free blocks as
    /// admission sees them ([`Backend::free_blocks`] — unused plus
    /// evictable), pinned blocks, the evictable subset, and radix-tree
    /// residency.
    fn pool_counters(&self) -> Option<crate::obs::sampler::PoolCounters> {
        let evictable = self
            .prefix
            .as_ref()
            .map(|c| c.lock().unwrap().evictable_blocks(&self.alloc))
            .unwrap_or(0);
        Some(crate::obs::sampler::PoolCounters {
            free_blocks: self.alloc.free_blocks() + evictable,
            used_blocks: self.alloc.used_blocks(),
            evictable_blocks: evictable,
            prefix_cached_blocks: self.cached_blocks(),
        })
    }

    /// Engine pool truth for admission: free blocks plus everything the
    /// prefix cache could evict on demand — cached-but-unpinned K/V is
    /// reclaimable capacity, not occupancy. Leaves pinned by anything
    /// beyond the tree's own hold (an admission in flight holding the
    /// matched prefix, a sequence table still referencing the rows) are
    /// *excluded*: counting them would overstate reclaimable capacity to
    /// the scheduler. This allocator sees every lease: prefills, decode
    /// appends, engine-level forks / copy-on-write, *and* radix-tree
    /// holds.
    fn free_blocks(&self) -> Option<usize> {
        let cache = self.prefix.as_ref();
        let evictable = cache.map(|c| c.lock().unwrap().evictable_blocks(&self.alloc)).unwrap_or(0);
        Some(self.alloc.free_blocks() + evictable)
    }

    /// Read-only longest-cached-prefix probe against this engine's radix
    /// tree (no LRU touch, no counters): the router's placement signal.
    fn cached_prefix_blocks(&self, prompt: &[u32]) -> usize {
        self.prefix.as_ref().map(|c| c.lock().unwrap().peek_prefix_blocks(prompt)).unwrap_or(0)
    }

    /// Thread-safe probe handle sharing this engine's live tree, for the
    /// router to consult while the backend itself runs on a worker thread.
    fn router_probe(&self) -> Option<PrefixProbeHandle> {
        let cache = Arc::clone(self.prefix.as_ref()?);
        Some(Arc::new(move |prompt: &[u32]| cache.lock().unwrap().peek_prefix_blocks(prompt)))
    }

    /// Pool truth for the metrics surface: actual allocated bytes plus the
    /// storage dtype name (see [`PagedNativeBackend::kv_pool_bytes`]).
    fn kv_pool(&self) -> Option<(usize, &'static str)> {
        Some((self.pool.bytes(), self.pool.dtype().name()))
    }

    /// The last decode step's attention/GEMM split, with the prefix-cache
    /// counter delta accumulated since the previous take merged in. The
    /// delta is reported even when no decode step ran (e.g. a trace of
    /// `max_new_tokens <= 1` requests completes without decoding), so the
    /// metrics surface never under-counts admissions.
    fn take_step_timing(&mut self) -> Option<StepTiming> {
        let mut timing = self.last_timing.take();
        let stats = self.prefix_stats();
        // Only admission counters are reported; insert/evict churn alone
        // must not fabricate a timing entry.
        let pending = stats.lookups != self.reported_prefix.lookups
            || stats.blocks_saved != self.reported_prefix.blocks_saved;
        if pending {
            let t = timing.get_or_insert_with(StepTiming::default);
            t.prefix_hits = stats.hits - self.reported_prefix.hits;
            t.prefix_misses = stats.misses() - self.reported_prefix.misses();
            t.prefix_blocks_saved = stats.blocks_saved - self.reported_prefix.blocks_saved;
            self.reported_prefix = stats;
        }
        timing
    }
}

impl PagedNativeBackend {
    /// Reserve `seq`'s blocks for `prompt`, adopting the longest cached
    /// whole-block prefix zero-copy, and seed its history with the
    /// adopted tokens (their K/V rows are resident; uncovered rows join
    /// the history as their chunks are written, so preemption/release
    /// never donates unwritten rows). Returns the covered token count.
    fn begin_prefill_inner(&mut self, seq: SeqId, prompt: &[u32]) -> Result<usize> {
        if prompt.is_empty() {
            bail!("prefill: empty prompt for seq {seq}");
        }
        // Longest cached whole-block prefix (never the full prompt: at
        // least one tail token is left so the final chunk produces the
        // last-position logits).
        let hit = match &self.prefix {
            Some(cache) => cache.lock().unwrap().lookup(prompt),
            None => Vec::new(),
        };
        // `adopted` is decided exactly once, at the registration that
        // stuck: the number of cached blocks this admission actually rides
        // on. Hit/miss stats derive from it atomically below — a failed
        // adoption attempt must not leave hit-path counters behind before
        // the cold fallback records its miss, or rates could exceed 1.0.
        let (registered, adopted) = if hit.is_empty() {
            (self.register_evicting(seq, &[], prompt.len()), 0)
        } else {
            // Temporary hold: the matched blocks are tree-only until
            // registration bumps their table refs, and the eviction
            // pressure valve inside `register_evicting` must not reclaim
            // them.
            self.alloc.hold_blocks(&hit);
            let adoption = self.register_evicting(seq, &hit, prompt.len());
            self.alloc.release_held(&hit);
            match adoption {
                Ok(()) => (Ok(()), hit.len()),
                Err(_) => {
                    // The tail didn't fit around the held prefix (the hold
                    // itself can pin the only evictable leaf). Drop the
                    // hit and admit cold: without the hold the matched
                    // leaf is evictable like any other, so a prompt that
                    // fits the pool is never rejected because of a
                    // partial cache match.
                    (self.register_evicting(seq, &[], prompt.len()), 0)
                }
            }
        };
        registered.map_err(|e| anyhow!("prefill seq {seq}: {e}"))?;
        // One stats record per admission that stuck — requeued admissions
        // don't inflate lookups, and a dropped hit counts as the miss its
        // cold registration actually was.
        if let Some(cache) = &self.prefix {
            cache.lock().unwrap().record_admission(adopted);
        }
        if adopted > 0 {
            // Thread-track marker: this admission rode `adopted` cached
            // prompt blocks instead of re-prefilling them.
            obs::instant(Phase::PrefixAdopt, adopted as u64);
        }
        let covered = adopted * self.alloc.config.block_size;
        if self.prefix.is_some() {
            // Only the resident prefix; chunk rows join as they are
            // written (see `step_inner`).
            self.histories.insert(seq, prompt[..covered].to_vec());
        }
        Ok(covered)
    }

    /// The fused batched step over mixed decode + prefill-chunk work.
    /// `record_timing` is false only for the monolithic [`Backend::prefill`]
    /// wrapper, whose single-chunk pass is an admission rather than a
    /// scheduler step and must not surface as one in the metrics.
    fn step_inner(&mut self, work: &[StepWork], record_timing: bool) -> Result<DecodeOutcome> {
        if work.is_empty() {
            return Ok(DecodeOutcome { logits: Vec::new(), preempted: Vec::new() });
        }
        let b = work.len();
        let d = self.model.config.d_model;
        let bs = self.alloc.config.block_size;

        // Phase 1 — lease a write slot per *decode* entry (copy-on-write
        // against forks). Chunk entries allocate nothing here: every
        // block of a prefilling prompt was reserved by `begin_prefill`,
        // which also makes them ineligible as preemption victims — their
        // rows are mid-write and the scheduler owns their replay record
        // only once they activate. Boundary/COW allocations first evict
        // cached prefixes under pool pressure; if the tree runs dry too,
        // the **youngest** decode entry (largest SeqId — admitted last)
        // is preempted and its blocks reclaimed, so exhaustion parks
        // low-priority work instead of erroring out of the whole step.
        let mut slots: Vec<Option<AppendSlot>> = vec![None; b];
        let mut parked = vec![false; b];
        let mut preempted: Vec<SeqId> = Vec::new();
        for i in 0..b {
            if parked[i] {
                continue;
            }
            let &StepWork::Decode { seq: id, token: tok } = &work[i] else {
                continue;
            };
            loop {
                match self.append_evicting(id) {
                    Ok(slot) => {
                        if let Some(src) = slot.copied_from {
                            self.pool.copy_block(src, slot.block);
                        }
                        if let Some(h) = self.histories.get_mut(&id) {
                            // The token whose K/V row is written below.
                            h.push(tok);
                        }
                        slots[i] = Some(slot);
                        break;
                    }
                    Err(KvError::OutOfBlocks { .. }) => {
                        let decode_seq = |j: usize| match work[j] {
                            StepWork::Decode { seq, .. } => Some(seq),
                            StepWork::PrefillChunk { .. } => None,
                        };
                        let candidates =
                            || (0..b).filter(|&j| !parked[j] && decode_seq(j).is_some());
                        // Victim policy: youngest (largest SeqId) by
                        // default; under `BDA_CLASS_PREEMPT` the lowest
                        // class priority yields first, youngest within a
                        // class. The gate only picks *who* recomputes —
                        // every victim still resumes bitwise (invariant 5).
                        let prio = |j: usize| {
                            self.priorities.get(&decode_seq(j).unwrap()).copied().unwrap_or(0)
                        };
                        let victim = if self.class_preempt {
                            candidates()
                                .max_by_key(|&j| (std::cmp::Reverse(prio(j)), decode_seq(j)))
                                .expect("the requester itself is a candidate")
                        } else {
                            candidates()
                                .max_by_key(|&j| decode_seq(j))
                                .expect("the requester itself is a candidate")
                        };
                        let victim_seq = decode_seq(victim).unwrap();
                        if victim_seq == id && candidates().count() == 1 {
                            // No lower-priority decode holds blocks and
                            // the tree is dry: genuine exhaustion — this
                            // sequence cannot grow with everything
                            // preemptible already reclaimed.
                            return Err(anyhow!(
                                "decode seq {id}: out of KV blocks with no \
                                 preemptible sequence left"
                            ));
                        }
                        self.preempt(victim_seq, slots[victim].is_some());
                        parked[victim] = true;
                        slots[victim] = None;
                        preempted.push(victim_seq);
                        if victim_seq == id {
                            break; // the requester parked itself
                        }
                    }
                    Err(e) => return Err(anyhow!("decode seq {id}: {e}")),
                }
            }
        }

        // Phase 2 — assemble the batched input: one embedded row per
        // decode survivor (its last token at its final position), a row
        // per chunk token at its prompt position. Every row also gets a
        // K/V write target: the decode entry's freshly leased slot, or
        // the chunk positions inside the blocks reserved at
        // `begin_prefill` (adoption is block-aligned and chunking starts
        // right after it, so chunk writes only touch private tail blocks
        // — never shared prefix rows).
        let survivors: Vec<usize> = (0..b).filter(|&i| !parked[i]).collect();
        debug_assert!(!survivors.is_empty(), "phase 1 errors before parking everyone");
        let sb = survivors.len();
        let mut prefill_chunks = 0u64;
        let mut chunked_tokens = 0u64;
        let mut total_rows = 0usize;
        for &i in &survivors {
            total_rows += match &work[i] {
                StepWork::Decode { .. } => 1,
                StepWork::PrefillChunk { tokens, .. } => tokens.len(),
            };
        }
        let mut x = Tensor::zeros(&[total_rows, d]);
        // Per-survivor (seq, K/V length visible to its rows, query rows).
        let mut meta: Vec<(SeqId, usize, usize)> = Vec::with_capacity(sb);
        let mut write_targets: Vec<(BlockId, usize)> = Vec::with_capacity(total_rows);
        let mut row = 0usize;
        for &i in &survivors {
            match &work[i] {
                StepWork::Decode { seq, token } => {
                    let len = self.alloc.seq_len(*seq).expect("survivor appended above");
                    let emb = self.model.embed_tokens(&[*token], len - 1);
                    x.row_mut(row).copy_from_slice(emb.row(0));
                    let slot = slots[i].expect("survivor slot");
                    write_targets.push((slot.block, slot.slot));
                    meta.push((*seq, len, 1));
                    row += 1;
                }
                StepWork::PrefillChunk { seq, tokens, start } => {
                    let registered = self
                        .alloc
                        .seq_len(*seq)
                        .ok_or_else(|| anyhow!("chunk for unregistered seq {seq}"))?;
                    anyhow::ensure!(
                        !tokens.is_empty() && start + tokens.len() <= registered,
                        "chunk rows {}..{} out of bounds for seq {seq} ({registered} registered)",
                        start,
                        start + tokens.len(),
                    );
                    let emb = self.model.embed_tokens(tokens, *start);
                    let blocks = self.alloc.seq_blocks(*seq).expect("registered above");
                    for (k, t) in (*start..start + tokens.len()).enumerate() {
                        x.row_mut(row + k).copy_from_slice(emb.row(k));
                        write_targets.push((blocks[t / bs], t % bs));
                    }
                    if let Some(h) = self.histories.get_mut(seq) {
                        debug_assert_eq!(h.len(), *start, "chunks must extend history in order");
                        h.extend_from_slice(tokens);
                    }
                    meta.push((*seq, start + tokens.len(), tokens.len()));
                    prefill_chunks += 1;
                    chunked_tokens += tokens.len() as u64;
                    row += tokens.len();
                }
            }
        }

        // Block tables are final once every append above has run, so the
        // gather views are built once and shared by all layers. A chunk's
        // visible length stops at its own last row — later prompt
        // positions are registered but unwritten.
        let views: Vec<PagedSeq> = meta
            .iter()
            .map(|&(seq, len, q_rows)| PagedSeq {
                blocks: self.alloc.seq_blocks(seq).expect("registered above"),
                len,
                q_rows,
            })
            .collect();

        let mut attn_secs = 0.0f64;
        let mut gemm_secs = 0.0f64;
        for (li, block) in self.model.blocks.iter().enumerate() {
            let s = block.attn.effective_shape();
            let width = s.proj_width();
            let h = x.rmsnorm(&block.norm1, 1e-5);
            let t = Instant::now();
            // One packed GEMM for Q|K|V (bit-identical to the three
            // separate projections; see `FusedQkv`).
            let (q, k, v) = self.fused_qkv[li].project(&h, &block.attn);
            let dt = t.elapsed();
            gemm_secs += dt.as_secs_f64();
            obs::span_at(Phase::Gemm, li as u64, t, dt);
            // Every row's K/V lands before attention runs, so a chunk's
            // rows see themselves and each other (causally masked by the
            // kernel's per-row visible limit).
            for (r, &(blk, slot)) in write_targets.iter().enumerate() {
                self.pool.write_row(
                    li,
                    blk,
                    slot,
                    &k.data[r * width..(r + 1) * width],
                    &v.data[r * width..(r + 1) * width],
                );
            }
            let layer = self.pool.layer_view(li);
            let t = Instant::now();
            let workers = self.threads.workers();
            let attn_out = paged_attention_decode_on(&self.threads, &q, &layer, &views, s, workers);
            let dt = t.elapsed();
            attn_secs += dt.as_secs_f64();
            obs::span_at(Phase::Attn, li as u64, t, dt);
            let t = Instant::now();
            let y = block.attn.output(&attn_out);
            let x1 = x.add(&y);
            x = block.ffn(&x1);
            let dt = t.elapsed();
            gemm_secs += dt.as_secs_f64();
            obs::span_at(Phase::Gemm, li as u64, t, dt);
        }

        // One logits row per surviving entry: a decode's single row, a
        // chunk's last row. Gathering before the final norm + GEMM is
        // bitwise-identical to computing them on every row and then
        // selecting (both are row-wise), and skips the vocab-sized GEMM
        // for chunk rows whose logits nobody reads.
        let mut sel = Tensor::zeros(&[sb, d]);
        let mut row = 0usize;
        for (e, &(_, _, q_rows)) in meta.iter().enumerate() {
            row += q_rows;
            sel.row_mut(e).copy_from_slice(x.row(row - 1));
        }
        let h = sel.rmsnorm(&self.model.norm_f, 1e-5);
        let t = Instant::now();
        let logits = matmul(&h, &self.embed_t);
        let dt = t.elapsed();
        gemm_secs += dt.as_secs_f64();
        // Logit projection: one past the last layer index on the GEMM track.
        obs::span_at(Phase::Gemm, self.model.blocks.len() as u64, t, dt);
        if record_timing {
            // The prefix-cache delta is merged in at take_step_timing
            // time, so admissions surface even when no further step runs.
            self.last_timing = Some(StepTiming {
                attn: attn_secs,
                gemm: gemm_secs,
                preemptions: preempted.len() as u64,
                prefill_chunks,
                chunked_tokens,
                ..Default::default()
            });
        }
        let mut out: Vec<Option<Vec<f32>>> = vec![None; b];
        for (e, &i) in survivors.iter().enumerate() {
            out[i] = Some(logits.row(e).to_vec());
        }
        Ok(DecodeOutcome { logits: out, preempted })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bd::Strategy;
    use crate::model::transformer::KvCache;
    use crate::model::ModelConfig;
    use crate::tensor::DType;

    // Dtype pinned to F32: these tests compare paged output bitwise
    // against the f32 per-sequence references (`model.prefill` /
    // `model.decode_step` / `KvCache`), which invariant 1 only promises at
    // matching storage precision. The `BDA_KV_DTYPE` CI axis exercises
    // 16-bit storage through the paged-vs-paged suites
    // (`tests/prop_kv_dtype.rs`, `tests/prop_preemption.rs`).
    fn kv() -> KvCacheConfig {
        KvCacheConfig { block_size: 4, num_blocks: 64, dtype: DType::F32 }
    }

    #[test]
    fn prefill_matches_reference() {
        let model = Transformer::new_mha(ModelConfig::tiny(), 5);
        let mut engine = PagedNativeBackend::new(model.clone(), kv());
        let prompt = [7u32, 23, 5, 91, 14];
        let got = engine.prefill(1, &prompt).unwrap();
        let mut cache = KvCache::new(model.config.n_layers);
        let want = model.prefill(&mut cache, &prompt);
        assert_eq!(got, want.data);
    }

    #[test]
    fn batched_decode_is_bit_identical_to_per_seq() {
        let model = Transformer::new_mha(ModelConfig::tiny(), 9);
        let mut engine = PagedNativeBackend::new(model.clone(), kv());
        let prompts: [&[u32]; 3] = [&[1, 2, 3], &[9, 4, 17, 200, 31], &[250]];
        let mut caches = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            engine.prefill(i as SeqId, p).unwrap();
            let mut c = KvCache::new(model.config.n_layers);
            let _ = model.prefill(&mut c, p);
            caches.push(c);
        }
        for round in 0..4u32 {
            let batch: Vec<(SeqId, u32)> =
                (0..3).map(|i| (i as SeqId, round * 3 + i as u32)).collect();
            let got = engine.decode(&batch).unwrap().expect_complete();
            for (i, c) in caches.iter_mut().enumerate() {
                let want = model.decode_step(c, batch[i].1);
                assert_eq!(got[i], want.data, "round {round} seq {i}");
            }
        }
    }

    #[test]
    fn bda_batched_decode_matches_bda_per_seq() {
        let mha = Transformer::new_mha(ModelConfig::tiny(), 13);
        let model = mha.to_bda(Strategy::ResidualMin, DType::F32).unwrap();
        let mut engine = PagedNativeBackend::new(model.clone(), kv());
        engine.prefill(1, &[5, 6, 7, 8, 9]).unwrap();
        let mut cache = KvCache::new(model.config.n_layers);
        let _ = model.prefill(&mut cache, &[5, 6, 7, 8, 9]);
        for tok in [3u32, 77, 12] {
            let got = engine.decode(&[(1, tok)]).unwrap().expect_complete();
            let want = model.decode_step(&mut cache, tok);
            assert_eq!(got[0], want.data);
        }
    }

    #[test]
    fn fork_dedups_kv_and_cow_isolates_parent() {
        let model = Transformer::new_mha(ModelConfig::tiny(), 21);
        let mut engine = PagedNativeBackend::new(model.clone(), kv());
        let prompt = [11u32, 42, 3, 8, 100]; // 5 tokens -> partial tail block
        engine.prefill(1, &prompt).unwrap();
        let used_parent = engine.used_blocks();

        // Fork shares all blocks: zero extra K/V memory.
        engine.fork(1, 2).unwrap();
        assert_eq!(engine.used_blocks(), used_parent, "fork must dedup K/V blocks");

        // Child decodes first: copy-on-write in the shared tail block.
        let child = engine.decode(&[(2, 7)]).unwrap().expect_complete();
        engine.alloc.check_invariants().unwrap();

        // Parent decodes the same token afterwards; its storage must be
        // untouched by the child's write — verify against the reference.
        let parent = engine.decode(&[(1, 7)]).unwrap().expect_complete();
        let mut cache = KvCache::new(model.config.n_layers);
        let _ = model.prefill(&mut cache, &prompt);
        let want = model.decode_step(&mut cache, 7);
        assert_eq!(parent[0], want.data, "child COW corrupted the parent");
        assert_eq!(child[0], want.data, "identical histories must agree");

        // Releasing the child frees only its private COW block (its full
        // shared prefix block may move into the prefix cache, which the
        // parent's table already keeps alive — still zero extra blocks).
        engine.release(2);
        assert_eq!(engine.used_blocks(), used_parent);
        engine.release(1);
        assert_eq!(
            engine.used_blocks(),
            engine.cached_blocks(),
            "only radix-tree residency may outlive the sequences"
        );
        engine.alloc.check_invariants().unwrap();
    }

    #[test]
    fn admission_sees_engine_level_forks() {
        use crate::coordinator::{Request, Scheduler, SchedulerConfig};
        let model = Transformer::new_mha(ModelConfig::tiny(), 23);
        let kvc = KvCacheConfig { block_size: 4, num_blocks: 4, ..Default::default() };
        let mut s = Scheduler::new(
            PagedNativeBackend::new(model, kvc),
            SchedulerConfig { max_active: 8, eos_token: None, kv: kvc, ..Default::default() },
        );
        // One active sequence holding 1 block (4-token prompt). The step
        // runs its admission's prefill chunk so the rows are resident
        // before the fork decodes over them.
        s.admit(Request::new(1, vec![1, 2, 3, 4], 8)).unwrap();
        s.step().unwrap();
        // Fork + decode at the engine level: invisible to the scheduler's
        // shadow allocator, visible to the backend pool.
        s.backend.fork(1, 99).unwrap();
        s.backend.decode(&[(99, 7)]).unwrap().expect_complete();
        assert_eq!(s.backend.free_blocks(), Some(2), "parent block + child boundary block");
        // The shadow allocator is retired for pool-owning backends: the
        // engine allocator is the single owner of block truth, so a
        // 3-block prompt must be rejected on engine state (2 free).
        assert!(s.kv.is_none(), "pooled backend must not carry a shadow allocator");
        let req = Request::new(2, (0u32..12).collect(), 4);
        assert!(!s.has_capacity_for(&req), "admission must query engine pool truth");
        // A prompt that fits the engine pool is still admissible.
        assert!(s.has_capacity_for(&Request::new(3, vec![1, 2, 3], 4)));
    }

    #[test]
    fn dedicated_thread_pool_matches_shared_pool_decode() {
        // `with_thread_pool` gives the engine its own parked worker set;
        // generations must stay bit-identical to the shared-pool engine
        // (the kernel's any-pool/any-width determinism contract).
        let model = Transformer::new_mha(ModelConfig::tiny(), 31);
        let mut shared = PagedNativeBackend::new(model.clone(), kv());
        let mut owned =
            PagedNativeBackend::with_thread_pool(model, kv(), Arc::new(ThreadPool::new(3)));
        assert_eq!(owned.thread_pool().workers(), 3);
        let prompt = [4u32, 8, 15, 16, 23, 42];
        let a = shared.prefill(1, &prompt).unwrap();
        let b = owned.prefill(1, &prompt).unwrap();
        assert_eq!(a, b);
        for tok in [7u32, 99, 3] {
            let x = shared.decode(&[(1, tok)]).unwrap().expect_complete();
            let y = owned.decode(&[(1, tok)]).unwrap().expect_complete();
            assert_eq!(x, y, "dedicated pool diverged from the shared pool at token {tok}");
        }
    }

    #[test]
    fn prefix_cache_hit_is_bitwise_identical_to_cold_prefill() {
        // Invariant 4 at the engine level: serve + release a prompt, then
        // re-serve a request sharing its prefix — the hit's prefill logits
        // and all subsequent decode logits must equal a cold per-sequence
        // run bit for bit.
        let model = Transformer::new_mha(ModelConfig::tiny(), 37);
        let mut engine = PagedNativeBackend::new(model.clone(), kv());
        engine.set_prefix_cache(true);
        let shared: Vec<u32> = (0..11).map(|j| (j * 19 + 3) % 250).collect();
        engine.prefill(1, &shared).unwrap();
        engine.decode(&[(1, 8)]).unwrap().expect_complete();
        engine.release(1);
        assert!(engine.cached_blocks() > 0, "release must seed the radix tree");

        let mut prompt = shared.clone();
        prompt.extend([123u32, 45]);
        let before = engine.prefix_stats();
        let got = engine.prefill(2, &prompt).unwrap();
        let after = engine.prefix_stats();
        assert_eq!(after.hits, before.hits + 1, "second request must hit the cache");
        assert!(after.blocks_saved > before.blocks_saved);

        let mut cache = KvCache::new(model.config.n_layers);
        let want = model.prefill(&mut cache, &prompt);
        assert_eq!(got, want.data, "hit prefill logits must be bit-identical to cold");
        for tok in [7u32, 200, 5, 64] {
            let g = engine.decode(&[(2, tok)]).unwrap().expect_complete();
            let w = model.decode_step(&mut cache, tok);
            assert_eq!(g[0], w.data, "decode after a cache hit diverged at token {tok}");
        }
        engine.release(2);
        engine.alloc.check_invariants().unwrap();
    }

    #[test]
    fn pool_pressure_evicts_cached_blocks() {
        // A full pool with only tree-held blocks must admit a new prompt
        // by evicting LRU leaves, and free_blocks must report the cached
        // blocks as reclaimable beforehand.
        let model = Transformer::new_mha(ModelConfig::tiny(), 41);
        let kvc = KvCacheConfig { block_size: 4, num_blocks: 4, ..Default::default() };
        let mut engine = PagedNativeBackend::new(model, kvc);
        engine.set_prefix_cache(true);
        engine.prefill(1, &(0u32..12).collect::<Vec<_>>()).unwrap(); // 3 blocks
        engine.release(1);
        assert_eq!(engine.cached_blocks(), 3);
        assert_eq!(engine.alloc.free_blocks(), 1);
        assert_eq!(
            engine.free_blocks(),
            Some(4),
            "evictable cached blocks count as reclaimable capacity"
        );
        // An unrelated 16-token prompt needs all 4 blocks: the tree must
        // give its residency back.
        engine.prefill(2, &(100u32..116).collect::<Vec<_>>()).unwrap();
        assert_eq!(engine.cached_blocks(), 0, "pressure must evict the cached prefix");
        assert_eq!(engine.used_blocks(), 4);
        engine.alloc.check_invariants().unwrap();
        assert!(engine.prefix_stats().evicted_blocks >= 3);
        engine.release(2);
    }

    #[test]
    fn partial_hit_under_pressure_falls_back_to_cold_admission() {
        // Regression: the temporary hold on a matched prefix pins that
        // leaf against eviction; when the tail then can't fit, admission
        // must drop the hit and register cold (evicting the leaf) rather
        // than reject a prompt the pool can serve.
        let model = Transformer::new_mha(ModelConfig::tiny(), 53);
        let kvc = KvCacheConfig { block_size: 4, num_blocks: 4, dtype: DType::F32 };
        let mut engine = PagedNativeBackend::new(model.clone(), kvc);
        engine.set_prefix_cache(true);
        let warm: Vec<u32> = (0..12).collect();
        engine.prefill(1, &warm).unwrap();
        engine.release(1);
        assert_eq!((engine.cached_blocks(), engine.alloc.free_blocks()), (3, 1));

        // Shares only the first block (tokens 0..4), then diverges; needs
        // 4 blocks total but only 1 is free and the hold pins the leaf.
        let mut prompt: Vec<u32> = (0..4).collect();
        prompt.extend(200..212);
        let got = engine.prefill(2, &prompt).unwrap();
        let stats = engine.prefix_stats();
        assert_eq!(stats.hits, 0, "dropped hit must be recorded as a miss");
        assert_eq!(engine.cached_blocks(), 0, "fallback must evict the cached leaf");
        // And the cold admission is still bit-identical to the reference.
        let mut cache = KvCache::new(model.config.n_layers);
        let want = model.prefill(&mut cache, &prompt);
        assert_eq!(got, want.data);
        engine.release(2);
        engine.alloc.check_invariants().unwrap();
    }

    #[test]
    fn decode_preempts_youngest_instead_of_erroring() {
        // Two 8-token sequences fill a 4-block pool exactly; both need a
        // boundary block on the next step. The step must not fail: the
        // youngest (seq 2) is preempted, the oldest advances with logits
        // bit-identical to the uninterrupted reference, and the victim
        // resumes bitwise after a replay prefill.
        let model = Transformer::new_mha(ModelConfig::tiny(), 61);
        let kvc = KvCacheConfig { block_size: 4, num_blocks: 4, dtype: DType::F32 };
        let mut engine = PagedNativeBackend::new(model.clone(), kvc);
        engine.set_prefix_cache(false);
        let p1: Vec<u32> = (0..8).collect();
        let p2: Vec<u32> = (100..108).collect();
        engine.prefill(1, &p1).unwrap();
        engine.prefill(2, &p2).unwrap();
        assert_eq!(engine.alloc.free_blocks(), 0);

        let out = engine.decode(&[(1, 7), (2, 9)]).unwrap();
        assert_eq!(out.preempted, vec![2], "the youngest sequence must yield");
        assert!(out.logits[1].is_none());
        let mut c1 = KvCache::new(model.config.n_layers);
        let _ = model.prefill(&mut c1, &p1);
        let w1 = model.decode_step(&mut c1, 7);
        assert_eq!(out.logits[0].as_ref().unwrap(), &w1.data, "survivor diverged");
        assert!(engine.alloc.seq_len(2).is_none(), "victim state must be released");
        assert_eq!(engine.take_step_timing().unwrap().preemptions, 1);
        engine.alloc.check_invariants().unwrap();

        // Resume: replay the victim's token record (just its prompt here)
        // and continue — bit-identical to never having been preempted.
        engine.release(1);
        engine.prefill(2, &p2).unwrap();
        let got = engine.decode(&[(2, 9)]).unwrap().expect_complete();
        let mut c2 = KvCache::new(model.config.n_layers);
        let _ = model.prefill(&mut c2, &p2);
        let w2 = model.decode_step(&mut c2, 9);
        assert_eq!(got[0], w2.data, "resumed decode diverged from uninterrupted run");
        engine.alloc.check_invariants().unwrap();
    }

    #[test]
    fn preemption_donates_history_and_resume_is_bitwise() {
        // With the prefix cache on, a victim's committed full-block
        // history is donated to the radix tree before its table release
        // (a warm start when pressure allows; reclaimable when it
        // doesn't), and a replay-resume continues bit-identically.
        let model = Transformer::new_mha(ModelConfig::tiny(), 59);
        let kvc = KvCacheConfig { block_size: 4, num_blocks: 6, dtype: DType::F32 };
        let mut engine = PagedNativeBackend::new(model.clone(), kvc);
        engine.set_prefix_cache(true);
        let p1: Vec<u32> = (0..8).collect();
        let p2: Vec<u32> = (100..108).collect();
        engine.prefill(1, &p1).unwrap();
        engine.prefill(2, &p2).unwrap();
        let mut c1 = KvCache::new(model.config.n_layers);
        let _ = model.prefill(&mut c1, &p1);
        let mut c2 = KvCache::new(model.config.n_layers);
        let _ = model.prefill(&mut c2, &p2);

        // Decode until growth exhausts the pool and preempts seq 2.
        let mut fed2: Vec<u32> = Vec::new();
        let mut preempted = false;
        for round in 0..6u32 {
            let (t1, t2) = (7 + round, 9 + round);
            let out = engine.decode(&[(1, t1), (2, t2)]).unwrap();
            let w1 = model.decode_step(&mut c1, t1);
            assert_eq!(out.logits[0].as_ref().unwrap(), &w1.data, "round {round}");
            if out.preempted.is_empty() {
                let w2 = model.decode_step(&mut c2, t2);
                assert_eq!(out.logits[1].as_ref().unwrap(), &w2.data, "round {round}");
                fed2.push(t2);
            } else {
                assert_eq!(out.preempted, vec![2], "youngest must be the victim");
                assert!(out.logits[1].is_none());
                preempted = true;
                break;
            }
        }
        assert!(preempted, "the 6-block pool must force a preemption");
        assert!(
            engine.prefix_stats().donated_blocks >= 2,
            "victim must donate its committed full-block prefix"
        );
        engine.alloc.check_invariants().unwrap();

        // Resume: free capacity (seq 1 completes), replay everything the
        // victim committed, continue — bitwise vs the uninterrupted run.
        engine.release(1);
        let mut replay = p2.clone();
        replay.extend(&fed2);
        engine.prefill(2, &replay).unwrap();
        let next = 9 + fed2.len() as u32;
        let got = engine.decode(&[(2, next)]).unwrap().expect_complete();
        let want = model.decode_step(&mut c2, next);
        assert_eq!(got[0], want.data, "resumed decode diverged from uninterrupted run");
        engine.release(2);
        engine.alloc.check_invariants().unwrap();
    }

    #[test]
    fn lone_sequence_exhaustion_still_errors() {
        // The terminal case the acceptance criterion reserves for Err: a
        // single sequence that cannot grow even with the whole pool — no
        // lower-priority victim holds blocks, so preemption cannot help.
        let model = Transformer::new_mha(ModelConfig::tiny(), 67);
        let kvc = KvCacheConfig { block_size: 4, num_blocks: 2, ..Default::default() };
        let mut engine = PagedNativeBackend::new(model, kvc);
        engine.set_prefix_cache(false);
        engine.prefill(1, &(0u32..8).collect::<Vec<_>>()).unwrap(); // fills the pool
        let err = engine.decode(&[(1, 3)]).unwrap_err();
        assert!(
            err.to_string().contains("no preemptible sequence"),
            "unexpected error: {err}"
        );
        engine.alloc.check_invariants().unwrap();
    }

    #[test]
    fn class_preempt_evicts_lowest_class_then_youngest() {
        // Three 8-token sequences fill a 6-block pool exactly; every
        // decode needs a boundary block. Priorities: seq 1 lowest, seq 2
        // highest, seq 3 middle. Gate ON: the *lowest class* (seq 1)
        // yields even though seq 3 is youngest; freeing its 2 blocks lets
        // both survivors grow.
        let model = Transformer::new_mha(ModelConfig::tiny(), 61);
        let kvc = KvCacheConfig { block_size: 4, num_blocks: 6, dtype: DType::F32 };
        let setup = |engine: &mut PagedNativeBackend| {
            engine.set_prefix_cache(false);
            for seq in 1..=3u64 {
                let p: Vec<u32> = (0..8).map(|j| (seq as u32 * 50 + j) % 250).collect();
                engine.prefill(seq, &p).unwrap();
            }
            engine.note_seq_priority(1, 0);
            engine.note_seq_priority(2, 2);
            engine.note_seq_priority(3, 1);
            assert_eq!(engine.alloc.free_blocks(), 0);
        };
        let batch = [(1u64, 7u32), (2, 9), (3, 11)];

        let mut engine = PagedNativeBackend::new(model.clone(), kvc);
        setup(&mut engine);
        engine.set_class_preempt(true);
        let out = engine.decode(&batch).unwrap();
        assert_eq!(out.preempted, vec![1], "lowest class must yield first");
        assert!(out.logits[0].is_none() && out.logits[1].is_some() && out.logits[2].is_some());
        engine.alloc.check_invariants().unwrap();

        // Tie within the lowest class: youngest (largest SeqId) yields.
        let mut engine = PagedNativeBackend::new(model.clone(), kvc);
        setup(&mut engine);
        engine.set_class_preempt(true);
        engine.note_seq_priority(1, 0);
        engine.note_seq_priority(2, 2);
        engine.note_seq_priority(3, 0);
        let out = engine.decode(&batch).unwrap();
        assert_eq!(out.preempted, vec![3], "youngest within the lowest class yields");

        // Gate OFF (default): priorities are ignored — youngest only,
        // bit-identical to the pre-class policy.
        let mut engine = PagedNativeBackend::new(model, kvc);
        setup(&mut engine);
        assert!(!engine.class_preempt_enabled());
        let out = engine.decode(&batch).unwrap();
        assert_eq!(out.preempted, vec![3], "default policy must stay youngest-only");
    }

    #[test]
    fn pool_counters_track_residency() {
        let model = Transformer::new_mha(ModelConfig::tiny(), 43);
        let mut engine = PagedNativeBackend::new(model, kv());
        engine.set_prefix_cache(true);
        let c0 = engine.pool_counters().unwrap();
        assert_eq!(c0.used_blocks, 0);
        assert_eq!(c0.free_blocks, 64);
        engine.prefill(1, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap(); // 2 blocks
        let c1 = engine.pool_counters().unwrap();
        assert_eq!(c1.used_blocks, 2);
        assert_eq!(c1.free_blocks, 62);
        assert_eq!(c1.evictable_blocks, 0, "live tables pin their blocks");
        engine.release(1);
        let c2 = engine.pool_counters().unwrap();
        assert_eq!(c2.prefix_cached_blocks, 2, "release seeds the radix tree");
        assert_eq!(c2.evictable_blocks, 2);
        assert_eq!(c2.free_blocks, 64, "evictable blocks count as reclaimable");
    }

    #[test]
    fn disabling_prefix_cache_releases_residency() {
        let model = Transformer::new_mha(ModelConfig::tiny(), 43);
        let mut engine = PagedNativeBackend::new(model, kv());
        engine.set_prefix_cache(true);
        engine.prefill(1, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        engine.release(1);
        assert!(engine.cached_blocks() > 0);
        engine.set_prefix_cache(false);
        assert!(!engine.prefix_cache_enabled());
        assert_eq!(engine.used_blocks(), 0, "disabling must free every cached block");
        engine.alloc.check_invariants().unwrap();
        // Disabled engines serve normally with zeroed stats.
        engine.prefill(2, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        assert_eq!(engine.prefix_stats(), super::PrefixStats::default());
        engine.release(2);
        assert_eq!(engine.used_blocks(), 0);
    }

    #[test]
    fn step_timing_reports_prefix_counters() {
        let model = Transformer::new_mha(ModelConfig::tiny(), 47);
        let mut engine = PagedNativeBackend::new(model, kv());
        engine.set_prefix_cache(true);
        let prompt: Vec<u32> = (0..9).collect();
        engine.prefill(1, &prompt).unwrap();
        engine.decode(&[(1, 2)]).unwrap().expect_complete();
        let t = engine.take_step_timing().unwrap();
        assert_eq!((t.prefix_hits, t.prefix_misses), (0, 1), "cold admission is a miss");
        engine.release(1);
        engine.prefill(2, &prompt).unwrap();
        engine.decode(&[(2, 2)]).unwrap().expect_complete();
        let t = engine.take_step_timing().unwrap();
        assert_eq!((t.prefix_hits, t.prefix_misses), (1, 0), "warm admission is a hit");
        assert_eq!(t.prefix_blocks_saved, 2, "8 of 9 prompt tokens ride cached blocks");
        engine.decode(&[(2, 3)]).unwrap().expect_complete();
        let t = engine.take_step_timing().unwrap();
        assert_eq!(
            (t.prefix_hits, t.prefix_misses, t.prefix_blocks_saved),
            (0, 0, 0),
            "deltas are consumed per step"
        );
    }

    #[test]
    fn step_timing_reported_and_consumed() {
        let model = Transformer::new_mha(ModelConfig::tiny(), 29);
        let mut engine = PagedNativeBackend::new(model, kv());
        // Cache off: with it on, the prefill's admission counters alone
        // would (correctly) surface a timing entry before any decode.
        engine.set_prefix_cache(false);
        engine.prefill(1, &[1, 2, 3]).unwrap();
        assert!(engine.take_step_timing().is_none(), "no decode step yet");
        engine.decode(&[(1, 9)]).unwrap().expect_complete();
        let t = engine.take_step_timing().expect("decode must record timing");
        assert!(t.attn >= 0.0 && t.gemm >= 0.0);
        assert!(engine.take_step_timing().is_none(), "timing is consumed on take");
    }

    #[test]
    fn serves_through_the_scheduler() {
        use crate::coordinator::{Request, Scheduler, SchedulerConfig};
        let model = Transformer::new_mha(ModelConfig::tiny(), 11);
        let engine = PagedNativeBackend::new(model, kv());
        let mut s = Scheduler::new(
            engine,
            SchedulerConfig { max_active: 8, eos_token: None, kv: kv(), ..Default::default() },
        );
        for i in 0..6u64 {
            s.admit(Request::new(i, vec![5 + i as u32, 6, 7], 4)).unwrap();
        }
        let done = s.drain().unwrap();
        assert_eq!(done.len(), 6);
        assert!(done.iter().all(|r| r.tokens.len() == 4));
        assert_eq!(
            s.backend.used_blocks(),
            s.backend.cached_blocks(),
            "completed seqs must free everything except radix-tree residency"
        );
        s.backend.alloc.check_invariants().unwrap();
    }

    #[test]
    fn scheduler_serving_matches_per_seq_backend() {
        use crate::coordinator::{NativeBackend, Request, Scheduler, SchedulerConfig};
        let model = Transformer::new_mha(ModelConfig::tiny(), 17);
        let cfg =
            SchedulerConfig { max_active: 8, eos_token: None, kv: kv(), ..Default::default() };
        let mut paged = Scheduler::new(PagedNativeBackend::new(model.clone(), kv()), cfg);
        let mut perseq = Scheduler::new(NativeBackend::new(model), cfg);
        for i in 0..5u64 {
            let prompt: Vec<u32> = (0..3 + i).map(|j| (j * 31 + i) as u32).collect();
            paged.admit(Request::new(i, prompt.clone(), 6)).unwrap();
            perseq.admit(Request::new(i, prompt, 6)).unwrap();
        }
        let mut a = paged.drain().unwrap();
        let mut b = perseq.drain().unwrap();
        a.sort_by_key(|r| r.id);
        b.sort_by_key(|r| r.id);
        let ta: Vec<_> = a.iter().map(|r| (r.id, r.tokens.clone())).collect();
        let tb: Vec<_> = b.iter().map(|r| (r.id, r.tokens.clone())).collect();
        assert_eq!(ta, tb, "paged batched serving must reproduce per-seq decode");
    }

    #[test]
    fn chunked_prefill_is_bitwise_identical_to_monolithic() {
        // Invariant 6 at the engine level: begin_prefill + budgeted chunk
        // steps produce the same last-position logits as one monolithic
        // prefill at every budget, and decode continues bitwise after.
        let model = Transformer::new_mha(ModelConfig::tiny(), 71);
        let prompt: Vec<u32> = (0..13).map(|j| (j * 37 + 5) % 250).collect();
        let mut mono = PagedNativeBackend::new(model.clone(), kv());
        mono.set_prefix_cache(false);
        let want = mono.prefill(1, &prompt).unwrap();
        for budget in [1usize, 4, 5, 512] {
            let mut engine = PagedNativeBackend::new(model.clone(), kv());
            engine.set_prefix_cache(false);
            let covered = engine.begin_prefill(1, &prompt).unwrap();
            assert_eq!(covered, 0, "no cache, nothing resident");
            let mut got: Option<Vec<f32>> = None;
            let mut start = covered;
            while start < prompt.len() {
                let n = budget.min(prompt.len() - start);
                let work = [StepWork::PrefillChunk {
                    seq: 1,
                    tokens: prompt[start..start + n].to_vec(),
                    start,
                }];
                let out = engine.step(&work).unwrap().expect_complete();
                let t = engine.take_step_timing().expect("chunk steps record timing");
                assert_eq!((t.prefill_chunks, t.chunked_tokens), (1, n as u64));
                got = out.into_iter().next();
                start += n;
            }
            assert_eq!(
                got.as_deref(),
                Some(&want[..]),
                "budget {budget} diverged from monolithic prefill"
            );
            let g = engine.decode(&[(1, 9)]).unwrap().expect_complete();
            let mut c = KvCache::new(model.config.n_layers);
            let _ = model.prefill(&mut c, &prompt);
            let w = model.decode_step(&mut c, 9);
            assert_eq!(g[0], w.data, "decode after budget-{budget} chunking diverged");
        }
    }

    #[test]
    fn fused_chunk_and_decode_rows_are_bitwise() {
        // A long prompt's chunks ride the same steps as an active
        // sequence's decodes; both must match their per-sequence
        // references bit for bit, and the chunk counters must surface.
        let model = Transformer::new_mha(ModelConfig::tiny(), 73);
        let mut engine = PagedNativeBackend::new(model.clone(), kv());
        engine.set_prefix_cache(false);
        let p1: Vec<u32> = (0..5).collect();
        engine.prefill(1, &p1).unwrap();
        let p2: Vec<u32> = (50..61).collect();
        assert_eq!(engine.begin_prefill(2, &p2).unwrap(), 0);
        let mut c1 = KvCache::new(model.config.n_layers);
        let _ = model.prefill(&mut c1, &p1);
        let mut start = 0usize;
        let mut last: Option<Vec<f32>> = None;
        for (round, tok) in [3u32, 77, 12, 8].into_iter().enumerate() {
            let mut work = vec![StepWork::Decode { seq: 1, token: tok }];
            let n = 4.min(p2.len() - start);
            if n > 0 {
                work.push(StepWork::PrefillChunk {
                    seq: 2,
                    tokens: p2[start..start + n].to_vec(),
                    start,
                });
                start += n;
            }
            let out = engine.step(&work).unwrap().expect_complete();
            let w1 = model.decode_step(&mut c1, tok);
            assert_eq!(out[0], w1.data, "decode row diverged in round {round}");
            let t = engine.take_step_timing().unwrap();
            assert_eq!((t.prefill_chunks, t.chunked_tokens), (u64::from(n > 0), n as u64));
            if let Some(l) = out.into_iter().nth(1) {
                last = Some(l);
            }
        }
        let mut c2 = KvCache::new(model.config.n_layers);
        let want = model.prefill(&mut c2, &p2);
        assert_eq!(last.unwrap(), want.data, "fused chunks diverged from monolithic prefill");
        let g = engine.decode(&[(2, 7)]).unwrap().expect_complete();
        let w = model.decode_step(&mut c2, 7);
        assert_eq!(g[0], w.data, "seq 2 decode after fused prefill diverged");
    }

    #[test]
    fn chunked_prefill_rides_prefix_cache_hits_zero_copy() {
        // A prefix-cache hit under chunked prefill adopts the cached
        // blocks and chunks only the uncovered tail — no contiguous
        // gather, no staging cache — and stays bitwise-equal to a cold
        // monolithic prefill (invariants 4 + 6 composed).
        let model = Transformer::new_mha(ModelConfig::tiny(), 79);
        let mut engine = PagedNativeBackend::new(model.clone(), kv());
        engine.set_prefix_cache(true);
        let shared: Vec<u32> = (0..9).collect();
        engine.prefill(1, &shared).unwrap();
        engine.release(1);
        let mut prompt = shared.clone();
        prompt.extend([101u32, 102, 103]);
        let covered = engine.begin_prefill(2, &prompt).unwrap();
        assert_eq!(covered, 8, "two cached blocks must be adopted");
        let mut last: Option<Vec<f32>> = None;
        let mut start = covered;
        while start < prompt.len() {
            let n = 2.min(prompt.len() - start);
            let work = [StepWork::PrefillChunk {
                seq: 2,
                tokens: prompt[start..start + n].to_vec(),
                start,
            }];
            last = engine.step(&work).unwrap().expect_complete().into_iter().next();
            start += n;
        }
        let mut c = KvCache::new(model.config.n_layers);
        let want = model.prefill(&mut c, &prompt);
        assert_eq!(last.unwrap(), want.data, "hit + chunked tail must equal cold monolithic");
        for tok in [5u32, 9] {
            let g = engine.decode(&[(2, tok)]).unwrap().expect_complete();
            let w = model.decode_step(&mut c, tok);
            assert_eq!(g[0], w.data, "decode after chunked cache hit diverged at {tok}");
        }
        engine.release(2);
        engine.alloc.check_invariants().unwrap();
    }

    #[test]
    fn scheduler_chunked_prefill_matches_monolithic_generation() {
        // Invariant 6 end to end: a long prompt admitted mid-decode
        // generates the same tokens (for itself and for the sequence it
        // shares steps with) at any chunk budget, including unbounded.
        use crate::coordinator::{Request, Scheduler, SchedulerConfig};
        let run = |prefill_chunk: usize| {
            let model = Transformer::new_mha(ModelConfig::tiny(), 83);
            let mut s = Scheduler::new(
                PagedNativeBackend::new(model, kv()),
                SchedulerConfig { max_active: 4, eos_token: None, kv: kv(), prefill_chunk },
            );
            let short: Vec<u32> = (0u32..9).map(|j| j * 7 % 250).collect();
            s.admit(Request::new(1, short, 5)).unwrap();
            s.step().unwrap();
            let long: Vec<u32> = (0u32..23).map(|j| (j * 11 + 1) % 250).collect();
            s.admit(Request::new(2, long, 4)).unwrap();
            let mut done = s.drain().unwrap();
            done.sort_by_key(|r| r.id);
            done.into_iter().map(|r| (r.id, r.tokens)).collect::<Vec<_>>()
        };
        let mono = run(0);
        assert_eq!(mono.len(), 2);
        for budget in [1usize, 4, 7] {
            assert_eq!(run(budget), mono, "budget {budget} changed the token stream");
        }
    }

    #[test]
    fn pool_bytes_report_actual_storage() {
        // Satellite fix for `PagedKvPool::bytes()`: reported bytes are
        // what the pool actually allocates, so f32 -> f16 halves them and
        // the `Backend::kv_pool` metrics surface carries the same truth.
        let model = Transformer::new_mha(ModelConfig::tiny(), 89);
        let shape = |dtype| KvCacheConfig { block_size: 4, num_blocks: 8, dtype };
        let e32 = PagedNativeBackend::new(model.clone(), shape(DType::F32));
        let e16 = PagedNativeBackend::new(model, shape(DType::F16));
        assert!(e32.kv_pool_bytes() > 0);
        assert_eq!(e32.kv_pool_bytes(), 2 * e16.kv_pool_bytes(), "f16 must halve pool bytes");
        assert_eq!(e16.kv_pool(), Some((e16.kv_pool_bytes(), "fp16")));
        assert_eq!(e32.kv_pool(), Some((e32.kv_pool_bytes(), "fp32")));
        assert_eq!(e16.kv_dtype(), DType::F16);
    }

    #[test]
    fn sixteen_bit_pool_matches_quantize_at_write_reference() {
        // Invariant 7 at the engine level (the full matrix lives in
        // `tests/prop_kv_dtype.rs`): a 16-bit pool generates bitwise
        // identically to an f32 pool whose writes pass through
        // `quantize_slice` — across prefill, COW fork, and decode.
        let model = Transformer::new_mha(ModelConfig::tiny(), 97);
        for dt in [DType::F16, DType::BF16] {
            let shape = |dtype| KvCacheConfig { block_size: 4, num_blocks: 64, dtype };
            let mut real = PagedNativeBackend::new(model.clone(), shape(dt));
            let mut reference = PagedNativeBackend::new(model.clone(), shape(DType::F32));
            reference.set_kv_write_quantize(dt);
            let prompt = [7u32, 23, 5, 91, 14, 3, 249];
            let a = real.prefill(1, &prompt).unwrap();
            let b = reference.prefill(1, &prompt).unwrap();
            assert_eq!(a, b, "{dt} prefill logits diverged");
            real.fork(1, 2).unwrap();
            reference.fork(1, 2).unwrap();
            for tok in [3u32, 77, 12, 8] {
                let x = real.decode(&[(1, tok), (2, tok + 1)]).unwrap().expect_complete();
                let y = reference.decode(&[(1, tok), (2, tok + 1)]).unwrap().expect_complete();
                assert_eq!(x, y, "{dt} decode diverged at token {tok}");
            }
        }
    }
}
