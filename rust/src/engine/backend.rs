//! `PagedNativeBackend` — the paged batched decode engine.
//!
//! Drop-in [`Backend`] for the continuous-batching scheduler that replaces
//! [`crate::coordinator::NativeBackend`]'s one-sequence-at-a-time decode
//! (private contiguous `KvCache` per sequence) with:
//!
//! * a single [`crate::engine::PagedKvPool`] holding every sequence's K/V
//!   in shared block-granular storage, leased through the ref-counted
//!   [`BlockAllocator`];
//! * **one batched decode step** for the whole active set: one embedding
//!   gather, per layer one batched RMSNorm + one batched Q/K/V projection
//!   GEMM + one batched paged-attention call + one batched output/FFN
//!   pass, and a single logits GEMM against a cached transposed embedding
//!   — B rows through every weight matrix instead of B separate passes;
//! * ref-counted prefix sharing: [`PagedNativeBackend::fork`] duplicates
//!   block *tables* only, so forked sequences dedup K/V memory, with
//!   copy-on-write the first time a fork writes into a shared tail block.
//!
//! Every row-level operation (embedding, RMSNorm, GEMM row, attention
//! accumulation order, FFN, logits) is arithmetically identical to the
//! per-sequence path, so batched paged decode returns *bit-identical*
//! logits to `Transformer::decode_step` for MHA and BDA alike — the
//! paper's losslessness claim carried through the serving engine (see
//! `tests/prop_coordinator.rs`).

use crate::attention::paged::{paged_attention_decode_on, PagedSeq};
use crate::coordinator::kv_cache::{BlockAllocator, KvCacheConfig, KvError, SeqId};
use crate::coordinator::metrics::StepTiming;
use crate::coordinator::scheduler::Backend;
use crate::model::transformer::{KvCache, Transformer};
use crate::model::weights::FusedQkv;
use crate::tensor::matmul::matmul;
use crate::tensor::Tensor;
use crate::util::threadpool::{self, ThreadPool};
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;
use std::time::Instant;

/// Paged batched serving backend over the native Rust transformer.
pub struct PagedNativeBackend {
    pub model: Transformer,
    /// Block bookkeeping: tables, ref counts, copy-on-write decisions.
    pub alloc: BlockAllocator,
    /// Block storage: the K/V rows the tables point at.
    pool: super::paged_kv::PagedKvPool,
    /// Cached `embed.transpose()` for the tied LM head (the per-sequence
    /// path re-transposes it every decode step).
    embed_t: Tensor,
    /// Per-layer packed Q/K/V projection weights (one concatenated GEMM
    /// per layer instead of three), precomputed at construction.
    fused_qkv: Vec<FusedQkv>,
    /// Attention/GEMM wall-time split of the most recent decode step,
    /// consumed by the scheduler via [`Backend::take_step_timing`].
    last_timing: Option<StepTiming>,
    /// Persistent parked worker pool running the paged-attention hot path.
    /// Defaults to a handle on the process-wide pool; a dedicated pool
    /// ([`PagedNativeBackend::with_thread_pool`]) gives this engine its
    /// own worker set — groundwork for multi-worker sharding. GEMMs
    /// dispatched through the tensor wrappers still use the process pool.
    threads: Arc<ThreadPool>,
}

impl PagedNativeBackend {
    pub fn new(model: Transformer, kv: KvCacheConfig) -> PagedNativeBackend {
        PagedNativeBackend::with_thread_pool(model, kv, Arc::clone(threadpool::global()))
    }

    /// Construct with an explicit worker pool: this engine's batched
    /// paged-attention steps dispatch on `threads` instead of the
    /// process-wide pool. Output is bit-identical on any pool at any
    /// width (the kernel's determinism contract).
    pub fn with_thread_pool(
        model: Transformer,
        kv: KvCacheConfig,
        threads: Arc<ThreadPool>,
    ) -> PagedNativeBackend {
        let widths: Vec<usize> =
            model.blocks.iter().map(|b| b.attn.effective_shape().proj_width()).collect();
        let embed_t = model.embed.transpose();
        let fused_qkv = model.blocks.iter().map(|b| b.attn.pack_qkv()).collect();
        PagedNativeBackend {
            alloc: BlockAllocator::new(kv),
            pool: super::paged_kv::PagedKvPool::new(kv, &widths),
            embed_t,
            fused_qkv,
            last_timing: None,
            threads,
            model,
        }
    }

    /// Pool sized by the default [`KvCacheConfig`].
    pub fn with_default_pool(model: Transformer) -> PagedNativeBackend {
        PagedNativeBackend::new(model, KvCacheConfig::default())
    }

    /// The worker pool this engine dispatches paged attention on.
    pub fn thread_pool(&self) -> &Arc<ThreadPool> {
        &self.threads
    }

    /// Fork `child` from `parent`: shares every current block (table copy +
    /// ref-count bump), so the fork costs zero K/V memory until the child
    /// diverges — at which point copy-on-write gives it a private tail
    /// block. The K/V dedup counterpart of the allocator-level `fork`.
    ///
    /// When this backend is driven by a [`crate::coordinator::Scheduler`],
    /// admission capacity is queried through [`Backend::free_blocks`] —
    /// this allocator, the engine truth — so blocks consumed by forks and
    /// their copy-on-write divergence are visible to admission even though
    /// the scheduler's own bookkeeping allocator never sees them. (Full
    /// ownership unification — one allocator, preemption — remains a
    /// ROADMAP item.)
    pub fn fork(&mut self, parent: SeqId, child: SeqId) -> Result<(), KvError> {
        self.alloc.fork(parent, child)
    }

    /// Total pool capacity in bytes at the model's logical dtype.
    pub fn kv_pool_bytes(&self) -> usize {
        self.pool.bytes(self.model.dtype)
    }

    /// Blocks currently leased (dedup makes this less than the sum of
    /// per-sequence lengths when forks share prefixes).
    pub fn used_blocks(&self) -> usize {
        self.alloc.used_blocks()
    }

    /// Scatter a contiguous per-layer K/V cache (as produced by
    /// `Transformer::prefill`) into this sequence's leased blocks.
    fn scatter_prefill(&mut self, seq: SeqId, cache: &KvCache) -> Result<()> {
        let bs = self.alloc.config.block_size;
        let blocks = self
            .alloc
            .seq_blocks(seq)
            .ok_or_else(|| anyhow!("scatter: unknown seq {seq}"))?
            .to_vec();
        for (li, layer) in cache.layers.iter().enumerate() {
            let width = layer.width;
            debug_assert_eq!(width, self.pool.width(li));
            for t in 0..layer.len {
                self.pool.write_row(
                    li,
                    blocks[t / bs],
                    t % bs,
                    &layer.k[t * width..(t + 1) * width],
                    &layer.v[t * width..(t + 1) * width],
                );
            }
        }
        Ok(())
    }
}

impl Backend for PagedNativeBackend {
    fn vocab_size(&self) -> usize {
        self.model.config.vocab_size
    }

    fn max_seq_len(&self) -> usize {
        self.model.config.max_seq_len
    }

    fn prefill(&mut self, seq: SeqId, prompt: &[u32]) -> Result<Vec<f32>> {
        if prompt.is_empty() {
            bail!("prefill: empty prompt for seq {seq}");
        }
        self.alloc
            .register(seq, prompt.len())
            .map_err(|e| anyhow!("prefill seq {seq}: {e}"))?;
        // Prompt processing reuses the reference prefill (identical logits
        // by construction); the engine's batching win is the decode loop,
        // where steps outnumber prefills max_new_tokens to one.
        let mut cache = KvCache::new(self.model.config.n_layers);
        let logits = self.model.prefill(&mut cache, prompt);
        self.scatter_prefill(seq, &cache)?;
        Ok(logits.data)
    }

    /// The batched decode step: all sequences advance one token in one
    /// pass over the model.
    fn decode(&mut self, seqs: &[(SeqId, u32)]) -> Result<Vec<Vec<f32>>> {
        if seqs.is_empty() {
            return Ok(Vec::new());
        }
        let b = seqs.len();
        let d = self.model.config.d_model;

        // Lease a write slot per sequence (copy-on-write against forks),
        // then embed each last token at its own position.
        let mut x = Tensor::zeros(&[b, d]);
        let mut slots = Vec::with_capacity(b);
        let mut lens = Vec::with_capacity(b);
        for (i, &(id, tok)) in seqs.iter().enumerate() {
            let pos = self
                .alloc
                .seq_len(id)
                .ok_or_else(|| anyhow!("decode: unknown seq {id}"))?;
            let slot = self
                .alloc
                .append_token_cow(id)
                .map_err(|e| anyhow!("decode seq {id}: {e}"))?;
            if let Some(src) = slot.copied_from {
                self.pool.copy_block(src, slot.block);
            }
            let row = self.model.embed_tokens(&[tok], pos);
            x.row_mut(i).copy_from_slice(row.row(0));
            slots.push(slot);
            lens.push(pos + 1);
        }

        // Block tables are final once every append above has run, so the
        // gather views are built once and shared by all layers.
        let views: Vec<PagedSeq> = seqs
            .iter()
            .zip(lens.iter())
            .map(|(&(id, _), &len)| PagedSeq {
                blocks: self.alloc.seq_blocks(id).expect("registered above"),
                len,
            })
            .collect();

        let mut attn_secs = 0.0f64;
        let mut gemm_secs = 0.0f64;
        for (li, block) in self.model.blocks.iter().enumerate() {
            let s = block.attn.effective_shape();
            let width = s.proj_width();
            let h = x.rmsnorm(&block.norm1, 1e-5);
            let t = Instant::now();
            // One packed GEMM for Q|K|V (bit-identical to the three
            // separate projections; see `FusedQkv`).
            let (q, k, v) = self.fused_qkv[li].project(&h, &block.attn);
            gemm_secs += t.elapsed().as_secs_f64();
            for (i, slot) in slots.iter().enumerate() {
                self.pool.write_row(
                    li,
                    slot.block,
                    slot.slot,
                    &k.data[i * width..(i + 1) * width],
                    &v.data[i * width..(i + 1) * width],
                );
            }
            let layer = self.pool.layer_view(li);
            let t = Instant::now();
            let workers = self.threads.workers();
            let attn_out = paged_attention_decode_on(&self.threads, &q, &layer, &views, s, workers);
            attn_secs += t.elapsed().as_secs_f64();
            let t = Instant::now();
            let y = block.attn.output(&attn_out);
            let x1 = x.add(&y);
            x = block.ffn(&x1);
            gemm_secs += t.elapsed().as_secs_f64();
        }

        let h = x.rmsnorm(&self.model.norm_f, 1e-5);
        let t = Instant::now();
        let logits = matmul(&h, &self.embed_t);
        gemm_secs += t.elapsed().as_secs_f64();
        self.last_timing = Some(StepTiming { attn: attn_secs, gemm: gemm_secs });
        Ok((0..b).map(|i| logits.row(i).to_vec()).collect())
    }

    fn release(&mut self, seq: SeqId) {
        // Blocks return to the pool when their ref count hits zero; forks
        // still holding shared blocks keep them alive.
        let _ = self.alloc.release(seq);
    }

    /// Engine pool truth for admission: this allocator sees every lease —
    /// prefills, decode appends, *and* engine-level forks / copy-on-write
    /// blocks that the scheduler's shadow allocator cannot know about.
    fn free_blocks(&self) -> Option<usize> {
        Some(self.alloc.free_blocks())
    }

    fn take_step_timing(&mut self) -> Option<StepTiming> {
        self.last_timing.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bd::Strategy;
    use crate::model::ModelConfig;
    use crate::tensor::DType;

    fn kv() -> KvCacheConfig {
        KvCacheConfig { block_size: 4, num_blocks: 64 }
    }

    #[test]
    fn prefill_matches_reference() {
        let model = Transformer::new_mha(ModelConfig::tiny(), 5);
        let mut engine = PagedNativeBackend::new(model.clone(), kv());
        let prompt = [7u32, 23, 5, 91, 14];
        let got = engine.prefill(1, &prompt).unwrap();
        let mut cache = KvCache::new(model.config.n_layers);
        let want = model.prefill(&mut cache, &prompt);
        assert_eq!(got, want.data);
    }

    #[test]
    fn batched_decode_is_bit_identical_to_per_seq() {
        let model = Transformer::new_mha(ModelConfig::tiny(), 9);
        let mut engine = PagedNativeBackend::new(model.clone(), kv());
        let prompts: [&[u32]; 3] = [&[1, 2, 3], &[9, 4, 17, 200, 31], &[250]];
        let mut caches = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            engine.prefill(i as SeqId, p).unwrap();
            let mut c = KvCache::new(model.config.n_layers);
            let _ = model.prefill(&mut c, p);
            caches.push(c);
        }
        for round in 0..4u32 {
            let batch: Vec<(SeqId, u32)> =
                (0..3).map(|i| (i as SeqId, round * 3 + i as u32)).collect();
            let got = engine.decode(&batch).unwrap();
            for (i, c) in caches.iter_mut().enumerate() {
                let want = model.decode_step(c, batch[i].1);
                assert_eq!(got[i], want.data, "round {round} seq {i}");
            }
        }
    }

    #[test]
    fn bda_batched_decode_matches_bda_per_seq() {
        let mha = Transformer::new_mha(ModelConfig::tiny(), 13);
        let model = mha.to_bda(Strategy::ResidualMin, DType::F32).unwrap();
        let mut engine = PagedNativeBackend::new(model.clone(), kv());
        engine.prefill(1, &[5, 6, 7, 8, 9]).unwrap();
        let mut cache = KvCache::new(model.config.n_layers);
        let _ = model.prefill(&mut cache, &[5, 6, 7, 8, 9]);
        for tok in [3u32, 77, 12] {
            let got = engine.decode(&[(1, tok)]).unwrap();
            let want = model.decode_step(&mut cache, tok);
            assert_eq!(got[0], want.data);
        }
    }

    #[test]
    fn fork_dedups_kv_and_cow_isolates_parent() {
        let model = Transformer::new_mha(ModelConfig::tiny(), 21);
        let mut engine = PagedNativeBackend::new(model.clone(), kv());
        let prompt = [11u32, 42, 3, 8, 100]; // 5 tokens -> partial tail block
        engine.prefill(1, &prompt).unwrap();
        let used_parent = engine.used_blocks();

        // Fork shares all blocks: zero extra K/V memory.
        engine.fork(1, 2).unwrap();
        assert_eq!(engine.used_blocks(), used_parent, "fork must dedup K/V blocks");

        // Child decodes first: copy-on-write in the shared tail block.
        let child = engine.decode(&[(2, 7)]).unwrap();
        engine.alloc.check_invariants().unwrap();

        // Parent decodes the same token afterwards; its storage must be
        // untouched by the child's write — verify against the reference.
        let parent = engine.decode(&[(1, 7)]).unwrap();
        let mut cache = KvCache::new(model.config.n_layers);
        let _ = model.prefill(&mut cache, &prompt);
        let want = model.decode_step(&mut cache, 7);
        assert_eq!(parent[0], want.data, "child COW corrupted the parent");
        assert_eq!(child[0], want.data, "identical histories must agree");

        // Releasing the child frees only its private COW block.
        engine.release(2);
        assert_eq!(engine.used_blocks(), used_parent);
        engine.release(1);
        assert_eq!(engine.used_blocks(), 0);
        engine.alloc.check_invariants().unwrap();
    }

    #[test]
    fn admission_sees_engine_level_forks() {
        use crate::coordinator::{Request, Scheduler, SchedulerConfig};
        let model = Transformer::new_mha(ModelConfig::tiny(), 23);
        let kvc = KvCacheConfig { block_size: 4, num_blocks: 4 };
        let mut s = Scheduler::new(
            PagedNativeBackend::new(model, kvc),
            SchedulerConfig { max_active: 8, eos_token: None, kv: kvc },
        );
        // One active sequence holding 1 block (4-token prompt).
        s.admit(Request::new(1, vec![1, 2, 3, 4], 8)).unwrap();
        // Fork + decode at the engine level: invisible to the scheduler's
        // shadow allocator, visible to the backend pool.
        s.backend.fork(1, 99).unwrap();
        s.backend.decode(&[(99, 7)]).unwrap();
        assert_eq!(s.backend.free_blocks(), Some(2), "parent block + child boundary block");
        // Shadow allocator (1 block used of 4) would wrongly admit a
        // 3-block prompt; engine truth (2 free) must reject it.
        assert!(s.kv.can_admit(12));
        let req = Request::new(2, (0u32..12).collect(), 4);
        assert!(!s.has_capacity_for(&req), "admission must query engine pool truth");
        // A prompt that fits the engine pool is still admissible.
        assert!(s.has_capacity_for(&Request::new(3, vec![1, 2, 3], 4)));
    }

    #[test]
    fn dedicated_thread_pool_matches_shared_pool_decode() {
        // `with_thread_pool` gives the engine its own parked worker set;
        // generations must stay bit-identical to the shared-pool engine
        // (the kernel's any-pool/any-width determinism contract).
        let model = Transformer::new_mha(ModelConfig::tiny(), 31);
        let mut shared = PagedNativeBackend::new(model.clone(), kv());
        let mut owned =
            PagedNativeBackend::with_thread_pool(model, kv(), Arc::new(ThreadPool::new(3)));
        assert_eq!(owned.thread_pool().workers(), 3);
        let prompt = [4u32, 8, 15, 16, 23, 42];
        let a = shared.prefill(1, &prompt).unwrap();
        let b = owned.prefill(1, &prompt).unwrap();
        assert_eq!(a, b);
        for tok in [7u32, 99, 3] {
            let x = shared.decode(&[(1, tok)]).unwrap();
            let y = owned.decode(&[(1, tok)]).unwrap();
            assert_eq!(x, y, "dedicated pool diverged from the shared pool at token {tok}");
        }
    }

    #[test]
    fn step_timing_reported_and_consumed() {
        let model = Transformer::new_mha(ModelConfig::tiny(), 29);
        let mut engine = PagedNativeBackend::new(model, kv());
        engine.prefill(1, &[1, 2, 3]).unwrap();
        assert!(engine.take_step_timing().is_none(), "no decode step yet");
        engine.decode(&[(1, 9)]).unwrap();
        let t = engine.take_step_timing().expect("decode must record timing");
        assert!(t.attn >= 0.0 && t.gemm >= 0.0);
        assert!(engine.take_step_timing().is_none(), "timing is consumed on take");
    }

    #[test]
    fn serves_through_the_scheduler() {
        use crate::coordinator::{Request, Scheduler, SchedulerConfig};
        let model = Transformer::new_mha(ModelConfig::tiny(), 11);
        let engine = PagedNativeBackend::new(model, kv());
        let mut s = Scheduler::new(
            engine,
            SchedulerConfig { max_active: 8, eos_token: None, kv: kv() },
        );
        for i in 0..6u64 {
            s.admit(Request::new(i, vec![5 + i as u32, 6, 7], 4)).unwrap();
        }
        let done = s.drain().unwrap();
        assert_eq!(done.len(), 6);
        assert!(done.iter().all(|r| r.tokens.len() == 4));
        assert_eq!(s.backend.used_blocks(), 0, "completed seqs must free their blocks");
    }

    #[test]
    fn scheduler_serving_matches_per_seq_backend() {
        use crate::coordinator::{NativeBackend, Request, Scheduler, SchedulerConfig};
        let model = Transformer::new_mha(ModelConfig::tiny(), 17);
        let cfg = SchedulerConfig { max_active: 8, eos_token: None, kv: kv() };
        let mut paged = Scheduler::new(PagedNativeBackend::new(model.clone(), kv()), cfg);
        let mut perseq = Scheduler::new(NativeBackend::new(model), cfg);
        for i in 0..5u64 {
            let prompt: Vec<u32> = (0..3 + i).map(|j| (j * 31 + i) as u32).collect();
            paged.admit(Request::new(i, prompt.clone(), 6)).unwrap();
            perseq.admit(Request::new(i, prompt, 6)).unwrap();
        }
        let mut a = paged.drain().unwrap();
        let mut b = perseq.drain().unwrap();
        a.sort_by_key(|r| r.id);
        b.sort_by_key(|r| r.id);
        let ta: Vec<_> = a.iter().map(|r| (r.id, r.tokens.clone())).collect();
        let tb: Vec<_> = b.iter().map(|r| (r.id, r.tokens.clone())).collect();
        assert_eq!(ta, tb, "paged batched serving must reproduce per-seq decode");
    }
}
