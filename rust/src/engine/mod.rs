//! The paged batched decode engine — the default native serving path.
//!
//! Three parts:
//!
//! * [`paged_kv::PagedKvPool`] — contiguous per-layer K/V block storage,
//!   the real memory behind the coordinator's ref-counted
//!   [`crate::coordinator::kv_cache::BlockAllocator`] bookkeeping;
//! * [`backend::PagedNativeBackend`] — a drop-in scheduler
//!   [`crate::coordinator::Backend`] that decodes the entire active set in
//!   a single batched step against paged storage: per layer, one **fused
//!   Q/K/V packed GEMM** ([`crate::model::weights::FusedQkv`], precomputed
//!   at construction) + the **blocked parallel**
//!   [`crate::attention::paged::paged_attention_decode`] + one logits
//!   GEMM, with fork/copy-on-write prefix sharing that dedups K/V memory.
//!   It reports its attention/GEMM wall-time split per step through
//!   [`crate::coordinator::StepTiming`] and exposes pool truth to
//!   scheduler admission via `Backend::free_blocks`;
//! * [`prefix_cache::PrefixCache`] — a radix tree over released
//!   sequences' prompts whose nodes own ref-counted block fragments in
//!   the pool: **automatic cross-request K/V prompt sharing** (SGLang-style
//!   RadixAttention). Admission adopts the longest cached whole-block
//!   prefix zero-copy and prefills only the uncovered tail; LRU zero-ref
//!   leaves are evicted under pool pressure (and counted as reclaimable
//!   capacity by admission). On by default; `BDA_PREFIX_CACHE=0`
//!   disables.
//!
//! All parallel regions of the decode step run on the **persistent parked
//! worker pool** ([`crate::util::threadpool`]): workers are created once
//! and woken per dispatch, so the per-layer-per-step thread spawn/join of
//! the scoped implementation is gone and per-worker scratch survives
//! across layers and steps. Each engine holds a pool handle — the
//! process-wide pool by default, or a dedicated pool via
//! [`backend::PagedNativeBackend::with_thread_pool`] — which is what lets
//! the sharded server ([`crate::coordinator::Server::start_sharded`],
//! `BDA_WORKERS`) run N engines as pool-shard workers, each with its own
//! KV pool, prefix-cache shard, and thread pool, behind the prefix-aware
//! router ([`crate::coordinator::router`]).
//!
//! When a decode step exhausts the pool *and* the tree has nothing left
//! to evict, the engine **preempts** the youngest batch member — donating
//! its committed full-block prefix to the prefix cache, releasing its
//! blocks, and reporting it through
//! [`crate::coordinator::scheduler::DecodeOutcome`] — instead of failing
//! the batched step. The scheduler parks preempted sequences and
//! re-admits them ahead of the waiting queue by replaying their token
//! record through the prefill path (recompute-on-resume).
//!
//! # Load-bearing invariants
//!
//! Every optimization in the serving layer is constrained by eight
//! bit-exactness invariants, stated here once and property-tested in
//! `tests/prop_paged_parallel.rs`, `tests/prop_coordinator.rs`,
//! `tests/prop_preemption.rs`, `tests/prop_kv_dtype.rs`, and
//! `tests/prop_sharded.rs`:
//!
//! 1. **Paged batched decode is bit-identical to per-sequence decode.**
//!    Every row-level operation of the batched step (embedding, RMSNorm,
//!    GEMM row, attention accumulation, FFN, logits) is arithmetically
//!    identical to `Transformer::decode_step`, for MHA and BDA alike —
//!    the paper's losslessness claim carried through the engine.
//! 2. **Parallel attention is bit-identical to the serial reference.**
//!    The blocked kernel assigns `(sequence, head)` work items to workers
//!    dynamically, but per-row accumulation order is fixed and work items
//!    never share accumulators, so output does not depend on the worker
//!    count, the pool instance, or the assignment of items to workers
//!    (`BDA_NUM_THREADS` is a pure performance knob).
//! 3. **COW append isolates forks.** [`backend::PagedNativeBackend::fork`]
//!    copies block *tables* only; both sequences share K/V blocks until
//!    one appends into a shared tail block, at which point
//!    `BlockAllocator::append_token_cow` gives the writer a private copy
//!    first. A fork therefore never observes — or causes — a change in
//!    the other sequence's history, and identical histories decode to
//!    bit-identical logits whether or not they share storage.
//! 4. **A prefix-cache hit is bit-identical to a cold prefill.** Causal
//!    attention makes the K/V row at position `t` a function of tokens
//!    `0..=t` only, and every operator on the path (GEMM rows, RMSNorm,
//!    paged attention) is row-deterministic — so two requests sharing a
//!    token prefix produce identical prefix K/V. Adopting the cached
//!    blocks ([`prefix_cache::PrefixCache`]) and prefilling only the
//!    uncovered tail therefore yields the same logits, bit for bit, as
//!    prefilling the whole prompt from scratch — for MHA and BDA alike.
//!    Prompt caching is pure data reuse, never an approximation.
//! 5. **Preempt→resume is bit-identical to an uninterrupted run.** A
//!    preempted sequence's K/V is discarded entirely; its resume replays
//!    the token record (prompt + tokens generated so far, minus the
//!    not-yet-written last token) through the prefill path. Because every
//!    K/V row is a row-deterministic function of its own token and
//!    position (the same fact behind invariants 1 and 4), the recomputed
//!    state equals the released state float for float, so the resumed
//!    sequence's remaining generation — greedy or seeded-sampled — is
//!    exactly what the uninterrupted run would have produced, for MHA and
//!    BDA alike. Preemption trades recompute for memory, never output.
//! 6. **Chunked prefill is bit-identical to monolithic prefill.** Splitting
//!    a prompt into fixed-token-budget chunks (`BDA_PREFILL_CHUNK`) that
//!    ride batched decode steps changes neither the prompt's K/V nor its
//!    first-token logits: each chunk's rows attend causally over already-
//!    resident blocks plus themselves, in the same per-row accumulation
//!    order as a whole-prompt prefill (a monolithic prefill *is* the
//!    single-chunk special case of the same step), and every other
//!    operator on the path is row-wise. Holds at any budget, fused with
//!    any mix of live decode rows, across prefix-cache hits and
//!    preempt→resume replays — so the chunk budget is a pure
//!    TBT-vs-throughput knob, never a numerics knob.
//! 7. **A 16-bit pool equals quantize-at-write f32 storage, bitwise.**
//!    With `BDA_KV_DTYPE=f16|bf16` the pool stores K/V blocks as real
//!    `u16` words ([`paged_kv::PagedKvPool`]): rows are narrowed once at
//!    write (round-to-nearest-even) and widened exactly at the kernel
//!    boundary — widening a 16-bit value to f32 is lossless, so
//!    `widen(narrow(x)) == quantize(x)` bit for bit, and block copies
//!    (COW, prefix-cache donation/readoption) move stored words verbatim
//!    without re-rounding. A 16-bit pool therefore generates exactly what
//!    an f32 pool whose writes pass through `DType::quantize_slice`
//!    would — quantize-at-write is the reference semantics — and because
//!    the widened rows feed the same f32 accumulation order as native
//!    f32 storage, invariants 2–6 extend to 16-bit storage by
//!    composition. Storage width halves pool bytes and changes rounded
//!    K/V values; it never introduces nondeterminism. (Invariant 1 is
//!    the deliberate exception: the per-sequence reference stores f32,
//!    so paged == per-seq is pinned to f32 pools.)
//! 8. **Placement is unobservable in the token stream.** For a fixed
//!    request set, every request's token stream is bitwise identical at
//!    any worker count and any placement: the prefix-aware router
//!    ([`crate::coordinator::router`], `BDA_WORKERS`) never splits or
//!    migrates a sequence across pool shards, each shard runs the
//!    unchanged scheduler loop, and invariants 1–6 pin each scheduler's
//!    per-request output regardless of what else shares its batch, pool,
//!    or prefix cache. Routing inputs (cached-prefix length, free
//!    blocks, queue depth, preemption churn) therefore steer only
//!    *where* work runs — cost, never content. Property-tested for MHA
//!    and BDA at worker counts {1, 2, 4}, prefix cache on and off, over
//!    preempting per-shard pools (`tests/prop_sharded.rs`).
//!
//! BDA's losslessness (every QK inner product preserved, §3.4) makes the
//! engine attention-variant-agnostic: the same pool and batched step serve
//! MHA and BDA models bit-identically to per-sequence decode.

pub mod backend;
pub mod paged_kv;
pub mod prefix_cache;

pub use backend::PagedNativeBackend;
pub use paged_kv::PagedKvPool;
pub use prefix_cache::{PrefixCache, PrefixStats};
