//! The paged batched decode engine — the default native serving path.
//!
//! Two halves:
//!
//! * [`paged_kv::PagedKvPool`] — contiguous per-layer K/V block storage,
//!   the real memory behind the coordinator's ref-counted
//!   [`crate::coordinator::kv_cache::BlockAllocator`] bookkeeping;
//! * [`backend::PagedNativeBackend`] — a drop-in scheduler
//!   [`crate::coordinator::Backend`] that decodes the entire active set in
//!   a single batched step against paged storage: per layer, one **fused
//!   Q/K/V packed GEMM** ([`crate::model::weights::FusedQkv`], precomputed
//!   at construction) + the **blocked parallel**
//!   [`crate::attention::paged::paged_attention_decode`] (worker count via
//!   `BDA_NUM_THREADS`, bit-identical at any setting) + one logits GEMM,
//!   with fork/copy-on-write prefix sharing that dedups K/V memory. It
//!   reports its attention/GEMM wall-time split per step through
//!   [`crate::coordinator::StepTiming`] and exposes pool truth to
//!   scheduler admission via `Backend::free_blocks`.
//!
//! BDA's losslessness (every QK inner product preserved, §3.4) makes the
//! engine attention-variant-agnostic: the same pool and batched step serve
//! MHA and BDA models bit-identically to per-sequence decode.

pub mod backend;
pub mod paged_kv;

pub use backend::PagedNativeBackend;
pub use paged_kv::PagedKvPool;
