//! Weight packing and serialization for the pure-Rust transformer.
//!
//! Two halves:
//!
//! * [`FusedQkv`] — runtime weight packing: the per-layer Q/K/V projection
//!   weights concatenated into one matrix at engine construction, so the
//!   batched decode step issues a single `[B×d] @ [d×(q+k+v)]` GEMM
//!   instead of three kernel launches. Bit-identical to the separate
//!   projections (each output element touches exactly one packed column,
//!   in the same accumulation order), so the engine's losslessness
//!   contract survives the fusion.
//! * [`Checkpoint`] — binary serialization: magic + JSON header (config +
//!   tensor index) + raw little-endian f32 payloads. Lets prepared
//!   (BDA/low-rank/BD) models be deployed without re-running preparation —
//!   the "4s offline prep, then ship" workflow of the paper.

use crate::attention::kproj::kproj_bda;
use crate::attention::AttnShape;
use crate::bd::Tag;
use crate::model::config::ModelConfig;
use crate::model::AttentionImpl;
use crate::tensor::matmul::matmul;
use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Packed Q/K/V projection weights for one attention block, precomputed
/// once (at backend construction) and reused every decode step.
///
/// Every variant is constructed so that its `project` output is
/// **bitwise identical** to [`AttentionImpl::project_qkv`]: per output
/// element the same multiply-adds run in the same order (GEMM column
/// independence; identical k-blocking because the inner dimension is
/// unchanged), only the number of kernel launches differs.
#[derive(Clone, Debug)]
pub enum FusedQkv {
    /// All three projections are plain GEMMs (MHA, structured pruning):
    /// one packed `d × (q_cols + k_cols + v_cols)` weight, one GEMM,
    /// split into Q | K | V.
    Dense { packed: Tensor, q_cols: usize, k_cols: usize },
    /// BDA compact-basis fusion (requires a shared basis tag for the QK
    /// and VO sides): Q stays one GEMM against `b_q`; K' and V' fuse into
    /// a single widened k-projection — the repeated basis slice
    /// initializes both halves of the output and one strided GEMM over
    /// `X_rest` accumulates against the packed `[C_qk | C_vo]`.
    CompactBasis { b_q: Tensor, c_packed: Tensor, tag: Tag, shape: AttnShape },
    /// No packing available for this attention variant (per-projection
    /// low-rank layers, or BDA with differing QK/VO tags); fall back to
    /// the unfused path.
    Unfused,
}

impl FusedQkv {
    /// Pack the projection weights of an attention block, if its variant
    /// admits a fused form.
    pub fn pack(attn: &AttentionImpl) -> FusedQkv {
        match attn {
            AttentionImpl::Mha(w) => FusedQkv::Dense {
                packed: Tensor::concat_cols(&[&w.wq, &w.wk, &w.wv]),
                q_cols: w.wq.cols(),
                k_cols: w.wk.cols(),
            },
            AttentionImpl::Pruned(p) => FusedQkv::Dense {
                packed: Tensor::concat_cols(&[&p.wq, &p.wk, &p.wv]),
                q_cols: p.wq.cols(),
                k_cols: p.wk.cols(),
            },
            AttentionImpl::Bda(w) if w.tag_qk == w.tag_vo => FusedQkv::CompactBasis {
                b_q: w.b_qk.clone(),
                c_packed: Tensor::concat_cols(&[&w.c_qk, &w.c_vo]),
                tag: w.tag_qk,
                shape: w.shape,
            },
            _ => FusedQkv::Unfused,
        }
    }

    /// Q/K/V projections through the packed weights; falls back to
    /// `attn.project_qkv` for [`FusedQkv::Unfused`]. Output is bitwise
    /// identical to the fallback in every case.
    pub fn project(&self, x: &Tensor, attn: &AttentionImpl) -> (Tensor, Tensor, Tensor) {
        match self {
            FusedQkv::Dense { packed, q_cols, k_cols } => {
                let qkv = matmul(x, packed);
                let q = qkv.slice_cols(0, *q_cols);
                let k = qkv.slice_cols(*q_cols, *q_cols + *k_cols);
                let v = qkv.slice_cols(*q_cols + *k_cols, qkv.cols());
                (q, k, v)
            }
            FusedQkv::CompactBasis { b_q, c_packed, tag, shape } => {
                let q = matmul(x, b_q);
                // One k-projection at doubled head count computes K' | V'
                // in a single fused pass: the basis repeat covers heads
                // 0..n (K) and n..2n (V), the GEMM reads X_rest once.
                let wide = AttnShape::new(shape.d, shape.n_heads * 2, shape.d_h);
                let kv = kproj_bda(x, c_packed, *tag, wide);
                let w = shape.proj_width();
                let k = kv.slice_cols(0, w);
                let v = kv.slice_cols(w, 2 * w);
                (q, k, v)
            }
            FusedQkv::Unfused => attn.project_qkv(x),
        }
    }
}

const MAGIC: &[u8; 8] = b"BDAW0001";

/// A named collection of tensors + the model config (enough to rebuild the
/// dense-MHA transformer; converted forms are re-derived deterministically
/// from strategy + dtype, which is cheap).
pub struct Checkpoint {
    pub config: ModelConfig,
    pub tensors: Vec<(String, Tensor)>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut index = Vec::new();
        let mut offset = 0usize;
        for (name, t) in &self.tensors {
            index.push(Json::obj(vec![
                ("name", Json::str(name.clone())),
                ("shape", Json::arr(t.shape.iter().map(|&d| Json::num(d as f64)))),
                ("offset", Json::num(offset as f64)),
            ]));
            offset += t.numel() * 4;
        }
        let header = Json::obj(vec![
            ("config", self.config.to_json()),
            ("tensors", Json::Arr(index)),
        ])
        .to_string();

        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, t) in &self.tensors {
            // Little-endian f32 payload.
            let bytes: Vec<u8> = t.data.iter().flat_map(|v| v.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f =
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad magic in {}", path.display());
        }
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8)?;
        let hlen = u64::from_le_bytes(len8) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow!("checkpoint header: {e}"))?;
        let config = ModelConfig::from_json(&header.get("config"))
            .ok_or_else(|| anyhow!("bad config in header"))?;
        let mut rest = Vec::new();
        f.read_to_end(&mut rest)?;

        let mut tensors = Vec::new();
        for entry in header.get("tensors").as_arr().unwrap_or(&[]) {
            let name = entry.get("name").as_str().unwrap_or_default().to_string();
            let shape: Vec<usize> = entry
                .get("shape")
                .as_arr()
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default();
            let offset = entry.get("offset").as_usize().unwrap_or(0);
            let numel: usize = shape.iter().product();
            let end = offset + numel * 4;
            if end > rest.len() {
                bail!("tensor {name} out of bounds ({end} > {})", rest.len());
            }
            let data: Vec<f32> = rest[offset..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.push((name, Tensor::from_vec(data, &shape)));
        }
        Ok(Checkpoint { config, tensors })
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }
}

/// Export a dense-MHA transformer's weights.
pub fn export_mha(model: &crate::model::Transformer) -> Result<Checkpoint> {
    let mut tensors = vec![("embed".to_string(), model.embed.clone())];
    for (i, b) in model.blocks.iter().enumerate() {
        let crate::model::AttentionImpl::Mha(w) = &b.attn else {
            bail!("export_mha requires a dense-MHA model (block {i} is converted)");
        };
        tensors.push((format!("blocks.{i}.wq"), w.wq.clone()));
        tensors.push((format!("blocks.{i}.wk"), w.wk.clone()));
        tensors.push((format!("blocks.{i}.wv"), w.wv.clone()));
        tensors.push((format!("blocks.{i}.wo"), w.wo.clone()));
        for (name, lin) in
            [("w_gate", &b.w_gate), ("w_up", &b.w_up), ("w_down", &b.w_down)]
        {
            tensors.push((format!("blocks.{i}.{name}"), lin.to_dense()));
        }
        tensors.push((format!("blocks.{i}.norm1"), Tensor::from_vec(b.norm1.clone(), &[b.norm1.len()])));
        tensors.push((format!("blocks.{i}.norm2"), Tensor::from_vec(b.norm2.clone(), &[b.norm2.len()])));
    }
    tensors.push(("norm_f".to_string(), Tensor::from_vec(model.norm_f.clone(), &[model.norm_f.len()])));
    Ok(Checkpoint { config: model.config.clone(), tensors })
}

/// Rebuild a dense-MHA transformer from a checkpoint.
pub fn import_mha(ckpt: &Checkpoint) -> Result<crate::model::Transformer> {
    use crate::attention::mha::MhaWeights;
    use crate::model::lowrank::Linear;
    use crate::model::transformer::Block;
    let config = ckpt.config.clone();
    let shape = config.attn_shape();
    let need = |name: &str| -> Result<Tensor> {
        ckpt.get(name).cloned().ok_or_else(|| anyhow!("missing tensor {name}"))
    };
    let mut blocks = Vec::with_capacity(config.n_layers);
    for i in 0..config.n_layers {
        blocks.push(Block {
            attn: crate::model::AttentionImpl::Mha(MhaWeights {
                shape,
                wq: need(&format!("blocks.{i}.wq"))?,
                wk: need(&format!("blocks.{i}.wk"))?,
                wv: need(&format!("blocks.{i}.wv"))?,
                wo: need(&format!("blocks.{i}.wo"))?,
            }),
            norm1: need(&format!("blocks.{i}.norm1"))?.data,
            norm2: need(&format!("blocks.{i}.norm2"))?.data,
            w_gate: Linear::dense(need(&format!("blocks.{i}.w_gate"))?),
            w_up: Linear::dense(need(&format!("blocks.{i}.w_up"))?),
            w_down: Linear::dense(need(&format!("blocks.{i}.w_down"))?),
        });
    }
    Ok(crate::model::Transformer {
        embed: need("embed")?,
        norm_f: need("norm_f")?.data,
        blocks,
        config,
        dtype: crate::tensor::DType::F32,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Transformer;

    #[test]
    fn roundtrip_preserves_logits() {
        let model = Transformer::new_mha(ModelConfig::tiny(), 8);
        let ckpt = export_mha(&model).unwrap();
        let dir = std::env::temp_dir().join("bda_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.bdaw");
        ckpt.save(&path).unwrap();

        let loaded = Checkpoint::load(&path).unwrap();
        let model2 = import_mha(&loaded).unwrap();
        let toks = [1u32, 5, 9, 42];
        let a = model.forward_full(&toks);
        let b = model2.forward_full(&toks);
        assert_eq!(a, b, "checkpoint round-trip must be bit-exact");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("bda_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bdaw");
        std::fs::write(&path, b"NOTMAGIC rest").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn export_converted_model_fails_cleanly() {
        let model = Transformer::new_mha(ModelConfig::tiny(), 9);
        let bda = model.to_bda(crate::bd::Strategy::FirstR, crate::tensor::DType::F32).unwrap();
        assert!(export_mha(&bda).is_err());
    }

    #[test]
    fn get_by_name() {
        let model = Transformer::new_mha(ModelConfig::tiny(), 10);
        let ckpt = export_mha(&model).unwrap();
        assert!(ckpt.get("embed").is_some());
        assert!(ckpt.get("blocks.0.wq").is_some());
        assert!(ckpt.get("nope").is_none());
    }
}
