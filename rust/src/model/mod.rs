//! Model definitions: configs, weights, a pure-Rust decoder-only
//! transformer (the CPU reference used for PPL evaluation and the Table 3
//! model-level benches), and low-rank pruning.

pub mod config;
pub mod lowrank;
pub mod transformer;
pub mod weights;

pub use config::ModelConfig;
pub use transformer::{Transformer, AttentionImpl};
