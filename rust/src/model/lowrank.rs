//! Linear-layer representations: dense, low-rank (SVD-pruned, Zhao et al.
//! 2025 style), and BD form — the three columns of Table 3.

use crate::bd::{BdLinear, Strategy};
use crate::linalg::svd::truncated_svd;
use crate::tensor::matmul::matmul;
use crate::tensor::Tensor;

/// A linear layer `y = x W` in one of three storage forms.
#[derive(Clone, Debug)]
pub enum Linear {
    /// Dense d_in × d_out weight.
    Dense(Tensor),
    /// Low-rank factors: U d_in×r, V d_out×r; y = (xU)V^T.
    LowRank { u: Tensor, v: Tensor },
    /// BD form (from low-rank): y = [h, hC] with h = xB.
    Bd(BdLinear),
}

impl Linear {
    pub fn dense(w: Tensor) -> Linear {
        assert_eq!(w.ndim(), 2);
        Linear::Dense(w)
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        match self {
            Linear::Dense(w) => matmul(x, w),
            Linear::LowRank { u, v } => matmul(&matmul(x, u), &v.transpose()),
            Linear::Bd(l) => l.forward(x),
        }
    }

    pub fn d_in(&self) -> usize {
        match self {
            Linear::Dense(w) => w.rows(),
            Linear::LowRank { u, .. } => u.rows(),
            Linear::Bd(l) => l.d_in,
        }
    }

    pub fn d_out(&self) -> usize {
        match self {
            Linear::Dense(w) => w.cols(),
            Linear::LowRank { v, .. } => v.rows(),
            Linear::Bd(l) => l.d_out,
        }
    }

    /// Stored parameter count.
    pub fn param_count(&self) -> usize {
        match self {
            Linear::Dense(w) => w.numel(),
            Linear::LowRank { u, v } => u.numel() + v.numel(),
            Linear::Bd(l) => l.param_count(),
        }
    }

    /// FLOPs for a batch of L rows.
    pub fn flops(&self, l: usize) -> u64 {
        let (m, n) = (self.d_in() as u64, self.d_out() as u64);
        match self {
            Linear::Dense(_) => 2 * l as u64 * m * n,
            Linear::LowRank { u, .. } => {
                let r = u.cols() as u64;
                2 * l as u64 * r * (m + n)
            }
            Linear::Bd(bd) => {
                let r = bd.r as u64;
                2 * l as u64 * r * (m + n - r)
            }
        }
    }

    /// Prune to low-rank at `density` (fraction of dense parameter count):
    /// rank r = density·mn/(m+n), the Zhao et al. (2025) setting of Table 3.
    pub fn to_lowrank(&self, density: f64) -> Linear {
        let w = self.to_dense();
        let (m, n) = (w.rows(), w.cols());
        let r = ((density * (m * n) as f64) / (m + n) as f64).round().max(1.0) as usize;
        let r = r.min(m.min(n) - 1).max(1);
        let (us, v) = truncated_svd(&w, r);
        Linear::LowRank { u: us, v }
    }

    /// Transform a low-rank layer to BD form (the Table 3 "BD (from
    /// low-rank)" column). No-op params change for Dense (panics — callers
    /// must prune first, matching the paper's pipeline).
    pub fn to_bd(&self, strategy: Strategy) -> Linear {
        match self {
            Linear::LowRank { u, v } => {
                Linear::Bd(BdLinear::from_lowrank(u, v, strategy).expect("bd from lowrank"))
            }
            _ => panic!("to_bd requires a low-rank layer (paper pipeline: prune, then BD)"),
        }
    }

    /// Materialize the dense weight (for tests / conversions).
    pub fn to_dense(&self) -> Tensor {
        match self {
            Linear::Dense(w) => w.clone(),
            Linear::LowRank { u, v } => matmul(u, &v.transpose()),
            Linear::Bd(l) => crate::bd::reconstruct_col(l.tag, &l.b, &l.c),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_to_lowrank_to_bd_pipeline() {
        let w = Tensor::randn(&[48, 32], 0.2, 1);
        let dense = Linear::dense(w);
        let lr = dense.to_lowrank(0.8);
        let bd = lr.to_bd(Strategy::ResidualMin);
        // BD matches its low-rank source exactly.
        let x = Tensor::randn(&[5, 48], 1.0, 2);
        let y_lr = lr.forward(&x);
        let y_bd = bd.forward(&x);
        assert!(y_bd.max_abs_diff(&y_lr) < 1e-3, "diff {}", y_bd.max_abs_diff(&y_lr));
        // And params strictly decrease along the pipeline.
        assert!(lr.param_count() < dense.param_count());
        assert!(bd.param_count() < lr.param_count());
    }

    #[test]
    fn density_controls_params() {
        let w = Tensor::randn(&[64, 64], 0.2, 3);
        let dense = Linear::dense(w);
        let lr80 = dense.to_lowrank(0.8);
        let lr50 = dense.to_lowrank(0.5);
        let ratio80 = lr80.param_count() as f64 / dense.param_count() as f64;
        let ratio50 = lr50.param_count() as f64 / dense.param_count() as f64;
        assert!((ratio80 - 0.8).abs() < 0.05, "{ratio80}");
        assert!((ratio50 - 0.5).abs() < 0.05, "{ratio50}");
    }

    #[test]
    fn flops_ordering() {
        let w = Tensor::randn(&[64, 64], 0.2, 4);
        let dense = Linear::dense(w);
        let lr = dense.to_lowrank(0.8);
        let bd = lr.to_bd(Strategy::FirstR);
        assert!(lr.flops(16) < dense.flops(16));
        assert!(bd.flops(16) < lr.flops(16));
    }

    #[test]
    fn lowrank_is_best_approximation_sanity() {
        // On an exactly low-rank matrix, pruning at its rank is lossless.
        let u = Tensor::randn(&[32, 6], 0.3, 5);
        let v = Tensor::randn(&[24, 6], 0.3, 6);
        let w = matmul(&u, &v.transpose());
        let dense = Linear::dense(w.clone());
        // density for rank 6: 6*(32+24)/(32*24) = 0.4375
        let lr = dense.to_lowrank(0.4375);
        let x = Tensor::randn(&[4, 32], 1.0, 7);
        assert!(lr.forward(&x).max_abs_diff(&dense.forward(&x)) < 1e-3);
    }

    #[test]
    #[should_panic]
    fn bd_from_dense_panics() {
        let dense = Linear::dense(Tensor::randn(&[8, 8], 1.0, 8));
        let _ = dense.to_bd(Strategy::FirstR);
    }

    #[test]
    fn to_dense_roundtrip() {
        let w = Tensor::randn(&[20, 16], 0.3, 9);
        let dense = Linear::dense(w.clone());
        let lr = dense.to_lowrank(0.9);
        let bd = lr.to_bd(Strategy::ResidualMin);
        // bd.to_dense() must equal lr.to_dense() (BD is lossless on it).
        assert!(bd.to_dense().max_abs_diff(&lr.to_dense()) < 1e-3);
    }
}
