//! Pure-Rust decoder-only transformer.
//!
//! This is the L3 CPU reference model used for: Fig. 2a / Table 5 PPL
//! evaluation (MHA vs BDA, per dtype/strategy), the Table 3 dense /
//! low-rank / BD model-level benches (throughput with and without KV
//! cache, memory, PPL), and cross-validation against the AOT-compiled JAX
//! model. Positional information enters at the embedding layer (GPT-style
//! sinusoidal), which keeps BD fully lossless (Appendix D).
//!
//! In serving, this model is driven by the paged batched engine
//! ([`crate::engine`]); the per-sequence [`Transformer::decode_step`] path
//! here is the bit-exactness reference the engine's batched step is
//! property-tested against. Its GEMMs dispatch on the persistent worker
//! pool ([`crate::util::threadpool`]) like every other parallel region.

use crate::attention::bda::BdaWeights;
use crate::attention::mha::MhaWeights;
use crate::attention::pruning::PrunedAttention;
use crate::attention::AttnShape;
use crate::bd::{BdError, Strategy};
use crate::model::config::ModelConfig;
use crate::model::lowrank::Linear;
use crate::tensor::matmul::matmul;
use crate::tensor::{DType, Tensor};

/// Attention implementation used by a block — the experimental axis of the
/// paper's evaluation.
#[derive(Clone, Debug)]
pub enum AttentionImpl {
    /// Algorithm 1 (dense MHA).
    Mha(MhaWeights),
    /// Algorithm 2 (BD Attention).
    Bda(BdaWeights),
    /// Per-projection `Linear` layers (dense / low-rank / BD-from-low-rank:
    /// the §3.3 path used in Table 3).
    Projected { q: Linear, k: Linear, v: Linear, o: Linear, shape: AttnShape },
    /// Structured K/V channel pruning baseline (Fig. 2a dashed line).
    Pruned(PrunedAttention),
}

impl AttentionImpl {
    /// Effective per-head width of K/V (differs for Pruned).
    pub fn effective_shape(&self) -> AttnShape {
        match self {
            AttentionImpl::Mha(w) => w.shape,
            AttentionImpl::Bda(w) => w.shape,
            AttentionImpl::Projected { shape, .. } => *shape,
            AttentionImpl::Pruned(p) => AttnShape::new(p.shape.d, p.shape.n_heads, p.d_h_kept),
        }
    }

    /// Q/K/V projections for a whole sequence.
    pub fn project_qkv(&self, x: &Tensor) -> (Tensor, Tensor, Tensor) {
        match self {
            AttentionImpl::Mha(w) => {
                (matmul(x, &w.wq), matmul(x, &w.wk), matmul(x, &w.wv))
            }
            AttentionImpl::Bda(w) => {
                let q = matmul(x, &w.b_qk);
                let (k, v) = w.project_kv(x);
                (q, k, v)
            }
            AttentionImpl::Projected { q, k, v, .. } => {
                (q.forward(x), k.forward(x), v.forward(x))
            }
            AttentionImpl::Pruned(p) => {
                (matmul(x, &p.wq), matmul(x, &p.wk), matmul(x, &p.wv))
            }
        }
    }

    /// Pack this block's Q/K/V projection weights for the batched decode
    /// engine: one concatenated GEMM (or the BDA compact-basis fusion)
    /// instead of three kernel launches, precomputed once at backend
    /// construction. See [`crate::model::weights::FusedQkv`] for the
    /// bit-exactness argument.
    pub fn pack_qkv(&self) -> crate::model::weights::FusedQkv {
        crate::model::weights::FusedQkv::pack(self)
    }

    /// Output projection of concatenated head outputs.
    pub fn output(&self, concat: &Tensor) -> Tensor {
        match self {
            AttentionImpl::Mha(w) => matmul(concat, &w.wo),
            AttentionImpl::Bda(w) => matmul(concat, &w.b_vo),
            AttentionImpl::Projected { o, .. } => o.forward(concat),
            AttentionImpl::Pruned(p) => matmul(concat, &p.wo),
        }
    }

    pub fn param_count(&self) -> usize {
        match self {
            AttentionImpl::Mha(w) => w.param_count(),
            AttentionImpl::Bda(w) => w.param_count(),
            AttentionImpl::Projected { q, k, v, o, .. } => {
                q.param_count() + k.param_count() + v.param_count() + o.param_count()
            }
            AttentionImpl::Pruned(p) => {
                p.wq.numel() + p.wk.numel() + p.wv.numel() + p.wo.numel()
            }
        }
    }
}

/// One transformer block: pre-norm attention + pre-norm SwiGLU FFN.
#[derive(Clone, Debug)]
pub struct Block {
    pub attn: AttentionImpl,
    pub norm1: Vec<f32>,
    pub norm2: Vec<f32>,
    pub w_gate: Linear,
    pub w_up: Linear,
    pub w_down: Linear,
}

impl Block {
    fn forward(&self, x: &Tensor, causal: bool) -> Tensor {
        let s = self.attn.effective_shape();
        let h = x.rmsnorm(&self.norm1, 1e-5);
        let (q, k, v) = self.attn.project_qkv(&h);
        let attn_out = attend(&q, &k, &v, s, causal);
        let y = self.attn.output(&attn_out);
        let x1 = x.add(&y);
        self.ffn(&x1)
    }

    /// Post-attention half of the block: pre-norm SwiGLU FFN + residual.
    /// Public so the paged decode engine ([`crate::engine`]) can run the
    /// attention half against paged K/V storage and reuse this path
    /// unchanged (keeping batched decode bit-identical to [`Block::forward`]).
    pub fn ffn(&self, x1: &Tensor) -> Tensor {
        let h2 = x1.rmsnorm(&self.norm2, 1e-5);
        let gated = self.w_gate.forward(&h2).silu().mul_elem(&self.w_up.forward(&h2));
        let ffn = self.w_down.forward(&gated);
        x1.add(&ffn)
    }

    fn param_count(&self) -> usize {
        self.attn.param_count()
            + self.norm1.len()
            + self.norm2.len()
            + self.w_gate.param_count()
            + self.w_up.param_count()
            + self.w_down.param_count()
    }
}

/// Per-head attention with causal mask over a full sequence.
fn attend(q: &Tensor, k: &Tensor, v: &Tensor, s: AttnShape, causal: bool) -> Tensor {
    let scale = 1.0 / (s.d_h as f32).sqrt();
    let mut outs = Vec::with_capacity(s.n_heads);
    for i in 0..s.n_heads {
        let qi = q.slice_cols(i * s.d_h, (i + 1) * s.d_h);
        let ki = k.slice_cols(i * s.d_h, (i + 1) * s.d_h);
        let vi = v.slice_cols(i * s.d_h, (i + 1) * s.d_h);
        let scores = matmul(&qi, &ki.transpose()).scale(scale);
        let probs = if causal { scores.softmax_rows_causal(0) } else { scores.softmax_rows() };
        outs.push(matmul(&probs, &vi));
    }
    let refs: Vec<&Tensor> = outs.iter().collect();
    Tensor::concat_cols(&refs)
}

/// Per-layer KV cache for incremental decoding.
#[derive(Clone, Debug, Default)]
pub struct LayerKv {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub len: usize,
    pub width: usize,
}

/// Whole-model decode cache.
#[derive(Clone, Debug, Default)]
pub struct KvCache {
    pub layers: Vec<LayerKv>,
}

impl KvCache {
    pub fn new(n_layers: usize) -> KvCache {
        KvCache { layers: vec![LayerKv::default(); n_layers] }
    }

    pub fn seq_len(&self) -> usize {
        self.layers.first().map(|l| l.len).unwrap_or(0)
    }

    /// Bytes held by the cache at a logical dtype.
    pub fn bytes(&self, dtype: DType) -> usize {
        self.layers.iter().map(|l| (l.k.len() + l.v.len()) * dtype.size_bytes()).sum()
    }
}

/// Decoder-only transformer with tied embeddings.
#[derive(Clone, Debug)]
pub struct Transformer {
    pub config: ModelConfig,
    /// vocab × d embedding (tied with the LM head).
    pub embed: Tensor,
    pub blocks: Vec<Block>,
    pub norm_f: Vec<f32>,
    /// Logical dtype for memory accounting (weights are carried in f32).
    pub dtype: DType,
}

impl Transformer {
    /// Build a dense-MHA model with deterministic init.
    pub fn new_mha(config: ModelConfig, seed: u64) -> Transformer {
        let d = config.d_model;
        let shape = config.attn_shape();
        let blocks = (0..config.n_layers)
            .map(|l| {
                let s = seed + 1000 * (l as u64 + 1);
                Block {
                    attn: AttentionImpl::Mha(MhaWeights::random(shape, s)),
                    norm1: vec![1.0; d],
                    norm2: vec![1.0; d],
                    w_gate: Linear::dense(Tensor::randn(&[d, config.d_ff], 0.02, s + 10)),
                    w_up: Linear::dense(Tensor::randn(&[d, config.d_ff], 0.02, s + 11)),
                    w_down: Linear::dense(Tensor::randn(&[config.d_ff, d], 0.02, s + 12)),
                }
            })
            .collect();
        Transformer {
            embed: Tensor::randn(&[config.vocab_size, d], 0.02, seed),
            blocks,
            norm_f: vec![1.0; d],
            config,
            dtype: DType::F32,
        }
    }

    /// Replace every MHA block with BDA (Algorithm 3 over the whole model).
    /// Returns per-layer stats via the weights. Fails if any basis is
    /// exactly singular (probability 0 per Theorem 3.1).
    pub fn to_bda(&self, strategy: Strategy, dtype: DType) -> Result<Transformer, BdError> {
        let mut out = self.clone();
        for b in out.blocks.iter_mut() {
            if let AttentionImpl::Mha(w) = &b.attn {
                b.attn = AttentionImpl::Bda(BdaWeights::prepare(w, strategy, dtype)?);
            }
        }
        Ok(out)
    }

    /// Convert all linear layers (attention projections + FFN) to low-rank
    /// at the given density — the Table 3 "Low rank 80%" model.
    pub fn to_lowrank(&self, density: f64) -> Transformer {
        let mut out = self.clone();
        for b in out.blocks.iter_mut() {
            // Attention becomes per-projection low-rank.
            if let AttentionImpl::Mha(w) = &b.attn {
                b.attn = AttentionImpl::Projected {
                    q: Linear::dense(w.wq.clone()).to_lowrank(density),
                    k: Linear::dense(w.wk.clone()).to_lowrank(density),
                    v: Linear::dense(w.wv.clone()).to_lowrank(density),
                    o: Linear::dense(w.wo.clone()).to_lowrank(density),
                    shape: w.shape,
                };
            }
            b.w_gate = b.w_gate.to_lowrank(density);
            b.w_up = b.w_up.to_lowrank(density);
            b.w_down = b.w_down.to_lowrank(density);
        }
        out
    }

    /// Transform a low-rank model's layers to BD form — the Table 3
    /// "BD (from low-rank)" model. Lossless w.r.t. the low-rank model.
    pub fn to_bd_from_lowrank(&self, strategy: Strategy) -> Transformer {
        let mut out = self.clone();
        for b in out.blocks.iter_mut() {
            if let AttentionImpl::Projected { q, k, v, o, shape } = &b.attn {
                b.attn = AttentionImpl::Projected {
                    q: q.to_bd(strategy),
                    k: k.to_bd(strategy),
                    v: v.to_bd(strategy),
                    o: o.to_bd(strategy),
                    shape: *shape,
                };
            }
            b.w_gate = b.w_gate.to_bd(strategy);
            b.w_up = b.w_up.to_bd(strategy);
            b.w_down = b.w_down.to_bd(strategy);
        }
        out
    }

    /// Structured K/V pruning baseline at `frac` (Fig. 2a dashed line).
    pub fn to_pruned(&self, frac: f64) -> Transformer {
        let mut out = self.clone();
        for b in out.blocks.iter_mut() {
            if let AttentionImpl::Mha(w) = &b.attn {
                b.attn = AttentionImpl::Pruned(PrunedAttention::from_mha(w, frac));
            }
        }
        out
    }

    /// Sinusoidal positional encoding row (GPT-style, embedding-level).
    fn pos_row(&self, pos: usize, out: &mut [f32]) {
        let d = self.config.d_model;
        for k in 0..d / 2 {
            let theta = pos as f32 / 10000f32.powf(2.0 * k as f32 / d as f32);
            out[2 * k] += theta.sin();
            out[2 * k + 1] += theta.cos();
        }
    }

    /// Token embedding + positional encoding for positions
    /// [pos0, pos0+len). Public for the paged decode engine, which embeds
    /// each batched sequence at its own position.
    pub fn embed_tokens(&self, tokens: &[u32], pos0: usize) -> Tensor {
        let d = self.config.d_model;
        let mut x = Tensor::zeros(&[tokens.len(), d]);
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize % self.config.vocab_size;
            x.row_mut(i).copy_from_slice(self.embed.row(t));
            let row = x.row_mut(i);
            self.pos_row(pos0 + i, row);
        }
        x
    }

    /// Full-sequence causal forward: logits (L × vocab).
    pub fn forward_full(&self, tokens: &[u32]) -> Tensor {
        let mut x = self.embed_tokens(tokens, 0);
        for b in &self.blocks {
            x = b.forward(&x, true);
        }
        let h = x.rmsnorm(&self.norm_f, 1e-5);
        matmul(&h, &self.embed.transpose())
    }

    /// Prefill the KV cache with a prompt and return logits for the last
    /// position (1 × vocab).
    pub fn prefill(&self, cache: &mut KvCache, tokens: &[u32]) -> Tensor {
        assert_eq!(cache.layers.len(), self.blocks.len());
        let mut x = self.embed_tokens(tokens, cache.seq_len());
        for (li, b) in self.blocks.iter().enumerate() {
            let s = b.attn.effective_shape();
            let h = x.rmsnorm(&b.norm1, 1e-5);
            let (q, k, v) = b.attn.project_qkv(&h);
            let layer = &mut cache.layers[li];
            layer.width = s.proj_width();
            let prior = layer.len;
            layer.k.extend_from_slice(&k.data);
            layer.v.extend_from_slice(&v.data);
            layer.len += tokens.len();
            let attn_out = attend_cached(&q, layer, s, prior);
            let y = b.attn.output(&attn_out);
            let x1 = x.add(&y);
            x = b.ffn(&x1);
        }
        let h = x.slice_rows(x.rows() - 1, x.rows()).rmsnorm(&self.norm_f, 1e-5);
        matmul(&h, &self.embed.transpose())
    }

    /// Decode one token with the cache; returns logits (1 × vocab).
    pub fn decode_step(&self, cache: &mut KvCache, token: u32) -> Tensor {
        self.prefill(cache, &[token])
    }

    pub fn param_count(&self) -> usize {
        self.embed.numel()
            + self.norm_f.len()
            + self.blocks.iter().map(|b| b.param_count()).sum::<usize>()
    }

    /// Logical weight memory at the model's dtype (Table 3 "Memory").
    pub fn weight_bytes(&self) -> usize {
        self.param_count() * self.dtype.size_bytes()
    }
}

/// Attention over cached K/V for `q` rows at positions
/// [prior, prior + q.rows()).
fn attend_cached(q: &Tensor, layer: &LayerKv, s: AttnShape, prior: usize) -> Tensor {
    let l_q = q.rows();
    let l_kv = layer.len;
    let width = s.proj_width();
    let scale = 1.0 / (s.d_h as f32).sqrt();
    let mut out = Tensor::zeros(&[l_q, width]);
    for h in 0..s.n_heads {
        let off = h * s.d_h;
        for i in 0..l_q {
            let visible = (prior + i + 1).min(l_kv);
            // scores over visible cache rows
            let mut scores = vec![0.0f32; visible];
            let qrow = &q.data[i * width + off..i * width + off + s.d_h];
            for t in 0..visible {
                let krow = &layer.k[t * width + off..t * width + off + s.d_h];
                scores[t] = qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for v in scores.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            let orow = &mut out.data[i * width + off..i * width + off + s.d_h];
            for t in 0..visible {
                let w = scores[t] * inv;
                let vrow = &layer.v[t * width + off..t * width + off + s.d_h];
                for (o, vv) in orow.iter_mut().zip(vrow) {
                    *o += w * vv;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Transformer {
        Transformer::new_mha(ModelConfig::tiny(), 42)
    }

    #[test]
    fn forward_shapes() {
        let m = tiny();
        let logits = m.forward_full(&[1, 2, 3, 4]);
        assert_eq!(logits.shape, vec![4, m.config.vocab_size]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn bda_model_matches_mha_model() {
        // The headline claim at model level: identical logits (fp32 prep).
        let m = tiny();
        let bda = m.to_bda(Strategy::ResidualMin, DType::F32).unwrap();
        let toks = [5u32, 9, 17, 3, 250, 8];
        let a = m.forward_full(&toks);
        let b = bda.forward_full(&toks);
        let rel = (b.max_abs_diff(&a) as f64) / a.fro_norm().max(1e-9);
        assert!(rel < 1e-4, "rel {rel}");
    }

    #[test]
    fn bda_reduces_params() {
        let m = tiny();
        let bda = m.to_bda(Strategy::FirstR, DType::F32).unwrap();
        assert!(bda.param_count() < m.param_count());
        // Reduction equals 2·(d_h/d) of the K+V projections.
        let s = m.config.attn_shape();
        let per_layer_saving = 2 * s.d_h * s.proj_width();
        assert_eq!(m.param_count() - bda.param_count(), m.config.n_layers * per_layer_saving);
    }

    #[test]
    fn lowrank_then_bd_preserves_lowrank_outputs() {
        let m = tiny();
        let lr = m.to_lowrank(0.8);
        let bd = lr.to_bd_from_lowrank(Strategy::ResidualMin);
        let toks = [1u32, 2, 3, 4, 5];
        let a = lr.forward_full(&toks);
        let b = bd.forward_full(&toks);
        let rel = (b.max_abs_diff(&a) as f64) / a.fro_norm().max(1e-9);
        assert!(rel < 1e-3, "rel {rel}");
        assert!(bd.param_count() < lr.param_count());
        assert!(lr.param_count() < m.param_count());
    }

    #[test]
    fn lowrank_is_lossy_vs_dense() {
        let m = tiny();
        let lr = m.to_lowrank(0.8);
        let toks = [1u32, 2, 3, 4];
        let a = m.forward_full(&toks);
        let b = lr.forward_full(&toks);
        assert!(b.max_abs_diff(&a) > 1e-5);
    }

    #[test]
    fn pruned_model_runs_and_shrinks() {
        let m = tiny();
        let p = m.to_pruned(0.25);
        assert!(p.param_count() < m.param_count());
        let logits = p.forward_full(&[1, 2, 3]);
        assert_eq!(logits.shape, vec![3, m.config.vocab_size]);
    }

    #[test]
    fn cached_decode_matches_full_forward() {
        let m = tiny();
        let toks = [7u32, 23, 5, 91, 14];
        let full = m.forward_full(&toks);
        // Prefill 3, decode 2 — the last-row logits must match.
        let mut cache = KvCache::new(m.config.n_layers);
        let _ = m.prefill(&mut cache, &toks[..3]);
        let _ = m.decode_step(&mut cache, toks[3]);
        let logits = m.decode_step(&mut cache, toks[4]);
        let expect = full.slice_rows(4, 5);
        assert!(
            logits.max_abs_diff(&expect) < 1e-3,
            "diff {}",
            logits.max_abs_diff(&expect)
        );
    }

    #[test]
    fn bda_cached_decode_matches_mha() {
        let m = tiny();
        let bda = m.to_bda(Strategy::ResidualMin, DType::F32).unwrap();
        let toks = [3u32, 200, 41, 7];
        let mut c1 = KvCache::new(m.config.n_layers);
        let mut c2 = KvCache::new(m.config.n_layers);
        let _ = m.prefill(&mut c1, &toks[..3]);
        let _ = bda.prefill(&mut c2, &toks[..3]);
        let a = m.decode_step(&mut c1, toks[3]);
        let b = bda.decode_step(&mut c2, toks[3]);
        let rel = (b.max_abs_diff(&a) as f64) / a.fro_norm().max(1e-9);
        assert!(rel < 1e-4, "rel {rel}");
    }

    #[test]
    fn packed_qkv_matches_separate_projections_bitwise() {
        // The fused-GEMM contract: packed projection == three separate
        // GEMMs, bit for bit, for every packable attention variant.
        let x = Tensor::randn(&[5, ModelConfig::tiny().d_model], 1.0, 77);
        let mha = tiny();
        let bda = mha.to_bda(Strategy::FirstR, DType::F32).unwrap();
        let pruned = mha.to_pruned(0.5);
        for (label, model) in [("mha", &mha), ("bda", &bda), ("pruned", &pruned)] {
            for (li, block) in model.blocks.iter().enumerate() {
                let fused = block.attn.pack_qkv();
                let (q0, k0, v0) = block.attn.project_qkv(&x);
                let (q1, k1, v1) = fused.project(&x, &block.attn);
                assert_eq!(q0.data, q1.data, "{label} layer {li}: Q must be bit-identical");
                assert_eq!(k0.data, k1.data, "{label} layer {li}: K must be bit-identical");
                assert_eq!(v0.data, v1.data, "{label} layer {li}: V must be bit-identical");
            }
        }
        // FirstR preparation aligns both tags, so BDA must take the
        // compact-basis fused path, not the fallback.
        assert!(matches!(
            bda.blocks[0].attn.pack_qkv(),
            crate::model::weights::FusedQkv::CompactBasis { .. }
        ));
    }

    #[test]
    fn cache_grows() {
        let m = tiny();
        let mut cache = KvCache::new(m.config.n_layers);
        assert_eq!(cache.seq_len(), 0);
        let _ = m.prefill(&mut cache, &[1, 2, 3]);
        assert_eq!(cache.seq_len(), 3);
        let _ = m.decode_step(&mut cache, 4);
        assert_eq!(cache.seq_len(), 4);
        assert!(cache.bytes(DType::F16) > 0);
    }
}
