//! Model configuration presets.
//!
//! Substitution note (DESIGN.md §2): we keep the paper's *shape ratios*
//! (d_h/d = 25%, the determinant of BDA's savings) while scaling parameter
//! counts to CPU-tractable sizes. `deepseek_v3_kv_shape` reproduces the
//! exact operator shape of Tables 6–7.

use crate::attention::AttnShape;
use crate::util::json::Json;

/// Decoder-only transformer configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    /// Embedding / residual width.
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// Per-head dim; BDA requires d_h < d_model.
    pub d_h: usize,
    /// FFN hidden width.
    pub d_ff: usize,
    pub max_seq_len: usize,
}

impl ModelConfig {
    pub fn attn_shape(&self) -> AttnShape {
        AttnShape::new(self.d_model, self.n_heads, self.d_h)
    }

    /// Tiny config for unit/integration tests.
    pub fn tiny() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            vocab_size: 256,
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            d_h: 16,
            d_ff: 128,
            max_seq_len: 64,
        }
    }

    /// DeepSeek-V2-Lite-like simulation config: preserves the paper's
    /// d=512, d_h=128 ratio (25%) with 4 heads and a small depth so the
    /// Fig. 2a / Table 5 end-to-end PPL sweep runs on CPU.
    pub fn deepseek_lite_sim() -> ModelConfig {
        ModelConfig {
            name: "deepseek-lite-sim".into(),
            vocab_size: 2048,
            d_model: 512,
            n_layers: 4,
            n_heads: 4,
            d_h: 128,
            d_ff: 1024,
            max_seq_len: 256,
        }
    }

    /// LLaMA-2-7B-like scaled config for the Table 3 low-rank experiments
    /// (same d_model:d_ff:head ratios, scaled down).
    pub fn llama_sim() -> ModelConfig {
        ModelConfig {
            name: "llama-sim".into(),
            vocab_size: 2048,
            d_model: 256,
            n_layers: 4,
            n_heads: 4,
            d_h: 64,
            d_ff: 688,
            max_seq_len: 256,
        }
    }

    /// Larger LLaMA-like config (the "13B" row analogue of Table 3).
    pub fn llama_sim_l() -> ModelConfig {
        ModelConfig {
            name: "llama-sim-l".into(),
            vocab_size: 2048,
            d_model: 320,
            n_layers: 5,
            n_heads: 5,
            d_h: 64,
            d_ff: 864,
            max_seq_len: 256,
        }
    }

    /// Look up a preset by name.
    pub fn preset(name: &str) -> Option<ModelConfig> {
        match name {
            "tiny" => Some(Self::tiny()),
            "deepseek-lite-sim" | "deepseek" => Some(Self::deepseek_lite_sim()),
            "llama-sim" | "llama" => Some(Self::llama_sim()),
            "llama-sim-l" => Some(Self::llama_sim_l()),
            _ => None,
        }
    }

    /// Approximate parameter count (embeddings + blocks + head).
    pub fn param_count(&self) -> usize {
        let attn = 4 * self.d_model * self.n_heads * self.d_h;
        let ffn = 3 * self.d_model * self.d_ff; // gate, up, down
        let norms = 2 * self.d_model;
        let blocks = self.n_layers * (attn + ffn + norms);
        let embed = self.vocab_size * self.d_model;
        blocks + 2 * embed + self.d_model
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("vocab_size", Json::num(self.vocab_size as f64)),
            ("d_model", Json::num(self.d_model as f64)),
            ("n_layers", Json::num(self.n_layers as f64)),
            ("n_heads", Json::num(self.n_heads as f64)),
            ("d_h", Json::num(self.d_h as f64)),
            ("d_ff", Json::num(self.d_ff as f64)),
            ("max_seq_len", Json::num(self.max_seq_len as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<ModelConfig> {
        Some(ModelConfig {
            name: j.get("name").as_str()?.to_string(),
            vocab_size: j.get("vocab_size").as_usize()?,
            d_model: j.get("d_model").as_usize()?,
            n_layers: j.get("n_layers").as_usize()?,
            n_heads: j.get("n_heads").as_usize()?,
            d_h: j.get("d_h").as_usize()?,
            d_ff: j.get("d_ff").as_usize()?,
            max_seq_len: j.get("max_seq_len").as_usize()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_have_quarter_ratio() {
        // The paper's compression ratio d_h/d = 25% must hold for the
        // DeepSeek sim (and llama presets keep d_h < d for BD validity).
        let c = ModelConfig::deepseek_lite_sim();
        assert!((c.attn_shape().compression_ratio() - 0.25).abs() < 1e-12);
        for name in ["tiny", "llama-sim", "llama-sim-l"] {
            let c = ModelConfig::preset(name).unwrap();
            assert!(c.d_h < c.d_model, "{name}");
        }
    }

    #[test]
    fn json_roundtrip() {
        let c = ModelConfig::llama_sim();
        let j = c.to_json();
        let c2 = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn preset_lookup() {
        assert!(ModelConfig::preset("tiny").is_some());
        assert!(ModelConfig::preset("deepseek").is_some());
        assert!(ModelConfig::preset("nope").is_none());
    }

    #[test]
    fn param_count_positive_and_ordered() {
        let tiny = ModelConfig::tiny().param_count();
        let ds = ModelConfig::deepseek_lite_sim().param_count();
        assert!(tiny > 0 && ds > tiny);
    }
}
