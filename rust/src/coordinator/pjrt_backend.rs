//! PJRT-artifact serving backend: drives the AOT-compiled JAX/Pallas model
//! (fixed-shape `lm_*_fwd_b1` artifacts) behind the [`Backend`] trait.
//!
//! Decoding is full-sequence recompute (the artifact has no KV-cache
//! inputs); causality makes right-padding harmless, so one fixed (1, L)
//! executable serves any prompt ≤ L. The native backend covers the
//! incremental KV-decode path; this one proves the Python-free AOT serving
//! path end to end.

use super::kv_cache::SeqId;
use super::scheduler::{Backend, DecodeOutcome};
use crate::runtime::{lit_i32, Executable, Runtime};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

pub struct PjrtBackend {
    exe: Arc<Executable>,
    vocab: usize,
    max_seq: usize,
    seqs: HashMap<SeqId, Vec<u32>>,
}

impl PjrtBackend {
    /// Open `artifacts/` and load the b=1 forward executable for an
    /// attention variant ("mha" | "bda").
    pub fn open(dir: impl AsRef<std::path::Path>, attention: &str) -> Result<PjrtBackend> {
        let mut rt = Runtime::open(dir)?;
        let lm = rt
            .manifest
            .lm_config
            .clone()
            .ok_or_else(|| anyhow::anyhow!("manifest missing lm_config"))?;
        let exe = rt.load(&format!("lm_{attention}_fwd_b1"))?;
        Ok(PjrtBackend {
            exe,
            vocab: lm.vocab_size,
            max_seq: lm.max_seq_len,
            seqs: HashMap::new(),
        })
    }

    fn logits_last(&self, tokens: &[u32]) -> Result<Vec<f32>> {
        assert!(!tokens.is_empty() && tokens.len() <= self.max_seq);
        let mut padded: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        padded.resize(self.max_seq, 0);
        let lit = lit_i32(&padded, &[1, self.max_seq as i64])?;
        let out = self.exe.run(std::slice::from_ref(&lit))?;
        let logits: Vec<f32> = out[0].to_vec()?;
        let pos = tokens.len() - 1;
        Ok(logits[pos * self.vocab..(pos + 1) * self.vocab].to_vec())
    }
}

impl Backend for PjrtBackend {
    fn vocab_size(&self) -> usize {
        self.vocab
    }
    fn max_seq_len(&self) -> usize {
        self.max_seq
    }
    fn prefill(&mut self, seq: SeqId, prompt: &[u32]) -> Result<Vec<f32>> {
        self.seqs.insert(seq, prompt.to_vec());
        self.logits_last(prompt)
    }
    fn decode(&mut self, seqs: &[(SeqId, u32)]) -> Result<DecodeOutcome> {
        let mut out = Vec::with_capacity(seqs.len());
        for &(id, tok) in seqs {
            let tokens = self
                .seqs
                .get_mut(&id)
                .ok_or_else(|| anyhow::anyhow!("decode: unknown seq {id}"))?;
            tokens.push(tok);
            let t = tokens.clone();
            out.push(self.logits_last(&t)?);
        }
        Ok(DecodeOutcome::complete(out))
    }
    fn release(&mut self, seq: SeqId) {
        self.seqs.remove(&seq);
    }
}

// ---------------------------------------------------------------------------
// Incremental backend over the KV-cached `lm_*_step` artifact.
// ---------------------------------------------------------------------------

/// Per-sequence PJRT state: KV-cache literals threaded between step calls.
struct SeqState {
    k_cache: xla::Literal,
    v_cache: xla::Literal,
    pos: usize,
}

/// Incremental PJRT serving backend: O(1) work per decoded token.
///
/// Drives the `lm_{attn}_step` artifact (B=1):
/// `(k_cache, v_cache, token, pos) -> (logits, k_cache', v_cache')`.
/// The cache literals live on the PJRT side of the boundary and are
/// threaded between calls — the whole decode loop is Python-free AND
/// recompute-free (unlike [`PjrtBackend`]'s full-sequence path; the serve
/// example measures the difference).
pub struct PjrtIncrementalBackend {
    exe: Arc<Executable>,
    vocab: usize,
    max_seq: usize,
    n_layers: usize,
    width: usize,
    seqs: HashMap<SeqId, SeqState>,
}

impl PjrtIncrementalBackend {
    pub fn open(dir: impl AsRef<std::path::Path>, attention: &str) -> Result<PjrtIncrementalBackend> {
        let mut rt = Runtime::open(dir)?;
        let lm = rt
            .manifest
            .lm_config
            .clone()
            .ok_or_else(|| anyhow::anyhow!("manifest missing lm_config"))?;
        let exe = rt.load(&format!("lm_{attention}_step"))?;
        Ok(PjrtIncrementalBackend {
            exe,
            vocab: lm.vocab_size,
            max_seq: lm.max_seq_len,
            n_layers: lm.n_layers,
            width: lm.n_heads * lm.d_h,
            seqs: HashMap::new(),
        })
    }

    fn empty_cache(&self) -> Result<xla::Literal> {
        let n = self.n_layers * self.max_seq * self.width;
        crate::runtime::lit_f32(
            &vec![0.0; n],
            &[self.n_layers as i64, self.max_seq as i64, self.width as i64],
        )
    }

    /// Advance one token for one sequence; returns last-position logits.
    fn step(&mut self, seq: SeqId, token: u32) -> Result<Vec<f32>> {
        let state = self
            .seqs
            .get_mut(&seq)
            .ok_or_else(|| anyhow::anyhow!("step: unknown seq {seq}"))?;
        if state.pos >= self.max_seq {
            anyhow::bail!("sequence {seq} exceeds max_seq_len {}", self.max_seq);
        }
        let tok_lit = xla::Literal::scalar(token as i32);
        let pos_lit = xla::Literal::scalar(state.pos as i32);
        // Move the caches out (threaded through the call).
        let k = std::mem::replace(&mut state.k_cache, xla::Literal::scalar(0i32));
        let v = std::mem::replace(&mut state.v_cache, xla::Literal::scalar(0i32));
        let mut out = self.exe.run(&[k, v, tok_lit, pos_lit])?;
        let v_new = out.pop().unwrap();
        let k_new = out.pop().unwrap();
        let logits: Vec<f32> = out.pop().unwrap().to_vec()?;
        let state = self.seqs.get_mut(&seq).unwrap();
        state.k_cache = k_new;
        state.v_cache = v_new;
        state.pos += 1;
        Ok(logits)
    }
}

impl Backend for PjrtIncrementalBackend {
    fn vocab_size(&self) -> usize {
        self.vocab
    }
    fn max_seq_len(&self) -> usize {
        self.max_seq
    }
    fn prefill(&mut self, seq: SeqId, prompt: &[u32]) -> Result<Vec<f32>> {
        let state =
            SeqState { k_cache: self.empty_cache()?, v_cache: self.empty_cache()?, pos: 0 };
        self.seqs.insert(seq, state);
        let mut logits = Vec::new();
        for &t in prompt {
            logits = self.step(seq, t)?;
        }
        Ok(logits)
    }
    fn decode(&mut self, seqs: &[(SeqId, u32)]) -> Result<DecodeOutcome> {
        seqs.iter()
            .map(|&(id, tok)| self.step(id, tok))
            .collect::<Result<Vec<_>>>()
            .map(DecodeOutcome::complete)
    }
    fn release(&mut self, seq: SeqId) {
        self.seqs.remove(&seq);
    }
}
