//! Server: a prefix-aware router over N pool-shard engine workers, each
//! running the queue → batcher → scheduler loop on a dedicated thread
//! (see [`super::worker`]); clients talk over channels. The single-engine
//! server is the N = 1 case of the same machinery. Also provides
//! synchronous trace-replay modes used by the benchmarks and examples.

use super::batcher::BatcherConfig;
use super::metrics::{Metrics, Snapshot};
use super::queue::RequestQueue;
use super::request::{Request, Response};
use super::router::{self, ShardHandle, ShardView};
use super::scheduler::{Backend, Scheduler, SchedulerConfig};
use super::worker;
use anyhow::Result;
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::Duration;

#[derive(Clone, Copy, Debug, Default)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub scheduler: SchedulerConfig,
}

/// A running server instance: one router in front of N engine workers.
pub struct Server {
    /// Shard 0's admission queue (the only queue when N = 1); kept public
    /// for compatibility with single-engine callers.
    pub queue: Arc<RequestQueue>,
    /// Shard 0's metrics; use [`Server::snapshot`] for the aggregate view.
    pub metrics: Arc<Metrics>,
    shards: Vec<ShardHandle>,
    responses: Receiver<Response>,
    engines: Vec<std::thread::JoinHandle<Result<()>>>,
}

impl Server {
    /// Start a single engine worker over a backend (the N = 1 special
    /// case of [`Server::start_sharded`]).
    pub fn start<B: Backend + Send + 'static>(backend: B, config: ServerConfig) -> Server {
        Server::start_sharded(vec![backend], config)
    }

    /// Start one engine worker per backend, each owning its pool shard,
    /// behind the prefix-aware router. Every backend gets the same
    /// config; requests submitted via [`Server::submit`] are placed by
    /// [`router::pick_shard`] and never migrate between shards (engine
    /// invariant 8).
    pub fn start_sharded<B: Backend + Send + 'static>(
        backends: Vec<B>,
        config: ServerConfig,
    ) -> Server {
        assert!(!backends.is_empty(), "start_sharded needs at least one backend");
        let (tx, rx) = channel();
        let mut shards = Vec::with_capacity(backends.len());
        let mut engines = Vec::with_capacity(backends.len());
        for (i, backend) in backends.into_iter().enumerate() {
            let (handle, join) = worker::spawn(i as u32, backend, config, tx.clone());
            shards.push(handle);
            engines.push(join);
        }
        // Workers hold the only senders now: the channel disconnects when
        // the last worker exits, which shutdown uses as its drain signal.
        drop(tx);
        let queue = shards[0].queue.clone();
        let metrics = shards[0].metrics.clone();
        Server { queue, metrics, shards, responses: rx, engines }
    }

    /// Number of engine workers behind the router.
    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    /// Submit a request (blocking on backpressure). False if shut down.
    ///
    /// Placement is prefix-cache-aware and load-aware: the request goes
    /// to the shard whose radix tree holds its longest cached prefix,
    /// tie-broken away from preemption churn, then by free + evictable
    /// blocks and queue depth (see [`router::pick_shard`]).
    pub fn submit(&self, req: Request) -> bool {
        let shard = router::route(&self.shards, &req.prompt);
        self.shards[shard].queue.push(req)
    }

    /// Receive the next completed response (from any shard).
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        self.responses.recv_timeout(timeout).ok()
    }

    /// Aggregate metrics across all shards: counters summed, derived
    /// ratios recomputed from the sums (never averaged across shards).
    pub fn snapshot(&self) -> Snapshot {
        let snaps: Vec<Snapshot> = self.shards.iter().map(|s| s.metrics.snapshot()).collect();
        Snapshot::aggregate(&snaps)
    }

    /// Close every shard's queue and join all engine workers, returning
    /// remaining responses.
    pub fn shutdown(mut self) -> Result<Vec<Response>> {
        for s in &self.shards {
            s.queue.close();
        }
        let engines = std::mem::take(&mut self.engines);
        let mut rest = Vec::new();
        // Collect everything the workers flush while finishing.
        loop {
            match self.responses.recv_timeout(Duration::from_millis(200)) {
                Ok(r) => rest.push(r),
                Err(_) => {
                    if engines.iter().all(|h| h.is_finished()) {
                        while let Ok(r) = self.responses.try_recv() {
                            rest.push(r);
                        }
                        for h in engines {
                            h.join().map_err(|_| anyhow::anyhow!("engine panicked"))??;
                        }
                        break;
                    }
                }
            }
        }
        // Each worker flushed its own rings before exiting; flush once
        // more from the caller's side so spans recorded on *this* thread
        // (submit-side instrumentation) aren't stranded either.
        crate::obs::flush();
        Ok(rest)
    }
}

/// Synchronous trace replay (no threads): submit requests at their offsets,
/// step the scheduler, and collect all responses. Used by benches/examples
/// where deterministic timing matters.
pub fn replay_trace<B: Backend>(
    backend: B,
    config: ServerConfig,
    trace: Vec<Request>,
) -> Result<(Vec<Response>, Arc<Metrics>)> {
    let metrics = Arc::new(Metrics::new());
    let mut sched = Scheduler::new(backend, config.scheduler);
    sched.set_metrics(metrics.clone());
    let mut out = Vec::new();
    let mut pending: std::collections::VecDeque<Request> = trace.into();
    while !pending.is_empty()
        || sched.active_count() > 0
        || sched.preempted_count() > 0
        || sched.prefilling_count() > 0
    {
        // Admit as many as capacity allows. Count an admission only when
        // it sticks: under overload (parked preempted sequences block the
        // queue) the head request is retried once per step, and counting
        // attempts would inflate requests_admitted/tokens_in per retry.
        while let Some(req) = pending.pop_front() {
            let prompt_tokens = req.prompt.len();
            match sched.admit(req) {
                Ok(()) => {
                    metrics.admitted(prompt_tokens);
                    if sched.active_count() >= config.batcher.max_batch {
                        break;
                    }
                }
                Err(req) => {
                    pending.push_front(req);
                    break;
                }
            }
        }
        for resp in sched.step()? {
            metrics.tokens_generated(resp.tokens.len());
            metrics.completed(resp.latency, resp.ttft);
            metrics.slo_scored(&resp);
            out.push(resp);
        }
    }
    // Trailing spans (final completions) drain with the run.
    crate::obs::flush();
    Ok((out, metrics))
}

/// Synchronous sharded trace replay: one scheduler per backend, requests
/// placed by the same [`router::pick_shard`] policy the threaded server
/// uses, each shard stepped round-robin. Returns the responses in
/// completion order plus the aggregate [`Snapshot`] merged across shards.
///
/// This is the deterministic harness behind the invariant-8 property test
/// and the `sharded_scaling` benchmark: for a fixed request set the
/// per-request token streams are bitwise identical at any worker count
/// and any placement, because a request never splits across shards and
/// invariants 1–6 pin each scheduler's per-request output.
pub fn replay_trace_sharded<B: Backend>(
    backends: Vec<B>,
    config: ServerConfig,
    trace: Vec<Request>,
) -> Result<(Vec<Response>, Snapshot)> {
    assert!(!backends.is_empty(), "replay_trace_sharded needs at least one backend");
    struct Shard<B: Backend> {
        sched: Scheduler<B>,
        metrics: Arc<Metrics>,
        local: std::collections::VecDeque<Request>,
    }
    let mut shards: Vec<Shard<B>> = backends
        .into_iter()
        .map(|b| {
            let metrics = Arc::new(Metrics::new());
            let mut sched = Scheduler::new(b, config.scheduler);
            sched.set_metrics(metrics.clone());
            Shard { sched, metrics, local: std::collections::VecDeque::new() }
        })
        .collect();
    let mut pending: std::collections::VecDeque<Request> = trace.into();
    let mut out = Vec::new();
    while !pending.is_empty()
        || shards.iter().any(|s| {
            !s.local.is_empty()
                || s.sched.active_count() > 0
                || s.sched.preempted_count() > 0
                || s.sched.prefilling_count() > 0
        })
    {
        // Route arrivals incrementally: move requests onto shard-local
        // queues only while some shard still has admission headroom, so
        // later arrivals are placed against the prefix caches earlier
        // ones populated — mirroring the threaded router, which places
        // at submit time against live probes.
        while !pending.is_empty()
            && shards.iter().any(|s| s.local.len() < config.batcher.max_batch)
        {
            let req = pending.pop_front().unwrap();
            let views: Vec<ShardView> = shards
                .iter()
                .enumerate()
                .map(|(i, s)| ShardView {
                    shard: i,
                    cached_blocks: s.sched.backend.cached_prefix_blocks(&req.prompt),
                    free_blocks: s.sched.backend.free_blocks().unwrap_or(usize::MAX),
                    queue_depth: s.local.len()
                        + s.sched.active_count()
                        + s.sched.prefilling_count()
                        + s.sched.preempted_count(),
                    parked: s.sched.preempted_count(),
                })
                .collect();
            let shard = router::pick_shard(&views);
            shards[shard].local.push_back(req);
        }
        for (i, s) in shards.iter_mut().enumerate() {
            // Tag this shard's admission/step spans and samples.
            crate::obs::set_shard(i as u32);
            // Admit from the shard-local queue exactly as `replay_trace`
            // admits from its global one (same stick-only counting).
            while let Some(req) = s.local.pop_front() {
                let prompt_tokens = req.prompt.len();
                match s.sched.admit(req) {
                    Ok(()) => {
                        s.metrics.admitted(prompt_tokens);
                        if s.sched.active_count() >= config.batcher.max_batch {
                            break;
                        }
                    }
                    Err(req) => {
                        s.local.push_front(req);
                        break;
                    }
                }
            }
            for resp in s.sched.step()? {
                s.metrics.tokens_generated(resp.tokens.len());
                s.metrics.completed(resp.latency, resp.ttft);
                s.metrics.slo_scored(&resp);
                out.push(resp);
            }
        }
    }
    crate::obs::set_shard(0);
    let snaps: Vec<Snapshot> = shards.iter().map(|s| s.metrics.snapshot()).collect();
    // Trailing spans (final completions) drain with the run.
    crate::obs::flush();
    Ok((out, Snapshot::aggregate(&snaps)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv_cache::KvCacheConfig;
    use crate::coordinator::scheduler::test_support::MockBackend;

    fn config() -> ServerConfig {
        ServerConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            scheduler: SchedulerConfig {
                max_active: 8,
                eos_token: None,
                kv: KvCacheConfig { block_size: 4, num_blocks: 128, ..Default::default() },
                ..Default::default()
            },
        }
    }

    #[test]
    fn threaded_server_completes_all() {
        let server = Server::start(MockBackend::new(16, 64), config());
        for i in 0..20 {
            assert!(server.submit(Request::new(i, vec![1, 2], 3)));
        }
        let responses = {
            let mut got = Vec::new();
            while got.len() < 20 {
                match server.recv_timeout(Duration::from_secs(5)) {
                    Some(r) => got.push(r),
                    None => break,
                }
            }
            got
        };
        assert_eq!(responses.len(), 20);
        assert!(responses.iter().all(|r| r.tokens.len() == 3));
        let rest = server.shutdown().unwrap();
        assert!(rest.is_empty());
    }

    #[test]
    fn shutdown_flushes_in_flight() {
        let server = Server::start(MockBackend::new(16, 64), config());
        for i in 0..5 {
            server.submit(Request::new(i, vec![1], 4));
        }
        let rest = server.shutdown().unwrap();
        // All 5 must come out somewhere (drained on shutdown).
        assert_eq!(rest.len(), 5);
    }

    #[test]
    fn sharded_server_completes_all_and_aggregates() {
        let backends = vec![MockBackend::new(16, 64), MockBackend::new(16, 64)];
        let server = Server::start_sharded(backends, config());
        assert_eq!(server.workers(), 2);
        for i in 0..20 {
            assert!(server.submit(Request::new(i, vec![1, 2], 3)));
        }
        let mut got = Vec::new();
        while got.len() < 20 {
            match server.recv_timeout(Duration::from_secs(5)) {
                Some(r) => got.push(r),
                None => break,
            }
        }
        assert_eq!(got.len(), 20);
        assert!(got.iter().all(|r| r.tokens.len() == 3));
        let snap = server.snapshot();
        let rest = server.shutdown().unwrap();
        assert!(rest.is_empty());
        assert_eq!(snap.requests_completed, 20, "aggregate sums across both shards");
        assert_eq!(snap.tokens_out, 60);
    }

    #[test]
    fn replay_trace_deterministic() {
        let trace: Vec<Request> = (0..10).map(|i| Request::new(i, vec![1, 2, 3], 4)).collect();
        let (r1, m1) = replay_trace(MockBackend::new(16, 64), config(), trace.clone()).unwrap();
        let (r2, _) = replay_trace(MockBackend::new(16, 64), config(), trace).unwrap();
        assert_eq!(r1.len(), 10);
        let t1: Vec<_> = r1.iter().map(|r| (r.id, r.tokens.clone())).collect();
        let t2: Vec<_> = r2.iter().map(|r| (r.id, r.tokens.clone())).collect();
        assert_eq!(t1, t2);
        assert_eq!(m1.snapshot().requests_admitted, 10);
        assert_eq!(m1.snapshot().tokens_out, 40);
    }

    #[test]
    fn replay_trace_sharded_matches_single_worker() {
        let trace: Vec<Request> = (0..10).map(|i| Request::new(i, vec![1, 2, 3], 4)).collect();
        let (single, _) = replay_trace(MockBackend::new(16, 64), config(), trace.clone()).unwrap();
        let mut base: Vec<_> = single.iter().map(|r| (r.id, r.tokens.clone())).collect();
        base.sort();
        for workers in [1usize, 2, 4] {
            let backends: Vec<MockBackend> =
                (0..workers).map(|_| MockBackend::new(16, 64)).collect();
            let (resps, snap) = replay_trace_sharded(backends, config(), trace.clone()).unwrap();
            let mut got: Vec<_> = resps.iter().map(|r| (r.id, r.tokens.clone())).collect();
            got.sort();
            assert_eq!(got, base, "token streams identical at {workers} workers");
            assert_eq!(snap.requests_admitted, 10, "aggregate admissions at {workers} workers");
            assert_eq!(snap.tokens_out, 40);
            assert!(snap.tokens_per_sec > 0.0);
        }
    }

    #[test]
    fn metrics_track_throughput() {
        let trace: Vec<Request> = (0..4).map(|i| Request::new(i, vec![1], 8)).collect();
        let (_, m) = replay_trace(MockBackend::new(16, 64), config(), trace).unwrap();
        let s = m.snapshot();
        assert_eq!(s.tokens_out, 32);
        assert!(s.tokens_per_sec > 0.0);
        assert_eq!(s.requests_completed, 4);
    }
}
