//! Server: wires queue → batcher → scheduler on a dedicated engine thread
//! (the PJRT client and model state live on that thread; clients talk over
//! channels). Also provides a synchronous trace-replay mode used by the
//! benchmarks and examples.

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::queue::RequestQueue;
use super::request::{Request, Response};
use super::scheduler::{Backend, Scheduler, SchedulerConfig};
use anyhow::Result;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

#[derive(Clone, Copy, Debug, Default)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub scheduler: SchedulerConfig,
}

/// A running server instance.
pub struct Server {
    pub queue: Arc<RequestQueue>,
    pub metrics: Arc<Metrics>,
    responses: Receiver<Response>,
    engine: Option<std::thread::JoinHandle<Result<()>>>,
}

impl Server {
    /// Start the engine thread over a backend.
    pub fn start<B: Backend + Send + 'static>(backend: B, config: ServerConfig) -> Server {
        let queue = Arc::new(RequestQueue::new(256));
        let metrics = Arc::new(Metrics::new());
        let (tx, rx): (Sender<Response>, Receiver<Response>) = channel();
        let q = queue.clone();
        let m = metrics.clone();
        let engine = std::thread::spawn(move || -> Result<()> {
            if crate::obs::enabled() {
                crate::obs::set_thread_label("bda-engine");
            }
            let mut sched = Scheduler::new(backend, config.scheduler);
            sched.set_metrics(m.clone());
            let batcher = Batcher::new(config.batcher);
            loop {
                // Admit a batch (don't block long if sequences are active).
                let idle = if sched.active_count() + sched.prefilling_count() > 0 {
                    Duration::from_micros(100)
                } else if q.is_closed() && q.is_empty() {
                    break;
                } else {
                    Duration::from_millis(10)
                };
                let batch = batcher.next_batch(&q, idle);
                if crate::obs::enabled() {
                    // Feed the resource sampler the post-batch queue depth;
                    // the scheduler stamps it into its step-boundary sample.
                    crate::obs::sampler::note_queue_depth(q.len());
                }
                if !batch.is_empty() {
                    m.batch_formed(batch.len());
                }
                for req in batch {
                    m.admitted(req.prompt.len());
                    let mut pending = Some(req);
                    // Retry admission as capacity frees up.
                    while let Some(r) = pending.take() {
                        match sched.admit(r) {
                            Ok(()) => {}
                            Err(r) => {
                                if sched.active_count() == 0
                                    && sched.preempted_count() == 0
                                    && sched.prefilling_count() == 0
                                {
                                    // Can't ever admit: drop with rejection.
                                    m.rejected();
                                    break;
                                }
                                // Free capacity by stepping, then retry.
                                for resp in sched.step()? {
                                    m.tokens_generated(resp.tokens.len());
                                    m.completed(resp.latency, resp.ttft);
                                    m.slo_scored(&resp);
                                    let _ = tx.send(resp);
                                }
                                pending = Some(r);
                            }
                        }
                    }
                }
                // Decode progress.
                for resp in sched.step()? {
                    m.tokens_generated(resp.tokens.len());
                    m.completed(resp.latency, resp.ttft);
                    m.slo_scored(&resp);
                    let _ = tx.send(resp);
                }
            }
            // Drain remaining work after close.
            for resp in sched.drain()? {
                m.tokens_generated(resp.tokens.len());
                m.completed(resp.latency, resp.ttft);
                m.slo_scored(&resp);
                let _ = tx.send(resp);
            }
            // Final trace drain: spans recorded after the last step's
            // flush (completions above) must not be stranded in the rings.
            crate::obs::flush();
            Ok(())
        });
        Server { queue, metrics, responses: rx, engine: Some(engine) }
    }

    /// Submit a request (blocking on backpressure). False if shut down.
    pub fn submit(&self, req: Request) -> bool {
        self.queue.push(req)
    }

    /// Receive the next completed response.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        self.responses.recv_timeout(timeout).ok()
    }

    /// Close the queue and join the engine, returning remaining responses.
    pub fn shutdown(mut self) -> Result<Vec<Response>> {
        self.queue.close();
        let mut rest = Vec::new();
        if let Some(h) = self.engine.take() {
            // Collect everything the engine flushes while finishing.
            loop {
                match self.responses.recv_timeout(Duration::from_millis(200)) {
                    Ok(r) => rest.push(r),
                    Err(_) => {
                        if h.is_finished() {
                            while let Ok(r) = self.responses.try_recv() {
                                rest.push(r);
                            }
                            h.join().map_err(|_| anyhow::anyhow!("engine panicked"))??;
                            break;
                        }
                    }
                }
            }
        }
        // The engine thread flushed its own rings before exiting; flush
        // once more from the caller's side so spans recorded on *this*
        // thread (submit-side instrumentation) aren't stranded either.
        crate::obs::flush();
        Ok(rest)
    }
}

/// Synchronous trace replay (no threads): submit requests at their offsets,
/// step the scheduler, and collect all responses. Used by benches/examples
/// where deterministic timing matters.
pub fn replay_trace<B: Backend>(
    backend: B,
    config: ServerConfig,
    trace: Vec<Request>,
) -> Result<(Vec<Response>, Arc<Metrics>)> {
    let metrics = Arc::new(Metrics::new());
    let mut sched = Scheduler::new(backend, config.scheduler);
    sched.set_metrics(metrics.clone());
    let mut out = Vec::new();
    let mut pending: std::collections::VecDeque<Request> = trace.into();
    while !pending.is_empty()
        || sched.active_count() > 0
        || sched.preempted_count() > 0
        || sched.prefilling_count() > 0
    {
        // Admit as many as capacity allows. Count an admission only when
        // it sticks: under overload (parked preempted sequences block the
        // queue) the head request is retried once per step, and counting
        // attempts would inflate requests_admitted/tokens_in per retry.
        while let Some(req) = pending.pop_front() {
            let prompt_tokens = req.prompt.len();
            match sched.admit(req) {
                Ok(()) => {
                    metrics.admitted(prompt_tokens);
                    if sched.active_count() >= config.batcher.max_batch {
                        break;
                    }
                }
                Err(req) => {
                    pending.push_front(req);
                    break;
                }
            }
        }
        for resp in sched.step()? {
            metrics.tokens_generated(resp.tokens.len());
            metrics.completed(resp.latency, resp.ttft);
            metrics.slo_scored(&resp);
            out.push(resp);
        }
    }
    // Trailing spans (final completions) drain with the run.
    crate::obs::flush();
    Ok((out, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv_cache::KvCacheConfig;
    use crate::coordinator::scheduler::test_support::MockBackend;

    fn config() -> ServerConfig {
        ServerConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            scheduler: SchedulerConfig {
                max_active: 8,
                eos_token: None,
                kv: KvCacheConfig { block_size: 4, num_blocks: 128, ..Default::default() },
                ..Default::default()
            },
        }
    }

    #[test]
    fn threaded_server_completes_all() {
        let server = Server::start(MockBackend::new(16, 64), config());
        for i in 0..20 {
            assert!(server.submit(Request::new(i, vec![1, 2], 3)));
        }
        let responses = {
            let mut got = Vec::new();
            while got.len() < 20 {
                match server.recv_timeout(Duration::from_secs(5)) {
                    Some(r) => got.push(r),
                    None => break,
                }
            }
            got
        };
        assert_eq!(responses.len(), 20);
        assert!(responses.iter().all(|r| r.tokens.len() == 3));
        let rest = server.shutdown().unwrap();
        assert!(rest.is_empty());
    }

    #[test]
    fn shutdown_flushes_in_flight() {
        let server = Server::start(MockBackend::new(16, 64), config());
        for i in 0..5 {
            server.submit(Request::new(i, vec![1], 4));
        }
        let rest = server.shutdown().unwrap();
        // All 5 must come out somewhere (drained on shutdown).
        assert_eq!(rest.len(), 5);
    }

    #[test]
    fn replay_trace_deterministic() {
        let trace: Vec<Request> = (0..10).map(|i| Request::new(i, vec![1, 2, 3], 4)).collect();
        let (r1, m1) = replay_trace(MockBackend::new(16, 64), config(), trace.clone()).unwrap();
        let (r2, _) = replay_trace(MockBackend::new(16, 64), config(), trace).unwrap();
        assert_eq!(r1.len(), 10);
        let t1: Vec<_> = r1.iter().map(|r| (r.id, r.tokens.clone())).collect();
        let t2: Vec<_> = r2.iter().map(|r| (r.id, r.tokens.clone())).collect();
        assert_eq!(t1, t2);
        assert_eq!(m1.snapshot().requests_admitted, 10);
        assert_eq!(m1.snapshot().tokens_out, 40);
    }

    #[test]
    fn metrics_track_throughput() {
        let trace: Vec<Request> = (0..4).map(|i| Request::new(i, vec![1], 8)).collect();
        let (_, m) = replay_trace(MockBackend::new(16, 64), config(), trace).unwrap();
        let s = m.snapshot();
        assert_eq!(s.tokens_out, 32);
        assert!(s.tokens_per_sec > 0.0);
        assert_eq!(s.requests_completed, 4);
    }
}
