//! L3 serving coordinator — the system the paper's inference speedups plug
//! into (vLLM-router-shaped): bounded admission queue → dynamic batcher →
//! continuous-batching scheduler over a model backend (the paged batched
//! decode engine by default, the per-sequence native transformer, or the
//! PJRT artifact backend behind the `pjrt` feature) with a block-based
//! KV-cache manager and latency/throughput metrics. The scheduler loop
//! scales out behind a prefix-aware router over N pool-shard engine
//! workers ([`router`]/[`worker`]; `BDA_WORKERS`), each owning its own
//! queue, KV pool, and metrics shard. Python is never on this path.

pub mod batcher;
pub mod kv_cache;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod pjrt_backend;
pub mod queue;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod worker;

pub use batcher::{Batcher, BatcherConfig};
pub use kv_cache::{kv_dtype_from_env, BlockAllocator, KvCacheConfig, KvDtype};
pub use metrics::{ClassSlo, Metrics, Snapshot, StepTiming};
#[cfg(feature = "pjrt")]
pub use pjrt_backend::{PjrtBackend, PjrtIncrementalBackend};
pub use queue::RequestQueue;
pub use request::{Request, RequestClass, RequestId, Response};
pub use router::{pick_shard, workers_from_env, ShardStatus, ShardView};
pub use scheduler::{
    Backend, DecodeOutcome, NativeBackend, PrefixProbeHandle, Scheduler, SchedulerConfig,
};
pub use server::{Server, ServerConfig};

// The paged batched decode engine is the default native serving backend;
// re-exported here so serving code imports one module.
pub use crate::engine::PagedNativeBackend;
