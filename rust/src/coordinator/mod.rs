//! L3 serving coordinator — the system the paper's inference speedups plug
//! into (vLLM-router-shaped): bounded admission queue → dynamic batcher →
//! continuous-batching scheduler over a model backend (PJRT artifact or
//! native Rust transformer) with a block-based KV-cache manager and
//! latency/throughput metrics. Python is never on this path.

pub mod batcher;
pub mod kv_cache;
pub mod metrics;
pub mod pjrt_backend;
pub mod queue;
pub mod request;
pub mod scheduler;
pub mod server;

pub use batcher::{Batcher, BatcherConfig};
pub use kv_cache::{BlockAllocator, KvCacheConfig};
pub use metrics::Metrics;
pub use pjrt_backend::{PjrtBackend, PjrtIncrementalBackend};
pub use queue::RequestQueue;
pub use request::{Request, RequestId, Response};
pub use scheduler::{Backend, NativeBackend, Scheduler, SchedulerConfig};
pub use server::{Server, ServerConfig};
