//! L3 serving coordinator — the system the paper's inference speedups plug
//! into (vLLM-router-shaped): bounded admission queue → dynamic batcher →
//! continuous-batching scheduler over a model backend (the paged batched
//! decode engine by default, the per-sequence native transformer, or the
//! PJRT artifact backend behind the `pjrt` feature) with a block-based
//! KV-cache manager and latency/throughput metrics. Python is never on
//! this path.

pub mod batcher;
pub mod kv_cache;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod pjrt_backend;
pub mod queue;
pub mod request;
pub mod scheduler;
pub mod server;

pub use batcher::{Batcher, BatcherConfig};
pub use kv_cache::{kv_dtype_from_env, BlockAllocator, KvCacheConfig, KvDtype};
pub use metrics::{ClassSlo, Metrics, Snapshot, StepTiming};
#[cfg(feature = "pjrt")]
pub use pjrt_backend::{PjrtBackend, PjrtIncrementalBackend};
pub use queue::RequestQueue;
pub use request::{Request, RequestClass, RequestId, Response};
pub use scheduler::{Backend, DecodeOutcome, NativeBackend, Scheduler, SchedulerConfig};
pub use server::{Server, ServerConfig};

// The paged batched decode engine is the default native serving backend;
// re-exported here so serving code imports one module.
pub use crate::engine::PagedNativeBackend;
