//! Continuous-batching scheduler: admits requests (prefill), interleaves
//! batched decode steps across active sequences, samples, and completes.
//!
//! The backend abstraction separates coordination from compute so the same
//! scheduler serves: the native Rust transformer (incremental KV decode),
//! the PJRT artifact backend (AOT-compiled JAX model), and a mock backend
//! for deterministic tests.

use super::kv_cache::{BlockAllocator, KvCacheConfig, SeqId};
use super::metrics::{Metrics, StepTiming};
use super::request::{Request, Response};
use crate::model::transformer::{KvCache, Transformer};
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// Model compute interface used by the scheduler.
///
/// Not `Send` by itself (the PJRT wrapper types are thread-pinned); the
/// threaded [`super::server::Server`] adds a `Send` bound, while the
/// synchronous `replay_trace` path works with any backend.
pub trait Backend {
    fn vocab_size(&self) -> usize;
    fn max_seq_len(&self) -> usize;
    /// Start a sequence (prefill); returns logits for the last prompt
    /// position.
    fn prefill(&mut self, seq: SeqId, prompt: &[u32]) -> Result<Vec<f32>>;
    /// One decode step for a batch of sequences, feeding each its last
    /// token; returns per-sequence logits.
    fn decode(&mut self, seqs: &[(SeqId, u32)]) -> Result<Vec<Vec<f32>>>;
    /// Drop per-sequence state.
    fn release(&mut self, seq: SeqId);
    /// Free blocks in the backend's *own* KV pool — the engine truth —
    /// when the backend owns real block storage. `None` means the backend
    /// has no pool of its own and the scheduler must fall back to its
    /// admission-side [`BlockAllocator`]. Routing admission through this
    /// method makes engine-level state the shadow allocator cannot see
    /// (e.g. `fork`/copy-on-write dedup) visible to capacity decisions.
    fn free_blocks(&self) -> Option<usize> {
        None
    }
    /// Timing split of the most recent decode step, if this backend
    /// instruments its hot path. Consumed (take) by the scheduler after
    /// every step so stale timings are never re-reported.
    fn take_step_timing(&mut self) -> Option<StepTiming> {
        None
    }
}

/// Backend over the pure-Rust transformer with per-sequence KV caches.
pub struct NativeBackend {
    pub model: Transformer,
    caches: HashMap<SeqId, KvCache>,
}

impl NativeBackend {
    pub fn new(model: Transformer) -> NativeBackend {
        NativeBackend { model, caches: HashMap::new() }
    }
}

impl Backend for NativeBackend {
    fn vocab_size(&self) -> usize {
        self.model.config.vocab_size
    }

    fn max_seq_len(&self) -> usize {
        self.model.config.max_seq_len
    }

    fn prefill(&mut self, seq: SeqId, prompt: &[u32]) -> Result<Vec<f32>> {
        let mut cache = KvCache::new(self.model.config.n_layers);
        let logits = self.model.prefill(&mut cache, prompt);
        self.caches.insert(seq, cache);
        Ok(logits.data)
    }

    fn decode(&mut self, seqs: &[(SeqId, u32)]) -> Result<Vec<Vec<f32>>> {
        // Per-sequence incremental decode (each has its own cache).
        let mut out = Vec::with_capacity(seqs.len());
        for &(id, tok) in seqs {
            let cache = self
                .caches
                .get_mut(&id)
                .ok_or_else(|| anyhow::anyhow!("decode: unknown seq {id}"))?;
            let logits = self.model.decode_step(cache, tok);
            out.push(logits.data);
        }
        Ok(out)
    }

    fn release(&mut self, seq: SeqId) {
        self.caches.remove(&seq);
    }
}

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Max sequences decoded per iteration.
    pub max_active: usize,
    /// Optional stop token.
    pub eos_token: Option<u32>,
    pub kv: KvCacheConfig,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_active: 16, eos_token: None, kv: KvCacheConfig::default() }
    }
}

struct ActiveSeq {
    req: Request,
    generated: Vec<u32>,
    first_token_at: Option<Instant>,
    last_token: u32,
}

/// The continuous-batching engine.
pub struct Scheduler<B: Backend> {
    pub backend: B,
    pub config: SchedulerConfig,
    pub kv: BlockAllocator,
    active: Vec<ActiveSeq>,
    next_seq: SeqId,
    seq_of_req: HashMap<u64, SeqId>,
    metrics: Option<Arc<Metrics>>,
}

impl<B: Backend> Scheduler<B> {
    pub fn new(backend: B, config: SchedulerConfig) -> Scheduler<B> {
        Scheduler {
            backend,
            kv: BlockAllocator::new(config.kv),
            config,
            active: Vec::new(),
            next_seq: 1,
            seq_of_req: HashMap::new(),
            metrics: None,
        }
    }

    /// Attach a metrics sink; each decode iteration then emits its batch
    /// size and occupancy (tokens-per-step / decode-batch counters).
    pub fn set_metrics(&mut self, metrics: Arc<Metrics>) {
        self.metrics = Some(metrics);
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    pub fn has_capacity_for(&self, req: &Request) -> bool {
        if self.active.len() >= self.config.max_active {
            return false;
        }
        // Engine pool truth when the backend owns real block storage (so
        // engine-level forks / copy-on-write are visible to admission);
        // the admission-side shadow allocator otherwise. Block geometry
        // comes from this scheduler's config, which every construction
        // site shares with the backend pool; full capacity-query
        // unification behind the Backend trait is a ROADMAP item.
        match self.backend.free_blocks() {
            Some(free) => req.prompt.len().max(1).div_ceil(self.config.kv.block_size) <= free,
            None => self.kv.can_admit(req.prompt.len()),
        }
    }

    /// Admit a request: KV registration + prefill + first sampled token.
    /// On failure the request is returned for re-queueing.
    pub fn admit(&mut self, req: Request) -> std::result::Result<(), Request> {
        if !self.has_capacity_for(&req) {
            return Err(req);
        }
        let seq = self.next_seq;
        // The shadow allocator is worst-case bookkeeping (no prefix
        // sharing, no eviction). When the backend owns real block storage
        // its pool is the admission truth — a backend that can serve the
        // request (e.g. by adopting a cached prefix or evicting the
        // radix tree) must not be vetoed by shadow-side pessimism — so
        // the shadow is maintained only for pool-less backends; its
        // append/release calls degrade to ignored no-ops otherwise.
        if self.backend.free_blocks().is_none()
            && self.kv.register(seq, req.prompt.len()).is_err()
        {
            return Err(req);
        }
        let logits = match self.backend.prefill(seq, &req.prompt) {
            Ok(l) => l,
            Err(_) => {
                let _ = self.kv.release(seq);
                return Err(req);
            }
        };
        self.next_seq += 1;
        let first = sample(&logits, &req);
        self.seq_of_req.insert(req.id, seq);
        let mut seq_state = ActiveSeq {
            last_token: first,
            generated: vec![first],
            first_token_at: Some(Instant::now()),
            req,
        };
        // A request asking for 0 tokens completes immediately on next step;
        // normalize to at least the first token.
        if seq_state.req.max_new_tokens == 0 {
            seq_state.generated.clear();
        }
        self.active.push(seq_state);
        Ok(())
    }

    /// One decode iteration over all active sequences. Returns completed
    /// responses.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();
        if self.active.is_empty() {
            return Ok(done);
        }
        // Finish check before decoding (covers max_new_tokens == 0/1).
        self.complete_finished(&mut done);
        if self.active.is_empty() {
            // No decode step will run, but admissions may have recorded
            // backend counters (e.g. prefix-cache hits for max_new <= 1
            // requests) — surface them rather than dropping the tail.
            if let Some(m) = &self.metrics {
                if let Some(t) = self.backend.take_step_timing() {
                    m.decode_timing(t, 0.0);
                }
            }
            return Ok(done);
        }

        let batch: Vec<(SeqId, u32)> = self
            .active
            .iter()
            .map(|a| (self.seq_of_req[&a.req.id], a.last_token))
            .collect();
        if let Some(m) = &self.metrics {
            m.decode_step(batch.len(), self.config.max_active);
        }
        let logits = self.backend.decode(&batch)?;
        // Shadow-allocator growth tracking only applies to pool-less
        // backends (pool owners were never shadow-registered on admit).
        let shadow = self.backend.free_blocks().is_none();
        let mut sample_secs = 0.0f64;
        for (a, l) in self.active.iter_mut().zip(logits.iter()) {
            let seq = self.seq_of_req[&a.req.id];
            // Time only sample() so the metrics split doesn't charge
            // allocator bookkeeping to the "sampling" bucket.
            let t = Instant::now();
            let tok = sample(l, &a.req);
            sample_secs += t.elapsed().as_secs_f64();
            a.generated.push(tok);
            a.last_token = tok;
            if a.first_token_at.is_none() {
                a.first_token_at = Some(Instant::now());
            }
            if shadow {
                let _ = self.kv.append_token(seq);
            }
        }
        if let Some(m) = &self.metrics {
            if let Some(t) = self.backend.take_step_timing() {
                m.decode_timing(t, sample_secs);
            }
        }
        self.complete_finished(&mut done);
        Ok(done)
    }

    fn complete_finished(&mut self, done: &mut Vec<Response>) {
        let eos = self.config.eos_token;
        let max_total = self.backend.max_seq_len();
        let mut i = 0;
        while i < self.active.len() {
            let a = &self.active[i];
            let hit_eos = eos.map(|e| a.generated.last() == Some(&e)).unwrap_or(false);
            let full = a.req.prompt.len() + a.generated.len() >= max_total;
            if a.generated.len() >= a.req.max_new_tokens || hit_eos || full {
                let a = self.active.remove(i);
                let seq = self.seq_of_req.remove(&a.req.id).unwrap();
                let _ = self.kv.release(seq);
                self.backend.release(seq);
                let now = Instant::now();
                done.push(Response {
                    id: a.req.id,
                    prompt_len: a.req.prompt.len(),
                    ttft: a
                        .first_token_at
                        .map(|t| (t - a.req.arrival).as_secs_f64())
                        .unwrap_or(0.0),
                    latency: (now - a.req.arrival).as_secs_f64(),
                    tokens: a.generated,
                });
            } else {
                i += 1;
            }
        }
    }

    /// Drain: run steps until every active sequence completes.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while !self.active.is_empty() {
            out.extend(self.step()?);
        }
        Ok(out)
    }
}

/// Sampling: greedy argmax, or temperature sampling seeded by request id
/// (deterministic per request).
fn sample(logits: &[f32], req: &Request) -> u32 {
    match req.temperature {
        None => argmax(logits),
        Some(t) if t <= 0.0 => argmax(logits),
        Some(t) => {
            let mut rng = Rng::new(req.id ^ 0x5bd1e995);
            let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f64> = logits.iter().map(|&l| (((l - max) / t) as f64).exp()).collect();
            let total: f64 = exps.iter().sum();
            let mut u = rng.next_f64() * total;
            for (i, e) in exps.iter().enumerate() {
                u -= e;
                if u <= 0.0 {
                    return i as u32;
                }
            }
            (logits.len() - 1) as u32
        }
    }
}

fn argmax(xs: &[f32]) -> u32 {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best as u32
}

/// Deterministic mock backend — used by unit/property/integration tests
/// and the batcher ablation bench (kept out of cfg(test) so external test
/// targets and benches can use it).
pub mod test_support {
    use super::*;

    /// Deterministic mock: logits put all mass on (seq_id + step) % vocab.
    pub struct MockBackend {
        pub vocab: usize,
        pub max_seq: usize,
        pub steps: HashMap<SeqId, u32>,
        pub released: Vec<SeqId>,
        pub fail_prefill: bool,
    }

    impl MockBackend {
        pub fn new(vocab: usize, max_seq: usize) -> MockBackend {
            MockBackend {
                vocab,
                max_seq,
                steps: HashMap::new(),
                released: Vec::new(),
                fail_prefill: false,
            }
        }

        fn logits_for(&self, seq: SeqId, step: u32) -> Vec<f32> {
            let mut l = vec![0.0; self.vocab];
            l[((seq as u32 + step) % self.vocab as u32) as usize] = 10.0;
            l
        }
    }

    impl Backend for MockBackend {
        fn vocab_size(&self) -> usize {
            self.vocab
        }
        fn max_seq_len(&self) -> usize {
            self.max_seq
        }
        fn prefill(&mut self, seq: SeqId, _prompt: &[u32]) -> Result<Vec<f32>> {
            if self.fail_prefill {
                anyhow::bail!("mock prefill failure");
            }
            self.steps.insert(seq, 0);
            Ok(self.logits_for(seq, 0))
        }
        fn decode(&mut self, seqs: &[(SeqId, u32)]) -> Result<Vec<Vec<f32>>> {
            seqs.iter()
                .map(|&(id, _)| {
                    let s = self.steps.get_mut(&id).expect("unknown seq");
                    *s += 1;
                    let step = *s;
                    Ok(self.logits_for(id, step))
                })
                .collect()
        }
        fn release(&mut self, seq: SeqId) {
            self.released.push(seq);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::MockBackend;
    use super::*;

    fn sched(max_active: usize) -> Scheduler<MockBackend> {
        Scheduler::new(
            MockBackend::new(16, 64),
            SchedulerConfig {
                max_active,
                eos_token: None,
                kv: KvCacheConfig { block_size: 4, num_blocks: 64 },
            },
        )
    }

    #[test]
    fn generates_exact_token_count() {
        let mut s = sched(8);
        s.admit(Request::new(1, vec![1, 2, 3], 5)).unwrap();
        let done = s.drain().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), 5);
        assert!(done[0].ttft <= done[0].latency);
    }

    #[test]
    fn deterministic_mock_tokens() {
        let mut s = sched(8);
        s.admit(Request::new(1, vec![0], 3)).unwrap();
        let done = s.drain().unwrap();
        // seq id 1: tokens (1+0)%16, (1+1)%16, (1+2)%16
        assert_eq!(done[0].tokens, vec![1, 2, 3]);
    }

    #[test]
    fn interleaves_multiple_requests() {
        let mut s = sched(8);
        s.admit(Request::new(1, vec![1], 2)).unwrap();
        s.admit(Request::new(2, vec![1, 2], 4)).unwrap();
        let done = s.drain().unwrap();
        assert_eq!(done.len(), 2);
        let by_id: HashMap<u64, &Response> = done.iter().map(|r| (r.id, r)).collect();
        assert_eq!(by_id[&1].tokens.len(), 2);
        assert_eq!(by_id[&2].tokens.len(), 4);
    }

    #[test]
    fn respects_max_active() {
        let mut s = sched(1);
        s.admit(Request::new(1, vec![1], 2)).unwrap();
        let rejected = s.admit(Request::new(2, vec![1], 2));
        assert!(rejected.is_err());
        s.drain().unwrap();
        assert!(s.admit(rejected.unwrap_err()).is_ok());
    }

    #[test]
    fn kv_blocks_freed_on_completion() {
        let mut s = sched(8);
        let free0 = s.kv.free_blocks();
        s.admit(Request::new(1, vec![1, 2, 3, 4, 5], 6)).unwrap();
        assert!(s.kv.free_blocks() < free0);
        s.drain().unwrap();
        assert_eq!(s.kv.free_blocks(), free0);
        s.kv.check_invariants().unwrap();
        assert_eq!(s.backend.released, vec![1]);
    }

    #[test]
    fn eos_stops_generation() {
        let mut s = Scheduler::new(
            MockBackend::new(16, 64),
            SchedulerConfig {
                max_active: 4,
                eos_token: Some(3), // seq 1 emits 1, 2, 3 -> stops at 3
                kv: KvCacheConfig::default(),
            },
        );
        s.admit(Request::new(1, vec![0], 10)).unwrap();
        let done = s.drain().unwrap();
        assert_eq!(done[0].tokens, vec![1, 2, 3]);
    }

    #[test]
    fn max_seq_len_bounds_generation() {
        let mut s = Scheduler::new(
            MockBackend::new(16, 8), // tiny context
            SchedulerConfig::default(),
        );
        s.admit(Request::new(1, vec![1, 2, 3, 4], 100)).unwrap();
        let done = s.drain().unwrap();
        assert_eq!(done[0].tokens.len() + 4, 8);
    }

    #[test]
    fn failed_prefill_requeues() {
        let mut s = sched(4);
        s.backend.fail_prefill = true;
        let r = s.admit(Request::new(1, vec![1], 2));
        assert!(r.is_err());
        s.kv.check_invariants().unwrap();
        assert_eq!(s.kv.used_blocks(), 0, "failed admit must not leak blocks");
    }

    #[test]
    fn temperature_sampling_deterministic_per_request() {
        let logits = vec![0.0, 1.0, 2.0, 3.0];
        let mut r1 = Request::new(42, vec![1], 4);
        r1.temperature = Some(1.0);
        let a = super::sample(&logits, &r1);
        let b = super::sample(&logits, &r1);
        assert_eq!(a, b);
    }

    #[test]
    fn native_backend_serves_real_model() {
        use crate::model::{ModelConfig, Transformer};
        let model = Transformer::new_mha(ModelConfig::tiny(), 11);
        let mut s = Scheduler::new(NativeBackend::new(model), SchedulerConfig::default());
        s.admit(Request::new(1, vec![5, 6, 7], 4)).unwrap();
        let done = s.drain().unwrap();
        assert_eq!(done[0].tokens.len(), 4);
        assert!(done[0].tokens.iter().all(|&t| t < 256));
    }

    #[test]
    fn native_mha_and_bda_generate_identical_tokens() {
        // The serving-level losslessness check: greedy decodes agree.
        use crate::bd::Strategy;
        use crate::model::{ModelConfig, Transformer};
        use crate::tensor::DType;
        let mha = Transformer::new_mha(ModelConfig::tiny(), 13);
        let bda = mha.to_bda(Strategy::ResidualMin, DType::F32).unwrap();
        let mut s1 = Scheduler::new(NativeBackend::new(mha), SchedulerConfig::default());
        let mut s2 = Scheduler::new(NativeBackend::new(bda), SchedulerConfig::default());
        s1.admit(Request::new(1, vec![9, 4, 17], 8)).unwrap();
        s2.admit(Request::new(1, vec![9, 4, 17], 8)).unwrap();
        let t1 = s1.drain().unwrap().remove(0).tokens;
        let t2 = s2.drain().unwrap().remove(0).tokens;
        assert_eq!(t1, t2, "BDA must reproduce MHA's greedy decode exactly");
    }
}
