//! Continuous-batching scheduler: admits requests (prefill), interleaves
//! batched decode steps across active sequences, samples, and completes.
//! Sequences a pool-owning backend preempts under memory pressure are
//! parked and re-admitted ahead of the waiting queue with their token
//! record replayed through the prefill path (recompute-on-resume, bitwise
//! — engine invariant 5).
//!
//! On backends that support it ([`Backend::supports_chunked_prefill`]),
//! prompt prefill is **chunked**: admission only reserves blocks
//! ([`Backend::begin_prefill`]), and the prompt's query rows are then fed
//! through the same fused batched step as the active decodes, at most
//! [`SchedulerConfig::prefill_chunk`] prompt tokens per step
//! (Sarathi/vLLM-style continuous batching). A long prompt no longer
//! stalls every active sequence for its full length — time-between-tokens
//! stays bounded by the chunk budget — and the generated tokens are
//! bit-identical at any budget (engine invariant 6). Preempted sequences
//! resume through the same chunked path, ahead of the waiting queue.
//!
//! The backend abstraction separates coordination from compute so the same
//! scheduler serves: the native Rust transformer (incremental KV decode),
//! the PJRT artifact backend (AOT-compiled JAX model), and a mock backend
//! for deterministic tests.

use super::kv_cache::{BlockAllocator, KvCacheConfig, SeqId};
use super::metrics::{Metrics, StepTiming};
use super::request::{Request, Response};
use crate::model::transformer::{KvCache, Transformer};
use crate::obs::{self, Phase};
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// Result of one batched decode step: per-sequence logits for every
/// sequence that advanced, plus the sequences the backend **preempted**
/// under pool exhaustion instead of erroring out of the step.
///
/// A preempted sequence's backend state (K/V blocks, history) is gone by
/// the time the outcome is returned — the caller owns its token record
/// and re-admits it later by replaying that record through the prefill
/// path (recompute-on-resume). Row determinism makes the recomputed K/V
/// bit-identical, so a resumed sequence's output equals an uninterrupted
/// run's (engine invariant 5).
#[derive(Debug)]
pub struct DecodeOutcome {
    /// One entry per input sequence, in input order: `Some(logits)` for
    /// sequences that advanced, `None` for preempted ones.
    pub logits: Vec<Option<Vec<f32>>>,
    /// Sequences preempted during this step (their `logits` entry is
    /// `None`); the step itself still succeeds for everyone else.
    pub preempted: Vec<SeqId>,
}

impl DecodeOutcome {
    /// Outcome of a step that advanced every sequence (backends without a
    /// pool never preempt).
    pub fn complete(logits: Vec<Vec<f32>>) -> DecodeOutcome {
        DecodeOutcome { logits: logits.into_iter().map(Some).collect(), preempted: Vec::new() }
    }

    /// All logits, panicking if any sequence was preempted — for tests
    /// and benches that drive a backend with an ample pool directly.
    pub fn expect_complete(self) -> Vec<Vec<f32>> {
        assert!(self.preempted.is_empty(), "unexpected preemption of {:?}", self.preempted);
        self.logits.into_iter().map(|l| l.expect("logits present")).collect()
    }
}

/// One unit of work in a fused batched step ([`Backend::step`]): either a
/// single-token decode for an active sequence, or a chunk of a sequence's
/// prompt prefill. A chunk-capable backend runs both through the same
/// batched forward pass — one embedding gather, batched GEMMs over every
/// row, one multi-row paged-attention call per layer — so a prefill chunk
/// costs the decodes riding the same step no extra passes.
#[derive(Clone, Debug)]
pub enum StepWork {
    /// Append `token` to `seq`'s K/V and decode one row.
    Decode { seq: SeqId, token: u32 },
    /// Process prompt positions `start .. start + tokens.len()` of `seq`.
    /// The sequence's blocks were reserved by [`Backend::begin_prefill`];
    /// the returned logits row is the chunk's last position (only the
    /// final chunk's row is sampled).
    PrefillChunk { seq: SeqId, tokens: Vec<u32>, start: usize },
}

/// Thread-safe longest-cached-prefix probe: `prompt -> whole blocks
/// cached`, shared between a worker's backend (which owns the prefix
/// cache) and the router thread (which compares shards). See
/// [`Backend::router_probe`].
pub type PrefixProbeHandle = Arc<dyn Fn(&[u32]) -> usize + Send + Sync>;

/// Model compute interface used by the scheduler.
///
/// Not `Send` by itself (the PJRT wrapper types are thread-pinned); the
/// threaded [`super::server::Server`] adds a `Send` bound, while the
/// synchronous `replay_trace` path works with any backend.
pub trait Backend {
    fn vocab_size(&self) -> usize;
    fn max_seq_len(&self) -> usize;
    /// Start a sequence (prefill); returns logits for the last prompt
    /// position.
    fn prefill(&mut self, seq: SeqId, prompt: &[u32]) -> Result<Vec<f32>>;
    /// One decode step for a batch of sequences, feeding each its last
    /// token. A pool-owning backend whose pool runs dry mid-step preempts
    /// victims (reported in the outcome) rather than failing the step;
    /// `Err` is reserved for genuine failures — including exhaustion with
    /// no preemptible sequence left.
    fn decode(&mut self, seqs: &[(SeqId, u32)]) -> Result<DecodeOutcome>;
    /// Drop per-sequence state.
    fn release(&mut self, seq: SeqId);
    /// Free blocks in the backend's *own* KV pool — the engine truth —
    /// when the backend owns real block storage. `None` means the backend
    /// has no pool of its own and the scheduler must fall back to its
    /// admission-side [`BlockAllocator`]. Routing admission through this
    /// method makes engine-level state the shadow allocator cannot see
    /// (e.g. `fork`/copy-on-write dedup) visible to capacity decisions.
    fn free_blocks(&self) -> Option<usize> {
        None
    }
    /// Timing split of the most recent decode step, if this backend
    /// instruments its hot path. Consumed (take) by the scheduler after
    /// every step so stale timings are never re-reported.
    fn take_step_timing(&mut self) -> Option<StepTiming> {
        None
    }
    /// `(actual allocated bytes, storage dtype name)` of the backend's own
    /// K/V pool, when it owns real block storage — surfaced through
    /// [`super::metrics::Snapshot`] so the memory the report claims is the
    /// memory the process holds (a 16-bit pool reports half an f32 pool's
    /// bytes). `None` for backends without a pool.
    fn kv_pool(&self) -> Option<(usize, &'static str)> {
        None
    }
    /// Record `seq`'s scheduling priority (its request's
    /// [`super::request::RequestClass`]) so a pool-owning backend's
    /// config-gated victim policy (`BDA_CLASS_PREEMPT=1`) can evict the
    /// lowest class first. Called at admission and resume; backends
    /// without class-aware preemption ignore it.
    fn note_seq_priority(&mut self, seq: SeqId, priority: u8) {
        let _ = (seq, priority);
    }
    /// Pool occupancy counters for the continuous resource sampler
    /// ([`crate::obs::sampler`]), when the backend owns real block
    /// storage. `None` (the default) omits the pool gauges from the
    /// sampled series; queue depths are still recorded.
    fn pool_counters(&self) -> Option<crate::obs::sampler::PoolCounters> {
        None
    }
    /// Whether this backend can run prompt prefill as [`StepWork::PrefillChunk`]
    /// entries fused into batched steps. When `false` the scheduler uses
    /// the monolithic [`Backend::prefill`] path unchanged.
    fn supports_chunked_prefill(&self) -> bool {
        false
    }
    /// Reserve a sequence's K/V blocks (adopting any cached prefix) without
    /// running the forward pass; the prompt rows are then fed through
    /// [`Backend::step`] as chunks. Returns the number of leading prompt
    /// tokens already resident from a prefix-cache hit — the scheduler
    /// starts chunking after them. Only meaningful when
    /// [`Backend::supports_chunked_prefill`] is `true`.
    fn begin_prefill(&mut self, seq: SeqId, prompt: &[u32]) -> Result<usize> {
        let _ = (seq, prompt);
        anyhow::bail!("backend does not support chunked prefill")
    }
    /// How many whole K/V blocks of `prompt` this backend's prefix cache
    /// already holds — a **read-only** probe (no LRU touch, no holds, no
    /// stat counters) the sharded router compares across shards to place a
    /// request on the shard with its longest cached prefix. `0` (the
    /// default) for backends without a prefix cache.
    fn cached_prefix_blocks(&self, prompt: &[u32]) -> usize {
        let _ = prompt;
        0
    }
    /// A `Send + Sync` handle performing [`Backend::cached_prefix_blocks`]
    /// probes without `&self` — the threaded router holds one per worker
    /// and probes shards whose backends live on other threads. `None` (the
    /// default) tells the router to treat this shard as having no cached
    /// prefixes.
    fn router_probe(&self) -> Option<PrefixProbeHandle> {
        None
    }
    /// One fused batched step over mixed decode + prefill-chunk work. The
    /// default forwards pure-decode work to [`Backend::decode`]; backends
    /// that advertise [`Backend::supports_chunked_prefill`] override it.
    /// Returns one logits entry per work item, in order (`None` only for
    /// preempted decode entries; a chunk entry's row is its last position).
    fn step(&mut self, work: &[StepWork]) -> Result<DecodeOutcome> {
        let batch: Vec<(SeqId, u32)> = work
            .iter()
            .map(|w| match w {
                StepWork::Decode { seq, token } => Ok((*seq, *token)),
                StepWork::PrefillChunk { seq, .. } => Err(anyhow::anyhow!(
                    "backend does not support chunked prefill (chunk for seq {seq})"
                )),
            })
            .collect::<Result<_>>()?;
        self.decode(&batch)
    }
}

/// Backend over the pure-Rust transformer with per-sequence KV caches.
pub struct NativeBackend {
    pub model: Transformer,
    caches: HashMap<SeqId, KvCache>,
}

impl NativeBackend {
    pub fn new(model: Transformer) -> NativeBackend {
        NativeBackend { model, caches: HashMap::new() }
    }
}

impl Backend for NativeBackend {
    fn vocab_size(&self) -> usize {
        self.model.config.vocab_size
    }

    fn max_seq_len(&self) -> usize {
        self.model.config.max_seq_len
    }

    fn prefill(&mut self, seq: SeqId, prompt: &[u32]) -> Result<Vec<f32>> {
        let mut cache = KvCache::new(self.model.config.n_layers);
        let logits = self.model.prefill(&mut cache, prompt);
        self.caches.insert(seq, cache);
        Ok(logits.data)
    }

    fn decode(&mut self, seqs: &[(SeqId, u32)]) -> Result<DecodeOutcome> {
        // Per-sequence incremental decode (each has its own cache).
        let mut out = Vec::with_capacity(seqs.len());
        for &(id, tok) in seqs {
            let cache = self
                .caches
                .get_mut(&id)
                .ok_or_else(|| anyhow::anyhow!("decode: unknown seq {id}"))?;
            let logits = self.model.decode_step(cache, tok);
            out.push(logits.data);
        }
        Ok(DecodeOutcome::complete(out))
    }

    fn release(&mut self, seq: SeqId) {
        self.caches.remove(&seq);
    }
}

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Max sequences decoded per iteration.
    pub max_active: usize,
    /// Optional stop token.
    pub eos_token: Option<u32>,
    pub kv: KvCacheConfig,
    /// Prompt-token budget per batched step for chunked prefill (`0` =
    /// unbounded: the whole remaining prompt in one chunk). Ignored on
    /// backends without chunked-prefill support. Defaults from
    /// `BDA_PREFILL_CHUNK` (unset → 512). Generated tokens are
    /// bit-identical at any value (engine invariant 6); the budget only
    /// trades prefill throughput against decode time-between-tokens.
    pub prefill_chunk: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_active: 16,
            eos_token: None,
            kv: KvCacheConfig::default(),
            prefill_chunk: prefill_chunk_from_env(),
        }
    }
}

/// Per-step chunked-prefill token budget from `BDA_PREFILL_CHUNK`:
/// `0` = unbounded, unset or unparseable = 512.
pub fn prefill_chunk_from_env() -> usize {
    std::env::var("BDA_PREFILL_CHUNK").ok().and_then(|v| v.parse::<usize>().ok()).unwrap_or(512)
}

struct ActiveSeq {
    req: Request,
    generated: Vec<u32>,
    first_token_at: Option<Instant>,
    /// When the most recent token was sampled — the previous point of the
    /// sequence's time-between-tokens (TBT) series. Reuses the sampling
    /// timer's clock read, so TBT tracking adds none of its own.
    last_token_at: Option<Instant>,
    last_token: u32,
    /// Worst observed gap between consecutive sampled tokens, seconds —
    /// the response's `max_tbt`, scored against the class TBT budget. A
    /// park/resume cycle's recompute gap lands here naturally (the field
    /// rides the parked state), so an evicted victim that blows its
    /// budget is scored truthfully.
    max_tbt: f64,
}

/// A preempted sequence parked for resume: the backend released its
/// blocks; the scheduler keeps the full token record and replays
/// `prompt + generated[..len-1]` through the prefill path when capacity
/// returns (the last generated token has no K/V row yet — it is the next
/// decode step's input, exactly as it was at preemption time).
struct ParkedSeq {
    /// The sequence id it ran under — reused on resume, so engine-side
    /// victim selection ("youngest = largest id") keeps respecting
    /// original admission order across preempt/resume cycles.
    seq: SeqId,
    state: ActiveSeq,
    /// When the backend evicted it — the parked interval shows up as a
    /// `park` span on the sequence's trace track.
    parked_at: Instant,
}

/// A sequence whose prompt (or preemption replay) is mid-chunked-prefill:
/// its blocks are reserved ([`Backend::begin_prefill`]) and its remaining
/// token rows are fed through batched steps under the per-step budget.
/// It joins `active` when the last chunk completes.
struct PrefillingSeq {
    seq: SeqId,
    /// The full token record being prefilled: the prompt for an
    /// admission, `prompt + generated[..len-1]` for a resume.
    tokens: Vec<u32>,
    /// Leading tokens already resident (prefix-cache adoption at
    /// `begin_prefill`, plus every chunk processed so far).
    covered: usize,
    kind: PrefillKind,
}

enum PrefillKind {
    /// A fresh admission: the final chunk's logits row is sampled for the
    /// first token. `prefill_begin` anchors the aggregate `prefill` span
    /// (block reservation through final chunk) on the request's trace
    /// track; the per-step `prefill_chunk` spans nest under it.
    Admission { req: Request, prefill_begin: Instant },
    /// A preempt→resume replay: the final chunk's logits are discarded
    /// (the token they produce is already in the record) and the parked
    /// [`ActiveSeq`] rejoins decode unchanged.
    Resume { state: ActiveSeq, parked_at: Instant, resume_begin: Instant },
}

/// The continuous-batching engine.
pub struct Scheduler<B: Backend> {
    pub backend: B,
    pub config: SchedulerConfig,
    /// Shadow admission allocator, maintained **only** for pool-less
    /// backends (`Backend::free_blocks() == None`). Pool-owning backends
    /// retire it entirely (`None`): the engine allocator is the single
    /// owner of block truth — admission, growth, forks, copy-on-write,
    /// prefix-cache holds, and preemption all live in one place.
    pub kv: Option<BlockAllocator>,
    active: Vec<ActiveSeq>,
    /// Preempted sequences awaiting resume, re-admitted ahead of the
    /// waiting queue (oldest admission first).
    preempted: Vec<ParkedSeq>,
    /// Sequences mid-chunked-prefill (chunk-capable backends only).
    /// Resumes are inserted at the front so they outrank queued
    /// admissions, matching the monolithic resume priority.
    prefilling: Vec<PrefillingSeq>,
    next_seq: SeqId,
    seq_of_req: HashMap<u64, SeqId>,
    metrics: Option<Arc<Metrics>>,
    /// Resume counters accumulated since the last step-timing report
    /// (merged into the next [`StepTiming`] forwarded to the metrics).
    pending_resumes: u64,
    pending_recomputed: u64,
}

impl<B: Backend> Scheduler<B> {
    pub fn new(backend: B, config: SchedulerConfig) -> Scheduler<B> {
        // One allocator owner per pool: backends that report their own
        // block truth never get a shadow.
        let kv = backend.free_blocks().is_none().then(|| BlockAllocator::new(config.kv));
        Scheduler {
            backend,
            kv,
            config,
            active: Vec::new(),
            preempted: Vec::new(),
            prefilling: Vec::new(),
            next_seq: 1,
            seq_of_req: HashMap::new(),
            metrics: None,
            pending_resumes: 0,
            pending_recomputed: 0,
        }
    }

    /// Attach a metrics sink; each decode iteration then emits its batch
    /// size and occupancy (tokens-per-step / decode-batch counters). A
    /// pool-owning backend's actual allocated pool bytes and storage dtype
    /// are recorded once here (capacity is fixed at construction).
    pub fn set_metrics(&mut self, metrics: Arc<Metrics>) {
        if let Some((bytes, dtype)) = self.backend.kv_pool() {
            metrics.set_kv_pool(bytes, dtype);
        }
        self.metrics = Some(metrics);
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Sequences preempted under pool pressure and parked for resume.
    pub fn preempted_count(&self) -> usize {
        self.preempted.len()
    }

    /// Sequences whose prompt is mid-chunked-prefill (not yet decoding).
    pub fn prefilling_count(&self) -> usize {
        self.prefilling.len()
    }

    /// Free blocks available to admission, from whichever allocator owns
    /// the pool truth (engine pool for pool-owning backends, the shadow
    /// otherwise).
    fn admission_free_blocks(&self) -> usize {
        match self.backend.free_blocks() {
            Some(free) => free,
            None => self.kv.as_ref().map(|kv| kv.free_blocks()).unwrap_or(usize::MAX),
        }
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.max(1).div_ceil(self.config.kv.block_size)
    }

    pub fn has_capacity_for(&self, req: &Request) -> bool {
        if self.active.len() + self.prefilling.len() >= self.config.max_active {
            return false;
        }
        // Parked (preempted) sequences outrank the waiting queue: their
        // requests are mid-generation, so new admissions wait until every
        // parked sequence is resumed.
        if !self.preempted.is_empty() {
            return false;
        }
        // Engine pool truth when the backend owns real block storage (so
        // engine-level forks / copy-on-write / prefix-cache residency are
        // visible to admission); the admission-side shadow allocator
        // otherwise. Block geometry comes from this scheduler's config,
        // which every construction site shares with the backend pool.
        self.blocks_for(req.prompt.len()) <= self.admission_free_blocks()
    }

    /// Admit a request: KV registration + prefill + first sampled token.
    /// On failure the request is returned for re-queueing.
    pub fn admit(&mut self, req: Request) -> std::result::Result<(), Request> {
        if !self.has_capacity_for(&req) {
            return Err(req);
        }
        // Tracing clock reads are gated so a disabled trace adds nothing
        // to the admission path beyond one relaxed load.
        let admit_start = obs::enabled().then(Instant::now);
        let seq = self.next_seq;
        if self.backend.supports_chunked_prefill() {
            // Chunk-capable backends: reserve blocks (adopting any cached
            // prefix) now; the prompt rows ride subsequent batched steps
            // under the per-step budget. The first token is sampled when
            // the final chunk lands.
            let Ok(covered) = self.backend.begin_prefill(seq, &req.prompt) else {
                return Err(req);
            };
            self.backend.note_seq_priority(seq, req.class.priority);
            self.next_seq += 1;
            self.seq_of_req.insert(req.id, seq);
            if let Some(t0) = admit_start {
                obs::span_at(
                    Phase::Enqueue,
                    req.id,
                    req.arrival,
                    t0.saturating_duration_since(req.arrival),
                );
                obs::span_at(Phase::Admit, req.id, t0, t0.elapsed());
            }
            let tokens = req.prompt.clone();
            self.prefilling.push(PrefillingSeq {
                seq,
                tokens,
                covered,
                kind: PrefillKind::Admission { req, prefill_begin: Instant::now() },
            });
            return Ok(());
        }
        // The shadow allocator is worst-case bookkeeping (no prefix
        // sharing, no eviction) for pool-less backends only; pool owners
        // retired it (`self.kv` is `None`) — their own allocator is the
        // single source of block truth.
        if let Some(kv) = &mut self.kv {
            if kv.register(seq, req.prompt.len()).is_err() {
                return Err(req);
            }
        }
        let prefill_start = admit_start.map(|_| Instant::now());
        let logits = match self.backend.prefill(seq, &req.prompt) {
            Ok(l) => l,
            Err(_) => {
                if let Some(kv) = &mut self.kv {
                    let _ = kv.release(seq);
                }
                return Err(req);
            }
        };
        if let Some(t) = prefill_start {
            obs::span_at(Phase::Prefill, req.id, t, t.elapsed());
        }
        self.next_seq += 1;
        self.backend.note_seq_priority(seq, req.class.priority);
        let first = sample(&logits, &req);
        self.seq_of_req.insert(req.id, seq);
        let first_at = Instant::now();
        let mut seq_state = ActiveSeq {
            last_token: first,
            generated: vec![first],
            first_token_at: Some(first_at),
            last_token_at: Some(first_at),
            max_tbt: 0.0,
            req,
        };
        // A request asking for 0 tokens completes immediately on next step;
        // normalize to at least the first token.
        if seq_state.req.max_new_tokens == 0 {
            seq_state.generated.clear();
        }
        if let Some(t0) = admit_start {
            let r = &seq_state.req;
            // Queue wait (arrival → admission start), then the admission
            // itself; plus the first token of the sequence's timeline.
            obs::span_at(Phase::Enqueue, r.id, r.arrival, t0.saturating_duration_since(r.arrival));
            obs::span_at(Phase::Admit, r.id, t0, t0.elapsed());
            if !seq_state.generated.is_empty() {
                obs::event_at(Phase::Token, r.id, first_at);
            }
        }
        self.active.push(seq_state);
        Ok(())
    }

    /// Resume parked (preempted) sequences — oldest admission first, ahead
    /// of any queued work — by replaying each one's token record through
    /// the prefill path. The replayed K/V is bit-identical to the released
    /// state (engine invariant 5), so generation continues exactly where
    /// it stopped; the replay prefill's logits are discarded because the
    /// token they produced is already in the record.
    fn try_resume(&mut self) -> Result<()> {
        if self.preempted.is_empty() {
            return Ok(());
        }
        self.preempted.sort_unstable_by_key(|p| p.seq);
        while !self.preempted.is_empty()
            && self.active.len() + self.prefilling.len() < self.config.max_active
        {
            let replay_len = {
                let s = &self.preempted[0].state;
                s.req.prompt.len() + s.generated.len().saturating_sub(1)
            };
            let need = self.blocks_for(replay_len);
            if need > self.config.kv.num_blocks {
                // The same terminal condition the uninterrupted run would
                // have hit: this sequence cannot fit the pool even alone.
                anyhow::bail!(
                    "resume of request {} needs {need} blocks but the pool has {} total",
                    self.preempted[0].state.req.id,
                    self.config.kv.num_blocks,
                );
            }
            if need > self.admission_free_blocks() {
                if self.active.is_empty() && self.prefilling.is_empty() {
                    // Nothing left to complete or preempt, maximum
                    // reclaimable capacity reached, still short: the pool
                    // genuinely cannot serve this sequence.
                    anyhow::bail!(
                        "resume of request {} needs {need} blocks but only {} are reclaimable",
                        self.preempted[0].state.req.id,
                        self.admission_free_blocks(),
                    );
                }
                break; // wait for completions to free capacity
            }
            let p = self.preempted.remove(0);
            let replay: Vec<u32> = p
                .state
                .req
                .prompt
                .iter()
                .chain(p.state.generated[..p.state.generated.len().saturating_sub(1)].iter())
                .copied()
                .collect();
            if self.backend.supports_chunked_prefill() {
                // Replay rides the chunked path like any prompt, but at
                // the front of the chunk queue: resumes outrank queued
                // admissions (same priority the monolithic path gives
                // them by resuming before `admit` can run).
                let covered = self.backend.begin_prefill(p.seq, &replay)?;
                self.backend.note_seq_priority(p.seq, p.state.req.class.priority);
                self.seq_of_req.insert(p.state.req.id, p.seq);
                self.prefilling.insert(
                    0,
                    PrefillingSeq {
                        seq: p.seq,
                        tokens: replay,
                        covered,
                        kind: PrefillKind::Resume {
                            state: p.state,
                            parked_at: p.parked_at,
                            resume_begin: Instant::now(),
                        },
                    },
                );
                continue;
            }
            if let Some(kv) = &mut self.kv {
                let _ = kv.register(p.seq, replay.len());
            }
            let resume_start = obs::enabled().then(Instant::now);
            self.backend.prefill(p.seq, &replay)?;
            self.backend.note_seq_priority(p.seq, p.state.req.class.priority);
            if let Some(t) = resume_start {
                let id = p.state.req.id;
                let parked = t.saturating_duration_since(p.parked_at);
                obs::span_at(Phase::Park, id, p.parked_at, parked);
                obs::span_at(Phase::Resume, id, t, t.elapsed());
            }
            self.pending_resumes += 1;
            self.pending_recomputed += replay.len() as u64;
            self.seq_of_req.insert(p.state.req.id, p.seq);
            self.active.push(p.state);
        }
        Ok(())
    }

    /// Forward the backend's step timing to the metrics sink, with any
    /// resume counters accumulated since the previous report merged in.
    fn flush_step_timing(&mut self, sample_secs: f64) {
        let Some(m) = &self.metrics else {
            self.pending_resumes = 0;
            self.pending_recomputed = 0;
            return;
        };
        let mut timing = self.backend.take_step_timing();
        if self.pending_resumes > 0 || self.pending_recomputed > 0 {
            let t = timing.get_or_insert_with(StepTiming::default);
            t.resumes += self.pending_resumes;
            t.recomputed_tokens += self.pending_recomputed;
            self.pending_resumes = 0;
            self.pending_recomputed = 0;
        }
        if let Some(t) = timing {
            m.decode_timing(t, sample_secs);
        }
    }

    /// One batched iteration: a decode row for every active sequence plus
    /// prefill chunks (chunk-capable backends) under the per-step token
    /// budget, fused into a single backend step. Returns completed
    /// responses.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();
        // Parked sequences are re-admitted before anything else runs.
        self.try_resume()?;
        if self.active.is_empty() && self.prefilling.is_empty() {
            return Ok(done);
        }
        // Finish check before decoding (covers max_new_tokens == 0/1).
        self.complete_finished(&mut done);
        if self.active.is_empty() && self.prefilling.is_empty() {
            // No decode step will run, but admissions may have recorded
            // backend counters (e.g. prefix-cache hits for max_new <= 1
            // requests) — surface them rather than dropping the tail.
            self.flush_step_timing(0.0);
            return Ok(done);
        }

        let decode_n = self.active.len();
        let mut work: Vec<StepWork> = self
            .active
            .iter()
            .map(|a| StepWork::Decode { seq: self.seq_of_req[&a.req.id], token: a.last_token })
            .collect();
        // Prefill chunks ride the same step, FIFO over the prefilling
        // queue, at most `prefill_chunk` prompt tokens total per step
        // (0 = unbounded).
        let mut budget =
            if self.config.prefill_chunk == 0 { usize::MAX } else { self.config.prefill_chunk };
        // (prefilling index, rows contributed this step), aligned with
        // `work[decode_n..]`.
        let mut chunk_rows: Vec<(usize, usize)> = Vec::new();
        for (pi, p) in self.prefilling.iter().enumerate() {
            if budget == 0 {
                break;
            }
            let n = (p.tokens.len() - p.covered).min(budget);
            budget -= n;
            work.push(StepWork::PrefillChunk {
                seq: p.seq,
                tokens: p.tokens[p.covered..p.covered + n].to_vec(),
                start: p.covered,
            });
            chunk_rows.push((pi, n));
        }
        if decode_n > 0 {
            if let Some(m) = &self.metrics {
                m.decode_step(decode_n, self.config.max_active);
            }
        }
        let step_start = obs::enabled().then(Instant::now);
        let outcome = self.backend.step(&work)?;
        let step_elapsed = step_start.map(|t| t.elapsed());
        if let (Some(t), Some(d)) = (step_start, step_elapsed) {
            obs::span_at(Phase::DecodeStep, work.len() as u64, t, d);
        }
        anyhow::ensure!(
            outcome.logits.len() == work.len(),
            "backend returned {} logit rows for a {}-entry step",
            outcome.logits.len(),
            work.len(),
        );
        // The scheduler parks on the `None` logit rows; `preempted` is the
        // same information in id form (kept for tests/metrics consumers).
        // A backend that lets the two drift has a bug — catch it early.
        debug_assert!(
            {
                let mut none_ids: Vec<SeqId> = work[..decode_n]
                    .iter()
                    .zip(&outcome.logits)
                    .filter(|(_, l)| l.is_none())
                    .map(|(w, _)| match w {
                        StepWork::Decode { seq, .. } => *seq,
                        StepWork::PrefillChunk { seq, .. } => *seq,
                    })
                    .collect();
                none_ids.sort_unstable();
                let mut reported = outcome.preempted.clone();
                reported.sort_unstable();
                none_ids == reported
            },
            "backend's preempted list disagrees with its None logit rows"
        );
        let mut logit_rows = outcome.logits.into_iter();
        let mut sample_secs = 0.0f64;
        let mut tbts: Vec<f64> = Vec::new();
        let stepped = std::mem::take(&mut self.active);
        for (mut a, l) in stepped.into_iter().zip(&mut logit_rows) {
            let seq = self.seq_of_req[&a.req.id];
            let Some(l) = l else {
                // Preempted by the backend: its engine-side state is gone
                // and no token was produced this step. Park the request's
                // token record for a recompute-on-resume re-admission.
                self.seq_of_req.remove(&a.req.id);
                if let Some(kv) = &mut self.kv {
                    let _ = kv.release(seq);
                }
                obs::instant(Phase::Preempt, a.req.id);
                self.preempted.push(ParkedSeq { seq, state: a, parked_at: Instant::now() });
                continue;
            };
            // Time only sample() so the metrics split doesn't charge
            // allocator bookkeeping to the "sampling" bucket. The closing
            // clock read doubles as the token timestamp for TBT and the
            // sequence's trace timeline — no extra reads per token.
            let t = Instant::now();
            let tok = sample(&l, &a.req);
            let now = Instant::now();
            sample_secs += (now - t).as_secs_f64();
            obs::span_at(Phase::Sample, a.req.id, t, now - t);
            obs::event_at(Phase::Token, a.req.id, now);
            a.generated.push(tok);
            a.last_token = tok;
            if a.first_token_at.is_none() {
                a.first_token_at = Some(now);
            }
            if let Some(prev) = a.last_token_at {
                let gap = now.saturating_duration_since(prev).as_secs_f64();
                a.max_tbt = a.max_tbt.max(gap);
                tbts.push(gap);
            }
            a.last_token_at = Some(now);
            // Shadow-allocator growth tracking, pool-less backends only.
            if let Some(kv) = &mut self.kv {
                let _ = kv.append_token(seq);
            }
            self.active.push(a);
        }
        // Advance the prefilling queue by the chunks that rode this step.
        // Chunks are never preempted (their blocks were reserved up
        // front), so every chunk entry has a logits row.
        let mut finished: Vec<(usize, Vec<f32>)> = Vec::new();
        for &(pi, rows) in &chunk_rows {
            let l = logit_rows
                .next()
                .flatten()
                .ok_or_else(|| anyhow::anyhow!("backend dropped a prefill-chunk logits row"))?;
            let p = &mut self.prefilling[pi];
            if let (Some(t), Some(d)) = (step_start, step_elapsed) {
                let id = match &p.kind {
                    PrefillKind::Admission { req, .. } => req.id,
                    PrefillKind::Resume { state, .. } => state.req.id,
                };
                obs::span_at(Phase::PrefillChunk, id, t, d);
            }
            p.covered += rows;
            if p.covered == p.tokens.len() {
                // Only the final chunk's logits row is meaningful: it is
                // the prompt's last position.
                finished.push((pi, l));
            }
        }
        // Remove back-to-front (indices stay valid), activate in FIFO
        // order.
        let mut activated: Vec<(PrefillingSeq, Vec<f32>)> = Vec::new();
        for (pi, l) in finished.into_iter().rev() {
            activated.push((self.prefilling.remove(pi), l));
        }
        for (p, l) in activated.into_iter().rev() {
            self.activate_prefilled(p, l);
        }
        if let Some(m) = &self.metrics {
            m.record_tbts(&tbts);
        }
        self.flush_step_timing(sample_secs);
        self.complete_finished(&mut done);
        // Step boundary: one resource sample for the Perfetto counter
        // tracks / Prometheus gauges, then drain every thread's trace
        // ring (both a single relaxed load when tracing is disabled —
        // sampling observes, never steers, the token stream).
        if obs::enabled() {
            obs::sampler::record(
                self.backend.pool_counters(),
                self.active.len(),
                self.prefilling.len(),
                self.preempted.len(),
            );
        }
        obs::flush();
        Ok(done)
    }

    /// A sequence's final prefill chunk landed: sample the first token
    /// (admissions) or discard the replayed logits (resumes) and move the
    /// sequence to the active set.
    fn activate_prefilled(&mut self, p: PrefillingSeq, logits: Vec<f32>) {
        match p.kind {
            PrefillKind::Admission { req, prefill_begin } => {
                if obs::enabled() {
                    obs::span_at(Phase::Prefill, req.id, prefill_begin, prefill_begin.elapsed());
                }
                let first = sample(&logits, &req);
                let first_at = Instant::now();
                let mut seq_state = ActiveSeq {
                    last_token: first,
                    generated: vec![first],
                    first_token_at: Some(first_at),
                    last_token_at: Some(first_at),
                    max_tbt: 0.0,
                    req,
                };
                // A request asking for 0 tokens completes immediately on
                // the next step; normalize to at least the first token.
                if seq_state.req.max_new_tokens == 0 {
                    seq_state.generated.clear();
                }
                if obs::enabled() && !seq_state.generated.is_empty() {
                    obs::event_at(Phase::Token, seq_state.req.id, first_at);
                }
                self.active.push(seq_state);
            }
            PrefillKind::Resume { state, parked_at, resume_begin } => {
                // The replay's last logits row reproduces a token already
                // in the record — drop it; decode continues from
                // `state.last_token` exactly where preemption struck.
                drop(logits);
                if obs::enabled() {
                    let id = state.req.id;
                    let parked = resume_begin.saturating_duration_since(parked_at);
                    obs::span_at(Phase::Park, id, parked_at, parked);
                    obs::span_at(Phase::Resume, id, resume_begin, resume_begin.elapsed());
                }
                self.pending_resumes += 1;
                self.pending_recomputed += p.tokens.len() as u64;
                self.active.push(state);
            }
        }
    }

    fn complete_finished(&mut self, done: &mut Vec<Response>) {
        let eos = self.config.eos_token;
        let max_total = self.backend.max_seq_len();
        let mut i = 0;
        while i < self.active.len() {
            let a = &self.active[i];
            let hit_eos = eos.map(|e| a.generated.last() == Some(&e)).unwrap_or(false);
            let full = a.req.prompt.len() + a.generated.len() >= max_total;
            if a.generated.len() >= a.req.max_new_tokens || hit_eos || full {
                let a = self.active.remove(i);
                let seq = self.seq_of_req.remove(&a.req.id).unwrap();
                if let Some(kv) = &mut self.kv {
                    let _ = kv.release(seq);
                }
                self.backend.release(seq);
                let now = Instant::now();
                obs::event_at(Phase::Complete, a.req.id, now);
                done.push(Response {
                    id: a.req.id,
                    prompt_len: a.req.prompt.len(),
                    ttft: a
                        .first_token_at
                        .map(|t| (t - a.req.arrival).as_secs_f64())
                        .unwrap_or(0.0),
                    latency: (now - a.req.arrival).as_secs_f64(),
                    class: a.req.class,
                    max_tbt: a.max_tbt,
                    tokens: a.generated,
                });
            } else {
                i += 1;
            }
        }
    }

    /// Drain: run steps until every active, parked, *and prefilling*
    /// sequence completes.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while !self.active.is_empty() || !self.preempted.is_empty() || !self.prefilling.is_empty()
        {
            out.extend(self.step()?);
        }
        Ok(out)
    }
}

/// Sampling: greedy argmax, or temperature sampling seeded by request id
/// (deterministic per request).
fn sample(logits: &[f32], req: &Request) -> u32 {
    match req.temperature {
        None => argmax(logits),
        Some(t) if t <= 0.0 => argmax(logits),
        Some(t) => {
            let mut rng = Rng::new(req.id ^ 0x5bd1e995);
            let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f64> = logits.iter().map(|&l| (((l - max) / t) as f64).exp()).collect();
            let total: f64 = exps.iter().sum();
            let mut u = rng.next_f64() * total;
            for (i, e) in exps.iter().enumerate() {
                u -= e;
                if u <= 0.0 {
                    return i as u32;
                }
            }
            (logits.len() - 1) as u32
        }
    }
}

fn argmax(xs: &[f32]) -> u32 {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best as u32
}

/// Deterministic mock backend — used by unit/property/integration tests
/// and the batcher ablation bench (kept out of cfg(test) so external test
/// targets and benches can use it).
pub mod test_support {
    use super::*;

    /// Deterministic mock: logits put all mass on (seq_id + step) % vocab.
    pub struct MockBackend {
        pub vocab: usize,
        pub max_seq: usize,
        pub steps: HashMap<SeqId, u32>,
        pub released: Vec<SeqId>,
        pub fail_prefill: bool,
    }

    impl MockBackend {
        pub fn new(vocab: usize, max_seq: usize) -> MockBackend {
            MockBackend {
                vocab,
                max_seq,
                steps: HashMap::new(),
                released: Vec::new(),
                fail_prefill: false,
            }
        }

        fn logits_for(&self, seq: SeqId, step: u32) -> Vec<f32> {
            let mut l = vec![0.0; self.vocab];
            l[((seq as u32 + step) % self.vocab as u32) as usize] = 10.0;
            l
        }
    }

    impl Backend for MockBackend {
        fn vocab_size(&self) -> usize {
            self.vocab
        }
        fn max_seq_len(&self) -> usize {
            self.max_seq
        }
        fn prefill(&mut self, seq: SeqId, _prompt: &[u32]) -> Result<Vec<f32>> {
            if self.fail_prefill {
                anyhow::bail!("mock prefill failure");
            }
            self.steps.insert(seq, 0);
            Ok(self.logits_for(seq, 0))
        }
        fn decode(&mut self, seqs: &[(SeqId, u32)]) -> Result<DecodeOutcome> {
            seqs.iter()
                .map(|&(id, _)| {
                    let s = self.steps.get_mut(&id).expect("unknown seq");
                    *s += 1;
                    let step = *s;
                    Ok(self.logits_for(id, step))
                })
                .collect::<Result<Vec<_>>>()
                .map(DecodeOutcome::complete)
        }
        fn release(&mut self, seq: SeqId) {
            self.released.push(seq);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::MockBackend;
    use super::*;
    use crate::coordinator::metrics::Snapshot;

    fn sched(max_active: usize) -> Scheduler<MockBackend> {
        Scheduler::new(
            MockBackend::new(16, 64),
            SchedulerConfig {
                max_active,
                eos_token: None,
                kv: KvCacheConfig { block_size: 4, num_blocks: 64, ..Default::default() },
                ..SchedulerConfig::default()
            },
        )
    }

    #[test]
    fn generates_exact_token_count() {
        let mut s = sched(8);
        s.admit(Request::new(1, vec![1, 2, 3], 5)).unwrap();
        let done = s.drain().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), 5);
        assert!(done[0].ttft <= done[0].latency);
    }

    #[test]
    fn deterministic_mock_tokens() {
        let mut s = sched(8);
        s.admit(Request::new(1, vec![0], 3)).unwrap();
        let done = s.drain().unwrap();
        // seq id 1: tokens (1+0)%16, (1+1)%16, (1+2)%16
        assert_eq!(done[0].tokens, vec![1, 2, 3]);
    }

    #[test]
    fn interleaves_multiple_requests() {
        let mut s = sched(8);
        s.admit(Request::new(1, vec![1], 2)).unwrap();
        s.admit(Request::new(2, vec![1, 2], 4)).unwrap();
        let done = s.drain().unwrap();
        assert_eq!(done.len(), 2);
        let by_id: HashMap<u64, &Response> = done.iter().map(|r| (r.id, r)).collect();
        assert_eq!(by_id[&1].tokens.len(), 2);
        assert_eq!(by_id[&2].tokens.len(), 4);
    }

    #[test]
    fn respects_max_active() {
        let mut s = sched(1);
        s.admit(Request::new(1, vec![1], 2)).unwrap();
        let rejected = s.admit(Request::new(2, vec![1], 2));
        assert!(rejected.is_err());
        s.drain().unwrap();
        assert!(s.admit(rejected.unwrap_err()).is_ok());
    }

    #[test]
    fn kv_blocks_freed_on_completion() {
        let mut s = sched(8);
        let free0 = s.kv.as_ref().unwrap().free_blocks();
        s.admit(Request::new(1, vec![1, 2, 3, 4, 5], 6)).unwrap();
        assert!(s.kv.as_ref().unwrap().free_blocks() < free0);
        s.drain().unwrap();
        assert_eq!(s.kv.as_ref().unwrap().free_blocks(), free0);
        s.kv.as_ref().unwrap().check_invariants().unwrap();
        assert_eq!(s.backend.released, vec![1]);
    }

    #[test]
    fn response_carries_class_and_worst_token_gap() {
        use crate::coordinator::request::RequestClass;
        let mut s = sched(8);
        let class = RequestClass { priority: 3, ttft_deadline: 0.5, tbt_budget: 0.05 };
        s.admit(Request::new(1, vec![1, 2], 4).with_class(class)).unwrap();
        let done = s.drain().unwrap();
        assert_eq!(done[0].class, class);
        assert!(done[0].max_tbt >= 0.0 && done[0].max_tbt <= done[0].latency);
    }

    #[test]
    fn eos_stops_generation() {
        let mut s = Scheduler::new(
            MockBackend::new(16, 64),
            SchedulerConfig {
                max_active: 4,
                eos_token: Some(3), // seq 1 emits 1, 2, 3 -> stops at 3
                ..SchedulerConfig::default()
            },
        );
        s.admit(Request::new(1, vec![0], 10)).unwrap();
        let done = s.drain().unwrap();
        assert_eq!(done[0].tokens, vec![1, 2, 3]);
    }

    #[test]
    fn max_seq_len_bounds_generation() {
        let mut s = Scheduler::new(
            MockBackend::new(16, 8), // tiny context
            SchedulerConfig::default(),
        );
        s.admit(Request::new(1, vec![1, 2, 3, 4], 100)).unwrap();
        let done = s.drain().unwrap();
        assert_eq!(done[0].tokens.len() + 4, 8);
    }

    #[test]
    fn failed_prefill_requeues() {
        let mut s = sched(4);
        s.backend.fail_prefill = true;
        let r = s.admit(Request::new(1, vec![1], 2));
        assert!(r.is_err());
        s.kv.as_ref().unwrap().check_invariants().unwrap();
        assert_eq!(s.kv.as_ref().unwrap().used_blocks(), 0, "failed admit must not leak blocks");
    }

    /// Pool-less mock whose logits are a pure function of (seq id,
    /// history length), so a preempt→resume replay is transparent: a
    /// resumed sequence continues the exact token stream an uninterrupted
    /// run produces. Preempts the youngest batch member on one chosen
    /// decode call.
    struct PreemptingMock {
        vocab: usize,
        lens: HashMap<SeqId, usize>,
        prefills_per_seq: HashMap<SeqId, usize>,
        preempt_on_call: usize,
        calls: usize,
        unreported_preemptions: u64,
    }

    impl PreemptingMock {
        fn new(vocab: usize, preempt_on_call: usize) -> PreemptingMock {
            PreemptingMock {
                vocab,
                lens: HashMap::new(),
                prefills_per_seq: HashMap::new(),
                preempt_on_call,
                calls: 0,
                unreported_preemptions: 0,
            }
        }

        fn logits_for(&self, seq: SeqId, len: usize) -> Vec<f32> {
            let mut l = vec![0.0; self.vocab];
            l[(seq as usize + len) % self.vocab] = 10.0;
            l
        }
    }

    impl Backend for PreemptingMock {
        fn vocab_size(&self) -> usize {
            self.vocab
        }
        fn max_seq_len(&self) -> usize {
            64
        }
        fn prefill(&mut self, seq: SeqId, prompt: &[u32]) -> Result<Vec<f32>> {
            self.lens.insert(seq, prompt.len());
            *self.prefills_per_seq.entry(seq).or_insert(0) += 1;
            Ok(self.logits_for(seq, prompt.len()))
        }
        fn decode(&mut self, seqs: &[(SeqId, u32)]) -> Result<DecodeOutcome> {
            self.calls += 1;
            let victim = (self.calls == self.preempt_on_call && seqs.len() > 1)
                .then(|| seqs.iter().map(|&(id, _)| id).max().unwrap());
            let mut out = DecodeOutcome { logits: Vec::new(), preempted: Vec::new() };
            for &(id, _) in seqs {
                if victim == Some(id) {
                    self.lens.remove(&id);
                    out.logits.push(None);
                    out.preempted.push(id);
                    self.unreported_preemptions += 1;
                } else {
                    let len = self.lens.get_mut(&id).expect("unknown seq");
                    *len += 1;
                    let len = *len;
                    out.logits.push(Some(self.logits_for(id, len)));
                }
            }
            Ok(out)
        }
        fn release(&mut self, seq: SeqId) {
            self.lens.remove(&seq);
        }
        fn take_step_timing(&mut self) -> Option<StepTiming> {
            (self.unreported_preemptions > 0).then(|| {
                let t = StepTiming {
                    preemptions: self.unreported_preemptions,
                    ..Default::default()
                };
                self.unreported_preemptions = 0;
                t
            })
        }
    }

    fn preempting_sched(preempt_on_call: usize) -> Scheduler<PreemptingMock> {
        Scheduler::new(
            PreemptingMock::new(16, preempt_on_call),
            SchedulerConfig {
                max_active: 4,
                eos_token: None,
                kv: KvCacheConfig { block_size: 4, num_blocks: 64, ..Default::default() },
                ..SchedulerConfig::default()
            },
        )
    }

    #[test]
    fn preempted_sequence_resumes_with_uninterrupted_token_stream() {
        let run = |preempt_on_call: usize| -> (Vec<(u64, Vec<u32>)>, Snapshot) {
            let metrics = Arc::new(Metrics::new());
            let mut s = preempting_sched(preempt_on_call);
            s.set_metrics(metrics.clone());
            s.admit(Request::new(1, vec![1, 2, 3], 5)).unwrap();
            s.admit(Request::new(2, vec![1, 2], 4)).unwrap();
            let mut done = s.drain().unwrap();
            done.sort_by_key(|r| r.id);
            (done.into_iter().map(|r| (r.id, r.tokens)).collect(), metrics.snapshot())
        };
        let (clean, clean_snap) = run(usize::MAX);
        let (preempted, snap) = run(2);
        assert_eq!(clean_snap.preemptions, 0);
        assert_eq!(snap.preemptions, 1, "the chosen decode call must preempt");
        assert_eq!(snap.resumes, 1, "the parked sequence must resume");
        // Replay = 2 prompt tokens + 1 already-generated token.
        assert_eq!(snap.recomputed_tokens, 3);
        assert_eq!(preempted, clean, "preempt→resume must not change the token stream");
    }

    #[test]
    fn parked_sequences_outrank_the_waiting_queue() {
        let mut s = preempting_sched(2);
        s.admit(Request::new(1, vec![1, 2, 3], 6)).unwrap();
        s.admit(Request::new(2, vec![1, 2], 5)).unwrap();
        s.step().unwrap(); // both advance
        s.step().unwrap(); // youngest (seq 2) preempted
        assert_eq!(s.preempted_count(), 1);
        assert_eq!(s.active_count(), 1);
        let probe = Request::new(9, vec![1], 1);
        assert!(
            !s.has_capacity_for(&probe),
            "admission must wait while a preempted sequence is parked"
        );
        s.step().unwrap(); // resume runs ahead of anything else
        assert_eq!(s.preempted_count(), 0);
        assert_eq!(s.active_count(), 2);
        assert_eq!(s.backend.prefills_per_seq[&2], 2, "resume must replay via the prefill path");
        assert!(s.has_capacity_for(&probe));
        let done = s.drain().unwrap();
        assert_eq!(done.len(), 2);
        // The shadow allocator (pool-less mock) is fully reconciled.
        assert_eq!(s.kv.as_ref().unwrap().used_blocks(), 0);
        s.kv.as_ref().unwrap().check_invariants().unwrap();
    }

    #[test]
    fn pooled_backends_retire_the_shadow_allocator() {
        use crate::engine::PagedNativeBackend;
        use crate::model::{ModelConfig, Transformer};
        let model = Transformer::new_mha(ModelConfig::tiny(), 19);
        let kvc = KvCacheConfig { block_size: 4, num_blocks: 32, ..Default::default() };
        let s = Scheduler::new(
            PagedNativeBackend::new(model, kvc),
            SchedulerConfig { max_active: 4, eos_token: None, kv: kvc, ..Default::default() },
        );
        assert!(s.kv.is_none(), "pool-owning backends must not get a shadow allocator");
        let mock = sched(4);
        assert!(mock.kv.is_some(), "pool-less backends keep the shadow");
    }

    #[test]
    fn temperature_sampling_deterministic_per_request() {
        let logits = vec![0.0, 1.0, 2.0, 3.0];
        let mut r1 = Request::new(42, vec![1], 4);
        r1.temperature = Some(1.0);
        let a = super::sample(&logits, &r1);
        let b = super::sample(&logits, &r1);
        assert_eq!(a, b);
    }

    #[test]
    fn native_backend_serves_real_model() {
        use crate::model::{ModelConfig, Transformer};
        let model = Transformer::new_mha(ModelConfig::tiny(), 11);
        let mut s = Scheduler::new(NativeBackend::new(model), SchedulerConfig::default());
        s.admit(Request::new(1, vec![5, 6, 7], 4)).unwrap();
        let done = s.drain().unwrap();
        assert_eq!(done[0].tokens.len(), 4);
        assert!(done[0].tokens.iter().all(|&t| t < 256));
    }

    #[test]
    fn native_mha_and_bda_generate_identical_tokens() {
        // The serving-level losslessness check: greedy decodes agree.
        use crate::bd::Strategy;
        use crate::model::{ModelConfig, Transformer};
        use crate::tensor::DType;
        let mha = Transformer::new_mha(ModelConfig::tiny(), 13);
        let bda = mha.to_bda(Strategy::ResidualMin, DType::F32).unwrap();
        let mut s1 = Scheduler::new(NativeBackend::new(mha), SchedulerConfig::default());
        let mut s2 = Scheduler::new(NativeBackend::new(bda), SchedulerConfig::default());
        s1.admit(Request::new(1, vec![9, 4, 17], 8)).unwrap();
        s2.admit(Request::new(1, vec![9, 4, 17], 8)).unwrap();
        let t1 = s1.drain().unwrap().remove(0).tokens;
        let t2 = s2.drain().unwrap().remove(0).tokens;
        assert_eq!(t1, t2, "BDA must reproduce MHA's greedy decode exactly");
    }
}
