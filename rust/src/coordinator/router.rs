//! Prefix-aware, load-aware request router over N pool-shard engine
//! workers.
//!
//! The router owns **placement only**: it decides which shard a request
//! lands on, and the chosen shard's scheduler does everything else with
//! the existing single-engine loop. A request is never split or migrated
//! across shards, which is what makes engine invariant 8 hold: per-request
//! token streams are placement-invariant because every invariant-1..6
//! guarantee is per-scheduler, and the router only chooses *which*
//! scheduler runs the whole sequence.
//!
//! # Placement policy
//!
//! For each candidate shard the router builds a [`ShardView`] and picks
//! the minimum of the composite key
//!
//! ```text
//! (Reverse(cached_blocks), parked > 0, Reverse(free_blocks), queue_depth, shard)
//! ```
//!
//! in order of meaning:
//!
//! 1. **Prefix affinity** — the shard whose radix tree already holds the
//!    longest cached prefix of this prompt wins outright (those blocks are
//!    adopted instead of recomputed, the dominant cost).
//! 2. **Pressure balancing** — among equally-cached shards (typically all
//!    zero for a fresh prompt), shards currently parking preempted
//!    sequences are in pool churn; new admissions steer away so they can
//!    drain.
//! 3. **Capacity** — more free + evictable pool blocks wins.
//! 4. **Queue depth** — fewer waiting + in-flight requests wins.
//! 5. **Shard index** — final deterministic tie-break.
//!
//! The key is total and every input is a point-in-time snapshot, so
//! routing is deterministic for a fixed sequence of views — which the
//! synchronous [`super::server::replay_trace_sharded`] relies on.

use super::metrics::Metrics;
use super::queue::RequestQueue;
use super::scheduler::PrefixProbeHandle;
use std::cmp::Reverse;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Point-in-time routing inputs for one shard.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardView {
    /// Shard index (also the last tie-break).
    pub shard: usize,
    /// Longest cached prefix of the candidate prompt in this shard's
    /// radix tree, in blocks (0 when the shard has no prefix cache).
    pub cached_blocks: usize,
    /// Free + evictable pool blocks (`usize::MAX` when unknown, e.g. a
    /// backend without a block pool).
    pub free_blocks: usize,
    /// Waiting + in-flight requests on this shard.
    pub queue_depth: usize,
    /// Preempted sequences parked for resume (pool-churn signal).
    pub parked: usize,
}

/// Pick the shard a request should run on. Pure and deterministic; see
/// the module docs for the key ordering. Returns 0 for an empty slice.
pub fn pick_shard(views: &[ShardView]) -> usize {
    views
        .iter()
        .min_by_key(|v| {
            (Reverse(v.cached_blocks), v.parked > 0, Reverse(v.free_blocks), v.queue_depth, v.shard)
        })
        .map(|v| v.shard)
        .unwrap_or(0)
}

/// Worker count from `BDA_WORKERS` (default 1; zero and garbage clamp
/// to 1). Read at each call, not latched — callers decide when to
/// resolve it (servers at startup, benches per child process).
pub fn workers_from_env() -> usize {
    parse_workers(std::env::var("BDA_WORKERS").ok().as_deref())
}

fn parse_workers(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok()).map(|n| n.max(1)).unwrap_or(1)
}

/// Shared load counters one engine worker publishes each loop iteration
/// and the router reads on every placement. All accesses are relaxed:
/// the values are advisory load signals, never correctness inputs (a
/// stale read changes *where* a request runs, which invariant 8 makes
/// unobservable in the token stream).
#[derive(Debug)]
pub struct ShardStatus {
    /// Free + evictable pool blocks; `usize::MAX` until the worker first
    /// publishes (so an unstarted shard reads as roomy, not full).
    free_blocks: AtomicUsize,
    /// Sequences decoding.
    active: AtomicUsize,
    /// Sequences mid-chunked-prefill.
    prefilling: AtomicUsize,
    /// Preempted sequences parked for resume.
    parked: AtomicUsize,
}

impl Default for ShardStatus {
    fn default() -> Self {
        ShardStatus {
            free_blocks: AtomicUsize::new(usize::MAX),
            active: AtomicUsize::new(0),
            prefilling: AtomicUsize::new(0),
            parked: AtomicUsize::new(0),
        }
    }
}

impl ShardStatus {
    pub fn new() -> Arc<ShardStatus> {
        Arc::new(ShardStatus::default())
    }

    /// Publish this shard's current load (worker side, once per loop).
    pub fn publish(
        &self,
        free_blocks: Option<usize>,
        active: usize,
        prefilling: usize,
        parked: usize,
    ) {
        self.free_blocks.store(free_blocks.unwrap_or(usize::MAX), Ordering::Relaxed);
        self.active.store(active, Ordering::Relaxed);
        self.prefilling.store(prefilling, Ordering::Relaxed);
        self.parked.store(parked, Ordering::Relaxed);
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks.load(Ordering::Relaxed)
    }

    pub fn parked(&self) -> usize {
        self.parked.load(Ordering::Relaxed)
    }

    /// Sequences the shard is carrying (decoding + prefilling + parked).
    pub fn in_flight(&self) -> usize {
        self.active.load(Ordering::Relaxed)
            + self.prefilling.load(Ordering::Relaxed)
            + self.parked.load(Ordering::Relaxed)
    }
}

/// The router's per-shard handle inside a threaded [`super::Server`]:
/// the shard's admission queue, its metrics, its published load, and a
/// thread-safe longest-cached-prefix probe captured from the backend
/// before it moved onto the worker thread.
pub struct ShardHandle {
    pub shard: u32,
    pub queue: Arc<RequestQueue>,
    pub metrics: Arc<Metrics>,
    pub status: Arc<ShardStatus>,
    pub probe: Option<PrefixProbeHandle>,
}

impl ShardHandle {
    /// Snapshot this shard's routing inputs for one candidate prompt.
    pub fn view(&self, prompt: &[u32]) -> ShardView {
        ShardView {
            shard: self.shard as usize,
            cached_blocks: self.probe.as_ref().map(|p| p(prompt)).unwrap_or(0),
            free_blocks: self.status.free_blocks(),
            queue_depth: self.queue.len() + self.status.in_flight(),
            parked: self.status.parked(),
        }
    }
}

/// Route one prompt across the shard handles (threaded-server path).
pub fn route(shards: &[ShardHandle], prompt: &[u32]) -> usize {
    let views: Vec<ShardView> = shards.iter().map(|s| s.view(prompt)).collect();
    pick_shard(&views)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(shard: usize) -> ShardView {
        ShardView { shard, cached_blocks: 0, free_blocks: 100, queue_depth: 0, parked: 0 }
    }

    #[test]
    fn longest_cached_prefix_wins() {
        let views = [
            ShardView { cached_blocks: 1, ..view(0) },
            ShardView { cached_blocks: 3, ..view(1) },
            ShardView { cached_blocks: 2, ..view(2) },
        ];
        assert_eq!(pick_shard(&views), 1);
    }

    #[test]
    fn cache_affinity_beats_pressure_and_load() {
        // The shard holding the prefix is churning (parked > 0), nearly
        // full, and deep-queued — it still wins: adopting cached blocks
        // beats recomputing the prefix elsewhere.
        let views = [
            ShardView { cached_blocks: 2, free_blocks: 3, queue_depth: 9, parked: 2, shard: 0 },
            ShardView { cached_blocks: 0, ..view(1) },
        ];
        assert_eq!(pick_shard(&views), 0);
    }

    #[test]
    fn pressure_steers_fresh_prompts_off_churning_shards() {
        // No cache anywhere: the preempting shard loses even though it
        // has more free blocks right now.
        let views = [
            ShardView { parked: 1, free_blocks: 80, ..view(0) },
            ShardView { free_blocks: 40, ..view(1) },
        ];
        assert_eq!(pick_shard(&views), 1);
    }

    #[test]
    fn free_blocks_then_queue_depth_then_index() {
        let more_free =
            [ShardView { free_blocks: 10, ..view(0) }, ShardView { free_blocks: 20, ..view(1) }];
        assert_eq!(pick_shard(&more_free), 1);
        let shallower =
            [ShardView { queue_depth: 4, ..view(0) }, ShardView { queue_depth: 1, ..view(1) }];
        assert_eq!(pick_shard(&shallower), 1);
        let all_equal = [view(0), view(1), view(2)];
        assert_eq!(pick_shard(&all_equal), 0, "full tie goes to the lowest shard");
        assert_eq!(pick_shard(&[]), 0, "empty view set defaults to shard 0");
    }

    #[test]
    fn parse_workers_clamps_and_defaults() {
        assert_eq!(parse_workers(None), 1);
        assert_eq!(parse_workers(Some("4")), 4);
        assert_eq!(parse_workers(Some(" 2 ")), 2);
        assert_eq!(parse_workers(Some("0")), 1, "zero clamps to one worker");
        assert_eq!(parse_workers(Some("lots")), 1, "garbage falls back");
    }

    #[test]
    fn status_defaults_roomy_and_publishes() {
        let status = ShardStatus::new();
        assert_eq!(status.free_blocks(), usize::MAX, "unpublished shard reads roomy");
        assert_eq!(status.in_flight(), 0);
        status.publish(Some(12), 3, 1, 2);
        assert_eq!(status.free_blocks(), 12);
        assert_eq!(status.in_flight(), 6);
        assert_eq!(status.parked(), 2);
        status.publish(None, 0, 0, 0);
        assert_eq!(status.free_blocks(), usize::MAX, "poolless backend stays unknown");
    }
}
