//! Block-based KV-cache manager (paged-attention-style bookkeeping).
//!
//! Sequences lease fixed-size blocks of KV slots; blocks are ref-counted so
//! shared prefixes can be forked cheaply. BDA preserves every query–key
//! inner product (§3.4), so this manager is attention-variant-agnostic:
//! the same cache logic serves MHA and BDA backends — the paper's
//! "compatible with KV-cache compression" claim at the systems level.

use std::collections::HashMap;

/// Storage dtype of the paged K/V pool. An alias of the tensor-level
/// [`DType`](crate::tensor::DType): `F32` stores rows as raw `f32`;
/// `F16`/`BF16` store real 16-bit words (half the resident bytes) that are
/// widened back to f32 at the kernel boundary. Unlike the pure
/// perf/observability knobs, a 16-bit setting *changes numerics* — its
/// contract is engine invariant 7: generations are bitwise identical to an
/// f32 pool whose writes pass through
/// [`DType::quantize_slice`](crate::tensor::DType::quantize_slice).
pub type KvDtype = crate::tensor::DType;

/// `BDA_KV_DTYPE` ∈ {f32, f16, bf16}: storage dtype for new K/V pools.
/// Read at config-construction time (each `KvCacheConfig::default()`), not
/// latched; unset or unparsable falls back to `F32`.
pub fn kv_dtype_from_env() -> KvDtype {
    std::env::var("BDA_KV_DTYPE")
        .ok()
        .and_then(|s| KvDtype::parse(s.trim()))
        .unwrap_or(KvDtype::F32)
}

#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Tokens per block.
    pub block_size: usize,
    /// Total number of blocks in the pool.
    pub num_blocks: usize,
    /// Storage dtype of pool block data (see [`KvDtype`]).
    pub dtype: KvDtype,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig { block_size: 16, num_blocks: 1024, dtype: kv_dtype_from_env() }
    }
}

pub type SeqId = u64;
pub type BlockId = usize;

/// `BDA_TEST_POOL_BLOCKS`: the overload knob for the test suite. Tests
/// that drive the preempt/resume path read it to size their "small" pool
/// (`None` when unset or unparsable — tests fall back to their hand-built
/// tiny pools), so CI can force pool pressure in every determinism-matrix
/// cell instead of relying on one hand-constructed fixture. A pure test
/// harness knob: generated tokens never change (engine invariant 5 —
/// preempt→resume is bitwise-identical to an uninterrupted run).
pub fn test_pool_blocks() -> Option<usize> {
    std::env::var("BDA_TEST_POOL_BLOCKS").ok()?.trim().parse().ok()
}

/// Block pool + per-sequence block tables.
#[derive(Debug)]
pub struct BlockAllocator {
    pub config: KvCacheConfig,
    free: Vec<BlockId>,
    ref_counts: Vec<u32>,
    /// External holds per block (references owned by something other than a
    /// sequence table — the engine's radix-tree prefix cache). A hold
    /// contributes to `ref_counts`, so held blocks never return to the free
    /// list while held; `check_invariants` verifies
    /// `ref_counts[b] == table refs + holds[b]` for every block.
    holds: Vec<u32>,
    tables: HashMap<SeqId, SeqTable>,
}

#[derive(Clone, Debug, Default)]
struct SeqTable {
    blocks: Vec<BlockId>,
    len_tokens: usize,
}

/// Where an appended token's K/V entry must be written (see
/// [`BlockAllocator::append_token_cow`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AppendSlot {
    /// Block leased to (or already owned by) the sequence for this token.
    pub block: BlockId,
    /// Row within the block (`position % block_size`).
    pub slot: usize,
    /// When copy-on-write triggered: the shared block whose contents must
    /// be copied into `block` before writing the new row.
    pub copied_from: Option<BlockId>,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum KvError {
    #[error("out of KV blocks (need {need}, free {free})")]
    OutOfBlocks { need: usize, free: usize },
    #[error("unknown sequence {0}")]
    UnknownSeq(SeqId),
    #[error("sequence {0} already registered")]
    DuplicateSeq(SeqId),
}

impl BlockAllocator {
    pub fn new(config: KvCacheConfig) -> BlockAllocator {
        BlockAllocator {
            free: (0..config.num_blocks).rev().collect(),
            ref_counts: vec![0; config.num_blocks],
            holds: vec![0; config.num_blocks],
            tables: HashMap::new(),
            config,
        }
    }

    /// Current reference count of one block (table refs + external holds).
    pub fn ref_count(&self, block: BlockId) -> u32 {
        self.ref_counts[block]
    }

    /// Number of external holds on one block (the hold component of
    /// [`BlockAllocator::ref_count`]). The prefix cache uses it to tell a
    /// block it alone holds (`ref == 1`, `holds == 1`) from a block whose
    /// single reference is a sequence table (`holds == 0`) — only the
    /// former is reclaimable by dropping the tree's hold.
    pub fn hold_count(&self, block: BlockId) -> u32 {
        self.holds[block]
    }

    /// Number of blocks with at least one external hold (prefix-cache
    /// residency, not per-hold multiplicity).
    pub fn held_blocks(&self) -> usize {
        self.holds.iter().filter(|&&h| h > 0).count()
    }

    /// Take an external hold on `blocks`: each must currently be referenced
    /// (by a table or a prior hold) — holds extend the life of live blocks,
    /// they cannot resurrect freed ones. Used by the prefix cache when a
    /// releasing sequence's prefix blocks move into the radix tree.
    pub fn hold_blocks(&mut self, blocks: &[BlockId]) {
        for &b in blocks {
            debug_assert!(self.ref_counts[b] > 0, "hold on unreferenced block {b}");
            self.ref_counts[b] += 1;
            self.holds[b] += 1;
        }
    }

    /// Drop an external hold on `blocks`; a block returns to the free list
    /// when its last reference (table or hold) goes.
    pub fn release_held(&mut self, blocks: &[BlockId]) {
        for &b in blocks {
            debug_assert!(self.holds[b] > 0, "release_held without hold on block {b}");
            debug_assert!(self.ref_counts[b] > 0);
            self.holds[b] -= 1;
            self.ref_counts[b] -= 1;
            if self.ref_counts[b] == 0 {
                self.free.push(b);
            }
        }
    }

    /// Register a sequence whose first `prefix.len()` blocks are adopted
    /// from already-live storage (a prefix-cache hit): each prefix block is
    /// ref-bumped (zero-copy sharing, copy-on-write on divergence like any
    /// fork), and only the uncovered tail allocates fresh blocks. The
    /// prefix must cover whole blocks and strictly fewer tokens than
    /// `total_tokens` (a hit always leaves at least one tail token to
    /// prefill). On `OutOfBlocks` nothing is modified.
    pub fn register_with_prefix(
        &mut self,
        seq: SeqId,
        prefix: &[BlockId],
        total_tokens: usize,
    ) -> Result<(), KvError> {
        if self.tables.contains_key(&seq) {
            return Err(KvError::DuplicateSeq(seq));
        }
        let need_total = self.blocks_for(total_tokens.max(1));
        debug_assert!(
            prefix.len() * self.config.block_size < total_tokens,
            "prefix ({} blocks) must cover fewer than total_tokens ({total_tokens})",
            prefix.len()
        );
        let tail = need_total.saturating_sub(prefix.len());
        if tail > self.free.len() {
            return Err(KvError::OutOfBlocks { need: tail, free: self.free.len() });
        }
        let mut table =
            SeqTable { blocks: Vec::with_capacity(need_total), len_tokens: total_tokens };
        for &b in prefix {
            debug_assert!(self.ref_counts[b] > 0, "prefix block {b} is not live");
            self.ref_counts[b] += 1;
            table.blocks.push(b);
        }
        for _ in 0..tail {
            let b = self.free.pop().unwrap();
            debug_assert_eq!(self.ref_counts[b], 0);
            self.ref_counts[b] = 1;
            table.blocks.push(b);
        }
        self.tables.insert(seq, table);
        Ok(())
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.config.num_blocks - self.free.len()
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.config.block_size)
    }

    /// Register a sequence and allocate blocks for its prompt.
    pub fn register(&mut self, seq: SeqId, prompt_tokens: usize) -> Result<(), KvError> {
        if self.tables.contains_key(&seq) {
            return Err(KvError::DuplicateSeq(seq));
        }
        let need = self.blocks_for(prompt_tokens.max(1));
        if need > self.free.len() {
            return Err(KvError::OutOfBlocks { need, free: self.free.len() });
        }
        let mut table = SeqTable { blocks: Vec::with_capacity(need), len_tokens: prompt_tokens };
        for _ in 0..need {
            let b = self.free.pop().unwrap();
            debug_assert_eq!(self.ref_counts[b], 0);
            self.ref_counts[b] = 1;
            table.blocks.push(b);
        }
        self.tables.insert(seq, table);
        Ok(())
    }

    /// Extend a sequence by one token, allocating a block on boundary.
    /// Copy-on-write-safe (see [`BlockAllocator::append_token_cow`]); use
    /// the `_cow` variant when the caller owns real K/V storage and needs
    /// the write position.
    pub fn append_token(&mut self, seq: SeqId) -> Result<(), KvError> {
        self.append_token_cow(seq).map(|_| ())
    }

    /// Extend a sequence by one token and return where its K/V entry must
    /// be written. Three cases:
    ///
    /// * the token lands in a fresh block (boundary): allocate one;
    /// * it lands in a block this sequence owns exclusively: write in place;
    /// * it lands in a block shared with a fork ancestor/sibling
    ///   (`ref_count > 1`): copy-on-write — lease a private replacement
    ///   block and report `copied_from` so the storage owner can copy the
    ///   block's K/V data before writing. Writing into a shared block
    ///   would corrupt every other sequence referencing it.
    pub fn append_token_cow(&mut self, seq: SeqId) -> Result<AppendSlot, KvError> {
        let table = self.tables.get_mut(&seq).ok_or(KvError::UnknownSeq(seq))?;
        let pos = table.len_tokens;
        let idx = pos / self.config.block_size;
        let slot = pos % self.config.block_size;
        if idx == table.blocks.len() {
            // Boundary: the token opens a fresh, private block.
            let Some(b) = self.free.pop() else {
                return Err(KvError::OutOfBlocks { need: 1, free: 0 });
            };
            debug_assert_eq!(self.ref_counts[b], 0);
            self.ref_counts[b] = 1;
            table.blocks.push(b);
            table.len_tokens = pos + 1;
            return Ok(AppendSlot { block: b, slot, copied_from: None });
        }
        let b = table.blocks[idx];
        if self.ref_counts[b] > 1 {
            // Shared tail block: copy-on-write.
            let Some(nb) = self.free.pop() else {
                return Err(KvError::OutOfBlocks { need: 1, free: 0 });
            };
            debug_assert_eq!(self.ref_counts[nb], 0);
            self.ref_counts[b] -= 1;
            self.ref_counts[nb] = 1;
            table.blocks[idx] = nb;
            table.len_tokens = pos + 1;
            return Ok(AppendSlot { block: nb, slot, copied_from: Some(b) });
        }
        table.len_tokens = pos + 1;
        Ok(AppendSlot { block: b, slot, copied_from: None })
    }

    /// Fork `child` from `parent`, sharing all current blocks (copy-on-
    /// write bookkeeping; actual COW copy is the backend's job when it
    /// writes into a shared tail block).
    pub fn fork(&mut self, parent: SeqId, child: SeqId) -> Result<(), KvError> {
        if self.tables.contains_key(&child) {
            return Err(KvError::DuplicateSeq(child));
        }
        let ptable = self.tables.get(&parent).ok_or(KvError::UnknownSeq(parent))?.clone();
        for &b in &ptable.blocks {
            self.ref_counts[b] += 1;
        }
        self.tables.insert(child, ptable);
        Ok(())
    }

    /// Release a sequence; blocks return to the pool when refs hit zero.
    pub fn release(&mut self, seq: SeqId) -> Result<(), KvError> {
        self.release_counting(seq).map(|_| ())
    }

    /// Release a sequence's whole table in one pass and report how many
    /// blocks actually returned to the free list. Shared references are
    /// respected: blocks still held by forks' tables or by external holds
    /// (the prefix cache) survive with their counts decremented. The
    /// engine's preemption path uses the count to tell whether evicting a
    /// victim reclaimed real capacity or only dropped shared references.
    pub fn release_counting(&mut self, seq: SeqId) -> Result<usize, KvError> {
        let table = self.tables.remove(&seq).ok_or(KvError::UnknownSeq(seq))?;
        let mut freed = 0;
        for b in table.blocks {
            debug_assert!(self.ref_counts[b] > 0);
            self.ref_counts[b] -= 1;
            if self.ref_counts[b] == 0 {
                self.free.push(b);
                freed += 1;
            }
        }
        Ok(freed)
    }

    pub fn seq_len(&self, seq: SeqId) -> Option<usize> {
        self.tables.get(&seq).map(|t| t.len_tokens)
    }

    pub fn seq_blocks(&self, seq: SeqId) -> Option<&[BlockId]> {
        self.tables.get(&seq).map(|t| t.blocks.as_slice())
    }

    pub fn active_seqs(&self) -> usize {
        self.tables.len()
    }

    /// Can a prompt of this many tokens be admitted right now?
    pub fn can_admit(&self, prompt_tokens: usize) -> bool {
        self.blocks_for(prompt_tokens.max(1)) <= self.free.len()
    }

    /// Invariant check (used by property tests): every block is either
    /// free with ref 0, or referenced by exactly `ref` table entries plus
    /// external holds.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut refs = vec![0u32; self.config.num_blocks];
        for t in self.tables.values() {
            for &b in &t.blocks {
                refs[b] += 1;
            }
        }
        for (b, &h) in self.holds.iter().enumerate() {
            refs[b] += h;
        }
        for b in 0..self.config.num_blocks {
            if refs[b] != self.ref_counts[b] {
                return Err(format!("block {b}: counted {} != stored {}", refs[b], self.ref_counts[b]));
            }
        }
        let free_set: std::collections::HashSet<_> = self.free.iter().collect();
        if free_set.len() != self.free.len() {
            return Err("duplicate blocks in free list".into());
        }
        for &b in &self.free {
            if self.ref_counts[b] != 0 {
                return Err(format!("free block {b} has refs"));
            }
        }
        if self.free.len() + refs.iter().filter(|&&r| r > 0).count() != self.config.num_blocks {
            return Err("block leak: free + referenced != total".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alloc(blocks: usize) -> BlockAllocator {
        // Dtype inherited from env: allocator bookkeeping is storage-agnostic.
        BlockAllocator::new(KvCacheConfig { block_size: 4, num_blocks: blocks, ..Default::default() })
    }

    #[test]
    fn register_allocates_ceil_blocks() {
        let mut a = alloc(16);
        a.register(1, 9).unwrap(); // ceil(9/4) = 3
        assert_eq!(a.used_blocks(), 3);
        assert_eq!(a.seq_len(1), Some(9));
        a.check_invariants().unwrap();
    }

    #[test]
    fn append_allocates_on_boundary() {
        let mut a = alloc(16);
        a.register(1, 4).unwrap(); // exactly 1 block
        assert_eq!(a.used_blocks(), 1);
        a.append_token(1).unwrap(); // 5 tokens -> 2 blocks
        assert_eq!(a.used_blocks(), 2);
        for _ in 0..3 {
            a.append_token(1).unwrap(); // up to 8 -> still 2 blocks
        }
        assert_eq!(a.used_blocks(), 2);
        a.check_invariants().unwrap();
    }

    #[test]
    fn release_returns_blocks() {
        let mut a = alloc(8);
        a.register(1, 10).unwrap();
        a.register(2, 10).unwrap();
        assert_eq!(a.free_blocks(), 2);
        a.release(1).unwrap();
        assert_eq!(a.free_blocks(), 5);
        a.check_invariants().unwrap();
    }

    #[test]
    fn out_of_blocks_rejected_cleanly() {
        let mut a = alloc(2);
        a.register(1, 8).unwrap();
        let err = a.register(2, 4).unwrap_err();
        assert!(matches!(err, KvError::OutOfBlocks { .. }));
        // Failed registration must not leak state.
        assert_eq!(a.active_seqs(), 1);
        a.check_invariants().unwrap();
    }

    #[test]
    fn fork_shares_blocks() {
        let mut a = alloc(8);
        a.register(1, 8).unwrap();
        let used = a.used_blocks();
        a.fork(1, 2).unwrap();
        assert_eq!(a.used_blocks(), used, "fork allocates nothing");
        // Release parent: blocks stay (child holds refs).
        a.release(1).unwrap();
        assert_eq!(a.used_blocks(), used);
        a.release(2).unwrap();
        assert_eq!(a.used_blocks(), 0);
        a.check_invariants().unwrap();
    }

    #[test]
    fn cow_append_on_shared_tail_block() {
        let mut a = alloc(8);
        a.register(1, 5).unwrap(); // blocks [b0, b1]; b1 holds 1 of 4 slots
        a.fork(1, 2).unwrap();
        let parent_blocks = a.seq_blocks(1).unwrap().to_vec();

        // Child appends into the shared tail block -> copy-on-write.
        let s = a.append_token_cow(2).unwrap();
        assert_eq!(s.slot, 1);
        assert_eq!(s.copied_from, Some(parent_blocks[1]));
        assert_ne!(s.block, parent_blocks[1], "COW must lease a private block");
        assert_eq!(a.seq_blocks(1).unwrap(), &parent_blocks[..], "parent table untouched");
        assert_eq!(a.seq_blocks(2).unwrap()[1], s.block);
        a.check_invariants().unwrap();

        // Parent now owns b1 exclusively again: its append writes in place.
        let p = a.append_token_cow(1).unwrap();
        assert_eq!(p.copied_from, None);
        assert_eq!(p.block, parent_blocks[1]);
        a.check_invariants().unwrap();
    }

    #[test]
    fn release_forked_child_keeps_parent_blocks() {
        // Regression (fork + release accounting): freeing a forked child —
        // including its private COW blocks — must not free blocks still
        // referenced by the parent.
        let mut a = alloc(8);
        a.register(1, 6).unwrap(); // 2 blocks
        a.fork(1, 2).unwrap();
        a.append_token_cow(2).unwrap(); // COW: child now holds 1 shared + 1 private
        assert_eq!(a.used_blocks(), 3);
        a.release(2).unwrap();
        assert_eq!(a.used_blocks(), 2, "parent's blocks must survive child release");
        // Parent is fully usable afterwards.
        for _ in 0..4 {
            a.append_token(1).unwrap();
        }
        a.check_invariants().unwrap();
        a.release(1).unwrap();
        assert_eq!(a.used_blocks(), 0);
        a.check_invariants().unwrap();
    }

    #[test]
    fn cow_append_reports_out_of_blocks() {
        let mut a = alloc(2);
        a.register(1, 5).unwrap(); // uses both blocks
        a.fork(1, 2).unwrap();
        let err = a.append_token_cow(2).unwrap_err();
        assert!(matches!(err, KvError::OutOfBlocks { .. }));
        // Failure must not corrupt state.
        a.check_invariants().unwrap();
        assert_eq!(a.seq_len(2), Some(5));
    }

    #[test]
    fn duplicate_and_unknown_errors() {
        let mut a = alloc(8);
        a.register(1, 4).unwrap();
        assert_eq!(a.register(1, 4).unwrap_err(), KvError::DuplicateSeq(1));
        assert_eq!(a.release(9).unwrap_err(), KvError::UnknownSeq(9));
        assert_eq!(a.append_token(9).unwrap_err(), KvError::UnknownSeq(9));
    }

    #[test]
    fn held_blocks_survive_table_release() {
        // Regression (prefix-cache holds): a hold keeps blocks leased when
        // the owning sequence releases; dropping the hold frees them.
        let mut a = alloc(8);
        a.register(1, 8).unwrap(); // 2 blocks
        let blocks = a.seq_blocks(1).unwrap().to_vec();
        a.hold_blocks(&blocks);
        assert_eq!(a.held_blocks(), 2);
        a.check_invariants().unwrap();
        a.release(1).unwrap();
        assert_eq!(a.used_blocks(), 2, "held blocks must not return to the pool");
        assert_eq!(a.ref_count(blocks[0]), 1);
        a.check_invariants().unwrap();
        a.release_held(&blocks);
        assert_eq!(a.used_blocks(), 0);
        assert_eq!(a.held_blocks(), 0);
        a.check_invariants().unwrap();
    }

    #[test]
    fn register_with_prefix_shares_and_allocates_tail() {
        let mut a = alloc(8);
        a.register(1, 8).unwrap(); // blocks [b0, b1]
        let prefix = a.seq_blocks(1).unwrap().to_vec();
        a.hold_blocks(&prefix);
        a.release(1).unwrap(); // tree-style residency: only holds remain

        // New sequence adopts both prefix blocks + 1 fresh tail block.
        a.register_with_prefix(2, &prefix, 10).unwrap();
        assert_eq!(a.seq_len(2), Some(10));
        assert_eq!(a.seq_blocks(2).unwrap().len(), 3);
        assert_eq!(&a.seq_blocks(2).unwrap()[..2], &prefix[..]);
        assert_eq!(a.ref_count(prefix[0]), 2, "hold + table");
        a.check_invariants().unwrap();

        // Appending into the shared (held) tail region copy-on-writes.
        // Position 10 is inside block 2 (private), so in-place is fine; but
        // writing into block 1 via a second adopter must COW.
        a.register_with_prefix(3, &prefix[..1], 5).unwrap();
        let s = a.append_token_cow(3).unwrap(); // pos 5, block 1 is private to seq 3
        assert_eq!(s.copied_from, None);
        a.check_invariants().unwrap();

        // Capacity errors leave state untouched.
        let mut b = alloc(2);
        b.register(9, 8).unwrap();
        let pfx = b.seq_blocks(9).unwrap().to_vec();
        b.hold_blocks(&pfx);
        let err = b.register_with_prefix(10, &pfx, 12).unwrap_err();
        assert!(matches!(err, KvError::OutOfBlocks { .. }));
        assert_eq!(b.active_seqs(), 1);
        b.check_invariants().unwrap();
    }

    #[test]
    fn release_counting_respects_shares_and_holds() {
        // The preemption path's bulk release: freeing a victim reports how
        // many blocks actually came back — blocks still referenced by a
        // fork's table or a prefix-cache hold stay leased.
        let mut a = alloc(8);
        a.register(1, 8).unwrap(); // 2 blocks
        a.fork(1, 2).unwrap();
        a.append_token_cow(2).unwrap(); // boundary: child gets 1 private block
        let child_blocks = a.seq_blocks(2).unwrap().to_vec();
        a.hold_blocks(&child_blocks[..1]); // tree-style hold on the shared block
        // Child release: block 0 shared with parent + held, block 1 (COW)
        // private -> exactly 1 block returns.
        assert_eq!(a.release_counting(2).unwrap(), 1);
        a.check_invariants().unwrap();
        // Parent release: block 0 still held -> 1 of 2 returns.
        assert_eq!(a.release_counting(1).unwrap(), 1);
        assert_eq!(a.used_blocks(), 1, "held block outlives both tables");
        a.release_held(&child_blocks[..1]);
        assert_eq!(a.used_blocks(), 0);
        a.check_invariants().unwrap();
        assert_eq!(a.release_counting(9).unwrap_err(), KvError::UnknownSeq(9));
    }

    #[test]
    fn hold_count_distinguishes_holds_from_table_refs() {
        let mut a = alloc(8);
        a.register(1, 4).unwrap();
        let b = a.seq_blocks(1).unwrap()[0];
        assert_eq!((a.ref_count(b), a.hold_count(b)), (1, 0));
        a.hold_blocks(&[b]);
        assert_eq!((a.ref_count(b), a.hold_count(b)), (2, 1));
        a.release(1).unwrap();
        assert_eq!((a.ref_count(b), a.hold_count(b)), (1, 1));
        a.release_held(&[b]);
        assert_eq!((a.ref_count(b), a.hold_count(b)), (0, 0));
        a.check_invariants().unwrap();
    }

    #[test]
    fn admission_check() {
        let mut a = alloc(3);
        assert!(a.can_admit(12));
        a.register(1, 8).unwrap();
        assert!(a.can_admit(4));
        assert!(!a.can_admit(8));
    }
}
