//! Dynamic batcher: greedily forms batches up to `max_batch`, waiting at
//! most `max_wait` for stragglers once the first request arrives — the
//! standard latency/throughput knob of serving systems.

use super::queue::RequestQueue;
use super::request::Request;
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

pub struct Batcher {
    pub config: BatcherConfig,
}

impl Batcher {
    pub fn new(config: BatcherConfig) -> Batcher {
        assert!(config.max_batch > 0);
        Batcher { config }
    }

    /// Pull the next batch from the queue. Blocks up to `idle_timeout` for
    /// the first request; once one arrives, tops up for at most
    /// `config.max_wait`. Returns an empty vec on idle timeout (caller
    /// decides whether to spin again or shut down).
    pub fn next_batch(&self, queue: &RequestQueue, idle_timeout: Duration) -> Vec<Request> {
        let Some(first) = queue.pop_timeout(idle_timeout) else {
            return Vec::new();
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + self.config.max_wait;
        while batch.len() < self.config.max_batch {
            // Fast path: drain what's already there.
            let room = self.config.max_batch - batch.len();
            let mut got = queue.drain_up_to(room);
            if !got.is_empty() {
                batch.append(&mut got);
                continue;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            if let Some(r) = queue.pop_timeout(deadline - now) {
                batch.push(r);
            } else {
                break;
            }
        }
        batch
    }

    /// Pick the smallest artifact batch size that fits `n` requests from a
    /// sorted list of available sizes (PJRT artifacts are fixed-shape; the
    /// batch is padded up to the chosen size).
    pub fn pick_bucket(available: &[usize], n: usize) -> Option<usize> {
        available.iter().copied().find(|&b| b >= n).or(available.last().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1], 4)
    }

    #[test]
    fn batches_up_to_max() {
        let q = RequestQueue::new(32);
        for i in 0..10 {
            q.push(req(i));
        }
        let b = Batcher::new(BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) });
        let batch = b.next_batch(&q, Duration::from_millis(10));
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].id, 0);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn returns_partial_after_wait() {
        let q = RequestQueue::new(32);
        q.push(req(0));
        q.push(req(1));
        let b = Batcher::new(BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) });
        let t0 = Instant::now();
        let batch = b.next_batch(&q, Duration::from_millis(10));
        assert_eq!(batch.len(), 2);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn empty_on_idle_timeout() {
        let q = RequestQueue::new(4);
        let b = Batcher::new(BatcherConfig::default());
        let batch = b.next_batch(&q, Duration::from_millis(5));
        assert!(batch.is_empty());
    }

    #[test]
    fn straggler_joins_within_wait() {
        let q = Arc::new(RequestQueue::new(8));
        q.push(req(0));
        let q2 = q.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            q2.push(req(1));
        });
        let b = Batcher::new(BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(100) });
        let batch = b.next_batch(&q, Duration::from_millis(50));
        assert_eq!(batch.len(), 2, "straggler should join the batch");
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(Batcher::pick_bucket(&[1, 8], 1), Some(1));
        assert_eq!(Batcher::pick_bucket(&[1, 8], 2), Some(8));
        assert_eq!(Batcher::pick_bucket(&[1, 8], 8), Some(8));
        // Oversized n falls back to the largest bucket (caller splits).
        assert_eq!(Batcher::pick_bucket(&[1, 8], 9), Some(8));
        assert_eq!(Batcher::pick_bucket(&[], 1), None);
    }
}
