//! Bounded admission queue with backpressure (Mutex + Condvar; no tokio in
//! the offline crate set).

use super::request::Request;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// FIFO queue with a capacity bound. `push` blocks when full (backpressure
/// to the client); `pop` blocks until an item or close.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner {
    items: VecDeque<Request>,
    closed: bool,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> RequestQueue {
        assert!(capacity > 0);
        RequestQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push. Returns false if the queue is closed.
    pub fn push(&self, req: Request) -> bool {
        let mut g = self.inner.lock().unwrap();
        while g.items.len() >= self.capacity && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return false;
        }
        g.items.push_back(req);
        self.not_empty.notify_one();
        true
    }

    /// Non-blocking push. Err(req) when full or closed.
    pub fn try_push(&self, req: Request) -> Result<(), Request> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.items.len() >= self.capacity {
            return Err(req);
        }
        g.items.push_back(req);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop with timeout. None on timeout or when closed & empty.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<Request> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.items.pop_front() {
                self.not_full.notify_one();
                return Some(r);
            }
            if g.closed {
                return None;
            }
            let (g2, res) = self.not_empty.wait_timeout(g, timeout).unwrap();
            g = g2;
            if res.timed_out() {
                return g.items.pop_front().inspect(|_| {
                    self.not_full.notify_one();
                });
            }
        }
    }

    /// Drain up to `max` items without blocking.
    pub fn drain_up_to(&self, max: usize) -> Vec<Request> {
        let mut g = self.inner.lock().unwrap();
        let n = g.items.len().min(max);
        let out: Vec<Request> = g.items.drain(..n).collect();
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: pushes fail, pops drain the remainder then None.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1], 4)
    }

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(8);
        for i in 0..5 {
            q.push(req(i));
        }
        for i in 0..5 {
            assert_eq!(q.pop_timeout(Duration::from_millis(10)).unwrap().id, i);
        }
        assert!(q.pop_timeout(Duration::from_millis(5)).is_none());
    }

    #[test]
    fn try_push_full() {
        let q = RequestQueue::new(2);
        assert!(q.try_push(req(0)).is_ok());
        assert!(q.try_push(req(1)).is_ok());
        assert!(q.try_push(req(2)).is_err());
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_none() {
        let q = RequestQueue::new(4);
        q.push(req(0));
        q.close();
        assert!(!q.push(req(1)));
        assert_eq!(q.pop_timeout(Duration::from_millis(5)).unwrap().id, 0);
        assert!(q.pop_timeout(Duration::from_millis(5)).is_none());
    }

    #[test]
    fn drain_up_to_respects_max() {
        let q = RequestQueue::new(8);
        for i in 0..6 {
            q.push(req(i));
        }
        let batch = q.drain_up_to(4);
        assert_eq!(batch.len(), 4);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn blocking_push_unblocks_on_pop() {
        let q = Arc::new(RequestQueue::new(1));
        q.push(req(0));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push(req(1)));
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop_timeout(Duration::from_millis(100)).unwrap().id, 0);
        assert!(h.join().unwrap());
        assert_eq!(q.pop_timeout(Duration::from_millis(100)).unwrap().id, 1);
    }

    #[test]
    fn cross_thread_producer_consumer() {
        let q = Arc::new(RequestQueue::new(4));
        let qp = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                qp.push(req(i));
            }
            qp.close();
        });
        let mut seen = Vec::new();
        while let Some(r) = q.pop_timeout(Duration::from_millis(200)) {
            seen.push(r.id);
        }
        producer.join().unwrap();
        assert_eq!(seen, (0..100).collect::<Vec<_>>());
    }
}
