//! One engine worker: a dedicated thread running the *existing*
//! queue → batcher → scheduler loop over its own pool shard.
//!
//! The sharded server ([`super::Server::start_sharded`]) spawns N of
//! these; the single-engine [`super::Server::start`] is the N = 1 case of
//! the same code. Each worker owns its backend (and thus its KV pool and
//! prefix-cache shard), its admission queue, and its [`Metrics`]; the only
//! cross-shard artifacts are the shared response channel, the
//! [`ShardStatus`] load counters the router reads, and the prefix probe
//! captured from the backend before it moved onto the worker thread.
//!
//! Workers stamp their shard id into the tracing thread-locals
//! ([`crate::obs::set_shard`]) at spawn, so every lifecycle span and
//! resource sample the loop records carries its shard.

use super::batcher::Batcher;
use super::metrics::Metrics;
use super::queue::RequestQueue;
use super::request::Response;
use super::router::{ShardHandle, ShardStatus};
use super::scheduler::{Backend, Scheduler};
use super::server::ServerConfig;
use anyhow::Result;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

/// Spawn one engine worker over `backend`. Returns the router-facing
/// handle and the join handle for shutdown.
pub(crate) fn spawn<B: Backend + Send + 'static>(
    shard: u32,
    backend: B,
    config: ServerConfig,
    tx: Sender<Response>,
) -> (ShardHandle, std::thread::JoinHandle<Result<()>>) {
    let queue = Arc::new(RequestQueue::new(256));
    let metrics = Arc::new(Metrics::new());
    let status = ShardStatus::new();
    // Capture the prefix probe on the caller's thread; the backend itself
    // moves onto the worker thread and is never touched from outside again.
    let probe = backend.router_probe();
    let q = queue.clone();
    let m = metrics.clone();
    let s = status.clone();
    let join = std::thread::spawn(move || run_engine(shard, backend, config, q, m, s, tx));
    (ShardHandle { shard, queue, metrics, status, probe }, join)
}

/// The engine loop, unchanged from the pre-sharding server except for
/// shard tagging and status publication: admit a batch, retry admissions
/// against capacity, step for decode progress, publish load, repeat until
/// the queue closes; then drain.
fn run_engine<B: Backend>(
    shard: u32,
    backend: B,
    config: ServerConfig,
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    status: Arc<ShardStatus>,
    tx: Sender<Response>,
) -> Result<()> {
    // Tag this thread's spans/samples with the shard id unconditionally —
    // tracing may be enabled later via `set_enabled`, and the tag must
    // already be in place when the first span records.
    crate::obs::set_shard(shard);
    if crate::obs::enabled() {
        // Shard 0 keeps the historical label so single-worker traces are
        // unchanged; higher shards get an indexed label.
        if shard == 0 {
            crate::obs::set_thread_label("bda-engine");
        } else {
            crate::obs::set_thread_label(&format!("bda-engine-{shard}"));
        }
    }
    let mut sched = Scheduler::new(backend, config.scheduler);
    sched.set_metrics(metrics.clone());
    let publish = |sched: &Scheduler<B>, status: &ShardStatus| {
        status.publish(
            sched.backend.free_blocks(),
            sched.active_count(),
            sched.prefilling_count(),
            sched.preempted_count(),
        );
    };
    publish(&sched, &status);
    let batcher = Batcher::new(config.batcher);
    loop {
        // Admit a batch (don't block long if sequences are active).
        let idle = if sched.active_count() + sched.prefilling_count() > 0 {
            Duration::from_micros(100)
        } else if queue.is_closed() && queue.is_empty() {
            break;
        } else {
            Duration::from_millis(10)
        };
        let batch = batcher.next_batch(&queue, idle);
        if crate::obs::enabled() {
            // Feed the resource sampler this shard's post-batch queue
            // depth; the scheduler stamps it into its step-boundary
            // sample (the depth cell is thread-local, so concurrent
            // workers don't clobber each other's gauge).
            crate::obs::sampler::note_queue_depth(queue.len());
        }
        if !batch.is_empty() {
            metrics.batch_formed(batch.len());
        }
        for req in batch {
            metrics.admitted(req.prompt.len());
            let mut pending = Some(req);
            // Retry admission as capacity frees up.
            while let Some(r) = pending.take() {
                match sched.admit(r) {
                    Ok(()) => {}
                    Err(r) => {
                        if sched.active_count() == 0
                            && sched.preempted_count() == 0
                            && sched.prefilling_count() == 0
                        {
                            // Can't ever admit: drop with rejection.
                            metrics.rejected();
                            break;
                        }
                        // Free capacity by stepping, then retry.
                        for resp in sched.step()? {
                            metrics.tokens_generated(resp.tokens.len());
                            metrics.completed(resp.latency, resp.ttft);
                            metrics.slo_scored(&resp);
                            let _ = tx.send(resp);
                        }
                        pending = Some(r);
                    }
                }
            }
        }
        // Decode progress.
        for resp in sched.step()? {
            metrics.tokens_generated(resp.tokens.len());
            metrics.completed(resp.latency, resp.ttft);
            metrics.slo_scored(&resp);
            let _ = tx.send(resp);
        }
        publish(&sched, &status);
    }
    // Drain remaining work after close.
    for resp in sched.drain()? {
        metrics.tokens_generated(resp.tokens.len());
        metrics.completed(resp.latency, resp.ttft);
        metrics.slo_scored(&resp);
        let _ = tx.send(resp);
    }
    publish(&sched, &status);
    // Final trace drain: spans recorded after the last step's flush
    // (completions above) must not be stranded in this worker's rings.
    crate::obs::flush();
    Ok(())
}
