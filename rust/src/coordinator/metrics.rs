//! Serving metrics: request counters, token throughput, latency/TTFT/TBT
//! and per-step-phase histograms.
//!
//! Aggregate state lives behind one mutex, but the per-step hot-path
//! counters ([`Metrics::decode_step`], [`Metrics::tokens_generated`]) are
//! relaxed atomics on the `Metrics` struct itself: a decode step records
//! its counters without serializing on the report mutex, so snapshot
//! readers never stall the decode loop. The occupancy accumulator is an
//! `f64` carried in an `AtomicU64` via a `to_bits` CAS loop (exact
//! mean-of-ratios semantics preserved, no lock).

use crate::coordinator::request::Response;
use crate::util::stats::{HistSnapshot, Histogram, Quantiles};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Wall-time split of one batched decode step, measured by backends that
/// instrument their hot path (attention vs everything-GEMM-shaped); the
/// scheduler adds its own sampling time before forwarding the triple to
/// [`Metrics::decode_timing`]. Lets perf PRs attribute wins: "2× decode"
/// means little without knowing which slice shrank.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepTiming {
    /// Seconds spent in (paged) attention.
    pub attn: f64,
    /// Seconds spent in GEMMs: QKV projections, output projection, FFN,
    /// and the logits matmul.
    pub gemm: f64,
    /// Prefix-cache lookups since the previous reported step that matched
    /// at least one cached block (admissions land between decode steps, so
    /// the engine reports them with the next step's timing).
    pub prefix_hits: u64,
    /// Prefix-cache lookups since the previous reported step that matched
    /// nothing.
    pub prefix_misses: u64,
    /// Prompt K/V blocks adopted from the radix tree instead of being
    /// re-prefilled, since the previous reported step.
    pub prefix_blocks_saved: u64,
    /// Active sequences preempted during this step: the pool ran dry and
    /// a victim's blocks were released (recompute-on-resume) instead of
    /// erroring out of the batched step.
    pub preemptions: u64,
    /// Preempted sequences re-admitted ahead of the waiting queue since
    /// the previous reported step (the scheduler merges these in).
    pub resumes: u64,
    /// Tokens replayed through the prefill path by those resumes — the
    /// recompute cost of preemption (resume output stays bit-identical;
    /// engine invariant 5).
    pub recomputed_tokens: u64,
    /// Prefill chunks fused into this batched step alongside the decode
    /// rows (Sarathi-style continuous batching; engine invariant 6 keeps
    /// the chunked output bitwise equal to a monolithic prefill).
    pub prefill_chunks: u64,
    /// Prompt tokens those chunks pushed through the step.
    pub chunked_tokens: u64,
}

#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<Inner>,
    start: Instant,
    // Hot-path counters: updated once per decode step / completion batch
    // with relaxed atomics so per-step recording never contends with
    // snapshot readers on the mutex.
    tokens_out: AtomicU64,
    decode_steps: AtomicU64,
    decode_tokens: AtomicU64,
    /// Sum of per-step batch/capacity ratios, as `f64::to_bits`.
    occupancy_sum_bits: AtomicU64,
}

#[derive(Debug)]
struct Inner {
    /// Actual allocated K/V pool bytes as reported by the backend (0 for
    /// backends without a paged pool). Honest about storage width: a
    /// 16-bit pool reports half the bytes of an f32 pool with the same
    /// block count.
    kv_pool_bytes: u64,
    /// Storage dtype of the K/V pool (`None` for pool-less backends).
    kv_dtype: Option<&'static str>,
    requests_admitted: u64,
    requests_completed: u64,
    requests_rejected: u64,
    tokens_in: u64,
    batches: u64,
    batch_size_sum: u64,
    decode_attn_secs: f64,
    decode_gemm_secs: f64,
    decode_sample_secs: f64,
    prefix_hits: u64,
    prefix_misses: u64,
    prefix_blocks_saved: u64,
    preemptions: u64,
    resumes: u64,
    recomputed_tokens: u64,
    prefill_chunks: u64,
    chunked_tokens: u64,
    /// Per-priority-class SLO tally: `priority -> (completed, met)`.
    /// Scored at completion from the response's deadline class
    /// ([`Metrics::slo_scored`]); observation only — classes never steer
    /// the token stream.
    slo: BTreeMap<u8, (u64, u64)>,
    /// Generated tokens from responses that met their class SLO — the
    /// numerator of goodput (tokens/s *under* SLO).
    goodput_tokens: u64,
    /// Completed requests whose TTFT exceeded their class deadline.
    ttft_violations: u64,
    /// Completed requests with at least one token gap over budget.
    tbt_violations: u64,
    latency: Histogram,
    ttft: Histogram,
    /// Time-between-tokens: per-step gaps between consecutive tokens of
    /// one sequence (a gap spanning a preemption includes parked time —
    /// that is what the waiting client experiences).
    tbt: Histogram,
    // Per-step phase latency (seconds per batched decode step). Only
    // steps with real backend timing are recorded, so mock backends and
    // admission-only iterations don't pollute the distributions.
    step_attn: Histogram,
    step_gemm: Histogram,
    step_sample: Histogram,
}

/// `num / den`, or 0.0 when the denominator is not positive. Every ratio
/// field in [`Snapshot`] is guarded here, in one place.
fn ratio(num: f64, den: f64) -> f64 {
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// Add `v` to an `f64` accumulator stored as bits in an `AtomicU64`.
fn atomic_f64_add(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

/// Per-priority-class SLO attainment, one row of [`Snapshot::slo_by_class`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ClassSlo {
    pub priority: u8,
    /// Requests of this class completed and scored.
    pub completed: u64,
    /// Of those, how many met both their TTFT deadline and TBT budget.
    pub met: u64,
}

impl ClassSlo {
    /// Fraction of this class's completions that met the SLO.
    pub fn attainment(&self) -> f64 {
        ratio(self.met as f64, self.completed as f64)
    }
}

/// A point-in-time snapshot for reporting.
///
/// Carries both derived ratios (for display) and the raw sums they were
/// computed from (`batches`, `batch_size_sum`, `occupancy_sum`,
/// `decode_tokens`, the `*_hist` buckets) so per-shard snapshots
/// [`merge`](Snapshot::merge) into one aggregate whose ratios are
/// recomputed from summed numerators/denominators — never averaged
/// across shards.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub elapsed: f64,
    /// Actual allocated K/V pool bytes (0 when the backend has no paged
    /// pool); halves when the pool stores 16-bit words.
    pub kv_pool_bytes: u64,
    /// K/V pool storage dtype name, `None` for pool-less backends.
    pub kv_dtype: Option<&'static str>,
    pub requests_admitted: u64,
    pub requests_completed: u64,
    pub requests_rejected: u64,
    pub tokens_in: u64,
    pub tokens_out: u64,
    pub tokens_per_sec: f64,
    pub mean_batch_size: f64,
    /// Batches formed (raw denominator behind `mean_batch_size`).
    pub batches: u64,
    /// Sum of formed batch sizes (raw numerator behind `mean_batch_size`).
    pub batch_size_sum: u64,
    /// Number of batched decode iterations the engine ran.
    pub decode_steps: u64,
    /// Tokens produced by decode steps (raw numerator behind
    /// `tokens_per_step`).
    pub decode_tokens: u64,
    /// Sum of per-step batch/capacity ratios (raw numerator behind
    /// `decode_occupancy`).
    pub occupancy_sum: f64,
    /// Mean sequences decoded per iteration (tokens produced per step).
    pub tokens_per_step: f64,
    /// Mean decode-batch occupancy: batch size / configured max_active.
    pub decode_occupancy: f64,
    /// Cumulative decode-step wall time spent in attention.
    pub decode_attn_secs: f64,
    /// Cumulative decode-step wall time spent in GEMMs.
    pub decode_gemm_secs: f64,
    /// Cumulative decode-step wall time spent sampling.
    pub decode_sample_secs: f64,
    /// Prefix-cache lookups that matched at least one cached block.
    pub prefix_hits: u64,
    /// Prefix-cache lookups that matched nothing.
    pub prefix_misses: u64,
    /// Prompt K/V blocks deduplicated against the radix tree (prefill
    /// work and pool memory saved).
    pub prefix_blocks_saved: u64,
    /// Active sequences preempted under pool exhaustion (blocks released,
    /// recompute-on-resume) instead of erroring out of the batched step.
    pub preemptions: u64,
    /// Preempted sequences re-admitted ahead of the waiting queue.
    pub resumes: u64,
    /// Tokens replayed through the prefill path by resumes — the
    /// recompute cost of graceful overload handling.
    pub recomputed_tokens: u64,
    /// Prefill chunks fused into batched decode steps (chunked prefill /
    /// continuous batching).
    pub prefill_chunks: u64,
    /// Prompt tokens processed through those fused chunks.
    pub chunked_tokens: u64,
    pub latency_p50: f64,
    pub latency_p95: f64,
    pub latency_p99: f64,
    pub latency_mean: f64,
    pub ttft_p50: f64,
    pub ttft_p95: f64,
    pub ttft_p99: f64,
    /// Time-between-tokens distribution (seconds).
    pub tbt: Quantiles,
    /// Per-decode-step attention latency distribution (seconds).
    pub step_attn: Quantiles,
    /// Per-decode-step GEMM latency distribution (seconds).
    pub step_gemm: Quantiles,
    /// Per-decode-step sampling latency distribution (seconds).
    pub step_sample: Quantiles,
    /// Per-class SLO attainment, ascending priority (empty until the
    /// first completion is scored).
    pub slo_by_class: Vec<ClassSlo>,
    /// Generated tokens from SLO-met responses.
    pub goodput_tokens: u64,
    /// Goodput: tokens/s counting only responses that met their SLO.
    pub goodput_tok_s: f64,
    /// Scored completions whose TTFT blew the class deadline.
    pub ttft_violations: u64,
    /// Scored completions with a token gap over the class budget.
    pub tbt_violations: u64,
    /// Request-latency histogram buckets (raw data behind `latency_p*`;
    /// merged bucketwise across shards so aggregate quantiles come from
    /// the combined distribution, not averaged percentiles).
    pub latency_hist: HistSnapshot,
    /// Cumulative-bucket histograms for native Prometheus export
    /// (`_bucket`/`_sum`/`_count` series; empty when nothing recorded).
    pub ttft_hist: HistSnapshot,
    pub tbt_hist: HistSnapshot,
    pub step_attn_hist: HistSnapshot,
    pub step_gemm_hist: HistSnapshot,
    pub step_sample_hist: HistSnapshot,
    /// Span events dropped by the obs recorder's full rings + collection
    /// overflow, as of this snapshot (global; 0 when tracing never ran).
    pub trace_dropped_events: u64,
}

impl Snapshot {
    /// Overall SLO attainment across every scored class (0.0 before any
    /// completion is scored).
    pub fn slo_attainment(&self) -> f64 {
        let (met, completed) = self
            .slo_by_class
            .iter()
            .fold((0u64, 0u64), |(m, c), s| (m + s.met, c + s.completed));
        ratio(met as f64, completed as f64)
    }

    /// Fold another shard's snapshot into this one — THE aggregation path
    /// for sharded serving (router + N workers). Counters and histogram
    /// buckets sum; `elapsed` takes the max (shards run concurrently over
    /// the same wall clock, so aggregate throughput divides summed tokens
    /// by shared wall time, not by summed elapsed); every derived ratio
    /// (`tokens_per_sec`, `mean_batch_size`, `tokens_per_step`,
    /// `decode_occupancy`, `goodput_tok_s`, the latency/TTFT/TBT/step
    /// quantiles, and anything computed on demand like
    /// [`Snapshot::slo_attainment`] / [`Snapshot::prefix_hit_rate`]) is
    /// recomputed from the summed raw numerators and denominators.
    /// Averaging per-shard ratios would weight an idle shard equally with
    /// a saturated one; summing first keeps the aggregate exact.
    ///
    /// Merging one live snapshot into a default reproduces it: derived
    /// values recompute to the shard's own (histogram quantile semantics
    /// are shared with the live [`Histogram`], see [`HistSnapshot`]).
    pub fn merge(&mut self, other: &Snapshot) {
        self.elapsed = self.elapsed.max(other.elapsed);
        self.kv_pool_bytes += other.kv_pool_bytes;
        self.kv_dtype = self.kv_dtype.or(other.kv_dtype);
        self.requests_admitted += other.requests_admitted;
        self.requests_completed += other.requests_completed;
        self.requests_rejected += other.requests_rejected;
        self.tokens_in += other.tokens_in;
        self.tokens_out += other.tokens_out;
        self.batches += other.batches;
        self.batch_size_sum += other.batch_size_sum;
        self.decode_steps += other.decode_steps;
        self.decode_tokens += other.decode_tokens;
        self.occupancy_sum += other.occupancy_sum;
        self.decode_attn_secs += other.decode_attn_secs;
        self.decode_gemm_secs += other.decode_gemm_secs;
        self.decode_sample_secs += other.decode_sample_secs;
        self.prefix_hits += other.prefix_hits;
        self.prefix_misses += other.prefix_misses;
        self.prefix_blocks_saved += other.prefix_blocks_saved;
        self.preemptions += other.preemptions;
        self.resumes += other.resumes;
        self.recomputed_tokens += other.recomputed_tokens;
        self.prefill_chunks += other.prefill_chunks;
        self.chunked_tokens += other.chunked_tokens;
        self.goodput_tokens += other.goodput_tokens;
        self.ttft_violations += other.ttft_violations;
        self.tbt_violations += other.tbt_violations;
        // The obs drop counter is process-global: every shard's snapshot
        // reads the same atomic, so summing would multiply-count it.
        self.trace_dropped_events = self.trace_dropped_events.max(other.trace_dropped_events);
        for o in &other.slo_by_class {
            match self.slo_by_class.iter_mut().find(|c| c.priority == o.priority) {
                Some(c) => {
                    c.completed += o.completed;
                    c.met += o.met;
                }
                None => self.slo_by_class.push(*o),
            }
        }
        self.slo_by_class.sort_by_key(|c| c.priority);
        self.latency_hist.merge(&other.latency_hist);
        self.ttft_hist.merge(&other.ttft_hist);
        self.tbt_hist.merge(&other.tbt_hist);
        self.step_attn_hist.merge(&other.step_attn_hist);
        self.step_gemm_hist.merge(&other.step_gemm_hist);
        self.step_sample_hist.merge(&other.step_sample_hist);
        // Derived values: recompute from summed raws, never averaged.
        self.tokens_per_sec = ratio(self.tokens_out as f64, self.elapsed);
        self.mean_batch_size = ratio(self.batch_size_sum as f64, self.batches as f64);
        self.tokens_per_step = ratio(self.decode_tokens as f64, self.decode_steps as f64);
        self.decode_occupancy = ratio(self.occupancy_sum, self.decode_steps as f64);
        self.goodput_tok_s = ratio(self.goodput_tokens as f64, self.elapsed);
        self.latency_p50 = self.latency_hist.quantile(0.5);
        self.latency_p95 = self.latency_hist.quantile(0.95);
        self.latency_p99 = self.latency_hist.quantile(0.99);
        self.latency_mean = self.latency_hist.mean();
        self.ttft_p50 = self.ttft_hist.quantile(0.5);
        self.ttft_p95 = self.ttft_hist.quantile(0.95);
        self.ttft_p99 = self.ttft_hist.quantile(0.99);
        self.tbt = self.tbt_hist.quantiles();
        self.step_attn = self.step_attn_hist.quantiles();
        self.step_gemm = self.step_gemm_hist.quantiles();
        self.step_sample = self.step_sample_hist.quantiles();
    }

    /// Merge an iterator of per-shard snapshots into one aggregate.
    pub fn aggregate<'a>(shards: impl IntoIterator<Item = &'a Snapshot>) -> Snapshot {
        let mut out = Snapshot::default();
        for s in shards {
            out.merge(s);
        }
        out
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            inner: Mutex::new(Inner {
                kv_pool_bytes: 0,
                kv_dtype: None,
                requests_admitted: 0,
                requests_completed: 0,
                requests_rejected: 0,
                tokens_in: 0,
                batches: 0,
                batch_size_sum: 0,
                decode_attn_secs: 0.0,
                decode_gemm_secs: 0.0,
                decode_sample_secs: 0.0,
                prefix_hits: 0,
                prefix_misses: 0,
                prefix_blocks_saved: 0,
                preemptions: 0,
                resumes: 0,
                recomputed_tokens: 0,
                prefill_chunks: 0,
                chunked_tokens: 0,
                slo: BTreeMap::new(),
                goodput_tokens: 0,
                ttft_violations: 0,
                tbt_violations: 0,
                latency: Histogram::latency(),
                ttft: Histogram::latency(),
                tbt: Histogram::latency(),
                step_attn: Histogram::latency(),
                step_gemm: Histogram::latency(),
                step_sample: Histogram::latency(),
            }),
            start: Instant::now(),
            tokens_out: AtomicU64::new(0),
            decode_steps: AtomicU64::new(0),
            decode_tokens: AtomicU64::new(0),
            occupancy_sum_bits: AtomicU64::new(0.0f64.to_bits()),
        }
    }

    /// Record the backend's K/V pool footprint: actual allocated bytes
    /// and the storage dtype. Called once when the scheduler attaches
    /// metrics to a pooled backend; pool-less backends never call it.
    pub fn set_kv_pool(&self, bytes: usize, dtype: &'static str) {
        let mut g = self.inner.lock().unwrap();
        g.kv_pool_bytes = bytes as u64;
        g.kv_dtype = Some(dtype);
    }

    pub fn admitted(&self, prompt_tokens: usize) {
        let mut g = self.inner.lock().unwrap();
        g.requests_admitted += 1;
        g.tokens_in += prompt_tokens as u64;
    }

    pub fn rejected(&self) {
        self.inner.lock().unwrap().requests_rejected += 1;
    }

    pub fn batch_formed(&self, size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batch_size_sum += size as u64;
    }

    /// One batched decode iteration: `batch` sequences stepped together
    /// out of `capacity` (= scheduler `max_active`) decode slots.
    /// Lock-free: three relaxed counter updates.
    pub fn decode_step(&self, batch: usize, capacity: usize) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.decode_tokens.fetch_add(batch as u64, Ordering::Relaxed);
        if capacity > 0 {
            atomic_f64_add(&self.occupancy_sum_bits, batch as f64 / capacity as f64);
        }
    }

    /// Per-step decode timing split: the backend's attention/GEMM
    /// measurement plus the scheduler's sampling time. Steps with real
    /// backend timing also feed the per-step phase histograms.
    pub fn decode_timing(&self, step: StepTiming, sample_secs: f64) {
        let mut g = self.inner.lock().unwrap();
        g.decode_attn_secs += step.attn;
        g.decode_gemm_secs += step.gemm;
        g.decode_sample_secs += sample_secs;
        if step.attn > 0.0 || step.gemm > 0.0 {
            g.step_attn.record(step.attn);
            g.step_gemm.record(step.gemm);
            g.step_sample.record(sample_secs);
        }
        g.prefix_hits += step.prefix_hits;
        g.prefix_misses += step.prefix_misses;
        g.prefix_blocks_saved += step.prefix_blocks_saved;
        g.preemptions += step.preemptions;
        g.resumes += step.resumes;
        g.recomputed_tokens += step.recomputed_tokens;
        g.prefill_chunks += step.prefill_chunks;
        g.chunked_tokens += step.chunked_tokens;
    }

    /// Lock-free: one relaxed counter update.
    pub fn tokens_generated(&self, n: usize) {
        self.tokens_out.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Record a batch of time-between-tokens gaps (seconds), one lock for
    /// the whole step's worth of samples.
    pub fn record_tbts(&self, gaps: &[f64]) {
        if gaps.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        for &v in gaps {
            g.tbt.record(v);
        }
    }

    pub fn completed(&self, latency: f64, ttft: f64) {
        let mut g = self.inner.lock().unwrap();
        g.requests_completed += 1;
        g.latency.record(latency);
        g.ttft.record(ttft);
    }

    /// Score one completed response against its deadline class: per-class
    /// attainment tallies, goodput tokens (SLO-met responses only), and
    /// the TTFT/TBT violation counters. Called at the same completion
    /// sites as [`Metrics::completed`]; pure observation — it reads the
    /// response, never steers scheduling.
    pub fn slo_scored(&self, resp: &Response) {
        let mut g = self.inner.lock().unwrap();
        let entry = g.slo.entry(resp.class.priority).or_insert((0, 0));
        entry.0 += 1;
        if resp.slo_met() {
            entry.1 += 1;
            g.goodput_tokens += resp.tokens.len() as u64;
        }
        if resp.ttft > resp.class.ttft_deadline {
            g.ttft_violations += 1;
        }
        if resp.max_tbt > resp.class.tbt_budget {
            g.tbt_violations += 1;
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let elapsed = self.start.elapsed().as_secs_f64();
        let tokens_out = self.tokens_out.load(Ordering::Relaxed);
        let decode_steps = self.decode_steps.load(Ordering::Relaxed);
        let decode_tokens = self.decode_tokens.load(Ordering::Relaxed);
        let occupancy_sum = f64::from_bits(self.occupancy_sum_bits.load(Ordering::Relaxed));
        Snapshot {
            elapsed,
            kv_pool_bytes: g.kv_pool_bytes,
            kv_dtype: g.kv_dtype,
            requests_admitted: g.requests_admitted,
            requests_completed: g.requests_completed,
            requests_rejected: g.requests_rejected,
            tokens_in: g.tokens_in,
            tokens_out,
            tokens_per_sec: ratio(tokens_out as f64, elapsed),
            mean_batch_size: ratio(g.batch_size_sum as f64, g.batches as f64),
            batches: g.batches,
            batch_size_sum: g.batch_size_sum,
            decode_steps,
            decode_tokens,
            occupancy_sum,
            tokens_per_step: ratio(decode_tokens as f64, decode_steps as f64),
            decode_occupancy: ratio(occupancy_sum, decode_steps as f64),
            decode_attn_secs: g.decode_attn_secs,
            decode_gemm_secs: g.decode_gemm_secs,
            decode_sample_secs: g.decode_sample_secs,
            prefix_hits: g.prefix_hits,
            prefix_misses: g.prefix_misses,
            prefix_blocks_saved: g.prefix_blocks_saved,
            preemptions: g.preemptions,
            resumes: g.resumes,
            recomputed_tokens: g.recomputed_tokens,
            prefill_chunks: g.prefill_chunks,
            chunked_tokens: g.chunked_tokens,
            latency_p50: g.latency.quantile(0.5),
            latency_p95: g.latency.quantile(0.95),
            latency_p99: g.latency.quantile(0.99),
            latency_mean: g.latency.mean(),
            ttft_p50: g.ttft.quantile(0.5),
            ttft_p95: g.ttft.quantile(0.95),
            ttft_p99: g.ttft.quantile(0.99),
            tbt: g.tbt.quantiles(),
            step_attn: g.step_attn.quantiles(),
            step_gemm: g.step_gemm.quantiles(),
            step_sample: g.step_sample.quantiles(),
            slo_by_class: g
                .slo
                .iter()
                .map(|(&priority, &(completed, met))| ClassSlo { priority, completed, met })
                .collect(),
            goodput_tokens: g.goodput_tokens,
            goodput_tok_s: ratio(g.goodput_tokens as f64, elapsed),
            ttft_violations: g.ttft_violations,
            tbt_violations: g.tbt_violations,
            latency_hist: g.latency.hist_snapshot(),
            ttft_hist: g.ttft.hist_snapshot(),
            tbt_hist: g.tbt.hist_snapshot(),
            step_attn_hist: g.step_attn.hist_snapshot(),
            step_gemm_hist: g.step_gemm.hist_snapshot(),
            step_sample_hist: g.step_sample.hist_snapshot(),
            trace_dropped_events: crate::obs::dropped_total(),
        }
    }
}

impl Snapshot {
    /// Prefix-cache hit fraction over all lookups (0.0 before any lookup).
    pub fn prefix_hit_rate(&self) -> f64 {
        ratio(self.prefix_hits as f64, (self.prefix_hits + self.prefix_misses) as f64)
    }

    /// Human-readable prefix-cache line, or `None` when no lookups ran
    /// (cache disabled, or a backend without one).
    pub fn prefix_cache_line(&self) -> Option<String> {
        let lookups = self.prefix_hits + self.prefix_misses;
        if lookups == 0 {
            return None;
        }
        Some(format!(
            "{}/{} prompts hit ({:.0}%), {} K/V blocks deduped",
            self.prefix_hits,
            lookups,
            100.0 * self.prefix_hit_rate(),
            self.prefix_blocks_saved,
        ))
    }

    /// Human-readable K/V pool footprint line, or `None` for backends
    /// without a paged pool. Bytes are the actual allocation, so the line
    /// halves when 16-bit storage is selected.
    pub fn kv_pool_line(&self) -> Option<String> {
        let dtype = self.kv_dtype?;
        Some(format!("{dtype}, {:.1} MiB", self.kv_pool_bytes as f64 / (1024.0 * 1024.0)))
    }

    /// Human-readable preemption line, or `None` when the run never hit
    /// pool exhaustion (no preemptions and no resumes).
    pub fn preemption_line(&self) -> Option<String> {
        if self.preemptions == 0 && self.resumes == 0 {
            return None;
        }
        Some(format!(
            "{} preempted, {} resumed, {} tokens recomputed",
            self.preemptions, self.resumes, self.recomputed_tokens,
        ))
    }

    /// Human-readable chunked-prefill line, or `None` when prefill never
    /// ran chunked (budget unbounded with no fused steps, or a backend
    /// without chunking support).
    pub fn chunked_prefill_line(&self) -> Option<String> {
        if self.prefill_chunks == 0 && self.chunked_tokens == 0 {
            return None;
        }
        Some(format!(
            "{} chunks, {} prompt tokens ({:.1} tok/chunk)",
            self.prefill_chunks,
            self.chunked_tokens,
            ratio(self.chunked_tokens as f64, self.prefill_chunks as f64),
        ))
    }

    /// Human-readable decode-step timing split, or `None` when no backend
    /// reported timing (per-sequence / mock backends don't instrument).
    pub fn decode_split(&self) -> Option<String> {
        let total = self.decode_attn_secs + self.decode_gemm_secs + self.decode_sample_secs;
        if total <= 0.0 {
            return None;
        }
        let pct = |x: f64| 100.0 * ratio(x, total);
        Some(format!(
            "attention {:.1}ms ({:.0}%) | gemm {:.1}ms ({:.0}%) | sampling {:.1}ms ({:.0}%)",
            self.decode_attn_secs * 1e3,
            pct(self.decode_attn_secs),
            self.decode_gemm_secs * 1e3,
            pct(self.decode_gemm_secs),
            self.decode_sample_secs * 1e3,
            pct(self.decode_sample_secs),
        ))
    }

    /// Time-between-tokens percentile line, or `None` with fewer than one
    /// recorded gap (single-token generations have no TBT).
    pub fn tbt_line(&self) -> Option<String> {
        if self.tbt.count == 0 {
            return None;
        }
        Some(format!(
            "p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms",
            self.tbt.p50 * 1e3,
            self.tbt.p95 * 1e3,
            self.tbt.p99 * 1e3,
        ))
    }

    /// Per-step phase percentile line, or `None` when no instrumented
    /// backend ran.
    pub fn step_phase_line(&self) -> Option<String> {
        if self.step_attn.count == 0 {
            return None;
        }
        let fmt = |q: &Quantiles| {
            format!("p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms", q.p50 * 1e3, q.p95 * 1e3, q.p99 * 1e3)
        };
        Some(format!(
            "attn {} | gemm {} | sample {}",
            fmt(&self.step_attn),
            fmt(&self.step_gemm),
            fmt(&self.step_sample),
        ))
    }

    /// Per-class SLO attainment + goodput line, or `None` before any
    /// completion is scored against its class.
    pub fn slo_line(&self) -> Option<String> {
        if self.slo_by_class.iter().all(|c| c.completed == 0) {
            return None;
        }
        let per_class = self
            .slo_by_class
            .iter()
            .map(|c| format!("p{}: {}/{}", c.priority, c.met, c.completed))
            .collect::<Vec<_>>()
            .join(", ");
        Some(format!(
            "{:.0}% attained ({per_class}) | goodput {:.1} tok/s | \
             violations {} ttft / {} tbt",
            100.0 * self.slo_attainment(),
            self.goodput_tok_s,
            self.ttft_violations,
            self.tbt_violations,
        ))
    }

    pub fn report(&self) -> String {
        let mut extra = match self.prefix_cache_line() {
            Some(line) => format!(" | prefix cache: {line}"),
            None => String::new(),
        };
        if let Some(line) = self.kv_pool_line() {
            extra.push_str(&format!(" | kv pool: {line}"));
        }
        if let Some(line) = self.preemption_line() {
            extra.push_str(&format!(" | preemption: {line}"));
        }
        if let Some(line) = self.chunked_prefill_line() {
            extra.push_str(&format!(" | chunked prefill: {line}"));
        }
        if let Some(line) = self.tbt_line() {
            extra.push_str(&format!(" | tbt {line}"));
        }
        if let Some(line) = self.step_phase_line() {
            extra.push_str(&format!(" | step {line}"));
        }
        if let Some(line) = self.slo_line() {
            extra.push_str(&format!(" | slo: {line}"));
        }
        if self.trace_dropped_events > 0 {
            extra.push_str(&format!(" | trace drops: {} events", self.trace_dropped_events));
        }
        format!(
            "reqs: {} admitted / {} done / {} rejected | tokens: {} in, {} out \
             ({:.1} tok/s) | batch avg {:.2} | decode: {} steps, {:.2} tok/step, \
             {:.0}% occupancy | latency p50 {:.1}ms p95 {:.1}ms p99 {:.1}ms | \
             ttft p50 {:.1}ms p95 {:.1}ms p99 {:.1}ms{extra}",
            self.requests_admitted,
            self.requests_completed,
            self.requests_rejected,
            self.tokens_in,
            self.tokens_out,
            self.tokens_per_sec,
            self.mean_batch_size,
            self.decode_steps,
            self.tokens_per_step,
            self.decode_occupancy * 100.0,
            self.latency_p50 * 1e3,
            self.latency_p95 * 1e3,
            self.latency_p99 * 1e3,
            self.ttft_p50 * 1e3,
            self.ttft_p95 * 1e3,
            self.ttft_p99 * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.admitted(10);
        m.admitted(5);
        m.rejected();
        m.batch_formed(2);
        m.tokens_generated(7);
        m.completed(0.1, 0.02);
        let s = m.snapshot();
        assert_eq!(s.requests_admitted, 2);
        assert_eq!(s.requests_rejected, 1);
        assert_eq!(s.requests_completed, 1);
        assert_eq!(s.tokens_in, 15);
        assert_eq!(s.tokens_out, 7);
        assert_eq!(s.mean_batch_size, 2.0);
        assert!(s.latency_p50 > 0.0);
        assert!(s.latency_p99 >= s.latency_p95);
        assert!(s.ttft_p99 >= s.ttft_p95);
    }

    #[test]
    fn decode_step_counters() {
        let m = Metrics::new();
        m.decode_step(4, 8);
        m.decode_step(8, 8);
        let s = m.snapshot();
        assert_eq!(s.decode_steps, 2);
        assert_eq!(s.tokens_per_step, 6.0);
        assert!((s.decode_occupancy - 0.75).abs() < 1e-12);
        assert!(s.report().contains("tok/step"));
    }

    #[test]
    fn decode_timing_split_accumulates() {
        let m = Metrics::new();
        assert!(m.snapshot().decode_split().is_none(), "no timing yet");
        m.decode_timing(StepTiming { attn: 0.010, gemm: 0.030, ..Default::default() }, 0.005);
        m.decode_timing(StepTiming { attn: 0.010, gemm: 0.020, ..Default::default() }, 0.005);
        let s = m.snapshot();
        assert!((s.decode_attn_secs - 0.020).abs() < 1e-12);
        assert!((s.decode_gemm_secs - 0.050).abs() < 1e-12);
        assert!((s.decode_sample_secs - 0.010).abs() < 1e-12);
        let split = s.decode_split().expect("split present");
        assert!(split.contains("attention"));
        assert!(split.contains("sampling"));
    }

    #[test]
    fn step_phase_histograms_skip_untimed_steps() {
        let m = Metrics::new();
        // Mock/admission-only step: no backend timing → no histogram sample.
        m.decode_timing(StepTiming::default(), 0.001);
        assert_eq!(m.snapshot().step_attn.count, 0);
        assert!(m.snapshot().step_phase_line().is_none());
        m.decode_timing(StepTiming { attn: 0.002, gemm: 0.004, ..Default::default() }, 0.001);
        let s = m.snapshot();
        assert_eq!(s.step_attn.count, 1);
        assert_eq!(s.step_gemm.count, 1);
        assert_eq!(s.step_sample.count, 1);
        assert!(s.step_attn.p50 >= 0.002);
        let line = s.step_phase_line().expect("line present");
        assert!(line.contains("attn") && line.contains("p99"));
        assert!(s.report().contains("step attn"));
    }

    #[test]
    fn tbt_records_in_batches() {
        let m = Metrics::new();
        assert!(m.snapshot().tbt_line().is_none(), "no gaps yet");
        m.record_tbts(&[]);
        assert_eq!(m.snapshot().tbt.count, 0);
        m.record_tbts(&[0.010, 0.012]);
        m.record_tbts(&[0.011]);
        let s = m.snapshot();
        assert_eq!(s.tbt.count, 3);
        assert!(s.tbt.p50 >= 0.010);
        assert!(s.tbt.p99 >= s.tbt.p50);
        assert!(s.report().contains("tbt p50"));
    }

    #[test]
    fn prefix_counters_accumulate_and_report() {
        let m = Metrics::new();
        assert!(m.snapshot().prefix_cache_line().is_none(), "no lookups yet");
        assert!(!m.snapshot().report().contains("prefix cache"));
        let step1 = StepTiming {
            prefix_hits: 1,
            prefix_misses: 3,
            prefix_blocks_saved: 4,
            ..Default::default()
        };
        let step2 = StepTiming {
            prefix_hits: 2,
            prefix_misses: 0,
            prefix_blocks_saved: 6,
            ..Default::default()
        };
        m.decode_timing(step1, 0.0);
        m.decode_timing(step2, 0.0);
        let s = m.snapshot();
        assert_eq!((s.prefix_hits, s.prefix_misses, s.prefix_blocks_saved), (3, 3, 10));
        assert!((s.prefix_hit_rate() - 0.5).abs() < 1e-12);
        let line = s.prefix_cache_line().expect("line present");
        assert!(line.contains("3/6"));
        assert!(line.contains("10 K/V blocks"));
        assert!(s.report().contains("prefix cache"));
    }

    #[test]
    fn preemption_counters_accumulate_and_report() {
        let m = Metrics::new();
        assert!(m.snapshot().preemption_line().is_none(), "no preemptions yet");
        assert!(!m.snapshot().report().contains("preemption"));
        m.decode_timing(StepTiming { preemptions: 2, ..Default::default() }, 0.0);
        let resumed = StepTiming { resumes: 2, recomputed_tokens: 31, ..Default::default() };
        m.decode_timing(resumed, 0.0);
        let s = m.snapshot();
        assert_eq!((s.preemptions, s.resumes, s.recomputed_tokens), (2, 2, 31));
        let line = s.preemption_line().expect("line present");
        assert!(line.contains("2 preempted"));
        assert!(line.contains("31 tokens recomputed"));
        assert!(s.report().contains("preemption"));
    }

    #[test]
    fn chunked_prefill_counters_accumulate_and_report() {
        let m = Metrics::new();
        assert!(m.snapshot().chunked_prefill_line().is_none(), "no chunks yet");
        assert!(!m.snapshot().report().contains("chunked prefill"));
        let step = |chunks, tokens| StepTiming {
            prefill_chunks: chunks,
            chunked_tokens: tokens,
            ..Default::default()
        };
        m.decode_timing(step(1, 512), 0.0);
        m.decode_timing(step(2, 520), 0.0);
        let s = m.snapshot();
        assert_eq!((s.prefill_chunks, s.chunked_tokens), (3, 1032));
        let line = s.chunked_prefill_line().expect("line present");
        assert!(line.contains("3 chunks"));
        assert!(line.contains("1032 prompt tokens"));
        assert!(s.report().contains("chunked prefill"));
    }

    #[test]
    fn kv_pool_footprint_reports() {
        let m = Metrics::new();
        assert!(m.snapshot().kv_pool_line().is_none(), "no pool recorded yet");
        assert!(!m.snapshot().report().contains("kv pool"));
        m.set_kv_pool(8 * 1024 * 1024, "fp16");
        let s = m.snapshot();
        assert_eq!(s.kv_pool_bytes, 8 * 1024 * 1024);
        assert_eq!(s.kv_dtype, Some("fp16"));
        let line = s.kv_pool_line().expect("line present");
        assert!(line.contains("fp16"));
        assert!(line.contains("8.0 MiB"));
        assert!(s.report().contains("kv pool: fp16"));
    }

    #[test]
    fn report_formats() {
        let m = Metrics::new();
        m.admitted(1);
        let r = m.snapshot().report();
        assert!(r.contains("admitted"));
        assert!(r.contains("tok/s"));
        assert!(r.contains("p99"));
    }

    #[test]
    fn empty_snapshot_ratios_are_zero() {
        let s = Metrics::new().snapshot();
        for v in [
            s.tokens_per_sec,
            s.mean_batch_size,
            s.tokens_per_step,
            s.decode_occupancy,
            s.prefix_hit_rate(),
        ] {
            assert_eq!(v, 0.0);
            assert!(v.is_finite());
        }
    }

    #[test]
    fn slo_scoring_tallies_per_class_and_goodput() {
        use crate::coordinator::request::RequestClass;
        let m = Metrics::new();
        assert!(m.snapshot().slo_line().is_none(), "nothing scored yet");
        assert_eq!(m.snapshot().slo_attainment(), 0.0);
        let resp = |priority, ttft, max_tbt, n_tokens: usize| Response {
            id: 0,
            tokens: vec![1; n_tokens],
            ttft,
            latency: ttft + 0.1,
            prompt_len: 4,
            class: RequestClass { priority, ttft_deadline: 0.5, tbt_budget: 0.1 },
            max_tbt,
        };
        m.slo_scored(&resp(2, 0.1, 0.05, 10)); // met
        m.slo_scored(&resp(2, 0.9, 0.05, 10)); // ttft violation
        m.slo_scored(&resp(0, 0.1, 0.05, 7)); // met
        m.slo_scored(&resp(0, 0.1, 0.3, 7)); // tbt violation
        let s = m.snapshot();
        assert_eq!(s.slo_by_class.len(), 2);
        assert_eq!(s.slo_by_class[0], ClassSlo { priority: 0, completed: 2, met: 1 });
        assert_eq!(s.slo_by_class[1], ClassSlo { priority: 2, completed: 2, met: 1 });
        assert!((s.slo_attainment() - 0.5).abs() < 1e-12);
        assert!((s.slo_by_class[0].attainment() - 0.5).abs() < 1e-12);
        assert_eq!(s.goodput_tokens, 17, "only SLO-met responses count toward goodput");
        assert!(s.goodput_tok_s > 0.0);
        assert_eq!((s.ttft_violations, s.tbt_violations), (1, 1));
        let line = s.slo_line().expect("line present");
        assert!(line.contains("50% attained"));
        assert!(line.contains("p0: 1/2"));
        assert!(line.contains("p2: 1/2"));
        assert!(s.report().contains("slo:"));
    }

    #[test]
    fn histogram_snapshots_exported_cumulative() {
        let m = Metrics::new();
        m.completed(0.5, 0.1);
        m.completed(0.6, 0.2);
        m.record_tbts(&[0.01, 0.02, 0.03]);
        let s = m.snapshot();
        assert_eq!(s.ttft_hist.count, 2);
        assert_eq!(s.tbt_hist.count, 3);
        assert!(s.tbt_hist.buckets.windows(2).all(|w| w[0].1 <= w[1].1));
        assert!((s.tbt_hist.sum - 0.06).abs() < 1e-12);
        assert_eq!(s.step_attn_hist.count, 0, "no instrumented steps ran");
    }

    #[test]
    fn merge_into_default_reproduces_the_shard() {
        use crate::coordinator::request::RequestClass;
        let m = Metrics::new();
        m.set_kv_pool(1024, "fp16");
        m.admitted(10);
        m.batch_formed(3);
        m.tokens_generated(12);
        m.decode_step(3, 4);
        m.decode_step(1, 4);
        m.decode_timing(
            StepTiming { attn: 0.002, gemm: 0.004, prefix_hits: 1, ..Default::default() },
            0.001,
        );
        m.record_tbts(&[0.01, 0.02]);
        m.completed(0.25, 0.05);
        m.slo_scored(&Response {
            id: 0,
            tokens: vec![1; 12],
            ttft: 0.05,
            latency: 0.25,
            prompt_len: 10,
            class: RequestClass { priority: 1, ttft_deadline: 0.5, tbt_budget: 0.1 },
            max_tbt: 0.02,
        });
        let s = m.snapshot();
        let merged = Snapshot::aggregate([&s]);
        assert_eq!(merged.requests_admitted, s.requests_admitted);
        assert_eq!(merged.tokens_out, s.tokens_out);
        assert_eq!(merged.kv_pool_bytes, s.kv_pool_bytes);
        assert_eq!(merged.kv_dtype, s.kv_dtype);
        assert_eq!(merged.mean_batch_size, s.mean_batch_size);
        assert_eq!(merged.tokens_per_step, s.tokens_per_step);
        assert_eq!(merged.decode_occupancy, s.decode_occupancy);
        assert_eq!(merged.tokens_per_sec, s.tokens_per_sec);
        assert_eq!(merged.goodput_tok_s, s.goodput_tok_s);
        assert_eq!(
            (merged.latency_p50, merged.latency_p95, merged.latency_p99, merged.latency_mean),
            (s.latency_p50, s.latency_p95, s.latency_p99, s.latency_mean),
        );
        assert_eq!(
            (merged.ttft_p50, merged.ttft_p95, merged.ttft_p99),
            (s.ttft_p50, s.ttft_p95, s.ttft_p99),
        );
        assert_eq!(merged.tbt, s.tbt);
        assert_eq!(merged.step_attn, s.step_attn);
        assert_eq!(merged.slo_by_class, s.slo_by_class);
        assert_eq!(merged.slo_attainment(), s.slo_attainment());
        assert_eq!(merged.prefix_hit_rate(), s.prefix_hit_rate());
    }

    #[test]
    fn merge_recomputes_ratios_from_sums_not_averages() {
        // A busy shard and a near-idle shard. Averaging per-shard ratios
        // would give mean_batch_size (8+1)/2 = 4.5 and 50% SLO attainment;
        // the exact aggregate recomputes from summed raws.
        let busy = Metrics::new();
        for _ in 0..9 {
            busy.batch_formed(8);
            busy.decode_step(8, 8);
        }
        busy.tokens_generated(72);
        let idle = Metrics::new();
        idle.batch_formed(1);
        idle.decode_step(1, 8);
        idle.tokens_generated(1);
        let (sb, si) = (busy.snapshot(), idle.snapshot());
        let agg = Snapshot::aggregate([&sb, &si]);
        assert_eq!(agg.batches, 10);
        assert_eq!(agg.batch_size_sum, 73);
        assert!((agg.mean_batch_size - 7.3).abs() < 1e-12, "73/10, not (8+1)/2");
        assert_eq!(agg.decode_steps, 10);
        assert!((agg.tokens_per_step - 7.3).abs() < 1e-12);
        let expected_occ = (sb.occupancy_sum + si.occupancy_sum) / 10.0;
        assert!((agg.decode_occupancy - expected_occ).abs() < 1e-12);
        assert_eq!(agg.tokens_out, 73);
        assert_eq!(agg.elapsed, sb.elapsed.max(si.elapsed), "shared wall clock, not summed");
        assert_eq!(agg.tokens_per_sec, 73.0 / agg.elapsed);
    }

    #[test]
    fn merge_sums_slo_classes_and_latency_buckets() {
        use crate::coordinator::request::RequestClass;
        let resp = |priority, ttft: f64, n_tokens: usize| Response {
            id: 0,
            tokens: vec![1; n_tokens],
            ttft,
            latency: ttft + 0.1,
            prompt_len: 4,
            class: RequestClass { priority, ttft_deadline: 0.5, tbt_budget: 0.1 },
            max_tbt: 0.01,
        };
        let a = Metrics::new();
        a.slo_scored(&resp(0, 0.1, 5)); // met
        a.slo_scored(&resp(2, 0.9, 5)); // ttft violation
        a.completed(0.2, 0.1);
        let b = Metrics::new();
        b.slo_scored(&resp(2, 0.1, 8)); // met
        b.completed(0.4, 0.1);
        b.completed(0.4, 0.1);
        let agg = Snapshot::aggregate([&a.snapshot(), &b.snapshot()]);
        assert_eq!(agg.slo_by_class.len(), 2);
        assert_eq!(agg.slo_by_class[0], ClassSlo { priority: 0, completed: 1, met: 1 });
        assert_eq!(agg.slo_by_class[1], ClassSlo { priority: 2, completed: 2, met: 1 });
        assert!((agg.slo_attainment() - 2.0 / 3.0).abs() < 1e-12, "2 met of 3 scored");
        assert_eq!(agg.goodput_tokens, 13);
        assert_eq!(agg.ttft_violations, 1);
        // Latency buckets combine: 3 samples total, p99 lands in the
        // 0.4s bucket of the merged distribution.
        assert_eq!(agg.latency_hist.count, 3);
        assert_eq!(agg.requests_completed, 3);
        assert!(agg.latency_p99 >= 0.4 && agg.latency_p99 < 0.5);
        assert!(agg.latency_p50 >= 0.2);
    }

    #[test]
    fn thread_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        m.admitted(1);
                        m.tokens_generated(1);
                        m.decode_step(1, 4);
                    }
                });
            }
        });
        let snap = m.snapshot();
        assert_eq!(snap.requests_admitted, 400);
        assert_eq!(snap.tokens_out, 400);
        assert_eq!(snap.decode_steps, 400);
        assert!((snap.decode_occupancy - 0.25).abs() < 1e-9);
    }
}