//! Request/response types for the serving path.

use std::time::Instant;

pub type RequestId = u64;

/// Deadline/priority class of a request: the SLO it is scored against and
/// the weight the config-gated preemption victim policy gives it.
///
/// `priority` orders classes (higher = more important; the class-aware
/// victim policy evicts the *lowest* priority first, youngest within a
/// class). `ttft_deadline` and `tbt_budget` are the latency SLOs, in
/// seconds: a completed request **meets its SLO** iff its time-to-first-
/// token is within `ttft_deadline` AND its worst per-token gap is within
/// `tbt_budget`. Classes are pure observability + victim-ordering inputs —
/// they never change what tokens a request generates (the bitwise
/// invariants are class-agnostic).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RequestClass {
    /// Scheduling weight; higher survives pool exhaustion longer under
    /// the class-aware victim policy (`BDA_CLASS_PREEMPT=1`).
    pub priority: u8,
    /// Time-to-first-token deadline, seconds from arrival.
    pub ttft_deadline: f64,
    /// Per-token budget: the maximum acceptable gap between consecutive
    /// generated tokens, seconds.
    pub tbt_budget: f64,
}

impl Default for RequestClass {
    /// The ambient default class, overridable per process via
    /// `BDA_SLO_PRIORITY` / `BDA_SLO_TTFT` / `BDA_SLO_TBT` (read at each
    /// construction, not latched — like `BDA_KV_DTYPE`). Unset or
    /// unparsable values fall back to priority 1, a 1 s TTFT deadline,
    /// and a 250 ms per-token budget.
    fn default() -> Self {
        fn env_f64(key: &str, fallback: f64) -> f64 {
            std::env::var(key).ok().and_then(|s| s.trim().parse().ok()).unwrap_or(fallback)
        }
        let priority = std::env::var("BDA_SLO_PRIORITY")
            .ok()
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(1u8);
        RequestClass {
            priority,
            ttft_deadline: env_f64("BDA_SLO_TTFT", 1.0),
            tbt_budget: env_f64("BDA_SLO_TBT", 0.25),
        }
    }
}

impl RequestClass {
    /// A class with the given priority and the ambient deadline defaults.
    pub fn with_priority(priority: u8) -> RequestClass {
        RequestClass { priority, ..Default::default() }
    }
}

/// An inference request: a prompt and a generation budget.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Greedy if None; otherwise softmax temperature sampling with this
    /// temperature and the request id as seed.
    pub temperature: Option<f32>,
    pub arrival: Instant,
    /// Deadline/priority class (SLO scoring + victim-policy input).
    pub class: RequestClass,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<u32>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens,
            temperature: None,
            arrival: Instant::now(),
            class: RequestClass::default(),
        }
    }

    /// Builder: the same request in an explicit deadline/priority class.
    pub fn with_class(mut self, class: RequestClass) -> Request {
        self.class = class;
        self
    }
}

/// A completed request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    /// Seconds from arrival to first generated token.
    pub ttft: f64,
    /// Seconds from arrival to completion.
    pub latency: f64,
    pub prompt_len: usize,
    /// The class the request was scored against.
    pub class: RequestClass,
    /// Worst observed gap between consecutive generated tokens, seconds
    /// (0.0 for single-token responses). A preemption's recompute gap
    /// lands here, so an evicted victim that blows its budget is scored
    /// truthfully.
    pub max_tbt: f64,
}

impl Response {
    pub fn tokens_generated(&self) -> usize {
        self.tokens.len()
    }

    /// Did this response meet its class SLO (TTFT within deadline AND
    /// every token gap within budget)?
    pub fn slo_met(&self) -> bool {
        self.ttft <= self.class.ttft_deadline && self.max_tbt <= self.class.tbt_budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_fields() {
        let r = Request::new(7, vec![1, 2, 3], 16);
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt.len(), 3);
        assert_eq!(r.max_new_tokens, 16);
        assert!(r.temperature.is_none());
        assert_eq!(r.class, RequestClass::default());
    }

    #[test]
    fn with_class_overrides_default() {
        let class = RequestClass { priority: 3, ttft_deadline: 0.5, tbt_budget: 0.05 };
        let r = Request::new(7, vec![1], 4).with_class(class);
        assert_eq!(r.class, class);
        assert_eq!(RequestClass::with_priority(9).priority, 9);
    }

    #[test]
    fn response_count() {
        let resp = Response {
            id: 1,
            tokens: vec![5, 6],
            ttft: 0.1,
            latency: 0.2,
            prompt_len: 3,
            class: RequestClass::default(),
            max_tbt: 0.0,
        };
        assert_eq!(resp.tokens_generated(), 2);
    }

    #[test]
    fn slo_met_checks_both_deadlines() {
        let class = RequestClass { priority: 1, ttft_deadline: 0.2, tbt_budget: 0.1 };
        let base = Response {
            id: 1,
            tokens: vec![5, 6],
            ttft: 0.1,
            latency: 0.3,
            prompt_len: 3,
            class,
            max_tbt: 0.05,
        };
        assert!(base.slo_met());
        assert!(!Response { ttft: 0.3, ..base.clone() }.slo_met(), "ttft violation");
        assert!(!Response { max_tbt: 0.2, ..base.clone() }.slo_met(), "tbt violation");
    }
}
