//! Request/response types for the serving path.

use std::time::Instant;

pub type RequestId = u64;

/// An inference request: a prompt and a generation budget.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Greedy if None; otherwise softmax temperature sampling with this
    /// temperature and the request id as seed.
    pub temperature: Option<f32>,
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<u32>, max_new_tokens: usize) -> Request {
        Request { id, prompt, max_new_tokens, temperature: None, arrival: Instant::now() }
    }
}

/// A completed request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    /// Seconds from arrival to first generated token.
    pub ttft: f64,
    /// Seconds from arrival to completion.
    pub latency: f64,
    pub prompt_len: usize,
}

impl Response {
    pub fn tokens_generated(&self) -> usize {
        self.tokens.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_fields() {
        let r = Request::new(7, vec![1, 2, 3], 16);
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt.len(), 3);
        assert_eq!(r.max_new_tokens, 16);
        assert!(r.temperature.is_none());
    }

    #[test]
    fn response_count() {
        let resp = Response { id: 1, tokens: vec![5, 6], ttft: 0.1, latency: 0.2, prompt_len: 3 };
        assert_eq!(resp.tokens_generated(), 2);
    }
}
