//! Attention operators: the MHA reference (Alg. 1), BD Attention (Alg. 2),
//! the PIFA-style per-head-pivot baseline, the structured-pruning baseline,
//! standalone k/v projection operators (the Fig. 2b / Tables 6–7 bench
//! targets), batched paged attention (the serving engine's decode
//! operator), and decoupled RoPE (Appendix D).

pub mod bda;
pub mod kproj;
pub mod mha;
pub mod paged;
pub mod pifa;
pub mod pruning;
pub mod rope;

pub use bda::{BdaAttention, BdaWeights};
pub use mha::{mha_forward, MhaWeights};
pub use pifa::PifaAttention;

use crate::tensor::Tensor;

/// Shape of one attention block: input dim `d`, `n_heads` heads of
/// dimension `d_h` each. The paper's operator benches use the DeepSeek-V3
/// KV configuration d=512, d_h=128, n=128 (compression ratio d_h/d = 25%).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AttnShape {
    pub d: usize,
    pub n_heads: usize,
    pub d_h: usize,
}

impl AttnShape {
    pub fn new(d: usize, n_heads: usize, d_h: usize) -> Self {
        assert!(d_h < d, "BD requires d_h < d");
        AttnShape { d, n_heads, d_h }
    }

    /// The DeepSeek-V3 KV shape used in Tables 6–7.
    pub fn deepseek_v3() -> Self {
        AttnShape::new(512, 128, 128)
    }

    /// Total projection width n·d_h.
    pub fn proj_width(&self) -> usize {
        self.n_heads * self.d_h
    }

    /// Compression ratio d_h/d (paper: 25%).
    pub fn compression_ratio(&self) -> f64 {
        self.d_h as f64 / self.d as f64
    }
}

/// Split an L×(n·d_h) tensor into n per-head L×d_h views (copies).
pub fn split_heads(x: &Tensor, n_heads: usize) -> Vec<Tensor> {
    assert_eq!(x.ndim(), 2);
    let total = x.cols();
    assert_eq!(total % n_heads, 0);
    let d_h = total / n_heads;
    (0..n_heads).map(|i| x.slice_cols(i * d_h, (i + 1) * d_h)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_invariants() {
        let s = AttnShape::deepseek_v3();
        assert_eq!(s.proj_width(), 128 * 128);
        assert!((s.compression_ratio() - 0.25).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn dh_must_be_less_than_d() {
        AttnShape::new(128, 4, 128);
    }

    #[test]
    fn split_heads_roundtrip() {
        let x = Tensor::randn(&[3, 8], 1.0, 1);
        let heads = split_heads(&x, 4);
        assert_eq!(heads.len(), 4);
        let refs: Vec<&Tensor> = heads.iter().collect();
        assert_eq!(Tensor::concat_cols(&refs), x);
    }
}
