//! Standalone K/V-projection operators — the bench targets of Fig. 2b and
//! Tables 6–7.
//!
//! * `kproj_mha`  — `K = X W_k`: one L×d @ d×(n·d_h) GEMM.
//! * `kproj_bda`  — Line 2 of Algorithm 2, *fused*: the repeat of the
//!   shared basis slice is written directly into the output buffer which
//!   the GEMM then accumulates into — the Rust analogue of the paper's
//!   fused Triton kernel (slice + repeat + matmul + add in one pass,
//!   no intermediate materialization).
//! * `kproj_pifa` — the PIFA-style baseline: per-head *scattered* basis
//!   indices force per-head gathers of X (the memory-traffic penalty that
//!   makes PIFA slower than even MHA in the paper's Tables 6–7).

use super::AttnShape;
use crate::bd::Tag;
use crate::tensor::matmul::matmul;
use crate::tensor::{DType, Tensor};
use crate::util::threadpool::{parallel_chunks, SendPtr};

/// Baseline MHA k-projection: `K = X W_k`.
pub fn kproj_mha(x: &Tensor, w_k: &Tensor) -> Tensor {
    matmul(x, w_k)
}

/// Fused BDA k-projection (Algorithm 2, line 2):
/// `K' = [X_basis]^{×n} + X_rest · C` with `C: (d−d_h) × n·d_h`.
///
/// Fusion: the output is *initialized* with the repeated basis slice
/// (block copy per head) and the GEMM accumulates into it — no separate
/// repeat buffer, no second addition pass.
pub fn kproj_bda(x: &Tensor, c: &Tensor, tag: Tag, s: AttnShape) -> Tensor {
    let (l, d) = (x.rows(), x.cols());
    assert_eq!(d, s.d);
    let d_h = s.d_h;
    let width = s.proj_width();
    assert_eq!(c.shape, vec![d - d_h, width], "C shape mismatch");

    let (basis_lo, rest_lo, rest_hi) = match tag {
        Tag::First => (0usize, d_h, d),
        Tag::Last => (d - d_h, 0, d - d_h),
    };
    let rest_w = rest_hi - rest_lo;

    let mut out = Tensor::zeros(&[l, width]);
    out.dtype = x.dtype;

    // Pass 1 (fused init): out[i, h*d_h + j] = x[i, basis_lo + j] for all h.
    // Pass 2: out += X[:, rest] @ C, using a packed copy of the rest slice
    // per row panel (stays in cache; avoids strided GEMM reads).
    let xs = &x.data;
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    // Sized by the current dispatch pool (the engine's own pool under
    // `threadpool::with_pool`, like the blocked GEMM) so panel count and
    // worker count agree; panel boundaries don't affect per-row
    // accumulation order, so this is a pure scheduling choice.
    let panel = l.div_ceil(crate::util::threadpool::current_workers() * 2).clamp(8, 128);
    parallel_chunks(l, panel, |lo, hi| {
        let rows = hi - lo;
        let out_panel = unsafe {
            std::slice::from_raw_parts_mut(out_ptr.get().add(lo * width), rows * width)
        };
        // Fused repeat-init.
        for i in 0..rows {
            let src = &xs[(lo + i) * d + basis_lo..(lo + i) * d + basis_lo + d_h];
            let dst = &mut out_panel[i * width..(i + 1) * width];
            for h in 0..s.n_heads {
                dst[h * d_h..(h + 1) * d_h].copy_from_slice(src);
            }
        }
        // GEMM accumulate into the pre-initialized panel, reading the
        // X_rest column slice in place (strided rows; no packing copy —
        // perf iteration 2, see EXPERIMENTS.md SS Perf).
        let a = &xs[lo * d + rest_lo..];
        crate::tensor::matmul::gemm_serial_strided(a, d, &c.data, out_panel, rows, rest_w, width);
    });

    out.requantize();
    out
}

/// Unfused BDA k-projection (ablation): materializes the repeat, computes
/// the GEMM into a separate buffer, then adds — three passes over memory.
pub fn kproj_bda_unfused(x: &Tensor, c: &Tensor, tag: Tag, s: AttnShape) -> Tensor {
    let d = s.d;
    let d_h = s.d_h;
    let (basis, rest) = match tag {
        Tag::First => (x.slice_cols(0, d_h), x.slice_cols(d_h, d)),
        Tag::Last => (x.slice_cols(d - d_h, d), x.slice_cols(0, d - d_h)),
    };
    let repeated = basis.repeat_cols(s.n_heads);
    let prod = matmul(&rest, c);
    let mut out = repeated.add(&prod);
    out.dtype = x.dtype;
    out.requantize();
    out
}

/// PIFA-style per-head k-projection: each head has its own *scattered*
/// basis indices into the d input channels, so X must be gathered per head
/// before the per-head GEMM — the slow path of the paper's comparison.
pub struct PifaKproj {
    pub s: AttnShape,
    /// Per head: d_h basis column indices (non-contiguous, from QR pivots).
    pub basis_idx: Vec<Vec<usize>>,
    /// Per head: complement indices, (d−d_h).
    pub rest_idx: Vec<Vec<usize>>,
    /// Per head: coefficient matrix (d−d_h) × d_h.
    pub coef: Vec<Tensor>,
}

impl PifaKproj {
    /// Project: for each head i, K'_i = X[:, basis_i] + X[:, rest_i] @ C_i.
    pub fn project(&self, x: &Tensor) -> Tensor {
        let (l, d) = (x.rows(), x.cols());
        assert_eq!(d, self.s.d);
        let d_h = self.s.d_h;
        let width = self.s.proj_width();
        let mut out = Tensor::zeros(&[l, width]);
        out.dtype = x.dtype;
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        let xs = &x.data;
        // Parallel over heads: each head does its own gathers (the point:
        // n separate scattered passes over X instead of one shared slice).
        parallel_chunks(self.s.n_heads, 1, |h0, h1| {
            for h in h0..h1 {
                let bi = &self.basis_idx[h];
                let ri = &self.rest_idx[h];
                let rest_w = ri.len();
                // Gather basis -> init out head block.
                let out_all = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.get(), l * width)
                };
                for i in 0..l {
                    let dst = &mut out_all[i * width + h * d_h..i * width + (h + 1) * d_h];
                    for (j, &src_col) in bi.iter().enumerate() {
                        dst[j] = xs[i * d + src_col];
                    }
                }
                // Gather rest (scattered copy) then per-head GEMM accumulate.
                let mut xr = vec![0.0f32; l * rest_w];
                for i in 0..l {
                    for (j, &src_col) in ri.iter().enumerate() {
                        xr[i * rest_w + j] = xs[i * d + src_col];
                    }
                }
                // Accumulate into the scattered head block via a temp panel
                // (head block is strided in out, so GEMM into temp + add).
                let mut tmp = vec![0.0f32; l * d_h];
                matmul_into_serial(&xr, &self.coef[h].data, &mut tmp, l, rest_w, d_h);
                for i in 0..l {
                    let dst = &mut out_all[i * width + h * d_h..i * width + (h + 1) * d_h];
                    for j in 0..d_h {
                        dst[j] += tmp[i * d_h + j];
                    }
                }
            }
        });
        out.requantize();
        out
    }
}

/// Serial GEMM accumulate helper shared by the fused paths (panel-local, so
/// parallelism lives at the panel level, not inside the GEMM). Delegates to
/// the blocked micro-kernel in tensor::matmul so fused operators and plain
/// matmul share identical GEMM quality (perf iteration 1 — see
/// EXPERIMENTS.md SS Perf: the naive i-k-j loop here cost BDA its speedup).
fn matmul_into_serial(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    crate::tensor::matmul::gemm_serial(a, b, c, m, k, n)
}

/// Build a PIFA-style projector from per-head QK products via QR column
/// pivoting (the paper's §4.1 comparator).
pub fn pifa_from_mha(mha: &super::mha::MhaWeights) -> PifaKproj {
    let s = mha.shape;
    let mut basis_idx = Vec::with_capacity(s.n_heads);
    let mut rest_idx = Vec::with_capacity(s.n_heads);
    let mut coef = Vec::with_capacity(s.n_heads);
    for i in 0..s.n_heads {
        let w = matmul(&mha.wq_head(i), &mha.wk_head(i).transpose()); // d×d
        // Pivot columns of W (basis columns), like PIFA's pivoted selection.
        let qr = crate::linalg::qr::qr_column_pivoting(&w);
        let mut bi: Vec<usize> = qr.pivots[..s.d_h].to_vec();
        bi.sort();
        let bset: std::collections::BTreeSet<usize> = bi.iter().copied().collect();
        let ri: Vec<usize> = (0..s.d).filter(|j| !bset.contains(j)).collect();
        // Solve B C = W_rest for C ((d−d_h)×d_h appears transposed here:
        // K'_i = X[:,basis] + X[:,rest] @ C_i with C_i: (d−d_h)×d_h solving
        // W[:,rest_cols] = W[:,basis] · C_colform — mirror of contiguous BD.
        let b = gather_cols(&w, &bi);
        let rest = gather_cols(&w, &ri);
        let btb = matmul(&b.transpose(), &b);
        let btr = matmul(&b.transpose(), &rest);
        let c_bd = crate::linalg::lu::lu_solve_matrix(&btb, &btr).expect("pifa solve");
        // c_bd: d_h × (d−d_h); our projector wants (d−d_h) × d_h.
        coef.push(c_bd.transpose());
        basis_idx.push(bi);
        rest_idx.push(ri);
    }
    PifaKproj { s, basis_idx, rest_idx, coef }
}

fn gather_cols(t: &Tensor, idx: &[usize]) -> Tensor {
    let r = t.rows();
    let mut out = Tensor::zeros(&[r, idx.len()]);
    for i in 0..r {
        for (j, &c) in idx.iter().enumerate() {
            *out.at_mut(i, j) = t.at(i, c);
        }
    }
    out
}

/// FLOPs of the MHA k-projection (2·L·d·n·d_h).
pub fn kproj_mha_flops(l: usize, s: AttnShape) -> u64 {
    2 * l as u64 * s.d as u64 * s.proj_width() as u64
}

/// FLOPs of the BDA k-projection (2·L·(d−d_h)·n·d_h + L·n·d_h adds).
pub fn kproj_bda_flops(l: usize, s: AttnShape) -> u64 {
    2 * l as u64 * (s.d - s.d_h) as u64 * s.proj_width() as u64
        + l as u64 * s.proj_width() as u64
}

/// Quantize inputs to the bench dtype (operators accumulate f32 and
/// requantize outputs, like tensor-core GEMMs).
pub fn bench_inputs(l: usize, s: AttnShape, dt: DType, seed: u64) -> (Tensor, Tensor) {
    let x = Tensor::randn(&[l, s.d], 1.0, seed).cast(dt);
    let w = Tensor::randn(&[s.d, s.proj_width()], 0.02, seed + 1).cast(dt);
    (x, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::mha::MhaWeights;
    use crate::bd::Strategy;
    use crate::tensor::DType;

    fn shape_small() -> AttnShape {
        AttnShape::new(32, 4, 8)
    }

    #[test]
    fn fused_matches_unfused() {
        let s = shape_small();
        let x = Tensor::randn(&[9, s.d], 1.0, 1);
        let c = Tensor::randn(&[s.d - s.d_h, s.proj_width()], 0.1, 2);
        for tag in [Tag::First, Tag::Last] {
            let a = kproj_bda(&x, &c, tag, s);
            let b = kproj_bda_unfused(&x, &c, tag, s);
            assert!(a.max_abs_diff(&b) < 1e-4, "tag {tag:?}");
        }
    }

    #[test]
    fn bda_kproj_equals_mha_kproj_after_prep() {
        // K' from BDA applied to X must reproduce per-head inner products;
        // here we check the stronger statement used by Alg. 2: K' equals
        // X · (reconstructed K-side factor) for the First tag.
        let s = shape_small();
        let mha = MhaWeights::random(s, 3);
        let bda =
            crate::attention::bda::BdaWeights::prepare(&mha, Strategy::FirstR, DType::F32)
                .unwrap();
        let x = Tensor::randn(&[7, s.d], 1.0, 4);
        let kp = kproj_bda(&x, &bda.c_qk, bda.tag_qk, s);
        // Reference: per head, [I, C] X^T transposed -> X_basis + X_rest C^T
        let xb = x.slice_cols(0, s.d_h);
        let xr = x.slice_cols(s.d_h, s.d);
        for i in 0..s.n_heads {
            let ci = bda.c_qk.slice_cols(i * s.d_h, (i + 1) * s.d_h);
            let expect = xb.add(&matmul(&xr, &ci));
            let got = kp.slice_cols(i * s.d_h, (i + 1) * s.d_h);
            assert!(got.max_abs_diff(&expect) < 1e-4);
        }
    }

    #[test]
    fn pifa_matches_mha_scores() {
        // PIFA is also exact (it's a BD with pivoted basis): per-head
        // Q'K'^T must match QK^T when paired with the pivoted q-side.
        // We verify the projector reproduces W's action: for each head,
        // X[:,basis] + X[:,rest] C = X W_perm_head …
        // Simpler end-to-end check: gather+coef reproduces X @ W columns.
        let s = shape_small();
        let mha = MhaWeights::random(s, 5);
        let pifa = pifa_from_mha(&mha);
        let x = Tensor::randn(&[6, s.d], 1.0, 6);
        let kp = pifa.project(&x);
        for h in 0..s.n_heads {
            let w = matmul(&mha.wq_head(h), &mha.wk_head(h).transpose());
            // Expected head block: X[:, basis] + X[:, rest] @ C_h must equal
            // X @ W[:, basis-ordered reconstruction]… the invariant we rely
            // on downstream is inner-product preservation; check the
            // projector is *consistent*: out = gather(X) + gathered-rest @ C.
            let bi = &pifa.basis_idx[h];
            let ri = &pifa.rest_idx[h];
            let xb = gather_cols(&x, bi);
            let xr = gather_cols(&x, ri);
            let expect = xb.add(&matmul(&xr, &pifa.coef[h]));
            let got = kp.slice_cols(h * s.d_h, (h + 1) * s.d_h);
            assert!(got.max_abs_diff(&expect) < 1e-4, "head {h}");
            let _ = w;
        }
    }

    #[test]
    fn flops_ratio_is_one_third_savings() {
        let s = AttnShape::deepseek_v3();
        let l = 1024;
        let ratio = kproj_mha_flops(l, s) as f64 / kproj_bda_flops(l, s) as f64;
        // d/(d−d_h) = 4/3 up to the small add term.
        assert!((ratio - 4.0 / 3.0).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn fused_f16_quantizes_output() {
        let s = shape_small();
        let x = Tensor::randn(&[4, s.d], 1.0, 7).cast(DType::F16);
        let c = Tensor::randn(&[s.d - s.d_h, s.proj_width()], 0.1, 8).cast(DType::F16);
        let out = kproj_bda(&x, &c, Tag::First, s);
        assert_eq!(out.dtype, DType::F16);
        // Every value representable in f16.
        for &v in &out.data {
            assert_eq!(crate::tensor::dtype::DType::F16.quantize(v), v);
        }
    }

    #[test]
    fn large_l_consistency() {
        // Cross-check fused vs unfused on a larger L to exercise panels.
        let s = AttnShape::new(64, 8, 16);
        let x = Tensor::randn(&[300, s.d], 1.0, 9);
        let c = Tensor::randn(&[s.d - s.d_h, s.proj_width()], 0.05, 10);
        let a = kproj_bda(&x, &c, Tag::First, s);
        let b = kproj_bda_unfused(&x, &c, Tag::First, s);
        assert!(a.max_abs_diff(&b) < 1e-3);
    }
}
