//! Structured K/V-channel pruning baseline (the dashed line in Fig. 2a).
//!
//! Following the relative-importance scoring of Zhang et al. (2024) as the
//! paper describes: each K/V channel's importance is estimated from weight
//! magnitudes, summed, and the least important fraction is pruned — at the
//! same compression ratio as BDA (d_h/d = 25% of K/V channels), but
//! *lossy*, unlike BDA.

use super::mha::{attention_core, MhaWeights};
use super::AttnShape;
use crate::tensor::matmul::matmul;
use crate::tensor::Tensor;

/// MHA with a fraction of K/V channels structurally removed (per head, so
/// head widths stay uniform).
#[derive(Clone, Debug)]
pub struct PrunedAttention {
    pub shape: AttnShape,
    /// Pruned per-head dim.
    pub d_h_kept: usize,
    /// d × n·d_h_kept
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    /// n·d_h_kept × d
    pub wo: Tensor,
    /// Kept channel indices per head (into the original d_h).
    pub kept: Vec<Vec<usize>>,
}

/// Channel importance: relative magnitude score — |w| of the channel
/// normalized by its row's total magnitude, summed over rows (a
/// calibration-free variant of relative-importance pruning).
fn channel_scores(w: &Tensor) -> Vec<f64> {
    let (d, cols) = (w.rows(), w.cols());
    let mut row_sums = vec![0.0f64; d];
    for i in 0..d {
        row_sums[i] = w.row(i).iter().map(|v| v.abs() as f64).sum::<f64>().max(1e-12);
    }
    let mut scores = vec![0.0f64; cols];
    for i in 0..d {
        for j in 0..cols {
            scores[j] += (w.at(i, j).abs() as f64) / row_sums[i];
        }
    }
    scores
}

impl PrunedAttention {
    /// Prune `frac` of each head's K/V channels (e.g. 0.25 to match BDA's
    /// compression). Q channels follow K (scores must stay aligned);
    /// O rows follow V.
    pub fn from_mha(mha: &MhaWeights, frac: f64) -> PrunedAttention {
        let s = mha.shape;
        let drop = ((s.d_h as f64) * frac).round() as usize;
        let keep = s.d_h - drop;
        assert!(keep >= 1);

        // Importance per head from combined K and V magnitudes.
        let k_scores = channel_scores(&mha.wk);
        let v_scores = channel_scores(&mha.wv);
        let mut kept_per_head = Vec::with_capacity(s.n_heads);
        for h in 0..s.n_heads {
            let base = h * s.d_h;
            let mut idx: Vec<usize> = (0..s.d_h).collect();
            idx.sort_by(|&a, &b| {
                let sa = k_scores[base + a] + v_scores[base + a];
                let sb = k_scores[base + b] + v_scores[base + b];
                sb.partial_cmp(&sa).unwrap()
            });
            let mut kept: Vec<usize> = idx[..keep].to_vec();
            kept.sort();
            kept_per_head.push(kept);
        }

        // Build pruned weights.
        let sel_cols = |w: &Tensor| -> Tensor {
            let mut parts = Vec::new();
            for h in 0..s.n_heads {
                for &j in &kept_per_head[h] {
                    parts.push(w.slice_cols(h * s.d_h + j, h * s.d_h + j + 1));
                }
            }
            let refs: Vec<&Tensor> = parts.iter().collect();
            Tensor::concat_cols(&refs)
        };
        let sel_rows = |w: &Tensor| -> Tensor {
            let mut parts = Vec::new();
            for h in 0..s.n_heads {
                for &j in &kept_per_head[h] {
                    parts.push(w.slice_rows(h * s.d_h + j, h * s.d_h + j + 1));
                }
            }
            let refs: Vec<&Tensor> = parts.iter().collect();
            Tensor::concat_rows(&refs)
        };

        PrunedAttention {
            shape: s,
            d_h_kept: keep,
            wq: sel_cols(&mha.wq),
            wk: sel_cols(&mha.wk),
            wv: sel_cols(&mha.wv),
            wo: sel_rows(&mha.wo),
            kept: kept_per_head,
        }
    }

    pub fn forward(&self, x: &Tensor, causal: bool) -> Tensor {
        let s_pruned = AttnShape::new(self.shape.d, self.shape.n_heads, self.d_h_kept);
        let q = matmul(x, &self.wq);
        let k = matmul(x, &self.wk);
        let v = matmul(x, &self.wv);
        attention_core(&q, &k, &v, &self.wo, s_pruned, causal)
    }

    /// K/V parameter count after pruning.
    pub fn kv_param_count(&self) -> usize {
        self.wk.numel() + self.wv.numel()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::mha::mha_forward;

    #[test]
    fn prunes_exact_fraction() {
        let s = AttnShape::new(32, 4, 8);
        let mha = MhaWeights::random(s, 1);
        let pruned = PrunedAttention::from_mha(&mha, 0.25);
        assert_eq!(pruned.d_h_kept, 6);
        let ratio = pruned.kv_param_count() as f64 / (mha.wk.numel() + mha.wv.numel()) as f64;
        assert!((ratio - 0.75).abs() < 1e-12);
    }

    #[test]
    fn pruned_output_is_lossy() {
        // Unlike BDA, structured pruning changes outputs.
        let s = AttnShape::new(32, 4, 8);
        let mha = MhaWeights::random(s, 2);
        let pruned = PrunedAttention::from_mha(&mha, 0.25);
        let x = Tensor::randn(&[5, s.d], 1.0, 3);
        let y_ref = mha_forward(&mha, &x, false);
        let y = pruned.forward(&x, false);
        assert_eq!(y.shape, y_ref.shape);
        let rel = (y.max_abs_diff(&y_ref) as f64) / y_ref.fro_norm().max(1e-9);
        assert!(rel > 1e-4, "pruning should be lossy, rel {rel}");
    }

    #[test]
    fn keeps_high_importance_channels() {
        let s = AttnShape::new(16, 2, 4);
        let mut mha = MhaWeights::random(s, 4);
        // Make channel 2 of head 0 hugely important in K and V.
        for i in 0..s.d {
            *mha.wk.at_mut(i, 2) = 5.0;
            *mha.wv.at_mut(i, 2) = 5.0;
        }
        let pruned = PrunedAttention::from_mha(&mha, 0.25);
        assert!(pruned.kept[0].contains(&2));
    }

    #[test]
    fn forward_shape_preserved() {
        let s = AttnShape::new(16, 2, 4);
        let mha = MhaWeights::random(s, 5);
        let pruned = PrunedAttention::from_mha(&mha, 0.25);
        let x = Tensor::randn(&[7, s.d], 1.0, 6);
        assert_eq!(pruned.forward(&x, true).shape, vec![7, s.d]);
    }
}
