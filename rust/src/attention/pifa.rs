//! PIFA-style attention — the paper's §4.1 comparator.
//!
//! PIFA (Zhao et al., 2025) selects basis rows via QR with column pivoting,
//! giving each head a *different, scattered* basis. Exactness is identical
//! to BD (it is a BD with pivoted basis), but inference pays per-head
//! gathers and slices of X — which is why Tables 6–7 show it slower than
//! even baseline MHA. This module wires the pivoted k/v projections into a
//! full attention block so end-to-end comparisons are possible.

use super::kproj::{pifa_from_mha, PifaKproj};
use super::mha::{attention_core, MhaWeights};
use super::AttnShape;
use crate::tensor::matmul::matmul;
use crate::tensor::Tensor;

/// PIFA-style attention block: pivoted-basis K projection, with Q/V/O kept
/// dense (the paper benches the k_proj operator; for the end-to-end block
/// we pair the pivoted K with the matching pivoted Q so scores are exact).
pub struct PifaAttention {
    pub shape: AttnShape,
    /// Per-head pivoted k-projection.
    pub kproj: PifaKproj,
    /// Q-side basis: per-head d × d_h (X B_i), from the same pivot set.
    pub b_q: Tensor,
    /// Dense V/O kept from the original model.
    pub wv: Tensor,
    pub wo: Tensor,
}

impl PifaAttention {
    /// Build from MHA weights: per-head QR-pivot decomposition of the QK
    /// product; V/O unchanged.
    pub fn from_mha(mha: &MhaWeights) -> PifaAttention {
        let s = mha.shape;
        let kproj = pifa_from_mha(mha);
        // Q-side: B_i = columns of W_i at the pivot indices (d × d_h each).
        let mut parts = Vec::with_capacity(s.n_heads);
        for i in 0..s.n_heads {
            let w = matmul(&mha.wq_head(i), &mha.wk_head(i).transpose());
            let bi = &kproj.basis_idx[i];
            let mut b = Tensor::zeros(&[s.d, s.d_h]);
            for r in 0..s.d {
                for (j, &c) in bi.iter().enumerate() {
                    *b.at_mut(r, j) = w.at(r, c);
                }
            }
            parts.push(b);
        }
        let refs: Vec<&Tensor> = parts.iter().collect();
        let b_q = Tensor::concat_cols(&refs);
        PifaAttention { shape: s, kproj, b_q, wv: mha.wv.clone(), wo: mha.wo.clone() }
    }

    /// Forward pass: Q' = X B_q (pivoted), K' = pivoted projection,
    /// V = X W_v, out = core(Q', K', V) W_o.
    pub fn forward(&self, x: &Tensor, causal: bool) -> Tensor {
        let q = matmul(x, &self.b_q);
        let k = self.kproj.project(x);
        let v = matmul(x, &self.wv);
        attention_core(&q, &k, &v, &self.wo, self.shape, causal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::mha::mha_forward;

    #[test]
    fn pifa_scores_match_mha() {
        // PIFA is exact too: X B_i (pivoted-basis Q) times pivoted K'
        // reproduces X W_i X^T, so the full forward matches MHA.
        let s = AttnShape::new(24, 3, 8);
        let mha = MhaWeights::random(s, 1);
        let pifa = PifaAttention::from_mha(&mha);
        let x = Tensor::randn(&[5, s.d], 1.0, 2);
        let y_ref = mha_forward(&mha, &x, false);
        let y = pifa.forward(&x, false);
        let rel = (y.max_abs_diff(&y_ref) as f64) / y_ref.fro_norm().max(1e-9);
        assert!(rel < 1e-3, "rel {rel}");
    }

    #[test]
    fn per_head_bases_differ() {
        // The whole point of the comparison: pivot sets differ across heads
        // (with prob. 1 on random weights), so no shared slice exists.
        let s = AttnShape::new(32, 4, 8);
        let mha = MhaWeights::random(s, 3);
        let pifa = PifaAttention::from_mha(&mha);
        let all_same = pifa
            .kproj
            .basis_idx
            .windows(2)
            .all(|w| w[0] == w[1]);
        assert!(!all_same, "pivot bases should differ across heads");
        // And they are generally non-contiguous.
        let contiguous = |v: &Vec<usize>| v.windows(2).all(|w| w[1] == w[0] + 1);
        assert!(
            !pifa.kproj.basis_idx.iter().all(contiguous),
            "pivot bases should be scattered"
        );
    }

    #[test]
    fn causal_forward_matches_mha() {
        let s = AttnShape::new(16, 2, 4);
        let mha = MhaWeights::random(s, 4);
        let pifa = PifaAttention::from_mha(&mha);
        let x = Tensor::randn(&[6, s.d], 1.0, 5);
        let y_ref = mha_forward(&mha, &x, true);
        let y = pifa.forward(&x, true);
        assert!(y.max_abs_diff(&y_ref) < 1e-3);
    }
}
