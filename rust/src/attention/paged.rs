//! Batched paged attention — the decode-time operator of the serving
//! engine ([`crate::engine`]).
//!
//! One call attends every active sequence's single query row against its
//! own K/V history, where histories live in a shared block pool (vLLM-style
//! paged attention) instead of per-sequence contiguous buffers. The block
//! table supplies the indirection; arithmetic is kept *exactly* the same as
//! the contiguous cached path (`model::transformer::attend_cached`) — same
//! dot-product, max-subtraction, and accumulation order — so paged batched
//! decode is bit-identical to per-sequence decode for both MHA and BDA
//! (the paper's losslessness carried through the serving layer).

use super::AttnShape;
use crate::tensor::Tensor;

/// One layer of paged K/V storage: `num_blocks * block_size` rows of
/// `width = n_heads * d_h` values each, for K and V respectively.
#[derive(Clone, Copy, Debug)]
pub struct PagedLayerView<'a> {
    pub k: &'a [f32],
    pub v: &'a [f32],
    /// Tokens per block.
    pub block_size: usize,
    /// Row width (n_heads * d_h).
    pub width: usize,
}

impl<'a> PagedLayerView<'a> {
    /// Flat storage offset of token `t` of a sequence with block table
    /// `blocks`.
    #[inline]
    pub fn row_offset(&self, blocks: &[usize], t: usize) -> usize {
        (blocks[t / self.block_size] * self.block_size + t % self.block_size) * self.width
    }
}

/// One sequence's view for a batched decode step: its block table and its
/// K/V length *including* the token being decoded (whose K/V row must
/// already be written to storage).
#[derive(Clone, Copy, Debug)]
pub struct PagedSeq<'a> {
    pub blocks: &'a [usize],
    pub len: usize,
}

/// Batched paged attention over one layer: row `i` of `q` attends over the
/// first `seqs[i].len` K/V rows of sequence `i`, gathered through its block
/// table. Returns the concatenated per-head outputs (B × width), ready for
/// the output projection.
pub fn paged_attention_decode(
    q: &Tensor,
    layer: &PagedLayerView,
    seqs: &[PagedSeq],
    s: AttnShape,
) -> Tensor {
    let b = q.rows();
    assert_eq!(seqs.len(), b, "one PagedSeq per query row");
    let width = s.proj_width();
    assert_eq!(q.cols(), width, "query width mismatch");
    assert_eq!(layer.width, width, "storage width mismatch");
    let scale = 1.0 / (s.d_h as f32).sqrt();
    let mut out = Tensor::zeros(&[b, width]);
    for h in 0..s.n_heads {
        let off = h * s.d_h;
        for i in 0..b {
            let visible = seqs[i].len;
            debug_assert!(visible > 0, "seq {i}: empty K/V history");
            debug_assert!(
                visible <= seqs[i].blocks.len() * layer.block_size,
                "seq {i}: len exceeds block table"
            );
            let qrow = &q.data[i * width + off..i * width + off + s.d_h];
            let mut scores = vec![0.0f32; visible];
            for (t, sc) in scores.iter_mut().enumerate() {
                let base = layer.row_offset(seqs[i].blocks, t) + off;
                let krow = &layer.k[base..base + s.d_h];
                *sc = qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for v in scores.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            let orow = &mut out.data[i * width + off..i * width + off + s.d_h];
            for (t, sc) in scores.iter().enumerate() {
                let w = sc * inv;
                let base = layer.row_offset(seqs[i].blocks, t) + off;
                let vrow = &layer.v[base..base + s.d_h];
                for (o, vv) in orow.iter_mut().zip(vrow) {
                    *o += w * vv;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference: contiguous single-sequence attention over cached K/V for
    /// one query row (mirrors `attend_cached` with prior = len - 1).
    fn reference_row(q: &[f32], k: &[f32], v: &[f32], len: usize, s: AttnShape) -> Vec<f32> {
        let width = s.proj_width();
        let scale = 1.0 / (s.d_h as f32).sqrt();
        let mut out = vec![0.0f32; width];
        for h in 0..s.n_heads {
            let off = h * s.d_h;
            let qrow = &q[off..off + s.d_h];
            let mut scores = vec![0.0f32; len];
            for t in 0..len {
                let krow = &k[t * width + off..t * width + off + s.d_h];
                scores[t] = qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for sv in scores.iter_mut() {
                *sv = (*sv - max).exp();
                sum += *sv;
            }
            let inv = 1.0 / sum;
            for t in 0..len {
                let w = scores[t] * inv;
                let vrow = &v[t * width + off..t * width + off + s.d_h];
                for (o, vv) in out[off..off + s.d_h].iter_mut().zip(vrow) {
                    *o += w * vv;
                }
            }
        }
        out
    }

    /// Scatter `len` contiguous K/V rows into paged pools under a block
    /// table.
    fn scatter(
        pk: &mut [f32],
        pv: &mut [f32],
        k: &[f32],
        v: &[f32],
        len: usize,
        width: usize,
        block_size: usize,
        table: &[usize],
    ) {
        for t in 0..len {
            let base = (table[t / block_size] * block_size + t % block_size) * width;
            pk[base..base + width].copy_from_slice(&k[t * width..(t + 1) * width]);
            pv[base..base + width].copy_from_slice(&v[t * width..(t + 1) * width]);
        }
    }

    #[test]
    fn matches_contiguous_reference_bitwise() {
        let s = AttnShape::new(16, 2, 4);
        let width = s.proj_width();
        let (block_size, num_blocks) = (4usize, 8usize);
        // Two sequences of different lengths, scattered over shuffled blocks.
        let lens = [6usize, 3];
        let tables: [&[usize]; 2] = [&[5, 2], &[7]];
        let q = Tensor::randn(&[2, width], 1.0, 3);
        let k1 = Tensor::randn(&[lens[0], width], 1.0, 4);
        let v1 = Tensor::randn(&[lens[0], width], 1.0, 5);
        let k2 = Tensor::randn(&[lens[1], width], 1.0, 6);
        let v2 = Tensor::randn(&[lens[1], width], 1.0, 7);

        let mut pk = vec![0.0f32; num_blocks * block_size * width];
        let mut pv = vec![0.0f32; num_blocks * block_size * width];
        scatter(&mut pk, &mut pv, &k1.data, &v1.data, lens[0], width, block_size, tables[0]);
        scatter(&mut pk, &mut pv, &k2.data, &v2.data, lens[1], width, block_size, tables[1]);

        let layer = PagedLayerView { k: &pk, v: &pv, block_size, width };
        let seqs = [
            PagedSeq { blocks: tables[0], len: lens[0] },
            PagedSeq { blocks: tables[1], len: lens[1] },
        ];
        let out = paged_attention_decode(&q, &layer, &seqs, s);

        let r1 = reference_row(q.row(0), &k1.data, &v1.data, lens[0], s);
        let r2 = reference_row(q.row(1), &k2.data, &v2.data, lens[1], s);
        assert_eq!(out.row(0), &r1[..], "seq 0 must be bit-identical");
        assert_eq!(out.row(1), &r2[..], "seq 1 must be bit-identical");
    }

    #[test]
    fn single_token_history_is_identity_weighted() {
        // With one K/V row, softmax weight is exactly 1.0: output == V row.
        let s = AttnShape::new(8, 2, 2);
        let width = s.proj_width();
        let q = Tensor::randn(&[1, width], 1.0, 11);
        let k = Tensor::randn(&[1, width], 1.0, 12);
        let v = Tensor::randn(&[1, width], 1.0, 13);
        let mut pk = vec![0.0f32; 4 * 2 * width];
        let mut pv = vec![0.0f32; 4 * 2 * width];
        scatter(&mut pk, &mut pv, &k.data, &v.data, 1, width, 2, &[3]);
        let layer = PagedLayerView { k: &pk, v: &pv, block_size: 2, width };
        let out = paged_attention_decode(&q, &layer, &[PagedSeq { blocks: &[3], len: 1 }], s);
        assert_eq!(out.data, v.data);
    }

    #[test]
    fn block_table_order_is_respected() {
        // Same K/V rows under two different block layouts give identical
        // results: the table, not block numbering, defines token order.
        let s = AttnShape::new(8, 1, 4);
        let width = s.proj_width();
        let len = 5usize;
        let q = Tensor::randn(&[1, width], 1.0, 21);
        let k = Tensor::randn(&[len, width], 1.0, 22);
        let v = Tensor::randn(&[len, width], 1.0, 23);
        let mut outs = Vec::new();
        for table in [&[0usize, 1][..], &[6, 2][..]] {
            let mut pk = vec![0.0f32; 8 * 4 * width];
            let mut pv = vec![0.0f32; 8 * 4 * width];
            scatter(&mut pk, &mut pv, &k.data, &v.data, len, width, 4, table);
            let layer = PagedLayerView { k: &pk, v: &pv, block_size: 4, width };
            outs.push(paged_attention_decode(
                &q,
                &layer,
                &[PagedSeq { blocks: table, len }],
                s,
            ));
        }
        assert_eq!(outs[0], outs[1]);
        // And both match the contiguous reference.
        let r = reference_row(q.row(0), &k.data, &v.data, len, s);
        assert_eq!(outs[0].row(0), &r[..]);
    }
}
