//! Batched paged attention — the decode- and prefill-time operator of the
//! serving engine ([`crate::engine`]).
//!
//! One call attends every active sequence's query rows against its own K/V
//! history, where histories live in a shared block pool (vLLM-style paged
//! attention) instead of per-sequence contiguous buffers. A sequence
//! contributes [`PagedSeq::q_rows`] consecutive query rows: one for a
//! decode step, N for a prefill chunk — causal masking falls out of the
//! per-row visible length (row `j` of a sequence whose pool holds `len`
//! tokens sees exactly `len - q_rows + j + 1` of them), so prompt chunks
//! and decode steps ride the same kernel in one fused batched call. The
//! block table supplies the indirection; arithmetic is kept *exactly* the
//! same as the contiguous cached path
//! (`model::transformer::attend_cached`) — same dot-product,
//! max-subtraction, and accumulation order — so paged batched decode is
//! bit-identical to per-sequence decode and chunked prefill is
//! bit-identical to monolithic prefill, for both MHA and BDA (the paper's
//! losslessness carried through the serving layer).
//!
//! # The blocked parallel kernel and its bit-exactness contract
//!
//! [`paged_attention_decode`] runs a *blocked* kernel parallelized over
//! independent `(query row, head)` work items on the **persistent parked
//! worker pool** ([`crate::util::threadpool::ThreadPool`]; the process
//! pool sized by `BDA_NUM_THREADS` by default, or an engine-owned pool via
//! [`paged_attention_decode_on`]):
//!
//! * K/V history is walked **per block** over contiguous rows, hoisting the
//!   `block_table[t / block_size]` + `t % block_size` indirection out of
//!   the token loop (one base offset per block instead of a div/mod per
//!   token);
//! * the score buffer is a **per-worker scratch arena** reused across all
//!   work items a worker steals — and, because pool workers are
//!   long-lived, across every layer and decode step of the process —
//!   replacing the per-(head, row) heap allocation of the naive loop;
//! * work items write disjoint `d_h`-wide output slices, so no
//!   synchronization is needed on the output;
//! * 16-bit K/V storage ([`KvSlice::U16`]) is widened to f32 per block run
//!   into a second per-worker scratch; the f32 inner loops are shared with
//!   the zero-copy f32 path, so storage width never changes accumulation
//!   order (engine invariant 7 composes with all of the above).
//!
//! **Invariant (the contract every change here must keep):** within one
//! `(query row, head)` work item, visible tokens are visited in ascending
//! position order and every float operation — dot-product accumulation,
//! running max, `exp`/sum, weighted-V accumulation — happens in exactly the
//! order of the retained serial reference
//! [`paged_attention_decode_serial`]. Work items never share accumulators,
//! and a row's arithmetic never depends on how many sibling rows share its
//! call (a chunk of N rows equals N single-row calls, bit for bit).
//! Therefore the parallel output is bit-identical to the serial reference
//! at *any* worker count — on the shared process pool or a dedicated one —
//! and determinism across `BDA_NUM_THREADS` settings is enforced by tests
//! and CI. The full set of serving-layer invariants (paged == per-sequence
//! decode, parallel == serial, COW fork semantics, chunked == monolithic
//! prefill) is stated in one place in [`crate::engine`].

use super::AttnShape;
use crate::tensor::{DType, Tensor};
use crate::util::threadpool::{self, SendPtr, ThreadPool};
use std::cell::RefCell;

/// One layer's K or V pool storage in its resident representation. `F32`
/// rows are read in place (zero-copy, the historical path); `U16` rows —
/// real 16-bit f16/bf16 words from a 16-bit
/// [`PagedKvPool`](crate::engine::PagedKvPool) — are widened to f32 at the
/// kernel boundary through a per-worker scratch. Widening is exact, so the
/// f32 values the kernel sees are bit-identical to an f32 pool holding
/// quantize-at-write data (engine invariant 7), and the f32 accumulation
/// order downstream is byte-for-byte unchanged.
#[derive(Clone, Copy, Debug)]
pub enum KvSlice<'a> {
    F32(&'a [f32]),
    U16 { bits: &'a [u16], dtype: DType },
}

impl<'a> KvSlice<'a> {
    /// Stored element count (rows × width), dtype-independent.
    pub fn len(&self) -> usize {
        match self {
            KvSlice::F32(d) => d.len(),
            KvSlice::U16 { bits, .. } => bits.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Widening row accessor: the `d_h` f32 values starting at flat element
    /// offset `base`. F32 storage returns the slice in place; 16-bit
    /// storage widens into `buf` (contiguous `u16 → f32` conversion — the
    /// natural on-ramp for an explicit SIMD widening load).
    #[inline]
    pub fn row<'b>(&self, base: usize, d_h: usize, buf: &'b mut Vec<f32>) -> &'b [f32]
    where
        'a: 'b,
    {
        match self {
            KvSlice::F32(d) => &d[base..base + d_h],
            KvSlice::U16 { bits, dtype } => {
                let widen = dtype.widen_u16();
                buf.clear();
                buf.extend(bits[base..base + d_h].iter().map(|&b| widen(b)));
                &buf[..]
            }
        }
    }
}

/// One layer of paged K/V storage: `num_blocks * block_size` rows of
/// `width = n_heads * d_h` values each, for K and V respectively.
#[derive(Clone, Copy, Debug)]
pub struct PagedLayerView<'a> {
    pub k: KvSlice<'a>,
    pub v: KvSlice<'a>,
    /// Tokens per block.
    pub block_size: usize,
    /// Row width (n_heads * d_h).
    pub width: usize,
}

impl<'a> PagedLayerView<'a> {
    /// View over plain f32 storage (the kernel-level tests' and
    /// microbenches' fixture path; engine pools build views via
    /// `PagedKvPool::layer_view`, which picks the storage representation).
    pub fn f32(k: &'a [f32], v: &'a [f32], block_size: usize, width: usize) -> PagedLayerView<'a> {
        PagedLayerView { k: KvSlice::F32(k), v: KvSlice::F32(v), block_size, width }
    }

    /// Flat storage offset of token `t` of a sequence with block table
    /// `blocks`.
    #[inline]
    pub fn row_offset(&self, blocks: &[usize], t: usize) -> usize {
        (blocks[t / self.block_size] * self.block_size + t % self.block_size) * self.width
    }
}

/// One sequence's view for a fused batched step: its block table, its K/V
/// length *including* every token being processed this call (whose K/V
/// rows must already be written to storage), and how many query rows it
/// contributes to the batch.
///
/// A decode step is `q_rows == 1`; a prefill chunk is `q_rows == n` for an
/// `n`-token chunk. Causal masking is positional: the sequence's query row
/// `j` (0-based within its chunk) attends over the first
/// `len - q_rows + j + 1` pool rows, i.e. the resident prefix plus its own
/// position — exactly what `attend_cached` sees with
/// `prior = len - q_rows`.
#[derive(Clone, Copy, Debug)]
pub struct PagedSeq<'a> {
    pub blocks: &'a [usize],
    pub len: usize,
    /// Query rows this sequence contributes to the batched call (≥ 1).
    pub q_rows: usize,
}

thread_local! {
    /// Per-worker score scratch, reused across every work item a worker
    /// processes. Pool workers are persistent, so this arena lives across
    /// layers and decode steps: it grows to the longest history a worker
    /// has seen and is never reallocated on the hot path afterwards.
    static SCORE_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };

    /// Per-worker widening scratch for 16-bit K/V storage: each block run
    /// of u16 rows is widened to f32 here before the (unchanged) f32 inner
    /// loops read it. Same persistence story as `SCORE_SCRATCH`; unused —
    /// never touched, never grown — on f32 pools.
    static WIDEN_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Widen one block run of 16-bit rows into `scratch`: `rows` segments of
/// `d_h` words each, `width` elements apart starting at `base0`, packed
/// contiguously at stride `d_h`. One contiguous `u16 → f32` conversion per
/// row segment — the shape an explicit SIMD widening load would take.
#[inline]
fn widen_run(
    bits: &[u16],
    dtype: DType,
    base0: usize,
    rows: usize,
    width: usize,
    d_h: usize,
    scratch: &mut Vec<f32>,
) {
    let widen = dtype.widen_u16();
    scratch.clear();
    scratch.reserve(rows * d_h);
    for r in 0..rows {
        let seg = &bits[base0 + r * width..base0 + r * width + d_h];
        scratch.extend(seg.iter().map(|&b| widen(b)));
    }
}

/// Validate batch geometry before touching raw storage. These used to be
/// `debug_assert!`s, which release builds skipped even though they guard
/// unchecked slice arithmetic — they are real assertions now.
fn validate(layer: &PagedLayerView, seqs: &[PagedSeq]) {
    let bs = layer.block_size;
    assert!(bs > 0, "paged attention: block_size must be positive");
    for (i, seq) in seqs.iter().enumerate() {
        assert!(seq.len > 0, "paged attention: seq {i} has empty K/V history");
        assert!(seq.q_rows > 0, "paged attention: seq {i} has zero query rows");
        assert!(
            seq.q_rows <= seq.len,
            "paged attention: seq {i} q_rows {} exceeds K/V len {}",
            seq.q_rows,
            seq.len
        );
        assert!(
            seq.len <= seq.blocks.len() * bs,
            "paged attention: seq {i} len {} exceeds block table capacity {}",
            seq.len,
            seq.blocks.len() * bs
        );
        for &blk in &seq.blocks[..seq.len.div_ceil(bs)] {
            assert!(
                (blk + 1) * bs * layer.width <= layer.k.len(),
                "paged attention: seq {i} block {blk} out of K pool bounds"
            );
            assert!(
                (blk + 1) * bs * layer.width <= layer.v.len(),
                "paged attention: seq {i} block {blk} out of V pool bounds"
            );
        }
    }
}

/// Batched paged attention over one layer: sequence `i` contributes
/// `seqs[i].q_rows` consecutive rows of `q` (in batch order), each
/// causally attending over its visible prefix of the sequence's K/V rows,
/// gathered through the block table. Returns the concatenated per-head
/// outputs (`sum(q_rows)` × width), ready for the output projection.
///
/// Runs the blocked kernel in parallel over `(query row, head)` work items
/// on the process-wide parked pool with up to `BDA_NUM_THREADS` workers;
/// output is bit-identical to [`paged_attention_decode_serial`] at any
/// worker count (see module docs).
pub fn paged_attention_decode(
    q: &Tensor,
    layer: &PagedLayerView,
    seqs: &[PagedSeq],
    s: AttnShape,
) -> Tensor {
    paged_attention_decode_with_workers(q, layer, seqs, s, threadpool::num_threads())
}

/// [`paged_attention_decode`] with an explicit worker count (determinism
/// tests sweep this; serving uses the `BDA_NUM_THREADS` default). A count
/// above the process pool's width runs on a transient dedicated pool so
/// the requested parallelism is real even when `BDA_NUM_THREADS` latched
/// the process pool small (e.g. the 1-thread CI determinism leg still
/// exercises genuinely 2- and 8-wide kernels here).
pub fn paged_attention_decode_with_workers(
    q: &Tensor,
    layer: &PagedLayerView,
    seqs: &[PagedSeq],
    s: AttnShape,
    workers: usize,
) -> Tensor {
    let process = threadpool::global();
    if workers > process.workers() {
        let dedicated = ThreadPool::new(workers);
        return paged_attention_decode_on(&dedicated, q, layer, seqs, s, workers);
    }
    paged_attention_decode_on(process, q, layer, seqs, s, workers)
}

/// [`paged_attention_decode`] on an explicit [`ThreadPool`] — the entry
/// point the serving engine uses so one engine owns one pool
/// (`PagedNativeBackend::with_thread_pool`), groundwork for multi-worker
/// sharding. `workers` is capped at the pool width; output is
/// bit-identical to the serial reference on any pool at any width.
pub fn paged_attention_decode_on(
    pool: &ThreadPool,
    q: &Tensor,
    layer: &PagedLayerView,
    seqs: &[PagedSeq],
    s: AttnShape,
    workers: usize,
) -> Tensor {
    let total_rows: usize = seqs.iter().map(|seq| seq.q_rows).sum();
    assert_eq!(q.rows(), total_rows, "query rows must equal the summed per-seq q_rows");
    let width = s.proj_width();
    assert_eq!(q.cols(), width, "query width mismatch");
    assert_eq!(layer.width, width, "storage width mismatch");
    validate(layer, seqs);

    // (sequence index, visible K/V length) per global query row, in batch
    // order — the only per-row state the work items need.
    let mut rows: Vec<(usize, usize)> = Vec::with_capacity(total_rows);
    for (i, seq) in seqs.iter().enumerate() {
        for j in 0..seq.q_rows {
            rows.push((i, seq.len - seq.q_rows + j + 1));
        }
    }

    let scale = 1.0 / (s.d_h as f32).sqrt();
    let n_heads = s.n_heads;
    let d_h = s.d_h;
    let mut out = Tensor::zeros(&[total_rows, width]);
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    let qd = &q.data;
    pool.run(total_rows * n_heads, workers, |w| {
        let r = w / n_heads;
        let h = w % n_heads;
        let (i, visible) = rows[r];
        let off = h * d_h;
        let qrow = &qd[r * width + off..r * width + off + d_h];
        // SAFETY: work item (r, h) writes only out[r*width+off .. +d_h];
        // these d_h-wide regions are pairwise disjoint across work items.
        let orow =
            unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(r * width + off), d_h) };
        SCORE_SCRATCH.with(|cell| {
            let mut scores = cell.borrow_mut();
            WIDEN_SCRATCH.with(|wcell| {
                let mut widen = wcell.borrow_mut();
                attend_head_blocked(
                    qrow, layer, &seqs[i], visible, off, d_h, scale, &mut scores, &mut widen, orow,
                );
            });
        });
    });
    out
}

/// One `(query row, head)` work item of the blocked kernel: walk the
/// row's `visible`-token causal prefix block by block (contiguous rows
/// within a block), scoring into the per-worker scratch, then softmax +
/// weighted-V accumulate in the same ascending-token order as the serial
/// reference. `orow` must be zeroed.
///
/// Each block run is resolved to a `(buf, base, stride)` triple once:
/// f32 storage yields the pool slice in place (`stride = width`, the
/// historical zero-copy path); 16-bit storage widens the run's `d_h`-wide
/// row segments into `widen` (`stride = d_h`). The f32 inner loops below
/// the match are shared verbatim, so accumulation order — and therefore
/// parallel == serial bit-exactness — is identical at every storage width.
#[allow(clippy::too_many_arguments)]
fn attend_head_blocked(
    qrow: &[f32],
    layer: &PagedLayerView,
    seq: &PagedSeq,
    visible: usize,
    off: usize,
    d_h: usize,
    scale: f32,
    scores: &mut Vec<f32>,
    widen: &mut Vec<f32>,
    orow: &mut [f32],
) {
    let bs = layer.block_size;
    let width = layer.width;
    scores.clear();
    scores.reserve(visible);

    // Pass 1: scores, one contiguous row run per block.
    let mut done = 0usize;
    for &blk in seq.blocks {
        if done == visible {
            break;
        }
        let rows = bs.min(visible - done);
        let base0 = blk * bs * width + off;
        let (buf, base, stride): (&[f32], usize, usize) = match layer.k {
            KvSlice::F32(data) => (data, base0, width),
            KvSlice::U16 { bits, dtype } => {
                widen_run(bits, dtype, base0, rows, width, d_h, widen);
                (&widen[..], 0, d_h)
            }
        };
        for r in 0..rows {
            let krow = &buf[base + r * stride..base + r * stride + d_h];
            scores.push(qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale);
        }
        done += rows;
    }

    // Softmax in ascending-token order (identical to the serial reference).
    let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in scores.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;

    // Pass 2: weighted V accumulation, same block walk, same token order.
    let mut done = 0usize;
    for &blk in seq.blocks {
        if done == visible {
            break;
        }
        let rows = bs.min(visible - done);
        let base0 = blk * bs * width + off;
        let (buf, base, stride): (&[f32], usize, usize) = match layer.v {
            KvSlice::F32(data) => (data, base0, width),
            KvSlice::U16 { bits, dtype } => {
                widen_run(bits, dtype, base0, rows, width, d_h, widen);
                (&widen[..], 0, d_h)
            }
        };
        for r in 0..rows {
            let w = scores[done + r] * inv;
            let vrow = &buf[base + r * stride..base + r * stride + d_h];
            for (o, vv) in orow.iter_mut().zip(vrow) {
                *o += w * vv;
            }
        }
        done += rows;
    }
}

/// The retained serial reference: the original single-threaded,
/// token-at-a-time kernel (per-token block indirection, per-(head, row)
/// score buffer). This is the bit-exactness contract for the blocked
/// parallel kernel — property tests assert `paged_attention_decode` equals
/// this function exactly — and the baseline the decode-throughput
/// microbenchmark measures speedups against.
pub fn paged_attention_decode_serial(
    q: &Tensor,
    layer: &PagedLayerView,
    seqs: &[PagedSeq],
    s: AttnShape,
) -> Tensor {
    let total_rows: usize = seqs.iter().map(|seq| seq.q_rows).sum();
    assert_eq!(q.rows(), total_rows, "query rows must equal the summed per-seq q_rows");
    let width = s.proj_width();
    assert_eq!(q.cols(), width, "query width mismatch");
    assert_eq!(layer.width, width, "storage width mismatch");
    validate(layer, seqs);
    let scale = 1.0 / (s.d_h as f32).sqrt();
    let mut out = Tensor::zeros(&[total_rows, width]);
    // Per-token widening buffer for 16-bit storage (no-op for f32: the
    // accessor returns pool rows in place).
    let mut wbuf: Vec<f32> = Vec::new();
    for h in 0..s.n_heads {
        let off = h * s.d_h;
        let mut r = 0usize;
        for seq in seqs {
            for j in 0..seq.q_rows {
                let visible = seq.len - seq.q_rows + j + 1;
                let qrow = &q.data[r * width + off..r * width + off + s.d_h];
                let mut scores = vec![0.0f32; visible];
                for (t, sc) in scores.iter_mut().enumerate() {
                    let base = layer.row_offset(seq.blocks, t) + off;
                    let krow = layer.k.row(base, s.d_h, &mut wbuf);
                    *sc = qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
                }
                let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut sum = 0.0;
                for v in scores.iter_mut() {
                    *v = (*v - max).exp();
                    sum += *v;
                }
                let inv = 1.0 / sum;
                let orow = &mut out.data[r * width + off..r * width + off + s.d_h];
                for (t, sc) in scores.iter().enumerate() {
                    let w = sc * inv;
                    let base = layer.row_offset(seq.blocks, t) + off;
                    let vrow = layer.v.row(base, s.d_h, &mut wbuf);
                    for (o, vv) in orow.iter_mut().zip(vrow) {
                        *o += w * vv;
                    }
                }
                r += 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference: contiguous single-sequence attention over cached K/V for
    /// one query row (mirrors `attend_cached` with prior = len - 1).
    fn reference_row(q: &[f32], k: &[f32], v: &[f32], len: usize, s: AttnShape) -> Vec<f32> {
        let width = s.proj_width();
        let scale = 1.0 / (s.d_h as f32).sqrt();
        let mut out = vec![0.0f32; width];
        for h in 0..s.n_heads {
            let off = h * s.d_h;
            let qrow = &q[off..off + s.d_h];
            let mut scores = vec![0.0f32; len];
            for t in 0..len {
                let krow = &k[t * width + off..t * width + off + s.d_h];
                scores[t] = qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for sv in scores.iter_mut() {
                *sv = (*sv - max).exp();
                sum += *sv;
            }
            let inv = 1.0 / sum;
            for t in 0..len {
                let w = scores[t] * inv;
                let vrow = &v[t * width + off..t * width + off + s.d_h];
                for (o, vv) in out[off..off + s.d_h].iter_mut().zip(vrow) {
                    *o += w * vv;
                }
            }
        }
        out
    }

    use crate::bench_support::scatter_paged_kv as scatter;

    #[test]
    fn matches_contiguous_reference_bitwise() {
        let s = AttnShape::new(16, 2, 4);
        let width = s.proj_width();
        let (block_size, num_blocks) = (4usize, 8usize);
        // Two sequences of different lengths, scattered over shuffled blocks.
        let lens = [6usize, 3];
        let tables: [&[usize]; 2] = [&[5, 2], &[7]];
        let q = Tensor::randn(&[2, width], 1.0, 3);
        let k1 = Tensor::randn(&[lens[0], width], 1.0, 4);
        let v1 = Tensor::randn(&[lens[0], width], 1.0, 5);
        let k2 = Tensor::randn(&[lens[1], width], 1.0, 6);
        let v2 = Tensor::randn(&[lens[1], width], 1.0, 7);

        let mut pk = vec![0.0f32; num_blocks * block_size * width];
        let mut pv = vec![0.0f32; num_blocks * block_size * width];
        scatter(&mut pk, &mut pv, &k1.data, &v1.data, lens[0], width, block_size, tables[0]);
        scatter(&mut pk, &mut pv, &k2.data, &v2.data, lens[1], width, block_size, tables[1]);

        let layer = PagedLayerView::f32(&pk, &pv, block_size, width);
        let seqs = [
            PagedSeq { blocks: tables[0], len: lens[0], q_rows: 1 },
            PagedSeq { blocks: tables[1], len: lens[1], q_rows: 1 },
        ];
        let out = paged_attention_decode(&q, &layer, &seqs, s);

        let r1 = reference_row(q.row(0), &k1.data, &v1.data, lens[0], s);
        let r2 = reference_row(q.row(1), &k2.data, &v2.data, lens[1], s);
        assert_eq!(out.row(0), &r1[..], "seq 0 must be bit-identical");
        assert_eq!(out.row(1), &r2[..], "seq 1 must be bit-identical");
        // The serial reference agrees too, bit for bit.
        let serial = paged_attention_decode_serial(&q, &layer, &seqs, s);
        assert_eq!(out, serial);
    }

    #[test]
    fn single_token_history_is_identity_weighted() {
        // With one K/V row, softmax weight is exactly 1.0: output == V row.
        let s = AttnShape::new(8, 2, 2);
        let width = s.proj_width();
        let q = Tensor::randn(&[1, width], 1.0, 11);
        let k = Tensor::randn(&[1, width], 1.0, 12);
        let v = Tensor::randn(&[1, width], 1.0, 13);
        let mut pk = vec![0.0f32; 4 * 2 * width];
        let mut pv = vec![0.0f32; 4 * 2 * width];
        scatter(&mut pk, &mut pv, &k.data, &v.data, 1, width, 2, &[3]);
        let layer = PagedLayerView::f32(&pk, &pv, 2, width);
        let seqs = [PagedSeq { blocks: &[3], len: 1, q_rows: 1 }];
        let out = paged_attention_decode(&q, &layer, &seqs, s);
        assert_eq!(out.data, v.data);
    }

    #[test]
    fn block_table_order_is_respected() {
        // Same K/V rows under two different block layouts give identical
        // results: the table, not block numbering, defines token order.
        let s = AttnShape::new(8, 1, 4);
        let width = s.proj_width();
        let len = 5usize;
        let q = Tensor::randn(&[1, width], 1.0, 21);
        let k = Tensor::randn(&[len, width], 1.0, 22);
        let v = Tensor::randn(&[len, width], 1.0, 23);
        let mut outs = Vec::new();
        for table in [&[0usize, 1][..], &[6, 2][..]] {
            let mut pk = vec![0.0f32; 8 * 4 * width];
            let mut pv = vec![0.0f32; 8 * 4 * width];
            scatter(&mut pk, &mut pv, &k.data, &v.data, len, width, 4, table);
            let layer = PagedLayerView::f32(&pk, &pv, 4, width);
            outs.push(paged_attention_decode(
                &q,
                &layer,
                &[PagedSeq { blocks: table, len, q_rows: 1 }],
                s,
            ));
        }
        assert_eq!(outs[0], outs[1]);
        // And both match the contiguous reference.
        let r = reference_row(q.row(0), &k.data, &v.data, len, s);
        assert_eq!(outs[0].row(0), &r[..]);
    }

    #[test]
    fn parallel_matches_serial_at_every_worker_count() {
        // Uneven lengths + partial tail blocks, swept over worker counts.
        let s = AttnShape::new(24, 3, 8);
        let width = s.proj_width();
        let (block_size, num_blocks) = (4usize, 16usize);
        let lens = [1usize, 7, 12, 4];
        let tables: [&[usize]; 4] = [&[9], &[3, 11], &[0, 5, 14], &[7]];
        let q = Tensor::randn(&[4, width], 1.0, 31);
        let mut pk = vec![0.0f32; num_blocks * block_size * width];
        let mut pv = vec![0.0f32; num_blocks * block_size * width];
        for (i, (&len, table)) in lens.iter().zip(tables.iter()).enumerate() {
            let k = Tensor::randn(&[len, width], 1.0, 40 + i as u64);
            let v = Tensor::randn(&[len, width], 1.0, 50 + i as u64);
            scatter(&mut pk, &mut pv, &k.data, &v.data, len, width, block_size, table);
        }
        let layer = PagedLayerView::f32(&pk, &pv, block_size, width);
        let seqs: Vec<PagedSeq> = lens
            .iter()
            .zip(tables.iter())
            .map(|(&len, &blocks)| PagedSeq { blocks, len, q_rows: 1 })
            .collect();
        let serial = paged_attention_decode_serial(&q, &layer, &seqs, s);
        for workers in [1, 2, 8] {
            let par = paged_attention_decode_with_workers(&q, &layer, &seqs, s, workers);
            assert_eq!(par, serial, "workers {workers} must be bit-identical to serial");
        }
    }

    #[test]
    fn multi_row_chunk_matches_single_row_sweep() {
        // A chunk of N query rows must equal N single-row calls bit for
        // bit: row j sees exactly the first j+1 tokens (causal), and its
        // arithmetic is independent of how many sibling rows share the
        // call. This is the kernel-level statement of invariant 6
        // (chunked prefill == monolithic prefill).
        let s = AttnShape::new(16, 2, 4);
        let width = s.proj_width();
        let (block_size, len) = (4usize, 7usize);
        let table: &[usize] = &[2, 0];
        let q = Tensor::randn(&[len, width], 1.0, 61);
        let k = Tensor::randn(&[len, width], 1.0, 62);
        let v = Tensor::randn(&[len, width], 1.0, 63);
        let mut pk = vec![0.0f32; 4 * block_size * width];
        let mut pv = vec![0.0f32; 4 * block_size * width];
        scatter(&mut pk, &mut pv, &k.data, &v.data, len, width, block_size, table);
        let layer = PagedLayerView::f32(&pk, &pv, block_size, width);

        let chunk =
            paged_attention_decode(&q, &layer, &[PagedSeq { blocks: table, len, q_rows: len }], s);
        for r in 0..len {
            let qr = q.slice_rows(r, r + 1);
            let single = paged_attention_decode(
                &qr,
                &layer,
                &[PagedSeq { blocks: table, len: r + 1, q_rows: 1 }],
                s,
            );
            assert_eq!(chunk.row(r), single.row(0), "row {r} must match its single-row call");
            let refr = reference_row(q.row(r), &k.data, &v.data, r + 1, s);
            assert_eq!(chunk.row(r), &refr[..], "row {r} must match the contiguous reference");
        }
    }

    #[test]
    fn mixed_decode_and_chunk_rows_parallel_matches_serial() {
        // A fused batch of decode rows (q_rows = 1) and prefill chunks
        // (q_rows > 1, including a chunk with resident prior context) must
        // be bit-identical to the serial reference at every worker count.
        let s = AttnShape::new(24, 3, 8);
        let width = s.proj_width();
        let (block_size, num_blocks) = (4usize, 16usize);
        let lens = [5usize, 9, 1, 8];
        let q_rows = [1usize, 9, 1, 3]; // decode, whole-prompt chunk, decode, tail chunk
        let tables: [&[usize]; 4] = [&[9, 1], &[3, 11, 6], &[0], &[7, 12]];
        let total: usize = q_rows.iter().sum();
        let q = Tensor::randn(&[total, width], 1.0, 71);
        let mut pk = vec![0.0f32; num_blocks * block_size * width];
        let mut pv = vec![0.0f32; num_blocks * block_size * width];
        for (i, (&len, table)) in lens.iter().zip(tables.iter()).enumerate() {
            let k = Tensor::randn(&[len, width], 1.0, 80 + i as u64);
            let v = Tensor::randn(&[len, width], 1.0, 90 + i as u64);
            scatter(&mut pk, &mut pv, &k.data, &v.data, len, width, block_size, table);
        }
        let layer = PagedLayerView::f32(&pk, &pv, block_size, width);
        let seqs: Vec<PagedSeq> = lens
            .iter()
            .zip(q_rows.iter())
            .zip(tables.iter())
            .map(|((&len, &q_rows), &blocks)| PagedSeq { blocks, len, q_rows })
            .collect();
        let serial = paged_attention_decode_serial(&q, &layer, &seqs, s);
        for workers in [1, 2, 8] {
            let par = paged_attention_decode_with_workers(&q, &layer, &seqs, s, workers);
            assert_eq!(par, serial, "workers {workers} must be bit-identical to serial");
        }
    }

    #[test]
    fn u16_storage_matches_quantized_f32_storage_bitwise() {
        // Invariant 7 at kernel level: a u16 view over narrowed bits must
        // produce the same output — parallel at every worker count AND
        // serial — as an f32 view holding the quantized values, bit for
        // bit, because widening a 16-bit word is exact and the f32
        // accumulation order is shared between both storage paths.
        let s = AttnShape::new(24, 3, 8);
        let width = s.proj_width();
        let (block_size, num_blocks) = (4usize, 16usize);
        let lens = [1usize, 7, 12, 4];
        let q_rows = [1usize, 3, 1, 4];
        let tables: [&[usize]; 4] = [&[9], &[3, 11], &[0, 5, 14], &[7]];
        let total: usize = q_rows.iter().sum();
        let q = Tensor::randn(&[total, width], 1.0, 131);
        let mut pk = vec![0.0f32; num_blocks * block_size * width];
        let mut pv = vec![0.0f32; num_blocks * block_size * width];
        for (i, (&len, table)) in lens.iter().zip(tables.iter()).enumerate() {
            let k = Tensor::randn(&[len, width], 1.0, 140 + i as u64);
            let v = Tensor::randn(&[len, width], 1.0, 150 + i as u64);
            scatter(&mut pk, &mut pv, &k.data, &v.data, len, width, block_size, table);
        }
        let seqs: Vec<PagedSeq> = lens
            .iter()
            .zip(q_rows.iter())
            .zip(tables.iter())
            .map(|((&len, &q_rows), &blocks)| PagedSeq { blocks, len, q_rows })
            .collect();
        for dtype in [DType::F16, DType::BF16] {
            let narrow = dtype.narrow_f32();
            let bk: Vec<u16> = pk.iter().map(|&x| narrow(x)).collect();
            let bv: Vec<u16> = pv.iter().map(|&x| narrow(x)).collect();
            let mut qk = pk.clone();
            let mut qv = pv.clone();
            dtype.quantize_slice(&mut qk);
            dtype.quantize_slice(&mut qv);
            let f32_layer = PagedLayerView::f32(&qk, &qv, block_size, width);
            let u16_layer = PagedLayerView {
                k: KvSlice::U16 { bits: &bk, dtype },
                v: KvSlice::U16 { bits: &bv, dtype },
                block_size,
                width,
            };
            let want = paged_attention_decode_serial(&q, &f32_layer, &seqs, s);
            let serial = paged_attention_decode_serial(&q, &u16_layer, &seqs, s);
            assert_eq!(serial, want, "{dtype} serial must match quantized-f32 storage");
            for workers in [1, 2, 8] {
                let par = paged_attention_decode_with_workers(&q, &u16_layer, &seqs, s, workers);
                assert_eq!(par, want, "{dtype} workers {workers} must be bit-identical");
            }
        }
    }

    #[test]
    #[should_panic(expected = "zero query rows")]
    fn zero_query_rows_rejected() {
        let s = AttnShape::new(8, 1, 4);
        let width = s.proj_width();
        let pk = vec![0.0f32; 4 * 2 * width];
        let pv = pk.clone();
        let layer = PagedLayerView::f32(&pk, &pv, 2, width);
        let q = Tensor::zeros(&[0, width]);
        let seqs = [PagedSeq { blocks: &[0], len: 1, q_rows: 0 }];
        let _ = paged_attention_decode(&q, &layer, &seqs, s);
    }

    #[test]
    #[should_panic(expected = "exceeds K/V len")]
    fn q_rows_exceeding_len_rejected() {
        let s = AttnShape::new(8, 1, 4);
        let width = s.proj_width();
        let pk = vec![0.0f32; 4 * 2 * width];
        let pv = pk.clone();
        let layer = PagedLayerView::f32(&pk, &pv, 2, width);
        let q = Tensor::zeros(&[2, width]);
        let seqs = [PagedSeq { blocks: &[0], len: 1, q_rows: 2 }];
        let _ = paged_attention_decode(&q, &layer, &seqs, s);
    }

    #[test]
    #[should_panic(expected = "empty K/V history")]
    fn empty_history_rejected_in_release_builds() {
        let s = AttnShape::new(8, 1, 4);
        let width = s.proj_width();
        let pk = vec![0.0f32; 4 * 2 * width];
        let pv = pk.clone();
        let layer = PagedLayerView::f32(&pk, &pv, 2, width);
        let q = Tensor::zeros(&[1, width]);
        let seqs = [PagedSeq { blocks: &[0], len: 0, q_rows: 1 }];
        let _ = paged_attention_decode(&q, &layer, &seqs, s);
    }

    #[test]
    #[should_panic(expected = "exceeds block table capacity")]
    fn len_exceeding_block_table_rejected() {
        let s = AttnShape::new(8, 1, 4);
        let width = s.proj_width();
        let pk = vec![0.0f32; 4 * 2 * width];
        let pv = pk.clone();
        let layer = PagedLayerView::f32(&pk, &pv, 2, width);
        let q = Tensor::zeros(&[1, width]);
        let seqs = [PagedSeq { blocks: &[0], len: 3, q_rows: 1 }];
        let _ = paged_attention_decode(&q, &layer, &seqs, s);
    }

    #[test]
    #[should_panic(expected = "out of K pool bounds")]
    fn out_of_pool_block_rejected() {
        let s = AttnShape::new(8, 1, 4);
        let width = s.proj_width();
        let pk = vec![0.0f32; 4 * 2 * width]; // pool holds blocks 0..4
        let pv = pk.clone();
        let layer = PagedLayerView::f32(&pk, &pv, 2, width);
        let q = Tensor::zeros(&[1, width]);
        let seqs = [PagedSeq { blocks: &[9], len: 1, q_rows: 1 }];
        let _ = paged_attention_decode(&q, &layer, &seqs, s);
    }
}
