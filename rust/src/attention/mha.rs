//! Standard multi-head attention — Algorithm 1 of the paper. This is the
//! exact-output reference that BDA must match bit-for-bit up to float
//! reassociation.

use super::{split_heads, AttnShape};
use crate::tensor::matmul::matmul;
use crate::tensor::Tensor;

/// MHA projection weights.
#[derive(Clone, Debug)]
pub struct MhaWeights {
    pub shape: AttnShape,
    /// d × n·d_h
    pub wq: Tensor,
    /// d × n·d_h
    pub wk: Tensor,
    /// d × n·d_h
    pub wv: Tensor,
    /// n·d_h × d
    pub wo: Tensor,
}

impl MhaWeights {
    /// Deterministic random init (std ≈ GPT-2 style 0.02·scale).
    pub fn random(shape: AttnShape, seed: u64) -> MhaWeights {
        let w = shape.proj_width();
        let std = 0.02;
        MhaWeights {
            shape,
            wq: Tensor::randn(&[shape.d, w], std, seed),
            wk: Tensor::randn(&[shape.d, w], std, seed + 1),
            wv: Tensor::randn(&[shape.d, w], std, seed + 2),
            wo: Tensor::randn(&[w, shape.d], std, seed + 3),
        }
    }

    /// Per-head vertical slice of W_q (d × d_h).
    pub fn wq_head(&self, i: usize) -> Tensor {
        self.wq.slice_cols(i * self.shape.d_h, (i + 1) * self.shape.d_h)
    }

    pub fn wk_head(&self, i: usize) -> Tensor {
        self.wk.slice_cols(i * self.shape.d_h, (i + 1) * self.shape.d_h)
    }

    pub fn wv_head(&self, i: usize) -> Tensor {
        self.wv.slice_cols(i * self.shape.d_h, (i + 1) * self.shape.d_h)
    }

    /// Per-head horizontal slice of W_o (d_h × d).
    pub fn wo_head(&self, i: usize) -> Tensor {
        self.wo.slice_rows(i * self.shape.d_h, (i + 1) * self.shape.d_h)
    }

    /// Total parameter count of the four projections.
    pub fn param_count(&self) -> usize {
        self.wq.numel() + self.wk.numel() + self.wv.numel() + self.wo.numel()
    }
}

/// Full MHA forward (Algorithm 1). `causal` applies the decoder mask.
pub fn mha_forward(w: &MhaWeights, x: &Tensor, causal: bool) -> Tensor {
    let s = w.shape;
    assert_eq!(x.cols(), s.d, "input dim mismatch");
    let q = matmul(x, &w.wq);
    let k = matmul(x, &w.wk);
    let v = matmul(x, &w.wv);
    attention_core(&q, &k, &v, &w.wo, s, causal)
}

/// Shared attention core: per-head softmax(Q_i K_i^T / √d_h) V_i, concat,
/// output projection. Used by MHA, BDA, and PIFA paths so the only
/// difference between them is how Q/K/V are produced — exactly the paper's
/// framing (Algorithms 1 vs 2 differ only in K/V computation).
pub fn attention_core(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    wo: &Tensor,
    s: AttnShape,
    causal: bool,
) -> Tensor {
    let scale = 1.0 / (s.d_h as f32).sqrt();
    let qs = split_heads(q, s.n_heads);
    let ks = split_heads(k, s.n_heads);
    let vs = split_heads(v, s.n_heads);
    let mut outs = Vec::with_capacity(s.n_heads);
    for i in 0..s.n_heads {
        let scores = matmul(&qs[i], &ks[i].transpose()).scale(scale);
        let probs = if causal {
            scores.softmax_rows_causal(0)
        } else {
            scores.softmax_rows()
        };
        outs.push(matmul(&probs, &vs[i]));
    }
    let refs: Vec<&Tensor> = outs.iter().collect();
    let concat = Tensor::concat_cols(&refs);
    matmul(&concat, wo)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_shape() {
        let s = AttnShape::new(32, 4, 8);
        let w = MhaWeights::random(s, 1);
        let x = Tensor::randn(&[5, 32], 1.0, 2);
        let y = mha_forward(&w, &x, false);
        assert_eq!(y.shape, vec![5, 32]);
    }

    #[test]
    fn causal_prefix_property() {
        // With a causal mask, output at position t depends only on x[..=t]:
        // truncating the input must not change earlier outputs.
        let s = AttnShape::new(16, 2, 8);
        let w = MhaWeights::random(s, 3);
        let x = Tensor::randn(&[6, 16], 1.0, 4);
        let y_full = mha_forward(&w, &x, true);
        let y_trunc = mha_forward(&w, &x.slice_rows(0, 4), true);
        let y_full_head = y_full.slice_rows(0, 4);
        assert!(y_full_head.max_abs_diff(&y_trunc) < 1e-5);
    }

    #[test]
    fn noncausal_sees_future() {
        let s = AttnShape::new(16, 2, 8);
        let w = MhaWeights::random(s, 5);
        let x = Tensor::randn(&[6, 16], 1.0, 6);
        let y_full = mha_forward(&w, &x, false);
        let y_trunc = mha_forward(&w, &x.slice_rows(0, 4), false);
        let y_full_head = y_full.slice_rows(0, 4);
        assert!(y_full_head.max_abs_diff(&y_trunc) > 1e-4);
    }

    #[test]
    fn head_slices_partition_weights() {
        let s = AttnShape::new(8, 2, 4);
        let w = MhaWeights::random(s, 7);
        let q0 = w.wq_head(0);
        let q1 = w.wq_head(1);
        assert_eq!(Tensor::concat_cols(&[&q0, &q1]), w.wq);
        let o0 = w.wo_head(0);
        let o1 = w.wo_head(1);
        assert_eq!(Tensor::concat_rows(&[&o0, &o1]), w.wo);
    }

    #[test]
    fn param_count() {
        let s = AttnShape::new(8, 2, 4);
        let w = MhaWeights::random(s, 8);
        assert_eq!(w.param_count(), 3 * 8 * 8 + 8 * 8);
    }

    #[test]
    fn equivalent_to_reformulated_sum() {
        // Eq. 10: Y = sum_i softmax(X (Wq_i Wk_i^T) X^T / sqrt(dh)) X (Wv_i Wo_i)
        let s = AttnShape::new(12, 3, 4);
        let w = MhaWeights::random(s, 9);
        let x = Tensor::randn(&[5, 12], 1.0, 10);
        let y = mha_forward(&w, &x, false);

        let scale = 1.0 / (s.d_h as f32).sqrt();
        let mut y2 = Tensor::zeros(&[5, 12]);
        for i in 0..s.n_heads {
            let wqk = matmul(&w.wq_head(i), &w.wk_head(i).transpose());
            let scores = matmul(&matmul(&x, &wqk), &x.transpose()).scale(scale);
            let probs = scores.softmax_rows();
            let wvo = matmul(&w.wv_head(i), &w.wo_head(i));
            y2.add_assign(&matmul(&probs, &matmul(&x, &wvo)));
        }
        assert!(y.max_abs_diff(&y2) < 1e-4, "diff {}", y.max_abs_diff(&y2));
    }
}
