//! BD Attention — Algorithms 2 & 3 of the paper.
//!
//! Offline preparation (Alg. 3): per head, column-BD of `W_q^i (W_k^i)^T`
//! and row-BD of `W_v^i W_o^i` at rank `d_h`, with all heads *aligned* to a
//! shared first-r/last-r tag (chosen by mean residual) so inference can use
//! one shared slice of X and coalesced GEMMs (Eq. 12 / Eq. 14).
//!
//! Inference (Alg. 2):
//! ```text
//! Q' = X B_qk
//! K' = [X_basis]^{×n} + X_rest C_qk
//! V' = [X_basis]^{×n} + X_rest C_vo
//! O'_i = softmax(Q'_i K'_i^T / √d_h) V'_i ;  Y = [O'_1..O'_n] B_vo
//! ```
//! Outputs equal MHA's exactly (up to float rounding): every per-head
//! QK inner product and every V·W_o product is preserved.

use super::mha::{attention_core, MhaWeights};
use super::{AttnShape, kproj};
use crate::bd::{bd_col, bd_row, BdError, Strategy, Tag};
use crate::tensor::matmul::matmul;
use crate::tensor::{DType, Tensor};

/// Per-projection residual statistics gathered during preparation
/// (Table 4's MSE/NMSE and Algorithm 3's mean-residual tag selection).
#[derive(Clone, Debug, Default)]
pub struct PrepStats {
    /// Per-head Frobenius residuals of the first-r candidate.
    pub residual_first: Vec<f64>,
    /// Per-head Frobenius residuals of the last-r candidate.
    pub residual_last: Vec<f64>,
    /// Per-head MSE of the selected candidate's reconstruction vs the
    /// (quantized) head product.
    pub mse: Vec<f64>,
    /// Per-head NMSE of the selected candidate.
    pub nmse: Vec<f64>,
}

impl PrepStats {
    pub fn mean_mse(&self) -> f64 {
        mean(&self.mse)
    }
    pub fn mean_nmse(&self) -> f64 {
        mean(&self.nmse)
    }
    pub fn mean_residual_first(&self) -> f64 {
        mean(&self.residual_first)
    }
    pub fn mean_residual_last(&self) -> f64 {
        mean(&self.residual_last)
    }
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// BDA weights for one attention block (Algorithm 2 inputs).
#[derive(Clone, Debug)]
pub struct BdaWeights {
    pub shape: AttnShape,
    /// Shared basis tag for all QK heads.
    pub tag_qk: Tag,
    /// Shared basis tag for all VO heads.
    pub tag_vo: Tag,
    /// d × n·d_h — per-head column bases `[B_qk^1 … B_qk^n]` (replaces W_q).
    pub b_qk: Tensor,
    /// (d−d_h) × n·d_h — `[C_qk^{1T} … C_qk^{nT}]` (replaces W_k).
    pub c_qk: Tensor,
    /// (d−d_h) × n·d_h — `[C_vo^1 … C_vo^n]` (replaces W_v).
    pub c_vo: Tensor,
    /// n·d_h × d — stacked row bases (replaces W_o).
    pub b_vo: Tensor,
    /// Residual stats from preparation.
    pub qk_stats: PrepStats,
    pub vo_stats: PrepStats,
}

/// Full BDA attention block.
#[derive(Clone, Debug)]
pub struct BdaAttention {
    pub weights: BdaWeights,
}

impl BdaWeights {
    /// Offline BDA preparation (Algorithm 3) from MHA weights.
    ///
    /// `dtype` simulates the precision the paper prepares in (Fig. 2a /
    /// Tables 4–5 sweep FP32/FP16/BF16): weights and products are rounded
    /// through it; the residual comparison and error stats are measured in
    /// that precision. `strategy` picks First-r vs Residual-min.
    pub fn prepare(mha: &MhaWeights, strategy: Strategy, dtype: DType) -> Result<BdaWeights, BdError> {
        let s = mha.shape;
        let (d, n, d_h) = (s.d, s.n_heads, s.d_h);
        let _ = d;

        // ---- QK: column-BD of each head product ---------------------------
        let mut qk_first = Vec::with_capacity(n);
        let mut qk_last = Vec::with_capacity(n);
        let mut qk_stats = PrepStats::default();
        let mut qk_products = Vec::with_capacity(n);
        for i in 0..n {
            let wq_i = quant(&mha.wq_head(i), dtype);
            let wk_i = quant(&mha.wk_head(i), dtype);
            let w = matmul_q(&wq_i, &wk_i.transpose(), dtype); // d×d, rank d_h
            // Evaluate both candidates (always both: Alg. 3 compares means).
            let first = bd_col_q(&w, d_h, Tag::First, dtype)?;
            let last = bd_col_q(&w, d_h, Tag::Last, dtype)?;
            qk_stats.residual_first.push(first.1);
            qk_stats.residual_last.push(last.1);
            qk_first.push(first);
            qk_last.push(last);
            qk_products.push(w);
        }
        let tag_qk = select_tag(strategy, &qk_stats);
        let chosen_qk = if tag_qk == Tag::First { &qk_first } else { &qk_last };
        for (i, (bc, _res)) in chosen_qk.iter().enumerate() {
            let recon = crate::bd::reconstruct_col(tag_qk, &bc.0, &bc.1);
            qk_stats.mse.push(recon.mse(&qk_products[i]));
            qk_stats.nmse.push(crate::tensor::ops::nmse(&recon, &qk_products[i]));
        }
        // Assemble B_qk (d × n·d_h) and C_qk ((d−d_h) × n·d_h).
        let b_parts: Vec<&Tensor> = chosen_qk.iter().map(|(bc, _)| &bc.0).collect();
        let b_qk = Tensor::concat_cols(&b_parts);
        // C^i is d_h×(d−d_h); stack transposes along columns.
        let c_t: Vec<Tensor> = chosen_qk.iter().map(|(bc, _)| bc.1.transpose()).collect();
        let c_refs: Vec<&Tensor> = c_t.iter().collect();
        let c_qk = Tensor::concat_cols(&c_refs);

        // ---- VO: row-BD of each head product -------------------------------
        let mut vo_first = Vec::with_capacity(n);
        let mut vo_last = Vec::with_capacity(n);
        let mut vo_stats = PrepStats::default();
        let mut vo_products = Vec::with_capacity(n);
        for i in 0..n {
            let wv_i = quant(&mha.wv_head(i), dtype);
            let wo_i = quant(&mha.wo_head(i), dtype);
            let w = matmul_q(&wv_i, &wo_i, dtype); // d×d, rank d_h
            let first = bd_row_q(&w, d_h, Tag::First, dtype)?;
            let last = bd_row_q(&w, d_h, Tag::Last, dtype)?;
            vo_stats.residual_first.push(first.1);
            vo_stats.residual_last.push(last.1);
            vo_first.push(first);
            vo_last.push(last);
            vo_products.push(w);
        }
        let tag_vo = select_tag(strategy, &vo_stats);
        let chosen_vo = if tag_vo == Tag::First { &vo_first } else { &vo_last };
        for (i, (bc, _res)) in chosen_vo.iter().enumerate() {
            let recon = crate::bd::reconstruct_row(tag_vo, &bc.0, &bc.1);
            vo_stats.mse.push(recon.mse(&vo_products[i]));
            vo_stats.nmse.push(crate::tensor::ops::nmse(&recon, &vo_products[i]));
        }
        // C_vo: (d−d_h) × n·d_h, col-stacked; B_vo: n·d_h × d, row-stacked.
        let c_parts: Vec<&Tensor> = chosen_vo.iter().map(|(bc, _)| &bc.1).collect();
        let c_vo = Tensor::concat_cols(&c_parts);
        let b_parts: Vec<&Tensor> = chosen_vo.iter().map(|(bc, _)| &bc.0).collect();
        let b_vo = Tensor::concat_rows(&b_parts);

        Ok(BdaWeights {
            shape: s,
            tag_qk,
            tag_vo,
            b_qk,
            c_qk,
            c_vo,
            b_vo,
            qk_stats,
            vo_stats,
        })
    }

    /// Parameter count of the BDA block (vs MHA's `4·d·n·d_h`).
    pub fn param_count(&self) -> usize {
        self.b_qk.numel() + self.c_qk.numel() + self.c_vo.numel() + self.b_vo.numel()
    }

    /// The K'/V' projections — Lines 2–3 of Algorithm 2 (the fused operator
    /// benchmarked in Fig. 2b / Tables 6–7).
    pub fn project_kv(&self, x: &Tensor) -> (Tensor, Tensor) {
        let k = kproj::kproj_bda(x, &self.c_qk, self.tag_qk, self.shape);
        let v = kproj::kproj_bda(x, &self.c_vo, self.tag_vo, self.shape);
        (k, v)
    }
}

impl BdaAttention {
    pub fn new(weights: BdaWeights) -> Self {
        BdaAttention { weights }
    }

    /// Prepare from MHA weights (convenience).
    pub fn from_mha(mha: &MhaWeights, strategy: Strategy, dtype: DType) -> Result<Self, BdError> {
        Ok(Self::new(BdaWeights::prepare(mha, strategy, dtype)?))
    }

    /// BDA inference — Algorithm 2.
    pub fn forward(&self, x: &Tensor, causal: bool) -> Tensor {
        let w = &self.weights;
        let s = w.shape;
        assert_eq!(x.cols(), s.d);
        let q = matmul(x, &w.b_qk);
        let (k, v) = w.project_kv(x);
        attention_core(&q, &k, &v, &w.b_vo, s, causal)
    }
}

fn quant(t: &Tensor, dt: DType) -> Tensor {
    crate::tensor::ops::quantized_copy(t, dt)
}

fn matmul_q(a: &Tensor, b: &Tensor, dt: DType) -> Tensor {
    crate::tensor::matmul::matmul_dt(a, b, DType::F32).cast(dt)
}

/// Column-BD at a fixed tag, quantizing factors through `dtype`;
/// returns ((B, C), residual-in-dtype).
fn bd_col_q(
    w: &Tensor,
    r: usize,
    tag: Tag,
    dt: DType,
) -> Result<((Tensor, Tensor), f64), BdError> {
    let strategy = match tag {
        Tag::First => Strategy::FirstR,
        Tag::Last => Strategy::ResidualMin, // we will pick the Last candidate below
    };
    // Run full residual-min to get both; cheaper path: call decompose once
    // per tag via slicing. Use the direct API:
    let col = match tag {
        Tag::First => bd_col(w, r, strategy)?,
        Tag::Last => {
            let both = bd_col(w, r, Strategy::ResidualMin)?;
            if both.tag == Tag::Last {
                both
            } else {
                // Force last: recompute on the reversed problem.
                force_col_last(w, r)?
            }
        }
    };
    let b = quant(&col.b, dt);
    let c = quant(&col.c, dt);
    let recon = crate::bd::reconstruct_col(tag, &b, &c);
    let residual = recon.sub(w).fro_norm();
    Ok(((b, c), residual))
}

fn bd_row_q(
    w: &Tensor,
    r: usize,
    tag: Tag,
    dt: DType,
) -> Result<((Tensor, Tensor), f64), BdError> {
    let row = match tag {
        Tag::First => bd_row(w, r, Strategy::FirstR)?,
        Tag::Last => {
            let both = bd_row(w, r, Strategy::ResidualMin)?;
            if both.tag == Tag::Last {
                both
            } else {
                force_row_last(w, r)?
            }
        }
    };
    let b = quant(&row.b, dt);
    let c = quant(&row.c, dt);
    let recon = crate::bd::reconstruct_row(tag, &b, &c);
    let residual = recon.sub(w).fro_norm();
    Ok(((b, c), residual))
}

/// Decompose with the last-r columns as basis (bypasses residual selection).
fn force_col_last(w: &Tensor, r: usize) -> Result<crate::bd::ColBd, BdError> {
    let n = w.cols();
    let b = w.slice_cols(n - r, n);
    let rest = w.slice_cols(0, n - r);
    let b64 = crate::linalg::lu::MatF64::from_tensor(&b);
    let rest64 = crate::linalg::lu::MatF64::from_tensor(&rest);
    let btb = b64.transpose().matmul(&b64);
    let btr = b64.transpose().matmul(&rest64);
    let c = crate::linalg::lu::lu_solve_matrix_f64(&btb, &btr)?.to_tensor();
    let recon = crate::bd::reconstruct_col(Tag::Last, &b, &c);
    let residual = recon.sub(w).fro_norm();
    Ok(crate::bd::ColBd {
        tag: Tag::Last,
        b,
        c,
        residual,
        residual_first: f64::NAN,
        residual_last: residual,
    })
}

fn force_row_last(w: &Tensor, r: usize) -> Result<crate::bd::RowBd, BdError> {
    let m = w.rows();
    let b = w.slice_rows(m - r, m);
    let rest = w.slice_rows(0, m - r);
    let b64 = crate::linalg::lu::MatF64::from_tensor(&b);
    let rest64 = crate::linalg::lu::MatF64::from_tensor(&rest);
    let bbt = b64.matmul(&b64.transpose());
    let rbt = rest64.matmul(&b64.transpose());
    let c = crate::linalg::lu::solve_xa_b_f64(&bbt, &rbt)?.to_tensor();
    let recon = crate::bd::reconstruct_row(Tag::Last, &b, &c);
    let residual = recon.sub(w).fro_norm();
    Ok(crate::bd::RowBd {
        tag: Tag::Last,
        b,
        c,
        residual,
        residual_first: f64::NAN,
        residual_last: residual,
    })
}

/// Algorithm 3 line 4–5: pick the tag with the smaller *mean* residual.
fn select_tag(strategy: Strategy, stats: &PrepStats) -> Tag {
    match strategy {
        Strategy::FirstR => Tag::First,
        Strategy::ResidualMin => {
            if stats.mean_residual_first() <= stats.mean_residual_last() {
                Tag::First
            } else {
                Tag::Last
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::mha::mha_forward;

    fn setup(d: usize, n: usize, d_h: usize, seed: u64) -> (MhaWeights, Tensor) {
        let s = AttnShape::new(d, n, d_h);
        let w = MhaWeights::random(s, seed);
        let x = Tensor::randn(&[6, d], 1.0, seed + 100);
        (w, x)
    }

    #[test]
    fn bda_matches_mha_fp32() {
        let (w, x) = setup(32, 4, 8, 1);
        let bda = BdaAttention::from_mha(&w, Strategy::ResidualMin, DType::F32).unwrap();
        let y_mha = mha_forward(&w, &x, false);
        let y_bda = bda.forward(&x, false);
        let rel = y_bda.max_abs_diff(&y_mha) / y_mha.fro_norm().max(1e-9) as f32;
        assert!(rel < 1e-3, "relative diff {rel}");
    }

    #[test]
    fn bda_matches_mha_causal() {
        let (w, x) = setup(24, 3, 8, 2);
        let bda = BdaAttention::from_mha(&w, Strategy::ResidualMin, DType::F32).unwrap();
        let y_mha = mha_forward(&w, &x, true);
        let y_bda = bda.forward(&x, true);
        assert!(y_bda.max_abs_diff(&y_mha) < 1e-4);
    }

    #[test]
    fn qk_inner_products_preserved() {
        // The paper's key invariant: Q'_i K'_i^T == Q_i K_i^T per head.
        let (w, x) = setup(16, 2, 4, 3);
        let bda = BdaAttention::from_mha(&w, Strategy::ResidualMin, DType::F32).unwrap();
        let s = w.shape;
        let q = matmul(&x, &w.wq);
        let k = matmul(&x, &w.wk);
        let qp = matmul(&x, &bda.weights.b_qk);
        let kp = kproj::kproj_bda(&x, &bda.weights.c_qk, bda.weights.tag_qk, s);
        for i in 0..s.n_heads {
            let qi = q.slice_cols(i * s.d_h, (i + 1) * s.d_h);
            let ki = k.slice_cols(i * s.d_h, (i + 1) * s.d_h);
            let qpi = qp.slice_cols(i * s.d_h, (i + 1) * s.d_h);
            let kpi = kp.slice_cols(i * s.d_h, (i + 1) * s.d_h);
            let scores = matmul(&qi, &ki.transpose());
            let scores_p = matmul(&qpi, &kpi.transpose());
            assert!(
                scores_p.max_abs_diff(&scores) < 1e-4,
                "head {i} diff {}",
                scores_p.max_abs_diff(&scores)
            );
        }
    }

    #[test]
    fn param_reduction_matches_formula() {
        // BDA replaces Wk (d×ndh) with C_qk ((d−dh)×ndh) and Wv likewise:
        // total saving = 2·dh·ndh; ratio on K/V weights = dh/d = 25% here.
        let (w, _) = setup(32, 4, 8, 4);
        let bda = BdaWeights::prepare(&w, Strategy::ResidualMin, DType::F32).unwrap();
        let expected = w.param_count() - 2 * 8 * 32 * 4 / 4; // 2·d_h·n·d_h… compute directly:
        let _ = expected;
        let mha_kv = 2 * 32 * (4 * 8); // Wk + Wv
        let bda_kv = 2 * (32 - 8) * (4 * 8); // C_qk + C_vo
        assert_eq!(bda.param_count(), w.param_count() - (mha_kv - bda_kv));
        let reduction = 1.0 - bda_kv as f64 / mha_kv as f64;
        assert!((reduction - 0.25).abs() < 1e-12);
    }

    #[test]
    fn fp16_prep_small_error() {
        let (w, x) = setup(32, 4, 8, 5);
        let bda = BdaAttention::from_mha(&w, Strategy::ResidualMin, DType::F16).unwrap();
        let y_mha = mha_forward(&w, &x, false);
        let y_bda = bda.forward(&x, false);
        let rel = (y_bda.max_abs_diff(&y_mha) as f64) / y_mha.fro_norm().max(1e-9);
        // FP16 prep: small but nonzero error.
        assert!(rel < 1e-1, "rel {rel}");
        assert!(bda.weights.qk_stats.mean_nmse() > 0.0);
    }

    #[test]
    fn residual_min_stats_complete() {
        let (w, _) = setup(16, 2, 4, 6);
        let bda = BdaWeights::prepare(&w, Strategy::ResidualMin, DType::F32).unwrap();
        assert_eq!(bda.qk_stats.residual_first.len(), 2);
        assert_eq!(bda.qk_stats.residual_last.len(), 2);
        assert_eq!(bda.qk_stats.mse.len(), 2);
        assert_eq!(bda.vo_stats.nmse.len(), 2);
    }

    #[test]
    fn shapes_of_bda_weights() {
        let (w, _) = setup(32, 4, 8, 7);
        let bda = BdaWeights::prepare(&w, Strategy::FirstR, DType::F32).unwrap();
        assert_eq!(bda.b_qk.shape, vec![32, 32]); // d × n·d_h
        assert_eq!(bda.c_qk.shape, vec![24, 32]); // (d−d_h) × n·d_h
        assert_eq!(bda.c_vo.shape, vec![24, 32]);
        assert_eq!(bda.b_vo.shape, vec![32, 32]); // n·d_h × d
        assert_eq!(bda.tag_qk, Tag::First);
    }
}
