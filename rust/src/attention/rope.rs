//! Rotary position embeddings and their interaction with BD (Appendix D).
//!
//! * Embedding-layer PE: orthogonal to BD (BD only touches projections).
//! * Vanilla RoPE inside MHA: breaks BD's QK exactness
//!   (`W_q R_{n−m} W_k^T ≠ B R_{n−m} [I, C]` in general).
//! * Decoupled RoPE (DeepSeek): separate RoPE channels added to the score;
//!   BD applies losslessly to the non-RoPE channels. This module implements
//!   all three so the Appendix D claims are testable.

use super::AttnShape;
use crate::tensor::matmul::matmul;
use crate::tensor::Tensor;

/// Apply vanilla RoPE to a per-head L×d_h tensor (pairs of channels rotated
/// by position-dependent angles). `base` is the frequency base (10000).
pub fn apply_rope(x: &Tensor, base: f32) -> Tensor {
    assert_eq!(x.ndim(), 2);
    let (l, d_h) = (x.rows(), x.cols());
    assert!(d_h % 2 == 0, "RoPE needs even head dim");
    let half = d_h / 2;
    let mut out = x.clone();
    for pos in 0..l {
        for k in 0..half {
            let theta = (pos as f32) * base.powf(-2.0 * (k as f32) / (d_h as f32));
            let (sin, cos) = theta.sin_cos();
            let a = x.at(pos, 2 * k);
            let b = x.at(pos, 2 * k + 1);
            *out.at_mut(pos, 2 * k) = a * cos - b * sin;
            *out.at_mut(pos, 2 * k + 1) = a * sin + b * cos;
        }
    }
    out
}

/// Decoupled-RoPE score contribution (DeepSeek style): a separate, small
/// RoPE'd projection whose per-head scores are *added* to the non-RoPE
/// (BD-compressed) scores.
pub struct DecoupledRope {
    pub shape: AttnShape,
    /// RoPE channels per head.
    pub d_r: usize,
    /// d × n·d_r query-side RoPE projection.
    pub w_qr: Tensor,
    /// d × d_r shared key-side RoPE projection (MQA-style, as in DeepSeek).
    pub w_kr: Tensor,
    pub base: f32,
}

impl DecoupledRope {
    pub fn random(shape: AttnShape, d_r: usize, seed: u64) -> DecoupledRope {
        DecoupledRope {
            shape,
            d_r,
            w_qr: Tensor::randn(&[shape.d, shape.n_heads * d_r], 0.02, seed),
            w_kr: Tensor::randn(&[shape.d, d_r], 0.02, seed + 1),
            base: 10000.0,
        }
    }

    /// Per-head additive score matrices (L×L each) from the RoPE channels.
    pub fn scores(&self, x: &Tensor) -> Vec<Tensor> {
        let n = self.shape.n_heads;
        let kr = apply_rope(&matmul(x, &self.w_kr), self.base); // L×d_r shared
        (0..n)
            .map(|i| {
                let qr_i = matmul(x, &self.w_qr.slice_cols(i * self.d_r, (i + 1) * self.d_r));
                let qr_i = apply_rope(&qr_i, self.base);
                matmul(&qr_i, &kr.transpose())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::mha::MhaWeights;
    use crate::bd::{Strategy, Tag};
    use crate::tensor::DType;

    #[test]
    fn rope_preserves_norms() {
        let x = Tensor::randn(&[6, 8], 1.0, 1);
        let r = apply_rope(&x, 10000.0);
        for i in 0..6 {
            let n0: f32 = x.row(i).iter().map(|v| v * v).sum();
            let n1: f32 = r.row(i).iter().map(|v| v * v).sum();
            assert!((n0 - n1).abs() < 1e-4);
        }
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let x = Tensor::randn(&[1, 8], 1.0, 2);
        let r = apply_rope(&x, 10000.0);
        assert!(r.max_abs_diff(&x) < 1e-6);
    }

    #[test]
    fn rope_relative_property() {
        // <RoPE_m(q), RoPE_n(k)> depends only on n−m: shifting both
        // positions by the same offset preserves the inner product.
        let d_h = 8;
        let q = Tensor::randn(&[1, d_h], 1.0, 3);
        let k = Tensor::randn(&[1, d_h], 1.0, 4);
        // Build length-5 sequences where q sits at pos p and k at pos p+2.
        let embed = |v: &Tensor, pos: usize, len: usize| {
            let mut m = Tensor::zeros(&[len, d_h]);
            for j in 0..d_h {
                *m.at_mut(pos, j) = v.data[j];
            }
            apply_rope(&m, 10000.0)
        };
        let dot = |a: &[f32], b: &[f32]| a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>();
        let q0 = embed(&q, 0, 5);
        let k2 = embed(&k, 2, 5);
        let q1 = embed(&q, 1, 5);
        let k3 = embed(&k, 3, 5);
        let d02 = dot(q0.row(0), k2.row(2));
        let d13 = dot(q1.row(1), k3.row(3));
        assert!((d02 - d13).abs() < 1e-4, "{d02} vs {d13}");
    }

    #[test]
    fn vanilla_rope_breaks_bd_exactness() {
        // Appendix D: rotating the *projected* q/k (vanilla RoPE) does not
        // commute with BD's reparameterization of q/k.
        let s = AttnShape::new(16, 1, 4);
        let mha = MhaWeights::random(s, 5);
        let bda =
            crate::attention::bda::BdaWeights::prepare(&mha, Strategy::FirstR, DType::F32)
                .unwrap();
        let x = Tensor::randn(&[5, s.d], 1.0, 6);

        // MHA scores with RoPE.
        let q = apply_rope(&matmul(&x, &mha.wq), 10000.0);
        let k = apply_rope(&matmul(&x, &mha.wk), 10000.0);
        let scores_mha = matmul(&q, &k.transpose());

        // BDA scores with RoPE applied to Q', K'.
        let qp = apply_rope(&matmul(&x, &bda.b_qk), 10000.0);
        let kp_raw =
            crate::attention::kproj::kproj_bda(&x, &bda.c_qk, Tag::First, s);
        let kp = apply_rope(&kp_raw, 10000.0);
        let scores_bda = matmul(&qp, &kp.transpose());

        let rel =
            (scores_bda.max_abs_diff(&scores_mha) as f64) / scores_mha.fro_norm().max(1e-9);
        assert!(rel > 1e-3, "vanilla RoPE should break exactness, rel {rel}");
    }

    #[test]
    fn decoupled_rope_keeps_bd_exact() {
        // Decoupled: BD channels carry no RoPE; RoPE channels are separate
        // and identical in both variants -> total scores match exactly.
        let s = AttnShape::new(16, 2, 4);
        let mha = MhaWeights::random(s, 7);
        let bda =
            crate::attention::bda::BdaWeights::prepare(&mha, Strategy::ResidualMin, DType::F32)
                .unwrap();
        let rope = DecoupledRope::random(s, 4, 8);
        let x = Tensor::randn(&[5, s.d], 1.0, 9);

        let rope_scores = rope.scores(&x);

        let q = matmul(&x, &mha.wq);
        let k = matmul(&x, &mha.wk);
        let qp = matmul(&x, &bda.b_qk);
        let kp = crate::attention::kproj::kproj_bda(&x, &bda.c_qk, bda.tag_qk, s);
        for i in 0..s.n_heads {
            let sl = |t: &Tensor| t.slice_cols(i * s.d_h, (i + 1) * s.d_h);
            let total_mha = matmul(&sl(&q), &sl(&k).transpose()).add(&rope_scores[i]);
            let total_bda = matmul(&sl(&qp), &sl(&kp).transpose()).add(&rope_scores[i]);
            assert!(
                total_bda.max_abs_diff(&total_mha) < 1e-3,
                "head {i}: decoupled RoPE must preserve exactness"
            );
        }
    }
}
