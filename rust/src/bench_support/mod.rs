//! Benchmark harness (no criterion in the offline crate set): warmup,
//! timed iterations with a minimum measurement window, robust statistics,
//! and the table printer that regenerates the paper's rows.

use crate::util::stats::Summary;
use crate::util::timer::Timer;

/// Measurement configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    /// Minimum number of timed iterations.
    pub min_iters: usize,
    /// Minimum total measurement time (seconds).
    pub min_time: f64,
    /// Hard cap on iterations.
    pub max_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 2, min_iters: 5, min_time: 0.5, max_iters: 200 }
    }
}

impl BenchConfig {
    /// Faster settings for CI-style smoke runs (BDA_BENCH_FAST=1).
    pub fn from_env() -> BenchConfig {
        if std::env::var("BDA_BENCH_FAST").is_ok() {
            BenchConfig { warmup_iters: 1, min_iters: 2, min_time: 0.05, max_iters: 10 }
        } else {
            BenchConfig::default()
        }
    }
}

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub summary: Summary,
    /// Work units per iteration (e.g. tokens) for throughput reporting.
    pub work_per_iter: f64,
}

impl Measurement {
    /// Median throughput in work units per second.
    pub fn throughput(&self) -> f64 {
        self.work_per_iter / self.summary.median
    }

    /// Throughput in millions of units per second (the paper's Mtok/s).
    pub fn mops(&self) -> f64 {
        self.throughput() / 1e6
    }

    /// Median iteration time in microseconds (latency-style reporting,
    /// e.g. the decode-throughput dispatch-overhead rows).
    pub fn median_us(&self) -> f64 {
        self.summary.median * 1e6
    }
}

/// Run a benchmark: calls `f()` repeatedly and times each call.
pub fn bench(name: &str, config: BenchConfig, work_per_iter: f64, mut f: impl FnMut()) -> Measurement {
    for _ in 0..config.warmup_iters {
        f();
    }
    let mut samples = Vec::new();
    let total = Timer::start();
    loop {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_secs());
        let enough_iters = samples.len() >= config.min_iters;
        let enough_time = total.elapsed_secs() >= config.min_time;
        if (enough_iters && enough_time) || samples.len() >= config.max_iters {
            break;
        }
    }
    Measurement { name: name.to_string(), summary: Summary::from(&samples), work_per_iter }
}

/// Markdown-ish table printer matching the paper's layout.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{c:>w$} | ", w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push_str(&format!(
            "|{}|\n",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        ));
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Scatter `len` contiguous K/V rows into paged block storage under a
/// block table — the test/bench-side mirror of the engine's prefill
/// scatter, shared by the paged-attention unit tests, property tests, and
/// the decode-throughput bench fixture so the layout is defined once.
#[allow(clippy::too_many_arguments)]
pub fn scatter_paged_kv(
    pk: &mut [f32],
    pv: &mut [f32],
    k: &[f32],
    v: &[f32],
    len: usize,
    width: usize,
    block_size: usize,
    table: &[usize],
) {
    for t in 0..len {
        let base = (table[t / block_size] * block_size + t % block_size) * width;
        pk[base..base + width].copy_from_slice(&k[t * width..(t + 1) * width]);
        pv[base..base + width].copy_from_slice(&v[t * width..(t + 1) * width]);
    }
}

/// Format a float to 2 decimal places (table cells).
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Format scientific (Table 4 cells).
pub fn sci(x: f64) -> String {
    format!("{x:.2e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let cfg = BenchConfig { warmup_iters: 1, min_iters: 3, min_time: 0.0, max_iters: 5 };
        let m = bench("spin", cfg, 100.0, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(m.summary.median > 0.0);
        assert!(m.throughput() > 0.0);
        assert!(m.summary.n >= 3);
    }

    #[test]
    fn bench_respects_max_iters() {
        let cfg = BenchConfig { warmup_iters: 0, min_iters: 1, min_time: 100.0, max_iters: 4 };
        let m = bench("fast", cfg, 1.0, || {});
        assert_eq!(m.summary.n, 4);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Seq. Len", "MHA", "BDA", "Speedup"]);
        t.row(vec!["64".into(), "1.79".into(), "2.16".into(), "1.21x".into()]);
        t.row(vec!["65536".into(), "5.41".into(), "7.06".into(), "1.30x".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("| Seq. Len |"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn formatting() {
        assert_eq!(f2(1.234), "1.23");
        assert!(sci(3.19e-12).contains("e-12"));
    }
}
