//! `bda` — CLI for the BD Attention reproduction.
//!
//! Subcommands:
//!   info                          cost model + environment summary
//!   prepare    [--model M]        Algorithm 3 over a model, report stats
//!   exactness  [--model M]        BDA vs MHA output diff across dtypes
//!   serve      [--attention A]    run the serving coordinator on a trace
//!                                 (--backend paged|per-seq; BDA_NUM_THREADS
//!                                 sets decode parallelism — output is
//!                                 bit-identical at any thread count;
//!                                 --prefix-cache on|off overrides the
//!                                 BDA_PREFIX_CACHE default for the paged
//!                                 engine's radix-tree prompt cache;
//!                                 --kv-dtype fp32|fp16|bf16 overrides the
//!                                 BDA_KV_DTYPE default for the paged
//!                                 engine's K/V block storage width
//!                                 (16-bit pools generate bitwise what an
//!                                 f32 pool with quantize-at-write would);
//!                                 --trace-out FILE enables structured
//!                                 tracing and writes a Perfetto-loadable
//!                                 Chrome trace; --prom-out FILE writes the
//!                                 metrics snapshot in Prometheus text
//!                                 format; BDA_TRACE=1 records without a
//!                                 file; --workers N shards the trace
//!                                 across N pool-shard engine workers
//!                                 behind the prefix-aware router —
//!                                 default from BDA_WORKERS, generations
//!                                 bit-identical at any worker count)
//!   eval-ppl   [--model M]        Fig. 2a-style PPL table (fp32/16/bf16)
//!   recon      [--model M]        Table 4-style reconstruction errors
//!   train      [--steps N]        drive the AOT train_step from Rust
//!   runtime-check                 execute artifacts & verify test vector

use bda::attention::AttnShape;
use bda::bd::{cost, Strategy};
use bda::coordinator::{self, NativeBackend, PagedNativeBackend, ServerConfig};
use bda::eval::{perplexity, trace};
use bda::model::{ModelConfig, Transformer};
use bda::prepare::prepare_model;
use bda::tensor::DType;
use bda::util::cli::Args;
use bda::util::timer::Timer;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("info");
    let code = match cmd {
        "info" => cmd_info(&args),
        "prepare" => cmd_prepare(&args),
        "exactness" => cmd_exactness(&args),
        "serve" => cmd_serve(&args),
        "eval-ppl" => cmd_eval_ppl(&args),
        "recon" => cmd_recon(&args),
        "train" => cmd_train(&args),
        "runtime-check" => cmd_runtime_check(&args),
        other => {
            eprintln!("unknown command: {other}");
            eprintln!("commands: info prepare exactness serve eval-ppl recon train runtime-check");
            2
        }
    };
    std::process::exit(code);
}

fn model_from_args(args: &Args) -> Transformer {
    let name = args.get_or("model", "tiny");
    let config = ModelConfig::preset(name).unwrap_or_else(|| {
        bda::obs::announce(&format!("unknown model preset {name}, using tiny"));
        ModelConfig::tiny()
    });
    Transformer::new_mha(config, args.get_u64("seed", 42))
}

fn cmd_info(_args: &Args) -> i32 {
    let s = AttnShape::deepseek_v3();
    println!("BD Attention (BDA) — reproduction of Zhao (2025)");
    println!("DeepSeek-V3 KV operator shape: d={} d_h={} n_heads={}", s.d, s.d_h, s.n_heads);
    println!(
        "  theoretical k_proj speedup: {:.3}x (paper: 1.33x)",
        cost::kproj_theoretical_speedup(s.d, s.d_h)
    );
    println!(
        "  K/V weight reduction:       {:.1}% (paper: 25%)",
        100.0 * cost::kv_weight_reduction(s.d, s.d_h)
    );
    let c = cost::BdCost::new(512, 512, 128);
    println!(
        "  512x512 rank-128 product: dense={} lowrank={} bd={} params",
        c.dense_params(),
        c.lowrank_params(),
        c.bd_params()
    );
    println!("threads: {}", bda::util::threadpool::num_threads());
    for preset in ["tiny", "deepseek-lite-sim", "llama-sim", "llama-sim-l"] {
        let m = ModelConfig::preset(preset).unwrap();
        println!("model {preset}: {} params", m.param_count());
    }
    0
}

fn cmd_prepare(args: &Args) -> i32 {
    let model = model_from_args(args);
    let strategy = if args.get_or("strategy", "residual-min") == "first-r" {
        Strategy::FirstR
    } else {
        Strategy::ResidualMin
    };
    let dtype = DType::parse(args.get_or("dtype", "fp32")).unwrap_or(DType::F32);
    println!(
        "preparing {} ({} params) as BDA [{} / {}]...",
        model.config.name,
        model.param_count(),
        strategy.name(),
        dtype
    );
    match prepare_model(&model, strategy, dtype) {
        Ok(rep) => {
            println!("preparation time: {:.3}s", rep.seconds);
            println!("QK: mse={:.3e} nmse={:.3e}", rep.qk_mse(), rep.qk_nmse());
            println!("VO: mse={:.3e} nmse={:.3e}", rep.vo_mse(), rep.vo_nmse());
            println!(
                "params: {} -> {} ({:.1}% smaller)",
                model.param_count(),
                rep.model.param_count(),
                100.0 * (1.0 - rep.model.param_count() as f64 / model.param_count() as f64)
            );
            0
        }
        Err(e) => {
            eprintln!("preparation failed: {e}");
            1
        }
    }
}

fn cmd_exactness(args: &Args) -> i32 {
    let model = model_from_args(args);
    let tokens: Vec<u32> =
        (0..32).map(|i| (i * 37 + 11) % model.config.vocab_size as u32).collect();
    let base = model.forward_full(&tokens);
    println!("BDA vs MHA logits diff on {} ({} tokens):", model.config.name, tokens.len());
    for dt in [DType::F32, DType::F16, DType::BF16] {
        for strat in [Strategy::FirstR, Strategy::ResidualMin] {
            let bda = model.to_bda(strat, dt).unwrap();
            let out = bda.forward_full(&tokens);
            let rel = (out.max_abs_diff(&base) as f64) / base.fro_norm().max(1e-12);
            println!("  {:>5} {:>13}: rel max diff {rel:.3e}", dt.name(), strat.name());
        }
    }
    0
}

fn cmd_serve(args: &Args) -> i32 {
    // Turn tracing on before any engine work (pool spin-up, prefill) so
    // the whole run lands in the exported trace.
    if args.get("trace-out").is_some() {
        bda::obs::set_enabled(true);
    }
    let model = model_from_args(args);
    let attention = args.get_or("attention", "bda");
    let model = if attention == "bda" {
        model.to_bda(Strategy::ResidualMin, DType::F32).expect("prepare")
    } else {
        model
    };
    let n = args.get_usize("requests", 32);
    let backend = args.get_or("backend", "paged").to_string();
    if backend != "paged" && backend != "per-seq" {
        eprintln!("unknown --backend {backend}; expected paged | per-seq");
        return 2;
    }
    let mut cfg = ServerConfig::default();
    if let Some(v) = args.get("kv-dtype") {
        match DType::parse(v) {
            Some(dt) => cfg.scheduler.kv.dtype = dt,
            None => {
                eprintln!("unknown --kv-dtype {v}; expected fp32 | fp16 | bf16");
                return 2;
            }
        }
    }
    let workers = args.get_usize("workers", coordinator::workers_from_env()).max(1);
    let t = trace::generate(trace::TraceConfig {
        n_requests: n,
        vocab_size: model.config.vocab_size,
        ..Default::default()
    });
    println!("serving {n} requests on {} [{attention} / {backend}]...", model.config.name);
    println!(
        "paged attention + GEMM workers: {} (set BDA_NUM_THREADS to override; \
         generations are bit-identical at any thread count)",
        bda::util::threadpool::num_threads()
    );
    if workers > 1 {
        println!(
            "pool shards: {workers} engine workers behind the prefix-aware router \
             (--workers / BDA_WORKERS; generations are bit-identical at any worker count)"
        );
    }
    let timer = Timer::start();
    let (responses, snap) = if backend == "per-seq" {
        if workers > 1 {
            let backends: Vec<NativeBackend> =
                (0..workers).map(|_| NativeBackend::new(model.clone())).collect();
            coordinator::server::replay_trace_sharded(backends, cfg, t).expect("serve")
        } else {
            let (responses, metrics) =
                coordinator::server::replay_trace(NativeBackend::new(model), cfg, t)
                    .expect("serve");
            let snap = metrics.snapshot();
            (responses, snap)
        }
    } else {
        // Default: the paged batched decode engine, with the radix-tree
        // prefix cache following BDA_PREFIX_CACHE unless --prefix-cache
        // overrides it (a pure perf/memory knob: cache hits are
        // bitwise-identical to cold prefills).
        let make_engine = |model: Transformer| {
            let mut engine = if workers > 1 {
                // Per-shard thread pools: split the global worker budget
                // so shards don't oversubscribe cores.
                let per_shard = (bda::util::threadpool::num_threads() / workers).max(1);
                let pool = std::sync::Arc::new(bda::util::threadpool::ThreadPool::new(per_shard));
                PagedNativeBackend::with_thread_pool(model, cfg.scheduler.kv, pool)
            } else {
                PagedNativeBackend::new(model, cfg.scheduler.kv)
            };
            if let Some(v) = args.get("prefix-cache") {
                engine.set_prefix_cache(bda::engine::backend::prefix_cache_flag(v));
            }
            engine
        };
        let first = make_engine(model.clone());
        println!(
            "prefix cache: {}",
            if first.prefix_cache_enabled() { "enabled" } else { "disabled" }
        );
        println!(
            "kv pool: {} storage, {:.1} MiB allocated per shard",
            first.kv_dtype().name(),
            first.kv_pool_bytes() as f64 / (1024.0 * 1024.0)
        );
        if workers > 1 {
            let mut backends = vec![first];
            backends.extend((1..workers).map(|_| make_engine(model.clone())));
            coordinator::server::replay_trace_sharded(backends, cfg, t).expect("serve")
        } else {
            let (responses, metrics) =
                coordinator::server::replay_trace(first, cfg, t).expect("serve");
            let snap = metrics.snapshot();
            (responses, snap)
        }
    };
    let secs = timer.elapsed_secs();
    println!("{}", snap.report());
    if let Some(split) = snap.decode_split() {
        println!("decode split: {split}");
    }
    if let Some(line) = snap.prefix_cache_line() {
        println!("prefix cache: {line}");
    }
    if let Some(line) = snap.kv_pool_line() {
        println!("kv pool: {line}");
    }
    if let Some(line) = snap.preemption_line() {
        println!("preemption: {line}");
    }
    if let Some(line) = snap.tbt_line() {
        println!("tbt: {line}");
    }
    if let Some(line) = snap.step_phase_line() {
        println!("step phases: {line}");
    }
    println!("wall: {secs:.2}s, completed {}", responses.len());
    if let Some(path) = args.get("prom-out") {
        if let Err(e) = std::fs::write(path, bda::obs::export::prometheus_text(&snap)) {
            eprintln!("write {path}: {e}");
            return 1;
        }
        println!("prometheus metrics written to {path}");
    }
    if bda::obs::enabled() {
        bda::obs::flush();
        let events = bda::obs::take_collected();
        let labels = bda::obs::thread_labels();
        if let Some(path) = args.get("trace-out") {
            let doc = bda::obs::export::chrome_trace(&events, &labels);
            if let Err(e) = std::fs::write(path, doc.to_string()) {
                eprintln!("write {path}: {e}");
                return 1;
            }
            println!(
                "chrome trace written to {path} ({} spans, {} dropped) — load in Perfetto",
                events.len(),
                bda::obs::dropped_total()
            );
        } else {
            println!("trace: {} spans recorded (pass --trace-out FILE to export)", events.len());
        }
    }
    0
}

fn cmd_eval_ppl(args: &Args) -> i32 {
    let model = model_from_args(args);
    let corpus = bda::eval::corpus::Corpus::tiny_wiki(
        model.config.vocab_size,
        args.get_usize("tokens", 2048),
        7,
    );
    let seq = model.config.max_seq_len.min(128);
    let base = perplexity(&model, &corpus.tokens, seq);
    println!("{}: base PPL {base:.4}", model.config.name);
    let mut table = bda::bench_support::Table::new(
        "Fig 2a / Table 5 — PPL increase after BDA replacement",
        &["dtype", "strategy", "PPL", "increase %"],
    );
    for dt in [DType::F32, DType::F16, DType::BF16] {
        for strat in [Strategy::FirstR, Strategy::ResidualMin] {
            let bda = model.to_bda(strat, dt).unwrap();
            let p = perplexity(&bda, &corpus.tokens, seq);
            table.row(vec![
                dt.name().into(),
                strat.name().into(),
                format!("{p:.4}"),
                format!("{:.4}%", bda::eval::ppl::ppl_increase_percent(base, p)),
            ]);
        }
    }
    table.print();
    0
}

fn cmd_recon(args: &Args) -> i32 {
    let model = model_from_args(args);
    let mut table = bda::bench_support::Table::new(
        "Table 4 — BD reconstruction errors",
        &["projection", "metric", "strategy", "fp32", "fp16", "bf16"],
    );
    let mut cells: std::collections::BTreeMap<(String, String, String), String> =
        Default::default();
    for dt in [DType::F32, DType::F16, DType::BF16] {
        for strat in [Strategy::FirstR, Strategy::ResidualMin] {
            let rep = prepare_model(&model, strat, dt).unwrap();
            for (proj, mse, nmse) in
                [("QK", rep.qk_mse(), rep.qk_nmse()), ("VO", rep.vo_mse(), rep.vo_nmse())]
            {
                cells.insert(
                    (proj.into(), "MSE".into(), format!("{}{}", strat.name(), dt.name())),
                    format!("{mse:.2e}"),
                );
                cells.insert(
                    (proj.into(), "NMSE".into(), format!("{}{}", strat.name(), dt.name())),
                    format!("{nmse:.2e}"),
                );
            }
        }
    }
    for proj in ["QK", "VO"] {
        for metric in ["MSE", "NMSE"] {
            for strat in ["First-r", "Residual-min"] {
                let cell = |dt: &str| {
                    cells
                        .get(&(proj.into(), metric.into(), format!("{strat}{dt}")))
                        .cloned()
                        .unwrap_or_default()
                };
                table.row(vec![
                    proj.into(),
                    metric.into(),
                    strat.into(),
                    cell("fp32"),
                    cell("fp16"),
                    cell("bf16"),
                ]);
            }
        }
    }
    table.print();
    0
}

fn cmd_train(args: &Args) -> i32 {
    let steps = args.get_usize("steps", 20);
    let attention = args.get_or("attention", "mha").to_string();
    let lr_scale = args.get_f64("lr-scale", 1.0) as f32;
    match run_train(&attention, steps, lr_scale, args.get_or("artifacts", "artifacts")) {
        Ok(losses) => {
            println!(
                "train[{attention}] first loss {:.4}, last loss {:.4}",
                losses.first().unwrap_or(&f32::NAN),
                losses.last().unwrap_or(&f32::NAN)
            );
            0
        }
        Err(e) => {
            eprintln!("train failed: {e}");
            1
        }
    }
}

/// Drive the AOT train_step artifact for a few steps on synthetic data.
#[cfg(feature = "pjrt")]
fn run_train(attention: &str, steps: usize, lr_scale: f32, dir: &str) -> anyhow::Result<Vec<f32>> {
    use bda::runtime::{lit_i32, lit_scalar_f32, literal_scalar_f32, Runtime};
    let mut rt = Runtime::open(dir)?;
    let init = rt.load(&format!("train_init_{attention}"))?;
    let step = rt.load(&format!("train_step_{attention}"))?;
    let tc = rt.manifest.train_config.clone().expect("train config");
    let mut state = init.run(&[])?;
    let pairs = bda::eval::corpus::translation_pairs(256, tc.vocab_size, 6, 16, 5);
    let mut losses = Vec::new();
    for i in 0..steps {
        let mut tokens: Vec<i32> = Vec::with_capacity(tc.batch * (tc.max_seq_len + 1));
        for b in 0..tc.batch {
            let p = &pairs[(i * tc.batch + b) % pairs.len()];
            tokens.extend(p.pack(tc.max_seq_len + 1).iter().map(|&t| t as i32));
        }
        let mut inputs = state;
        inputs.push(lit_i32(&tokens, &[tc.batch as i64, (tc.max_seq_len + 1) as i64])?);
        inputs.push(lit_scalar_f32(lr_scale));
        let mut out = step.run(&inputs)?;
        let loss = literal_scalar_f32(&out.pop().unwrap())?;
        losses.push(loss);
        state = out;
        if i % 5 == 0 {
            println!("  step {i}: loss {loss:.4}");
        }
    }
    Ok(losses)
}

#[cfg(not(feature = "pjrt"))]
fn run_train(_attention: &str, _steps: usize, _lr_scale: f32, _dir: &str) -> anyhow::Result<Vec<f32>> {
    anyhow::bail!("built without the `pjrt` feature; rebuild with --features pjrt")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_runtime_check(_args: &Args) -> i32 {
    eprintln!("runtime-check requires the `pjrt` feature; rebuild with --features pjrt");
    1
}

#[cfg(feature = "pjrt")]
fn cmd_runtime_check(args: &Args) -> i32 {
    use bda::runtime::{lit_i32, Runtime};
    let dir = args.get_or("artifacts", "artifacts");
    let mut rt = match Runtime::open(dir) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("open runtime: {e}");
            return 1;
        }
    };
    println!("platform: {}", rt.platform());
    let tv = rt.manifest.test_vector.clone().expect("test vector");
    let tokens: Vec<i32> = tv.tokens.iter().flatten().copied().collect();
    let lit = lit_i32(&tokens, &[tv.batch as i64, tv.seq_len as i64]).unwrap();
    for name in ["lm_mha_fwd_probe", "lm_bda_fwd_probe"] {
        let t = Timer::start();
        let exe = match rt.load(name) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("load {name}: {e}");
                return 1;
            }
        };
        let compile_s = t.elapsed_secs();
        let out = exe.run(std::slice::from_ref(&lit)).expect("run");
        let logits: Vec<f32> = out[0].to_vec().expect("logits");
        let head = &logits[..8];
        let max_diff: f32 = head
            .iter()
            .zip(tv.logits_head.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        println!("{name}: compile {compile_s:.2}s, head diff {max_diff:.3e}");
        let tolerance = if name.contains("bda") { 2e-2 } else { 1e-4 };
        if !(max_diff < tolerance) {
            eprintln!("  MISMATCH vs test vector (tolerance {tolerance})");
            return 1;
        }
    }
    println!("runtime check OK");
    0
}
