//! Wall-clock timing helpers for benches and the preparation pass.

use std::time::{Duration, Instant};

/// A simple scope timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

/// Pretty-print a duration in adaptive units.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_value() {
        let (v, secs) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed_secs();
        let b = t.elapsed_secs();
        assert!(b >= a);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_duration(2.0).ends_with('s'));
        assert!(fmt_duration(0.002).ends_with("ms"));
        assert!(fmt_duration(2e-6).ends_with("µs"));
        assert!(fmt_duration(2e-9).ends_with("ns"));
    }
}
