//! Minimal JSON parser/serializer.
//!
//! Used for `artifacts/manifest.json`, serving configs, and bench reports.
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null). No serde in the offline crate set.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    // -- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = (start + len).min(self.b.len());
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let src = r#"{"a": 1, "b": [true, null, "x"], "c": {"d": -2.5}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").as_f64(), Some(1.0));
        assert_eq!(v.get("b").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").get("d").as_f64(), Some(-2.5));
        // Serialize then reparse — identical value.
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn numbers() {
        for (s, n) in [("0", 0.0), ("-1", -1.0), ("3.25", 3.25), ("1e3", 1000.0), ("-2.5e-2", -0.025)]
        {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(n), "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,", "\"abc", "tru", "1 2", "{\"a\" 1}", ""] {
            assert!(Json::parse(s).is_err(), "{s:?} should fail");
        }
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn get_on_missing_is_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
        assert_eq!(Json::Null.get("x"), &Json::Null);
    }

    #[test]
    fn integer_display_has_no_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }
}
