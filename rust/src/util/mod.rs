//! Shared utilities: RNG, statistics, timing, JSON, CLI parsing, thread pool.
//!
//! The offline build environment ships only the `xla` crate's dependency
//! closure (no serde/clap/criterion/rayon), so these substrates are built
//! in-repo and unit-tested like everything else.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timer;

/// Resident set size of the current process, in bytes (Linux).
///
/// Used by the Table 3 memory measurements. Returns 0 if `/proc` is
/// unavailable.
pub fn rss_bytes() -> u64 {
    let Ok(s) = std::fs::read_to_string("/proc/self/statm") else {
        return 0;
    };
    let mut it = s.split_whitespace();
    let _size = it.next();
    let resident: u64 = it.next().and_then(|v| v.parse().ok()).unwrap_or(0);
    resident * page_size()
}

fn page_size() -> u64 {
    // Linux default; avoids a libc dependency. Correct on this image.
    4096
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_nonzero_on_linux() {
        assert!(rss_bytes() > 0);
    }
}
