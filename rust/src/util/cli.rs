//! Tiny CLI argument parser (no clap in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse from the process environment, skipping argv[0].
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["serve", "--verbose", "--batch", "8", "--rate=2.5", "extra"]);
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_usize("batch", 0), 8);
        assert!((a.get_f64("rate", 0.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b"]);
        assert!(a.flag("a") && a.flag("b"));
        assert!(a.get("a").is_none());
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("model", "tiny"), "tiny");
        assert_eq!(a.get_usize("n", 7), 7);
    }

    #[test]
    fn eq_form() {
        let a = parse(&["--k=v=w"]);
        assert_eq!(a.get("k"), Some("v=w"));
    }
}
