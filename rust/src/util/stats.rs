//! Summary statistics for benchmark measurements.

/// Summary of a sample of measurements (e.g. per-iteration wallclock).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    /// Median absolute deviation (scaled by 1.4826 for normal consistency).
    pub mad: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute a summary from raw samples. Panics on an empty slice.
    pub fn from(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::from on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = percentile_sorted(&sorted, 50.0);
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = percentile_sorted(&devs, 50.0) * 1.4826;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
            mad,
            p95: percentile_sorted(&sorted, 95.0),
        }
    }
}

/// Linear-interpolated percentile of an already-sorted slice. `p` in [0,100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Online histogram with fixed log-spaced buckets, for latency metrics.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Bucket upper bounds (seconds), log-spaced.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    pub total: u64,
    pub sum: f64,
    pub max: f64,
}

impl Histogram {
    /// Buckets from `lo` to `hi` seconds, `n` log-spaced bounds.
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && n >= 2);
        let ratio = (hi / lo).powf(1.0 / (n - 1) as f64);
        let bounds: Vec<f64> = (0..n).map(|i| lo * ratio.powi(i as i32)).collect();
        let len = bounds.len();
        Histogram { bounds, counts: vec![0; len + 1], total: 0, sum: 0.0, max: 0.0 }
    }

    /// Default latency histogram: 10µs .. 100s.
    pub fn latency() -> Self {
        Self::new(1e-5, 100.0, 64)
    }

    pub fn record(&mut self, v: f64) {
        let idx = self.bounds.partition_point(|b| *b < v);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.sum / self.total as f64 }
    }

    /// Approximate quantile from bucket bounds. `q` is clamped to [0,1],
    /// so out-of-range requests degrade to the min/max bucket instead of
    /// walking off the count array.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return if i < self.bounds.len() { self.bounds[i] } else { self.max };
            }
        }
        self.max
    }

    /// One-shot p50/p95/p99 summary of this histogram. All fields are 0
    /// when the histogram is empty (quantiles of nothing are 0 by the
    /// same convention as [`Histogram::quantile`]).
    pub fn quantiles(&self) -> Quantiles {
        Quantiles {
            p50: self.quantile(0.5),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            mean: self.mean(),
            count: self.total,
            sum: self.sum,
        }
    }

    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bounds.len(), other.bounds.len());
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Cumulative-bucket snapshot in Prometheus histogram convention:
    /// `buckets[i] = (upper_bound, samples <= upper_bound)`, with the
    /// implicit `+Inf` bucket equal to `count` (the overflow bucket is
    /// folded there, not listed). The exporter turns this into native
    /// `_bucket`/`_sum`/`_count` series.
    pub fn hist_snapshot(&self) -> HistSnapshot {
        let mut acc = 0u64;
        let buckets = self
            .bounds
            .iter()
            .zip(&self.counts)
            .map(|(&b, &c)| {
                acc += c;
                (b, acc)
            })
            .collect();
        HistSnapshot { buckets, sum: self.sum, count: self.total, max: self.max }
    }
}

/// Snapshot of one [`Histogram`] as cumulative Prometheus-style buckets
/// (see [`Histogram::hist_snapshot`]). Plain data, all-empty by default,
/// so `Snapshot` can embed one per exported distribution.
///
/// Carries enough state (`max` for the overflow bucket) that two shards'
/// snapshots [`merge`](HistSnapshot::merge) losslessly and
/// [`quantile`](HistSnapshot::quantile) reproduces the live
/// [`Histogram::quantile`] exactly — the aggregate serving `Snapshot`
/// recomputes its latency quantiles from merged buckets instead of
/// averaging per-shard quantiles.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistSnapshot {
    /// `(le_bound, cumulative_count)` per finite bucket, ascending.
    pub buckets: Vec<(f64, u64)>,
    pub sum: f64,
    pub count: u64,
    /// Largest recorded sample — the quantile value of the implicit
    /// `+Inf` overflow bucket, mirroring [`Histogram::max`].
    pub max: f64,
}

impl HistSnapshot {
    /// Fold `other`'s samples into `self`. An empty (default) side adopts
    /// the other wholesale; two live snapshots must come from histograms
    /// with identical bucket layouts (cumulative counts sum bucketwise).
    pub fn merge(&mut self, other: &HistSnapshot) {
        if other.buckets.is_empty() {
            return;
        }
        if self.buckets.is_empty() {
            *self = other.clone();
            return;
        }
        assert_eq!(
            self.buckets.len(),
            other.buckets.len(),
            "HistSnapshot::merge across different bucket layouts"
        );
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            a.1 += b.1;
        }
        self.sum += other.sum;
        self.count += other.count;
        self.max = self.max.max(other.max);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    /// Bucket-bound quantile with the exact semantics of
    /// [`Histogram::quantile`] (same clamping, same `target.max(1)`
    /// rounding, overflow resolves to `max`), so merging one shard's
    /// snapshot into an empty one reproduces the shard's own quantiles.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64).max(1);
        for &(bound, cum) in &self.buckets {
            if cum >= target {
                return bound;
            }
        }
        self.max
    }

    /// One-shot p50/p95/p99 summary, mirroring [`Histogram::quantiles`].
    pub fn quantiles(&self) -> Quantiles {
        Quantiles {
            p50: self.quantile(0.5),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            mean: self.mean(),
            count: self.count,
            sum: self.sum,
        }
    }
}

/// p50/p95/p99 + mean/count/sum summary of one [`Histogram`], in the
/// histogram's native unit (seconds for the latency histograms).
///
/// Snapshot-friendly: plain `Copy` data, all-zero for an empty histogram
/// (via [`Quantiles::default`]), so `Snapshot` can embed one per tracked
/// distribution without optionality.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Quantiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub mean: f64,
    pub count: u64,
    pub sum: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_single() {
        let s = Summary::from(&[2.5]);
        assert_eq!(s.median, 2.5);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let clean = Summary::from(&[1.0, 1.1, 0.9, 1.05, 0.95]);
        let dirty = Summary::from(&[1.0, 1.1, 0.9, 1.05, 100.0]);
        // MAD barely moves; std explodes.
        assert!(dirty.mad < clean.mad * 3.0 + 0.5);
        assert!(dirty.std > clean.std * 10.0);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::latency();
        let mut x = 1e-4;
        for _ in 0..1000 {
            h.record(x);
            x *= 1.005;
        }
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert_eq!(h.total, 1000);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::latency();
        let mut b = Histogram::latency();
        a.record(0.001);
        b.record(0.010);
        a.merge(&b);
        assert_eq!(a.total, 2);
        assert!(a.max >= 0.010);
    }

    #[test]
    fn histogram_mean() {
        let mut h = Histogram::latency();
        h.record(1.0);
        h.record(3.0);
        assert!((h.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_empty_quantiles_are_zero() {
        let h = Histogram::latency();
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantiles(), Quantiles::default());
    }

    #[test]
    fn histogram_single_sample() {
        let mut h = Histogram::latency();
        h.record(0.004);
        // Every quantile of a one-sample histogram lands in the sample's
        // bucket: at least the sample, at most one log-bucket above it.
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(v >= 0.004 && v <= 0.004 * 1.3, "q={q} v={v}");
        }
        let s = h.quantiles();
        assert_eq!(s.count, 1);
        assert!((s.mean - 0.004).abs() < 1e-12);
    }

    #[test]
    fn histogram_out_of_range_quantile_clamps() {
        let mut h = Histogram::latency();
        h.record(0.001);
        h.record(0.010);
        assert_eq!(h.quantile(-0.5), h.quantile(0.0));
        assert_eq!(h.quantile(1.5), h.quantile(1.0));
        assert!(!h.quantile(f64::NAN).is_nan());
    }

    #[test]
    fn histogram_quantile_above_max_bucket_returns_max() {
        let mut h = Histogram::new(1e-3, 1.0, 4);
        h.record(50.0); // beyond the last bound → overflow bucket
        assert_eq!(h.quantile(0.99), 50.0);
    }

    #[test]
    fn hist_snapshot_is_cumulative() {
        let mut h = Histogram::new(1e-3, 1.0, 4);
        h.record(0.002); // bucket 1
        h.record(0.002);
        h.record(0.5); // bucket 3
        h.record(50.0); // overflow: folded into +Inf (count), not a bucket
        let s = h.hist_snapshot();
        assert_eq!(s.buckets.len(), 4);
        assert!(s.buckets.windows(2).all(|w| w[0].0 < w[1].0), "bounds ascend");
        assert!(s.buckets.windows(2).all(|w| w[0].1 <= w[1].1), "counts cumulative");
        assert_eq!(s.buckets.last().unwrap().1, 3, "finite buckets exclude overflow");
        assert_eq!(s.count, 4);
        assert!((s.sum - 50.504).abs() < 1e-9);
        assert_eq!(Histogram::latency().hist_snapshot().count, 0);
    }

    #[test]
    fn quantiles_summary_ordered() {
        let mut h = Histogram::latency();
        let mut x = 1e-4;
        for _ in 0..500 {
            h.record(x);
            x *= 1.01;
        }
        let q = h.quantiles();
        assert!(q.p50 <= q.p95 && q.p95 <= q.p99);
        assert_eq!(q.count, 500);
        assert!(q.sum > 0.0 && q.mean > 0.0);
    }
}
