//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 for seeding, xoshiro256++ for the stream — fast, reproducible,
//! and adequate for weight init, synthetic corpora, and property testing.
//! (The image has no `rand` crate; `rand_core` alone has no generators.)

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Rejection-free modulo is fine for our (non-crypto) uses.
        self.next_u64() % n
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a slice with N(0, std^2) samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.next_gaussian() as f32 * std;
        }
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(11);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 6);
            assert!((3..=6).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi);
    }
}
