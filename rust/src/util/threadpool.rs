//! A small scoped thread pool for data-parallel loops.
//!
//! Used by the blocked matmul and batch execution paths (no rayon in the
//! offline crate set). Work is expressed as "run `f(chunk_index)` for
//! indices 0..n" with the closure shared across a fixed set of workers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Number of worker threads to use for data-parallel loops.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("BDA_NUM_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            })
            .max(1)
    })
}

/// Run `f(i)` for every `i in 0..n`, distributing indices across up to
/// `num_threads()` scoped workers via an atomic counter (work stealing by
/// chunk). `f` must be `Sync`; per-index work should be coarse enough to
/// amortize the atomic fetch.
pub fn parallel_for(n: usize, f: impl Fn(usize) + Sync) {
    parallel_for_with(n, num_threads(), f);
}

/// [`parallel_for`] with an explicit worker count instead of the
/// `BDA_NUM_THREADS` global. Lets callers (and determinism tests) pin the
/// parallelism width per call — e.g. the paged-attention property tests
/// sweep worker counts inside one process, which the env-var route cannot
/// do because [`num_threads`] is latched on first use.
pub fn parallel_for_with(n: usize, workers: usize, f: impl Fn(usize) + Sync) {
    let workers = workers.clamp(1, n.max(1));
    if workers <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Shared-across-workers raw mutable pointer for data-parallel writers
/// whose output regions are provably disjoint (blocked GEMM row panels,
/// paged-attention head slices). The accessor keeps closures capturing the
/// whole (Sync) struct rather than the raw-pointer field (edition-2021
/// disjoint capture). Safety is the *caller's* obligation: never write
/// overlapping regions from different workers.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(pub(crate) *mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}
impl SendPtr {
    pub(crate) fn get(self) -> *mut f32 {
        self.0
    }
}

/// Run `f(chunk_start, chunk_end)` over contiguous chunks of `0..n`,
/// one chunk per worker invocation; `chunk` is the chunk size.
pub fn parallel_chunks(n: usize, chunk: usize, f: impl Fn(usize, usize) + Sync) {
    let chunk = chunk.max(1);
    let n_chunks = n.div_ceil(chunk);
    parallel_for(n_chunks, |ci| {
        let lo = ci * chunk;
        let hi = (lo + chunk).min(n);
        f(lo, hi);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices_once() {
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn chunks_cover_range() {
        let total = AtomicU64::new(0);
        parallel_chunks(103, 10, |lo, hi| {
            assert!(hi <= 103 && lo < hi);
            total.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 103);
    }

    #[test]
    fn zero_work_ok() {
        parallel_for(0, |_| panic!("should not run"));
        parallel_chunks(0, 8, |_, _| panic!("should not run"));
    }

    #[test]
    fn explicit_worker_counts_cover_all_indices() {
        for workers in [1, 2, 8, 64] {
            let n = 257;
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            parallel_for_with(n, workers, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "workers {workers} index {i}");
            }
        }
    }

    #[test]
    fn single_item() {
        let total = AtomicU64::new(0);
        parallel_for(1, |i| {
            assert_eq!(i, 0);
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 1);
    }
}
