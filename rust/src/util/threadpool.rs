//! Persistent parked worker pool for data-parallel loops.
//!
//! The serving hot path dispatches one data-parallel region per layer per
//! decode step (blocked GEMM row panels plus the paged-attention kernel).
//! The original implementation spawned and joined *scoped OS threads* for
//! every such region — one spawn/join cycle per layer per step. This
//! module replaces that with a long-lived [`ThreadPool`]: workers are
//! created once, parked on a condvar between dispatches, and woken per
//! region via an epoch counter. [`parallel_for`], [`parallel_for_with`],
//! and [`parallel_chunks`] keep their exact signatures and index-space
//! contracts as thin wrappers over the process-wide pool ([`global`]), so
//! callers (blocked GEMM, paged attention, batched decode) migrated
//! without change. The pre-pool implementation is retained as
//! [`scoped_parallel_for_with`] — the spawn-overhead baseline measured by
//! `benches/decode_throughput.rs` and an independent execution strategy
//! the pool lifecycle tests compare against.
//!
//! # Determinism contract
//!
//! Work *assignment* is dynamic (participants claim indices from a shared
//! atomic counter), but every consumer keeps per-item float work
//! self-contained: work items never share accumulators and per-item
//! accumulation order is fixed. Output is therefore bit-identical at any
//! worker count — the invariant stated centrally in [`crate::engine`] and
//! enforced by `tests/prop_paged_parallel.rs` at worker counts {1, 2, 8}.
//!
//! # Per-worker scratch arenas
//!
//! Because workers are persistent, `thread_local!` scratch touched inside
//! a work item (e.g. the paged-attention score buffer in
//! [`crate::attention::paged`]) now lives across layers *and* decode
//! steps: it is allocated once per worker per process instead of once per
//! dispatch. This is the pool's second win besides spawn amortization.
//!
//! # The `BDA_NUM_THREADS` latch
//!
//! [`num_threads`] reads `BDA_NUM_THREADS` **once** and latches the result
//! for the process lifetime; the global pool is sized with it on first
//! use. Setting the variable after the first dispatch has no effect. To
//! make the latch visible, the resolved worker count (and whether it came
//! from the environment or from `available_parallelism`) is logged to
//! stderr once when the global pool is constructed. Code that needs to
//! vary the width inside one process passes an explicit count to
//! [`parallel_for_with`] (which honors widths above the pool size with
//! one-off scoped threads) or constructs its own [`ThreadPool`].

use std::cell::{Cell, RefCell};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

thread_local! {
    /// Ambient pool override stack for the calling thread (see
    /// [`with_pool`]). Empty means [`parallel_for`]-family wrappers
    /// dispatch on the process-wide pool.
    static AMBIENT_POOL: RefCell<Vec<Arc<ThreadPool>>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` with `pool` as this thread's ambient pool: every
/// [`parallel_for`] / [`parallel_for_with`] / [`parallel_chunks`] region
/// dispatched *by this thread* inside `f` — notably the GEMMs issued
/// through the tensor `matmul` wrappers — runs on `pool` instead of the
/// process-wide one. This is how the paged engine routes **all** of a
/// decode step's parallel work (attention and GEMMs alike) onto its own
/// worker set (`PagedNativeBackend::with_thread_pool`): per-engine
/// isolation for multi-worker sharding without threading a pool handle
/// through every tensor-level call signature.
///
/// The override is a stack (nesting restores the outer pool) and is
/// per-thread only — pool *workers* never inherit it, which is irrelevant
/// in practice because nested dispatch from inside a work item runs
/// inline. Output is unaffected by pool routing (the determinism
/// contract); this is purely a scheduling-isolation knob.
pub fn with_pool<R>(pool: &Arc<ThreadPool>, f: impl FnOnce() -> R) -> R {
    AMBIENT_POOL.with(|s| s.borrow_mut().push(Arc::clone(pool)));
    struct Guard;
    impl Drop for Guard {
        fn drop(&mut self) {
            AMBIENT_POOL.with(|s| {
                s.borrow_mut().pop();
            });
        }
    }
    let _guard = Guard;
    f()
}

/// The pool the calling thread's `parallel_*` wrappers currently dispatch
/// on: the innermost [`with_pool`] override, or the process-wide pool.
pub fn current() -> Arc<ThreadPool> {
    AMBIENT_POOL
        .with(|s| s.borrow().last().cloned())
        .unwrap_or_else(|| Arc::clone(global()))
}

/// Worker count of the calling thread's current dispatch pool — what
/// panel-sizing heuristics (e.g. the blocked GEMM) should divide work by.
pub fn current_workers() -> usize {
    // Avoid constructing the global pool just to size panels when no
    // override is active.
    match AMBIENT_POOL.with(|s| s.borrow().last().cloned()) {
        Some(pool) => pool.workers(),
        None => num_threads(),
    }
}

/// Process-unique token per thread (0 is reserved for "no owner"), used to
/// detect same-thread re-entry into a pool's dispatch path without relying
/// on the unstable `ThreadId::as_u64`.
fn thread_token() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TOKEN: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TOKEN.with(|t| *t)
}

/// Number of worker threads used for data-parallel loops, resolved from
/// `BDA_NUM_THREADS` (falling back to `available_parallelism`).
///
/// **Latch:** the value is computed once and cached for the process
/// lifetime — later changes to the environment variable are ignored. The
/// global pool logs the resolved count once at construction (see
/// [`global`]) so the latched value is observable.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("BDA_NUM_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            })
            .max(1)
    })
}

/// The process-wide pool, created on first use with [`num_threads`]
/// workers. Announces the resolved worker count (and its source) exactly
/// once, at construction — the observable record of the
/// `BDA_NUM_THREADS` latch. The announcement goes through
/// [`crate::obs::announce`], so library embedders can silence it with
/// `BDA_QUIET=1` instead of getting unconditional stderr.
pub fn global() -> &'static Arc<ThreadPool> {
    static POOL: OnceLock<Arc<ThreadPool>> = OnceLock::new();
    POOL.get_or_init(|| {
        let n = num_threads();
        let source = if std::env::var_os("BDA_NUM_THREADS").is_some() {
            "BDA_NUM_THREADS"
        } else {
            "available_parallelism"
        };
        crate::obs::announce(&format!(
            "[bda] thread pool: {n} worker{} (from {source}; latched for the process lifetime)",
            if n == 1 { "" } else { "s" }
        ));
        Arc::new(ThreadPool::new(n))
    })
}

/// One dispatched parallel region, type-erased so parked workers can run
/// it: a raw pointer to the dispatcher's stack-held task closure plus a
/// monomorphized trampoline that restores its type. The dispatch barrier
/// keeps the pointee alive — [`ThreadPool::run`] does not return until
/// every worker has reported completion of this epoch.
#[derive(Clone, Copy)]
struct Job {
    task: *const (),
    /// Calls `task` at its concrete closure type.
    call: unsafe fn(*const ()),
}

// SAFETY: `task` points at a `Sync` closure owned by the dispatching
// frame, which strictly outlives all worker access (only ticket-holding
// workers touch the job, and the barrier in `ThreadPool::run` waits for
// every one of them).
unsafe impl Send for Job {}

unsafe fn trampoline<F: Fn() + Sync>(task: *const ()) {
    let f = &*task.cast::<F>();
    f();
}

fn erase<F: Fn() + Sync>(task: &F) -> Job {
    Job { task: (task as *const F).cast(), call: trampoline::<F> }
}

struct State {
    /// Bumped once per dispatch; workers track the last epoch they served.
    epoch: u64,
    job: Option<Job>,
    /// Unclaimed worker-participation slots of the current epoch. Claimed
    /// under this lock, so a dispatch narrower than the pool lets surplus
    /// workers skip the job — and the barrier — entirely without ever
    /// touching the dispatcher's frame.
    tickets: usize,
    /// Ticket holders that have not yet finished with the current epoch.
    active: usize,
    /// First worker panic of the current epoch, rethrown by the dispatcher.
    panic: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between dispatches.
    work: Condvar,
    /// The dispatcher parks here until `active` drops to zero.
    done: Condvar,
}

thread_local! {
    /// True on pool worker threads; a nested dispatch from inside a work
    /// item runs inline instead of deadlocking on the barrier.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// A persistent set of parked worker threads for data-parallel loops.
///
/// A pool of width `w` owns `w - 1` OS threads; the dispatching thread is
/// always participant zero, so `ThreadPool::new(1)` spawns nothing and
/// runs everything inline. Dropping the pool wakes and joins every
/// worker. Most code uses the process-wide instance via [`global`] /
/// [`parallel_for`]; the serving engine can own a dedicated pool
/// (`PagedNativeBackend::with_thread_pool`) — groundwork for multi-worker
/// sharding where each engine shard gets its own worker set.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// Serializes dispatches: concurrent dispatchers from other threads
    /// block here (regions run back to back, each at full width) rather
    /// than degrading to serial execution.
    gate: Mutex<()>,
    /// [`thread_token`] of the thread currently holding `gate` (0 = none);
    /// lets same-thread re-entry — a work item executed by the dispatcher
    /// that opens another region on this pool — fall back inline instead
    /// of self-deadlocking on `gate`.
    gate_owner: AtomicU64,
    width: usize,
}

impl ThreadPool {
    /// Create a pool of the given width (clamped to at least 1). Workers
    /// are spawned immediately and park until the first dispatch.
    pub fn new(workers: usize) -> ThreadPool {
        let width = workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                tickets: 0,
                active: 0,
                panic: None,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..width - 1)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("bda-pool-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { shared, handles, gate: Mutex::new(()), gate_owner: AtomicU64::new(0), width }
    }

    /// Parallelism width of this pool (spawned workers + the dispatcher).
    pub fn workers(&self) -> usize {
        self.width
    }

    /// Run `f(i)` for every `i in 0..n` at up to `width` participants
    /// (capped by the pool width), blocking until all items finish.
    ///
    /// Inline fast path: zero- and one-item dispatches, width 1, and
    /// nested dispatches (from inside a pool worker, or from the thread
    /// that already holds this pool's dispatch gate) run serially on the
    /// calling thread — identical output by the determinism contract,
    /// with no parking or wakeups involved. Concurrent dispatches from
    /// *other* threads queue on the gate and run back to back, each at
    /// full width. Panics in work items are propagated to the caller
    /// after the barrier, as the scoped implementation did.
    pub fn run<F: Fn(usize) + Sync>(&self, n: usize, width: usize, f: F) {
        let width = width.clamp(1, n.max(1)).min(self.width);
        if n <= 1 || width <= 1 || self.handles.is_empty() || IN_POOL_WORKER.with(Cell::get) {
            return run_serial(n, &f);
        }
        let token = thread_token();
        if self.gate_owner.load(Ordering::Relaxed) == token {
            // Same-thread re-entry: this thread is mid-dispatch on this
            // pool (a work item it executes opened another region);
            // blocking on the gate would self-deadlock.
            return run_serial(n, &f);
        }
        let gate = self.gate.lock().unwrap();
        self.gate_owner.store(token, Ordering::Relaxed);

        // The region — index counter and item closure — lives in this
        // frame; `task` is what gets type-erased and handed to the parked
        // workers that win a participation ticket.
        let next = AtomicUsize::new(0);
        let task = || loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            f(i);
        };
        let job = erase(&task);
        // The dispatcher holds one participant slot; only this many
        // workers join the region (and its completion barrier).
        let worker_participants = (width - 1).min(self.handles.len());
        {
            let mut st = self.shared.state.lock().unwrap();
            st.epoch += 1;
            st.job = Some(job);
            st.tickets = worker_participants;
            st.active = worker_participants;
        }
        self.shared.work.notify_all();

        // The dispatcher is participant zero. A panic here must still wait
        // for the barrier: ticket holders borrow into this frame.
        let caller = catch_unwind(AssertUnwindSafe(&task));

        let worker_panic = {
            let mut st = self.shared.state.lock().unwrap();
            while st.active > 0 {
                st = self.shared.done.wait(st).unwrap();
            }
            st.job = None;
            st.panic.take()
        };
        // Clear ownership and release the gate *before* rethrowing:
        // unwinding past a held guard would poison it and wedge every
        // later dispatch.
        self.gate_owner.store(0, Ordering::Relaxed);
        drop(gate);
        if let Err(p) = caller {
            resume_unwind(p);
        }
        if let Some(p) = worker_panic {
            resume_unwind(p);
        }
    }
}

impl Drop for ThreadPool {
    /// Wake and join every worker. Any in-flight dispatch has returned by
    /// the time drop can run (dispatch borrows the pool), so no work is
    /// lost.
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn run_serial<F: Fn(usize)>(n: usize, f: &F) {
    for i in 0..n {
        f(i);
    }
}

/// Body of a parked worker: wait for a new epoch, claim a participation
/// ticket, run the posted job, and report completion. Tickets are claimed
/// under the state lock and preset equal to `active`, so the barrier
/// counts exactly the workers that touched the job; surplus workers (and
/// stragglers that missed an already-completed epoch) skip both. A worker
/// that sleeps through an entire epoch simply never sees it — epochs only
/// advance after their barrier completes, so nothing is lost.
fn worker_loop(shared: &Shared, index: usize) {
    IN_POOL_WORKER.with(|w| w.set(true));
    // Worker-id tagging for trace tracks. When tracing is off at spawn
    // this is skipped — the recorder falls back to the thread's builder
    // name (`bda-pool-{index}`, identical) if tracing turns on later, and
    // skipping avoids eagerly allocating a ring per worker.
    if crate::obs::enabled() {
        crate::obs::set_thread_label(&format!("bda-pool-{index}"));
    }
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = shared.work.wait(st).unwrap();
            }
            seen = st.epoch;
            if st.tickets == 0 {
                // Not a participant of this region; never touches the
                // dispatcher's frame, not part of the barrier.
                continue;
            }
            st.tickets -= 1;
            st.job.expect("unclaimed tickets outlive their job")
        };
        // SAFETY: ticket holders are counted in `active`; the dispatcher
        // blocks until every one of them decrements below, so the task
        // closure in its frame is alive for the duration of this call.
        let work_start = crate::obs::enabled().then(std::time::Instant::now);
        let result = catch_unwind(AssertUnwindSafe(|| unsafe { (job.call)(job.task) }));
        if let Some(t) = work_start {
            crate::obs::span_at(crate::obs::Phase::Work, seen, t, t.elapsed());
        }
        let mut st = shared.state.lock().unwrap();
        if let Err(p) = result {
            if st.panic.is_none() {
                st.panic = Some(p);
            }
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done.notify_all();
        }
    }
}

/// Run `f(i)` for every `i in 0..n` across the calling thread's current
/// dispatch pool at its full width — the process-wide pool
/// ([`num_threads`] workers) unless a [`with_pool`] override is active.
/// `f` must be `Sync`; per-index work should be coarse enough to amortize
/// the atomic fetch.
pub fn parallel_for(n: usize, f: impl Fn(usize) + Sync) {
    let pool = current();
    pool.run(n, pool.workers(), f);
}

/// [`parallel_for`] with an explicit participant count instead of the
/// `BDA_NUM_THREADS` default. Lets callers and determinism tests pin the
/// parallelism width per call — e.g. the paged-attention property tests
/// sweep worker counts inside one process, which the env-var route cannot
/// do because [`num_threads`] is latched on first use. Widths up to the
/// global pool width dispatch on the parked pool; wider requests (a
/// test/bench case — production never exceeds the pool) are honored with
/// one-off scoped threads so the requested parallelism is real even when
/// the pool was sized small.
pub fn parallel_for_with(n: usize, workers: usize, f: impl Fn(usize) + Sync) {
    let pool = current();
    if workers > pool.workers() {
        return scoped_parallel_for_with(n, workers, f);
    }
    pool.run(n, workers, f);
}

/// The pre-pool implementation: spawn `workers` scoped OS threads for this
/// one call and join them before returning. Retained as the spawn-overhead
/// baseline for the `decode_throughput` dispatch benchmark and as an
/// independent execution strategy the pool lifecycle tests compare
/// against; production code paths all go through the parked pool.
pub fn scoped_parallel_for_with(n: usize, workers: usize, f: impl Fn(usize) + Sync) {
    let workers = workers.clamp(1, n.max(1));
    if workers <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Shared-across-workers raw mutable pointer for data-parallel writers
/// whose output regions are provably disjoint (blocked GEMM row panels,
/// paged-attention head slices). The accessor keeps closures capturing the
/// whole (Sync) struct rather than the raw-pointer field (edition-2021
/// disjoint capture). Safety is the *caller's* obligation: never write
/// overlapping regions from different workers.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(pub(crate) *mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}
impl SendPtr {
    pub(crate) fn get(self) -> *mut f32 {
        self.0
    }
}

/// Run `f(chunk_start, chunk_end)` over contiguous chunks of `0..n`,
/// one chunk per worker invocation; `chunk` is the chunk size.
pub fn parallel_chunks(n: usize, chunk: usize, f: impl Fn(usize, usize) + Sync) {
    let chunk = chunk.max(1);
    let n_chunks = n.div_ceil(chunk);
    parallel_for(n_chunks, |ci| {
        let lo = ci * chunk;
        let hi = (lo + chunk).min(n);
        f(lo, hi);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices_once() {
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn chunks_cover_range() {
        let total = AtomicU64::new(0);
        parallel_chunks(103, 10, |lo, hi| {
            assert!(hi <= 103 && lo < hi);
            total.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 103);
    }

    #[test]
    fn zero_work_ok() {
        parallel_for(0, |_| panic!("should not run"));
        parallel_chunks(0, 8, |_, _| panic!("should not run"));
    }

    #[test]
    fn explicit_worker_counts_cover_all_indices() {
        for workers in [1, 2, 8, 64] {
            let n = 257;
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            parallel_for_with(n, workers, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "workers {workers} index {i}");
            }
        }
    }

    #[test]
    fn single_item() {
        let total = AtomicU64::new(0);
        parallel_for(1, |i| {
            assert_eq!(i, 0);
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 1);
    }

    // ---- persistent-pool lifecycle -------------------------------------

    #[test]
    fn repeated_dispatches_match_scoped_execution() {
        // One long-lived pool, many dispatches: results must be identical
        // to a fresh scoped-thread execution of the same index space
        // (no state may leak between dispatches).
        let pool = ThreadPool::new(4);
        for round in 0..16u64 {
            let n = 129;
            let pooled: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            let scoped: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.run(n, 4, |i| {
                pooled[i].fetch_add(round * n as u64 + i as u64, Ordering::Relaxed);
            });
            scoped_parallel_for_with(n, 4, |i| {
                scoped[i].fetch_add(round * n as u64 + i as u64, Ordering::Relaxed);
            });
            for i in 0..n {
                assert_eq!(
                    pooled[i].load(Ordering::Relaxed),
                    scoped[i].load(Ordering::Relaxed),
                    "round {round} index {i}"
                );
            }
        }
    }

    #[test]
    fn drop_joins_all_workers() {
        // Drop must wake the parked workers and join every handle; a lost
        // wakeup or leaked worker shows up here as a hang.
        let pool = ThreadPool::new(8);
        let hits = AtomicU64::new(0);
        pool.run(100, 8, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
        drop(pool);
    }

    #[test]
    fn zero_and_one_item_dispatch_is_inline() {
        let pool = ThreadPool::new(4);
        pool.run(0, 4, |_| panic!("zero-item dispatch must not run the body"));
        let caller = std::thread::current().id();
        let seen = Mutex::new(None);
        pool.run(1, 4, |i| {
            assert_eq!(i, 0);
            *seen.lock().unwrap() = Some(std::thread::current().id());
        });
        assert_eq!(
            *seen.lock().unwrap(),
            Some(caller),
            "single-item dispatch must take the inline fast path on the caller"
        );
    }

    #[test]
    fn nested_dispatch_runs_inline_without_deadlock() {
        // Inner dispatches come from pool workers (worker-flag fallback)
        // and from the dispatching thread itself (gate fallback); both
        // must run inline rather than deadlock on the barrier.
        let pool = ThreadPool::new(4);
        let total = AtomicU64::new(0);
        pool.run(4, 4, |_| {
            pool.run(8, 4, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn concurrent_dispatchers_queue_without_deadlock() {
        // Two threads dispatching on one pool: the loser must block on
        // the gate and then run at full width (not silently degrade),
        // and both regions must complete exactly once per index.
        let pool = ThreadPool::new(4);
        let n = 300;
        let a: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let b: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        std::thread::scope(|s| {
            s.spawn(|| {
                pool.run(n, 4, |i| {
                    a[i].fetch_add(1, Ordering::Relaxed);
                });
            });
            s.spawn(|| {
                pool.run(n, 4, |i| {
                    b[i].fetch_add(1, Ordering::Relaxed);
                });
            });
        });
        for i in 0..n {
            assert_eq!(a[i].load(Ordering::Relaxed), 1, "region A index {i}");
            assert_eq!(b[i].load(Ordering::Relaxed), 1, "region B index {i}");
        }
    }

    #[test]
    fn width_above_pool_size_is_capped() {
        let pool = ThreadPool::new(2);
        let hits: Vec<AtomicU64> = (0..50).map(|_| AtomicU64::new(0)).collect();
        pool.run(50, 64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    // ---- ambient pool override (per-engine GEMM pools) ------------------

    #[test]
    fn with_pool_overrides_and_restores_current() {
        let dedicated = Arc::new(ThreadPool::new(3));
        let inner = Arc::new(ThreadPool::new(2));
        let before = current();
        with_pool(&dedicated, || {
            assert!(Arc::ptr_eq(&current(), &dedicated));
            assert_eq!(current_workers(), 3);
            // Nesting stacks: the innermost override wins, then unwinds.
            with_pool(&inner, || {
                assert!(Arc::ptr_eq(&current(), &inner));
                assert_eq!(current_workers(), 2);
            });
            assert!(Arc::ptr_eq(&current(), &dedicated));
        });
        assert!(Arc::ptr_eq(&current(), &before), "override must restore the outer pool");
    }

    #[test]
    fn with_pool_routes_wrapper_dispatches() {
        // parallel_for under an override must produce identical coverage
        // (the determinism contract makes routing unobservable in output).
        let dedicated = Arc::new(ThreadPool::new(2));
        let n = 301;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        with_pool(&dedicated, || {
            parallel_for(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            parallel_chunks(n, 16, |lo, hi| {
                for i in lo..hi {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 2, "index {i}");
        }
    }

    #[test]
    fn with_pool_restores_on_panic() {
        let dedicated = Arc::new(ThreadPool::new(2));
        let before = current();
        let result = catch_unwind(AssertUnwindSafe(|| {
            with_pool(&dedicated, || panic!("boom"));
        }));
        assert!(result.is_err());
        assert!(Arc::ptr_eq(&current(), &before), "guard must pop the override on unwind");
    }

    #[test]
    fn worker_panic_propagates_to_dispatcher() {
        let pool = ThreadPool::new(4);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(64, 4, |i| {
                if i == 33 {
                    panic!("boom at {i}");
                }
            });
        }));
        assert!(result.is_err(), "a work-item panic must reach the dispatcher");
        // The pool must survive the panic and serve later dispatches.
        let hits = AtomicU64::new(0);
        pool.run(10, 4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }
}
