//! One-sided Jacobi SVD.
//!
//! Used by `model::lowrank` to build the low-rank-pruned baselines of
//! Table 3 (and the structured-pruning reference of Fig. 2a uses singular
//! values for sanity checks). One-sided Jacobi is simple, accurate, and
//! fast enough for the projection-sized matrices we factor offline.

use crate::tensor::Tensor;

/// Thin SVD: A (m×n, m ≥ n after internal transpose handling) = U Σ V^T,
/// with U m×n, Σ length n (descending), V n×n.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Tensor,
    pub s: Vec<f32>,
    pub v: Tensor,
}

/// Compute the thin SVD of a 2-D tensor via one-sided Jacobi rotations.
pub fn svd(a: &Tensor) -> Svd {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape[0], a.shape[1]);
    if m < n {
        // SVD(A^T) = V Σ U^T
        let t = svd(&a.transpose());
        return Svd { u: t.v, s: t.s, v: t.u };
    }

    // Work on columns of G = A (m×n); one-sided Jacobi orthogonalizes G's
    // columns: G -> U Σ, accumulating rotations into V.
    let mut g: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let col = |g: &Vec<f64>, j: usize, i: usize| g[i * n + j];
    let max_sweeps = 60;
    let eps = 1e-14;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries over columns p, q.
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    let gp = col(&g, p, i);
                    let gq = col(&g, q, i);
                    app += gp * gp;
                    aqq += gq * gq;
                    apq += gp * gq;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let gp = g[i * n + p];
                    let gq = g[i * n + q];
                    g[i * n + p] = c * gp - s * gq;
                    g[i * n + q] = s * gp + c * gq;
                }
                for i in 0..n {
                    let vp = v[i * n + p];
                    let vq = v[i * n + q];
                    v[i * n + p] = c * vp - s * vq;
                    v[i * n + q] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-12 {
            break;
        }
    }

    // Singular values = column norms of G; U = G normalized.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sigma = vec![0.0f64; n];
    for j in 0..n {
        sigma[j] = (0..m).map(|i| g[i * n + j] * g[i * n + j]).sum::<f64>().sqrt();
    }
    order.sort_by(|&a, &b| sigma[b].partial_cmp(&sigma[a]).unwrap());

    let mut u = Tensor::zeros(&[m, n]);
    let mut vt = Tensor::zeros(&[n, n]);
    let mut s = vec![0.0f32; n];
    for (jj, &j) in order.iter().enumerate() {
        s[jj] = sigma[j] as f32;
        let inv = if sigma[j] > 0.0 { 1.0 / sigma[j] } else { 0.0 };
        for i in 0..m {
            *u.at_mut(i, jj) = (g[i * n + j] * inv) as f32;
        }
        for i in 0..n {
            *vt.at_mut(i, jj) = v[i * n + j] as f32;
        }
    }
    Svd { u, s, v: vt }
}

/// Rank-r truncation: returns (U_r Σ_r, V_r) so that A ≈ (UΣ) V^T — the
/// low-rank factors a pruning method would store.
pub fn truncated_svd(a: &Tensor, r: usize) -> (Tensor, Tensor) {
    let d = svd(a);
    let n = d.s.len();
    let r = r.min(n);
    let m = d.u.shape[0];
    let mut us = Tensor::zeros(&[m, r]);
    for i in 0..m {
        for j in 0..r {
            *us.at_mut(i, j) = d.u.at(i, j) * d.s[j];
        }
    }
    let nv = d.v.shape[0];
    let mut vr = Tensor::zeros(&[nv, r]);
    for i in 0..nv {
        for j in 0..r {
            *vr.at_mut(i, j) = d.v.at(i, j);
        }
    }
    (us, vr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul::matmul;

    fn reconstruct(d: &Svd) -> Tensor {
        let (m, n) = (d.u.shape[0], d.s.len());
        let mut us = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                *us.at_mut(i, j) = d.u.at(i, j) * d.s[j];
            }
        }
        matmul(&us, &d.v.transpose())
    }

    #[test]
    fn reconstructs_random() {
        let a = Tensor::randn(&[8, 5], 1.0, 1);
        let d = svd(&a);
        let r = reconstruct(&d);
        assert!(r.max_abs_diff(&a) < 1e-4, "diff {}", r.max_abs_diff(&a));
    }

    #[test]
    fn wide_matrix() {
        let a = Tensor::randn(&[4, 9], 1.0, 2);
        let d = svd(&a);
        let r = reconstruct(&d);
        assert!(r.max_abs_diff(&a) < 1e-4);
    }

    #[test]
    fn singular_values_descending_nonneg() {
        let a = Tensor::randn(&[10, 6], 1.0, 3);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(d.s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn orthonormal_factors() {
        let a = Tensor::randn(&[7, 4], 1.0, 4);
        let d = svd(&a);
        let utu = matmul(&d.u.transpose(), &d.u);
        let vtv = matmul(&d.v.transpose(), &d.v);
        assert!(utu.max_abs_diff(&Tensor::eye(4)) < 1e-4);
        assert!(vtv.max_abs_diff(&Tensor::eye(4)) < 1e-4);
    }

    #[test]
    fn rank_deficient_exact() {
        let u = Tensor::randn(&[8, 2], 1.0, 5);
        let vt = Tensor::randn(&[2, 6], 1.0, 6);
        let a = matmul(&u, &vt);
        let d = svd(&a);
        assert!(d.s[1] > 1e-3);
        assert!(d.s[2] < 1e-4 * d.s[0]);
    }

    #[test]
    fn truncated_is_best_rank_r() {
        // Truncating a rank-2 matrix at r=2 is exact.
        let u = Tensor::randn(&[6, 2], 1.0, 7);
        let vt = Tensor::randn(&[2, 5], 1.0, 8);
        let a = matmul(&u, &vt);
        let (us, v) = truncated_svd(&a, 2);
        let r = matmul(&us, &v.transpose());
        assert!(r.max_abs_diff(&a) < 1e-4);
        assert_eq!(us.shape, vec![6, 2]);
        assert_eq!(v.shape, vec![5, 2]);
    }

    #[test]
    fn known_diagonal() {
        let a = Tensor::from_vec(vec![3.0, 0.0, 0.0, 2.0], &[2, 2]);
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-5);
        assert!((d.s[1] - 2.0).abs() < 1e-5);
    }
}
