//! Dense linear algebra substrate: LU solve (the BD coefficient solve),
//! QR with column pivoting (the PIFA-style baseline), and one-sided Jacobi
//! SVD (low-rank pruning for Table 3).

pub mod lu;
pub mod qr;
pub mod svd;

pub use lu::{lu_factor, lu_solve_matrix, solve_xa_b, Lu};
pub use qr::qr_column_pivoting;
pub use svd::{svd, truncated_svd, Svd};
