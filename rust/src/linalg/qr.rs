//! Householder QR with column pivoting (Businger & Golub 1971).
//!
//! This powers the *PIFA-style attention* baseline (§4.1 of the paper):
//! PIFA selects basis rows via QR with column pivoting, which yields a
//! *different, non-contiguous* pivot set per head — the source of its
//! scattered memory traffic that BDA's contiguous first/last-r basis avoids.

use crate::tensor::Tensor;

/// Result of QR with column pivoting on A (m×n): the pivot order and the
/// R factor. `pivots[j]` is the original column index chosen at step j,
/// ordered by decreasing residual column norm.
#[derive(Clone, Debug)]
pub struct PivotedQr {
    /// Column pivot order (length n).
    pub pivots: Vec<usize>,
    /// R factor (min(m,n) × n), in pivoted column order.
    pub r: Tensor,
    /// Diagonal magnitudes of R — numerical-rank signal.
    pub r_diag: Vec<f64>,
}

/// QR with column pivoting via Householder reflections. Returns pivots in
/// selection order. O(mn·min(m,n)).
pub fn qr_column_pivoting(a: &Tensor) -> PivotedQr {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape[0], a.shape[1]);
    let steps = m.min(n);
    let mut work = a.clone(); // gets overwritten with R above the diagonal
    let mut pivots: Vec<usize> = (0..n).collect();

    // Running squared column norms (updated, recomputed on cancellation).
    let mut norms: Vec<f64> = (0..n)
        .map(|j| (0..m).map(|i| (work.at(i, j) as f64).powi(2)).sum())
        .collect();
    let mut r_diag = Vec::with_capacity(steps);

    for k in 0..steps {
        // Pivot: column with max residual norm among k..n.
        let (pj, _) = norms[k..]
            .iter()
            .enumerate()
            .fold((0usize, -1.0f64), |(bj, bv), (j, &v)| if v > bv { (j, v) } else { (bj, bv) });
        let pj = pj + k;
        if pj != k {
            for i in 0..m {
                let tmp = work.at(i, k);
                *work.at_mut(i, k) = work.at(i, pj);
                *work.at_mut(i, pj) = tmp;
            }
            norms.swap(k, pj);
            pivots.swap(k, pj);
        }

        // Householder vector for column k, rows k..m.
        let alpha: f64 = (k..m).map(|i| (work.at(i, k) as f64).powi(2)).sum::<f64>().sqrt();
        r_diag.push(alpha);
        if alpha == 0.0 {
            continue; // exactly rank-deficient here; remaining cols are 0 too
        }
        let x0 = work.at(k, k) as f64;
        let sign = if x0 >= 0.0 { 1.0 } else { -1.0 };
        let mut v: Vec<f64> = (k..m).map(|i| work.at(i, k) as f64).collect();
        v[0] += sign * alpha;
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 > 0.0 {
            // Apply H = I - 2 v v^T / (v^T v) to columns k..n.
            for j in k..n {
                let dot: f64 =
                    (k..m).map(|i| v[i - k] * work.at(i, j) as f64).sum::<f64>();
                let scale = 2.0 * dot / vnorm2;
                for i in k..m {
                    *work.at_mut(i, j) -= (scale * v[i - k]) as f32;
                }
            }
        }
        // R(k,k) = -sign*alpha by construction; force exact value.
        *work.at_mut(k, k) = (-sign * alpha) as f32;

        // Downdate column norms, with recompute on heavy cancellation.
        for j in (k + 1)..n {
            let rkj = work.at(k, j) as f64;
            let updated = norms[j] - rkj * rkj;
            norms[j] = if updated < 1e-10 * norms[j].max(1e-300) || updated < 0.0 {
                ((k + 1)..m).map(|i| (work.at(i, j) as f64).powi(2)).sum()
            } else {
                updated
            };
        }
    }

    // Extract R (upper trapezoid of work).
    let mut r = Tensor::zeros(&[steps, n]);
    for i in 0..steps {
        for j in i..n {
            *r.at_mut(i, j) = work.at(i, j);
        }
    }
    PivotedQr { pivots, r, r_diag }
}

/// The first `r` pivot indices — PIFA's basis-row selection when applied to
/// W^T (rows of W = columns of W^T).
pub fn pivot_rows(a_t: &Tensor, r: usize) -> Vec<usize> {
    let qr = qr_column_pivoting(a_t);
    qr.pivots[..r.min(qr.pivots.len())].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul::matmul;

    #[test]
    fn pivots_are_permutation() {
        let a = Tensor::randn(&[6, 8], 1.0, 1);
        let qr = qr_column_pivoting(&a);
        let mut p = qr.pivots.clone();
        p.sort();
        assert_eq!(p, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn r_diag_nonincreasing_for_random() {
        let a = Tensor::randn(&[10, 10], 1.0, 2);
        let qr = qr_column_pivoting(&a);
        for w in qr.r_diag.windows(2) {
            // Column-pivoted QR guarantees non-increasing |R_kk| (within fp slack).
            assert!(w[1] <= w[0] * 1.0 + 1e-6, "{:?}", qr.r_diag);
        }
    }

    #[test]
    fn detects_numerical_rank() {
        // Build a rank-3 10x10 matrix.
        let u = Tensor::randn(&[10, 3], 1.0, 3);
        let v = Tensor::randn(&[3, 10], 1.0, 4);
        let a = matmul(&u, &v);
        let qr = qr_column_pivoting(&a);
        assert!(qr.r_diag[2] > 1e-3);
        assert!(qr.r_diag[3] < 1e-3 * qr.r_diag[0], "{:?}", qr.r_diag);
    }

    #[test]
    fn first_pivot_is_largest_column() {
        let mut a = Tensor::randn(&[5, 5], 0.1, 5);
        // Make column 3 dominant.
        for i in 0..5 {
            *a.at_mut(i, 3) = 10.0 + i as f32;
        }
        let qr = qr_column_pivoting(&a);
        assert_eq!(qr.pivots[0], 3);
    }

    #[test]
    fn pivot_rows_selects_r() {
        let a = Tensor::randn(&[6, 4], 1.0, 6);
        let rows = pivot_rows(&a.transpose(), 3);
        assert_eq!(rows.len(), 3);
        let mut sorted = rows.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 3);
        assert!(sorted.iter().all(|&r| r < 6));
    }

    #[test]
    fn zero_matrix_is_rank_zero() {
        let a = Tensor::zeros(&[4, 4]);
        let qr = qr_column_pivoting(&a);
        assert!(qr.r_diag.iter().all(|&d| d == 0.0));
    }
}
