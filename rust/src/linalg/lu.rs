//! LU factorization with partial pivoting and the linear solves used by
//! BD decomposition (Algorithm 4's `linsolve`).

use crate::tensor::Tensor;

/// LU factorization of a square matrix with partial (row) pivoting.
/// `lu` packs L (unit lower, below diagonal) and U (upper incl. diagonal);
/// `perm[i]` is the source row of pivoted row i.
#[derive(Clone, Debug)]
pub struct Lu {
    pub lu: Tensor,
    pub perm: Vec<usize>,
    pub n: usize,
    /// Smallest |pivot| encountered — conditioning signal.
    pub min_pivot: f64,
}

#[derive(Debug, thiserror::Error)]
pub enum LinalgError {
    #[error("singular matrix (pivot {pivot:e} at step {step})")]
    Singular { step: usize, pivot: f64 },
    #[error("shape mismatch: {0}")]
    Shape(String),
}

/// Factor a square matrix. Fails only on an exactly-zero pivot; near-zero
/// pivots are reported via `min_pivot` (Theorem 3.1 says exact singularity
/// has probability 0 for noised weights).
pub fn lu_factor(a: &Tensor) -> Result<Lu, LinalgError> {
    if a.ndim() != 2 || a.shape[0] != a.shape[1] {
        return Err(LinalgError::Shape(format!("lu_factor needs square, got {:?}", a.shape)));
    }
    let n = a.shape[0];
    let mut lu = a.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    let mut min_pivot = f64::INFINITY;

    for k in 0..n {
        // Partial pivot: max |value| in column k at/below row k.
        let mut p = k;
        let mut best = lu.at(k, k).abs();
        for i in (k + 1)..n {
            let v = lu.at(i, k).abs();
            if v > best {
                best = v;
                p = i;
            }
        }
        if best == 0.0 {
            return Err(LinalgError::Singular { step: k, pivot: 0.0 });
        }
        min_pivot = min_pivot.min(best as f64);
        if p != k {
            for j in 0..n {
                let tmp = lu.at(k, j);
                *lu.at_mut(k, j) = lu.at(p, j);
                *lu.at_mut(p, j) = tmp;
            }
            perm.swap(k, p);
        }
        let pivot = lu.at(k, k);
        for i in (k + 1)..n {
            let factor = lu.at(i, k) / pivot;
            *lu.at_mut(i, k) = factor;
            if factor != 0.0 {
                for j in (k + 1)..n {
                    let v = lu.at(k, j);
                    *lu.at_mut(i, j) -= factor * v;
                }
            }
        }
    }
    Ok(Lu { lu, perm, n, min_pivot })
}

impl Lu {
    /// Solve `A x = b` for a single RHS vector.
    pub fn solve_vec(&self, b: &[f32]) -> Vec<f32> {
        assert_eq!(b.len(), self.n);
        let n = self.n;
        // Apply permutation, forward-substitute L, back-substitute U.
        let mut y: Vec<f32> = (0..n).map(|i| b[self.perm[i]]).collect();
        for i in 1..n {
            let mut acc = y[i];
            for j in 0..i {
                acc -= self.lu.at(i, j) * y[j];
            }
            y[i] = acc;
        }
        for i in (0..n).rev() {
            let mut acc = y[i];
            for j in (i + 1)..n {
                acc -= self.lu.at(i, j) * y[j];
            }
            y[i] = acc / self.lu.at(i, i);
        }
        y
    }
}

/// Solve `A X = B` column-by-column (A: n×n, B: n×m) → X: n×m.
pub fn lu_solve_matrix(a: &Tensor, b: &Tensor) -> Result<Tensor, LinalgError> {
    let lu = lu_factor(a)?;
    let n = lu.n;
    if b.shape[0] != n {
        return Err(LinalgError::Shape(format!("B rows {} != {}", b.shape[0], n)));
    }
    let m = b.shape[1];
    let mut x = Tensor::zeros(&[n, m]);
    let mut col = vec![0.0f32; n];
    for j in 0..m {
        for i in 0..n {
            col[i] = b.at(i, j);
        }
        let sol = lu.solve_vec(&col);
        for i in 0..n {
            *x.at_mut(i, j) = sol[i];
        }
    }
    Ok(x)
}

/// Solve `X A = B` for X (A: n×n, B: m×n) → X: m×n.
///
/// This is the BD coefficient solve: rows of B expressed in the basis A.
/// Equivalent to solving `A^T X^T = B^T`.
pub fn solve_xa_b(a: &Tensor, b: &Tensor) -> Result<Tensor, LinalgError> {
    let at = a.transpose();
    let bt = b.transpose();
    Ok(lu_solve_matrix(&at, &bt)?.transpose())
}

// ---- f64 path (offline BD preparation solves in double precision) ----------

/// Row-major f64 matrix view used by the offline solves.
pub struct MatF64 {
    pub data: Vec<f64>,
    pub rows: usize,
    pub cols: usize,
}

impl MatF64 {
    pub fn from_tensor(t: &Tensor) -> MatF64 {
        assert_eq!(t.ndim(), 2);
        MatF64 {
            data: t.data.iter().map(|&x| x as f64).collect(),
            rows: t.shape[0],
            cols: t.shape[1],
        }
    }

    pub fn to_tensor(&self) -> Tensor {
        Tensor::from_vec(
            self.data.iter().map(|&x| x as f32).collect(),
            &[self.rows, self.cols],
        )
    }

    #[inline]
    fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// self @ other.
    pub fn matmul(&self, other: &MatF64) -> MatF64 {
        assert_eq!(self.cols, other.rows);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = MatF64 { data: vec![0.0; m * n], rows: m, cols: n };
        for i in 0..m {
            for p in 0..k {
                let a = self.at(i, p);
                if a != 0.0 {
                    for j in 0..n {
                        out.data[i * n + j] += a * other.at(p, j);
                    }
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> MatF64 {
        let mut out = MatF64 { data: vec![0.0; self.data.len()], rows: self.cols, cols: self.rows };
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.set(j, i, self.at(i, j));
            }
        }
        out
    }
}

/// Solve `A X = B` in f64 (A: n×n, B: n×m) with partial pivoting.
pub fn lu_solve_matrix_f64(a: &MatF64, b: &MatF64) -> Result<MatF64, LinalgError> {
    let n = a.rows;
    if a.rows != a.cols {
        return Err(LinalgError::Shape(format!("square needed, got {}x{}", a.rows, a.cols)));
    }
    if b.rows != n {
        return Err(LinalgError::Shape(format!("B rows {} != {}", b.rows, n)));
    }
    let m = b.cols;
    let mut lu = a.data.clone();
    let mut x = b.data.clone();
    for k in 0..n {
        // Pivot
        let mut p = k;
        let mut best = lu[k * n + k].abs();
        for i in (k + 1)..n {
            let v = lu[i * n + k].abs();
            if v > best {
                best = v;
                p = i;
            }
        }
        if best == 0.0 {
            return Err(LinalgError::Singular { step: k, pivot: 0.0 });
        }
        if p != k {
            for j in 0..n {
                lu.swap(k * n + j, p * n + j);
            }
            for j in 0..m {
                x.swap(k * m + j, p * m + j);
            }
        }
        let pivot = lu[k * n + k];
        for i in (k + 1)..n {
            let f = lu[i * n + k] / pivot;
            if f != 0.0 {
                lu[i * n + k] = f;
                for j in (k + 1)..n {
                    lu[i * n + j] -= f * lu[k * n + j];
                }
                for j in 0..m {
                    x[i * m + j] -= f * x[k * m + j];
                }
            } else {
                lu[i * n + k] = 0.0;
            }
        }
    }
    // Back substitution.
    for k in (0..n).rev() {
        let pivot = lu[k * n + k];
        for j in 0..m {
            let mut acc = x[k * m + j];
            for i in (k + 1)..n {
                acc -= lu[k * n + i] * x[i * m + j];
            }
            x[k * m + j] = acc / pivot;
        }
    }
    Ok(MatF64 { data: x, rows: n, cols: m })
}

/// Solve `X A = B` in f64 (A: n×n, B: m×n) → X: m×n.
pub fn solve_xa_b_f64(a: &MatF64, b: &MatF64) -> Result<MatF64, LinalgError> {
    let at = a.transpose();
    let bt = b.transpose();
    Ok(lu_solve_matrix_f64(&at, &bt)?.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul::matmul;

    #[test]
    fn f64_solve_matches_known() {
        let a = Tensor::randn(&[10, 10], 1.0, 41);
        let x_true = Tensor::randn(&[10, 4], 1.0, 42);
        let b = matmul(&a, &x_true);
        let x = lu_solve_matrix_f64(&MatF64::from_tensor(&a), &MatF64::from_tensor(&b))
            .unwrap()
            .to_tensor();
        assert!(x.max_abs_diff(&x_true) < 1e-4);
    }

    #[test]
    fn f64_xa_b() {
        let a = Tensor::randn(&[6, 6], 1.0, 43);
        let x_true = Tensor::randn(&[3, 6], 1.0, 44);
        let b = matmul(&x_true, &a);
        let x = solve_xa_b_f64(&MatF64::from_tensor(&a), &MatF64::from_tensor(&b))
            .unwrap()
            .to_tensor();
        assert!(x.max_abs_diff(&x_true) < 1e-4);
    }

    #[test]
    fn solve_identity() {
        let a = Tensor::eye(4);
        let b = Tensor::randn(&[4, 3], 1.0, 1);
        let x = lu_solve_matrix(&a, &b).unwrap();
        assert!(x.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn solve_random_full_rank() {
        let a = Tensor::randn(&[8, 8], 1.0, 2);
        let x_true = Tensor::randn(&[8, 5], 1.0, 3);
        let b = matmul(&a, &x_true);
        let x = lu_solve_matrix(&a, &b).unwrap();
        assert!(x.max_abs_diff(&x_true) < 1e-3, "diff {}", x.max_abs_diff(&x_true));
    }

    #[test]
    fn solve_requires_pivoting() {
        // Zero on the diagonal forces a row swap.
        let a = Tensor::from_vec(vec![0.0, 1.0, 1.0, 0.0], &[2, 2]);
        let b = Tensor::from_vec(vec![2.0, 3.0], &[2, 1]);
        let x = lu_solve_matrix(&a, &b).unwrap();
        // x = [3, 2]
        assert!((x.data[0] - 3.0).abs() < 1e-6);
        assert!((x.data[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn singular_detected() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 2.0, 4.0], &[2, 2]);
        assert!(lu_factor(&a).is_err());
    }

    #[test]
    fn xa_b_solve() {
        // X A = B with known X.
        let a = Tensor::randn(&[6, 6], 1.0, 4);
        let x_true = Tensor::randn(&[3, 6], 1.0, 5);
        let b = matmul(&x_true, &a);
        let x = solve_xa_b(&a, &b).unwrap();
        assert!(x.max_abs_diff(&x_true) < 1e-3);
    }

    #[test]
    fn min_pivot_reported() {
        let a = Tensor::randn(&[5, 5], 1.0, 6);
        let lu = lu_factor(&a).unwrap();
        assert!(lu.min_pivot > 0.0 && lu.min_pivot.is_finite());
    }

    #[test]
    fn non_square_rejected() {
        let a = Tensor::zeros(&[3, 4]);
        assert!(lu_factor(&a).is_err());
    }
}
