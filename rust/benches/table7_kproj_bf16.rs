//! Table 7: k_proj throughput (Mtok/s), BF16 — same grid as Table 6.
//!
//! Run: cargo bench --bench table7_kproj_bf16

mod common;

use bda::bench_support::BenchConfig;
use bda::tensor::DType;

fn main() {
    let cfg = BenchConfig::from_env();
    let s = common::op_shape();
    println!(
        "Table 7 — BF16 k_proj throughput | shape d={} d_h={} n_heads={} (paper: n=128, A6000)",
        s.d, s.d_h, s.n_heads
    );
    let rows: Vec<common::OpRow> = common::seq_lens()
        .into_iter()
        .map(|l| {
            let r = common::run_point(l, DType::BF16, cfg, true);
            println!(
                "  L={:<6} mha {:.3} | pifa {:.3} | bda {:.3} Mtok/s ({:.2}x)",
                r.seq_len, r.mha_mtok, r.pifa_mtok, r.bda_mtok, r.speedup()
            );
            r
        })
        .collect();
    common::print_op_table("Table 7 — Throughput (Mtok/s), BF16", &rows);
}
