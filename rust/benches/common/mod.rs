//! Shared bench driver for the k_proj operator tables (6, 7) and Fig. 2b.

use bda::attention::kproj::{kproj_bda, kproj_mha, pifa_from_mha};
use bda::attention::mha::MhaWeights;
use bda::attention::AttnShape;
use bda::bd::{Strategy, Tag};
use bda::bench_support::{bench, BenchConfig, Table};
use bda::tensor::{DType, Tensor};

/// Sequence lengths of Tables 6/7 (full sweep) — trimmed on fast mode.
pub fn seq_lens() -> Vec<usize> {
    if std::env::var("BDA_BENCH_FAST").is_ok() {
        vec![64, 256, 1024]
    } else {
        vec![64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384]
    }
}

/// The operator shape. The paper uses n=128 heads (DeepSeek-V3); we default
/// to 16 on this single-core CPU testbed and note the scaling in
/// EXPERIMENTS.md (FLOP ratios are head-count-invariant).
pub fn op_shape() -> AttnShape {
    let n: usize = std::env::var("BDA_BENCH_HEADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16);
    AttnShape::new(512, n, 128)
}

pub struct OpRow {
    pub seq_len: usize,
    pub mha_mtok: f64,
    pub pifa_mtok: f64,
    pub bda_mtok: f64,
}

impl OpRow {
    pub fn speedup(&self) -> f64 {
        self.bda_mtok / self.mha_mtok
    }
}

/// Run the three k_proj implementations at one (L, dtype) point.
/// Throughput unit: million tokens/s (a "token" = one sequence position),
/// matching Tables 6–7.
pub fn run_point(l: usize, dt: DType, cfg: BenchConfig, with_pifa: bool) -> OpRow {
    let s = op_shape();
    let x = Tensor::randn(&[l, s.d], 1.0, l as u64).cast(dt);
    let w_k = Tensor::randn(&[s.d, s.proj_width()], 0.02, 7).cast(dt);

    let mha = MhaWeights::random(s, 11);
    let bda = bda::attention::bda::BdaWeights::prepare(&mha, Strategy::FirstR, DType::F32)
        .expect("prep");
    let c_qk = bda.c_qk.clone().cast(dt);

    let m_mha = bench("mha", cfg, l as f64, || {
        std::hint::black_box(kproj_mha(&x, &w_k));
    });
    let m_bda = bench("bda", cfg, l as f64, || {
        std::hint::black_box(kproj_bda(&x, &c_qk, Tag::First, s));
    });
    let pifa_mtok = if with_pifa {
        let pifa = pifa_from_mha(&mha);
        let m_pifa = bench("pifa", cfg, l as f64, || {
            std::hint::black_box(pifa.project(&x));
        });
        m_pifa.mops()
    } else {
        f64::NAN
    };

    OpRow { seq_len: l, mha_mtok: m_mha.mops(), pifa_mtok, bda_mtok: m_bda.mops() }
}

/// Render a Tables-6/7-shaped table.
pub fn print_op_table(title: &str, rows: &[OpRow]) {
    let mut t = Table::new(title, &["Seq. Len", "MHA", "PIFA-style (per-head QR)", "BDA", "Speedup"]);
    for r in rows {
        t.row(vec![
            r.seq_len.to_string(),
            format!("{:.3}", r.mha_mtok),
            if r.pifa_mtok.is_nan() { "-".into() } else { format!("{:.3}", r.pifa_mtok) },
            format!("{:.3}", r.bda_mtok),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    t.print();
    let avg: f64 = rows.iter().map(|r| r.speedup()).sum::<f64>() / rows.len() as f64;
    println!(
        "average speedup: {avg:.2}x | theoretical bound {:.2}x (paper avg: 1.32x fp16 / 1.34x bf16)",
        bda::bd::cost::kproj_theoretical_speedup(512, 128)
    );
}
