//! Decode throughput: the paged batched engine vs the per-sequence native
//! backend, swept over concurrency. Every configuration decodes the same
//! trace greedily, so generations are bit-identical between the two
//! backends (asserted) — the speedup is pure engineering, exactly the
//! "complementary to engineering-level optimizations" framing of §1.
//!
//! The per-sequence backend runs B separate passes over every weight
//! matrix per decode iteration; the paged engine streams each weight once
//! for all B rows and attends through the shared block pool, so the gap
//! widens with concurrency.
//!
//! Run: cargo bench --bench decode_throughput
//! Fast smoke: BDA_BENCH_FAST=1 cargo bench --bench decode_throughput

use bda::bench_support::{f2, Table};
use bda::coordinator::server::replay_trace;
use bda::coordinator::{
    BatcherConfig, KvCacheConfig, NativeBackend, Request, SchedulerConfig, ServerConfig,
};
use bda::engine::PagedNativeBackend;
use bda::eval::trace::{self, TraceConfig};
use bda::model::{ModelConfig, Transformer};
use bda::util::timer::Timer;
use std::time::Duration;

fn make_trace(n: usize, vocab: usize, max_new: usize) -> Vec<Request> {
    trace::generate(TraceConfig {
        n_requests: n,
        vocab_size: vocab,
        min_prompt: 12,
        max_prompt: 12,
        min_new: max_new,
        max_new,
        seed: 17,
    })
}

fn config(concurrency: usize) -> ServerConfig {
    ServerConfig {
        batcher: BatcherConfig { max_batch: concurrency, max_wait: Duration::from_millis(0) },
        scheduler: SchedulerConfig {
            max_active: concurrency,
            eos_token: None,
            kv: KvCacheConfig { block_size: 16, num_blocks: 1024 },
        },
    }
}

struct Run {
    tokens: u64,
    wall: f64,
    occupancy: f64,
    generations: Vec<(u64, Vec<u32>)>,
}

fn run(backend_label: &str, model: &Transformer, concurrency: usize, max_new: usize) -> Run {
    let cfg = config(concurrency);
    let t = make_trace(concurrency, model.config.vocab_size, max_new);
    let timer = Timer::start();
    let (mut responses, metrics) = if backend_label == "paged" {
        let backend = PagedNativeBackend::new(model.clone(), cfg.scheduler.kv);
        replay_trace(backend, cfg, t).expect("paged serve")
    } else {
        replay_trace(NativeBackend::new(model.clone()), cfg, t).expect("per-seq serve")
    };
    let wall = timer.elapsed_secs();
    let snap = metrics.snapshot();
    responses.sort_by_key(|r| r.id);
    Run {
        tokens: snap.tokens_out,
        wall,
        occupancy: snap.decode_occupancy,
        generations: responses.into_iter().map(|r| (r.id, r.tokens)).collect(),
    }
}

fn main() {
    let fast = std::env::var("BDA_BENCH_FAST").is_ok();
    let config_name = if fast { "tiny" } else { "deepseek-lite-sim" };
    let model = Transformer::new_mha(ModelConfig::preset(config_name).unwrap(), 42);
    let max_new = if fast { 8 } else { 32 };
    let sweep: &[usize] = if fast { &[1, 8] } else { &[1, 4, 8, 16] };

    println!(
        "Decode throughput — paged batched engine vs per-sequence backend \
         ({config_name}, {} params, {} new tokens/request)",
        model.param_count(),
        max_new
    );
    let mut table = Table::new(
        "Batched paged decode vs per-sequence decode",
        &["Concurrency", "per-seq tok/s", "paged tok/s", "speedup", "occupancy"],
    );
    let mut speedup_at_8plus = Vec::new();
    for &c in sweep {
        let per_seq = run("per-seq", &model, c, max_new);
        let paged = run("paged", &model, c, max_new);
        assert_eq!(
            paged.generations, per_seq.generations,
            "paged and per-seq generations must be bit-identical"
        );
        assert_eq!(paged.tokens, per_seq.tokens);
        let tps_seq = per_seq.tokens as f64 / per_seq.wall;
        let tps_paged = paged.tokens as f64 / paged.wall;
        let speedup = tps_paged / tps_seq;
        if c >= 8 {
            speedup_at_8plus.push(speedup);
        }
        println!(
            "  c={c:<3} per-seq {tps_seq:>9.1} tok/s | paged {tps_paged:>9.1} tok/s | \
             {speedup:.2}x | occupancy {:.0}%",
            paged.occupancy * 100.0
        );
        table.row(vec![
            c.to_string(),
            f2(tps_seq),
            f2(tps_paged),
            format!("{speedup:.2}x"),
            format!("{:.0}%", paged.occupancy * 100.0),
        ]);
    }
    table.print();
    if let Some(min) = speedup_at_8plus.iter().cloned().reduce(f64::min) {
        println!(
            "\npaged engine at >=8 concurrent sequences: min speedup {min:.2}x \
             ({})",
            if min > 1.0 { "BEATS per-sequence decode" } else { "NO speedup — investigate" }
        );
    }
}
