//! Decode throughput: the paged batched engine vs the per-sequence native
//! backend, plus a paged-attention microbenchmark (blocked parallel kernel
//! vs the retained serial reference), a **dispatch-overhead
//! microbenchmark** (scoped thread spawn/join vs waking the persistent
//! parked pool — the per-layer-per-step cost the pool amortizes away),
//! and a **shared-prefix workload** (radix-tree prefix cache off vs on:
//! identical generations, hit rate, deduped blocks, prefill work saved),
//! swept over **thread count × batch size**. Every configuration decodes
//! the same trace greedily, so generations are bit-identical between the
//! two backends (asserted) and across thread counts — the speedup is pure
//! engineering, exactly the "complementary to engineering-level
//! optimizations" framing of §1.
//!
//! `BDA_NUM_THREADS` is latched once per process, so the thread sweep
//! re-execs this binary once per thread count (child mode is selected by
//! the `BDA_BENCH_OUT` env var, which names the child's JSON fragment
//! file). The parent aggregates all fragments into machine-readable
//! `BENCH_decode.json` in the working directory — the repo's perf
//! trajectory record.
//!
//! Run: cargo bench --bench decode_throughput
//! Fast smoke: BDA_BENCH_FAST=1 cargo bench --bench decode_throughput

use bda::attention::paged::{
    paged_attention_decode, paged_attention_decode_serial, PagedLayerView, PagedSeq,
};
use bda::attention::AttnShape;
use bda::bench_support::{bench, f2, scatter_paged_kv, BenchConfig, Table};
use bda::coordinator::server::replay_trace;
use bda::coordinator::{
    BatcherConfig, KvCacheConfig, Metrics, NativeBackend, Request, Scheduler, SchedulerConfig,
    Server, ServerConfig, Snapshot,
};
use bda::engine::PagedNativeBackend;
use bda::eval::trace::{self, TraceConfig};
use bda::model::{ModelConfig, Transformer};
use bda::coordinator::kv_cache::test_pool_blocks;
use bda::tensor::{DType, Tensor};
use bda::util::json::Json;
use bda::util::stats::Quantiles;
use bda::util::threadpool;
use bda::util::timer::Timer;
use std::time::Duration;

fn make_trace(n: usize, vocab: usize, max_new: usize) -> Vec<Request> {
    trace::generate(TraceConfig {
        n_requests: n,
        vocab_size: vocab,
        min_prompt: 12,
        max_prompt: 12,
        min_new: max_new,
        max_new,
        seed: 17,
    })
}

fn config(concurrency: usize) -> ServerConfig {
    ServerConfig {
        batcher: BatcherConfig { max_batch: concurrency, max_wait: Duration::from_millis(0) },
        scheduler: SchedulerConfig {
            max_active: concurrency,
            eos_token: None,
            // f32 pinned: these runs assert paged == per-seq generations,
            // and the per-sequence backend always stores f32 (16-bit
            // storage has its own bench fragment, kv_dtype_row).
            kv: KvCacheConfig { block_size: 16, num_blocks: 1024, dtype: DType::F32 },
            ..Default::default()
        },
    }
}

struct Run {
    tokens: u64,
    wall: f64,
    occupancy: f64,
    generations: Vec<(u64, Vec<u32>)>,
    snap: Snapshot,
}

/// p50/p95/p99 of a latency distribution, in milliseconds, as a JSON
/// object (the schema of the `ttft_ms` / `tbt_ms` / `step_*_ms` bench
/// fields documented in docs/benchmarks.md).
fn quantiles_ms_json(q: &Quantiles) -> Json {
    Json::obj(vec![
        ("p50", Json::num(q.p50 * 1e3)),
        ("p95", Json::num(q.p95 * 1e3)),
        ("p99", Json::num(q.p99 * 1e3)),
        ("count", Json::num(q.count as f64)),
    ])
}

fn run(backend_label: &str, model: &Transformer, concurrency: usize, max_new: usize) -> Run {
    let cfg = config(concurrency);
    let t = make_trace(concurrency, model.config.vocab_size, max_new);
    let timer = Timer::start();
    let (mut responses, metrics) = if backend_label == "paged" {
        let backend = PagedNativeBackend::new(model.clone(), cfg.scheduler.kv);
        replay_trace(backend, cfg, t).expect("paged serve")
    } else {
        replay_trace(NativeBackend::new(model.clone()), cfg, t).expect("per-seq serve")
    };
    let wall = timer.elapsed_secs();
    let snap = metrics.snapshot();
    responses.sort_by_key(|r| r.id);
    Run {
        tokens: snap.tokens_out,
        wall,
        occupancy: snap.decode_occupancy,
        generations: responses.into_iter().map(|r| (r.id, r.tokens)).collect(),
        snap,
    }
}

/// Paged-attention microbenchmark fixture: `batch` sequences of `len`
/// tokens each, scattered over an interleaved block layout (seq i owns
/// blocks i, i+batch, i+2·batch, … — adjacent tables, like a real pool
/// after round-robin admission).
struct MicroFixture {
    q: Tensor,
    pk: Vec<f32>,
    pv: Vec<f32>,
    tables: Vec<Vec<usize>>,
    lens: Vec<usize>,
    s: AttnShape,
    block_size: usize,
}

impl MicroFixture {
    fn new(batch: usize, len: usize, s: AttnShape, block_size: usize) -> MicroFixture {
        let width = s.proj_width();
        let blocks_per_seq = len.div_ceil(block_size);
        let num_blocks = blocks_per_seq * batch;
        let mut pk = vec![0.0f32; num_blocks * block_size * width];
        let mut pv = vec![0.0f32; num_blocks * block_size * width];
        let mut tables = Vec::with_capacity(batch);
        for i in 0..batch {
            let table: Vec<usize> = (0..blocks_per_seq).map(|b| b * batch + i).collect();
            let k = Tensor::randn(&[len, width], 1.0, 2 * i as u64 + 1);
            let v = Tensor::randn(&[len, width], 1.0, 2 * i as u64 + 2);
            scatter_paged_kv(&mut pk, &mut pv, &k.data, &v.data, len, width, block_size, &table);
            tables.push(table);
        }
        MicroFixture {
            q: Tensor::randn(&[batch, width], 1.0, 7),
            pk,
            pv,
            tables,
            lens: vec![len; batch],
            s,
            block_size,
        }
    }

    fn layer(&self) -> PagedLayerView<'_> {
        PagedLayerView::f32(&self.pk, &self.pv, self.block_size, self.s.proj_width())
    }

    fn seqs(&self) -> Vec<PagedSeq<'_>> {
        self.tables
            .iter()
            .zip(&self.lens)
            .map(|(t, &len)| PagedSeq { blocks: t, len, q_rows: 1 })
            .collect()
    }
}

/// One (batch size) microbenchmark row: blocked parallel kernel vs the
/// serial reference, with a bitwise equality check on the outputs.
fn micro_row(batch: usize, len: usize, s: AttnShape, cfg: BenchConfig) -> Json {
    let fx = MicroFixture::new(batch, len, s, 16);
    let layer = fx.layer();
    let seqs = fx.seqs();

    let out_par = paged_attention_decode(&fx.q, &layer, &seqs, s);
    let out_ser = paged_attention_decode_serial(&fx.q, &layer, &seqs, s);
    assert_eq!(out_par, out_ser, "parallel blocked kernel must match the serial reference");

    let m_ser = bench("paged_attn_serial", cfg, (batch * len) as f64, || {
        std::hint::black_box(paged_attention_decode_serial(&fx.q, &layer, &seqs, s));
    });
    let m_par = bench("paged_attn_parallel", cfg, (batch * len) as f64, || {
        std::hint::black_box(paged_attention_decode(&fx.q, &layer, &seqs, s));
    });
    let serial_us = m_ser.median_us();
    let parallel_us = m_par.median_us();
    Json::obj(vec![
        ("batch", Json::num(batch as f64)),
        ("len", Json::num(len as f64)),
        ("serial_us", Json::num(serial_us)),
        ("parallel_us", Json::num(parallel_us)),
        ("speedup", Json::num(serial_us / parallel_us)),
    ])
}

/// Dispatch-overhead row: one parallel region of `items` near-empty work
/// items, executed by (a) the pre-pool strategy — spawn + join `threads`
/// scoped OS threads per call — and (b) waking the persistent parked pool.
/// This is the fixed cost paid once per layer per decode step (GEMM panels
/// and the paged-attention kernel each dispatch one region), so the gap
/// here is the pool's per-step win independent of arithmetic throughput.
fn dispatch_row(threads: usize, cfg: BenchConfig) -> Json {
    let items = 64usize;
    let m_scoped = bench("dispatch_scoped_spawn", cfg, items as f64, || {
        threadpool::scoped_parallel_for_with(items, threads, |i| {
            std::hint::black_box(i);
        });
    });
    let m_pool = bench("dispatch_parked_pool", cfg, items as f64, || {
        threadpool::parallel_for_with(items, threads, |i| {
            std::hint::black_box(i);
        });
    });
    let scoped_us = m_scoped.median_us();
    let pooled_us = m_pool.median_us();
    println!(
        "dispatch overhead ({threads} threads, {items} trivial items): \
         scoped spawn {scoped_us:.2}us vs parked pool {pooled_us:.2}us ({:.2}x)",
        scoped_us / pooled_us
    );
    Json::obj(vec![
        ("workers", Json::num(threads as f64)),
        ("items", Json::num(items as f64)),
        ("scoped_spawn_us", Json::num(scoped_us)),
        ("parked_pool_us", Json::num(pooled_us)),
        ("speedup", Json::num(scoped_us / pooled_us)),
    ])
}

/// Shared-prefix workload: `n` requests whose prompts share a
/// `shared_len`-token system prompt followed by a short unique suffix,
/// replayed at bounded concurrency so early completions seed the radix
/// tree before later admissions. Runs the identical trace with the prefix
/// cache off and on; generations must be bit-identical (invariant 4), and
/// the JSON row records the hit rate, blocks deduped, and the prefill
/// work the cache removed.
fn prefix_cache_row(fast: bool) -> Json {
    // tiny's context is 64 tokens, so the workload is sized to leave
    // decode room: 32 shared + 6 unique prompt tokens + 4 generated.
    let model = Transformer::new_mha(ModelConfig::tiny(), 42);
    let vocab = model.config.vocab_size as u32;
    let shared_len = 32usize;
    let block_size = 8usize;
    let n = if fast { 12 } else { 24 };
    let concurrency = 4usize;
    let shared: Vec<u32> = (0..shared_len as u32).map(|j| (j * 13 + 7) % vocab).collect();
    let make_requests = || -> Vec<Request> {
        (0..n as u64)
            .map(|i| {
                let mut prompt = shared.clone();
                prompt.extend((0..6).map(|j| (1000 + i * 31 + j) as u32 % vocab));
                Request::new(i, prompt, 4)
            })
            .collect()
    };
    let cfg = ServerConfig {
        batcher: BatcherConfig { max_batch: concurrency, max_wait: Duration::from_millis(0) },
        scheduler: SchedulerConfig {
            max_active: concurrency,
            eos_token: None,
            kv: KvCacheConfig { block_size, num_blocks: 1024, ..Default::default() },
            ..Default::default()
        },
    };
    let mut runs = Vec::new();
    for enabled in [false, true] {
        let mut backend = PagedNativeBackend::new(model.clone(), cfg.scheduler.kv);
        backend.set_prefix_cache(enabled);
        let timer = Timer::start();
        let (mut responses, metrics) = replay_trace(backend, cfg, make_requests()).unwrap();
        let wall = timer.elapsed_secs();
        let snap = metrics.snapshot();
        responses.sort_by_key(|r| r.id);
        let generations: Vec<(u64, Vec<u32>)> =
            responses.into_iter().map(|r| (r.id, r.tokens)).collect();
        runs.push((wall, snap, generations));
    }
    let (cold_wall, cold_snap, cold_gen) = &runs[0];
    let (warm_wall, warm_snap, warm_gen) = &runs[1];
    assert_eq!(
        warm_gen, cold_gen,
        "prefix-cache hits must not change generations (invariant 4)"
    );
    assert_eq!(cold_snap.prefix_hits + cold_snap.prefix_misses, 0, "cache off must not look up");
    assert!(warm_snap.prefix_blocks_saved > 0, "shared-prefix sweep must produce hits");
    // Prefill work actually executed, in tokens: every request's prompt
    // (computed from the workload), minus the tokens adopted from the
    // radix tree.
    let prefill_cold = (n * (shared_len + 6)) as u64;
    let prefill_warm = prefill_cold - warm_snap.prefix_blocks_saved * block_size as u64;
    println!(
        "prefix cache (shared {shared_len}-token prompt, {n} requests): hit rate {:.0}%, \
         {} blocks deduped, prefill tokens {prefill_cold} -> {prefill_warm}, \
         wall {:.3}s -> {:.3}s",
        100.0 * warm_snap.prefix_hit_rate(),
        warm_snap.prefix_blocks_saved,
        cold_wall,
        warm_wall,
    );
    Json::obj(vec![
        ("requests", Json::num(n as f64)),
        ("shared_prefix_tokens", Json::num(shared_len as f64)),
        ("block_size", Json::num(block_size as f64)),
        ("hit_rate", Json::num(warm_snap.prefix_hit_rate())),
        ("blocks_saved", Json::num(warm_snap.prefix_blocks_saved as f64)),
        ("prefill_tokens_cold", Json::num(prefill_cold as f64)),
        ("prefill_tokens_cached", Json::num(prefill_warm as f64)),
        ("wall_cold_s", Json::num(*cold_wall)),
        ("wall_cached_s", Json::num(*warm_wall)),
        ("wall_speedup", Json::num(cold_wall / warm_wall)),
    ])
}

/// Overload workload: the same trace replayed on an ample pool and on a
/// deliberately tiny one, so decode steps exhaust the pool and the engine
/// preempts victims (recompute-on-resume) instead of erroring. The two
/// runs must produce bit-identical generations (engine invariant 5); the
/// JSON row records the preemption/recompute cost and how gracefully
/// throughput degrades under memory pressure.
fn preemption_row(fast: bool) -> Json {
    let model = Transformer::new_mha(ModelConfig::tiny(), 57);
    let vocab = model.config.vocab_size as u32;
    let n = if fast { 8 } else { 16 };
    let concurrency = 4usize;
    let overload_blocks = 12usize; // 4 × 5-block peak demand vs 12 blocks
    let make_requests = || -> Vec<Request> {
        (0..n as u64)
            .map(|i| {
                let prompt: Vec<u32> =
                    (0..8u64).map(|j| ((i * 31 + j * 7 + 3) % vocab as u64) as u32).collect();
                Request::new(i, prompt, 12)
            })
            .collect()
    };
    let run = |num_blocks: usize| {
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_batch: concurrency, max_wait: Duration::from_millis(0) },
            scheduler: SchedulerConfig {
                max_active: concurrency,
                eos_token: None,
                kv: KvCacheConfig { block_size: 4, num_blocks, ..Default::default() },
                ..Default::default()
            },
        };
        let backend = PagedNativeBackend::new(model.clone(), cfg.scheduler.kv);
        let timer = Timer::start();
        let (mut responses, metrics) = replay_trace(backend, cfg, make_requests()).unwrap();
        let wall = timer.elapsed_secs();
        let snap = metrics.snapshot();
        responses.sort_by_key(|r| r.id);
        let generations: Vec<(u64, Vec<u32>)> =
            responses.into_iter().map(|r| (r.id, r.tokens)).collect();
        (generations, snap, wall)
    };
    let (ample_gen, ample_snap, ample_wall) = run(1024);
    let (tight_gen, tight_snap, tight_wall) = run(overload_blocks);
    assert_eq!(tight_gen, ample_gen, "preemption must not change generations (invariant 5)");
    assert_eq!(ample_snap.preemptions, 0, "the ample pool must not preempt");
    assert!(tight_snap.preemptions > 0, "the overload sweep must actually preempt");
    let ample_tok_s = ample_snap.tokens_out as f64 / ample_wall;
    let overload_tok_s = tight_snap.tokens_out as f64 / tight_wall;
    println!(
        "preemption ({n} requests, {overload_blocks}-block pool): {} preempted, \
         {} resumed, {} tokens recomputed, throughput {:.1} -> {:.1} tok/s \
         ({:.2}x of ample)",
        tight_snap.preemptions,
        tight_snap.resumes,
        tight_snap.recomputed_tokens,
        ample_tok_s,
        overload_tok_s,
        overload_tok_s / ample_tok_s,
    );
    Json::obj(vec![
        ("requests", Json::num(n as f64)),
        ("pool_blocks", Json::num(overload_blocks as f64)),
        ("preemptions", Json::num(tight_snap.preemptions as f64)),
        ("resumes", Json::num(tight_snap.resumes as f64)),
        ("recomputed_tokens", Json::num(tight_snap.recomputed_tokens as f64)),
        ("ample_tok_s", Json::num(ample_tok_s)),
        ("overload_tok_s", Json::num(overload_tok_s)),
        ("overload_throughput_ratio", Json::num(overload_tok_s / ample_tok_s)),
    ])
}

/// K/V storage dtype workload: the overload trace replayed on (a) an
/// f32 pool, (b) an f16 pool with the **same block count** — half the
/// bytes, identical scheduling — and (c) an f16 pool with the **same
/// byte budget** — twice the blocks, so more sequences stay resident and
/// fewer decode steps hit pool exhaustion. The f32 block count honors
/// the `BDA_TEST_POOL_BLOCKS` overload knob. The JSON row records
/// truthful pool bytes, resident-sequence capacity, preemption counts,
/// and decode throughput for each configuration; the acceptance keys pin
/// "16-bit halves pool bytes" and "equal-budget f16 preempts strictly
/// less than f32".
fn kv_dtype_row(fast: bool) -> Json {
    let model = Transformer::new_mha(ModelConfig::tiny(), 63);
    let vocab = model.config.vocab_size as u32;
    let n = if fast { 8 } else { 16 };
    let concurrency = 4usize;
    let block_size = 4usize;
    // 8-token prompts + 12 generated = 5 blocks peak per sequence.
    let blocks_per_seq = (8usize + 12).div_ceil(block_size);
    let f32_blocks = test_pool_blocks().map(|b| b.clamp(6, 64)).unwrap_or(12);
    let make_requests = || -> Vec<Request> {
        (0..n as u64)
            .map(|i| {
                let prompt: Vec<u32> =
                    (0..8u64).map(|j| ((i * 31 + j * 7 + 3) % vocab as u64) as u32).collect();
                Request::new(i, prompt, 12)
            })
            .collect()
    };
    let run = |dtype: DType, num_blocks: usize| {
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_batch: concurrency, max_wait: Duration::from_millis(0) },
            scheduler: SchedulerConfig {
                max_active: concurrency,
                eos_token: None,
                kv: KvCacheConfig { block_size, num_blocks, dtype },
                ..Default::default()
            },
        };
        let backend = PagedNativeBackend::new(model.clone(), cfg.scheduler.kv);
        let pool_bytes = backend.kv_pool_bytes();
        let timer = Timer::start();
        let (responses, metrics) = replay_trace(backend, cfg, make_requests()).unwrap();
        let wall = timer.elapsed_secs();
        assert_eq!(responses.len(), n, "kv dtype sweep lost responses");
        (metrics.snapshot(), wall, pool_bytes)
    };
    let (s32, wall32, bytes32) = run(DType::F32, f32_blocks);
    // (a) equal blocks: half the bytes, and scheduling is block-count
    // driven, so the narrower pool preempts exactly as often.
    let (s16eq, _, bytes16eq) = run(DType::F16, f32_blocks);
    assert_eq!(bytes16eq * 2, bytes32, "16-bit storage must halve pool bytes");
    assert_eq!(
        s16eq.preemptions, s32.preemptions,
        "storage width must not change scheduling at a fixed block count"
    );
    // (b) equal bytes: twice the blocks buy resident capacity, so the
    // f16 pool preempts strictly less whenever the f32 pool preempts.
    let (s16, wall16, bytes16) = run(DType::F16, f32_blocks * 2);
    assert_eq!(bytes16, bytes32, "equal-budget f16 pool must cost the same bytes");
    if s32.preemptions > 0 {
        assert!(
            s16.preemptions < s32.preemptions,
            "equal bytes must buy strictly fewer preemptions in 16-bit storage \
             ({} vs {})",
            s16.preemptions,
            s32.preemptions
        );
    }
    let tok_s_32 = s32.tokens_out as f64 / wall32;
    let tok_s_16 = s16.tokens_out as f64 / wall16;
    println!(
        "kv dtype ({n} requests, {bytes32} byte budget): fp32 {f32_blocks} blocks \
         ({} preemptions, {tok_s_32:.1} tok/s) vs fp16 {} blocks \
         ({} preemptions, {tok_s_16:.1} tok/s); equal-block fp16 pool is \
         {bytes16eq} bytes (half)",
        s32.preemptions,
        f32_blocks * 2,
        s16.preemptions,
    );
    Json::obj(vec![
        ("requests", Json::num(n as f64)),
        ("block_size", Json::num(block_size as f64)),
        ("pool_blocks_f32", Json::num(f32_blocks as f64)),
        ("pool_bytes_f32", Json::num(bytes32 as f64)),
        ("pool_bytes_f16_equal_blocks", Json::num(bytes16eq as f64)),
        ("pool_blocks_f16_equal_budget", Json::num((f32_blocks * 2) as f64)),
        ("pool_bytes_f16_equal_budget", Json::num(bytes16 as f64)),
        ("capacity_seqs_f32", Json::num((f32_blocks / blocks_per_seq) as f64)),
        ("capacity_seqs_f16_equal_budget", Json::num((f32_blocks * 2 / blocks_per_seq) as f64)),
        ("preemptions_f32", Json::num(s32.preemptions as f64)),
        ("preemptions_f16_equal_budget", Json::num(s16.preemptions as f64)),
        ("recomputed_tokens_f32", Json::num(s32.recomputed_tokens as f64)),
        ("recomputed_tokens_f16_equal_budget", Json::num(s16.recomputed_tokens as f64)),
        ("decode_tok_s_f32", Json::num(tok_s_32)),
        ("decode_tok_s_f16_equal_budget", Json::num(tok_s_16)),
    ])
}

/// Mixed-traffic workload: short requests decode steadily until a long
/// prompt lands mid-stream. Run monolithically (unbounded chunk budget —
/// the whole prompt fuses into one step, stalling every decode row riding
/// it) and chunked (fixed token budget — the prompt spreads over several
/// steps). Generations must be bit-identical (engine invariant 6); the
/// JSON row records both runs' decode TBT tails and the prefill tokens
/// each step carried, showing the chunked run bounds the per-token stall
/// independent of prompt length.
fn chunked_prefill_row(fast: bool) -> Json {
    let model = Transformer::new_mha(ModelConfig::tiny(), 91);
    let vocab = model.config.vocab_size as u32;
    let n_short = if fast { 3u64 } else { 4 };
    let chunk_budget = 8usize;
    let long_len = 40usize; // tiny's 64-token context: 40 prompt + 8 new
    let run = |prefill_chunk: usize| {
        let cfg = SchedulerConfig {
            max_active: n_short as usize + 1,
            eos_token: None,
            kv: KvCacheConfig { block_size: 4, num_blocks: 1024, ..Default::default() },
            prefill_chunk,
        };
        let backend = PagedNativeBackend::new(model.clone(), cfg.kv);
        let mut sched = Scheduler::new(backend, cfg);
        let metrics = std::sync::Arc::new(Metrics::new());
        sched.set_metrics(metrics.clone());
        let mut done = Vec::new();
        for i in 0..n_short {
            let prompt: Vec<u32> =
                (0..4u64).map(|j| ((i * 29 + j * 11 + 2) % vocab as u64) as u32).collect();
            sched.admit(Request::new(i, prompt, 20)).unwrap();
        }
        // Let the short sequences reach steady-state decode...
        for _ in 0..4 {
            done.extend(sched.step().unwrap());
        }
        // ...then the long prompt arrives mid-decode.
        let long: Vec<u32> =
            (0..long_len as u64).map(|j| ((j * 13 + 5) % vocab as u64) as u32).collect();
        sched.admit(Request::new(99, long, 8)).unwrap();
        done.extend(sched.drain().unwrap());
        done.sort_by_key(|r| r.id);
        let gens: Vec<(u64, Vec<u32>)> = done.into_iter().map(|r| (r.id, r.tokens)).collect();
        (gens, metrics.snapshot())
    };
    let (mono_gen, mono_snap) = run(0);
    let (chunk_gen, chunk_snap) = run(chunk_budget);
    assert_eq!(
        chunk_gen, mono_gen,
        "chunked prefill must not change generations (invariant 6)"
    );
    assert!(
        chunk_snap.prefill_chunks >= (long_len / chunk_budget) as u64,
        "the long prompt must actually run in chunks"
    );
    let per_step = |s: &Snapshot| {
        if s.prefill_chunks > 0 {
            s.chunked_tokens as f64 / s.prefill_chunks as f64
        } else {
            0.0
        }
    };
    let tbt_ratio =
        if mono_snap.tbt.p99 > 0.0 { chunk_snap.tbt.p99 / mono_snap.tbt.p99 } else { 0.0 };
    println!(
        "chunked prefill ({long_len}-token prompt mid-decode, budget {chunk_budget}): \
         tbt p95 {:.2}ms -> {:.2}ms, p99 {:.2}ms -> {:.2}ms, \
         prefill tok/step {:.1} -> {:.1} (identical generations — invariant 6)",
        mono_snap.tbt.p95 * 1e3,
        chunk_snap.tbt.p95 * 1e3,
        mono_snap.tbt.p99 * 1e3,
        chunk_snap.tbt.p99 * 1e3,
        per_step(&mono_snap),
        per_step(&chunk_snap),
    );
    Json::obj(vec![
        ("short_requests", Json::num(n_short as f64)),
        ("long_prompt_tokens", Json::num(long_len as f64)),
        ("chunk_budget", Json::num(chunk_budget as f64)),
        ("monolithic_tbt_ms", quantiles_ms_json(&mono_snap.tbt)),
        ("chunked_tbt_ms", quantiles_ms_json(&chunk_snap.tbt)),
        ("monolithic_prefill_tokens_per_step", Json::num(per_step(&mono_snap))),
        ("chunked_prefill_tokens_per_step", Json::num(per_step(&chunk_snap))),
        ("chunked_prefill_chunks", Json::num(chunk_snap.prefill_chunks as f64)),
        ("chunked_tokens", Json::num(chunk_snap.chunked_tokens as f64)),
        ("tbt_p99_ratio_chunked_vs_monolithic", Json::num(tbt_ratio)),
    ])
}

/// Sharded-scaling workload: the same trace served by the threaded
/// prefix-aware router over 1 → N pool-shard engine workers, each shard
/// with its own single-thread compute pool, its own KV pool, and the
/// same per-shard concurrency — so per-request latency is pinned by the
/// shard-local batch size while aggregate tokens/s scales with worker
/// count. Generations must be bit-identical at every worker count
/// (engine invariant 8); the JSON row records aggregate throughput and
/// the merged per-request latency tail per worker count, plus the
/// scaling efficiency (tok/s at N workers over N × tok/s at 1).
fn sharded_scaling_row(fast: bool) -> Json {
    let model = Transformer::new_mha(ModelConfig::tiny(), 73);
    let n = if fast { 16 } else { 32 };
    let max_new = 8usize;
    let concurrency = 4usize; // per shard — fixed across worker counts
    let worker_counts: &[usize] = if fast { &[1, 2] } else { &[1, 2, 4] };
    let cfg = ServerConfig {
        batcher: BatcherConfig { max_batch: concurrency, max_wait: Duration::from_millis(0) },
        scheduler: SchedulerConfig {
            max_active: concurrency,
            eos_token: None,
            kv: KvCacheConfig { block_size: 16, num_blocks: 256, dtype: DType::F32 },
            ..Default::default()
        },
    };
    let run = |workers: usize| {
        let backends: Vec<PagedNativeBackend> = (0..workers)
            .map(|_| {
                let pool = std::sync::Arc::new(threadpool::ThreadPool::new(1));
                PagedNativeBackend::with_thread_pool(model.clone(), cfg.scheduler.kv, pool)
            })
            .collect();
        let server = Server::start_sharded(backends, cfg);
        let trace = make_trace(n, model.config.vocab_size, max_new);
        let timer = Timer::start();
        for req in trace {
            assert!(server.submit(req), "sharded scaling submit rejected");
        }
        let mut responses = Vec::new();
        while responses.len() < n {
            match server.recv_timeout(Duration::from_secs(10)) {
                Some(r) => responses.push(r),
                None => break,
            }
        }
        let wall = timer.elapsed_secs();
        let snap = server.snapshot();
        responses.extend(server.shutdown().expect("sharded scaling shutdown"));
        assert_eq!(responses.len(), n, "sharded scaling lost responses at {workers} workers");
        responses.sort_by_key(|r| r.id);
        let generations: Vec<(u64, Vec<u32>)> =
            responses.into_iter().map(|r| (r.id, r.tokens)).collect();
        (generations, snap, wall)
    };
    let mut baseline: Option<Vec<(u64, Vec<u32>)>> = None;
    let mut tok_s_by_workers = Vec::new();
    let mut rows = Vec::new();
    for &workers in worker_counts {
        let (generations, snap, wall) = run(workers);
        match &baseline {
            None => baseline = Some(generations),
            Some(base) => assert_eq!(
                &generations, base,
                "sharded serving changed generations at {workers} workers (invariant 8)"
            ),
        }
        let tok_s = snap.tokens_out as f64 / wall;
        let latency = Quantiles {
            p50: snap.latency_p50,
            p95: snap.latency_p95,
            p99: snap.latency_p99,
            mean: snap.latency_mean,
            count: snap.requests_completed,
            sum: 0.0,
        };
        println!(
            "sharded scaling ({n} requests, concurrency {concurrency}/shard): \
             {workers} workers -> {tok_s:.1} tok/s aggregate, latency p50 {:.2}ms \
             p99 {:.2}ms",
            snap.latency_p50 * 1e3,
            snap.latency_p99 * 1e3,
        );
        tok_s_by_workers.push((workers, tok_s));
        rows.push(Json::obj(vec![
            ("workers", Json::num(workers as f64)),
            ("aggregate_tok_s", Json::num(tok_s)),
            ("latency_ms", quantiles_ms_json(&latency)),
            ("requests_completed", Json::num(snap.requests_completed as f64)),
            ("tokens_out", Json::num(snap.tokens_out as f64)),
        ]));
    }
    let (w1, t1) = tok_s_by_workers[0];
    assert_eq!(w1, 1, "the sweep's first point is the single-worker baseline");
    let &(max_workers, t_max) = tok_s_by_workers.last().unwrap();
    let efficiency = if t1 > 0.0 { (t_max / t1) / max_workers as f64 } else { 0.0 };
    Json::obj(vec![
        ("requests", Json::num(n as f64)),
        ("max_new_tokens", Json::num(max_new as f64)),
        ("per_shard_concurrency", Json::num(concurrency as f64)),
        ("max_workers", Json::num(max_workers as f64)),
        ("runs", Json::Arr(rows)),
        ("scaling_efficiency_max_workers", Json::num(efficiency)),
    ])
}

/// Child mode: measure at the current (env-latched) thread count and write
/// a JSON fragment to `$BDA_BENCH_OUT`.
fn run_child(out_path: &str) {
    let fast = std::env::var("BDA_BENCH_FAST").is_ok();
    let threads = threadpool::num_threads();
    let cfg = BenchConfig::from_env();

    // --- dispatch overhead: scoped spawn vs parked pool --------------------
    let dispatch = dispatch_row(threads, cfg);

    // --- paged-attention microbenchmark: batch sweep -----------------------
    let s = AttnShape::new(256, 8, 32);
    let len = if fast { 128 } else { 256 };
    let batches: &[usize] = if fast { &[1, 8] } else { &[1, 4, 8, 16] };
    let mut micro_rows = Vec::new();
    let mut micro_table = Table::new(
        &format!("Paged attention micro ({threads} threads, len {len})"),
        &["Batch", "serial µs", "parallel µs", "speedup"],
    );
    for &b in batches {
        let row = micro_row(b, len, s, cfg);
        micro_table.row(vec![
            b.to_string(),
            f2(row.get("serial_us").as_f64().unwrap_or(0.0)),
            f2(row.get("parallel_us").as_f64().unwrap_or(0.0)),
            format!("{:.2}x", row.get("speedup").as_f64().unwrap_or(0.0)),
        ]);
        micro_rows.push(row);
    }
    micro_table.print();

    // --- engine-level throughput: only at the sweep's end points -----------
    // (thread count 1 and the machine maximum; the engine run is the
    // expensive part and the intermediate points add little signal).
    let np = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let engine_rows = if threads == 1 || threads == np {
        let config_name = if fast { "tiny" } else { "deepseek-lite-sim" };
        let model = Transformer::new_mha(ModelConfig::preset(config_name).unwrap(), 42);
        let max_new = if fast { 8 } else { 32 };
        let sweep: &[usize] = if fast { &[1, 8] } else { &[1, 4, 8, 16] };
        let mut table = Table::new(
            &format!("Batched paged decode vs per-sequence decode ({threads} threads)"),
            &["Concurrency", "per-seq tok/s", "paged tok/s", "speedup", "occupancy"],
        );
        let mut rows = Vec::new();
        for &c in sweep {
            let per_seq = run("per-seq", &model, c, max_new);
            let paged = run("paged", &model, c, max_new);
            assert_eq!(
                paged.generations, per_seq.generations,
                "paged and per-seq generations must be bit-identical"
            );
            assert_eq!(paged.tokens, per_seq.tokens);
            let tps_seq = per_seq.tokens as f64 / per_seq.wall;
            let tps_paged = paged.tokens as f64 / paged.wall;
            table.row(vec![
                c.to_string(),
                f2(tps_seq),
                f2(tps_paged),
                format!("{:.2}x", tps_paged / tps_seq),
                format!("{:.0}%", paged.occupancy * 100.0),
            ]);
            // Tail-latency record for the paged run: TTFT and TBT
            // (per-sequence token timelines) plus the per-step phase
            // split, each as p50/p95/p99 in milliseconds.
            let ps = &paged.snap;
            let ttft = Quantiles {
                p50: ps.ttft_p50,
                p95: ps.ttft_p95,
                p99: ps.ttft_p99,
                mean: 0.0,
                count: ps.requests_completed,
                sum: 0.0,
            };
            rows.push(Json::obj(vec![
                ("concurrency", Json::num(c as f64)),
                ("per_seq_tok_s", Json::num(tps_seq)),
                ("paged_tok_s", Json::num(tps_paged)),
                ("speedup", Json::num(tps_paged / tps_seq)),
                ("occupancy", Json::num(paged.occupancy)),
                ("ttft_ms", quantiles_ms_json(&ttft)),
                ("tbt_ms", quantiles_ms_json(&ps.tbt)),
                ("step_attn_ms", quantiles_ms_json(&ps.step_attn)),
                ("step_gemm_ms", quantiles_ms_json(&ps.step_gemm)),
                ("step_sample_ms", quantiles_ms_json(&ps.step_sample)),
            ]));
        }
        table.print();
        rows
    } else {
        Vec::new()
    };

    // --- prefix cache: shared-prefix workload (cold vs cached) -------------
    // Like the engine rows, only at the sweep's end-point thread counts.
    let prefix_cache = if threads == 1 || threads == np {
        prefix_cache_row(fast)
    } else {
        Json::Null
    };

    // --- preemption: overload workload (tiny pool vs ample pool) -----------
    let preemption = if threads == 1 || threads == np {
        preemption_row(fast)
    } else {
        Json::Null
    };

    // --- chunked prefill: long prompt mid-decode (monolithic vs chunked) ---
    let chunked_prefill = if threads == 1 || threads == np {
        chunked_prefill_row(fast)
    } else {
        Json::Null
    };

    // --- kv storage dtype: f32 vs f16 pools at fixed memory ----------------
    let kv_dtype = if threads == 1 || threads == np { kv_dtype_row(fast) } else { Json::Null };

    // --- sharded scaling: 1 -> N pool-shard workers behind the router ------
    // (independent of BDA_NUM_THREADS — each shard owns a 1-thread pool —
    // so one run at the sweep's max-thread cell suffices).
    let sharded_scaling = if threads == np { sharded_scaling_row(fast) } else { Json::Null };

    let fragment = Json::obj(vec![
        ("num_threads", Json::num(threads as f64)),
        ("dispatch", dispatch),
        ("paged_attention", Json::Arr(micro_rows)),
        ("engine", Json::Arr(engine_rows)),
        ("prefix_cache", prefix_cache),
        ("preemption", preemption),
        ("chunked_prefill", chunked_prefill),
        ("kv_dtype", kv_dtype),
        ("sharded_scaling", sharded_scaling),
    ]);
    std::fs::write(out_path, fragment.to_string()).expect("write bench fragment");
}

/// Parent mode: re-exec once per thread count, aggregate the fragments
/// into BENCH_decode.json, and print the acceptance verdict.
fn run_parent() {
    let fast = std::env::var("BDA_BENCH_FAST").is_ok();
    let np = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut counts: Vec<usize> = if fast {
        vec![1, np]
    } else {
        [1usize, 2, 4, 8].into_iter().filter(|&t| t < np).chain([np]).collect()
    };
    counts.dedup();

    println!(
        "Decode throughput sweep: thread counts {counts:?} × batch sizes \
         (machine parallelism {np}, fast={fast})"
    );

    let exe = std::env::current_exe().expect("current_exe");
    let mut fragments = Vec::new();
    for &t in &counts {
        let tmp = std::env::temp_dir().join(format!("bda_bench_decode_{t}.json"));
        println!("\n--- BDA_NUM_THREADS={t} ---");
        // Sweep cells must be independent of the parent's environment:
        // both engine knobs are reset explicitly per fragment (a parent
        // launched with BDA_PREFIX_CACHE=0 or a stale BDA_NUM_THREADS
        // must not leak into the children and skew the sweep).
        let status = std::process::Command::new(&exe)
            .env("BDA_NUM_THREADS", t.to_string())
            .env("BDA_PREFIX_CACHE", "1")
            .env("BDA_BENCH_OUT", &tmp)
            .status()
            .expect("spawn bench child");
        assert!(status.success(), "bench child for {t} threads failed");
        let text = std::fs::read_to_string(&tmp).expect("read child fragment");
        fragments.push(Json::parse(&text).expect("parse child fragment"));
        std::fs::remove_file(&tmp).ok();
    }

    // Acceptance: paged-attention speedup (blocked parallel kernel vs the
    // serial reference) at batch >= 8 on the max-thread configuration.
    let mut accept = f64::INFINITY;
    if let Some(frag) = fragments.last() {
        for row in frag.get("paged_attention").as_arr().unwrap_or(&[]) {
            let batch = row.get("batch").as_usize().unwrap_or(0);
            let speedup = row.get("speedup").as_f64().unwrap_or(0.0);
            if batch >= 8 {
                accept = accept.min(speedup);
            }
        }
    }
    let accept = if accept.is_finite() { accept } else { 0.0 };

    // Spawn-overhead vs parked-pool dispatch latency at the max-thread
    // configuration — the per-layer-per-step cost the pool amortizes.
    let dispatch_speedup = fragments
        .last()
        .map(|frag| frag.get("dispatch").get("speedup").as_f64().unwrap_or(0.0))
        .unwrap_or(0.0);

    // Prefix-cache acceptance from the max-thread fragment: hit rate and
    // the prefill-token reduction of the shared-prefix sweep.
    let (prefix_hit_rate, prefix_blocks_saved, prefill_reduction) = fragments
        .last()
        .map(|frag| {
            let pc = frag.get("prefix_cache");
            let cold = pc.get("prefill_tokens_cold").as_f64().unwrap_or(0.0);
            let cached = pc.get("prefill_tokens_cached").as_f64().unwrap_or(0.0);
            let reduction = if cold > 0.0 { 1.0 - cached / cold } else { 0.0 };
            (
                pc.get("hit_rate").as_f64().unwrap_or(0.0),
                pc.get("blocks_saved").as_f64().unwrap_or(0.0),
                reduction,
            )
        })
        .unwrap_or((0.0, 0.0, 0.0));

    // Preemption acceptance from the max-thread fragment: how much the
    // overload run preempted/recomputed, and the throughput it retained
    // relative to the ample-pool run (graceful degradation, not an error).
    let (preemptions, recomputed_tokens, overload_ratio) = fragments
        .last()
        .map(|frag| {
            let p = frag.get("preemption");
            (
                p.get("preemptions").as_f64().unwrap_or(0.0),
                p.get("recomputed_tokens").as_f64().unwrap_or(0.0),
                p.get("overload_throughput_ratio").as_f64().unwrap_or(0.0),
            )
        })
        .unwrap_or((0.0, 0.0, 0.0));

    // Chunked-prefill acceptance from the max-thread fragment: the decode
    // TBT tail of the chunked run relative to monolithic, and the prefill
    // tokens a fused step carried (bounded by the chunk budget, not the
    // prompt length).
    // K/V storage dtype acceptance from the max-thread fragment: pool-byte
    // halving at equal blocks, and the preemption win equal bytes buy.
    let (kv_bytes_ratio, kv_f16_fewer, kv_tok_s_f32, kv_tok_s_f16) = fragments
        .last()
        .map(|frag| {
            let k = frag.get("kv_dtype");
            let b32 = k.get("pool_bytes_f32").as_f64().unwrap_or(0.0);
            let b16 = k.get("pool_bytes_f16_equal_blocks").as_f64().unwrap_or(0.0);
            let p32 = k.get("preemptions_f32").as_f64().unwrap_or(0.0);
            let p16 = k.get("preemptions_f16_equal_budget").as_f64().unwrap_or(0.0);
            (
                if b16 > 0.0 { b32 / b16 } else { 0.0 },
                p32 > 0.0 && p16 < p32,
                k.get("decode_tok_s_f32").as_f64().unwrap_or(0.0),
                k.get("decode_tok_s_f16_equal_budget").as_f64().unwrap_or(0.0),
            )
        })
        .unwrap_or((0.0, false, 0.0, 0.0));

    // Sharded-scaling acceptance from the max-thread fragment: aggregate
    // throughput efficiency at the largest worker count (tok/s at N over
    // N × tok/s at 1), with per-request latency pinned per shard.
    let (sharded_efficiency, sharded_max_workers) = fragments
        .last()
        .map(|frag| {
            let s = frag.get("sharded_scaling");
            (
                s.get("scaling_efficiency_max_workers").as_f64().unwrap_or(0.0),
                s.get("max_workers").as_f64().unwrap_or(0.0),
            )
        })
        .unwrap_or((0.0, 0.0));

    let (chunked_tbt_p99_ratio, chunked_tok_per_step, mono_tok_per_step) = fragments
        .last()
        .map(|frag| {
            let c = frag.get("chunked_prefill");
            (
                c.get("tbt_p99_ratio_chunked_vs_monolithic").as_f64().unwrap_or(0.0),
                c.get("chunked_prefill_tokens_per_step").as_f64().unwrap_or(0.0),
                c.get("monolithic_prefill_tokens_per_step").as_f64().unwrap_or(0.0),
            )
        })
        .unwrap_or((0.0, 0.0, 0.0));

    let report = Json::obj(vec![
        ("bench", Json::str("decode_throughput")),
        ("fast", Json::Bool(fast)),
        ("available_parallelism", Json::num(np as f64)),
        ("runs", Json::Arr(fragments)),
        (
            "acceptance",
            Json::obj(vec![
                ("paged_attention_speedup_batch_ge8_max_threads", Json::num(accept)),
                ("parked_pool_dispatch_speedup_max_threads", Json::num(dispatch_speedup)),
                ("prefix_cache_hit_rate_max_threads", Json::num(prefix_hit_rate)),
                ("prefix_cache_blocks_saved_max_threads", Json::num(prefix_blocks_saved)),
                ("prefix_cache_prefill_reduction_max_threads", Json::num(prefill_reduction)),
                ("preemptions_overload_max_threads", Json::num(preemptions)),
                ("recomputed_tokens_overload_max_threads", Json::num(recomputed_tokens)),
                ("overload_throughput_ratio_max_threads", Json::num(overload_ratio)),
                ("chunked_prefill_tbt_p99_ratio_max_threads", Json::num(chunked_tbt_p99_ratio)),
                ("chunked_prefill_tokens_per_step_max_threads", Json::num(chunked_tok_per_step)),
                ("monolithic_prefill_tokens_per_step_max_threads", Json::num(mono_tok_per_step)),
                ("kv_f16_pool_bytes_ratio_vs_f32", Json::num(kv_bytes_ratio)),
                ("kv_f16_fewer_preemptions_equal_budget", Json::Bool(kv_f16_fewer)),
                ("kv_decode_tok_s_f32", Json::num(kv_tok_s_f32)),
                ("kv_decode_tok_s_f16_equal_budget", Json::num(kv_tok_s_f16)),
                ("sharded_scaling_efficiency_max_workers", Json::num(sharded_efficiency)),
                ("sharded_scaling_max_workers", Json::num(sharded_max_workers)),
                ("target", Json::num(2.0)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_decode.json", report.to_string()).expect("write BENCH_decode.json");
    println!(
        "prefix cache at {np} threads: {:.0}% hit rate, {prefix_blocks_saved:.0} blocks \
         deduped, prefill work reduced {:.0}%",
        prefix_hit_rate * 100.0,
        prefill_reduction * 100.0
    );
    println!(
        "\npaged attention at batch >= 8, {np} threads: {accept:.2}x vs serial reference \
         ({}) — recorded in BENCH_decode.json",
        if accept >= 2.0 { "MEETS the >=2x target" } else { "below the 2x target — investigate" }
    );
    println!(
        "parked-pool dispatch at {np} threads: {dispatch_speedup:.2}x faster than \
         scoped spawn/join per parallel region"
    );
    println!(
        "overload at {np} threads: {preemptions:.0} preemptions, \
         {recomputed_tokens:.0} tokens recomputed, {:.0}% of ample-pool throughput \
         retained (identical generations — invariant 5)",
        overload_ratio * 100.0
    );
    println!(
        "chunked prefill at {np} threads: tbt p99 at {:.2}x of monolithic, \
         prefill tok/step {mono_tok_per_step:.1} -> {chunked_tok_per_step:.1} \
         (identical generations — invariant 6)",
        chunked_tbt_p99_ratio
    );
    println!(
        "kv dtype at {np} threads: fp16 pool bytes {kv_bytes_ratio:.2}x smaller at equal \
         blocks; equal-budget fp16 preempts {} than fp32 \
         ({kv_tok_s_f32:.1} -> {kv_tok_s_f16:.1} tok/s under overload)",
        if kv_f16_fewer { "strictly less" } else { "no less (pool was ample)" }
    );
    println!(
        "sharded scaling: {sharded_efficiency:.2} aggregate-throughput efficiency at \
         {sharded_max_workers:.0} pool-shard workers (identical generations — invariant 8)"
    );
}

fn main() {
    match std::env::var("BDA_BENCH_OUT") {
        Ok(path) => run_child(&path),
        Err(_) => run_parent(),
    }
}
