//! Fig. 2a + Table 5: end-to-end perplexity after replacing all MHA layers
//! with BDA, per dtype (FP32/FP16/BF16) and strategy (First-r /
//! Residual-min), with the structured-pruning baseline (25% K/V channels)
//! as the dashed reference line, plus preparation times.
//!
//! Run: cargo bench --bench fig2a_table5_ppl

use bda::bd::Strategy;
use bda::bench_support::Table;
use bda::eval::corpus::Corpus;
use bda::eval::perplexity;
use bda::eval::ppl::ppl_increase_percent;
use bda::model::{ModelConfig, Transformer};
use bda::prepare::prepare_model;
use bda::tensor::DType;

fn main() {
    let fast = std::env::var("BDA_BENCH_FAST").is_ok();
    let mut config = ModelConfig::deepseek_lite_sim();
    let mut n_tokens = 4096;
    if fast {
        config = ModelConfig::tiny();
        n_tokens = 768;
    }
    println!(
        "Fig. 2a / Table 5 — PPL on tiny-wiki | model {} ({} params)",
        config.name,
        config.param_count()
    );
    let seq = config.max_seq_len.min(128);
    let model = Transformer::new_mha(config.clone(), 314);
    let corpus = Corpus::tiny_wiki(config.vocab_size, n_tokens, 2718);

    let base = perplexity(&model, &corpus.tokens, seq);
    println!("original PPL: {base:.6}");

    let mut t = Table::new(
        "Table 5 — end-to-end PPL (paper: FP32 +0.0004%, FP16 +0.02%, BF16 +0.2%)",
        &["dtype", "strategy", "BD PPL", "increase", "prep time (s)"],
    );
    let mut increases = std::collections::BTreeMap::new();
    for dt in [DType::F32, DType::F16, DType::BF16] {
        for strat in [Strategy::FirstR, Strategy::ResidualMin] {
            let rep = prepare_model(&model, strat, dt).expect("prepare");
            let p = perplexity(&rep.model, &corpus.tokens, seq);
            let inc = ppl_increase_percent(base, p);
            increases.insert((dt.name(), strat.name()), inc);
            println!(
                "  {} {:>13}: PPL {p:.6} ({inc:+.4}%) prep {:.2}s",
                dt.name(),
                strat.name(),
                rep.seconds
            );
            t.row(vec![
                dt.name().into(),
                strat.name().into(),
                format!("{p:.6}"),
                format!("{inc:+.4}%"),
                format!("{:.2}", rep.seconds),
            ]);
        }
    }
    t.print();

    // The dashed line of Fig. 2a: structured pruning at the same ratio.
    let pruned = model.to_pruned(0.25);
    let p_pruned = perplexity(&pruned, &corpus.tokens, seq);
    println!(
        "\nstructured-pruning baseline (25% K/V channels): PPL {p_pruned:.4} ({:+.2}%) — the Fig. 2a dashed line",
        ppl_increase_percent(base, p_pruned)
    );

    // Shape assertions.
    let f32_inc = increases[&("fp32", "Residual-min")].abs();
    let bf16_inc = increases[&("bf16", "Residual-min")].abs();
    assert!(f32_inc < 0.01, "fp32 increase should be negligible: {f32_inc}%");
    assert!(f32_inc <= bf16_inc + 1e-9, "precision ordering");
    let prune_inc = ppl_increase_percent(base, p_pruned).abs();
    assert!(
        prune_inc > bf16_inc,
        "pruning must degrade more than any BDA variant ({prune_inc}% vs {bf16_inc}%)"
    );
    println!("shape checks hold: fp32 ≈ lossless; BDA ≪ structured pruning  ✓");
}
